//! Domain example: capacity planning for a datacenter serving fleet.
//!
//! Given a mixed fleet of models (the paper's motivation: CPUs serve "a
//! large, diverse collection of DL use cases in production datacenter
//! fleets"), compute per-model tuned settings and the fleet-wide capacity
//! win over the one-size-fits-all recommended settings.
//!
//! ```sh
//! cargo run --release --example tune_and_compare
//! ```

use parframe::config::CpuPlatform;
use parframe::models;
use parframe::sim;
use parframe::tuner::{self, Baseline};
use parframe::util::stats;

/// A production fleet slice: (model, share of traffic).
const FLEET: [(&str, f64); 5] = [
    ("resnet50", 0.25),     // vision filtering
    ("inception_v3", 0.10), // vision tagging
    ("wide_deep", 0.30),    // ads ranking
    ("ncf", 0.25),          // feed recommendation
    ("transformer", 0.10),  // translation
];

fn main() {
    let platform = CpuPlatform::large2();
    println!("fleet capacity planning on {} ({} cores)\n", platform.name, platform.physical_cores());
    println!(
        "{:<14} {:>7} {:<30} {:>12} {:>12} {:>9}",
        "model", "share", "tuned setting", "tuned ms", "TF-rec ms", "speedup"
    );

    let mut weighted_speedup = Vec::new();
    let mut weights = Vec::new();
    for (name, share) in FLEET {
        let g = models::build(name, models::canonical_batch(name)).unwrap();
        let tuned = tuner::tune(&g, &platform);
        let ours = sim::simulate(&g, &platform, &tuned.config).latency_s;
        let rec = sim::simulate(
            &g,
            &platform,
            &tuner::baseline_config(Baseline::TensorFlowRecommended, &platform),
        )
        .latency_s;
        let setting = format!(
            "{}p x {}mkl x {}intra [{}]",
            tuned.config.inter_op_pools,
            tuned.config.mkl_threads,
            tuned.config.intra_op_threads,
            tuned.config.sched_policy.name()
        );
        println!(
            "{:<14} {:>6.0}% {:<30} {:>12.3} {:>12.3} {:>8.2}x",
            name,
            share * 100.0,
            setting,
            ours * 1e3,
            rec * 1e3,
            rec / ours
        );
        weighted_speedup.push((rec / ours).ln() * share);
        weights.push(share);
    }
    let fleet_gain =
        (weighted_speedup.iter().sum::<f64>() / weights.iter().sum::<f64>()).exp();
    println!(
        "\ntraffic-weighted fleet speedup from per-model tuning: {:.2}x",
        fleet_gain
    );
    println!(
        "(equivalently: {:.1}% of the serving fleet's machines freed)",
        (1.0 - 1.0 / fleet_gain) * 100.0
    );
    let _ = stats::mean(&weights); // touch stats to show the util API
}
