//! Domain example: capacity planning for a datacenter serving fleet.
//!
//! Given a mixed fleet of models (the paper's motivation: CPUs serve "a
//! large, diverse collection of DL use cases in production datacenter
//! fleets"), compute per-model tuned settings and the fleet-wide capacity
//! win over the one-size-fits-all recommended settings.
//!
//! ```sh
//! cargo run --release --example tune_and_compare
//! ```

use std::sync::Arc;
use std::time::Instant;

use parframe::config::CpuPlatform;
use parframe::models;
use parframe::sim::{self, SimCache};
use parframe::tuner::{self, Baseline, SweepOptions};
use parframe::util::stats;

/// A production fleet slice: (model, share of traffic).
const FLEET: [(&str, f64); 5] = [
    ("resnet50", 0.25),     // vision filtering
    ("inception_v3", 0.10), // vision tagging
    ("wide_deep", 0.30),    // ads ranking
    ("ncf", 0.25),          // feed recommendation
    ("transformer", 0.10),  // translation
];

fn main() {
    let platform = CpuPlatform::large2();
    println!("fleet capacity planning on {} ({} cores)\n", platform.name, platform.physical_cores());
    println!(
        "{:<14} {:>7} {:<30} {:>12} {:>12} {:>9}",
        "model", "share", "tuned setting", "tuned ms", "TF-rec ms", "speedup"
    );

    let mut weighted_speedup = Vec::new();
    let mut weights = Vec::new();
    for (name, share) in FLEET {
        let g = models::build(name, models::canonical_batch(name)).unwrap();
        let tuned = tuner::tune(&g, &platform);
        let ours = sim::simulate(&g, &platform, &tuned.config).latency_s;
        let rec = sim::simulate(
            &g,
            &platform,
            &tuner::baseline_config(Baseline::TensorFlowRecommended, &platform),
        )
        .latency_s;
        let setting = format!(
            "{}p x {}mkl x {}intra [{}]",
            tuned.config.inter_op_pools,
            tuned.config.mkl_threads,
            tuned.config.intra_op_threads,
            tuned.config.sched_policy.name()
        );
        println!(
            "{:<14} {:>6.0}% {:<30} {:>12.3} {:>12.3} {:>8.2}x",
            name,
            share * 100.0,
            setting,
            ours * 1e3,
            rec * 1e3,
            rec / ours
        );
        weighted_speedup.push((rec / ours).ln() * share);
        weights.push(share);
    }
    let fleet_gain =
        (weighted_speedup.iter().sum::<f64>() / weights.iter().sum::<f64>()).exp();
    println!(
        "\ntraffic-weighted fleet speedup from per-model tuning: {:.2}x",
        fleet_gain
    );
    println!(
        "(equivalently: {:.1}% of the serving fleet's machines freed)",
        (1.0 - 1.0 / fleet_gain) * 100.0
    );
    let _ = stats::mean(&weights); // touch stats to show the util API

    // how close is the one-shot guideline to the swept global optimum?
    // (the parallel, memoized sweep makes this affordable fleet-wide: one
    // shared cache, every model's lattice fanned over the worker pool)
    let jobs = tuner::default_jobs();
    let cache = Arc::new(SimCache::new());
    println!("\nguideline vs exhaustive optimum (jobs={jobs}, shared sim cache):");
    let t0 = Instant::now();
    for (name, _) in FLEET {
        let g = models::build(name, models::canonical_batch(name)).unwrap();
        let tuned = tuner::tune(&g, &platform);
        let guided = sim::simulate(&g, &platform, &tuned.config).latency_s;
        let opt = tuner::exhaustive_search_with(
            &g,
            &platform,
            &SweepOptions::shared(jobs, Arc::clone(&cache)),
        );
        println!(
            "  {:<14} optimum {:>9.3} ms over {:>4} points — guideline at {:.3}x",
            name,
            opt.best_latency_s * 1e3,
            opt.evaluated,
            guided / opt.best_latency_s
        );
    }
    println!(
        "  swept {} simulations ({} deduped as cache hits) in {:.2}s",
        cache.misses(),
        cache.hits(),
        t0.elapsed().as_secs_f64()
    );
}
