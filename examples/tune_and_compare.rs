//! Domain example: capacity planning for a datacenter serving fleet —
//! driven through the `parframe::api` facade.
//!
//! Given a mixed fleet of models (the paper's motivation: CPUs serve "a
//! large, diverse collection of DL use cases in production datacenter
//! fleets"), compute per-model tuned plans and the fleet-wide capacity
//! win over the one-size-fits-all recommended settings. One [`Session`]
//! holds the shared simulation cache, so every tier of every model's
//! tuning dedupes against the others.
//!
//! ```sh
//! cargo run --release --example tune_and_compare
//! ```

use std::time::Instant;

use parframe::api::{Session, Workload};
use parframe::tuner::Baseline;
use parframe::util::stats;
use parframe::PallasResult;

/// A production fleet slice: (model, share of traffic).
const FLEET: [(&str, f64); 5] = [
    ("resnet50", 0.25),     // vision filtering
    ("inception_v3", 0.10), // vision tagging
    ("wide_deep", 0.30),    // ads ranking
    ("ncf", 0.25),          // feed recommendation
    ("transformer", 0.10),  // translation
];

fn main() -> PallasResult<()> {
    let session = Session::builder().platform_named("large.2")?.build();
    let platform = session.platform().clone();
    println!(
        "fleet capacity planning on {} ({} cores)\n",
        platform.name,
        platform.physical_cores()
    );
    println!(
        "{:<14} {:>7} {:<30} {:>12} {:>12} {:>9}",
        "model", "share", "tuned setting", "tuned ms", "TF-rec ms", "speedup"
    );

    let mut weighted_speedup = Vec::new();
    let mut weights = Vec::new();
    for (name, share) in FLEET {
        let w = Workload::single(name)?;
        let tuned = session.tune(&w)?;
        let e = &tuned.entries[0];
        let ours = e.predicted_latency_s;
        let rec = session.tune_baseline(&w, Baseline::TensorFlowRecommended)?.entries[0]
            .predicted_latency_s;
        let setting = format!(
            "{}p x {}mkl x {}intra [{}]",
            e.config.inter_op_pools,
            e.config.mkl_threads,
            e.config.intra_op_threads,
            e.config.sched_policy.name()
        );
        println!(
            "{:<14} {:>6.0}% {:<30} {:>12.3} {:>12.3} {:>8.2}x",
            name,
            share * 100.0,
            setting,
            ours * 1e3,
            rec * 1e3,
            rec / ours
        );
        weighted_speedup.push((rec / ours).ln() * share);
        weights.push(share);
    }
    let fleet_gain =
        (weighted_speedup.iter().sum::<f64>() / weights.iter().sum::<f64>()).exp();
    println!(
        "\ntraffic-weighted fleet speedup from per-model tuning: {:.2}x",
        fleet_gain
    );
    println!(
        "(equivalently: {:.1}% of the serving fleet's machines freed)",
        (1.0 - 1.0 / fleet_gain) * 100.0
    );
    let _ = stats::mean(&weights); // touch stats to show the util API

    // how close is the one-shot guideline to the swept global optimum?
    // (the session's shared cache makes this affordable fleet-wide: every
    // model's lattice fans over the worker pool and dedupes design points
    // the guideline/baseline tiers already simulated)
    println!(
        "\nguideline vs exhaustive optimum (jobs={}, shared session cache):",
        session.jobs()
    );
    let t0 = Instant::now();
    for (name, _) in FLEET {
        let w = Workload::single(name)?;
        let guided = session.tune(&w)?.entries[0].predicted_latency_s;
        let opt = session.tune_exhaustive(&w)?;
        println!(
            "  {:<14} optimum {:>9.3} ms over {:>4} points — guideline at {:.3}x",
            name,
            opt.entries[0].predicted_latency_s * 1e3,
            opt.evaluated,
            guided / opt.entries[0].predicted_latency_s
        );
    }
    println!(
        "  swept {} simulations ({} deduped as cache hits) in {:.2}s",
        session.cache().misses(),
        session.cache().hits(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
