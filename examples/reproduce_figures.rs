//! Regenerate the paper's full evaluation: every figure and table, written
//! to stdout and to `figures_out/` as text files (plus a Chrome trace for
//! Fig. 8 you can load in `chrome://tracing`).
//!
//! ```sh
//! cargo run --release --example reproduce_figures
//! ```

use std::fs;
use std::io::Write as _;

use parframe::bench_tables;
use parframe::config::{CpuPlatform, FrameworkConfig, OperatorImpl};
use parframe::models;
use parframe::sim::{self, SimOptions};
use parframe::trace;

fn main() -> anyhow::Result<()> {
    let out_dir = std::path::Path::new("figures_out");
    fs::create_dir_all(out_dir)?;

    for n in bench_tables::FIGURES {
        let s = bench_tables::figure(n).unwrap();
        println!("{s}");
        fs::write(out_dir.join(format!("fig{n:02}.txt")), &s)?;
    }
    let t2 = bench_tables::table(2).unwrap();
    println!("{t2}");
    fs::write(out_dir.join("table02.txt"), &t2)?;
    let t3 = bench_tables::table(3).unwrap();
    println!("{t3}");
    fs::write(out_dir.join("table03.txt"), &t3)?;

    // bonus: interactive Chrome trace of the Fig. 8 best case
    let p = CpuPlatform::small();
    let g = models::build("inception_v2", 16).unwrap();
    let cfg = FrameworkConfig {
        inter_op_pools: 2,
        mkl_threads: 2,
        intra_op_threads: 1,
        operator_impl: OperatorImpl::Serial,
        ..FrameworkConfig::tuned_default()
    };
    let r = sim::simulate_opts(&g, &p, &cfg, &SimOptions { record_timelines: true })?;
    let mut f = fs::File::create(out_dir.join("fig08_2x2.trace.json"))?;
    f.write_all(trace::chrome_trace(&r.timelines).as_bytes())?;
    println!("wrote figures_out/*.txt and fig08_2x2.trace.json (chrome://tracing)");
    Ok(())
}
