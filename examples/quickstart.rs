//! Quickstart: the 60-second tour of parframe's public API.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! 1. Build a model graph from the zoo and analyse its width.
//! 2. Tune framework knobs with the paper's guideline.
//! 3. Simulate it against the recommended baselines.
//! 4. If AOT artifacts exist, run real numerics through PJRT.

use parframe::config::CpuPlatform;
use parframe::graph::analyze_width;
use parframe::models;
use parframe::runtime::ModelRuntime;
use parframe::sim;
use parframe::tuner;

fn main() -> anyhow::Result<()> {
    // 1. a model graph
    let platform = CpuPlatform::large2();
    let graph = models::build("wide_deep", 16).expect("model in zoo");
    let width = analyze_width(&graph);
    println!("wide_deep: {} ops, {} heavy, avg width {}", graph.len(), width.heavy_ops, width.avg_width);

    // 2. tune (paper §8: pools = avg width, threads = cores / pools;
    //    wide graphs also get critical-path-first dispatch)
    let tuned = tuner::tune(&graph, &platform);
    println!(
        "guideline setting: {} pools × ({} MKL + {} intra-op) threads, {} dispatch",
        tuned.config.inter_op_pools,
        tuned.config.mkl_threads,
        tuned.config.intra_op_threads,
        tuned.config.sched_policy.name()
    );

    // 3. simulate vs the published recommendations
    let ours = sim::simulate(&graph, &platform, &tuned.config);
    println!("simulated latency: {:.3} ms", ours.latency_s * 1e3);
    for b in tuner::Baseline::ALL {
        let r = sim::simulate(&graph, &platform, &tuner::baseline_config(b, &platform));
        println!("  {:<26} {:>8.3} ms ({:.2}x ours)", b.name(), r.latency_s * 1e3, r.latency_s / ours.latency_s);
    }

    // 4. real numerics (build-time artifacts, PJRT CPU)
    let dir = std::path::Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        let rt = ModelRuntime::load_some(dir, |e| e.name == "mlp_b1")?;
        rt.self_check("mlp_b1")?;
        println!("PJRT check: mlp_b1 digest verified on {}", rt.platform());
    } else {
        println!("(run `make artifacts` to enable the PJRT quickstart step)");
    }
    Ok(())
}
