//! Quickstart: the 60-second tour of the `parframe::api` facade.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! 1. Open a [`Session`] on a platform and describe a [`Workload`].
//! 2. Tune it with the paper's §8 guideline → a serializable [`Plan`].
//! 3. Compare against the published baseline recommendations.
//! 4. Round-trip the plan through JSON — the tune-once/serve-many artifact.
//! 5. If AOT artifacts exist, run real numerics through PJRT.

use parframe::api::{Plan, Session, Workload};
use parframe::runtime::ModelRuntime;
use parframe::tuner::Baseline;
use parframe::PallasResult;

fn main() -> PallasResult<()> {
    // 1. a session (owns the platform + simulation cache) and a workload
    let session = Session::builder().platform_named("large.2")?.build();
    let workload = Workload::single("wide_deep")?;

    // 2. tune (paper §8: pools = avg width, threads = cores / pools;
    //    wide graphs also get critical-path-first dispatch)
    let plan = session.tune(&workload)?;
    let e = &plan.entries[0];
    println!(
        "guideline setting for {}: {} pools × ({} MKL + {} intra-op) threads, {} dispatch",
        e.kind,
        e.config.inter_op_pools,
        e.config.mkl_threads,
        e.config.intra_op_threads,
        e.config.sched_policy.name()
    );
    println!("simulated latency: {:.3} ms", e.predicted_latency_s * 1e3);

    // 3. versus the published recommendations
    for b in Baseline::ALL {
        let r = session.tune_baseline(&workload, b)?;
        let lat = r.entries[0].predicted_latency_s;
        println!(
            "  {:<26} {:>8.3} ms ({:.2}x ours)",
            b.name(),
            lat * 1e3,
            lat / e.predicted_latency_s
        );
    }

    // 4. the plan is an artifact: JSON round-trip is bit-identical, so
    //    `tune --emit-plan` in one process serves unchanged in another
    let restored = Plan::from_json(&plan.to_json())?;
    assert_eq!(restored, plan);
    println!(
        "plan round-trips through JSON ({} bytes, tier {})",
        plan.to_json().len(),
        plan.tier.name()
    );

    // 5. real numerics (build-time artifacts, PJRT CPU)
    let dir = std::path::Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        let rt = ModelRuntime::load_some(dir, |e| e.name == "mlp_b1")?;
        rt.self_check("mlp_b1")?;
        println!("PJRT check: mlp_b1 digest verified on {}", rt.platform());
    } else {
        println!("(run `make artifacts` to enable the PJRT quickstart step)");
    }
    Ok(())
}
