//! END-TO-END driver (DESIGN.md §Experiment index, row "E2E"): serve a
//! workload through the full three-layer stack and report the
//! latency/throughput table — with **zero external artifacts**, on the
//! simulation backend.
//!
//! Path exercised: seeded load generator (closed- and open-loop) → router
//! → dynamic batcher (bucketed batching, max-wait) → worker lanes
//! executing on `SimBackend` (per-batch latency from the discrete-event
//! simulator under tuner-chosen framework knobs) → per-request latency
//! accounting.
//!
//! ```sh
//! cargo run --release --example serve_workload
//! ```
//!
//! With AOT artifacts built (`make artifacts`), swap the config for
//! `CoordinatorConfig::pjrt("artifacts", &["mlp"])` to drive the same
//! harness over PJRT.

use std::time::Duration;

use parframe::config::CpuPlatform;
use parframe::coordinator::{
    loadgen, BatchPolicy, Coordinator, CoordinatorConfig, LoadgenConfig, MixPhase, MixReport,
};
use parframe::sched::LanePlan;
use parframe::tuner::{OnlineTuner, OnlineTunerConfig};

fn coordinator(kind: &str, lanes: usize) -> anyhow::Result<Coordinator> {
    let mut cfg = CoordinatorConfig::sim(CpuPlatform::large2(), &[kind]);
    cfg.lanes = lanes;
    cfg.policy = BatchPolicy { max_wait: Duration::from_millis(2), max_batch: usize::MAX };
    Coordinator::start(cfg)
}

fn main() -> anyhow::Result<()> {
    println!("end-to-end serving driver (sim backend, large.2, tuner-chosen knobs)\n");
    println!(
        "{:<12} {:<14} {:>11} {:>10} {:>10} {:>10} {:>11}",
        "model", "arrival", "achieved/s", "p50 ms", "p99 ms", "mean ms", "mean batch"
    );

    // closed loop: rising concurrency fills batches (the paper's §2.2.3
    // request-level parallelism mapped onto the batch dimension)
    for concurrency in [1usize, 4, 16] {
        let coord = coordinator("wide_deep", 1)?;
        let cfg = LoadgenConfig::closed("wide_deep", 256, concurrency).with_seed(42);
        let r = loadgen::run(&coord, &cfg)?;
        anyhow::ensure!(r.errors == 0, "closed-loop errors: {}", r.errors);
        println!(
            "{:<12} {:<14} {:>11.0} {:>10.3} {:>10.3} {:>10.3} {:>11.2}",
            "wide_deep",
            format!("closed x{concurrency}"),
            r.throughput_rps,
            r.model_p50_ms,
            r.model_p99_ms,
            r.model_mean_ms,
            r.mean_batch
        );
    }

    // open loop: Poisson arrivals at rising offered rates
    for rate in [200.0f64, 1000.0, 4000.0] {
        let coord = coordinator("wide_deep", 1)?;
        let r =
            loadgen::run(&coord, &LoadgenConfig::open("wide_deep", 256, rate).with_seed(7))?;
        anyhow::ensure!(r.errors == 0, "open-loop errors: {}", r.errors);
        println!(
            "{:<12} {:<14} {:>11.0} {:>10.3} {:>10.3} {:>10.3} {:>11.2}",
            "wide_deep",
            format!("open {rate:.0}/s"),
            r.throughput_rps,
            r.model_p50_ms,
            r.model_p99_ms,
            r.model_mean_ms,
            r.mean_batch
        );
    }

    // a sequence model rides the same path (32 rows per item)
    let coord = coordinator("transformer", 2)?;
    let r = loadgen::run(&coord, &LoadgenConfig::closed("transformer", 48, 8))?;
    anyhow::ensure!(r.errors == 0, "transformer errors: {}", r.errors);
    println!(
        "{:<12} {:<14} {:>11.0} {:>10.3} {:>10.3} {:>10.3} {:>11.2}",
        "transformer",
        "closed x8",
        r.throughput_rps,
        r.model_p50_ms,
        r.model_p99_ms,
        r.model_mean_ms,
        r.mean_batch
    );

    println!("\n(batching kicks in as offered load rises: mean batch grows, per-request");
    println!(" throughput scales — the paper's §2.2.3 request-level parallelism.)");

    // core-aware lanes + online re-tuning: resnet50 ramps up while
    // wide_deep drains; the adaptive run re-splits cores between phases,
    // the frozen run keeps the startup §8 split
    println!("\nadaptive vs frozen core-aware lanes under a load shift (large.2):");
    let frozen = run_shift(false)?;
    let adaptive = run_shift(true)?;
    let f = frozen.kind("resnet50").expect("hot kind served");
    let a = adaptive.kind("resnet50").expect("hot kind served");
    println!(
        "  final phase, hot kind resnet50: frozen mean {:.3} ms | adaptive mean {:.3} ms ({:.2}x)",
        f.model_mean_ms,
        a.model_mean_ms,
        f.model_mean_ms / a.model_mean_ms
    );
    Ok(())
}

/// Drive the shifting mix through `loadgen::run_shift`; re-tune between
/// phases when `adaptive`. Returns the final (post-shift, steady) phase
/// report.
fn run_shift(adaptive: bool) -> anyhow::Result<MixReport> {
    let platform = CpuPlatform::large2();
    let kinds = ["wide_deep", "resnet50"];
    let plan = LanePlan::guideline(&platform, &kinds)?;
    let coord =
        Coordinator::start(CoordinatorConfig::sim(platform.clone(), &kinds).with_plan(plan))?;
    let mut phases = vec![MixPhase::new(&[("wide_deep", 0.9), ("resnet50", 0.1)], 48)];
    phases.extend(std::iter::repeat_with(|| {
        MixPhase::new(&[("wide_deep", 0.1), ("resnet50", 0.9)], 64)
    })
    .take(3));
    let mut tuner = OnlineTuner::with_config(
        platform,
        &kinds,
        OnlineTunerConfig { smoothing: 0.7, ..OnlineTunerConfig::default() },
    );
    let reports = loadgen::run_shift(
        &coord,
        &phases,
        8,
        0x5EED,
        if adaptive { Some(&mut tuner) } else { None },
    )?;
    for r in &reports {
        anyhow::ensure!(r.overall.errors == 0, "mix errors: {}", r.overall.errors);
    }
    Ok(reports.into_iter().last().expect("at least one phase"))
}
