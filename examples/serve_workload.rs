//! END-TO-END driver (DESIGN.md §Experiment index, row "E2E"): serve a
//! workload through the full three-layer stack and report the
//! latency/throughput table — with **zero external artifacts**, on the
//! simulation backend, driven entirely through the `parframe::api`
//! facade.
//!
//! Path exercised: seeded load generator (closed- and open-loop) → router
//! → dynamic batcher (bucketed batching, max-wait) → worker lanes
//! executing on `SimBackend` (per-batch latency from the discrete-event
//! simulator under tuner-chosen framework knobs) → per-request latency
//! accounting.
//!
//! ```sh
//! cargo run --release --example serve_workload
//! ```
//!
//! With AOT artifacts built (`make artifacts`), swap the config for
//! `CoordinatorConfig::pjrt("artifacts", &["mlp"])` to drive the same
//! harness over PJRT.

use parframe::api::{ServeHandle, Session, Workload};
use parframe::coordinator::{loadgen, LoadgenConfig, MixPhase, MixReport};
use parframe::tuner::OnlineTunerConfig;
use parframe::{PallasError, PallasResult};

fn main() -> PallasResult<()> {
    // ONE session for the whole driver: every deployment below shares
    // its simulation cache, so repeated wide_deep table builds dedupe
    let session = Session::builder().platform_named("large.2")?.build();
    println!("end-to-end serving driver (sim backend, large.2, tuner-chosen knobs)\n");
    println!(
        "{:<12} {:<14} {:>11} {:>10} {:>10} {:>10} {:>11}",
        "model", "arrival", "achieved/s", "p50 ms", "p99 ms", "mean ms", "mean batch"
    );

    // closed loop: rising concurrency fills batches (the paper's §2.2.3
    // request-level parallelism mapped onto the batch dimension)
    for concurrency in [1usize, 4, 16] {
        let handle = session.serve_unplanned(&["wide_deep"], 1)?;
        let r = handle.run_closed("wide_deep", 256, concurrency)?;
        ensure_no_errors(r.errors, "closed-loop")?;
        print_row("wide_deep", &format!("closed x{concurrency}"), &r);
    }

    // open loop: Poisson arrivals at rising offered rates (loadgen's
    // open loop drives the facade's coordinator directly)
    for rate in [200.0f64, 1000.0, 4000.0] {
        let handle = session.serve_unplanned(&["wide_deep"], 1)?;
        let r = loadgen::run(
            handle.coordinator(),
            &LoadgenConfig::open("wide_deep", 256, rate).with_seed(7),
        )?;
        ensure_no_errors(r.errors, "open-loop")?;
        print_row("wide_deep", &format!("open {rate:.0}/s"), &r);
    }

    // a sequence model rides the same path (32 rows per item)
    let handle = session.serve_unplanned(&["transformer"], 2)?;
    let r = handle.run_closed("transformer", 48, 8)?;
    ensure_no_errors(r.errors, "transformer")?;
    print_row("transformer", "closed x8", &r);

    println!("\n(batching kicks in as offered load rises: mean batch grows, per-request");
    println!(" throughput scales — the paper's §2.2.3 request-level parallelism.)");

    // core-aware lanes + online re-tuning: resnet50 ramps up while
    // wide_deep drains; the adaptive run re-splits cores between phases,
    // the frozen run keeps the startup §8 split
    println!("\nadaptive vs frozen core-aware lanes under a load shift (large.2):");
    let frozen = run_shift(&session, false)?;
    let adaptive = run_shift(&session, true)?;
    let f = frozen.kind("resnet50").expect("hot kind served");
    let a = adaptive.kind("resnet50").expect("hot kind served");
    println!(
        "  final phase, hot kind resnet50: frozen mean {:.3} ms | adaptive mean {:.3} ms ({:.2}x)",
        f.model_mean_ms,
        a.model_mean_ms,
        f.model_mean_ms / a.model_mean_ms
    );
    Ok(())
}

fn print_row(model: &str, arrival: &str, r: &parframe::coordinator::LoadReport) {
    println!(
        "{:<12} {:<14} {:>11.0} {:>10.3} {:>10.3} {:>10.3} {:>11.2}",
        model,
        arrival,
        r.throughput_rps,
        r.model_p50_ms,
        r.model_p99_ms,
        r.model_mean_ms,
        r.mean_batch
    );
}

fn ensure_no_errors(errors: usize, what: &str) -> PallasResult<()> {
    if errors > 0 {
        return Err(PallasError::Backend(format!("{what} errors: {errors}")));
    }
    Ok(())
}

/// Tune-once/serve the shifting mix through the facade; re-tune between
/// phases when `adaptive` (with a heavier EWMA weight so the controller
/// chases the ramp quickly). Returns the final (post-shift, steady)
/// phase report.
fn run_shift(session: &Session, adaptive: bool) -> PallasResult<MixReport> {
    let plan = session.tune(&Workload::kinds(&["wide_deep", "resnet50"])?)?;
    let handle: ServeHandle = session.serve(&plan)?;
    let mut phases = vec![MixPhase::new(&[("wide_deep", 0.9), ("resnet50", 0.1)], 48)];
    phases.extend(std::iter::repeat_with(|| {
        MixPhase::new(&[("wide_deep", 0.1), ("resnet50", 0.9)], 64)
    })
    .take(3));
    let tuner_cfg =
        adaptive.then(|| OnlineTunerConfig { smoothing: 0.7, ..OnlineTunerConfig::default() });
    let reports = handle.run_shift_with(&phases, 8, 0x5EED, tuner_cfg)?;
    for r in &reports {
        ensure_no_errors(r.overall.errors, "mix")?;
    }
    Ok(reports.into_iter().last().expect("at least one phase"))
}
