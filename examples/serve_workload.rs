//! END-TO-END driver (DESIGN.md §Experiment index, row "E2E"): serve a
//! workload through the full three-layer stack and report the
//! latency/throughput table — with **zero external artifacts**, on the
//! simulation backend.
//!
//! Path exercised: seeded load generator (closed- and open-loop) → router
//! → dynamic batcher (bucketed batching, max-wait) → worker lanes
//! executing on `SimBackend` (per-batch latency from the discrete-event
//! simulator under tuner-chosen framework knobs) → per-request latency
//! accounting.
//!
//! ```sh
//! cargo run --release --example serve_workload
//! ```
//!
//! With AOT artifacts built (`make artifacts`), swap the config for
//! `CoordinatorConfig::pjrt("artifacts", &["mlp"])` to drive the same
//! harness over PJRT.

use std::time::Duration;

use parframe::config::CpuPlatform;
use parframe::coordinator::{loadgen, BatchPolicy, Coordinator, CoordinatorConfig, LoadgenConfig};

fn coordinator(kind: &str, lanes: usize) -> anyhow::Result<Coordinator> {
    let mut cfg = CoordinatorConfig::sim(CpuPlatform::large2(), &[kind]);
    cfg.lanes = lanes;
    cfg.policy = BatchPolicy { max_wait: Duration::from_millis(2), max_batch: usize::MAX };
    Coordinator::start(cfg)
}

fn main() -> anyhow::Result<()> {
    println!("end-to-end serving driver (sim backend, large.2, tuner-chosen knobs)\n");
    println!(
        "{:<12} {:<14} {:>11} {:>10} {:>10} {:>10} {:>11}",
        "model", "arrival", "achieved/s", "p50 ms", "p99 ms", "mean ms", "mean batch"
    );

    // closed loop: rising concurrency fills batches (the paper's §2.2.3
    // request-level parallelism mapped onto the batch dimension)
    for concurrency in [1usize, 4, 16] {
        let coord = coordinator("wide_deep", 1)?;
        let cfg = LoadgenConfig::closed("wide_deep", 256, concurrency).with_seed(42);
        let r = loadgen::run(&coord, &cfg)?;
        anyhow::ensure!(r.errors == 0, "closed-loop errors: {}", r.errors);
        println!(
            "{:<12} {:<14} {:>11.0} {:>10.3} {:>10.3} {:>10.3} {:>11.2}",
            "wide_deep",
            format!("closed x{concurrency}"),
            r.throughput_rps,
            r.model_p50_ms,
            r.model_p99_ms,
            r.model_mean_ms,
            r.mean_batch
        );
    }

    // open loop: Poisson arrivals at rising offered rates
    for rate in [200.0f64, 1000.0, 4000.0] {
        let coord = coordinator("wide_deep", 1)?;
        let r =
            loadgen::run(&coord, &LoadgenConfig::open("wide_deep", 256, rate).with_seed(7))?;
        anyhow::ensure!(r.errors == 0, "open-loop errors: {}", r.errors);
        println!(
            "{:<12} {:<14} {:>11.0} {:>10.3} {:>10.3} {:>10.3} {:>11.2}",
            "wide_deep",
            format!("open {rate:.0}/s"),
            r.throughput_rps,
            r.model_p50_ms,
            r.model_p99_ms,
            r.model_mean_ms,
            r.mean_batch
        );
    }

    // a sequence model rides the same path (32 rows per item)
    let coord = coordinator("transformer", 2)?;
    let r = loadgen::run(&coord, &LoadgenConfig::closed("transformer", 48, 8))?;
    anyhow::ensure!(r.errors == 0, "transformer errors: {}", r.errors);
    println!(
        "{:<12} {:<14} {:>11.0} {:>10.3} {:>10.3} {:>10.3} {:>11.2}",
        "transformer",
        "closed x8",
        r.throughput_rps,
        r.model_p50_ms,
        r.model_p99_ms,
        r.model_mean_ms,
        r.mean_batch
    );

    println!("\n(batching kicks in as offered load rises: mean batch grows, per-request");
    println!(" throughput scales — the paper's §2.2.3 request-level parallelism.)");
    Ok(())
}
