//! END-TO-END driver (DESIGN.md §Experiment index, row "E2E"): serve a
//! real workload through the full three-layer stack and report the
//! latency/throughput table.
//!
//! Path exercised: Poisson request generator → router → dynamic batcher
//! (bucketed to the AOT batch sizes) → PJRT worker lanes executing the
//! JAX/Pallas-compiled artifacts → per-request latency accounting.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_workload
//! ```

use std::path::Path;
use std::time::{Duration, Instant};

use parframe::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig};
use parframe::runtime::gen_input;
use parframe::util::prng::Prng;
use parframe::util::stats;

struct RunSummary {
    kind: &'static str,
    offered_rps: f64,
    achieved_rps: f64,
    p50_ms: f64,
    p95_ms: f64,
    p99_ms: f64,
    mean_batch: f64,
}

fn drive(kind: &'static str, n_requests: usize, offered_rps: f64) -> anyhow::Result<RunSummary> {
    let mut cfg = CoordinatorConfig::for_kind("artifacts", kind);
    cfg.policy = BatchPolicy { max_wait: Duration::from_millis(2), max_batch: usize::MAX };
    let coord = Coordinator::start(cfg)?;
    let shape = coord.router().item_shape(kind).unwrap().clone();
    let dims: Vec<usize> = std::iter::once(shape.rows_per_item)
        .chain(shape.feature_dims.iter().copied())
        .collect();

    // Poisson arrivals at the offered rate
    let mut rng = Prng::new(7);
    let t0 = Instant::now();
    let mut rxs = Vec::with_capacity(n_requests);
    let mut next_arrival = 0.0f64;
    for i in 0..n_requests {
        next_arrival += rng.exp(1.0 / offered_rps);
        let now = t0.elapsed().as_secs_f64();
        if next_arrival > now {
            std::thread::sleep(Duration::from_secs_f64(next_arrival - now));
        }
        let input = gen_input(i as u32 % 977, &dims, 1.0);
        rxs.push(coord.submit(kind, input)?);
    }
    let mut latencies = Vec::with_capacity(n_requests);
    for rx in rxs {
        let resp = rx.recv()?;
        anyhow::ensure!(resp.is_ok(), "request failed: {:?}", resp.output.err());
        latencies.push(resp.queue_s + resp.execute_s);
    }
    let wall = t0.elapsed().as_secs_f64();
    Ok(RunSummary {
        kind,
        offered_rps,
        achieved_rps: n_requests as f64 / wall,
        p50_ms: stats::median(&latencies) * 1e3,
        p95_ms: stats::percentile(&latencies, 95.0) * 1e3,
        p99_ms: stats::percentile(&latencies, 99.0) * 1e3,
        mean_batch: coord.metrics().mean_batch_size(),
    })
}

fn main() -> anyhow::Result<()> {
    if !Path::new("artifacts/manifest.json").exists() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(1);
    }
    println!("end-to-end serving driver (PJRT CPU, AOT JAX/Pallas artifacts)\n");
    println!(
        "{:<12} {:>11} {:>11} {:>9} {:>9} {:>9} {:>11}",
        "model", "offered/s", "achieved/s", "p50 ms", "p95 ms", "p99 ms", "mean batch"
    );
    // the MLP ranker at three load levels; the transformer at one
    for (kind, n, rps) in [
        ("mlp", 200, 200.0),
        ("mlp", 200, 1000.0),
        ("mlp", 200, 4000.0),
        ("transformer", 24, 8.0),
    ] {
        let s = drive(kind, n, rps)?;
        println!(
            "{:<12} {:>11.0} {:>11.0} {:>9.2} {:>9.2} {:>9.2} {:>11.2}",
            s.kind, s.offered_rps, s.achieved_rps, s.p50_ms, s.p95_ms, s.p99_ms, s.mean_batch
        );
    }
    println!("\n(batching kicks in as offered load rises: mean batch grows, per-request");
    println!(" throughput scales — the paper's §2.2.3 request-level parallelism.)");
    Ok(())
}
