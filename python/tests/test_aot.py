"""AOT export contract: HLO text artifacts + manifest digests.

Runs the full exporter into a temp dir (session-scoped: it is the expensive
part) and checks the manifest is exactly what the Rust loader
(rust/src/runtime/artifact.rs) expects.
"""
from __future__ import annotations

import json
import math
import os

import numpy as np
import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="session")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build_all(str(out))
    return str(out), manifest


class TestManifest:
    def test_artifact_files_exist(self, built):
        out, manifest = built
        for e in manifest["artifacts"]:
            p = os.path.join(out, e["file"])
            assert os.path.exists(p), e["file"]
            assert os.path.getsize(p) > 100

    def test_manifest_json_roundtrip(self, built):
        out, manifest = built
        with open(os.path.join(out, "manifest.json")) as f:
            loaded = json.load(f)
        assert loaded["version"] == 1
        assert len(loaded["artifacts"]) == len(manifest["artifacts"])

    def test_expected_batch_buckets(self, built):
        _, manifest = built
        mlp = [e for e in manifest["artifacts"] if e["kind"] == "mlp"]
        assert sorted(e["batch"] for e in mlp) == aot.MLP_BATCHES
        tr = [e for e in manifest["artifacts"] if e["kind"] == "transformer"]
        assert sorted(e["batch"] for e in tr) == aot.TRANSFORMER_BATCHES

    def test_hlo_is_text(self, built):
        out, manifest = built
        path = os.path.join(out, manifest["artifacts"][0]["file"])
        head = open(path).read(200)
        assert "HloModule" in head  # text format, not proto bytes

    def test_digest_matches_recomputation(self, built):
        """Expected digests are reproducible from the deterministic inputs."""
        _, manifest = built
        entry = next(e for e in manifest["artifacts"] if e["kind"] == "mlp"
                     and e["batch"] == 2)
        spec = M.MlpSpec()
        fn = M.make_mlp_fn(spec, use_pallas=False)
        inputs = [aot.materialize(s) for s in entry["inputs"]]
        out = np.asarray(fn(*inputs)[0], dtype=np.float64).reshape(-1)
        assert out.size == entry["expected"]["count"]
        np.testing.assert_allclose(out.sum(), entry["expected"]["sum"],
                                   rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(out[:16], entry["expected"]["prefix"],
                                   rtol=1e-3, atol=1e-4)

    def test_output_shapes_recorded(self, built):
        _, manifest = built
        for e in manifest["artifacts"]:
            n = math.prod(e["output_shape"])
            assert n == e["expected"]["count"]


class TestDigest:
    def test_digest_fields(self):
        d = aot.digest(np.arange(5, dtype=np.float32))
        assert d["count"] == 5
        assert d["sum"] == pytest.approx(10.0)
        assert d["abs_sum"] == pytest.approx(10.0)
        assert d["prefix"] == [0.0, 1.0, 2.0, 3.0, 4.0]

    def test_digest_prefix_truncates(self):
        d = aot.digest(np.ones(100), prefix_len=4)
        assert len(d["prefix"]) == 4
