"""L2 correctness: model forward passes (Pallas path vs pure-jnp path)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.aot import gen_input


class TestMlp:
    spec = M.MlpSpec()

    @pytest.mark.parametrize("batch", [1, 2, 4, 8])
    def test_pallas_matches_ref(self, batch):
        params = M.mlp_params(self.spec)
        x = gen_input(7, (batch, self.spec.in_dim))
        got = M.mlp_forward(params, x, use_pallas=True)
        want = M.mlp_forward(params, x, use_pallas=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_output_shape(self):
        params = M.mlp_params(self.spec)
        x = gen_input(1, (4, self.spec.in_dim))
        assert M.mlp_forward(params, x).shape == (4, self.spec.out_dim)

    def test_batch_rows_independent(self):
        """Row i of a batched forward equals the unbatched forward of row i.

        This is the invariant that makes the coordinator's dynamic batching
        legal (paper §2.2.3: requests map onto the batch dimension).
        """
        params = M.mlp_params(self.spec)
        x = gen_input(7, (4, self.spec.in_dim))
        full = np.asarray(M.mlp_forward(params, x, use_pallas=False))
        for i in range(4):
            row = np.asarray(M.mlp_forward(params, x[i:i + 1],
                                           use_pallas=False))
            np.testing.assert_allclose(full[i:i + 1], row,
                                       rtol=1e-5, atol=1e-5)

    def test_params_deterministic(self):
        p1 = M.mlp_params(self.spec)
        p2 = M.mlp_params(self.spec)
        for k in p1:
            np.testing.assert_array_equal(np.asarray(p1[k]),
                                          np.asarray(p2[k]))

    def test_hidden_layers_relu_nonnegative(self):
        params = M.mlp_params(self.spec)
        x = gen_input(2, (2, self.spec.in_dim))
        h = M.mlp_forward({k: params[k] for k in ("w0", "b0")}, x,
                          use_pallas=False)
        # single-layer model: final layer is linear, so emulate hidden relu
        h_relu = np.asarray(jnp.maximum(
            jnp.matmul(x, params["w0"]) + params["b0"], 0.0))
        assert (h_relu >= 0).all()
        assert h.shape == (2, self.spec.hidden[0])


class TestTransformer:
    spec = M.TransformerSpec()

    @pytest.mark.parametrize("batch", [1, 2])
    def test_pallas_matches_ref(self, batch):
        params = M.transformer_params(self.spec)
        x = gen_input(11, (batch * self.spec.seq, self.spec.d_model), 0.5)
        got = M.transformer_forward(params, x, self.spec, use_pallas=True)
        want = M.transformer_forward(params, x, self.spec, use_pallas=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=5e-4, atol=5e-4)

    def test_output_shape(self):
        params = M.transformer_params(self.spec)
        x = gen_input(1, (self.spec.seq, self.spec.d_model), 0.5)
        y = M.transformer_forward(params, x, self.spec, use_pallas=False)
        assert y.shape == x.shape

    def test_sequences_independent(self):
        """Each sequence in the flattened batch attends only to itself."""
        params = M.transformer_params(self.spec)
        s, d = self.spec.seq, self.spec.d_model
        x = gen_input(11, (2 * s, d), 0.5)
        full = np.asarray(M.transformer_forward(params, x, self.spec,
                                                use_pallas=False))
        first = np.asarray(M.transformer_forward(params, x[:s], self.spec,
                                                 use_pallas=False))
        np.testing.assert_allclose(full[:s], first, rtol=1e-4, atol=1e-4)

    def test_residual_structure(self):
        """Zeroing all projections reduces the block to identity."""
        params = {k: jnp.zeros_like(v) if k.startswith(("w", "b"))
                  else v for k, v in M.transformer_params(self.spec).items()}
        x = gen_input(3, (self.spec.seq, self.spec.d_model), 0.5)
        y = M.transformer_forward(params, x, self.spec, use_pallas=False)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x),
                                   rtol=1e-5, atol=1e-6)


class TestDeterministicInputs:
    def test_gen_input_rule(self):
        """The manifest's input rule must match this exact formula."""
        x = np.asarray(gen_input(7, (3,), 2.0))
        # the whole pipeline is float32 (rust mirrors this exactly)
        idx = np.arange(3, dtype=np.float32)
        arg = idx * np.float32(0.9898) + np.float32(7) * np.float32(78.233)
        want = np.sin(arg, dtype=np.float32) * np.float32(2.0)
        np.testing.assert_allclose(x, want, rtol=1e-5, atol=1e-5)

    def test_det_array_scale(self):
        a = np.asarray(M.det_array(0, (100,), 0.5))
        assert np.abs(a).max() <= 0.5 + 1e-6
