"""L1 correctness: Pallas kernels vs pure-jnp oracles.

This is the core correctness signal of the compile path: the hypothesis
sweep drives the kernels across shapes (ragged and MXU-aligned), block
sizes, and activations, asserting allclose against ``ref.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul_pallas as K
from compile.kernels import ref


def rand(key, *shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


dims = st.integers(min_value=1, max_value=96)
blocks = st.sampled_from([8, 16, 32, 128])


class TestMatmul:
    @pytest.mark.parametrize("m,k,n", [
        (1, 1, 1), (1, 256, 8), (8, 8, 8), (16, 64, 32),
        (128, 128, 128), (256, 512, 128), (33, 7, 5), (100, 40, 60),
    ])
    def test_fixed_shapes(self, m, k, n):
        x, w = rand(0, m, k), rand(1, k, n)
        got = K.matmul(x, w)
        want = ref.matmul(x, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    @settings(max_examples=40, deadline=None)
    @given(m=dims, k=dims, n=dims, bm=blocks, bn=blocks, bk=blocks)
    def test_hypothesis_shapes_blocks(self, m, k, n, bm, bn, bk):
        x, w = rand(2, m, k), rand(3, k, n)
        got = K.matmul(x, w, bm=bm, bn=bn, bk=bk)
        want = ref.matmul(x, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_block_tiling_independence(self):
        """Same numerics regardless of the HBM↔VMEM tiling schedule."""
        x, w = rand(4, 64, 64), rand(5, 64, 64)
        base = np.asarray(K.matmul(x, w, bm=64, bn=64, bk=64))
        for b in (8, 16, 32):
            tiled = np.asarray(K.matmul(x, w, bm=b, bn=b, bk=b))
            np.testing.assert_allclose(tiled, base, rtol=1e-5, atol=1e-5)

    def test_identity(self):
        x = rand(6, 32, 32)
        eye = jnp.eye(32)
        np.testing.assert_allclose(np.asarray(K.matmul(x, eye)),
                                   np.asarray(x), rtol=1e-5, atol=1e-6)

    def test_zeros(self):
        x = rand(7, 16, 24)
        z = jnp.zeros((24, 8))
        assert np.abs(np.asarray(K.matmul(x, z))).max() == 0.0


class TestLinear:
    @pytest.mark.parametrize("activation", ["relu", "tanh", "none"])
    @pytest.mark.parametrize("m,k,n", [(1, 256, 512), (8, 512, 256),
                                       (32, 128, 128), (5, 17, 9)])
    def test_activations(self, activation, m, k, n):
        x, w, b = rand(8, m, k), rand(9, k, n, scale=0.1), rand(10, n, scale=0.1)
        got = K.matmul_bias_act(x, w, b, activation=activation)
        want = ref.matmul_bias_act(x, w, b, activation=activation)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    @settings(max_examples=25, deadline=None)
    @given(m=dims, k=dims, n=dims,
           act=st.sampled_from(["relu", "tanh", "none"]))
    def test_hypothesis(self, m, k, n, act):
        x, w, b = rand(11, m, k), rand(12, k, n, scale=0.2), rand(13, n, scale=0.2)
        got = K.matmul_bias_act(x, w, b, activation=act)
        want = ref.matmul_bias_act(x, w, b, activation=act)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_relu_clamps_negative(self):
        x = -jnp.ones((4, 8))
        w = jnp.eye(8)
        b = jnp.zeros((8,))
        out = np.asarray(K.matmul_bias_act(x, w, b, activation="relu"))
        assert (out >= 0).all() and out.max() == 0.0


class TestKernelStructure:
    """Structural (perf-model) invariants of the TPU tiling."""

    def test_vmem_footprint_default_blocks(self):
        # default 128³ tiling: 3 tiles × 64 KiB = 192 KiB ≪ 16 MiB VMEM
        assert K.vmem_footprint_bytes(128, 128, 128) == 3 * 128 * 128 * 4
        assert K.vmem_footprint_bytes(128, 128, 128) < 16 * 2**20

    def test_vmem_footprint_large_blocks_still_fit(self):
        assert K.vmem_footprint_bytes(512, 512, 512) < 16 * 2**20

    def test_mxu_utilization_aligned(self):
        assert K.mxu_utilization_estimate(512, 512, 512) == pytest.approx(1.0)

    def test_mxu_utilization_ragged_penalty(self):
        ragged = K.mxu_utilization_estimate(100, 100, 100)
        aligned = K.mxu_utilization_estimate(128, 128, 128)
        assert ragged < aligned <= 1.0

    def test_pick_block_divides(self):
        for dim in (1, 7, 96, 100, 128, 257, 512):
            b = K._pick_block(dim, 128)
            assert dim % b == 0 and 1 <= b <= 128
