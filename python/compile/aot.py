"""AOT export: lower the L2 JAX models (calling L1 Pallas kernels) to HLO text.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage (from ``python/``)::

    python -m compile.aot --out-dir ../artifacts

Emits one ``<name>.hlo.txt`` per (model, batch-size) bucket — the dynamic
batcher on the Rust side routes requests to the nearest bucket — plus
``manifest.json`` describing each artifact's I/O contract and a deterministic
expected-output digest the Rust integration tests verify numerics against.
"""
from __future__ import annotations

import argparse
import json
import math
import os
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

MLP_BATCHES = [1, 2, 4, 8]
TRANSFORMER_BATCHES = [1, 2, 4]
MATMUL_SIZES = [128, 256, 512]

# Input-generation scheme shared with rust/src/runtime/artifact.rs::gen_input.
# x[i] = sin(i * 0.9898 + tag * 78.233) * scale
INPUT_RULE = "sin(i * 0.9898 + tag * 78.233) * scale"


def gen_input(tag: int, shape, scale: float = 1.0) -> jnp.ndarray:
    """Deterministic input tensor; must match the Rust reimplementation."""
    n = int(math.prod(shape))
    idx = jnp.arange(n, dtype=jnp.float32)
    return (jnp.sin(idx * 0.9898 + float(tag) * 78.233) * scale).reshape(shape)


def materialize(spec: Dict[str, Any]) -> jnp.ndarray:
    """Turn an input spec (det ``tag/scale`` or constant ``fill``) into data."""
    if "fill" in spec:
        return jnp.full(tuple(spec["shape"]), float(spec["fill"]),
                        dtype=jnp.float32)
    return gen_input(spec["tag"], tuple(spec["shape"]), spec.get("scale", 1.0))


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR → XlaComputation → HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def digest(arr: np.ndarray, prefix_len: int = 16) -> Dict[str, Any]:
    """Compact numeric fingerprint for cross-language comparison."""
    flat = np.asarray(arr, dtype=np.float64).reshape(-1)
    return {
        "prefix": [float(v) for v in flat[:prefix_len]],
        "sum": float(flat.sum()),
        "abs_sum": float(np.abs(flat).sum()),
        "count": int(flat.size),
    }


def export_one(name: str, fn, ref_fn, input_specs: List[Dict[str, Any]],
               out_dir: str) -> Dict[str, Any]:
    """Lower ``fn`` for the given inputs, validate vs oracle, write artifact."""
    shapes = [jax.ShapeDtypeStruct(tuple(s["shape"]), jnp.float32)
              for s in input_specs]
    lowered = jax.jit(fn).lower(*shapes)
    text = to_hlo_text(lowered)
    # weights travel as arguments precisely to avoid elided large constants
    # ("constant({...})"), which the text parser would zero-fill
    assert "constant({...})" not in text, f"{name}: elided constant in HLO"
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)

    inputs = [materialize(s) for s in input_specs]
    out = np.asarray(jax.jit(fn)(*inputs)[0])
    ref_out = np.asarray(ref_fn(*inputs)[0])
    np.testing.assert_allclose(out, ref_out, rtol=2e-4, atol=2e-4)

    def spec_json(s: Dict[str, Any]) -> Dict[str, Any]:
        out_s: Dict[str, Any] = {"shape": list(s["shape"]), "dtype": "f32"}
        if "fill" in s:
            out_s["fill"] = float(s["fill"])
        else:
            out_s["tag"] = s["tag"]
            out_s["scale"] = s.get("scale", 1.0)
        return out_s

    return {
        "name": name,
        "file": fname,
        "inputs": [spec_json(s) for s in input_specs],
        "output_shape": list(out.shape),
        "expected": digest(out),
    }


def build_all(out_dir: str) -> Dict[str, Any]:
    """Export every serving artifact + the manifest dict."""
    os.makedirs(out_dir, exist_ok=True)
    entries: List[Dict[str, Any]] = []

    mlp_spec = M.MlpSpec()
    mlp_params = M.mlp_param_specs(mlp_spec)
    for b in MLP_BATCHES:
        entries.append(export_one(
            f"mlp_b{b}",
            M.make_mlp_fn(mlp_spec, use_pallas=True),
            M.make_mlp_fn(mlp_spec, use_pallas=False),
            [{"shape": (b, mlp_spec.in_dim), "tag": 7, "scale": 1.0}, *mlp_params],
            out_dir,
        ) | {"kind": "mlp", "batch": b})

    tr_spec = M.TransformerSpec()
    tr_params = M.transformer_param_specs(tr_spec)
    for b in TRANSFORMER_BATCHES:
        tokens = b * tr_spec.seq
        entries.append(export_one(
            f"transformer_b{b}",
            M.make_transformer_fn(tr_spec, use_pallas=True),
            M.make_transformer_fn(tr_spec, use_pallas=False),
            [{"shape": (tokens, tr_spec.d_model), "tag": 11, "scale": 0.5},
             *tr_params],
            out_dir,
        ) | {"kind": "transformer", "batch": b, "seq": tr_spec.seq})

    for n in MATMUL_SIZES:
        entries.append(export_one(
            f"matmul_{n}",
            M.make_matmul_fn(n, use_pallas=True),
            M.make_matmul_fn(n, use_pallas=False),
            [{"shape": (n, n), "tag": 3, "scale": 1.0 / math.sqrt(n)},
             {"shape": (n, n), "tag": 5, "scale": 1.0 / math.sqrt(n)}],
            out_dir,
        ) | {"kind": "matmul", "size": n})

    manifest = {
        "version": 1,
        "input_rule": INPUT_RULE,
        "mlp": {"in_dim": mlp_spec.in_dim, "out_dim": mlp_spec.out_dim,
                "hidden": list(mlp_spec.hidden), "batches": MLP_BATCHES},
        "transformer": {"seq": tr_spec.seq, "d_model": tr_spec.d_model,
                        "n_heads": tr_spec.n_heads, "d_ff": tr_spec.d_ff,
                        "batches": TRANSFORMER_BATCHES},
        "artifacts": entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    manifest = build_all(args.out_dir)
    n = len(manifest["artifacts"])
    print(f"wrote {n} artifacts + manifest.json to {args.out_dir}")


if __name__ == "__main__":
    main()
