"""L2: JAX forward graphs for the models the Rust coordinator serves.

Two serving workloads, matching the paper's evaluation mix:

* ``mlp`` — a wide&deep-style ranking MLP (the YouTube/Facebook
  recommendation FC stacks of §5.1: hidden sizes in the 256–512 range).
* ``transformer`` — a single pre-norm transformer encoder block (the
  Transformer FC/attention mix of §5, MatMul-4k class).

Every dense layer calls the L1 Pallas kernel
(:func:`compile.kernels.matmul_pallas.matmul_bias_act`), so the AOT-lowered
HLO exercises the full three-layer stack. Weights are generated from a
counter-based deterministic scheme (no PRNG state needed at load time) so the
Rust integration tests can check numerics against ``expected_*.json``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import matmul_pallas as K
from .kernels import ref


# --------------------------------------------------------------------------
# Deterministic weights
# --------------------------------------------------------------------------

def det_array(tag: int, shape: Tuple[int, ...], scale: float) -> jnp.ndarray:
    """Deterministic pseudo-random weights: sin over an affine index grid.

    Cheap, seed-free, identical across hosts — the Rust side never needs to
    reproduce this (it reads expected outputs from the manifest), but pytest
    re-derives it when checking the AOT artifacts.
    """
    n = int(math.prod(shape))
    idx = jnp.arange(n, dtype=jnp.float32)
    vals = jnp.sin(idx * 0.9898 + float(tag) * 78.233)
    return (vals * scale).reshape(shape)


# --------------------------------------------------------------------------
# MLP ranker (wide & deep style)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MlpSpec:
    """Architecture of the ranking MLP."""

    in_dim: int = 256
    hidden: Tuple[int, ...] = (512, 256, 128)
    out_dim: int = 8

    @property
    def layer_dims(self) -> List[Tuple[int, int]]:
        dims = (self.in_dim, *self.hidden, self.out_dim)
        return list(zip(dims[:-1], dims[1:]))


def mlp_params(spec: MlpSpec) -> Dict[str, jnp.ndarray]:
    """Deterministic parameters for :func:`mlp_forward`."""
    params: Dict[str, jnp.ndarray] = {}
    for li, (din, dout) in enumerate(spec.layer_dims):
        scale = 1.0 / math.sqrt(din)
        params[f"w{li}"] = det_array(2 * li, (din, dout), scale)
        params[f"b{li}"] = det_array(2 * li + 1, (dout,), 0.1)
    return params


def mlp_forward(params: Dict[str, jnp.ndarray], x: jnp.ndarray,
                use_pallas: bool = True) -> jnp.ndarray:
    """Forward pass of the ranking MLP; final layer is linear (logits)."""
    n_layers = len([k for k in params if k.startswith("w")])
    h = x
    for li in range(n_layers):
        act = "relu" if li < n_layers - 1 else "none"
        if use_pallas:
            h = K.matmul_bias_act(h, params[f"w{li}"], params[f"b{li}"],
                                  activation=act)
        else:
            h = ref.matmul_bias_act(h, params[f"w{li}"], params[f"b{li}"],
                                    activation=act)
    return h


# --------------------------------------------------------------------------
# Transformer encoder block
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TransformerSpec:
    """Single pre-norm encoder block (batch of independent sequences)."""

    seq: int = 32
    d_model: int = 128
    n_heads: int = 4
    d_ff: int = 512

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


def transformer_params(spec: TransformerSpec) -> Dict[str, jnp.ndarray]:
    """Deterministic parameters for :func:`transformer_forward`."""
    d, f = spec.d_model, spec.d_ff
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": det_array(101, (d, d), s), "bq": det_array(102, (d,), 0.02),
        "wk": det_array(103, (d, d), s), "bk": det_array(104, (d,), 0.02),
        "wv": det_array(105, (d, d), s), "bv": det_array(106, (d,), 0.02),
        "wo": det_array(107, (d, d), s), "bo": det_array(108, (d,), 0.02),
        "w1": det_array(109, (d, f), s), "b1": det_array(110, (f,), 0.02),
        "w2": det_array(111, (f, d), 1.0 / math.sqrt(f)),
        "b2": det_array(112, (d,), 0.02),
        "ln1_g": jnp.ones((d,)), "ln1_b": jnp.zeros((d,)),
        "ln2_g": jnp.ones((d,)), "ln2_b": jnp.zeros((d,)),
    }
    return p


def _heads(x: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """[tokens, d_model] -> [heads, tokens, d_head]."""
    t, d = x.shape
    return x.reshape(t, n_heads, d // n_heads).transpose(1, 0, 2)


def transformer_forward(params: Dict[str, jnp.ndarray], x: jnp.ndarray,
                        spec: TransformerSpec,
                        use_pallas: bool = True) -> jnp.ndarray:
    """Pre-norm encoder block over ``x: [batch*seq, d_model]``.

    The Q/K/V/O projections are four *independent heavy ops* — exactly the
    inter-op-parallelism structure that gives Transformer an average graph
    width of 4 in the paper's Table 2. Attention itself is applied per
    sequence within the flattened batch.
    """
    mm = (lambda a, w, b: K.matmul_bias_act(a, w, b, activation="none")) \
        if use_pallas else \
        (lambda a, w, b: ref.matmul_bias_act(a, w, b, activation="none"))

    tokens, d = x.shape
    assert d == spec.d_model and tokens % spec.seq == 0
    n_seqs = tokens // spec.seq

    h = ref.layernorm(x, params["ln1_g"], params["ln1_b"])
    q, k, v = (mm(h, params[f"w{n}"], params[f"b{n}"]) for n in "qkv")

    outs = []
    for si in range(n_seqs):
        sl = slice(si * spec.seq, (si + 1) * spec.seq)
        qh, kh, vh = (_heads(t[sl], spec.n_heads) for t in (q, k, v))
        per_head = [ref.attention(qh[hh], kh[hh], vh[hh])
                    for hh in range(spec.n_heads)]
        att = jnp.concatenate(per_head, axis=-1)
        outs.append(att)
    att = jnp.concatenate(outs, axis=0)

    x = x + mm(att, params["wo"], params["bo"])

    h = ref.layernorm(x, params["ln2_g"], params["ln2_b"])
    ff = mm(h, params["w1"], params["b1"])
    ff = jnp.maximum(ff, 0.0)
    ff = mm(ff, params["w2"], params["b2"])
    return x + ff


# --------------------------------------------------------------------------
# Entry points used by aot.py
#
# Weights are passed as ARGUMENTS, not closed-over constants: HLO *text*
# elides large constants ("constant({...})"), which the 0.5.1 text parser
# fills with zeros. The Rust runtime regenerates every parameter from the
# same deterministic (tag, scale) rule recorded in the manifest.
# --------------------------------------------------------------------------

def mlp_param_specs(spec: MlpSpec) -> List[dict]:
    """(name, shape, gen-rule) for every MLP parameter, in argument order."""
    specs = []
    for li, (din, dout) in enumerate(spec.layer_dims):
        specs.append({"name": f"w{li}", "shape": (din, dout),
                      "tag": 2 * li, "scale": 1.0 / math.sqrt(din)})
        specs.append({"name": f"b{li}", "shape": (dout,),
                      "tag": 2 * li + 1, "scale": 0.1})
    return specs


def make_mlp_fn(spec: MlpSpec, use_pallas: bool = True):
    """Returns ``f(x, *params) -> (logits,)`` taking weights as arguments."""
    names = [s["name"] for s in mlp_param_specs(spec)]

    def fn(x, *args):
        params = dict(zip(names, args))
        return (mlp_forward(params, x, use_pallas=use_pallas),)

    return fn


# transformer parameter argument order (ln params use fill rules)
TRANSFORMER_PARAM_ORDER: Tuple[str, ...] = (
    "wq", "bq", "wk", "bk", "wv", "bv", "wo", "bo",
    "w1", "b1", "w2", "b2", "ln1_g", "ln1_b", "ln2_g", "ln2_b",
)


def transformer_param_specs(spec: TransformerSpec) -> List[dict]:
    """(name, shape, gen-rule) for every transformer parameter, in order."""
    d, f = spec.d_model, spec.d_ff
    s = 1.0 / math.sqrt(d)
    det = lambda name, tag, shape, scale: {
        "name": name, "shape": shape, "tag": tag, "scale": scale}
    fill = lambda name, shape, value: {
        "name": name, "shape": shape, "fill": value}
    return [
        det("wq", 101, (d, d), s), det("bq", 102, (d,), 0.02),
        det("wk", 103, (d, d), s), det("bk", 104, (d,), 0.02),
        det("wv", 105, (d, d), s), det("bv", 106, (d,), 0.02),
        det("wo", 107, (d, d), s), det("bo", 108, (d,), 0.02),
        det("w1", 109, (d, f), s), det("b1", 110, (f,), 0.02),
        det("w2", 111, (f, d), 1.0 / math.sqrt(f)),
        det("b2", 112, (d,), 0.02),
        fill("ln1_g", (d,), 1.0), fill("ln1_b", (d,), 0.0),
        fill("ln2_g", (d,), 1.0), fill("ln2_b", (d,), 0.0),
    ]


def make_transformer_fn(spec: TransformerSpec, use_pallas: bool = True):
    """Returns ``f(x, *params) -> (y,)`` taking weights as arguments."""

    def fn(x, *args):
        params = dict(zip(TRANSFORMER_PARAM_ORDER, args))
        return (transformer_forward(params, x, spec, use_pallas=use_pallas),)

    return fn


def make_matmul_fn(n: int, use_pallas: bool = True):
    """Square matmul micro-workload (the paper's MatMul-N)."""
    def fn(x, w):
        if use_pallas:
            return (K.matmul(x, w),)
        return (ref.matmul(x, w),)

    return fn
