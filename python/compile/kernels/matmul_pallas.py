"""L1: tiled matmul Pallas kernel — the paper's GEMM hot-spot on TPU terms.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper tiles GEMM
for AVX-512 FMA units and core-private caches and parallelises across MKL
(OpenMP) threads.  On TPU the same insight maps to:

* the 128×128 MXU systolic array  → block shapes are multiples of 128 where
  the problem allows (8-lane sublane × 128-lane vregs for f32),
* VMEM (~16 MiB scratchpad)       → the ``BlockSpec`` tile working set
  ``(bm·bk + bk·bn + bm·bn)·4 B`` is kept well under VMEM,
* MKL-thread parallelism          → the Pallas *grid*: each (i, j) grid cell
  owns one output tile, the k-loop is the innermost grid axis so partial
  products accumulate in the output ref.

Kernels are lowered with ``interpret=True`` — the CPU PJRT client cannot run
Mosaic custom-calls; real-TPU numbers are estimated analytically in
EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, o_ref, *, n_k: int):
    """One grid step: accumulate ``x_tile @ w_tile`` into the output tile.

    Grid layout is ``(m_tiles, n_tiles, k_tiles)`` with k innermost; the
    output BlockSpec maps every k step of a given (i, j) onto the same tile,
    so ``o_ref`` acts as the f32 accumulator the MXU would use.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)
    del n_k  # part of the cache key; the grid bound carries the loop count


def _pick_block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is ≤ target (prefers MXU multiples)."""
    if dim <= target:
        return dim
    for cand in range(target, 0, -1):
        if dim % cand == 0:
            return cand
    return dim


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x: jnp.ndarray, w: jnp.ndarray, *, bm: int = 128, bn: int = 128,
           bk: int = 128) -> jnp.ndarray:
    """Tiled Pallas matmul: ``x[m,k] @ w[k,n] -> [m,n]``.

    Block sizes are clamped to divisors of the problem shape so the kernel
    handles the small/ragged shapes the hypothesis sweep throws at it; for
    MXU-friendly shapes (multiples of 128) the requested tiling is used
    as-is.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch: {x.shape} @ {w.shape}"
    bm, bn, bk = _pick_block(m, bm), _pick_block(n, bn), _pick_block(k, bk)
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w)


def _linear_kernel(x_ref, w_ref, b_ref, o_ref, *, n_k: int, activation: str):
    """Fused linear layer tile: GEMM accumulate + bias/activation epilogue."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)

    # Epilogue runs once, on the last k step: this is the fusion the paper's
    # MatMul2 operator achieves by keeping the post-GEMM work inside the
    # kernel instead of a separate framework-native op.
    @pl.when(k == n_k - 1)
    def _epilogue():
        y = o_ref[...] + b_ref[...]
        if activation == "relu":
            y = jnp.maximum(y, 0.0)
        elif activation == "tanh":
            y = jnp.tanh(y)
        o_ref[...] = y


@functools.partial(jax.jit, static_argnames=("activation", "bm", "bn", "bk"))
def matmul_bias_act(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, *,
                    activation: str = "relu", bm: int = 128, bn: int = 128,
                    bk: int = 128) -> jnp.ndarray:
    """Fused ``act(x @ w + b)`` Pallas kernel (the FC-layer hot path)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,)
    bm, bn, bk = _pick_block(m, bm), _pick_block(n, bn), _pick_block(k, bk)
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_linear_kernel, n_k=n_k, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w, b)


def vmem_footprint_bytes(bm: int, bn: int, bk: int,
                         dtype_bytes: int = 4) -> int:
    """VMEM working set of one grid step (x-tile + w-tile + o-tile).

    Used by the perf notes in EXPERIMENTS.md and asserted <16 MiB in tests.
    """
    return dtype_bytes * (bm * bk + bk * bn + bm * bn)


def mxu_utilization_estimate(m: int, n: int, k: int, bm: int = 128,
                             bn: int = 128, bk: int = 128) -> float:
    """Fraction of MXU issue slots doing useful work for this tiling.

    The 128×128 MXU retires a full tile per pass; ragged edges waste the
    remainder. This mirrors the paper's FMA-utilisation argument (§5.1) in
    TPU terms.
    """
    def eff(dim, block, native=128):
        per_block = -(-dim // block) * block  # padded to block multiple
        per_pass = -(-per_block // native) * native
        return dim / per_pass

    return eff(m, bm, 8) * eff(n, bn, 128) * eff(k, bk, 128)
