"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must match its oracle to float32 tolerance under pytest (see
``python/tests/test_kernel.py``). The oracles are deliberately written with
plain ``jnp`` ops only — no Pallas, no custom calls — so they lower to
vanilla HLO on any backend.
"""
from __future__ import annotations

import jax.numpy as jnp


def matmul(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Plain matrix multiplication oracle: ``x @ w``.

    Args:
        x: ``[m, k]`` activation matrix.
        w: ``[k, n]`` weight matrix.

    Returns:
        ``[m, n]`` product, accumulated in float32.
    """
    return jnp.matmul(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def matmul_bias_act(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    activation: str = "relu",
) -> jnp.ndarray:
    """Fused linear-layer oracle: ``act(x @ w + b)``.

    This is the compute hot-spot the paper's FC-layer analysis revolves
    around (MatMul-512 / MatMul-4k in §5): a GEMM plus its epilogue.
    """
    y = jnp.matmul(x, w, preferred_element_type=jnp.float32) + b.astype(jnp.float32)
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation == "tanh":
        y = jnp.tanh(y)
    elif activation == "none":
        pass
    else:  # pragma: no cover - defensive
        raise ValueError(f"unknown activation: {activation}")
    return y.astype(x.dtype)


def softmax(x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Numerically-stable softmax oracle."""
    m = jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=axis, keepdims=True)


def layernorm(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray,
              eps: float = 1e-5) -> jnp.ndarray:
    """Layer normalisation oracle over the last axis."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Single-head scaled-dot-product attention oracle.

    Shapes: q ``[s, d]``, k ``[s, d]``, v ``[s, d]`` → ``[s, d]``.
    """
    d = q.shape[-1]
    scores = jnp.matmul(q, k.T) / jnp.sqrt(jnp.float32(d))
    return jnp.matmul(softmax(scores, axis=-1), v)
