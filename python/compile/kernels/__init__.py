"""Pallas kernels (L1) and their pure-jnp oracles."""
from . import matmul_pallas, ref  # noqa: F401
