"""Build-time compile path: L2 JAX models + L1 Pallas kernels + AOT export.

Nothing in this package is imported at run time; the Rust coordinator only
consumes the HLO-text artifacts that ``python -m compile.aot`` writes.
"""
