//! Offline stub of the `xla` crate (PJRT bindings over `xla_extension`).
//!
//! The hermetic build cannot fetch the native XLA library, so this stub
//! keeps `parframe::runtime::client` compiling with the exact API surface
//! it uses; every entry point returns an "unavailable" error at runtime.
//! Serving without AOT artifacts goes through `parframe`'s `SimBackend`
//! instead. Swapping this path dependency for the real `xla` crate
//! re-enables the PJRT backend without source changes.

use std::path::Path;

/// Stub error (mirrors the real crate's opaque error type).
#[derive(Debug)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result alias.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error(
        "PJRT unavailable: built with the offline xla stub (vendor the real \
         `xla` crate + xla_extension to enable artifact execution)"
            .to_string(),
    )
}

/// PJRT client handle (stub).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// The real crate constructs a CPU client; the stub always errors.
    pub fn cpu() -> Result<Self> {
        Err(unavailable())
    }

    /// Platform name for diagnostics.
    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    /// Compile a computation (stub: always errors).
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an HLO text file (stub: always errors).
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<Self> {
        Err(unavailable())
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _private: () }
    }
}

/// Host literal (stub).
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Build a rank-1 literal from host data.
    pub fn vec1(_data: &[f32]) -> Self {
        Literal { _private: () }
    }

    /// Reshape to the given dimensions (stub: always errors).
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    /// Unwrap the first tuple element (stub: always errors).
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable())
    }

    /// Copy out as a host vector (stub: always errors).
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

/// Device buffer (stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Fetch the buffer to a host literal (stub: always errors).
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Loaded executable (stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given arguments (stub: always errors).
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn everything_is_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/tmp/x.hlo").is_err());
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
