//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The hermetic build cannot fetch crates.io dependencies, so this shim
//! implements exactly the subset parframe uses: [`Error`], [`Result`],
//! the [`anyhow!`], [`bail!`] and [`ensure!`] macros, and the [`Context`]
//! extension trait for `Result` and `Option`. Context is stored as a
//! pre-joined `"outer: inner"` message chain, which is what the real
//! crate's `{e:#}` alternate formatting prints.

use std::fmt;

/// An opaque error: a message chain joined as `"outer: inner"`.
pub struct Error(String);

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error(message.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

// `?` on any std error type (io::Error, RecvError, ParseIntError, ...).
// `Error` itself intentionally does not implement `std::error::Error`,
// which keeps this blanket impl coherent with the reflexive `From`.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error(e.to_string())
    }
}

/// `anyhow`-style result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failures (`Result`) or absences (`Option`).
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Wrap the error with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value
/// (the same three arms the real crate accepts).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/nonexistent/definitely/missing")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(io_fail().is_err());
    }

    #[test]
    fn context_chains_messages() {
        let e = io_fail().context("loading config").unwrap_err();
        let s = format!("{e:#}");
        assert!(s.starts_with("loading config: "), "{s}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(e.to_string(), "missing thing");
        assert_eq!(Some(3u32).context("ok").unwrap(), 3);
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad {}", 7);
        assert_eq!(e.to_string(), "bad 7");
        let owned = anyhow!(String::from("owned message"));
        assert_eq!(owned.to_string(), "owned message");
        let x = 3;
        assert_eq!(anyhow!("inline {x}").to_string(), "inline 3");
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "too big: {x}");
            if x == 0 {
                bail!("zero");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(0).unwrap_err().to_string(), "zero");
        assert_eq!(f(11).unwrap_err().to_string(), "too big: 11");
    }
}
