//! Bench: the sim-engine fast path — calendar-queue event loop, scratch
//! arenas, and delta-simulation (policy-sibling phase-table sharing) —
//! against the seed BinaryHeap engine it replaced.
//!
//! The headline case is `fastpath-vs-seed`: evaluated design points per
//! second over the full exhaustive tuning lattice, SimCache fast path
//! vs `simulate_reference`. The ratio is the acceptance metric for the
//! engine-fast-path work (target ≥ 2x) and is asserted bit-identical
//! along the way — speed that changes the answer doesn't count.
//!
//! Case names are fixed across fast/full modes so the emitted
//! `BENCH_sim.json` stays diffable; `PARFRAME_BENCH_FAST=1` only swaps
//! in a smaller model/platform and budget.

use std::time::Instant;

use parframe::config::{CpuPlatform, FrameworkConfig, OperatorImpl};
use parframe::models;
use parframe::sim::{self, PreparedGraph, SimCache, SimOptions};
use parframe::tuner::lattice;
use parframe::util::bench::Bench;

fn main() {
    let mut b = Bench::new("sim");
    let (p, model) = if b.is_fast() {
        (CpuPlatform::small(), "squeezenet")
    } else {
        (CpuPlatform::large2(), "inception_v2")
    };
    let g = models::build(model, models::canonical_batch(model)).unwrap();
    println!("sim bench on {} / {model} ({} ops)", p.name, g.len());
    let cfg = FrameworkConfig {
        inter_op_pools: 3,
        mkl_threads: p.physical_cores() / 3,
        intra_op_threads: p.physical_cores() / 3,
        operator_impl: OperatorImpl::IntraOpParallel,
        ..FrameworkConfig::tuned_default()
    };

    // single-simulation hot path: seed engine vs calendar-queue engine
    // vs the prepared (ranks/CSR/scratch reused) entry point
    b.run_with_output("simulate/seed-engine", || {
        sim::simulate_reference(&g, &p, &cfg, &SimOptions::default()).unwrap().latency_s
    });
    b.run_with_output("simulate/fast-engine", || sim::simulate(&g, &p, &cfg).unwrap().latency_s);
    let prep = PreparedGraph::new(&g);
    b.run_with_output("simulate/prepared", || {
        sim::simulate_prepared(&prep, &p, &cfg, &SimOptions::default()).unwrap().latency_s
    });

    // exhaustive-lattice sweep: every unique design point once, serial,
    // seed path (fresh graph state per point is already amortised by
    // the reference engine itself) vs the SimCache fast path (prepared
    // graph + scratch pool + delta-sim across policy siblings)
    let points = lattice(&p);
    let t0 = Instant::now();
    let mut seed_sum = 0.0;
    for c in points.iter() {
        seed_sum += sim::simulate_reference(&g, &p, c, &SimOptions::default()).unwrap().latency_s;
    }
    let seed_wall = t0.elapsed().as_secs_f64();
    let seed_pps = points.len() as f64 / seed_wall.max(1e-12);
    b.record("lattice-sweep/seed", seed_pps, "points/s");

    let cache = SimCache::new();
    let t0 = Instant::now();
    let mut fast_sum = 0.0;
    for c in points.iter() {
        fast_sum += cache.latency(&prep, &p, c).unwrap();
    }
    let fast_wall = t0.elapsed().as_secs_f64();
    let fast_pps = points.len() as f64 / fast_wall.max(1e-12);
    b.record("lattice-sweep/fastpath", fast_pps, "points/s");
    b.record("fastpath-vs-seed", fast_pps / seed_pps, "x");
    println!(
        "sim/lattice {} points, delta-hits={} delta-fallbacks={}",
        points.len(),
        cache.delta_hits(),
        cache.delta_fallbacks()
    );

    // speed that changes the answer doesn't count: identical terms in
    // identical order must sum to identical bits
    assert_eq!(
        seed_sum.to_bits(),
        fast_sum.to_bits(),
        "fast path diverged from the seed engine over the lattice"
    );
    assert_eq!(cache.delta_fallbacks(), 0, "phase-table guard rejected a policy sibling");

    b.finish();
}
