//! Bench: the trace-store subsystem (`BENCH_trace.json`).
//!
//! Two planes: *capture* (closed-loop saturation throughput with and
//! without a recorder attached — `record-overhead` is the committed
//! contract, required ≤ 1.05x by the trace design note) and *codec*
//! (columnar encode/decode events-per-second plus the on-disk density
//! of the `.plt` format).

use std::sync::Arc;
use std::time::{Duration, Instant};

use parframe::config::CpuPlatform;
use parframe::coordinator::{loadgen, BatchPolicy, Coordinator, CoordinatorConfig, LoadgenConfig};
use parframe::tracestore::{TraceData, TraceEvent, TraceRecorder};
use parframe::util::bench::Bench;
use parframe::util::prng::Prng;

const KIND: &str = "wide_deep";

fn coordinator(recorder: Option<Arc<TraceRecorder>>) -> Coordinator {
    let platform = CpuPlatform::large();
    let mut cfg = CoordinatorConfig::sim(platform, &[KIND]);
    cfg.lanes = 2;
    cfg.policy = BatchPolicy { max_wait: Duration::from_micros(200), max_batch: usize::MAX };
    cfg.recorder = recorder;
    Coordinator::start(cfg).expect("start sim coordinator")
}

/// Closed-loop saturation: 8 workers re-submit as fast as responses come
/// back, so throughput is bounded by coordinator overhead — exactly the
/// path trace capture adds its per-batch work to.
fn saturation(coord: &Coordinator, requests: usize) -> f64 {
    loadgen::run(coord, &LoadgenConfig::closed(KIND, requests / 4, 8)).expect("warm-up");
    let r = loadgen::run(coord, &LoadgenConfig::closed(KIND, requests, 8)).expect("saturation");
    assert_eq!(r.errors, 0, "saturation run had errors");
    r.throughput_rps
}

/// Realistically-shaped synthetic events for the codec cases: monotone
/// timestamps with small deltas, a few kinds/lanes, batched ids — the
/// profile the delta-varint columns are designed around.
fn synthetic_trace(events: usize) -> TraceData {
    let mut rng = Prng::new(0x7A11A5);
    let mut t = 0u64;
    let evs = (0..events)
        .map(|i| {
            t += rng.below(2_000_000) as u64; // ≤ 2 ms inter-arrival
            let cut = t + rng.below(500_000) as u64;
            let dispatch = cut + rng.below(100_000) as u64;
            TraceEvent {
                request_id: i as u64,
                kind: (i % 3) as u16,
                lane: (i % 2) as u16,
                batch_id: (i / 4) as u64,
                occupancy: 4,
                bucket: 4,
                arrival_ns: t,
                cut_ns: cut,
                dispatch_ns: dispatch,
                complete_ns: dispatch + rng.below(3_000_000) as u64,
            }
        })
        .collect();
    TraceData::new(vec!["wide_deep".into(), "ncf".into(), "resnet50".into()], evs)
}

fn main() {
    let mut b = Bench::new("trace");
    let (sat_n, codec_events, codec_iters) =
        if b.is_fast() { (512, 20_000, 3u32) } else { (4096, 200_000, 10u32) };

    // -- capture plane: record-on vs record-off saturation --------------
    let off = {
        let coord = coordinator(None);
        saturation(&coord, sat_n)
    };
    b.record("saturation/record-off", off, "req/s");

    let recorder = Arc::new(TraceRecorder::new());
    let on = {
        let coord = coordinator(Some(Arc::clone(&recorder)));
        saturation(&coord, sat_n)
    };
    b.record("saturation/record-on", on, "req/s");
    // > 1.0 means recording costs throughput; the contract is ≤ 1.05
    b.record("record-overhead", off / on, "x");
    println!("trace/capture: {} events captured at saturation", recorder.drain().len());

    // -- codec plane -----------------------------------------------------
    let trace = synthetic_trace(codec_events);
    let mut bytes = trace.to_bytes();
    let t0 = Instant::now();
    for _ in 0..codec_iters {
        bytes = trace.to_bytes();
    }
    let encode_eps = (codec_iters as usize * codec_events) as f64 / t0.elapsed().as_secs_f64();
    b.record("encode/events-per-sec", encode_eps, "events/s");

    let mut decoded = TraceData::default();
    let t0 = Instant::now();
    for _ in 0..codec_iters {
        decoded = TraceData::from_bytes(&bytes).expect("decode");
    }
    let decode_eps = (codec_iters as usize * codec_events) as f64 / t0.elapsed().as_secs_f64();
    b.record("decode/events-per-sec", decode_eps, "events/s");
    assert_eq!(decoded, trace, "codec round-trip diverged");

    b.record("file/bytes-per-event", bytes.len() as f64 / codec_events as f64, "B");
    b.finish();
}
