//! Bench: DES engine throughput — how fast the simulator schedules and
//! accounts operator graphs (the L3 hot path for every figure harness).

use parframe::config::{CpuPlatform, FrameworkConfig, OperatorImpl, SchedPolicy};
use parframe::models;
use parframe::sim::{self, SimOptions};
use parframe::util::bench::Bench;

fn cfg(pools: usize, mkl: usize) -> FrameworkConfig {
    FrameworkConfig {
        inter_op_pools: pools,
        mkl_threads: mkl,
        intra_op_threads: mkl,
        operator_impl: OperatorImpl::IntraOpParallel,
        ..FrameworkConfig::tuned_default()
    }
}

fn main() {
    let mut b = Bench::new("scheduler");
    let p = CpuPlatform::large2();

    for name in ["resnet50", "inception_v3", "transformer", "densenet121"] {
        let g = models::build(name, models::canonical_batch(name)).unwrap();
        b.run_with_output(&format!("simulate/{name}"), || {
            sim::simulate(&g, &p, &cfg(4, 12)).unwrap().latency_s
        });
    }

    // dispatch-policy overhead: rank precomputation + heap ordering on the
    // widest zoo graph (policy choice must not make the engine itself slow)
    let gt = models::build("transformer", 16).unwrap();
    for policy in SchedPolicy::ALL {
        let c = FrameworkConfig { sched_policy: policy, ..cfg(4, 12) };
        b.run_with_output(&format!("simulate/transformer/{}", policy.name()), || {
            sim::simulate(&gt, &p, &c).unwrap().latency_s
        });
    }

    // prepared-graph fast path: ranks/weights/CSR precomputed once — the
    // per-simulation delta the tuning-throughput subsystem banks on
    let prep = parframe::sim::PreparedGraph::new(&gt);
    for policy in SchedPolicy::ALL {
        let c = FrameworkConfig { sched_policy: policy, ..cfg(4, 12) };
        b.run_with_output(&format!("simulate-prepared/transformer/{}", policy.name()), || {
            sim::simulate_prepared(&prep, &p, &c, &SimOptions::default()).unwrap().latency_s
        });
    }

    // graph construction itself
    b.run_with_output("build/transformer", || models::build("transformer", 16).unwrap().len());
    b.run_with_output("build/inception_v3", || models::build("inception_v3", 16).unwrap().len());

    // width analysis
    let g = models::build("transformer", 16).unwrap();
    b.run_with_output("width/transformer", || parframe::graph::analyze_width(&g).avg_width);

    // trace-recording overhead
    let g2 = models::build("inception_v2", 16).unwrap();
    b.run_with_output("simulate+timelines/inception_v2", || {
        sim::simulate_opts(&g2, &p, &cfg(2, 24), &SimOptions { record_timelines: true })
            .unwrap()
            .timelines
            .len()
    });

    b.finish();
}
