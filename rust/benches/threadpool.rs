//! Bench: the paper's Fig. 14 measurement for real — 10k micro-tasks
//! through each thread-pool implementation at 4 and 64 threads — plus
//! the pool-substrate cases: the preserved mutex [`ReferencePool`]
//! plane, `EigenPool`'s batched scatter/gather, and the headline
//! `fastpath-vs-reference` ratios that `parframe bench-check` validates
//! in the committed `BENCH_threadpool.json`.
//! (In-tree harness; criterion is unavailable offline.)

use parframe::bench_tables::libraries::{measure_pool_10k_on, measure_pool_batch_10k_on};
use parframe::config::PoolLib;
use parframe::libs::threadpool::{make_pool, scatter_gather, EigenPool, ReferencePool};
use parframe::util::bench::Bench;

fn main() {
    let mut b = Bench::new("threadpool");

    // Per-task submission plane: one `execute` and one wrapper closure
    // per task (the historical Fig. 14 shape) on every pool flavour.
    for threads in [4usize, 64] {
        for lib in PoolLib::ALL {
            let pool = make_pool(lib, threads);
            b.run_with_output(&format!("{}/{}threads/10k-tasks", lib.name(), threads), || {
                measure_pool_10k_on(pool.as_ref())
            });
        }
        let reference = ReferencePool::new(threads);
        b.run_with_output(&format!("reference/{threads}threads/10k-tasks"), || {
            measure_pool_10k_on(&reference)
        });
    }

    // Dispatch-only cost: single submit+join round-trips.
    for lib in PoolLib::ALL {
        let pool = make_pool(lib, 2);
        b.run(&format!("{}/single-task-roundtrip", lib.name()), || {
            scatter_gather(pool.as_ref(), vec![Box::new(|| {})]);
        });
    }
    let reference = ReferencePool::new(2);
    b.run("reference/single-task-roundtrip", || {
        scatter_gather(&reference, vec![Box::new(|| {})]);
    });

    // Batch plane: `EigenPool::execute_batch_counted` — one injection,
    // one wake decision, the completion latch carried inside the queue
    // units instead of a wrapper box per task.
    for threads in [4usize, 64] {
        let pool = EigenPool::new(threads);
        b.run_with_output(&format!("Eigen/{threads}threads/batch-submit"), || {
            measure_pool_batch_10k_on(&pool)
        });
    }

    // Headline ratios: 10k-task scatter/gather on the lock-free
    // substrate vs the preserved mutex reference plane. ≥ 1.5x at
    // 4 threads is the PR's acceptance bar; no regression at 64.
    for (case, threads) in
        [("fastpath-vs-reference", 4usize), ("fastpath-vs-reference/64threads", 64)]
    {
        let eigen = EigenPool::new(threads);
        let reference = ReferencePool::new(threads);
        let samples = if b.is_fast() { 3 } else { 7 };
        let mut ratios = Vec::with_capacity(samples);
        for _ in 0..samples {
            let fast = measure_pool_batch_10k_on(&eigen);
            let slow = measure_pool_batch_10k_on(&reference);
            ratios.push(slow / fast);
        }
        b.record_samples(case, ratios, "x");
    }

    b.finish();
}
