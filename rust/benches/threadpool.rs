//! Bench: the paper's Fig. 14 measurement for real — 10k micro-tasks
//! through each thread-pool implementation at 4 and 64 threads.
//! (In-tree harness; criterion is unavailable offline.)

use parframe::bench_tables::libraries::measure_pool_10k;
use parframe::config::PoolLib;
use parframe::util::bench::Bench;

fn main() {
    let mut b = Bench::new("threadpool");
    for lib in PoolLib::ALL {
        for threads in [4usize, 64] {
            b.run_with_output(&format!("{}/{}threads/10k-tasks", lib.name(), threads), || {
                measure_pool_10k(lib, threads)
            });
        }
    }
    // dispatch-only cost: single submit+join round-trips
    for lib in PoolLib::ALL {
        let pool = parframe::libs::threadpool::make_pool(lib, 2);
        b.run(&format!("{}/single-task-roundtrip", lib.name()), || {
            parframe::libs::threadpool::scatter_gather(pool.as_ref(), vec![Box::new(|| {})]);
        });
    }
    b.finish();
}
