//! Bench: the sim-backed serving data plane. Measures saturation
//! throughput and fixed-load tail latency for both planes — the seed
//! reference loop (string-keyed batchers, one-at-a-time ingress,
//! allocating cuts) vs the fast path (interned kinds, batched drain,
//! recycled batch buffers) — under both lane regimes (unassigned lanes
//! and a core-aware §8 plan), plus the coordinator round-trip micro-case.
//!
//! `fastpath-vs-seed` is the committed regression gate: the unassigned
//! saturation ratio, required ≥ 1.5x by `parframe bench-check`.

use std::collections::HashMap;
use std::time::Duration;

use parframe::config::CpuPlatform;
use parframe::coordinator::{loadgen, BatchPolicy, Coordinator, CoordinatorConfig, LoadgenConfig};
use parframe::runtime::gen_input;
use parframe::sched::LanePlan;
use parframe::util::bench::Bench;

const KIND: &str = "wide_deep";

/// `core_aware` picks the lane regime; `reference` picks the data plane.
fn coordinator(core_aware: bool, reference: bool) -> Coordinator {
    let platform = CpuPlatform::large();
    let mut cfg = CoordinatorConfig::sim(platform.clone(), &[KIND]);
    cfg.lanes = 2;
    cfg.policy = BatchPolicy { max_wait: Duration::from_micros(200), max_batch: usize::MAX };
    if core_aware {
        cfg = cfg.with_plan(LanePlan::guideline(&platform, &[KIND]).expect("guideline plan"));
    }
    Coordinator::start(cfg.with_reference_loop(reference)).expect("start sim coordinator")
}

/// Closed-loop saturation: 8 workers re-submit as fast as responses come
/// back, so throughput is bounded by coordinator overhead, not arrivals.
fn saturation(coord: &Coordinator, requests: usize) -> f64 {
    // warm-up primes lanes, sim tables, and the batch pool
    loadgen::run(coord, &LoadgenConfig::closed(KIND, requests / 4, 8)).expect("warm-up");
    let r = loadgen::run(coord, &LoadgenConfig::closed(KIND, requests, 8)).expect("saturation");
    assert_eq!(r.errors, 0, "saturation run had errors");
    r.throughput_rps
}

/// Open-loop fixed load well below saturation: tail latency reflects
/// batch-cut waits and dispatch overhead rather than queueing collapse.
fn fixed_load(coord: &Coordinator, requests: usize, rate_rps: f64) -> (f64, f64) {
    loadgen::run(coord, &LoadgenConfig::open(KIND, requests / 4, rate_rps)).expect("warm-up");
    let r = loadgen::run(coord, &LoadgenConfig::open(KIND, requests, rate_rps)).expect("open run");
    assert_eq!(r.errors, 0, "fixed-load run had errors");
    (r.wall_p50_ms, r.wall_p99_ms)
}

fn main() {
    let mut b = Bench::new("serving");
    let (sat_n, fixed_n, rate_rps) =
        if b.is_fast() { (512, 256, 2_000.0) } else { (4096, 1024, 4_000.0) };

    {
        let coord = coordinator(false, false);
        let dims = coord.router().item_shape(KIND).unwrap().dims();
        b.run_with_output("sim/single-roundtrip", || {
            coord.infer(KIND, gen_input(3, &dims, 1.0)).unwrap().is_ok()
        });
    }

    let mut sat: HashMap<(&str, &str), f64> = HashMap::new();
    for (regime, core_aware) in [("unassigned", false), ("core-aware", true)] {
        for (plane, reference) in [("seed", true), ("fastpath", false)] {
            let coord = coordinator(core_aware, reference);
            let rps = saturation(&coord, sat_n);
            b.record(&format!("saturation/{regime}/{plane}"), rps, "req/s");
            sat.insert((regime, plane), rps);
        }
        let coord = coordinator(core_aware, false);
        let (p50, p99) = fixed_load(&coord, fixed_n, rate_rps);
        b.record(&format!("fixed-load/{regime}/p50"), p50, "ms");
        b.record(&format!("fixed-load/{regime}/p99"), p99, "ms");
        let stats = coord.pool_stats();
        println!("serving/{regime} pool: {stats:?}");
    }

    let ratio = sat[&("unassigned", "fastpath")] / sat[&("unassigned", "seed")];
    b.record("fastpath-vs-seed", ratio, "x");
    b.finish();
}
