//! Bench: the sim-backed serving path — coordinator round-trips and
//! closed-loop load generation with zero external artifacts. This is the
//! coordinator-overhead counterpart of `benches/runtime.rs` (which needs
//! AOT artifacts and measures real PJRT execution).

use std::time::Duration;

use parframe::config::CpuPlatform;
use parframe::coordinator::{loadgen, BatchPolicy, Coordinator, CoordinatorConfig, LoadgenConfig};
use parframe::runtime::gen_input;
use parframe::util::bench::Bench;

fn coordinator(lanes: usize, max_wait: Duration) -> Coordinator {
    let mut cfg = CoordinatorConfig::sim(CpuPlatform::large(), &["wide_deep"]);
    cfg.lanes = lanes;
    cfg.policy = BatchPolicy { max_wait, max_batch: usize::MAX };
    Coordinator::start(cfg).expect("start sim coordinator")
}

fn main() {
    let mut b = Bench::new("serving");

    let coord = coordinator(1, Duration::from_micros(200));
    let dims = coord.router().item_shape("wide_deep").unwrap().dims();

    b.run_with_output("sim/single-roundtrip", || {
        coord.infer("wide_deep", gen_input(3, &dims, 1.0)).unwrap().is_ok()
    });

    b.run_with_output("sim/16-concurrent", || {
        let rxs: Vec<_> = (0..16)
            .map(|t| coord.submit("wide_deep", gen_input(t, &dims, 1.0)).unwrap())
            .collect();
        rxs.into_iter().filter(|rx| rx.recv().unwrap().is_ok()).count()
    });

    b.run_with_output("sim/loadgen-closed-64x4", || {
        let r = loadgen::run(&coord, &LoadgenConfig::closed("wide_deep", 64, 4)).unwrap();
        assert_eq!(r.errors, 0);
        r.completed
    });

    drop(coord);
    let two_lanes = coordinator(2, Duration::from_micros(200));
    b.run_with_output("sim/2-lanes/loadgen-closed-64x8", || {
        let r = loadgen::run(&two_lanes, &LoadgenConfig::closed("wide_deep", 64, 8)).unwrap();
        assert_eq!(r.errors, 0);
        r.completed
    });
    println!("coordinator metrics: {}", two_lanes.metrics().summary());
    b.finish();
}
