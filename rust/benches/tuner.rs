//! Bench: tuning throughput — the parallel, memoized sweep vs the
//! serial path, reported as evaluated design points per second (the
//! acceptance metric of the tuning-throughput subsystem), plus the
//! branch-and-bound cut (`pruned-cold`, `pruned-vs-flat`,
//! `simulated-fraction`) and the serving cold-start cut from parallel
//! latency-table pre-simulation.
//!
//! Sweep cases re-run the whole sweep several times in full mode
//! (`record_samples`, so `iters`/`p95`/`sd` in the emitted JSON are
//! real statistics, not single shots); fast mode runs each once. Case
//! names are fixed — they never embed the jobs count — so the emitted
//! `BENCH_tuner.json` is diffable across machines.
//!
//! Correctness gates (run in CI fast mode): the pruned sweep must match
//! the flat sweep bit-for-bit, and `bound_unsound()` must stay zero —
//! no simulated point may ever undercut its analytic lower bound.

use std::sync::Arc;
use std::time::Instant;

use parframe::config::CpuPlatform;
use parframe::models;
use parframe::runtime::{BackendFactory, SimBackendConfig, SimBackendFactory};
use parframe::sim::SimCache;
use parframe::tuner::{
    bound_unsound, default_jobs, exhaustive_search_with, SearchResult, SweepOptions, SweepPool,
};
use parframe::util::bench::Bench;

fn timed_sweep(
    graph: &parframe::graph::Graph,
    platform: &CpuPlatform,
    opts: &SweepOptions,
) -> (SearchResult, f64) {
    let t0 = Instant::now();
    let r = exhaustive_search_with(graph, platform, opts).unwrap();
    (r, t0.elapsed().as_secs_f64().max(1e-12))
}

fn main() {
    let mut b = Bench::new("tuner");
    let platform = CpuPlatform::large2();
    let jobs = default_jobs();
    let iters = if b.is_fast() { 1 } else { 3 };
    println!("tuner bench on {} (jobs={jobs}, iters={iters})", platform.name);

    for name in ["wide_deep", "inception_v3"] {
        let g = models::build(name, models::canonical_batch(name)).unwrap();
        // one persistent executor shared by every parallel case for this
        // model — steady-state sweeps must not pay a pool spawn each
        let pool = Arc::new(SweepPool::new(jobs));
        let mut serial_s = Vec::new();
        let mut par_s = Vec::new();
        let mut pruned_s = Vec::new();
        let mut warming_s = Vec::new();
        let mut warm_s = Vec::new();
        let (mut flat_wall, mut pruned_wall) = (0.0f64, 0.0f64);
        let mut fraction = 1.0f64;
        for _ in 0..iters {
            // serial flat baseline (fresh cache ⇒ every point simulates)
            let (serial, ws) =
                timed_sweep(&g, &platform, &SweepOptions::with_jobs(1).prune(false));
            serial_s.push(serial.evaluated as f64 / ws);
            // parallel flat, cold cache: the wall-clock win to report
            let (par, wp) = timed_sweep(
                &g,
                &platform,
                &SweepOptions::with_jobs(jobs).prune(false).on_pool(Arc::clone(&pool)),
            );
            par_s.push(par.evaluated as f64 / wp);
            flat_wall += wp;
            // branch-and-bound, cold cache: same lattice credit (the
            // numerator stays `evaluated`), far fewer simulations
            let (pruned, wb) = timed_sweep(
                &g,
                &platform,
                &SweepOptions::with_jobs(jobs).on_pool(Arc::clone(&pool)),
            );
            pruned_s.push(pruned.evaluated as f64 / wb);
            pruned_wall += wb;
            fraction = pruned.simulated as f64 / pruned.evaluated.max(1) as f64;
            // memoized re-sweep: a warm cache answers without simulating
            let cache = Arc::new(SimCache::new());
            let warm_opts = SweepOptions::shared(jobs, Arc::clone(&cache))
                .prune(false)
                .on_pool(Arc::clone(&pool));
            let (warming, ww) = timed_sweep(&g, &platform, &warm_opts);
            warming_s.push(warming.evaluated as f64 / ww);
            let (warm, wr) = timed_sweep(&g, &platform, &warm_opts);
            warm_s.push(warm.evaluated as f64 / wr);

            assert_eq!(serial.best, par.best, "parallel sweep diverged from serial");
            assert_eq!(serial.best, pruned.best, "pruned sweep diverged from flat");
            assert_eq!(
                serial.best_latency_s.to_bits(),
                pruned.best_latency_s.to_bits(),
                "pruned latency diverged from flat"
            );
            assert_eq!(serial.evaluated, pruned.evaluated, "pruning must not shrink the lattice");
            assert!(pruned.simulated <= pruned.evaluated);
            assert_eq!(
                serial.best_latency_s.to_bits(),
                warm.best_latency_s.to_bits(),
                "memoized sweep diverged from serial"
            );
        }
        b.record_samples(&format!("sweep/{name}/serial-cold"), serial_s, "points/s");
        b.record_samples(&format!("sweep/{name}/parallel-cold"), par_s, "points/s");
        b.record_samples(&format!("sweep/{name}/pruned-cold"), pruned_s, "points/s");
        b.record_samples(&format!("sweep/{name}/warming"), warming_s, "points/s");
        b.record_samples(&format!("sweep/{name}/warm-resweep"), warm_s, "points/s");
        assert!(pool.spawn_count() <= 1, "parallel cases must share one spawned pool");
        if name == "wide_deep" {
            // headline branch-and-bound cut on the largest platform:
            // flat vs pruned wall clock, and the fraction of lattice
            // points that actually simulated under pruning
            b.record("pruned-vs-flat", flat_wall / pruned_wall.max(1e-12), "x");
            b.record("simulated-fraction", fraction, "fraction");
        }
    }

    // the CI gate: no simulated point anywhere above may have come in
    // below its admissible lower bound
    assert_eq!(bound_unsound(), 0, "admissible bound violated during sweeps");

    // serving cold-start: lane-table pre-simulation for a three-model
    // catalog, serial vs parallel factory
    let kinds = ["wide_deep", "resnet50", "transformer"];
    for (label, jobs) in [("serial", 1), ("parallel", jobs)] {
        let mut cfg = SimBackendConfig::new(CpuPlatform::large2(), &kinds);
        cfg.jobs = jobs;
        let factory = SimBackendFactory::new(cfg);
        let t0 = Instant::now();
        factory.create().unwrap();
        let wall = t0.elapsed().as_secs_f64();
        b.record(&format!("coldstart/3-kinds/{label}"), wall, "s");
        println!(
            "tuner/coldstart/3-kinds {label:<8} sims={}",
            factory.cache().misses()
        );
    }

    b.finish();
}
