//! Bench: tuning throughput — the parallel, memoized sweep vs the
//! serial path, reported as evaluated design points per second (the
//! acceptance metric of the tuning-throughput subsystem), plus the
//! serving cold-start cut from parallel latency-table pre-simulation.
//!
//! Each sweep runs once (a full exhaustive lattice is the workload, not
//! a microsecond-scale case), so this target records whole-sweep
//! metrics with `Bench::record` instead of the repeated-timing loop.
//! Case names are fixed — they never embed the jobs count — so the
//! emitted `BENCH_tuner.json` is diffable across machines.

use std::sync::Arc;
use std::time::Instant;

use parframe::config::CpuPlatform;
use parframe::models;
use parframe::runtime::{BackendFactory, SimBackendConfig, SimBackendFactory};
use parframe::sim::SimCache;
use parframe::tuner::{default_jobs, exhaustive_search_with, SearchResult, SweepOptions};
use parframe::util::bench::Bench;

fn sweep(
    b: &mut Bench,
    case: &str,
    graph: &parframe::graph::Graph,
    platform: &CpuPlatform,
    opts: &SweepOptions,
) -> SearchResult {
    let t0 = Instant::now();
    let r = exhaustive_search_with(graph, platform, opts).unwrap();
    let wall = t0.elapsed().as_secs_f64();
    b.record(case, r.evaluated as f64 / wall.max(1e-12), "points/s");
    r
}

fn main() {
    let mut b = Bench::new("tuner");
    let platform = CpuPlatform::large2();
    let jobs = default_jobs();
    println!("tuner bench on {} (jobs={jobs})", platform.name);

    for name in ["wide_deep", "inception_v3"] {
        let g = models::build(name, models::canonical_batch(name)).unwrap();
        // serial baseline (fresh cache ⇒ every point simulates)
        let serial = sweep(
            &mut b,
            &format!("sweep/{name}/serial-cold"),
            &g,
            &platform,
            &SweepOptions::with_jobs(1),
        );
        // parallel, cold cache: the wall-clock win to report
        let par = sweep(
            &mut b,
            &format!("sweep/{name}/parallel-cold"),
            &g,
            &platform,
            &SweepOptions::with_jobs(jobs),
        );
        // memoized re-sweep: a warm cache answers without simulating
        let cache = Arc::new(SimCache::new());
        sweep(
            &mut b,
            &format!("sweep/{name}/warming"),
            &g,
            &platform,
            &SweepOptions::shared(jobs, Arc::clone(&cache)),
        );
        let warm = sweep(
            &mut b,
            &format!("sweep/{name}/warm-resweep"),
            &g,
            &platform,
            &SweepOptions::shared(jobs, Arc::clone(&cache)),
        );
        println!(
            "tuner/sweep/{name:<14} cache hits={} misses={} delta-hits={}",
            cache.hits(),
            cache.misses(),
            cache.delta_hits()
        );
        assert_eq!(serial.best, par.best, "parallel sweep diverged from serial");
        assert_eq!(
            serial.best_latency_s.to_bits(),
            warm.best_latency_s.to_bits(),
            "memoized sweep diverged from serial"
        );
    }

    // serving cold-start: lane-table pre-simulation for a three-model
    // catalog, serial vs parallel factory
    let kinds = ["wide_deep", "resnet50", "transformer"];
    for (label, jobs) in [("serial", 1), ("parallel", jobs)] {
        let mut cfg = SimBackendConfig::new(CpuPlatform::large2(), &kinds);
        cfg.jobs = jobs;
        let factory = SimBackendFactory::new(cfg);
        let t0 = Instant::now();
        factory.create().unwrap();
        let wall = t0.elapsed().as_secs_f64();
        b.record(&format!("coldstart/3-kinds/{label}"), wall, "s");
        println!(
            "tuner/coldstart/3-kinds {label:<8} sims={}",
            factory.cache().misses()
        );
    }

    b.finish();
}
