//! Bench: tuning throughput — the parallel, memoized sweep vs the
//! serial path, reported as evaluated design points per second (the
//! acceptance metric of the tuning-throughput subsystem), plus the
//! serving cold-start cut from parallel latency-table pre-simulation.
//!
//! Each sweep runs once (a full exhaustive lattice is the workload, not
//! a microsecond-scale case), so this target prints its own rows
//! instead of using the repeated-timing harness.

use std::sync::Arc;
use std::time::Instant;

use parframe::config::CpuPlatform;
use parframe::models;
use parframe::runtime::{BackendFactory, SimBackendConfig, SimBackendFactory};
use parframe::sim::SimCache;
use parframe::tuner::{default_jobs, exhaustive_search_with, SearchResult, SweepOptions};
use parframe::util::bench::fmt_t;

fn sweep(
    name: &str,
    graph: &parframe::graph::Graph,
    platform: &CpuPlatform,
    opts: &SweepOptions,
    label: &str,
) -> SearchResult {
    let t0 = Instant::now();
    let r = exhaustive_search_with(graph, platform, opts);
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "tuner/sweep/{name:<14} {label:<18} evaluated={:<5} wall={:<10} points/s={:.0}",
        r.evaluated,
        fmt_t(wall),
        r.evaluated as f64 / wall.max(1e-12)
    );
    r
}

fn main() {
    let platform = CpuPlatform::large2();
    let jobs = default_jobs();
    println!("tuner bench on {} (jobs={jobs})", platform.name);

    for name in ["wide_deep", "inception_v3"] {
        let g = models::build(name, models::canonical_batch(name)).unwrap();
        // serial baseline (fresh cache ⇒ every point simulates)
        let serial = sweep(name, &g, &platform, &SweepOptions::with_jobs(1), "jobs=1 cold");
        // parallel, cold cache: the wall-clock win to report
        let par = sweep(
            name,
            &g,
            &platform,
            &SweepOptions::with_jobs(jobs),
            &format!("jobs={jobs} cold"),
        );
        // memoized re-sweep: a warm cache answers without simulating
        let cache = Arc::new(SimCache::new());
        sweep(name, &g, &platform, &SweepOptions::shared(jobs, Arc::clone(&cache)), "warming");
        let warm = sweep(
            name,
            &g,
            &platform,
            &SweepOptions::shared(jobs, Arc::clone(&cache)),
            "warm re-sweep",
        );
        println!(
            "tuner/sweep/{name:<14} cache hits={} misses={}",
            cache.hits(),
            cache.misses()
        );
        assert_eq!(serial.best, par.best, "parallel sweep diverged from serial");
        assert_eq!(
            serial.best_latency_s.to_bits(),
            warm.best_latency_s.to_bits(),
            "memoized sweep diverged from serial"
        );
    }

    // serving cold-start: lane-table pre-simulation for a three-model
    // catalog, serial vs parallel factory
    let kinds = ["wide_deep", "resnet50", "transformer"];
    for jobs in [1, jobs] {
        let mut cfg = SimBackendConfig::new(CpuPlatform::large2(), &kinds);
        cfg.jobs = jobs;
        let factory = SimBackendFactory::new(cfg);
        let t0 = Instant::now();
        factory.create().unwrap();
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "tuner/coldstart/3-kinds jobs={jobs:<2} tables wall={:<10} sims={}",
            fmt_t(wall),
            factory.cache().misses()
        );
    }

    println!("bench suite 'tuner' done");
}
