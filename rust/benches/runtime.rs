//! Bench: the real serving hot path — PJRT execution of the AOT artifacts
//! and end-to-end coordinator round-trips.
//!
//! Skips (with a notice) when `make artifacts` has not been run.

use std::path::Path;
use std::time::Duration;

use parframe::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig};
use parframe::runtime::{gen_input, ModelRuntime};
use parframe::util::bench::Bench;

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("runtime bench skipped: artifacts/ not built (run `make artifacts`)");
        return;
    }
    let mut b = Bench::new("runtime");

    let rt = ModelRuntime::load_some(dir, |e| e.kind == "mlp" || e.name == "matmul_256")
        .expect("load artifacts");

    // raw PJRT execution per batch bucket
    for bucket in [1usize, 4, 8] {
        let name = format!("mlp_b{bucket}");
        let x = gen_input(7, &[bucket, 256], 1.0);
        b.run_with_output(&format!("pjrt/{name}"), || {
            rt.execute_x(&name, x.clone()).unwrap().data.len()
        });
    }
    let entry = rt.manifest().get("matmul_256").unwrap().clone();
    let inputs: Vec<_> = entry.inputs.iter().map(|s| s.generate()).collect();
    b.run_with_output("pjrt/matmul_256", || {
        rt.execute("matmul_256", &inputs).unwrap().data.len()
    });

    // coordinator round-trip (batching + channels + PJRT)
    let mut cfg = CoordinatorConfig::for_kind(dir, "mlp");
    cfg.policy = BatchPolicy { max_wait: Duration::from_micros(200), max_batch: 8 };
    let coord = Coordinator::start(cfg).expect("start coordinator");
    b.run_with_output("coordinator/single-roundtrip", || {
        coord.infer("mlp", gen_input(3, &[1, 256], 1.0)).unwrap().is_ok()
    });
    b.run_with_output("coordinator/8-concurrent", || {
        let rxs: Vec<_> = (0..8)
            .map(|t| coord.submit("mlp", gen_input(t, &[1, 256], 1.0)).unwrap())
            .collect();
        rxs.into_iter().filter(|rx| rx.recv().unwrap().is_ok()).count()
    });
    println!("coordinator metrics: {}", coord.metrics().summary());
    b.finish();
}
