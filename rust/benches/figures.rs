//! Bench: end-to-end figure regeneration — one case per paper table/figure
//! (the `cargo bench` entry the DESIGN.md experiment index points at).
//!
//! Each case regenerates the figure's full data series; timings bound how
//! long `parframe figures --all` takes.

use parframe::bench_tables;
use parframe::util::bench::Bench;

fn main() {
    // figure generation involves exhaustive search for fig 18 — keep the
    // harness snappy unless the user asked for full statistics
    if std::env::var("PARFRAME_BENCH_FULL").is_err() {
        std::env::set_var("PARFRAME_BENCH_FAST", "1");
    }
    let mut b = Bench::new("figures");
    for n in bench_tables::FIGURES {
        b.run_with_output(&format!("fig{n:02}"), || bench_tables::figure(n).unwrap().len());
    }
    b.run_with_output("table02", || bench_tables::table(2).unwrap().len());
    b.finish();
}
