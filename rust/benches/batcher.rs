//! Bench: coordinator hot paths — batch formation and router validation
//! (these run per request; they must stay far below model-execution time).

use std::path::Path;
use std::sync::mpsc::channel;
use std::time::{Duration, Instant};

use parframe::coordinator::batcher::{BatchPolicy, DynamicBatcher};
use parframe::coordinator::pool::BatchBuf;
use parframe::coordinator::request::{Request, RequestId};
use parframe::coordinator::router::Router;
use parframe::runtime::{KindId, Manifest, Tensor};
use parframe::util::bench::Bench;

const MANIFEST: &str = r#"{"version":1,"artifacts":[
  {"name":"mlp_b1","file":"f","kind":"mlp","batch":1,
   "inputs":[{"shape":[1,256],"tag":0,"scale":1.0}],"output_shape":[1,8],
   "expected":{"prefix":[],"sum":0,"abs_sum":0,"count":8}},
  {"name":"mlp_b2","file":"f","kind":"mlp","batch":2,
   "inputs":[{"shape":[2,256],"tag":0,"scale":1.0}],"output_shape":[2,8],
   "expected":{"prefix":[],"sum":0,"abs_sum":0,"count":16}},
  {"name":"mlp_b4","file":"f","kind":"mlp","batch":4,
   "inputs":[{"shape":[4,256],"tag":0,"scale":1.0}],"output_shape":[4,8],
   "expected":{"prefix":[],"sum":0,"abs_sum":0,"count":32}},
  {"name":"mlp_b8","file":"f","kind":"mlp","batch":8,
   "inputs":[{"shape":[8,256],"tag":0,"scale":1.0}],"output_shape":[8,8],
   "expected":{"prefix":[],"sum":0,"abs_sum":0,"count":64}}
]}"#;

fn req(id: u64) -> Request {
    let (tx, _rx) = channel();
    Request {
        id: RequestId(id),
        kind: KindId(0),
        input: Tensor { shape: vec![1, 256], data: vec![0.0; 256] },
        enqueued: Instant::now(),
        reply: tx,
    }
}

fn main() {
    let mut b = Bench::new("batcher");
    let manifest = Manifest::parse(Path::new("/tmp"), MANIFEST).unwrap();

    b.run("push+cut/64-requests", || {
        let mut batcher = DynamicBatcher::new(
            KindId(0),
            manifest.buckets("mlp"),
            BatchPolicy { max_wait: Duration::ZERO, max_batch: 8 },
        );
        for i in 0..64 {
            batcher.push(req(i));
        }
        while !batcher.is_empty() {
            std::hint::black_box(batcher.cut());
        }
    });

    b.run("push+cut_into/64-requests-recycled", || {
        let mut batcher = DynamicBatcher::new(
            KindId(0),
            manifest.buckets("mlp"),
            BatchPolicy { max_wait: Duration::ZERO, max_batch: 8 },
        );
        for i in 0..64 {
            batcher.push(req(i));
        }
        let mut buf = BatchBuf::new();
        while !batcher.is_empty() {
            let batch = std::hint::black_box(batcher.cut_into(buf));
            buf = batch.recycle();
        }
    });

    let router = Router::new(&manifest.catalog(&["mlp"]).unwrap()).unwrap();
    let r = req(0);
    b.run_with_output("router/validate", || router.route("mlp", &r.input).is_ok());

    b.run_with_output("manifest/parse", || {
        Manifest::parse(Path::new("/tmp"), MANIFEST).unwrap().artifacts.len()
    });

    b.finish();
}
