//! Trace emitters: render simulator timelines as ASCII (the paper's Fig. 8
//! execution traces) or Chrome `chrome://tracing` JSON for interactive
//! inspection.

use std::fmt::Write as _;

use crate::sim::{Category, Segment};
use crate::util::json::{self, Json};

/// Glyph for a category in ASCII traces.
fn glyph(cat: Category) -> char {
    match cat {
        Category::MklCompute => '#',
        Category::MklPrep => '+',
        Category::FwPrep => 'p',
        Category::FwNative => 'n',
        Category::FwSched => 's',
        Category::Barrier => '.',
        Category::UpiTransfer => 'u',
        Category::Idle => ' ',
    }
}

/// Render per-core timelines as an ASCII trace, `width` columns wide.
///
/// Each row is one logical core; each column is a time bucket; the glyph is
/// the category that dominated the bucket. A legend is appended.
pub fn ascii_trace(timelines: &[Vec<Segment>], latency: f64, width: usize) -> String {
    let mut out = String::new();
    let width = width.max(10);
    for (core, tl) in timelines.iter().enumerate() {
        if tl.is_empty() {
            continue;
        }
        let mut row = vec![' '; width];
        for seg in tl {
            // a zero-latency run (empty graph / all-cached path) has no
            // time extent; dividing by it yields NaN column indices, so
            // such segments draw nothing
            let (c0, c1) = if latency > 0.0 {
                (
                    ((seg.t0 / latency) * width as f64).floor() as usize,
                    (((seg.t1 / latency) * width as f64).ceil() as usize).min(width),
                )
            } else {
                (0, 0)
            };
            for slot in row.iter_mut().take(c1).skip(c0.min(width)) {
                // later segments overwrite idle but not real work
                if *slot == ' ' || *slot == '.' {
                    *slot = glyph(seg.cat);
                }
            }
        }
        let exec_frac = executing_fraction(tl, latency);
        let _ = writeln!(
            out,
            "core {core:>3} |{}| {:>4.0}%",
            row.iter().collect::<String>(),
            exec_frac * 100.0
        );
    }
    out.push_str("legend: #=MKL compute +=MKL prep p=TF prep n=native s=sched .=barrier u=UPI\n");
    out
}

/// Fraction of the run a core spent executing (not barrier/idle) — the
/// per-trace percentage the paper prints beside Fig. 8.
pub fn executing_fraction(tl: &[Segment], latency: f64) -> f64 {
    if latency <= 0.0 {
        return 0.0;
    }
    let busy: f64 = tl
        .iter()
        .filter(|s| !matches!(s.cat, Category::Barrier | Category::Idle))
        .map(|s| s.dur())
        .sum();
    (busy / latency).min(1.0)
}

/// Convert timelines to Chrome trace-event JSON.
pub fn chrome_trace(timelines: &[Vec<Segment>]) -> String {
    let mut events = Vec::new();
    for (core, tl) in timelines.iter().enumerate() {
        for seg in tl {
            let mut obj = std::collections::BTreeMap::new();
            obj.insert("name".to_string(), Json::Str(seg.cat.label().into()));
            obj.insert("ph".to_string(), Json::Str("X".into()));
            obj.insert("ts".to_string(), Json::Num(seg.t0 * 1e6));
            obj.insert("dur".to_string(), Json::Num(seg.dur() * 1e6));
            obj.insert("pid".to_string(), Json::Num(0.0));
            obj.insert("tid".to_string(), Json::Num(core as f64));
            let mut args = std::collections::BTreeMap::new();
            args.insert("op".to_string(), Json::Num(seg.op as f64));
            obj.insert("args".to_string(), Json::Obj(args));
            events.push(Json::Obj(obj));
        }
    }
    json::to_string(&Json::Arr(events))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seg(t0: f64, t1: f64, cat: Category) -> Segment {
        Segment { t0, t1, cat, op: 0 }
    }

    #[test]
    fn ascii_renders_rows_and_legend() {
        let tls = vec![
            vec![seg(0.0, 0.5, Category::MklCompute), seg(0.5, 1.0, Category::Barrier)],
            vec![seg(0.0, 1.0, Category::FwPrep)],
        ];
        let s = ascii_trace(&tls, 1.0, 20);
        assert!(s.contains("core   0"));
        assert!(s.contains('#'));
        assert!(s.contains('p'));
        assert!(s.contains("legend"));
    }

    #[test]
    fn executing_fraction_excludes_barrier() {
        let tl = vec![seg(0.0, 0.6, Category::MklCompute), seg(0.6, 1.0, Category::Barrier)];
        assert!((executing_fraction(&tl, 1.0) - 0.6).abs() < 1e-12);
    }

    #[test]
    fn chrome_trace_is_valid_json() {
        let tls = vec![vec![seg(0.0, 0.5, Category::MklCompute)]];
        let s = chrome_trace(&tls);
        let v = Json::parse(&s).unwrap();
        assert_eq!(v.as_arr().unwrap().len(), 1);
    }

    #[test]
    fn zero_latency_renders_without_nan() {
        // regression: latency 0.0 (empty graph / all-cached) used to
        // produce NaN column indices and garbage rows
        let tls = vec![vec![seg(0.0, 0.0, Category::MklCompute)]];
        let s = ascii_trace(&tls, 0.0, 12);
        let row = s.lines().next().unwrap();
        assert!(row.starts_with("core   0"));
        assert!(row.contains("0%"));
        assert!(!row.contains('#'), "zero-extent segment must draw nothing: {row}");
        assert!(s.contains("legend"));
    }

    #[test]
    fn empty_cores_skipped() {
        let tls = vec![Vec::new(), vec![seg(0.0, 1.0, Category::FwNative)]];
        let s = ascii_trace(&tls, 1.0, 10);
        assert!(!s.contains("core   0"));
        assert!(s.contains("core   1"));
    }
}
