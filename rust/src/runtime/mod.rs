//! Runtime: loads the AOT-compiled JAX/Pallas artifacts (HLO text) and
//! executes them on the PJRT CPU client — the only place real numerics
//! happen in the Rust layer. Python never runs on this path.
//!
//! * [`artifact`] — `artifacts/manifest.json` schema + deterministic input
//!   generation (mirrors `python/compile/aot.py`).
//! * [`client`] — the `xla` crate wrapper: HLO text → compile → execute.

pub mod artifact;
pub mod client;

pub use artifact::{gen_input, ArtifactEntry, Manifest, Tensor};
pub use client::ModelRuntime;
