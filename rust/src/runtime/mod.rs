//! Runtime: pluggable execution backends for the serving path.
//!
//! * [`backend`] — the [`Backend`]/[`BackendFactory`] traits and the
//!   [`Catalog`] contract the coordinator builds its router and batchers
//!   from.
//! * [`client`] — the PJRT backend: loads AOT-compiled JAX/Pallas
//!   artifacts (HLO text) and executes them on the PJRT CPU client. In
//!   hermetic builds the `xla` dependency is an offline stub and this
//!   path errors at load time.
//! * [`sim_backend`] — the simulation backend: deterministic numerics +
//!   per-batch latency from the discrete-event simulator; serves the
//!   whole model zoo with zero external artifacts.
//! * [`artifact`] — `artifacts/manifest.json` schema + deterministic input
//!   generation (mirrors `python/compile/aot.py`).

pub mod artifact;
pub mod backend;
pub mod client;
pub mod sim_backend;

pub use artifact::{gen_input, ArtifactEntry, Manifest, Tensor};
pub use backend::{
    Backend, BackendFactory, Catalog, Execution, ItemShape, KindId, KindTable, ModelSpec,
};
pub use client::{ModelRuntime, PjrtBackend, PjrtBackendFactory};
pub use sim_backend::{SimBackend, SimBackendConfig, SimBackendFactory, SIM_OUT_FEATURES};
