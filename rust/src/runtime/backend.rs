//! Pluggable execution backends for the serving path.
//!
//! The coordinator (router → dynamic batcher → worker lanes) is generic
//! over *what executes a batch*: the PJRT artifact runtime
//! ([`super::client::PjrtBackend`]), the discrete-event simulator
//! ([`super::sim_backend::SimBackend`]), or anything else that can state a
//! [`Catalog`] of servable model families and execute bucketed batches.
//!
//! Two traits split the lifecycle:
//!
//! * [`BackendFactory`] — shared, `Send + Sync`; describes the catalog and
//!   mints per-lane backend instances. Each worker lane calls
//!   [`BackendFactory::create`] **on its own thread**, because real PJRT
//!   clients are `!Sync` and must stay confined to one executor thread.
//! * [`Backend`] — a lane-owned executor; needs no thread-safety bounds.

use crate::error::PallasResult;
use crate::sched::LaneAssignment;

use super::artifact::Tensor;

/// Interned model-family identifier: the position of the kind in its
/// [`Catalog`]'s model list. Dense and stable for the catalog's
/// lifetime, so the serving data plane indexes `Vec`s by it instead of
/// hashing (and cloning) `String` keys on every hop.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct KindId(pub u16);

impl KindId {
    /// The id as a dense `Vec` index.
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// Dense kind-name table derived from a [`Catalog`]: names in catalog
/// order (index = [`KindId`]) plus a name-sorted permutation, so
/// [`KindTable::resolve`] is an allocation-free binary search and the
/// sorted listing needs no per-call sort.
#[derive(Debug, Clone)]
pub struct KindTable {
    names: Vec<String>,
    /// Indices into `names`, sorted by the name they point at.
    by_name: Vec<u16>,
}

impl KindTable {
    /// Intern `names` in the given (catalog) order.
    pub fn new(names: Vec<String>) -> Self {
        assert!(
            names.len() <= u16::MAX as usize,
            "kind table overflows u16 ({} kinds)",
            names.len()
        );
        let mut by_name: Vec<u16> = (0..names.len() as u16).collect();
        by_name.sort_unstable_by(|&a, &b| names[a as usize].cmp(&names[b as usize]));
        KindTable { names, by_name }
    }

    /// Interned id for `name`, if present (binary search, no allocation).
    pub fn resolve(&self, name: &str) -> Option<KindId> {
        self.by_name
            .binary_search_by(|&i| self.names[i as usize].as_str().cmp(name))
            .ok()
            .map(|pos| KindId(self.by_name[pos]))
    }

    /// The name behind an id.
    pub fn name(&self, id: KindId) -> &str {
        &self.names[id.index()]
    }

    /// All names, in id (catalog) order.
    pub fn names(&self) -> &[String] {
        &self.names
    }

    /// All names, sorted — precomputed at construction, no per-call sort.
    pub fn sorted_names(&self) -> Vec<&str> {
        self.by_name.iter().map(|&i| self.names[i as usize].as_str()).collect()
    }

    /// Number of interned kinds.
    pub fn len(&self) -> usize {
        self.names.len()
    }

    /// True when no kind is interned.
    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    /// All ids, in order.
    pub fn ids(&self) -> impl Iterator<Item = KindId> {
        (0..self.names.len() as u16).map(KindId)
    }
}

/// Per-item input contract for one served model family: an item occupies
/// `rows_per_item` rows of the batch dimension and has `feature_dims`
/// trailing dimensions.
#[derive(Debug, Clone, PartialEq)]
pub struct ItemShape {
    /// Rows one item contributes to the batch dimension (1 for an MLP
    /// feature row, the sequence length for a transformer).
    pub rows_per_item: usize,
    /// Trailing feature dimensions.
    pub feature_dims: Vec<usize>,
}

impl ItemShape {
    /// Full tensor dimensions of one item (`[rows_per_item, features...]`).
    pub fn dims(&self) -> Vec<usize> {
        std::iter::once(self.rows_per_item)
            .chain(self.feature_dims.iter().copied())
            .collect()
    }

    /// Element count of one item.
    pub fn elems(&self) -> usize {
        self.rows_per_item * self.feature_dims.iter().product::<usize>()
    }
}

/// One servable model family, as a backend exposes it to the coordinator.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    /// Family name ("mlp" for artifacts, a zoo name for the simulator).
    pub kind: String,
    /// Per-item input contract.
    pub item: ItemShape,
    /// Executable batch buckets, ascending.
    pub buckets: Vec<usize>,
}

/// Everything a backend can serve; drives router + batcher construction.
#[derive(Debug, Clone, Default)]
pub struct Catalog {
    /// Servable model families.
    pub models: Vec<ModelSpec>,
}

impl Catalog {
    /// Spec for a family, if served.
    pub fn get(&self, kind: &str) -> Option<&ModelSpec> {
        self.models.iter().find(|m| m.kind == kind)
    }

    /// Served family names, sorted.
    pub fn kinds(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.models.iter().map(|m| m.kind.as_str()).collect();
        v.sort_unstable();
        v
    }

    /// Intern the served kinds: index = position in `models`, the id
    /// space the whole serving data plane shares.
    pub fn kind_table(&self) -> KindTable {
        KindTable::new(self.models.iter().map(|m| m.kind.clone()).collect())
    }
}

/// Result of executing one batch.
#[derive(Debug, Clone)]
pub struct Execution {
    /// Batched output; first dimension is `bucket × rows_per_item`.
    pub output: Tensor,
    /// Model time for the batch: wall-clock seconds on real backends,
    /// simulated seconds on [`super::sim_backend::SimBackend`].
    pub model_time_s: f64,
}

/// A lane-owned batch executor.
pub trait Backend {
    /// Short backend name for diagnostics ("pjrt", "sim").
    fn name(&self) -> &'static str;

    /// Execute one gathered batch `x` for `kind` at the given bucket; the
    /// first dimension of `x` is `bucket × rows_per_item`, zero-padded
    /// past the live requests. `x` is borrowed so callers can recycle
    /// the gather buffer after the call.
    fn execute(&self, kind: &str, bucket: usize, x: &Tensor) -> PallasResult<Execution>;

    /// Interned-id fast path: like [`Backend::execute`], but keyed by the
    /// [`KindId`] of `kind` in the backend's own catalog, so backends with
    /// dense per-id tables skip the name lookup entirely. The default
    /// forwards to the name path (correct for any backend; `kind` must be
    /// the name behind `id`).
    fn execute_id(
        &self,
        id: KindId,
        kind: &str,
        bucket: usize,
        x: &Tensor,
    ) -> PallasResult<Execution> {
        let _ = id;
        self.execute(kind, bucket, x)
    }
}

/// Shared descriptor + per-lane constructor for a backend.
pub trait BackendFactory: Send + Sync {
    /// What this backend can serve.
    fn catalog(&self) -> PallasResult<Catalog>;

    /// Instantiate a lane-local executor (called on the lane's thread).
    fn create(&self) -> PallasResult<Box<dyn Backend>>;

    /// Instantiate a lane-local executor for a core-aware
    /// [`LaneAssignment`] (called on the lane's thread): the backend
    /// should execute under the assignment's physical-core slice and
    /// framework knobs, serving only the assigned kinds. Backends that
    /// cannot honour core allocations (e.g. PJRT, where the OS schedules
    /// threads) fall back to [`BackendFactory::create`].
    fn create_on(&self, assignment: &LaneAssignment) -> PallasResult<Box<dyn Backend>> {
        let _ = assignment;
        self.create()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn item_shape_dims_and_elems() {
        let s = ItemShape { rows_per_item: 32, feature_dims: vec![64] };
        assert_eq!(s.dims(), vec![32, 64]);
        assert_eq!(s.elems(), 2048);
        let flat = ItemShape { rows_per_item: 1, feature_dims: vec![] };
        assert_eq!(flat.dims(), vec![1]);
        assert_eq!(flat.elems(), 1);
    }

    #[test]
    fn catalog_lookup() {
        let c = Catalog {
            models: vec![
                ModelSpec {
                    kind: "b".into(),
                    item: ItemShape { rows_per_item: 1, feature_dims: vec![4] },
                    buckets: vec![1, 2],
                },
                ModelSpec {
                    kind: "a".into(),
                    item: ItemShape { rows_per_item: 2, feature_dims: vec![8] },
                    buckets: vec![1],
                },
            ],
        };
        assert_eq!(c.kinds(), vec!["a", "b"]);
        assert_eq!(c.get("a").unwrap().item.rows_per_item, 2);
        assert!(c.get("z").is_none());
    }

    #[test]
    fn kind_table_interns_catalog_order() {
        let t = KindTable::new(vec!["wide_deep".into(), "ncf".into(), "transformer".into()]);
        assert_eq!(t.len(), 3);
        // ids follow catalog order, not sort order
        assert_eq!(t.resolve("wide_deep"), Some(KindId(0)));
        assert_eq!(t.resolve("ncf"), Some(KindId(1)));
        assert_eq!(t.resolve("transformer"), Some(KindId(2)));
        assert_eq!(t.resolve("bert"), None);
        assert_eq!(t.name(KindId(1)), "ncf");
        assert_eq!(t.sorted_names(), vec!["ncf", "transformer", "wide_deep"]);
        assert_eq!(t.ids().collect::<Vec<_>>(), vec![KindId(0), KindId(1), KindId(2)]);
    }

    #[test]
    fn kind_table_from_catalog() {
        let c = Catalog {
            models: vec![
                ModelSpec {
                    kind: "b".into(),
                    item: ItemShape { rows_per_item: 1, feature_dims: vec![4] },
                    buckets: vec![1],
                },
                ModelSpec {
                    kind: "a".into(),
                    item: ItemShape { rows_per_item: 1, feature_dims: vec![4] },
                    buckets: vec![1],
                },
            ],
        };
        let t = c.kind_table();
        assert_eq!(t.names(), &["b".to_string(), "a".to_string()]);
        assert_eq!(t.resolve("a"), Some(KindId(1)));
        assert_eq!(t.sorted_names(), vec!["a", "b"]);
    }
}
