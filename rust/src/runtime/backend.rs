//! Pluggable execution backends for the serving path.
//!
//! The coordinator (router → dynamic batcher → worker lanes) is generic
//! over *what executes a batch*: the PJRT artifact runtime
//! ([`super::client::PjrtBackend`]), the discrete-event simulator
//! ([`super::sim_backend::SimBackend`]), or anything else that can state a
//! [`Catalog`] of servable model families and execute bucketed batches.
//!
//! Two traits split the lifecycle:
//!
//! * [`BackendFactory`] — shared, `Send + Sync`; describes the catalog and
//!   mints per-lane backend instances. Each worker lane calls
//!   [`BackendFactory::create`] **on its own thread**, because real PJRT
//!   clients are `!Sync` and must stay confined to one executor thread.
//! * [`Backend`] — a lane-owned executor; needs no thread-safety bounds.

use crate::error::PallasResult;
use crate::sched::LaneAssignment;

use super::artifact::Tensor;

/// Per-item input contract for one served model family: an item occupies
/// `rows_per_item` rows of the batch dimension and has `feature_dims`
/// trailing dimensions.
#[derive(Debug, Clone, PartialEq)]
pub struct ItemShape {
    /// Rows one item contributes to the batch dimension (1 for an MLP
    /// feature row, the sequence length for a transformer).
    pub rows_per_item: usize,
    /// Trailing feature dimensions.
    pub feature_dims: Vec<usize>,
}

impl ItemShape {
    /// Full tensor dimensions of one item (`[rows_per_item, features...]`).
    pub fn dims(&self) -> Vec<usize> {
        std::iter::once(self.rows_per_item)
            .chain(self.feature_dims.iter().copied())
            .collect()
    }

    /// Element count of one item.
    pub fn elems(&self) -> usize {
        self.rows_per_item * self.feature_dims.iter().product::<usize>()
    }
}

/// One servable model family, as a backend exposes it to the coordinator.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    /// Family name ("mlp" for artifacts, a zoo name for the simulator).
    pub kind: String,
    /// Per-item input contract.
    pub item: ItemShape,
    /// Executable batch buckets, ascending.
    pub buckets: Vec<usize>,
}

/// Everything a backend can serve; drives router + batcher construction.
#[derive(Debug, Clone, Default)]
pub struct Catalog {
    /// Servable model families.
    pub models: Vec<ModelSpec>,
}

impl Catalog {
    /// Spec for a family, if served.
    pub fn get(&self, kind: &str) -> Option<&ModelSpec> {
        self.models.iter().find(|m| m.kind == kind)
    }

    /// Served family names, sorted.
    pub fn kinds(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.models.iter().map(|m| m.kind.as_str()).collect();
        v.sort_unstable();
        v
    }
}

/// Result of executing one batch.
#[derive(Debug, Clone)]
pub struct Execution {
    /// Batched output; first dimension is `bucket × rows_per_item`.
    pub output: Tensor,
    /// Model time for the batch: wall-clock seconds on real backends,
    /// simulated seconds on [`super::sim_backend::SimBackend`].
    pub model_time_s: f64,
}

/// A lane-owned batch executor.
pub trait Backend {
    /// Short backend name for diagnostics ("pjrt", "sim").
    fn name(&self) -> &'static str;

    /// Execute one gathered batch `x` for `kind` at the given bucket; the
    /// first dimension of `x` is `bucket × rows_per_item`, zero-padded
    /// past the live requests.
    fn execute(&self, kind: &str, bucket: usize, x: Tensor) -> PallasResult<Execution>;
}

/// Shared descriptor + per-lane constructor for a backend.
pub trait BackendFactory: Send + Sync {
    /// What this backend can serve.
    fn catalog(&self) -> PallasResult<Catalog>;

    /// Instantiate a lane-local executor (called on the lane's thread).
    fn create(&self) -> PallasResult<Box<dyn Backend>>;

    /// Instantiate a lane-local executor for a core-aware
    /// [`LaneAssignment`] (called on the lane's thread): the backend
    /// should execute under the assignment's physical-core slice and
    /// framework knobs, serving only the assigned kinds. Backends that
    /// cannot honour core allocations (e.g. PJRT, where the OS schedules
    /// threads) fall back to [`BackendFactory::create`].
    fn create_on(&self, assignment: &LaneAssignment) -> PallasResult<Box<dyn Backend>> {
        let _ = assignment;
        self.create()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn item_shape_dims_and_elems() {
        let s = ItemShape { rows_per_item: 32, feature_dims: vec![64] };
        assert_eq!(s.dims(), vec![32, 64]);
        assert_eq!(s.elems(), 2048);
        let flat = ItemShape { rows_per_item: 1, feature_dims: vec![] };
        assert_eq!(flat.dims(), vec![1]);
        assert_eq!(flat.elems(), 1);
    }

    #[test]
    fn catalog_lookup() {
        let c = Catalog {
            models: vec![
                ModelSpec {
                    kind: "b".into(),
                    item: ItemShape { rows_per_item: 1, feature_dims: vec![4] },
                    buckets: vec![1, 2],
                },
                ModelSpec {
                    kind: "a".into(),
                    item: ItemShape { rows_per_item: 2, feature_dims: vec![8] },
                    buckets: vec![1],
                },
            ],
        };
        assert_eq!(c.kinds(), vec!["a", "b"]);
        assert_eq!(c.get("a").unwrap().item.rows_per_item, 2);
        assert!(c.get("z").is_none());
    }
}
