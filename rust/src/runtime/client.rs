//! PJRT execution: HLO-text artifacts → compiled executables → results.
//!
//! Wraps the `xla` crate (xla_extension 0.5.1). Gotchas handled here (see
//! /opt/xla-example/README.md):
//!
//! * artifacts are HLO **text**; `HloModuleProto::from_text_file` reassigns
//!   instruction ids, avoiding the 64-bit-id proto incompatibility;
//! * the exporter lowers with `return_tuple=True`, so results unwrap with
//!   `to_tuple1`;
//! * `PjRtClient`/`PjRtLoadedExecutable` are not `Sync` — the coordinator
//!   confines a `ModelRuntime` to one executor thread and feeds it work
//!   over channels.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::error::{PallasError, PallasResult};

use super::artifact::{ArtifactEntry, Manifest, Tensor};
use super::backend::{Backend, BackendFactory, Catalog, Execution};

/// A loaded set of model executables on one PJRT client.
pub struct ModelRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl ModelRuntime {
    /// Load every artifact in `dir` (compiling each HLO module).
    pub fn load(dir: &Path) -> PallasResult<Self> {
        let manifest = Manifest::load(dir)?;
        Self::load_filtered(manifest, |_| true)
    }

    /// Load only artifacts matching a predicate (e.g. one model family) —
    /// compilation is the slow part, so the coordinator loads what it
    /// serves.
    pub fn load_some(dir: &Path, pred: impl Fn(&ArtifactEntry) -> bool) -> PallasResult<Self> {
        let manifest = Manifest::load(dir)?;
        Self::load_filtered(manifest, pred)
    }

    fn load_filtered(
        manifest: Manifest,
        pred: impl Fn(&ArtifactEntry) -> bool,
    ) -> PallasResult<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| PallasError::Backend(format!("pjrt cpu client: {e:?}")))?;
        let mut executables = HashMap::new();
        for entry in manifest.artifacts.iter().filter(|e| pred(e)) {
            let path = manifest.dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| PallasError::parse("hlo", format!("{}: {e:?}", path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| PallasError::Backend(format!("compiling {}: {e:?}", entry.name)))?;
            executables.insert(entry.name.clone(), exe);
        }
        Ok(ModelRuntime { client, manifest, executables })
    }

    /// The artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Names of the loaded executables.
    pub fn loaded(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.executables.keys().map(String::as_str).collect();
        v.sort_unstable();
        v
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute a loaded artifact with the given inputs; returns the
    /// flattened f32 output of the first tuple element.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> PallasResult<Tensor> {
        let entry = self
            .manifest
            .get(name)
            .ok_or_else(|| PallasError::UnknownModel(name.to_string()))?;
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| PallasError::Backend(format!("artifact '{name}' not loaded")))?;
        if inputs.len() != entry.inputs.len() {
            return Err(PallasError::Backend(format!(
                "'{name}' expects {} inputs, got {}",
                entry.inputs.len(),
                inputs.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (t, spec) in inputs.iter().zip(entry.inputs.iter()) {
            if t.shape != spec.shape {
                return Err(PallasError::Backend(format!(
                    "'{name}' input shape {:?} != expected {:?}",
                    t.shape, spec.shape
                )));
            }
            let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(&t.data)
                .reshape(&dims)
                .map_err(|e| PallasError::Backend(format!("reshape: {e:?}")))?;
            literals.push(lit);
        }
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| PallasError::Backend(format!("execute '{name}': {e:?}")))?[0][0]
            .to_literal_sync()
            .map_err(|e| PallasError::Backend(format!("fetch result: {e:?}")))?;
        let out = result
            .to_tuple1()
            .map_err(|e| PallasError::Backend(format!("untuple: {e:?}")))?;
        let data = out
            .to_vec::<f32>()
            .map_err(|e| PallasError::Backend(format!("to_vec: {e:?}")))?;
        Ok(Tensor { shape: entry.output_shape.clone(), data })
    }

    /// Execute with a caller-supplied activation `x`; all remaining inputs
    /// (the model weights) are regenerated from the manifest's
    /// deterministic rules. This is the serving entry point: the request
    /// supplies only the data, the weights are fixed.
    pub fn execute_x(&self, name: &str, x: Tensor) -> PallasResult<Tensor> {
        let entry = self
            .manifest
            .get(name)
            .ok_or_else(|| PallasError::UnknownModel(name.to_string()))?;
        let mut inputs = Vec::with_capacity(entry.inputs.len());
        inputs.push(x);
        for spec in entry.inputs.iter().skip(1) {
            inputs.push(spec.generate());
        }
        self.execute(name, &inputs)
    }

    /// Run an artifact on its manifest-declared deterministic inputs and
    /// verify the output digest — the cross-language numerics check.
    pub fn self_check(&self, name: &str) -> PallasResult<()> {
        let entry = self
            .manifest
            .get(name)
            .ok_or_else(|| PallasError::UnknownModel(name.to_string()))?;
        let inputs: Vec<Tensor> = entry.inputs.iter().map(|s| s.generate()).collect();
        let out = self.execute(name, &inputs)?;
        entry
            .expected
            .verify(&out.data)
            .map_err(|e| PallasError::Backend(format!("digest mismatch for '{name}': {e}")))
    }
}

/// [`Backend`] over a loaded [`ModelRuntime`]: executes the AOT artifact
/// named `"{kind}_b{bucket}"` and reports wall-clock model time.
pub struct PjrtBackend {
    rt: ModelRuntime,
}

impl PjrtBackend {
    /// Wrap a loaded runtime.
    pub fn new(rt: ModelRuntime) -> Self {
        PjrtBackend { rt }
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn execute(&self, kind: &str, bucket: usize, x: &Tensor) -> PallasResult<Execution> {
        let t0 = Instant::now();
        // the PJRT entry point consumes its input; one copy here keeps
        // the coordinator's gather buffer recyclable on every backend
        let output = self.rt.execute_x(&format!("{kind}_b{bucket}"), x.clone())?;
        Ok(Execution { output, model_time_s: t0.elapsed().as_secs_f64() })
    }
}

/// Factory for PJRT lanes: each lane compiles its own executables from
/// the artifact directory (the PJRT client is `!Sync`).
pub struct PjrtBackendFactory {
    artifacts_dir: PathBuf,
    kinds: Vec<String>,
}

impl PjrtBackendFactory {
    /// Serve `kinds` from the artifacts in `dir`.
    pub fn new(dir: impl Into<PathBuf>, kinds: &[&str]) -> Self {
        PjrtBackendFactory {
            artifacts_dir: dir.into(),
            kinds: kinds.iter().map(|s| s.to_string()).collect(),
        }
    }
}

impl BackendFactory for PjrtBackendFactory {
    fn catalog(&self) -> PallasResult<Catalog> {
        let manifest = Manifest::load(&self.artifacts_dir)?;
        let kinds: Vec<&str> = self.kinds.iter().map(String::as_str).collect();
        manifest.catalog(&kinds)
    }

    fn create(&self) -> PallasResult<Box<dyn Backend>> {
        let kinds = self.kinds.clone();
        let rt = ModelRuntime::load_some(&self.artifacts_dir, |e| {
            kinds.iter().any(|k| *k == e.kind)
        })?;
        Ok(Box::new(PjrtBackend::new(rt)))
    }
}
