//! Simulation-backed serving backend.
//!
//! Executes inference batches "on" the discrete-event platform simulator:
//! per-batch latency comes from [`crate::sim::simulate`] of the model-zoo
//! graph at the batch bucket, under a [`FrameworkConfig`] chosen by the
//! paper's tuning guideline (or pinned by the caller); numerics are a
//! fixed pseudo-random row-local linear projection, so results are
//! deterministic and batching-invariant (row *i* of a batched execution
//! equals the single-item execution of row *i* — the invariant that makes
//! dynamic batching legal, testable with zero AOT artifacts).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::config::{CpuPlatform, FrameworkConfig, SchedPolicy};
use crate::error::{PallasError, PallasResult};
use crate::models;
use crate::sched::LaneAssignment;
use crate::sim::{platform_fingerprint, SimCache};
use crate::tuner;
use crate::tuner::parallel::{default_jobs, SweepPool};

use super::artifact::Tensor;
use super::backend::{Backend, BackendFactory, Catalog, Execution, ItemShape, KindId, ModelSpec};

/// Output features per served item row (the simulator's stand-in "head").
pub const SIM_OUT_FEATURES: usize = 8;

/// Configuration for a simulation backend.
#[derive(Debug, Clone)]
pub struct SimBackendConfig {
    /// Simulated hardware platform.
    pub platform: CpuPlatform,
    /// Model-zoo names to serve (each becomes a servable "kind").
    pub kinds: Vec<String>,
    /// Batch buckets to "compile" (ascending after normalisation).
    pub buckets: Vec<usize>,
    /// Framework knobs; `None` applies [`tuner::tune`] per model graph.
    pub framework: Option<FrameworkConfig>,
    /// Dispatch-policy override applied on top of the chosen knobs
    /// (pinned or per-bucket tuned) — pins *only* the policy dimension,
    /// so `serve --policy` A/Bs don't conflate it with thread knobs.
    pub policy: Option<SchedPolicy>,
    /// Sweep workers for latency-table pre-simulation (`--jobs`): the
    /// (kind, bucket) grid fans out over this many threads, cutting the
    /// serving cold-start (and `apply_plan` re-plan) latency. Results
    /// are bit-identical at any value.
    pub jobs: usize,
}

impl SimBackendConfig {
    /// Serve `kinds` on `platform` with the default bucket ladder
    /// {1, 2, 4, 8} and tuner-chosen framework knobs.
    pub fn new(platform: CpuPlatform, kinds: &[&str]) -> Self {
        SimBackendConfig {
            platform,
            kinds: kinds.iter().map(|s| s.to_string()).collect(),
            buckets: vec![1, 2, 4, 8],
            framework: None,
            policy: None,
            jobs: default_jobs(),
        }
    }

    /// The bucket ladder, ascending/deduplicated/non-zero; errors when no
    /// usable bucket remains. The single normalisation point for the sim
    /// backend (catalog and tables both go through here).
    fn normalized_buckets(&self) -> PallasResult<Vec<usize>> {
        let mut b: Vec<usize> = self.buckets.iter().copied().filter(|&b| b > 0).collect();
        b.sort_unstable();
        b.dedup();
        if b.is_empty() {
            return Err(PallasError::InvalidConfig(
                "sim backend: no batch buckets configured".into(),
            ));
        }
        Ok(b)
    }
}

/// Serving input contract for a zoo model: transformers submit one
/// sequence (32 rows × 64 features) per request, everything else one
/// feature row (1 × 64).
pub fn item_shape_for(kind: &str) -> ItemShape {
    if kind == "transformer" {
        ItemShape { rows_per_item: 32, feature_dims: vec![64] }
    } else {
        ItemShape { rows_per_item: 1, feature_dims: vec![64] }
    }
}

/// The pre-simulated latency table + shape contracts, shared across
/// lanes (the sim backend is stateless at execute time).
///
/// Latencies are held twice: a `(name, bucket)`-keyed map for the
/// name-based APIs, and a dense `[KindId][bucket-index]` grid over the
/// factory's full kind list (the coordinator's id space) for the
/// serving fast path — `None` rows are kinds this table does not host
/// (a core-aware lane serving a subset).
struct SimTables {
    latency: HashMap<(String, usize), f64>,
    shapes: HashMap<String, ItemShape>,
    /// The normalised bucket ladder the dense grid is indexed by.
    buckets: Vec<usize>,
    /// Per-id latency rows, aligned with `buckets`.
    dense: Vec<Option<Vec<f64>>>,
}

impl SimTables {
    /// For every (kind, bucket) pair, build the zoo graph at that batch
    /// size, pick the framework config (tuner guideline unless pinned),
    /// and pre-simulate the batch latency — fanned over `cfg.jobs` sweep
    /// workers through the factory's memo-cache, so identical design
    /// points across lanes/re-plans simulate once. The table contents
    /// are a pure function of the config (any `jobs`, warm or cold
    /// cache: same bits). `id_space` is the factory's full kind list —
    /// the dense grid is indexed by the coordinator's [`KindId`]s even
    /// when `cfg.kinds` is a lane's subset.
    fn build(
        cfg: &SimBackendConfig,
        cache: &Arc<SimCache>,
        id_space: &[String],
        sweep: &SweepPool,
    ) -> PallasResult<Self> {
        let buckets = cfg.normalized_buckets()?;
        let mut shapes = HashMap::new();
        let mut grid: Vec<(String, usize)> = Vec::new();
        for kind in &cfg.kinds {
            shapes.insert(kind.clone(), item_shape_for(kind));
            for &bucket in &buckets {
                grid.push((kind.clone(), bucket));
            }
        }
        let platform = Arc::new(cfg.platform.clone());
        let framework = cfg.framework.clone();
        let policy = cfg.policy;
        let cache = Arc::clone(cache);
        let rows: Vec<PallasResult<((String, usize), f64)>> =
            sweep.par_map(grid, move |_, (kind, bucket)| {
                let prep = cache
                    .prepared(&kind, bucket)
                    .ok_or_else(|| PallasError::UnknownModel(kind.clone()))?;
                let mut fw = match &framework {
                    Some(fw) => fw.clone(),
                    None => tuner::tune(prep.graph(), &platform).config,
                };
                if let Some(p) = policy {
                    fw.sched_policy = p;
                }
                let latency = cache.latency(&prep, &platform, &fw)?;
                Ok(((kind, bucket), latency))
            });
        let mut latency = HashMap::new();
        for row in rows {
            let (key, lat) = row?;
            latency.insert(key, lat);
        }
        let dense = id_space
            .iter()
            .map(|name| {
                if !shapes.contains_key(name) {
                    return None; // kind not hosted by this table
                }
                buckets
                    .iter()
                    .map(|&b| latency.get(&(name.clone(), b)).copied())
                    .collect::<Option<Vec<f64>>>()
            })
            .collect();
        Ok(SimTables { latency, shapes, buckets, dense })
    }

    /// Dense-grid lookup for the serving fast path; `None` when the id
    /// is outside this table's id space, unhosted, or the bucket is not
    /// on the ladder.
    fn dense_latency(&self, id: KindId, bucket: usize) -> Option<f64> {
        let row = self.dense.get(id.index())?.as_ref()?;
        let i = self.buckets.binary_search(&bucket).ok()?;
        Some(row[i])
    }
}

/// Cache key for one core-aware lane table: the *structural fingerprint*
/// of the lane's restricted platform (its core-slice shape — two lanes
/// at different first cores but the same shape share one table), the
/// hosted kinds, and the (possibly pinned) framework knobs.
type LaneKey = (u64, Vec<String>, Option<FrameworkConfig>);

/// Factory minting [`SimBackend`] lane instances. The whole-machine
/// latency table is simulated once on first use and shared across
/// unassigned lanes; core-aware lanes (`create_on`) get tables simulated
/// under *their allocation's* restricted platform, cached per (shape,
/// kinds, knobs) so same-shape siblings and re-plans back to a previous
/// split are free. All table construction goes through one factory-wide
/// [`SimCache`], so even distinct lane tables dedupe their overlapping
/// design points — the `Coordinator::apply_plan` cold-start cut.
pub struct SimBackendFactory {
    cfg: SimBackendConfig,
    cache: Arc<SimCache>,
    /// Persistent table-build executor: every whole-machine and lane
    /// table this factory ever builds (including each `apply_plan`
    /// re-plan) fans out over one lazily-spawned worker pool.
    sweep: Arc<SweepPool>,
    tables: Mutex<Option<Arc<SimTables>>>,
    lane_tables: Mutex<HashMap<LaneKey, Arc<SimTables>>>,
}

impl SimBackendFactory {
    /// Wrap a config (validated lazily at `catalog`/`create` time).
    pub fn new(cfg: SimBackendConfig) -> Self {
        Self::with_cache(cfg, Arc::new(SimCache::new()))
    }

    /// Wrap a config over an *injected* memo-cache, so table
    /// construction dedupes against other tiers holding the same cache
    /// (the CLI's `serve --adaptive` shares one cache between this
    /// factory and the online tuner).
    pub fn with_cache(cfg: SimBackendConfig, cache: Arc<SimCache>) -> Self {
        let sweep = Arc::new(SweepPool::new(cfg.jobs));
        SimBackendFactory {
            cfg,
            cache,
            sweep,
            tables: Mutex::new(None),
            lane_tables: Mutex::new(HashMap::new()),
        }
    }

    /// The factory-wide simulation memo-cache (hit/miss stats feed the
    /// tuner bench and the lane-sharing tests).
    pub fn cache(&self) -> &Arc<SimCache> {
        &self.cache
    }

    /// The pre-simulated latency table a lane would serve from, as
    /// `((kind, bucket), seconds)` rows sorted by kind then bucket. With
    /// an assignment this is the *same* `Arc`'d table the lane backend
    /// executes against (built on first use, cached per shape/kinds/
    /// knobs), so the facade's `tune --emit-plan` → `serve --plan`
    /// bit-identity check reads exactly what serving reads.
    pub fn latency_table(
        &self,
        assignment: Option<&LaneAssignment>,
    ) -> PallasResult<Vec<((String, usize), f64)>> {
        let tables = match assignment {
            Some(a) => self.lane_tables(a)?,
            None => self.tables()?,
        };
        let mut rows: Vec<((String, usize), f64)> =
            tables.latency.iter().map(|(k, v)| (k.clone(), *v)).collect();
        rows.sort_by(|a, b| a.0.cmp(&b.0));
        Ok(rows)
    }

    fn tables(&self) -> PallasResult<Arc<SimTables>> {
        let mut guard = self.tables.lock().unwrap();
        if let Some(t) = guard.as_ref() {
            return Ok(Arc::clone(t));
        }
        let t = Arc::new(SimTables::build(&self.cfg, &self.cache, &self.cfg.kinds, &self.sweep)?);
        *guard = Some(Arc::clone(&t));
        Ok(t)
    }

    fn lane_tables(&self, assignment: &LaneAssignment) -> PallasResult<Arc<SimTables>> {
        let kinds: Vec<String> = if assignment.kinds.is_empty() {
            self.cfg.kinds.clone()
        } else {
            self.cfg
                .kinds
                .iter()
                .filter(|k| assignment.kinds.contains(*k))
                .cloned()
                .collect()
        };
        if kinds.is_empty() {
            return Err(PallasError::InvalidPlan(format!(
                "sim backend: lane {} hosts none of the configured kinds",
                assignment.lane_id
            )));
        }
        let framework = assignment.framework.clone().or_else(|| self.cfg.framework.clone());
        let slice = self
            .cfg
            .platform
            .restrict(assignment.allocation.first_core, assignment.allocation.cores);
        let key: LaneKey = (platform_fingerprint(&slice), kinds.clone(), framework.clone());
        // hold the map lock across the build (like `tables()`): lanes
        // spawn concurrently, and without this two same-shape siblings
        // would both miss and re-simulate the whole table. The trade:
        // different-shape lanes also serialize here — accepted, since
        // each build fans out over `jobs` workers internally and plans
        // rarely exceed a handful of shapes (a per-key in-flight map
        // would restore cross-shape overlap if that changes)
        let mut guard = self.lane_tables.lock().unwrap();
        if let Some(t) = guard.get(&key) {
            return Ok(Arc::clone(t));
        }
        let sub = SimBackendConfig {
            platform: slice,
            kinds,
            buckets: self.cfg.buckets.clone(),
            framework,
            policy: self.cfg.policy,
            jobs: self.cfg.jobs,
        };
        // dense rows stay aligned with the factory's full kind list (the
        // coordinator id space), even though the lane hosts a subset
        let t = Arc::new(SimTables::build(&sub, &self.cache, &self.cfg.kinds, &self.sweep)?);
        guard.insert(key, Arc::clone(&t));
        Ok(t)
    }
}

impl BackendFactory for SimBackendFactory {
    fn catalog(&self) -> PallasResult<Catalog> {
        let buckets = self.cfg.normalized_buckets()?;
        let mut models = Vec::with_capacity(self.cfg.kinds.len());
        for kind in &self.cfg.kinds {
            if models::build(kind, 1).is_none() {
                return Err(PallasError::UnknownModel(kind.clone()));
            }
            models.push(ModelSpec {
                kind: kind.clone(),
                item: item_shape_for(kind),
                buckets: buckets.clone(),
            });
        }
        Ok(Catalog { models })
    }

    fn create(&self) -> PallasResult<Box<dyn Backend>> {
        Ok(Box::new(SimBackend { tables: self.tables()? }))
    }

    fn create_on(&self, assignment: &LaneAssignment) -> PallasResult<Box<dyn Backend>> {
        Ok(Box::new(SimBackend { tables: self.lane_tables(assignment)? }))
    }
}

/// A lane-owned simulation executor: pre-simulated per-(kind, bucket)
/// latencies plus the deterministic projection "numerics".
pub struct SimBackend {
    tables: Arc<SimTables>,
}

impl SimBackend {
    /// Build a standalone backend (lanes created through
    /// [`SimBackendFactory`] share one table instead).
    pub fn new(cfg: SimBackendConfig) -> PallasResult<Self> {
        let cache = Arc::new(SimCache::new());
        let id_space = cfg.kinds.clone();
        let sweep = SweepPool::new(cfg.jobs);
        Ok(SimBackend { tables: Arc::new(SimTables::build(&cfg, &cache, &id_space, &sweep)?) })
    }

    /// Pre-simulated latency for a (kind, bucket) pair, if configured.
    pub fn simulated_latency(&self, kind: &str, bucket: usize) -> Option<f64> {
        self.tables.latency.get(&(kind.to_string(), bucket)).copied()
    }

    /// The deterministic projection "numerics" shared by the name and
    /// interned-id execute paths.
    fn project(&self, kind: &str, model_time_s: f64, x: &Tensor) -> PallasResult<Execution> {
        if x.shape.is_empty() {
            return Err(PallasError::Backend(format!("sim backend: scalar input for '{kind}'")));
        }
        let rows = x.shape[0];
        let feat: usize = x.shape[1..].iter().product();
        if feat == 0 || x.data.len() != rows * feat {
            return Err(PallasError::Backend(format!(
                "sim backend: input shape {:?} inconsistent with {} elements",
                x.shape,
                x.data.len()
            )));
        }
        let scale = 1.0 / (feat as f32).sqrt();
        let mut out = Vec::with_capacity(rows * SIM_OUT_FEATURES);
        for r in 0..rows {
            let row = &x.data[r * feat..(r + 1) * feat];
            for j in 0..SIM_OUT_FEATURES {
                let mut acc = 0.0f32;
                for (i, &v) in row.iter().enumerate() {
                    acc += v * weight(i, j);
                }
                out.push(acc * scale);
            }
        }
        Ok(Execution {
            output: Tensor { shape: vec![rows, SIM_OUT_FEATURES], data: out },
            model_time_s,
        })
    }
}

/// The fixed projection weight for input feature `i` → output feature `j`.
/// Row-local and batch-independent by construction.
fn weight(i: usize, j: usize) -> f32 {
    ((i as f32) * 0.37 + (j as f32) * 1.13 + 0.5).sin()
}

impl Backend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn execute(&self, kind: &str, bucket: usize, x: &Tensor) -> PallasResult<Execution> {
        if !self.tables.shapes.contains_key(kind) {
            return Err(PallasError::Backend(format!("sim backend: kind '{kind}' not served")));
        }
        let model_time_s = self.simulated_latency(kind, bucket).ok_or_else(|| {
            PallasError::Backend(format!("sim backend: no bucket {bucket} for '{kind}'"))
        })?;
        self.project(kind, model_time_s, x)
    }

    fn execute_id(
        &self,
        id: KindId,
        kind: &str,
        bucket: usize,
        x: &Tensor,
    ) -> PallasResult<Execution> {
        // dense hit: no name hashing, no key allocation. Misses (foreign
        // id space, unhosted kind, off-ladder bucket) fall back to the
        // name path, which owns the error wording.
        match self.tables.dense_latency(id, bucket) {
            Some(model_time_s) => self.project(kind, model_time_s, x),
            None => self.execute(kind, bucket, x),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::gen_input;
    use crate::sched::CoreAllocation;

    fn backend(kinds: &[&str]) -> SimBackend {
        SimBackend::new(SimBackendConfig::new(CpuPlatform::large(), kinds)).unwrap()
    }

    #[test]
    fn unknown_model_rejected() {
        let cfg = SimBackendConfig::new(CpuPlatform::large(), &["bert"]);
        assert!(SimBackend::new(cfg.clone()).is_err());
        assert!(SimBackendFactory::new(cfg).catalog().is_err());
    }

    #[test]
    fn latency_grows_with_bucket() {
        let b = backend(&["wide_deep"]);
        let l1 = b.simulated_latency("wide_deep", 1).unwrap();
        let l8 = b.simulated_latency("wide_deep", 8).unwrap();
        assert!(l1 > 0.0 && l1.is_finite());
        assert!(l8 > l1, "l1={l1} l8={l8}");
    }

    #[test]
    fn execute_is_deterministic() {
        let b = backend(&["wide_deep"]);
        let x = gen_input(3, &[2, 64], 1.0);
        let a = b.execute("wide_deep", 2, &x).unwrap();
        let c = b.execute("wide_deep", 2, &x).unwrap();
        assert_eq!(a.output, c.output);
        assert_eq!(a.model_time_s, c.model_time_s);
        assert_eq!(a.output.shape, vec![2, SIM_OUT_FEATURES]);
        assert!(a.output.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn batched_rows_equal_unbatched_rows() {
        // the invariant that legalises dynamic batching
        let b = backend(&["wide_deep"]);
        let full = gen_input(9, &[4, 64], 1.0);
        let batched = b.execute("wide_deep", 4, &full).unwrap().output;
        for r in 0..4 {
            let row = Tensor {
                shape: vec![1, 64],
                data: full.data[r * 64..(r + 1) * 64].to_vec(),
            };
            let solo = b.execute("wide_deep", 1, &row).unwrap().output;
            for j in 0..SIM_OUT_FEATURES {
                assert_eq!(batched.data[r * SIM_OUT_FEATURES + j], solo.data[j], "r={r} j={j}");
            }
        }
    }

    #[test]
    fn padding_rows_do_not_disturb_live_rows() {
        let b = backend(&["wide_deep"]);
        let one = gen_input(5, &[1, 64], 1.0);
        let mut padded = one.data.clone();
        padded.resize(4 * 64, 0.0);
        let solo = b.execute("wide_deep", 1, &one).unwrap().output;
        let batched = b
            .execute("wide_deep", 4, &Tensor { shape: vec![4, 64], data: padded })
            .unwrap()
            .output;
        assert_eq!(&batched.data[..SIM_OUT_FEATURES], &solo.data[..]);
    }

    #[test]
    fn execute_rejects_bad_inputs() {
        let b = backend(&["wide_deep"]);
        let x = gen_input(1, &[1, 64], 1.0);
        assert!(b.execute("resnet50", 1, &x).is_err()); // kind not served
        assert!(b.execute("wide_deep", 3, &x).is_err()); // bucket not compiled
        let bad = Tensor { shape: vec![2, 64], data: vec![0.0; 64] };
        assert!(b.execute("wide_deep", 2, &bad).is_err()); // length mismatch
    }

    #[test]
    fn factory_catalog_matches_config() {
        let f = SimBackendFactory::new(SimBackendConfig::new(
            CpuPlatform::large(),
            &["wide_deep", "transformer"],
        ));
        let c = f.catalog().unwrap();
        assert_eq!(c.kinds(), vec!["transformer", "wide_deep"]);
        assert_eq!(c.get("transformer").unwrap().item.rows_per_item, 32);
        assert_eq!(c.get("wide_deep").unwrap().item.rows_per_item, 1);
        assert_eq!(c.get("wide_deep").unwrap().buckets, vec![1, 2, 4, 8]);
    }

    fn assignment(first_core: usize, cores: usize, kinds: &[&str]) -> LaneAssignment {
        LaneAssignment {
            lane_id: 0,
            allocation: CoreAllocation::new(first_core, cores),
            kinds: kinds.iter().map(|s| s.to_string()).collect(),
            framework: None,
        }
    }

    #[test]
    fn lane_allocation_slows_simulated_latency() {
        // a lane pinned to 4 of the 24 cores must see higher batch
        // latency than a lane owning the whole box — the double-counting
        // fix the core-aware scheduler exists for
        let f = SimBackendFactory::new(SimBackendConfig::new(CpuPlatform::large(), &["resnet50"]));
        let whole = f.create().unwrap();
        let slice = f.create_on(&assignment(0, 4, &["resnet50"])).unwrap();
        let x = gen_input(1, &[4, 64], 1.0);
        let t_whole = whole.execute("resnet50", 4, &x).unwrap().model_time_s;
        let t_slice = slice.execute("resnet50", 4, &x).unwrap().model_time_s;
        assert!(t_slice > t_whole, "slice={t_slice} whole={t_whole}");
    }

    #[test]
    fn lane_tables_cached_per_assignment() {
        let f = SimBackendFactory::new(SimBackendConfig::new(
            CpuPlatform::large(),
            &["wide_deep", "resnet50"],
        ));
        let a = assignment(0, 8, &["wide_deep"]);
        let b1 = f.create_on(&a).unwrap();
        let b2 = f.create_on(&a).unwrap();
        let x = gen_input(2, &[2, 64], 1.0);
        assert_eq!(
            b1.execute("wide_deep", 2, &x).unwrap().model_time_s,
            b2.execute("wide_deep", 2, &x).unwrap().model_time_s,
        );
        // the lane only hosts its assigned kinds
        assert!(b1.execute("resnet50", 2, &x).is_err());
    }

    #[test]
    fn same_shape_lanes_share_tables_and_simulations() {
        let f = SimBackendFactory::new(SimBackendConfig::new(CpuPlatform::large(), &["wide_deep"]));
        let a = f.create_on(&assignment(0, 8, &["wide_deep"])).unwrap();
        let misses = f.cache().misses();
        assert!(misses > 0);
        // a second lane with the same slice *shape* at a different first
        // core reuses the whole table: zero new simulations
        let b = f.create_on(&assignment(8, 8, &["wide_deep"])).unwrap();
        assert_eq!(f.cache().misses(), misses);
        let x = gen_input(2, &[2, 64], 1.0);
        assert_eq!(
            a.execute("wide_deep", 2, &x).unwrap().model_time_s,
            b.execute("wide_deep", 2, &x).unwrap().model_time_s,
        );
        // a different shape must rebuild (and re-simulate what it needs)
        let _ = f.create_on(&assignment(16, 4, &["wide_deep"])).unwrap();
        assert!(f.cache().misses() > misses);
    }

    #[test]
    fn tables_bit_identical_at_any_job_count() {
        let mut latencies: Vec<Vec<u64>> = Vec::new();
        for jobs in [1usize, 4] {
            let mut cfg = SimBackendConfig::new(CpuPlatform::large(), &["wide_deep", "resnet50"]);
            cfg.jobs = jobs;
            let b = SimBackend::new(cfg).unwrap();
            latencies.push(
                ["wide_deep", "resnet50"]
                    .iter()
                    .flat_map(|k| {
                        [1usize, 2, 4, 8]
                            .iter()
                            .map(|&bk| b.simulated_latency(k, bk).unwrap().to_bits())
                            .collect::<Vec<_>>()
                    })
                    .collect(),
            );
        }
        assert_eq!(latencies[0], latencies[1]);
    }

    #[test]
    fn lane_hosting_no_configured_kind_rejected() {
        let f = SimBackendFactory::new(SimBackendConfig::new(CpuPlatform::large(), &["wide_deep"]));
        assert!(f.create_on(&assignment(0, 4, &["resnet50"])).is_err());
        // empty kinds list means "host everything configured"
        assert!(f.create_on(&assignment(0, 4, &[])).is_ok());
    }

    #[test]
    fn policy_override_keeps_per_bucket_tuning() {
        // the override pins only the dispatch policy: thread knobs are
        // still tuned per bucket, so a topo-pinned transformer backend
        // differs from the width-rule default (critical-path) on some
        // bucket while a redundant critical-path pin changes nothing
        let base = SimBackend::new(SimBackendConfig::new(CpuPlatform::large2(), &["transformer"]))
            .unwrap();
        let mut cfg = SimBackendConfig::new(CpuPlatform::large2(), &["transformer"]);
        cfg.policy = Some(SchedPolicy::CriticalPathFirst);
        let pinned_cp = SimBackend::new(cfg.clone()).unwrap();
        cfg.policy = Some(SchedPolicy::Topo);
        let pinned_topo = SimBackend::new(cfg).unwrap();
        let mut topo_differs = false;
        for bucket in [1usize, 2, 4, 8] {
            let d = base.simulated_latency("transformer", bucket).unwrap();
            // transformer is wide at every bucket: the width rule already
            // picks critical-path, so that pin must be a no-op
            assert_eq!(d, pinned_cp.simulated_latency("transformer", bucket).unwrap());
            topo_differs |= d != pinned_topo.simulated_latency("transformer", bucket).unwrap();
        }
        assert!(topo_differs, "topo pin changed no bucket");
    }

    #[test]
    fn pinned_framework_config_is_used() {
        // pinning a deliberately bad config must change simulated latency
        let mut cfg = SimBackendConfig::new(CpuPlatform::large(), &["resnet50"]);
        let tuned = SimBackend::new(cfg.clone()).unwrap();
        cfg.framework = Some(FrameworkConfig::tuned_default()); // 1 pool × 1 thread
        let slow = SimBackend::new(cfg).unwrap();
        let a = tuned.simulated_latency("resnet50", 4).unwrap();
        let b = slow.simulated_latency("resnet50", 4).unwrap();
        assert!(b > a, "tuned={a} pinned-serial={b}");
    }
}
