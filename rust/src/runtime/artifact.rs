//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime.
//!
//! `make artifacts` writes `artifacts/manifest.json` describing every
//! exported HLO module: input shapes, the deterministic input-generation
//! rule, and an expected-output digest the integration tests verify
//! numerics against (cross-language, within float32 tolerance).

use std::path::{Path, PathBuf};

use crate::error::{PallasError, PallasResult};
use crate::util::json::Json;

use super::backend::{Catalog, ItemShape, ModelSpec};

/// A dense f32 tensor (host side).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    /// Dimension sizes.
    pub shape: Vec<usize>,
    /// Row-major data; `len == shape.product()`.
    pub data: Vec<f32>,
}

impl Tensor {
    /// Element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// Deterministic input: `x[i] = sin(i*0.9898 + tag*78.233) * scale`,
/// computed in f32 exactly like `compile.aot.gen_input`.
pub fn gen_input(tag: u32, shape: &[usize], scale: f32) -> Tensor {
    let n: usize = shape.iter().product();
    let data = (0..n)
        .map(|i| (i as f32 * 0.9898f32 + tag as f32 * 78.233f32).sin() * scale)
        .collect();
    Tensor { shape: shape.to_vec(), data }
}

/// How an input tensor is generated (mirrors `compile.aot.materialize`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum GenRule {
    /// Deterministic sin rule with (tag, scale).
    Det { tag: u32, scale: f32 },
    /// Constant fill (layer-norm gammas/betas).
    Fill(f32),
}

/// Input descriptor in the manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct InputSpec {
    /// Tensor shape.
    pub shape: Vec<usize>,
    /// Generation rule.
    pub rule: GenRule,
}

impl InputSpec {
    /// Materialise the deterministic input tensor.
    pub fn generate(&self) -> Tensor {
        match self.rule {
            GenRule::Det { tag, scale } => gen_input(tag, &self.shape, scale),
            GenRule::Fill(v) => Tensor {
                shape: self.shape.clone(),
                data: vec![v; self.shape.iter().product()],
            },
        }
    }
}

/// Expected-output digest (computed by the exporter).
#[derive(Debug, Clone, PartialEq)]
pub struct Digest {
    /// First elements of the flattened output.
    pub prefix: Vec<f64>,
    /// Sum over all elements.
    pub sum: f64,
    /// Sum of absolute values.
    pub abs_sum: f64,
    /// Element count.
    pub count: usize,
}

impl Digest {
    /// Verify a flattened output against this digest (f32-tolerant).
    pub fn verify(&self, out: &[f32]) -> PallasResult<()> {
        let fail = |m: String| Err(PallasError::Backend(m));
        if out.len() != self.count {
            return fail(format!("output count {} != expected {}", out.len(), self.count));
        }
        let tol = |expected: f64| 1e-3 * expected.abs().max(1.0);
        for (i, (&got, want)) in out.iter().zip(self.prefix.iter()).enumerate() {
            if (got as f64 - want).abs() > tol(*want).max(2e-3) {
                return fail(format!("prefix[{i}]: got {got} want {want}"));
            }
        }
        let sum: f64 = out.iter().map(|&v| v as f64).sum();
        // sums accumulate rounding over `count` elements
        let sum_tol = self.abs_sum * 1e-5 + 1e-3;
        if (sum - self.sum).abs() > sum_tol {
            return fail(format!("sum: got {sum} want {} (tol {sum_tol})", self.sum));
        }
        Ok(())
    }
}

/// One exported model/bucket.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactEntry {
    /// Artifact name ("mlp_b4").
    pub name: String,
    /// HLO text file name within the artifacts dir.
    pub file: String,
    /// Model family ("mlp", "transformer", "matmul").
    pub kind: String,
    /// Batch bucket (rows for mlp, sequences for transformer; 0 for
    /// micro-benchmarks).
    pub batch: usize,
    /// Input descriptors.
    pub inputs: Vec<InputSpec>,
    /// Output shape.
    pub output_shape: Vec<usize>,
    /// Expected-output digest.
    pub expected: Digest,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory the artifacts live in.
    pub dir: PathBuf,
    /// All exported artifacts.
    pub artifacts: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> PallasResult<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| PallasError::io(path.display(), e))?;
        Self::parse(dir, &text)
    }

    /// Parse manifest text.
    pub fn parse(dir: &Path, text: &str) -> PallasResult<Self> {
        let doc = Json::parse(text).map_err(|e| PallasError::parse("manifest", e))?;
        let version = doc.get("version").and_then(Json::as_usize).unwrap_or(0);
        if version != 1 {
            return Err(PallasError::parse(
                "manifest",
                format!("unsupported manifest version {version}"),
            ));
        }
        let arts = doc
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| PallasError::parse("manifest", "missing artifacts"))?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for a in arts {
            artifacts.push(parse_entry(a)?);
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    /// Find an artifact by name.
    pub fn get(&self, name: &str) -> Option<&ArtifactEntry> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// All batch buckets for a model kind, ascending.
    pub fn buckets(&self, kind: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == kind)
            .map(|a| a.batch)
            .collect();
        v.sort_unstable();
        v
    }

    /// Smallest bucket that fits `n` requests (or the largest bucket).
    pub fn bucket_for(&self, kind: &str, n: usize) -> Option<usize> {
        let buckets = self.buckets(kind);
        buckets.iter().copied().find(|&b| b >= n).or(buckets.last().copied())
    }

    /// Artifact for a (kind, bucket) pair.
    pub fn artifact_for(&self, kind: &str, bucket: usize) -> Option<&ArtifactEntry> {
        self.artifacts.iter().find(|a| a.kind == kind && a.batch == bucket)
    }

    /// Derive the serving [`Catalog`] for a set of families: the bucket-1
    /// (or smallest-bucket) artifact of each family defines the per-item
    /// shape, and the compiled batch sizes become the bucket ladder.
    pub fn catalog(&self, kinds: &[&str]) -> PallasResult<Catalog> {
        let mut models = Vec::with_capacity(kinds.len());
        for kind in kinds {
            let buckets = self.buckets(kind);
            let entry = self
                .artifact_for(kind, 1)
                .or_else(|| buckets.first().and_then(|&b| self.artifact_for(kind, b)))
                .ok_or_else(|| PallasError::UnknownModel(kind.to_string()))?;
            let batch = entry.batch.max(1);
            let full = &entry.inputs[0].shape;
            if full.is_empty() || full[0] % batch != 0 {
                return Err(PallasError::parse(
                    "manifest",
                    format!("kind '{kind}': first dim {full:?} not divisible by batch {batch}"),
                ));
            }
            models.push(ModelSpec {
                kind: kind.to_string(),
                item: ItemShape {
                    rows_per_item: full[0] / batch,
                    feature_dims: full[1..].to_vec(),
                },
                buckets,
            });
        }
        Ok(Catalog { models })
    }
}

fn parse_entry(a: &Json) -> PallasResult<ArtifactEntry> {
    let str_field = |k: &str| -> PallasResult<String> {
        Ok(a.get(k)
            .and_then(Json::as_str)
            .ok_or_else(|| PallasError::parse("manifest", format!("artifact missing {k}")))?
            .to_string())
    };
    let shape_of = |v: &Json| -> Vec<usize> {
        v.as_arr()
            .map(|arr| arr.iter().filter_map(Json::as_usize).collect())
            .unwrap_or_default()
    };
    let inputs = a
        .get("inputs")
        .and_then(Json::as_arr)
        .ok_or_else(|| PallasError::parse("manifest", "artifact missing inputs"))?
        .iter()
        .map(|i| -> PallasResult<InputSpec> {
            let rule = if let Some(fill) = i.get("fill").and_then(Json::as_f64) {
                GenRule::Fill(fill as f32)
            } else {
                GenRule::Det {
                    tag: i.get("tag").and_then(Json::as_usize).unwrap_or(0) as u32,
                    scale: i.get("scale").and_then(Json::as_f64).unwrap_or(1.0) as f32,
                }
            };
            Ok(InputSpec {
                shape: shape_of(
                    i.get("shape")
                        .ok_or_else(|| PallasError::parse("manifest", "input missing shape"))?,
                ),
                rule,
            })
        })
        .collect::<PallasResult<Vec<_>>>()?;
    let exp = a
        .get("expected")
        .ok_or_else(|| PallasError::parse("manifest", "artifact missing expected"))?;
    let expected = Digest {
        prefix: exp
            .get("prefix")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .filter_map(Json::as_f64)
            .collect(),
        sum: exp.get("sum").and_then(Json::as_f64).unwrap_or(0.0),
        abs_sum: exp.get("abs_sum").and_then(Json::as_f64).unwrap_or(0.0),
        count: exp.get("count").and_then(Json::as_usize).unwrap_or(0),
    };
    Ok(ArtifactEntry {
        name: str_field("name")?,
        file: str_field("file")?,
        kind: str_field("kind")?,
        batch: a.get("batch").and_then(Json::as_usize).unwrap_or(0),
        inputs,
        output_shape: a.get("output_shape").map(shape_of).unwrap_or_default(),
        expected,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {"name": "mlp_b2", "file": "mlp_b2.hlo.txt", "kind": "mlp", "batch": 2,
         "inputs": [{"shape": [2, 256], "tag": 7, "scale": 1.0}],
         "output_shape": [2, 8],
         "expected": {"prefix": [0.5, -0.25], "sum": 1.0, "abs_sum": 4.0, "count": 16}},
        {"name": "mlp_b4", "file": "mlp_b4.hlo.txt", "kind": "mlp", "batch": 4,
         "inputs": [{"shape": [4, 256], "tag": 7, "scale": 1.0}],
         "output_shape": [4, 8],
         "expected": {"prefix": [], "sum": 0.0, "abs_sum": 0.0, "count": 32}}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let a = m.get("mlp_b2").unwrap();
        assert_eq!(a.inputs[0].shape, vec![2, 256]);
        assert_eq!(a.expected.count, 16);
    }

    #[test]
    fn catalog_derives_item_shapes() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        let c = m.catalog(&["mlp"]).unwrap();
        let spec = c.get("mlp").unwrap();
        assert_eq!(spec.item.rows_per_item, 1); // [2,256] at batch 2
        assert_eq!(spec.item.feature_dims, vec![256]);
        assert_eq!(spec.buckets, vec![2, 4]);
        assert!(m.catalog(&["resnet"]).is_err());
    }

    #[test]
    fn bucket_selection() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        assert_eq!(m.buckets("mlp"), vec![2, 4]);
        assert_eq!(m.bucket_for("mlp", 1), Some(2));
        assert_eq!(m.bucket_for("mlp", 3), Some(4));
        assert_eq!(m.bucket_for("mlp", 9), Some(4)); // clamp to largest
        assert_eq!(m.bucket_for("resnet", 1), None);
    }

    #[test]
    fn gen_input_matches_python_pipeline() {
        // values from compile.aot.gen_input(7, (3,), 2.0)
        let t = gen_input(7, &[3], 2.0);
        let want = [1.676_275f32, 1.831_945_7, 0.334_655_7];
        for (g, w) in t.data.iter().zip(want.iter()) {
            assert!((g - w).abs() < 2e-5, "{g} vs {w}");
        }
    }

    #[test]
    fn digest_verify_catches_mismatch() {
        let d = Digest { prefix: vec![1.0, 2.0], sum: 3.0, abs_sum: 3.0, count: 2 };
        assert!(d.verify(&[1.0, 2.0]).is_ok());
        assert!(d.verify(&[1.0, 2.5]).is_err());
        assert!(d.verify(&[1.0]).is_err());
    }

    #[test]
    fn rejects_wrong_version() {
        assert!(Manifest::parse(Path::new("/tmp"), r#"{"version": 2, "artifacts": []}"#).is_err());
    }
}
