//! Queries over a decoded trace: per-kind latency breakdowns, batch
//! occupancy histograms, slowest-request ranking, replay-plan
//! extraction, and conversion to the per-lane timelines the existing
//! [`crate::trace`] emitters render.
//!
//! Each query reads only the columns it needs conceptually; the numbers
//! here are exactly the stored column values (the breakdown columns
//! `batching_ns` / `lane_wait_ns` / `service_ns` are the deltas the
//! codec wrote, so no reconstruction error can creep in).

use std::collections::BTreeMap;

use crate::sim::{Category, Segment};
use crate::util::stats;

use super::event::TraceEvent;
use super::format::TraceData;

/// Per-kind latency breakdown (milliseconds).
#[derive(Debug, Clone, PartialEq)]
pub struct KindBreakdown {
    /// Interned kind id.
    pub kind: u16,
    /// Kind name from the trace's footer table.
    pub name: String,
    /// Requests of this kind in the trace.
    pub count: usize,
    /// p50 / p99 time waiting in the dynamic batcher.
    pub p50_batching_ms: f64,
    /// 99th percentile of the batching wait.
    pub p99_batching_ms: f64,
    /// p50 time queued on the executing lane.
    pub p50_lane_wait_ms: f64,
    /// 99th percentile of the lane wait.
    pub p99_lane_wait_ms: f64,
    /// p50 backend execution time.
    pub p50_service_ms: f64,
    /// 99th percentile of backend execution time.
    pub p99_service_ms: f64,
    /// p50 end-to-end latency.
    pub p50_total_ms: f64,
    /// 99th percentile end-to-end latency.
    pub p99_total_ms: f64,
    /// Most frequent compiled bucket (smallest on ties).
    pub mode_bucket: u32,
}

/// Whole-trace summary: wall-clock span, batch shape, per-kind breakdowns.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceSummary {
    /// Total events (requests) in the trace.
    pub events: usize,
    /// First arrival → last completion, in seconds.
    pub duration_s: f64,
    /// Distinct batches executed.
    pub batches: usize,
    /// Mean requests per batch.
    pub mean_occupancy: f64,
    /// Distinct lanes that executed work.
    pub lanes: usize,
    /// Per-kind breakdowns, ascending kind id (kinds with no events omitted).
    pub kinds: Vec<KindBreakdown>,
}

/// A recorded arrival process, ready to re-issue: kind table plus
/// `(offset_s, kind_id)` pairs relative to the first arrival.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplayPlan {
    /// Interned id→name kind table (from the trace footer).
    pub kinds: Vec<String>,
    /// Arrival offsets in seconds since the first arrival, with the
    /// interned kind of each request, in arrival order.
    pub arrivals: Vec<(f64, u16)>,
    /// Seed for the replay's deterministic tag stream.
    pub seed: u64,
}

impl ReplayPlan {
    /// The kind name for an interned id.
    pub fn kind_name(&self, id: u16) -> &str {
        self.kinds.get(id as usize).map(String::as_str).unwrap_or("?")
    }
}

fn ms(ns: u64) -> f64 {
    ns as f64 / 1e6
}

impl TraceData {
    /// Requests per kind id, ascending id, zero-count kinds omitted.
    pub fn per_kind_counts(&self) -> Vec<(u16, usize)> {
        let mut counts: BTreeMap<u16, usize> = BTreeMap::new();
        for e in &self.events {
            *counts.entry(e.kind).or_insert(0) += 1;
        }
        counts.into_iter().collect()
    }

    /// The most frequent compiled bucket for `kind` (smallest on ties);
    /// `None` when the trace has no events of that kind.
    pub fn mode_bucket(&self, kind: u16) -> Option<u32> {
        let mut by_bucket: BTreeMap<u32, usize> = BTreeMap::new();
        for e in self.events.iter().filter(|e| e.kind == kind) {
            *by_bucket.entry(e.bucket).or_insert(0) += 1;
        }
        let mut best: Option<(u32, usize)> = None;
        for (bucket, n) in by_bucket {
            // ascending iteration: strictly-greater keeps the smallest
            // bucket on ties
            if best.is_none_or(|(_, bn)| n > bn) {
                best = Some((bucket, n));
            }
        }
        best.map(|(bucket, _)| bucket)
    }

    /// Distinct batches as `(batch_id, lane, occupancy, bucket)`,
    /// ascending batch id.
    pub fn batch_rows(&self) -> Vec<(u64, u16, u16, u32)> {
        let mut rows: BTreeMap<u64, (u16, u16, u32)> = BTreeMap::new();
        for e in &self.events {
            rows.entry(e.batch_id).or_insert((e.lane, e.occupancy, e.bucket));
        }
        rows.into_iter().map(|(id, (lane, occ, bucket))| (id, lane, occ, bucket)).collect()
    }

    /// Batch-occupancy histogram: `(occupancy, batches)` ascending.
    pub fn occupancy_histogram(&self) -> Vec<(u16, usize)> {
        let mut hist: BTreeMap<u16, usize> = BTreeMap::new();
        for (_, _, occ, _) in self.batch_rows() {
            *hist.entry(occ).or_insert(0) += 1;
        }
        hist.into_iter().collect()
    }

    /// The `n` slowest requests by end-to-end latency, slowest first
    /// (ties broken by request id for a stable order).
    pub fn slowest(&self, n: usize) -> Vec<TraceEvent> {
        let mut v = self.events.clone();
        v.sort_by_key(|e| (std::cmp::Reverse(e.total_ns()), e.request_id));
        v.truncate(n);
        v
    }

    /// Whole-trace summary with per-kind p50/p99 breakdowns.
    pub fn summary(&self) -> TraceSummary {
        let batch_rows = self.batch_rows();
        let mut lanes: Vec<u16> = batch_rows.iter().map(|&(_, lane, _, _)| lane).collect();
        lanes.sort_unstable();
        lanes.dedup();
        let mean_occupancy = if batch_rows.is_empty() {
            0.0
        } else {
            batch_rows.iter().map(|&(_, _, occ, _)| occ as f64).sum::<f64>()
                / batch_rows.len() as f64
        };
        let start = self.events.iter().map(|e| e.arrival_ns).min().unwrap_or(0);
        let end = self.events.iter().map(|e| e.complete_ns).max().unwrap_or(start);
        let mut kinds = Vec::new();
        for (kind, count) in self.per_kind_counts() {
            let of = |f: fn(&TraceEvent) -> u64| -> Vec<f64> {
                self.events
                    .iter()
                    .filter(|e| e.kind == kind)
                    .map(|e| ms(f(e)))
                    .collect()
            };
            let batching = of(TraceEvent::batching_ns);
            let lane_wait = of(TraceEvent::lane_wait_ns);
            let service = of(TraceEvent::service_ns);
            let total = of(TraceEvent::total_ns);
            kinds.push(KindBreakdown {
                kind,
                name: self.kind_name(kind),
                count,
                p50_batching_ms: stats::median(&batching),
                p99_batching_ms: stats::percentile(&batching, 99.0),
                p50_lane_wait_ms: stats::median(&lane_wait),
                p99_lane_wait_ms: stats::percentile(&lane_wait, 99.0),
                p50_service_ms: stats::median(&service),
                p99_service_ms: stats::percentile(&service, 99.0),
                p50_total_ms: stats::median(&total),
                p99_total_ms: stats::percentile(&total, 99.0),
                mode_bucket: self.mode_bucket(kind).unwrap_or(0),
            });
        }
        TraceSummary {
            events: self.events.len(),
            duration_s: end.saturating_sub(start) as f64 / 1e9,
            batches: batch_rows.len(),
            mean_occupancy,
            lanes: lanes.len(),
            kinds,
        }
    }

    /// Extract the recorded arrival process for replay: offsets in
    /// seconds since the first arrival, in arrival order.
    pub fn replay_plan(&self, seed: u64) -> ReplayPlan {
        let start = self.events.iter().map(|e| e.arrival_ns).min().unwrap_or(0);
        ReplayPlan {
            kinds: self.kinds.clone(),
            arrivals: self
                .events
                .iter()
                .map(|e| ((e.arrival_ns - start) as f64 / 1e9, e.kind))
                .collect(),
            seed,
        }
    }

    /// Convert the trace to per-lane timelines for the existing
    /// [`crate::trace::ascii_trace`] / [`crate::trace::chrome_trace`]
    /// emitters: one compute segment per batch (dispatch → complete,
    /// `op` = batch id), times in seconds relative to the first arrival.
    /// Returns `(timelines, span_s)`.
    pub fn lane_timelines(&self) -> (Vec<Vec<Segment>>, f64) {
        let start = self.events.iter().map(|e| e.arrival_ns).min().unwrap_or(0);
        let end = self.events.iter().map(|e| e.complete_ns).max().unwrap_or(start);
        // batch id → (lane, dispatch, complete); every request in a batch
        // carries the same triple, first one wins
        let mut batches: BTreeMap<u64, (u16, u64, u64)> = BTreeMap::new();
        for e in &self.events {
            batches.entry(e.batch_id).or_insert((e.lane, e.dispatch_ns, e.complete_ns));
        }
        let n_lanes = batches.values().map(|&(lane, _, _)| lane as usize + 1).max().unwrap_or(0);
        let mut timelines = vec![Vec::new(); n_lanes];
        for (batch_id, (lane, dispatch, complete)) in batches {
            timelines[lane as usize].push(Segment {
                t0: dispatch.saturating_sub(start) as f64 / 1e9,
                t1: complete.saturating_sub(start) as f64 / 1e9,
                cat: Category::MklCompute,
                op: batch_id as usize,
            });
        }
        for tl in &mut timelines {
            tl.sort_by(|a, b| a.t0.partial_cmp(&b.t0).unwrap());
        }
        (timelines, end.saturating_sub(start) as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(id: u64, kind: u16, lane: u16, batch: u64, occ: u16, bucket: u32, t: u64) -> TraceEvent {
        TraceEvent {
            request_id: id,
            kind,
            lane,
            batch_id: batch,
            occupancy: occ,
            bucket,
            arrival_ns: t,
            cut_ns: t + 1_000_000,
            dispatch_ns: t + 2_000_000,
            complete_ns: t + 10_000_000,
        }
    }

    fn sample() -> TraceData {
        TraceData::new(
            vec!["mlp".into(), "cnn".into()],
            vec![
                ev(0, 0, 0, 0, 2, 4, 0),
                ev(1, 0, 0, 0, 2, 4, 500_000),
                ev(2, 1, 1, 1, 1, 1, 1_000_000),
                ev(3, 0, 0, 2, 1, 8, 2_000_000),
            ],
        )
    }

    #[test]
    fn summary_counts_batches_and_kinds() {
        let s = sample().summary();
        assert_eq!(s.events, 4);
        assert_eq!(s.batches, 3);
        assert_eq!(s.lanes, 2);
        assert!((s.mean_occupancy - (2.0 + 1.0 + 1.0) / 3.0).abs() < 1e-12);
        assert_eq!(s.kinds.len(), 2);
        assert_eq!(s.kinds[0].name, "mlp");
        assert_eq!(s.kinds[0].count, 3);
        assert_eq!(s.kinds[0].mode_bucket, 4); // 4 twice, 8 once
        assert_eq!(s.kinds[1].count, 1);
        // every event has the same 8ms service time
        assert!((s.kinds[0].p50_service_ms - 8.0).abs() < 1e-9);
        assert!((s.duration_s - 0.012).abs() < 1e-9);
    }

    #[test]
    fn mode_bucket_breaks_ties_downward() {
        let t = TraceData::new(
            vec!["k".into()],
            vec![ev(0, 0, 0, 0, 1, 8, 0), ev(1, 0, 0, 1, 1, 2, 10)],
        );
        assert_eq!(t.mode_bucket(0), Some(2));
        assert_eq!(t.mode_bucket(9), None);
    }

    #[test]
    fn occupancy_histogram_is_per_batch() {
        let hist = sample().occupancy_histogram();
        assert_eq!(hist, vec![(1, 2), (2, 1)]);
    }

    #[test]
    fn slowest_ranks_by_total_latency() {
        let mut t = sample();
        t.events[2].complete_ns = t.events[2].arrival_ns + 50_000_000;
        let top = t.slowest(2);
        assert_eq!(top.len(), 2);
        assert_eq!(top[0].request_id, 2);
    }

    #[test]
    fn replay_plan_preserves_arrival_sequence() {
        let plan = sample().replay_plan(7);
        assert_eq!(plan.seed, 7);
        assert_eq!(plan.kinds, ["mlp", "cnn"]);
        let kinds: Vec<u16> = plan.arrivals.iter().map(|&(_, k)| k).collect();
        assert_eq!(kinds, vec![0, 0, 1, 0]);
        assert_eq!(plan.arrivals[0].0, 0.0);
        assert!((plan.arrivals[3].0 - 0.002).abs() < 1e-12);
        assert_eq!(plan.kind_name(1), "cnn");
    }

    #[test]
    fn lane_timelines_have_one_segment_per_batch() {
        let (tls, span) = sample().lane_timelines();
        assert_eq!(tls.len(), 2);
        assert_eq!(tls[0].len(), 2); // batches 0 and 2 on lane 0
        assert_eq!(tls[1].len(), 1);
        assert!(span > 0.0);
        assert!(tls[0].windows(2).all(|w| w[0].t0 <= w[1].t0));
    }

    #[test]
    fn empty_trace_queries_are_benign() {
        let t = TraceData::default();
        let s = t.summary();
        assert_eq!(s.events, 0);
        assert_eq!(s.batches, 0);
        assert!(s.kinds.is_empty());
        assert!(t.slowest(5).is_empty());
        assert!(t.occupancy_histogram().is_empty());
        let (tls, span) = t.lane_timelines();
        assert!(tls.is_empty());
        assert_eq!(span, 0.0);
        assert!(t.replay_plan(1).arrivals.is_empty());
    }
}
