//! Lock-light, per-lane-sharded capture of serving [`TraceEvent`]s.
//!
//! Lanes record whole batches under one short shard-mutex hold (shard =
//! `lane % SHARDS`, so concurrent lanes rarely contend); each shard is a
//! bounded ring that drops its *oldest* events under overflow, so a long
//! run keeps the most recent window and memory stays capped. When no
//! recorder is attached the data plane pays a single `Option` branch per
//! batch — the near-zero-overhead-when-disabled contract the serving
//! bench (`BENCH_trace.json`, `record-overhead`) measures.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::metrics::Counter;

use super::event::TraceEvent;

/// Default total event capacity of a recorder (across all shards).
pub const DEFAULT_TRACE_CAPACITY: usize = 65_536;

/// Shard count: lanes map to shards by `lane % SHARDS`, so up to this
/// many lanes record without sharing a lock.
const SHARDS: usize = 16;

/// Point-in-time recorder accounting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RecorderStats {
    /// Events accepted (including ones later evicted by overflow).
    pub recorded: u64,
    /// Events evicted because a shard ring was full.
    pub dropped: u64,
    /// Events currently buffered across all shards.
    pub buffered: usize,
}

/// Bounded, sharded ring buffer of serving trace events.
pub struct TraceRecorder {
    epoch: Instant,
    shards: Vec<Mutex<VecDeque<TraceEvent>>>,
    shard_cap: usize,
    next_batch: AtomicU64,
    recorded: Counter,
    dropped: Counter,
}

impl Default for TraceRecorder {
    fn default() -> Self {
        Self::new()
    }
}

impl TraceRecorder {
    /// Recorder with the default capacity.
    pub fn new() -> Self {
        Self::with_capacity(DEFAULT_TRACE_CAPACITY)
    }

    /// Recorder bounded to roughly `capacity` events in total (rounded
    /// up to a whole number per shard, minimum one per shard).
    pub fn with_capacity(capacity: usize) -> Self {
        let shard_cap = capacity.div_ceil(SHARDS).max(1);
        TraceRecorder {
            epoch: Instant::now(),
            shards: (0..SHARDS).map(|_| Mutex::new(VecDeque::new())).collect(),
            shard_cap,
            next_batch: AtomicU64::new(0),
            recorded: Counter::new(),
            dropped: Counter::new(),
        }
    }

    /// The instant timestamps are measured from (recorder construction).
    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    /// Nanoseconds from the epoch to `t` (0 for instants before it —
    /// the epoch predates every recorded request by construction).
    pub fn ns_since_epoch(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.epoch).as_nanos() as u64
    }

    /// A fresh batch id (monotone; completion-ordered across lanes).
    pub fn next_batch_id(&self) -> u64 {
        self.next_batch.fetch_add(1, Ordering::Relaxed)
    }

    /// Record one batch's per-request events from `lane` under a single
    /// shard-lock hold. Overflow evicts the shard's oldest events.
    pub fn record(&self, lane: usize, events: impl IntoIterator<Item = TraceEvent>) {
        let mut ring = self.shards[lane % SHARDS].lock().unwrap();
        for e in events {
            if ring.len() >= self.shard_cap {
                ring.pop_front();
                self.dropped.add(1);
            }
            ring.push_back(e);
            self.recorded.add(1);
        }
    }

    /// Current accounting snapshot.
    pub fn stats(&self) -> RecorderStats {
        RecorderStats {
            recorded: self.recorded.get(),
            dropped: self.dropped.get(),
            buffered: self.shards.iter().map(|s| s.lock().unwrap().len()).sum(),
        }
    }

    /// Drain every shard and return the merged events sorted by arrival
    /// (ties broken by request id, so one submitter's order is stable).
    /// The recorder is reusable afterwards; batch ids keep counting.
    pub fn drain(&self) -> Vec<TraceEvent> {
        let mut all = Vec::new();
        for shard in &self.shards {
            all.extend(shard.lock().unwrap().drain(..));
        }
        all.sort_by_key(|e| (e.arrival_ns, e.request_id));
        all
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(request_id: u64, arrival_ns: u64) -> TraceEvent {
        TraceEvent {
            request_id,
            kind: 0,
            lane: 0,
            batch_id: 0,
            occupancy: 1,
            bucket: 1,
            arrival_ns,
            cut_ns: arrival_ns + 1,
            dispatch_ns: arrival_ns + 2,
            complete_ns: arrival_ns + 3,
        }
    }

    #[test]
    fn drain_merges_shards_in_arrival_order() {
        let r = TraceRecorder::new();
        // different lanes land in different shards; drain re-merges
        r.record(3, [ev(2, 20), ev(3, 30)]);
        r.record(0, [ev(0, 5)]);
        r.record(7, [ev(1, 10)]);
        let drained = r.drain();
        let ids: Vec<u64> = drained.iter().map(|e| e.request_id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
        assert_eq!(r.stats().buffered, 0);
        assert_eq!(r.stats().recorded, 4);
    }

    #[test]
    fn overflow_drops_oldest_and_counts() {
        let r = TraceRecorder::with_capacity(SHARDS); // one slot per shard
        r.record(1, (0..5).map(|i| ev(i, i)));
        let s = r.stats();
        assert_eq!(s.recorded, 5);
        assert_eq!(s.dropped, 4);
        assert_eq!(s.buffered, 1);
        // the survivor is the newest event, not the oldest
        assert_eq!(r.drain()[0].request_id, 4);
    }

    #[test]
    fn batch_ids_are_monotone() {
        let r = TraceRecorder::new();
        let a = r.next_batch_id();
        let b = r.next_batch_id();
        assert!(b > a);
    }

    #[test]
    fn epoch_clamps_earlier_instants() {
        let r = TraceRecorder::new();
        assert_eq!(r.ns_since_epoch(r.epoch()), 0);
        let later = Instant::now();
        assert!(r.ns_since_epoch(later) < 10_000_000_000);
    }
}
