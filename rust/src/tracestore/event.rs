//! The per-request trace record the serving data plane emits.

/// One served request's life-cycle, timestamps in nanoseconds since the
/// recorder epoch. The data plane guarantees
/// `arrival_ns <= cut_ns <= dispatch_ns <= complete_ns`; the columnar
/// codec round-trips any values (wrapping deltas), so a malformed file
/// cannot panic the reader.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    /// Coordinator-assigned request id (submission order).
    pub request_id: u64,
    /// Interned kind id — resolves through the trace's footer kind table.
    pub kind: u16,
    /// Worker lane that executed the request's batch.
    pub lane: u16,
    /// Recorder-assigned batch id (groups co-batched requests).
    pub batch_id: u64,
    /// Requests in the batch (its real size, before bucket padding).
    pub occupancy: u16,
    /// Compiled bucket the batch was padded to.
    pub bucket: u32,
    /// Router admission (request enqueued).
    pub arrival_ns: u64,
    /// Batcher cut the request's batch.
    pub cut_ns: u64,
    /// Executing lane picked the batch up.
    pub dispatch_ns: u64,
    /// Backend execution finished.
    pub complete_ns: u64,
}

impl TraceEvent {
    /// Time spent waiting in the dynamic batcher (arrival → cut).
    pub fn batching_ns(&self) -> u64 {
        self.cut_ns.wrapping_sub(self.arrival_ns)
    }

    /// Time spent queued on the lane (cut → dispatch).
    pub fn lane_wait_ns(&self) -> u64 {
        self.dispatch_ns.wrapping_sub(self.cut_ns)
    }

    /// Backend execution time of the request's batch (dispatch → complete).
    pub fn service_ns(&self) -> u64 {
        self.complete_ns.wrapping_sub(self.dispatch_ns)
    }

    /// End-to-end latency (arrival → complete).
    pub fn total_ns(&self) -> u64 {
        self.complete_ns.wrapping_sub(self.arrival_ns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_components_sum_to_total() {
        let e = TraceEvent {
            request_id: 7,
            kind: 1,
            lane: 0,
            batch_id: 3,
            occupancy: 4,
            bucket: 8,
            arrival_ns: 100,
            cut_ns: 150,
            dispatch_ns: 170,
            complete_ns: 400,
        };
        assert_eq!(e.batching_ns(), 50);
        assert_eq!(e.lane_wait_ns(), 20);
        assert_eq!(e.service_ns(), 230);
        assert_eq!(e.total_ns(), e.batching_ns() + e.lane_wait_ns() + e.service_ns());
    }
}
