//! Trace capture, columnar storage and replay — the measurement side of
//! the paper's workload-dependence claim. Tuning tiers score candidates
//! against traffic; this subsystem makes that traffic *recorded* instead
//! of synthetic:
//!
//! * **Capture** — [`TraceRecorder`], a lock-light, per-lane-sharded ring
//!   buffer the serving data plane writes one [`TraceEvent`] per request
//!   into at batch completion (arrival / cut / dispatch / complete
//!   timestamps, batch id + occupancy, lane id). Bounded memory, one
//!   branch of overhead when no recorder is attached.
//! * **Store** — a schema-versioned columnar `.plt` file ([`TraceData`],
//!   [`TraceReader`]): per-column varint payloads with delta-encoded
//!   timestamps, and a JSON footer indexing the columns and carrying the
//!   interned kind table once (ids in the event columns, names only in
//!   the footer). Queries (p50/p99 queue/service breakdowns, occupancy
//!   histograms) read the relevant columns directly.
//! * **Replay** — [`ReplayPlan`], the exact arrival process of a
//!   recorded trace (inter-arrival offsets + kind sequence), which
//!   [`crate::coordinator::loadgen::Scenario::Replay`] re-issues against
//!   a live coordinator and `Session::tune --trace` turns into a
//!   trace-weighted tuning objective.
//!
//! The existing [`crate::trace`] module keeps its rendering role:
//! `parframe trace show` converts a stored trace into per-lane timelines
//! and hands them to the same ASCII/Chrome emitters sim reports use.

pub mod event;
pub mod format;
pub mod query;
pub mod recorder;

pub use event::TraceEvent;
pub use format::{TraceData, TraceReader, TRACE_SCHEMA_VERSION};
pub use query::{KindBreakdown, ReplayPlan, TraceSummary};
pub use recorder::{RecorderStats, TraceRecorder, DEFAULT_TRACE_CAPACITY};
