//! The `.plt` columnar trace file.
//!
//! Zero-dependency, in the same spirit as the vendored [`crate::util::json`]:
//!
//! ```text
//! "PLT1"                                  4-byte magic
//! column payloads, back to back           LEB128 varints, one per event
//! footer                                  compact JSON (schema version,
//!                                         event count, interned kind
//!                                         table, column index)
//! footer length                           u32 little-endian
//! "PLTE"                                  4-byte tail magic
//! ```
//!
//! Ten columns per event, each independently decodable through the
//! footer index. Timestamps are delta-encoded: `arrival_ns` against the
//! previous row (rows are arrival-sorted, so deltas are tiny), and the
//! cut/dispatch/complete instants as the *breakdown columns*
//! `batching_ns` / `lane_wait_ns` / `service_ns` — the exact quantities
//! the `parframe trace` queries want, so p50/p99 breakdowns read one
//! column with no reconstruction. All deltas are wrapping, so any u64
//! stream round-trips byte-identically regardless of ordering.
//!
//! Kind names are interned: events carry `u16` ids, the footer stores
//! the id→name table once (`Router::id_names()` order).

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{PallasError, PallasResult};
use crate::util::json::{self, Json};

use super::event::TraceEvent;

/// Version stamped into every footer; readers reject other versions.
pub const TRACE_SCHEMA_VERSION: u64 = 1;

const MAGIC: &[u8; 4] = b"PLT1";
const TAIL_MAGIC: &[u8; 4] = b"PLTE";

/// Column order is part of the schema (the footer index repeats it, but
/// writers always emit this order so files are byte-deterministic).
const COLUMNS: [(&str, Encoding); 10] = [
    ("request_id", Encoding::Varint),
    ("kind", Encoding::Varint),
    ("lane", Encoding::Varint),
    ("batch_id", Encoding::Varint),
    ("occupancy", Encoding::Varint),
    ("bucket", Encoding::Varint),
    ("arrival_ns", Encoding::DeltaVarint),
    ("batching_ns", Encoding::Varint),
    ("lane_wait_ns", Encoding::Varint),
    ("service_ns", Encoding::Varint),
];

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Encoding {
    /// Plain LEB128 varints.
    Varint,
    /// LEB128 varints of wrapping deltas against the previous value.
    DeltaVarint,
}

impl Encoding {
    fn name(self) -> &'static str {
        match self {
            Encoding::Varint => "varint",
            Encoding::DeltaVarint => "delta-varint",
        }
    }

    fn parse(s: &str) -> Option<Self> {
        match s {
            "varint" => Some(Encoding::Varint),
            "delta-varint" => Some(Encoding::DeltaVarint),
            _ => None,
        }
    }
}

fn put_varint(out: &mut Vec<u8>, mut v: u64) {
    while v >= 0x80 {
        out.push((v as u8) | 0x80);
        v >>= 7;
    }
    out.push(v as u8);
}

fn err(msg: impl Into<String>) -> PallasError {
    PallasError::parse("trace", msg.into())
}

/// A decoded trace: the interned kind table plus the events.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct TraceData {
    /// id→name table, indexed by [`TraceEvent::kind`].
    pub kinds: Vec<String>,
    /// Events in arrival order.
    pub events: Vec<TraceEvent>,
}

impl TraceData {
    /// A trace over a kind table and events (sorted into arrival order —
    /// the writer's canonical row order).
    pub fn new(kinds: Vec<String>, mut events: Vec<TraceEvent>) -> Self {
        events.sort_by_key(|e| (e.arrival_ns, e.request_id));
        TraceData { kinds, events }
    }

    /// The kind name for an interned id (`"kind<id>"` when the footer
    /// table is shorter than the id space — a malformed but readable file).
    pub fn kind_name(&self, id: u16) -> String {
        self.kinds
            .get(id as usize)
            .cloned()
            .unwrap_or_else(|| format!("kind{id}"))
    }

    /// Serialise to `.plt` bytes. Deterministic: the same trace always
    /// produces the same bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16 + self.events.len() * 12);
        out.extend_from_slice(MAGIC);
        let mut index = Vec::with_capacity(COLUMNS.len());
        for (name, enc) in COLUMNS {
            let start = out.len();
            let mut prev = 0u64;
            for e in &self.events {
                let raw = column_value(e, name);
                let stored = match enc {
                    Encoding::Varint => raw,
                    Encoding::DeltaVarint => {
                        let d = raw.wrapping_sub(prev);
                        prev = raw;
                        d
                    }
                };
                put_varint(&mut out, stored);
            }
            index.push((name, enc, start, out.len() - start));
        }
        let footer = json::to_string(&self.footer_json(&index));
        out.extend_from_slice(footer.as_bytes());
        out.extend_from_slice(&(footer.len() as u32).to_le_bytes());
        out.extend_from_slice(TAIL_MAGIC);
        out
    }

    fn footer_json(&self, index: &[(&str, Encoding, usize, usize)]) -> Json {
        let columns = index
            .iter()
            .map(|&(name, enc, offset, len)| {
                let mut col = BTreeMap::new();
                col.insert("encoding".to_string(), Json::Str(enc.name().to_string()));
                col.insert("len".to_string(), Json::Num(len as f64));
                col.insert("name".to_string(), Json::Str(name.to_string()));
                col.insert("offset".to_string(), Json::Num(offset as f64));
                Json::Obj(col)
            })
            .collect();
        let mut obj = BTreeMap::new();
        obj.insert("columns".to_string(), Json::Arr(columns));
        obj.insert("events".to_string(), Json::Num(self.events.len() as f64));
        obj.insert(
            "kinds".to_string(),
            Json::Arr(self.kinds.iter().map(|k| Json::Str(k.clone())).collect()),
        );
        obj.insert(
            "schema_version".to_string(),
            Json::Num(TRACE_SCHEMA_VERSION as f64),
        );
        Json::Obj(obj)
    }

    /// Decode `.plt` bytes (the eager counterpart of [`TraceReader`]).
    pub fn from_bytes(bytes: &[u8]) -> PallasResult<Self> {
        let reader = TraceReader::open(bytes)?;
        let n = reader.events();
        let mut cols = Vec::with_capacity(COLUMNS.len());
        for (name, _) in COLUMNS {
            let col = reader.read_column(name)?;
            if col.len() != n {
                return Err(err(format!(
                    "column '{name}': {} values for {n} events",
                    col.len()
                )));
            }
            cols.push(col);
        }
        let narrow = |v: u64, what: &str, max: u64| -> PallasResult<u64> {
            if v > max {
                return Err(err(format!("{what} {v} out of range (max {max})")));
            }
            Ok(v)
        };
        let mut events = Vec::with_capacity(n);
        for i in 0..n {
            let arrival_ns = cols[6][i];
            let cut_ns = arrival_ns.wrapping_add(cols[7][i]);
            let dispatch_ns = cut_ns.wrapping_add(cols[8][i]);
            let complete_ns = dispatch_ns.wrapping_add(cols[9][i]);
            events.push(TraceEvent {
                request_id: cols[0][i],
                kind: narrow(cols[1][i], "kind id", u16::MAX as u64)? as u16,
                lane: narrow(cols[2][i], "lane id", u16::MAX as u64)? as u16,
                batch_id: cols[3][i],
                occupancy: narrow(cols[4][i], "occupancy", u16::MAX as u64)? as u16,
                bucket: narrow(cols[5][i], "bucket", u32::MAX as u64)? as u32,
                arrival_ns,
                cut_ns,
                dispatch_ns,
                complete_ns,
            });
        }
        Ok(TraceData { kinds: reader.kinds().to_vec(), events })
    }

    /// Write the trace to `path`.
    pub fn save(&self, path: impl AsRef<Path>) -> PallasResult<()> {
        let path = path.as_ref();
        std::fs::write(path, self.to_bytes())
            .map_err(|e| PallasError::io(path.display(), e))
    }

    /// Read a trace from `path`.
    pub fn load(path: impl AsRef<Path>) -> PallasResult<Self> {
        let path = path.as_ref();
        let bytes =
            std::fs::read(path).map_err(|e| PallasError::io(path.display(), e))?;
        Self::from_bytes(&bytes)
    }
}

fn column_value(e: &TraceEvent, name: &str) -> u64 {
    match name {
        "request_id" => e.request_id,
        "kind" => e.kind as u64,
        "lane" => e.lane as u64,
        "batch_id" => e.batch_id,
        "occupancy" => e.occupancy as u64,
        "bucket" => e.bucket as u64,
        "arrival_ns" => e.arrival_ns,
        "batching_ns" => e.batching_ns(),
        "lane_wait_ns" => e.lane_wait_ns(),
        "service_ns" => e.service_ns(),
        _ => unreachable!("unknown column '{name}'"),
    }
}

#[derive(Debug, Clone)]
struct ColumnMeta {
    name: String,
    encoding: Encoding,
    offset: usize,
    len: usize,
}

/// Streaming `.plt` reader: validates the envelope and footer once, then
/// decodes individual columns on demand through [`ColumnCursor`] without
/// materialising the others.
pub struct TraceReader<'a> {
    bytes: &'a [u8],
    events: usize,
    kinds: Vec<String>,
    columns: Vec<ColumnMeta>,
}

impl<'a> TraceReader<'a> {
    /// Validate the envelope (magics, footer index, column bounds) and
    /// build a reader over borrowed bytes.
    pub fn open(bytes: &'a [u8]) -> PallasResult<Self> {
        if bytes.len() < MAGIC.len() + 4 + TAIL_MAGIC.len() || &bytes[..4] != MAGIC {
            return Err(err("not a .plt trace (bad magic or truncated)"));
        }
        if &bytes[bytes.len() - 4..] != TAIL_MAGIC {
            return Err(err("truncated .plt trace (bad tail magic)"));
        }
        let len_at = bytes.len() - 8;
        let footer_len =
            u32::from_le_bytes(bytes[len_at..len_at + 4].try_into().unwrap()) as usize;
        let footer_start = len_at
            .checked_sub(footer_len)
            .ok_or_else(|| err("footer length exceeds file size"))?;
        if footer_start < MAGIC.len() {
            return Err(err("footer overlaps the header"));
        }
        let footer_text = std::str::from_utf8(&bytes[footer_start..len_at])
            .map_err(|_| err("footer is not UTF-8"))?;
        let footer = Json::parse(footer_text)
            .map_err(|e| err(format!("footer is not valid JSON: {e}")))?;
        let version = footer
            .get("schema_version")
            .and_then(Json::as_usize)
            .ok_or_else(|| err("footer missing 'schema_version'"))?;
        if version as u64 != TRACE_SCHEMA_VERSION {
            return Err(err(format!(
                "unsupported trace schema version {version} (reader supports \
                 {TRACE_SCHEMA_VERSION})"
            )));
        }
        let events = footer
            .get("events")
            .and_then(Json::as_usize)
            .ok_or_else(|| err("footer missing 'events'"))?;
        let kinds = footer
            .get("kinds")
            .and_then(Json::as_arr)
            .ok_or_else(|| err("footer missing 'kinds'"))?
            .iter()
            .map(|k| {
                k.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| err("footer 'kinds' entry is not a string"))
            })
            .collect::<PallasResult<Vec<_>>>()?;
        let mut columns = Vec::new();
        for c in footer
            .get("columns")
            .and_then(Json::as_arr)
            .ok_or_else(|| err("footer missing 'columns'"))?
        {
            let name = c
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| err("column missing 'name'"))?;
            let encoding = c
                .get("encoding")
                .and_then(Json::as_str)
                .and_then(Encoding::parse)
                .ok_or_else(|| err(format!("column '{name}': unknown encoding")))?;
            let offset = c
                .get("offset")
                .and_then(Json::as_usize)
                .ok_or_else(|| err(format!("column '{name}': missing 'offset'")))?;
            let len = c
                .get("len")
                .and_then(Json::as_usize)
                .ok_or_else(|| err(format!("column '{name}': missing 'len'")))?;
            let end = offset
                .checked_add(len)
                .filter(|&e| e <= footer_start)
                .ok_or_else(|| err(format!("column '{name}': out of bounds")))?;
            let _ = end;
            columns.push(ColumnMeta { name: name.to_string(), encoding, offset, len });
        }
        Ok(TraceReader { bytes, events, kinds, columns })
    }

    /// Events per column.
    pub fn events(&self) -> usize {
        self.events
    }

    /// The interned id→name kind table from the footer.
    pub fn kinds(&self) -> &[String] {
        &self.kinds
    }

    /// Column names in file order.
    pub fn column_names(&self) -> Vec<&str> {
        self.columns.iter().map(|c| c.name.as_str()).collect()
    }

    /// A streaming cursor over one column (delta decoding applied).
    pub fn column(&self, name: &str) -> PallasResult<ColumnCursor<'a>> {
        let meta = self
            .columns
            .iter()
            .find(|c| c.name == name)
            .ok_or_else(|| err(format!("no column '{name}' in trace")))?;
        Ok(ColumnCursor {
            buf: &self.bytes[meta.offset..meta.offset + meta.len],
            pos: 0,
            left: self.events,
            delta: meta.encoding == Encoding::DeltaVarint,
            acc: 0,
        })
    }

    /// Decode one whole column.
    pub fn read_column(&self, name: &str) -> PallasResult<Vec<u64>> {
        let mut cursor = self.column(name)?;
        let mut out = Vec::with_capacity(self.events);
        while let Some(v) = cursor.next()? {
            out.push(v);
        }
        Ok(out)
    }
}

/// Streaming decoder over one column's payload.
pub struct ColumnCursor<'a> {
    buf: &'a [u8],
    pos: usize,
    left: usize,
    delta: bool,
    acc: u64,
}

impl ColumnCursor<'_> {
    /// The next value, or `None` once all of the column's events have
    /// been decoded. Truncated or oversized varints are errors.
    #[allow(clippy::should_implement_trait)] // fallible: Iterator can't surface the error
    pub fn next(&mut self) -> PallasResult<Option<u64>> {
        if self.left == 0 {
            return Ok(None);
        }
        let mut v = 0u64;
        let mut shift = 0u32;
        loop {
            let Some(&b) = self.buf.get(self.pos) else {
                return Err(err("column payload truncated mid-varint"));
            };
            self.pos += 1;
            if shift >= 64 {
                return Err(err("varint longer than 64 bits"));
            }
            v |= ((b & 0x7f) as u64) << shift;
            if b & 0x80 == 0 {
                break;
            }
            shift += 7;
        }
        self.left -= 1;
        if self.delta {
            self.acc = self.acc.wrapping_add(v);
            v = self.acc;
        }
        Ok(Some(v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(i: u64) -> TraceEvent {
        TraceEvent {
            request_id: i,
            kind: (i % 3) as u16,
            lane: (i % 2) as u16,
            batch_id: i / 4,
            occupancy: 4,
            bucket: 8,
            arrival_ns: i * 1000,
            cut_ns: i * 1000 + 50,
            dispatch_ns: i * 1000 + 70,
            complete_ns: i * 1000 + 400,
        }
    }

    fn sample(n: u64) -> TraceData {
        TraceData::new(
            vec!["a".into(), "b".into(), "c".into()],
            (0..n).map(ev).collect(),
        )
    }

    #[test]
    fn round_trips_events_and_bytes() {
        for n in [0u64, 1, 2, 100] {
            let t = sample(n);
            let bytes = t.to_bytes();
            let back = TraceData::from_bytes(&bytes).unwrap();
            assert_eq!(back, t, "n={n}");
            assert_eq!(back.to_bytes(), bytes, "n={n}: re-encode not byte-identical");
        }
    }

    #[test]
    fn streaming_cursor_matches_eager_decode() {
        let t = sample(37);
        let bytes = t.to_bytes();
        let r = TraceReader::open(&bytes).unwrap();
        assert_eq!(r.events(), 37);
        assert_eq!(r.kinds(), ["a", "b", "c"]);
        let mut cursor = r.column("arrival_ns").unwrap();
        let mut got = Vec::new();
        while let Some(v) = cursor.next().unwrap() {
            got.push(v);
        }
        let want: Vec<u64> = t.events.iter().map(|e| e.arrival_ns).collect();
        assert_eq!(got, want);
        // breakdown columns store the deltas directly
        let svc = r.read_column("service_ns").unwrap();
        assert!(svc.iter().all(|&v| v == 330));
    }

    #[test]
    fn rejects_malformed_envelopes() {
        assert!(TraceData::from_bytes(b"").is_err());
        assert!(TraceData::from_bytes(b"nope").is_err());
        let mut bytes = sample(3).to_bytes();
        // flip the tail magic
        let n = bytes.len();
        bytes[n - 1] = b'X';
        assert!(TraceData::from_bytes(&bytes).is_err());
        // truncate mid-column
        let bytes = sample(3).to_bytes();
        assert!(TraceData::from_bytes(&bytes[..bytes.len() / 2]).is_err());
    }

    #[test]
    fn rejects_future_schema_versions() {
        let mut bytes = sample(2).to_bytes();
        // patch "schema_version":1 -> 9 in place (same length, so the
        // envelope still parses and only the version check fires)
        let needle = b"\"schema_version\":1";
        let at = bytes
            .windows(needle.len())
            .position(|w| w == needle)
            .expect("footer carries the schema version");
        bytes[at + needle.len() - 1] = b'9';
        assert!(matches!(
            TraceData::from_bytes(&bytes),
            Err(PallasError::Parse { .. })
        ));
    }

    #[test]
    fn writer_sorts_rows_by_arrival() {
        let t = TraceData::new(vec!["a".into()], vec![ev(5), ev(1), ev(3)]);
        let ids: Vec<u64> = t.events.iter().map(|e| e.request_id).collect();
        assert_eq!(ids, vec![1, 3, 5]);
    }
}
