//! The `pallas` API layer — the one supported entry point to the crate.
//!
//! The paper's end product is a *workflow*: profile a model's design
//! features, pick a configuration by the §8 guidelines (or a deeper
//! tier), then run with it. This module makes that workflow first-class
//! instead of ad-hoc CLI plumbing:
//!
//! * [`Session`] — owns the shared pieces (platform, [`crate::sim::SimCache`],
//!   sweep jobs, policy pin) and exposes the tune / simulate / serve verbs;
//! * [`Workload`] — what to tune: model kinds + batches + traffic mix;
//! * [`Plan`] — the serializable output of any tuning tier: per-kind
//!   configs, lane layout, and provenance (tier, evaluated points, sim
//!   fingerprint), with bit-identical JSON round-trip so
//!   `tune --emit-plan plan.json` → `serve --plan plan.json` crosses
//!   processes losslessly;
//! * [`crate::PallasError`] — the facade's single typed error.
//!
//! ```no_run
//! use parframe::api::{Session, Workload};
//!
//! let session = Session::builder().platform_named("large.2")?.build();
//! let plan = session.tune(&Workload::kinds(&["wide_deep", "resnet50"])?)?;
//! plan.save("plan.json")?;                   // tune once...
//! let handle = session.serve(&Plan::load("plan.json")?)?; // ...serve many
//! # use parframe::api::Plan;
//! # let _ = handle;
//! # Ok::<(), parframe::PallasError>(())
//! ```
//!
//! The CLI (`rust/src/main.rs`) is a thin shell over this module; the
//! examples and integration tests go through it too.

pub mod plan;
pub mod session;
pub mod workload;

pub use plan::{group_line, sim_fingerprint, Plan, PlanEntry, PlanTier, PLAN_VERSION};
pub use session::{model_catalog, ModelInfo, ServeHandle, Session, SessionBuilder};
pub use workload::{Workload, WorkloadEntry};
