//! Workload descriptors — *what* a session tunes and serves.
//!
//! A [`Workload`] names one or more model-zoo kinds with a batch size and
//! a traffic weight each. Single-model tuning (`tune --model ncf`) is a
//! one-entry workload; core-aware serving (`serve --kinds a,b`) is a
//! multi-entry workload whose weights drive the proportional core split.
//! Model names are validated against the zoo at construction, so a typo
//! fails with [`PallasError::UnknownModel`] before any tuning work runs.

use crate::error::{PallasError, PallasResult};
use crate::models;
use crate::tracestore::TraceData;

/// One model in a workload: the zoo kind, the batch size tuning targets,
/// and the kind's share of traffic (relative; need not sum to 1).
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadEntry {
    /// Model-zoo name.
    pub kind: String,
    /// Batch size the tuner optimises for.
    pub batch: usize,
    /// Relative traffic weight (drives the core split in multi-kind
    /// workloads; ignored for a single kind).
    pub weight: f64,
}

/// A tuning/serving workload: model kinds + batches + traffic mix.
#[derive(Debug, Clone, PartialEq)]
pub struct Workload {
    /// The described kinds, in declaration order.
    pub entries: Vec<WorkloadEntry>,
}

impl Workload {
    /// Single-model workload at the model's canonical batch size.
    pub fn single(model: &str) -> PallasResult<Self> {
        Self::mix(&[(model, 1.0)])
    }

    /// Multi-model workload with equal traffic weights.
    pub fn kinds(kinds: &[&str]) -> PallasResult<Self> {
        let mix: Vec<(&str, f64)> = kinds.iter().map(|k| (*k, 1.0)).collect();
        Self::mix(&mix)
    }

    /// Multi-model workload with explicit traffic weights. Every kind
    /// must exist in the zoo and appear at most once (one lane group per
    /// kind — duplicate entries would silently collapse in the serving
    /// tables); batches default to each model's canonical serving batch.
    pub fn mix(mix: &[(&str, f64)]) -> PallasResult<Self> {
        if mix.is_empty() {
            return Err(PallasError::InvalidConfig("workload: no model kinds".into()));
        }
        for (i, (kind, _)) in mix.iter().enumerate() {
            if mix[..i].iter().any(|(k, _)| k == kind) {
                return Err(PallasError::InvalidConfig(format!(
                    "workload: duplicate kind '{kind}'"
                )));
            }
        }
        let entries = mix
            .iter()
            .map(|(kind, weight)| {
                if models::build(kind, 1).is_none() {
                    return Err(PallasError::UnknownModel(kind.to_string()));
                }
                Ok(WorkloadEntry {
                    kind: kind.to_string(),
                    batch: models::canonical_batch(kind),
                    weight: *weight,
                })
            })
            .collect::<PallasResult<Vec<_>>>()?;
        Ok(Workload { entries })
    }

    /// Derive a workload from a recorded serving trace (the `tune
    /// --trace` path): one entry per kind that saw traffic, weighted by
    /// its recorded request count, with the batch set to the kind's most
    /// frequent compiled bucket — so the tuner optimises for the batch
    /// shape the batcher actually produced, not the canonical default.
    /// Kinds are validated against the zoo exactly like [`Self::mix`].
    pub fn from_trace(trace: &TraceData) -> PallasResult<Self> {
        let counts = trace.per_kind_counts();
        if counts.is_empty() {
            return Err(PallasError::InvalidConfig("workload: trace has no events".into()));
        }
        let names: Vec<String> = counts.iter().map(|&(id, _)| trace.kind_name(id)).collect();
        let mix: Vec<(&str, f64)> = names
            .iter()
            .zip(&counts)
            .map(|(name, &(_, count))| (name.as_str(), count as f64))
            .collect();
        let mut workload = Self::mix(&mix)?;
        for (entry, &(id, _)) in workload.entries.iter_mut().zip(&counts) {
            if let Some(bucket) = trace.mode_bucket(id) {
                if bucket >= 1 {
                    entry.batch = bucket as usize;
                }
            }
        }
        Ok(workload)
    }

    /// Override the batch size of every entry (the `tune --batch` knob;
    /// meaningful for single-model workloads).
    pub fn with_batch(mut self, batch: usize) -> PallasResult<Self> {
        if batch == 0 {
            return Err(PallasError::InvalidConfig("workload: batch must be >= 1".into()));
        }
        for e in &mut self.entries {
            e.batch = batch;
        }
        Ok(self)
    }

    /// The described kind names, in declaration order.
    pub fn kind_names(&self) -> Vec<&str> {
        self.entries.iter().map(|e| e.kind.as_str()).collect()
    }

    /// The traffic mix as `(kind, weight)` pairs.
    pub fn weights(&self) -> Vec<(String, f64)> {
        self.entries.iter().map(|e| (e.kind.clone(), e.weight)).collect()
    }

    /// The first entry (the model of a single-model workload).
    pub fn primary(&self) -> &WorkloadEntry {
        &self.entries[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_uses_canonical_batch() {
        let w = Workload::single("wide_deep").unwrap();
        assert_eq!(w.entries.len(), 1);
        assert_eq!(w.primary().batch, models::canonical_batch("wide_deep"));
        assert_eq!(w.kind_names(), vec!["wide_deep"]);
    }

    #[test]
    fn unknown_model_rejected_at_construction() {
        assert_eq!(
            Workload::single("bert").unwrap_err(),
            PallasError::UnknownModel("bert".into())
        );
        assert!(Workload::mix(&[]).is_err());
        assert!(Workload::kinds(&["wide_deep", "gpt"]).is_err());
        assert!(matches!(
            Workload::mix(&[("wide_deep", 0.9), ("wide_deep", 0.1)]),
            Err(PallasError::InvalidConfig(m)) if m.contains("duplicate")
        ));
    }

    #[test]
    fn from_trace_weights_by_counts_and_sets_mode_buckets() {
        use crate::tracestore::TraceEvent;
        let ev = |id: u64, kind: u16, bucket: u32| TraceEvent {
            request_id: id,
            kind,
            lane: 0,
            batch_id: id,
            occupancy: 1,
            bucket,
            arrival_ns: id * 100,
            cut_ns: id * 100 + 1,
            dispatch_ns: id * 100 + 2,
            complete_ns: id * 100 + 3,
        };
        let trace = crate::tracestore::TraceData::new(
            vec!["wide_deep".into(), "resnet50".into()],
            vec![ev(0, 0, 4), ev(1, 0, 4), ev(2, 0, 8), ev(3, 1, 1)],
        );
        let w = Workload::from_trace(&trace).unwrap();
        assert_eq!(w.kind_names(), vec!["wide_deep", "resnet50"]);
        assert_eq!(w.entries[0].weight, 3.0);
        assert_eq!(w.entries[0].batch, 4); // mode bucket, not canonical
        assert_eq!(w.entries[1].weight, 1.0);
        assert_eq!(w.entries[1].batch, 1);
        // a kind name outside the zoo fails like mix() does
        let bad = crate::tracestore::TraceData::new(vec!["gpt".into()], vec![ev(0, 0, 1)]);
        assert!(matches!(Workload::from_trace(&bad), Err(PallasError::UnknownModel(_))));
        // an empty trace cannot describe a workload
        let empty = crate::tracestore::TraceData::default();
        assert!(Workload::from_trace(&empty).is_err());
    }

    #[test]
    fn batch_override_and_weights() {
        let w = Workload::mix(&[("wide_deep", 0.9), ("resnet50", 0.1)])
            .unwrap()
            .with_batch(4)
            .unwrap();
        assert!(w.entries.iter().all(|e| e.batch == 4));
        assert_eq!(w.weights()[0], ("wide_deep".to_string(), 0.9));
        assert!(Workload::single("ncf").unwrap().with_batch(0).is_err());
    }
}
