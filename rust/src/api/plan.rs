//! The serializable tuning-plan artifact — "pick a config offline,
//! deploy it as an artifact".
//!
//! A [`Plan`] is the output of any tuning tier ([`PlanTier`]): per-kind
//! [`FrameworkConfig`]s with their lane layout (core slice + lane count
//! per kind), plus provenance — which tier produced it, how many design
//! points it evaluated, and a simulator fingerprint binding the plan to
//! the exact graphs/platform shape it was tuned against. Plans round-trip
//! through JSON **bit-identically**: every knob is written explicitly in
//! the canonical spelling [`crate::config::framework_from_json`] parses
//! back, `f64` latencies use Rust's shortest round-trip formatting, and
//! the `u64` fingerprint travels as a hex string (JSON numbers are `f64`
//! and would truncate it). `tune --emit-plan plan.json` in one process
//! followed by `serve --plan plan.json` in another therefore serves the
//! *same* configuration bits in-process tuning would.
//!
//! Schema (version 1; unknown keys are rejected at every level):
//!
//! ```json
//! {
//!   "version": 1,
//!   "platform": "large.2",
//!   "tier": "guidelines",
//!   "evaluated": 2,
//!   "sim_fingerprint": "9f86d081884c7d65",
//!   "entries": [
//!     {"kind": "wide_deep", "batch": 64, "first_core": 0, "cores": 24,
//!      "lanes": 1, "predicted_latency_s": 0.00123,
//!      "config": { ...framework knobs, all explicit... }}
//!   ]
//! }
//! ```

use std::collections::BTreeMap;

use crate::config::{framework_from_json, framework_to_json, CpuPlatform, FrameworkConfig};
use crate::error::{PallasError, PallasResult};
use crate::models;
use crate::sched::{CoreAllocation, LaneGroup, LanePlan};
use crate::sim::{fingerprint_fold, graph_structure_fingerprint, platform_fingerprint};
use crate::tuner::Baseline;
use crate::util::json::{self, Json};

/// Which tuning tier produced a plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanTier {
    /// The paper's §8 closed-form guideline.
    Guidelines,
    /// Exhaustive sweep of the feasible design lattice (global optimum).
    Exhaustive,
    /// A published baseline recommendation.
    Baseline(Baseline),
    /// A snapshot of the online re-tuner's live plan.
    OnlineSnapshot,
}

impl PlanTier {
    /// Canonical artifact spelling.
    pub fn name(&self) -> String {
        match self {
            PlanTier::Guidelines => "guidelines".into(),
            PlanTier::Exhaustive => "exhaustive".into(),
            PlanTier::Baseline(b) => format!("baseline:{}", b.name()),
            PlanTier::OnlineSnapshot => "online-snapshot".into(),
        }
    }

    /// Parse the canonical spelling back.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "guidelines" => Some(PlanTier::Guidelines),
            "exhaustive" => Some(PlanTier::Exhaustive),
            "online-snapshot" => Some(PlanTier::OnlineSnapshot),
            other => other
                .strip_prefix("baseline:")
                .and_then(Baseline::parse)
                .map(PlanTier::Baseline),
        }
    }
}

/// One kind's slice of a plan: its lane layout and tuned knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanEntry {
    /// Model-zoo kind this entry serves.
    pub kind: String,
    /// Batch size the config was tuned for.
    pub batch: usize,
    /// First physical core of the kind's slice.
    pub first_core: usize,
    /// Physical cores in the slice.
    pub cores: usize,
    /// Worker lanes splitting the slice.
    pub lanes: usize,
    /// The tuned framework knobs for this slice.
    pub config: FrameworkConfig,
    /// Simulated batch latency under `config` on the slice, seconds
    /// (provenance; serving re-derives its own tables from `config`).
    pub predicted_latency_s: f64,
}

/// A serializable tuning decision: per-kind configs + lane layout +
/// provenance. See the module docs for the JSON schema.
#[derive(Debug, Clone, PartialEq)]
pub struct Plan {
    /// Platform preset name the plan was tuned for.
    pub platform: String,
    /// Producing tier.
    pub tier: PlanTier,
    /// Design points evaluated while producing the plan.
    pub evaluated: usize,
    /// Fingerprint of (platform shape, per-entry graph structure) — see
    /// [`sim_fingerprint`]. Serving refuses a plan whose fingerprint no
    /// longer matches the local zoo/simulator.
    pub sim_fingerprint: u64,
    /// Per-kind entries, in core order.
    pub entries: Vec<PlanEntry>,
}

/// Artifact schema version this build writes and reads.
pub const PLAN_VERSION: usize = 1;

const PLAN_KEYS: [&str; 6] =
    ["version", "platform", "tier", "evaluated", "sim_fingerprint", "entries"];
const ENTRY_KEYS: [&str; 7] =
    ["kind", "batch", "first_core", "cores", "lanes", "config", "predicted_latency_s"];

/// Fingerprint binding a plan to what it was tuned against: the platform
/// *shape* (FNV over every field the cost model reads, names excluded)
/// folded with each entry's graph-structure fingerprint in entry order.
/// Changing a model's graph, a platform constant, or the entry set
/// changes the fingerprint; renaming a platform or reordering JSON keys
/// does not.
pub fn sim_fingerprint(
    platform: &CpuPlatform,
    entries: &[(String, usize)],
) -> PallasResult<u64> {
    let mut h = platform_fingerprint(platform);
    for (kind, batch) in entries {
        let graph = models::build(kind, *batch)
            .ok_or_else(|| PallasError::UnknownModel(kind.clone()))?;
        // structure-only hash: no need to precompute ranks/CSR just to
        // fingerprint the provenance path
        h = fingerprint_fold(h, graph_structure_fingerprint(&graph));
    }
    Ok(h)
}

impl Plan {
    /// Serialize to compact JSON (the `tune --emit-plan` artifact).
    pub fn to_json(&self) -> String {
        let mut m = BTreeMap::new();
        m.insert("version".into(), Json::Num(PLAN_VERSION as f64));
        m.insert("platform".into(), Json::Str(self.platform.clone()));
        m.insert("tier".into(), Json::Str(self.tier.name()));
        m.insert("evaluated".into(), Json::Num(self.evaluated as f64));
        m.insert(
            "sim_fingerprint".into(),
            Json::Str(format!("{:016x}", self.sim_fingerprint)),
        );
        let entries = self
            .entries
            .iter()
            .map(|e| {
                let mut em = BTreeMap::new();
                em.insert("kind".into(), Json::Str(e.kind.clone()));
                em.insert("batch".into(), Json::Num(e.batch as f64));
                em.insert("first_core".into(), Json::Num(e.first_core as f64));
                em.insert("cores".into(), Json::Num(e.cores as f64));
                em.insert("lanes".into(), Json::Num(e.lanes as f64));
                em.insert("config".into(), framework_to_json(&e.config));
                em.insert("predicted_latency_s".into(), Json::Num(e.predicted_latency_s));
                Json::Obj(em)
            })
            .collect();
        m.insert("entries".into(), Json::Arr(entries));
        json::to_string(&Json::Obj(m))
    }

    /// Parse a plan artifact. Rejects unknown keys (at the top level, in
    /// entries, and inside each config object), wrong versions, and
    /// malformed fingerprints.
    pub fn from_json(text: &str) -> PallasResult<Self> {
        let doc = Json::parse(text).map_err(|e| PallasError::parse("plan", e))?;
        let obj = doc
            .as_obj()
            .ok_or_else(|| PallasError::parse("plan", "plan must be a JSON object"))?;
        for key in obj.keys() {
            if !PLAN_KEYS.contains(&key.as_str()) {
                return Err(PallasError::InvalidPlan(format!(
                    "unknown plan key '{key}' (accepted: {})",
                    PLAN_KEYS.join(", ")
                )));
            }
        }
        let version = obj.get("version").and_then(strict_usize).unwrap_or(0);
        if version != PLAN_VERSION {
            return Err(PallasError::InvalidPlan(format!(
                "unsupported plan version {version} (this build reads {PLAN_VERSION})"
            )));
        }
        let platform = obj
            .get("platform")
            .and_then(Json::as_str)
            .ok_or_else(|| PallasError::parse("plan", "missing platform"))?
            .to_string();
        let tier_name = obj
            .get("tier")
            .and_then(Json::as_str)
            .ok_or_else(|| PallasError::parse("plan", "missing tier"))?;
        let tier = PlanTier::parse(tier_name)
            .ok_or_else(|| PallasError::InvalidPlan(format!("unknown tier '{tier_name}'")))?;
        let evaluated = obj
            .get("evaluated")
            .and_then(strict_usize)
            .ok_or_else(|| PallasError::parse("plan", "missing or non-integer evaluated"))?;
        let fp_text = obj
            .get("sim_fingerprint")
            .and_then(Json::as_str)
            .ok_or_else(|| PallasError::parse("plan", "missing sim_fingerprint"))?;
        let sim_fingerprint = u64::from_str_radix(fp_text, 16)
            .map_err(|_| PallasError::parse("plan", format!("bad fingerprint '{fp_text}'")))?;
        let entries = obj
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| PallasError::parse("plan", "missing entries"))?
            .iter()
            .map(parse_entry)
            .collect::<PallasResult<Vec<_>>>()?;
        if entries.is_empty() {
            return Err(PallasError::InvalidPlan("plan has no entries".into()));
        }
        Ok(Plan { platform, tier, evaluated, sim_fingerprint, entries })
    }

    /// Write the artifact to a file.
    pub fn save(&self, path: &str) -> PallasResult<()> {
        std::fs::write(path, self.to_json()).map_err(|e| PallasError::io(path, e))
    }

    /// Read an artifact from a file.
    pub fn load(path: &str) -> PallasResult<Self> {
        let text = std::fs::read_to_string(path).map_err(|e| PallasError::io(path, e))?;
        Self::from_json(&text)
    }

    /// Capture a live [`LanePlan`] (each group must host exactly one
    /// kind) with per-entry batches and predicted latencies supplied by
    /// the caller, in group order.
    pub fn from_lane_plan(
        lane_plan: &LanePlan,
        tier: PlanTier,
        evaluated: usize,
        batches: &[usize],
        predicted: &[f64],
    ) -> PallasResult<Self> {
        if batches.len() != lane_plan.groups.len() || predicted.len() != lane_plan.groups.len() {
            return Err(PallasError::InvalidPlan(
                "from_lane_plan: batches/predicted length != group count".into(),
            ));
        }
        let mut entries = Vec::with_capacity(lane_plan.groups.len());
        for (i, g) in lane_plan.groups.iter().enumerate() {
            if g.kinds.len() != 1 {
                return Err(PallasError::InvalidPlan(
                    "plan artifact requires single-kind lane groups".into(),
                ));
            }
            entries.push(PlanEntry {
                kind: g.kinds[0].clone(),
                batch: batches[i],
                first_core: g.allocation.first_core,
                cores: g.allocation.cores,
                // lane_assignments treats 0 as 1; normalise here so every
                // captured plan re-parses (the artifact rejects lanes=0)
                lanes: g.lanes.max(1),
                config: g.framework.clone(),
                predicted_latency_s: predicted[i],
            });
        }
        let fp_entries: Vec<(String, usize)> =
            entries.iter().map(|e| (e.kind.clone(), e.batch)).collect();
        let sim_fingerprint = sim_fingerprint(&lane_plan.platform, &fp_entries)?;
        Ok(Plan {
            platform: lane_plan.platform.name.clone(),
            tier,
            evaluated,
            sim_fingerprint,
            entries,
        })
    }

    /// Reconstruct the runnable [`LanePlan`] on a concrete platform.
    /// Fails with [`PallasError::PlanMismatch`] when the platform differs
    /// from the one the plan was tuned for, and validates the lane
    /// invariants (disjoint slices inside the machine).
    pub fn lane_plan(&self, platform: &CpuPlatform) -> PallasResult<LanePlan> {
        if platform.name != self.platform {
            return Err(PallasError::PlanMismatch {
                expected_platform: self.platform.clone(),
                got: platform.name.clone(),
            });
        }
        let groups = self
            .entries
            .iter()
            .map(|e| LaneGroup {
                kinds: vec![e.kind.clone()],
                allocation: CoreAllocation::new(e.first_core, e.cores),
                lanes: e.lanes,
                framework: e.config.clone(),
            })
            .collect();
        let plan = LanePlan { platform: platform.clone(), groups };
        plan.validate()?;
        for e in &self.entries {
            e.config.validate(platform)?;
        }
        Ok(plan)
    }

    /// Recompute the fingerprint against the local zoo/platform and
    /// compare with the stored one — the staleness check serving runs
    /// before trusting a plan.
    pub fn verify_fingerprint(&self, platform: &CpuPlatform) -> PallasResult<()> {
        let fp_entries: Vec<(String, usize)> =
            self.entries.iter().map(|e| (e.kind.clone(), e.batch)).collect();
        let fresh = sim_fingerprint(platform, &fp_entries)?;
        if fresh != self.sim_fingerprint {
            return Err(PallasError::InvalidPlan(format!(
                "sim fingerprint mismatch: plan has {:016x}, local zoo/platform give \
                 {fresh:016x} (the plan was tuned against a different model or simulator \
                 version — re-run tune)",
                self.sim_fingerprint
            )));
        }
        Ok(())
    }

    /// The kinds this plan serves, in entry order.
    pub fn kinds(&self) -> Vec<&str> {
        self.entries.iter().map(|e| e.kind.as_str()).collect()
    }

    /// One human-readable line per entry (shared by `plan --show` and
    /// `serve --plan`, so CI can diff the *served* config against the
    /// artifact).
    pub fn group_lines(&self) -> Vec<String> {
        self.entries
            .iter()
            .map(|e| group_line(&e.kind, e.first_core, e.cores, e.lanes, &e.config))
            .collect()
    }
}

/// The canonical one-line rendering of one lane group's placement +
/// knobs. `Plan::group_lines` and the CLI's live-coordinator printout
/// both use this, so a `diff` between `plan --show` and `serve --plan`
/// output compares artifact bits against the live lane set.
pub fn group_line(
    kind: &str,
    first_core: usize,
    cores: usize,
    lanes: usize,
    config: &FrameworkConfig,
) -> String {
    format!(
        "  group {}: cores {}..={} ({}) lanes={} pools={} mkl={} intra={} policy={}",
        kind,
        first_core,
        first_core + cores.max(1) - 1,
        cores,
        lanes,
        config.inter_op_pools,
        config.mkl_threads,
        config.intra_op_threads,
        config.sched_policy.name()
    )
}

/// Strict non-negative integer: `Json` numbers are `f64`, and the lax
/// `Json::as_usize` would silently truncate `64.9` or saturate `-1` —
/// a plan artifact must deploy exactly what the file says or fail.
/// Bounded at 2^53, past which `f64` can't hold an exact integer (so
/// the cast below is always value-preserving).
fn strict_usize(v: &Json) -> Option<usize> {
    const MAX_EXACT: f64 = 9_007_199_254_740_992.0; // 2^53
    let n = v.as_f64()?;
    if n.fract() != 0.0 || !(0.0..MAX_EXACT).contains(&n) {
        return None;
    }
    Some(n as usize)
}

fn parse_entry(v: &Json) -> PallasResult<PlanEntry> {
    let obj = v
        .as_obj()
        .ok_or_else(|| PallasError::parse("plan", "entry must be an object"))?;
    for key in obj.keys() {
        if !ENTRY_KEYS.contains(&key.as_str()) {
            return Err(PallasError::InvalidPlan(format!(
                "unknown plan entry key '{key}' (accepted: {})",
                ENTRY_KEYS.join(", ")
            )));
        }
    }
    let usize_field = |name: &str| -> PallasResult<usize> {
        obj.get(name).and_then(strict_usize).ok_or_else(|| {
            PallasError::parse("plan", format!("entry missing or non-integer {name}"))
        })
    };
    let kind = obj
        .get("kind")
        .and_then(Json::as_str)
        .ok_or_else(|| PallasError::parse("plan", "entry missing kind"))?
        .to_string();
    let config = framework_from_json(
        obj.get("config")
            .ok_or_else(|| PallasError::parse("plan", "entry missing config"))?,
    )?;
    let predicted_latency_s = obj
        .get("predicted_latency_s")
        .and_then(Json::as_f64)
        .ok_or_else(|| PallasError::parse("plan", "entry missing predicted_latency_s"))?;
    let lanes = usize_field("lanes")?;
    if lanes == 0 {
        return Err(PallasError::InvalidPlan(format!("entry '{kind}': lanes must be >= 1")));
    }
    Ok(PlanEntry {
        kind,
        batch: usize_field("batch")?,
        first_core: usize_field("first_core")?,
        cores: usize_field("cores")?,
        lanes,
        config,
        predicted_latency_s,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SchedPolicy;

    fn sample_plan() -> Plan {
        let platform = CpuPlatform::large2();
        let lane_plan = LanePlan::guideline(&platform, &["wide_deep", "resnet50"]).unwrap();
        Plan::from_lane_plan(
            &lane_plan,
            PlanTier::Guidelines,
            2,
            &[64, 16],
            &[0.001234567890123, 0.08765],
        )
        .unwrap()
    }

    #[test]
    fn json_roundtrip_is_identity() {
        let p = sample_plan();
        let text = p.to_json();
        let back = Plan::from_json(&text).unwrap();
        assert_eq!(back, p);
        // serialization is a fixed point
        assert_eq!(back.to_json(), text);
    }

    #[test]
    fn tier_names_roundtrip() {
        for tier in [
            PlanTier::Guidelines,
            PlanTier::Exhaustive,
            PlanTier::Baseline(Baseline::IntelRecommended),
            PlanTier::OnlineSnapshot,
        ] {
            assert_eq!(PlanTier::parse(&tier.name()), Some(tier));
        }
        assert_eq!(PlanTier::parse("vibes"), None);
    }

    #[test]
    fn fingerprint_survives_roundtrip_and_detects_drift() {
        let p = sample_plan();
        let platform = CpuPlatform::large2();
        p.verify_fingerprint(&platform).unwrap();
        let back = Plan::from_json(&p.to_json()).unwrap();
        assert_eq!(back.sim_fingerprint, p.sim_fingerprint);
        back.verify_fingerprint(&platform).unwrap();
        // a different batch means a different graph: must be detected
        let mut drifted = p.clone();
        drifted.entries[0].batch += 1;
        assert!(matches!(
            drifted.verify_fingerprint(&platform),
            Err(PallasError::InvalidPlan(_))
        ));
    }

    #[test]
    fn lane_plan_reconstruction_checks_platform() {
        let p = sample_plan();
        let lp = p.lane_plan(&CpuPlatform::large2()).unwrap();
        lp.validate().unwrap();
        assert_eq!(lp.groups.len(), 2);
        assert_eq!(lp.groups[0].framework, p.entries[0].config);
        match p.lane_plan(&CpuPlatform::small()) {
            Err(PallasError::PlanMismatch { expected_platform, got }) => {
                assert_eq!(expected_platform, "large.2");
                assert_eq!(got, "small");
            }
            other => panic!("expected PlanMismatch, got {other:?}"),
        }
    }

    #[test]
    fn rejects_unknown_keys_and_bad_versions() {
        let p = sample_plan();
        let text = p.to_json();
        let poisoned = text.replacen("\"platform\"", "\"platfrom\"", 1);
        assert!(matches!(
            Plan::from_json(&poisoned),
            Err(PallasError::InvalidPlan(m)) if m.contains("platfrom")
        ));
        let wrong_version = text.replacen("\"version\":1", "\"version\":9", 1);
        assert!(Plan::from_json(&wrong_version).is_err());
        // a typo'd config knob inside an entry is also fatal
        let bad_knob = text.replacen("\"mkl_threads\"", "\"mkl_treads\"", 1);
        assert!(Plan::from_json(&bad_knob).is_err());
        // provenance fields are strict: a mistyped evaluated is rejected,
        // not defaulted to 0, and lanes=0 cannot deploy
        let bad_eval = text.replacen("\"evaluated\":2", "\"evaluated\":\"2\"", 1);
        assert!(Plan::from_json(&bad_eval).is_err());
        // integer fields are strict: fractional numbers don't truncate
        let frac_batch = text.replacen("\"batch\":64", "\"batch\":64.9", 1);
        assert!(Plan::from_json(&frac_batch).is_err());
        let frac_version = text.replacen("\"version\":1", "\"version\":1.9", 1);
        assert!(Plan::from_json(&frac_version).is_err());
        let zero_lanes = text.replacen("\"lanes\":1", "\"lanes\":0", 1);
        assert!(matches!(
            Plan::from_json(&zero_lanes),
            Err(PallasError::InvalidPlan(m)) if m.contains("lanes")
        ));
    }

    #[test]
    fn latency_bits_roundtrip_exactly() {
        let mut p = sample_plan();
        // an awkward f64 with no short decimal representation
        p.entries[0].predicted_latency_s = 1.0 / 3.0 * 1e-3;
        p.entries[1].predicted_latency_s = f64::from_bits(0x3F0F_0F0F_0F0F_0F0F);
        let back = Plan::from_json(&p.to_json()).unwrap();
        for (a, b) in p.entries.iter().zip(&back.entries) {
            assert_eq!(
                a.predicted_latency_s.to_bits(),
                b.predicted_latency_s.to_bits()
            );
        }
    }

    #[test]
    fn policy_and_layout_fields_preserved() {
        let platform = CpuPlatform::large2();
        let lane_plan = LanePlan::guideline(&platform, &["transformer", "resnet50"])
            .unwrap()
            .with_policy(SchedPolicy::CostlyFirst);
        let p =
            Plan::from_lane_plan(&lane_plan, PlanTier::OnlineSnapshot, 0, &[8, 16], &[0.0, 0.0])
                .unwrap();
        let back = Plan::from_json(&p.to_json()).unwrap();
        assert!(back
            .entries
            .iter()
            .all(|e| e.config.sched_policy == SchedPolicy::CostlyFirst));
        let lp = back.lane_plan(&platform).unwrap();
        assert_eq!(lp.groups[1].allocation, lane_plan.groups[1].allocation);
    }
}
