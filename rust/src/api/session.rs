//! The [`Session`] — the one supported entry point to tuning, simulation
//! and serving.
//!
//! A session owns the pieces every workflow shares: the target
//! [`CpuPlatform`], the process-wide [`SimCache`] (so tuning tiers,
//! backend tables and the online re-tuner dedupe simulations against
//! each other), the sweep worker count (`--jobs`), and an optional
//! dispatch-policy pin. On top it exposes the paper's workflow as three
//! verbs:
//!
//! * **tune** — any tier ([`Session::tune`], [`Session::tune_exhaustive`],
//!   [`Session::tune_baseline`]) turns a [`Workload`] into a serializable
//!   [`Plan`];
//! * **simulate** — score one config on the session platform;
//! * **serve** — [`Session::serve`] deploys a `Plan` (from this process
//!   or a `plan.json` written by another) onto a core-aware coordinator,
//!   bit-identical to in-process tuning.

use std::sync::Arc;

use crate::config::{CpuPlatform, FrameworkConfig, OperatorImpl, SchedPolicy};
use crate::coordinator::{
    loadgen, Coordinator, CoordinatorConfig, LoadReport, LoadgenConfig, MixPhase, MixReport,
};
use crate::error::{PallasError, PallasResult};
use crate::graph::{analyze_width, WidthAnalysis};
use crate::models;
use crate::runtime::{BackendFactory, SimBackendConfig, SimBackendFactory};
use crate::sched::{split_cores, LaneGroup, LanePlan};
use crate::sim::{SimCache, SimReport};
use crate::tracestore::{ReplayPlan, TraceData, TraceRecorder};
use crate::tuner::{
    self, baseline_config, Baseline, OnlineTuner, OnlineTunerConfig, SweepOptions, SweepPool,
};

use super::plan::{Plan, PlanTier};
use super::workload::Workload;

/// One zoo model with its width analysis (the `models` listing).
#[derive(Debug, Clone)]
pub struct ModelInfo {
    /// Zoo name.
    pub name: String,
    /// Canonical serving batch.
    pub batch: usize,
    /// Operator count at that batch.
    pub ops: usize,
    /// Width analysis at that batch.
    pub width: WidthAnalysis,
}

/// The zoo catalog with width analyses — what `parframe models` prints.
pub fn model_catalog() -> Vec<ModelInfo> {
    models::model_names()
        .iter()
        .map(|name| {
            let batch = models::canonical_batch(name);
            let g = models::build(name, batch).expect("zoo name builds");
            ModelInfo { name: name.to_string(), batch, ops: g.len(), width: analyze_width(&g) }
        })
        .collect()
}

/// Builder for a [`Session`].
#[derive(Debug, Clone)]
pub struct SessionBuilder {
    platform: CpuPlatform,
    jobs: usize,
    policy: Option<SchedPolicy>,
    cache: Option<Arc<SimCache>>,
    prune: bool,
}

impl SessionBuilder {
    /// Target platform (default: `large.2`).
    pub fn platform(mut self, platform: CpuPlatform) -> Self {
        self.platform = platform;
        self
    }

    /// Target platform by preset name.
    pub fn platform_named(mut self, name: &str) -> PallasResult<Self> {
        self.platform = CpuPlatform::by_name(name)
            .ok_or_else(|| PallasError::UnknownPlatform(name.to_string()))?;
        Ok(self)
    }

    /// Sweep worker threads (default: host parallelism, capped — results
    /// are bit-identical at any value).
    pub fn jobs(mut self, jobs: usize) -> Self {
        self.jobs = jobs.max(1);
        self
    }

    /// Pin the dispatch-policy dimension (tuned thread knobs keep their
    /// per-slice values, so A/Bs isolate dispatch order).
    pub fn policy(mut self, policy: SchedPolicy) -> Self {
        self.policy = Some(policy);
        self
    }

    /// Pin the dispatch policy by CLI name.
    pub fn policy_named(mut self, name: &str) -> PallasResult<Self> {
        self.policy = Some(
            SchedPolicy::parse(name).ok_or_else(|| PallasError::UnknownPolicy(name.to_string()))?,
        );
        Ok(self)
    }

    /// Share an existing simulation memo-cache (sessions otherwise own a
    /// fresh one).
    pub fn cache(mut self, cache: Arc<SimCache>) -> Self {
        self.cache = Some(cache);
        self
    }

    /// Enable/disable branch-and-bound pruning in the exhaustive tier
    /// (the `tune --no-prune` escape hatch — results are bit-identical
    /// either way; off only to measure the flat sweep).
    pub fn prune(mut self, prune: bool) -> Self {
        self.prune = prune;
        self
    }

    /// Finish the builder.
    pub fn build(self) -> Session {
        Session {
            platform: self.platform,
            jobs: self.jobs,
            policy: self.policy,
            cache: self.cache.unwrap_or_else(|| Arc::new(SimCache::new())),
            sweep: Arc::new(SweepPool::new(self.jobs)),
            prune: self.prune,
        }
    }
}

/// The facade session: shared platform + sim cache + sweep options. See
/// the module docs for the tune → plan → serve workflow.
#[derive(Debug, Clone)]
pub struct Session {
    platform: CpuPlatform,
    jobs: usize,
    policy: Option<SchedPolicy>,
    cache: Arc<SimCache>,
    /// Persistent sweep executor shared by every tier this session
    /// drives (exhaustive searches, online re-plans): worker threads
    /// spawn lazily on the first parallel sweep and are reused after.
    sweep: Arc<SweepPool>,
    prune: bool,
}

impl Session {
    /// Start building a session (platform `large.2`, default jobs, no
    /// policy pin, fresh cache).
    pub fn builder() -> SessionBuilder {
        SessionBuilder {
            platform: CpuPlatform::large2(),
            jobs: tuner::default_jobs(),
            policy: None,
            cache: None,
            prune: true,
        }
    }

    /// Session on a platform with every other knob at its default.
    pub fn on(platform: CpuPlatform) -> Self {
        Self::builder().platform(platform).build()
    }

    /// The session's platform.
    pub fn platform(&self) -> &CpuPlatform {
        &self.platform
    }

    /// The session's sweep worker count.
    pub fn jobs(&self) -> usize {
        self.jobs
    }

    /// The session's dispatch-policy pin, if any.
    pub fn policy(&self) -> Option<SchedPolicy> {
        self.policy
    }

    /// The session-wide simulation memo-cache.
    pub fn cache(&self) -> &Arc<SimCache> {
        &self.cache
    }

    /// The session's persistent sweep executor (shared by clones —
    /// `ServeHandle`s hand it to the online re-tuner, so re-plans reuse
    /// the tuning sweep's worker threads).
    pub fn sweep_pool(&self) -> &Arc<SweepPool> {
        &self.sweep
    }

    /// The exhaustive tier's design lattice for the session platform —
    /// memoized per platform *shape* for the life of the process, so
    /// repeated searches (and every online re-plan) share one `Arc`'d
    /// Vec instead of re-enumerating and re-deduplicating it.
    pub fn lattice(&self) -> Arc<Vec<FrameworkConfig>> {
        tuner::lattice(&self.platform)
    }

    // -- tuning tiers -----------------------------------------------------

    /// Tune a workload with the paper's §8 guideline (closed-form; one
    /// simulation per kind for the predicted latency). The session's
    /// policy pin overrides the dispatch dimension only.
    pub fn tune(&self, workload: &Workload) -> PallasResult<Plan> {
        let pin = self.policy;
        let (groups, batches) = self.grouped_configs(workload, |graph, slice| {
            let mut config = tuner::tune(graph, slice).config;
            if let Some(p) = pin {
                config.sched_policy = p;
            }
            Ok((config, 1))
        })?;
        self.make_plan(PlanTier::Guidelines, groups, &batches)
    }

    /// Tune a workload by exhaustively sweeping the feasible design
    /// lattice on each kind's core slice (the global-optimum tier;
    /// `evaluated` counts unique simulated points across kinds). A
    /// session policy pin *constrains the sweep* to that policy's
    /// sub-lattice, so the result is the true optimum under the pin.
    pub fn tune_exhaustive(&self, workload: &Workload) -> PallasResult<Plan> {
        let opts = SweepOptions::shared(self.jobs, Arc::clone(&self.cache))
            .pinned(self.policy)
            .on_pool(Arc::clone(&self.sweep))
            .prune(self.prune);
        let (groups, batches) = self.grouped_configs(workload, |graph, slice| {
            let r = tuner::exhaustive_search_with(graph, slice, &opts)?;
            Ok((r.best, r.evaluated))
        })?;
        self.make_plan(PlanTier::Exhaustive, groups, &batches)
    }

    /// Materialise a published baseline recommendation as a plan (the
    /// comparison bar of Fig. 18). The session's policy pin overrides
    /// the dispatch dimension only.
    pub fn tune_baseline(&self, workload: &Workload, baseline: Baseline) -> PallasResult<Plan> {
        let pin = self.policy;
        let (groups, batches) = self.grouped_configs(workload, |_, slice| {
            let mut config = baseline_config(baseline, slice);
            if let Some(p) = pin {
                config.sched_policy = p;
            }
            Ok((config, 1))
        })?;
        self.make_plan(PlanTier::Baseline(baseline), groups, &batches)
    }

    /// Snapshot a running core-aware serving handle's live plan as a
    /// deployable artifact (the online re-tuner's decisions survive the
    /// process). Batches come from the plan the handle was deployed
    /// with, so a batch-overridden tuning keeps its provenance; kinds
    /// the original plan never named fall back to their canonical batch.
    pub fn snapshot(&self, handle: &ServeHandle) -> PallasResult<Plan> {
        let lane_plan = handle.coordinator().current_plan().ok_or_else(|| {
            PallasError::InvalidPlan("snapshot: no core-aware plan is active".into())
        })?;
        let batches: Vec<usize> = lane_plan
            .groups
            .iter()
            .map(|g| {
                let kind = &g.kinds[0];
                handle
                    .tuned_batches
                    .get(kind)
                    .copied()
                    .unwrap_or_else(|| models::canonical_batch(kind))
            })
            .collect();
        self.plan_from_lane_plan(&lane_plan, PlanTier::OnlineSnapshot, 0, &batches)
    }

    // -- simulation -------------------------------------------------------

    /// Simulate one model/batch under a config on the session platform
    /// (memoized through the session cache).
    pub fn simulate(
        &self,
        model: &str,
        batch: usize,
        config: &FrameworkConfig,
    ) -> PallasResult<Arc<SimReport>> {
        config.validate(&self.platform)?;
        let prep = self
            .cache
            .prepared(model, batch)
            .ok_or_else(|| PallasError::UnknownModel(model.to_string()))?;
        self.cache.report(&prep, &self.platform, config)
    }

    /// A manually-knobbed config the way `simulate --pools/--mkl/--intra`
    /// builds one: unspecified MKL threads default to a fair share of the
    /// physical cores, intra-op follows MKL, and the session's policy pin
    /// (default topo) sets dispatch order.
    pub fn manual_config(
        &self,
        pools: Option<usize>,
        mkl: Option<usize>,
        intra: Option<usize>,
    ) -> PallasResult<FrameworkConfig> {
        let mut cfg = FrameworkConfig::tuned_default();
        cfg.operator_impl = OperatorImpl::IntraOpParallel;
        if let Some(p) = pools {
            cfg.inter_op_pools = p;
        }
        cfg.mkl_threads = mkl.unwrap_or_else(|| {
            (self.platform.physical_cores() / cfg.inter_op_pools.max(1)).max(1)
        });
        cfg.intra_op_threads = intra.unwrap_or(cfg.mkl_threads);
        if let Some(p) = self.policy {
            cfg.sched_policy = p;
        }
        cfg.validate(&self.platform)?;
        Ok(cfg)
    }

    // -- serving ----------------------------------------------------------

    /// Deploy a plan: verify its platform + sim fingerprint, reconstruct
    /// the lane plan, and start a core-aware coordinator whose backend
    /// tables are built from the plan's exact configs (through the
    /// session cache). Works identically for a plan tuned in-process and
    /// one loaded from `plan.json`.
    pub fn serve(&self, plan: &Plan) -> PallasResult<ServeHandle> {
        self.serve_with(plan, None)
    }

    /// [`Session::serve`] with an optional trace recorder attached to
    /// the coordinator (the `serve --record` path): lanes emit one
    /// trace event per request, and [`ServeHandle::drain_trace`]
    /// collects them as a saveable [`TraceData`].
    pub fn serve_with(
        &self,
        plan: &Plan,
        recorder: Option<Arc<TraceRecorder>>,
    ) -> PallasResult<ServeHandle> {
        // platform-name check first (PlanMismatch beats a confusing
        // fingerprint error when the whole machine is wrong)
        let lane_plan = plan.lane_plan(&self.platform)?;
        plan.verify_fingerprint(&self.platform)?;
        let kinds = plan.kinds();
        let mut sc = SimBackendConfig::new(self.platform.clone(), &kinds);
        sc.jobs = self.jobs;
        let factory = Arc::new(SimBackendFactory::with_cache(sc, Arc::clone(&self.cache)));
        let dyn_factory: Arc<dyn BackendFactory> = Arc::clone(&factory);
        let mut cfg = CoordinatorConfig::with_factory(dyn_factory).with_plan(lane_plan);
        cfg.recorder = recorder;
        let coord = Coordinator::start(cfg)?;
        Ok(ServeHandle {
            coord,
            factory,
            session: self.clone(),
            tuned_batches: plan.entries.iter().map(|e| (e.kind.clone(), e.batch)).collect(),
        })
    }

    /// Serve a workload on the §8-guideline plan directly (tune + serve
    /// in one step — the `serve --kinds a,b` path).
    pub fn serve_guideline(&self, workload: &Workload) -> PallasResult<ServeHandle> {
        let plan = self.tune(workload)?;
        self.serve(&plan)
    }

    /// Serve kinds on `lanes` identical whole-machine lanes with
    /// per-bucket tuned tables (the single-kind `serve --kind` path; no
    /// core-aware plan).
    pub fn serve_unplanned(&self, kinds: &[&str], lanes: usize) -> PallasResult<ServeHandle> {
        self.serve_unplanned_with(kinds, lanes, None)
    }

    /// [`Session::serve_unplanned`] with an optional trace recorder (the
    /// single-kind `serve --kind ... --record` path).
    pub fn serve_unplanned_with(
        &self,
        kinds: &[&str],
        lanes: usize,
        recorder: Option<Arc<TraceRecorder>>,
    ) -> PallasResult<ServeHandle> {
        let mut sc = SimBackendConfig::new(self.platform.clone(), kinds);
        sc.jobs = self.jobs;
        sc.policy = self.policy;
        let factory = Arc::new(SimBackendFactory::with_cache(sc, Arc::clone(&self.cache)));
        let dyn_factory: Arc<dyn BackendFactory> = Arc::clone(&factory);
        let mut cfg = CoordinatorConfig::with_factory(dyn_factory);
        cfg.lanes = lanes.max(1);
        cfg.recorder = recorder;
        let coord = Coordinator::start(cfg)?;
        Ok(ServeHandle {
            coord,
            factory,
            session: self.clone(),
            tuned_batches: std::collections::HashMap::new(),
        })
    }

    /// Score a plan against a recorded trace without serving: the
    /// trace-weighted mean of the plan's per-kind simulated latencies at
    /// each kind's recorded mode bucket, on each entry's core slice.
    /// Fully simulator-backed, so the score is bit-identical across runs
    /// and `--jobs` values — this is what `parframe trace ab` ranks two
    /// plans by, and the scoring view behind `tune --trace`.
    pub fn score_plan_on_trace(&self, plan: &Plan, trace: &TraceData) -> PallasResult<f64> {
        // platform + fingerprint gate, same as deploying the plan
        plan.lane_plan(&self.platform)?;
        let counts = trace.per_kind_counts();
        if counts.is_empty() {
            return Err(PallasError::InvalidConfig("trace has no events to score".into()));
        }
        let mut weighted = 0.0f64;
        let mut total = 0usize;
        for (id, count) in counts {
            let name = trace.kind_name(id);
            let entry = plan.entries.iter().find(|e| e.kind == name).ok_or_else(|| {
                PallasError::InvalidPlan(format!(
                    "plan has no entry for traced kind '{name}'"
                ))
            })?;
            let batch = trace
                .mode_bucket(id)
                .filter(|&b| b >= 1)
                .map(|b| b as usize)
                .unwrap_or(entry.batch);
            let prep = self
                .cache
                .prepared(&name, batch)
                .ok_or_else(|| PallasError::UnknownModel(name.clone()))?;
            let slice = self.platform.restrict(entry.first_core, entry.cores);
            weighted += count as f64 * self.cache.latency(&prep, &slice, &entry.config)?;
            total += count;
        }
        Ok(weighted / total as f64)
    }

    // -- internals --------------------------------------------------------

    /// Split cores by workload weights and pick each group's config via
    /// `pick(graph_at_entry_batch, slice) -> (config, evaluated_points)`.
    /// Policy pinning is the tier's (closure's) responsibility: the
    /// exhaustive tier constrains its sweep, the closed-form tiers
    /// override the dispatch knob.
    fn grouped_configs<F>(
        &self,
        workload: &Workload,
        mut pick: F,
    ) -> PallasResult<(Vec<(LaneGroup, usize)>, Vec<usize>)>
    where
        F: FnMut(&crate::graph::Graph, &CpuPlatform) -> PallasResult<(FrameworkConfig, usize)>,
    {
        let weights: Vec<f64> = workload.entries.iter().map(|e| e.weight).collect();
        let allocs = split_cores(&self.platform, &weights)?;
        let mut groups = Vec::with_capacity(workload.entries.len());
        let mut batches = Vec::with_capacity(workload.entries.len());
        for (entry, alloc) in workload.entries.iter().zip(allocs) {
            let slice = self.platform.restrict(alloc.first_core, alloc.cores);
            // the session's prepared-graph memo: repeated tune calls (and
            // the predicted-latency pass) share one graph build per kind
            let prep = self
                .cache
                .prepared(&entry.kind, entry.batch)
                .ok_or_else(|| PallasError::UnknownModel(entry.kind.clone()))?;
            let (config, evaluated) = pick(prep.graph(), &slice)?;
            groups.push((
                LaneGroup {
                    kinds: vec![entry.kind.clone()],
                    allocation: alloc,
                    lanes: 1,
                    framework: config,
                },
                evaluated,
            ));
            batches.push(entry.batch);
        }
        Ok((groups, batches))
    }

    fn make_plan(
        &self,
        tier: PlanTier,
        groups: Vec<(LaneGroup, usize)>,
        batches: &[usize],
    ) -> PallasResult<Plan> {
        let evaluated: usize = groups.iter().map(|(_, e)| *e).sum();
        let lane_plan = LanePlan {
            platform: self.platform.clone(),
            groups: groups.into_iter().map(|(g, _)| g).collect(),
        };
        lane_plan.validate()?;
        self.plan_from_lane_plan(&lane_plan, tier, evaluated, batches)
    }

    /// Predicted latencies + artifact assembly for a validated lane plan.
    fn plan_from_lane_plan(
        &self,
        lane_plan: &LanePlan,
        tier: PlanTier,
        evaluated: usize,
        batches: &[usize],
    ) -> PallasResult<Plan> {
        let mut predicted = Vec::with_capacity(lane_plan.groups.len());
        for (g, &batch) in lane_plan.groups.iter().zip(batches) {
            let kind = &g.kinds[0];
            let prep = self
                .cache
                .prepared(kind, batch)
                .ok_or_else(|| PallasError::UnknownModel(kind.clone()))?;
            let slice =
                self.platform.restrict(g.allocation.first_core, g.allocation.cores);
            predicted.push(self.cache.latency(&prep, &slice, &g.framework)?);
        }
        Plan::from_lane_plan(lane_plan, tier, evaluated, batches, &predicted)
    }
}

/// A running serving deployment minted by [`Session::serve`] (or the
/// unplanned variant): the coordinator plus the concrete sim-backend
/// factory, so callers can read the *served* latency tables and drive
/// load through the facade.
pub struct ServeHandle {
    coord: Coordinator,
    factory: Arc<SimBackendFactory>,
    /// The session that minted this handle (shares its cache/jobs/
    /// platform with every other deployment it mints).
    session: Session,
    /// kind → tuned batch of the deployed plan (empty for unplanned
    /// handles); keeps snapshot provenance honest under batch overrides.
    tuned_batches: std::collections::HashMap<String, usize>,
}

impl ServeHandle {
    /// The underlying coordinator (submit/await, metrics, live plan).
    pub fn coordinator(&self) -> &Coordinator {
        &self.coord
    }

    /// Closed-loop load: `requests` total over `concurrency` workers.
    pub fn run_closed(
        &self,
        kind: &str,
        requests: usize,
        concurrency: usize,
    ) -> PallasResult<LoadReport> {
        Ok(loadgen::run(&self.coord, &LoadgenConfig::closed(kind, requests, concurrency))?)
    }

    /// Open-loop load: `requests` Poisson arrivals at `rate_rps` from a
    /// single submitter (offered load is fixed, latency is measured).
    pub fn run_open(&self, kind: &str, requests: usize, rate_rps: f64) -> PallasResult<LoadReport> {
        Ok(loadgen::run(&self.coord, &LoadgenConfig::open(kind, requests, rate_rps))?)
    }

    /// Re-issue a recorded trace's exact arrival process against this
    /// deployment ([`crate::coordinator::Scenario::Replay`]).
    pub fn run_replay(&self, plan: &ReplayPlan) -> PallasResult<LoadReport> {
        Ok(loadgen::run_replay(&self.coord, plan)?)
    }

    /// The trace recorder attached at deployment, if recording is on.
    pub fn recorder(&self) -> Option<Arc<TraceRecorder>> {
        self.coord.recorder()
    }

    /// Drain the attached recorder into a saveable [`TraceData`] whose
    /// kind table is the coordinator's interned id→name slice. Errors if
    /// the deployment was started without a recorder.
    pub fn drain_trace(&self) -> PallasResult<TraceData> {
        let recorder = self.coord.recorder().ok_or_else(|| {
            PallasError::InvalidConfig(
                "no trace recorder attached (deploy with serve_with/--record)".into(),
            )
        })?;
        Ok(TraceData::new(self.coord.router().id_names().to_vec(), recorder.drain()))
    }

    /// Drive a multi-phase shifting mix; with `adaptive` the online
    /// re-tuner (sharing the session cache and jobs) re-plans between
    /// phases with default controller knobs.
    pub fn run_shift(
        &self,
        phases: &[MixPhase],
        concurrency: usize,
        seed: u64,
        adaptive: bool,
    ) -> PallasResult<Vec<MixReport>> {
        let cfg =
            adaptive.then(|| OnlineTunerConfig { jobs: self.session.jobs, ..Default::default() });
        self.run_shift_with(phases, concurrency, seed, cfg)
    }

    /// [`ServeHandle::run_shift`] with explicit online-tuner knobs:
    /// `Some(cfg)` re-tunes between phases with that controller config
    /// (smoothing, hysteresis, ...); `None` keeps the deployed plan
    /// frozen. The tuner always shares the session cache.
    pub fn run_shift_with(
        &self,
        phases: &[MixPhase],
        concurrency: usize,
        seed: u64,
        tuner_cfg: Option<OnlineTunerConfig>,
    ) -> PallasResult<Vec<MixReport>> {
        let kinds: Vec<String> =
            self.coord.router().kinds().iter().map(|k| k.to_string()).collect();
        let kind_refs: Vec<&str> = kinds.iter().map(String::as_str).collect();
        let mut tuner = tuner_cfg.map(|cfg| {
            OnlineTuner::with_config(self.session.platform.clone(), &kind_refs, cfg)
                .with_cache(Arc::clone(&self.session.cache))
                .with_pool(Arc::clone(&self.session.sweep))
        });
        Ok(loadgen::run_shift(&self.coord, phases, concurrency, seed, tuner.as_mut())?)
    }

    /// The latency tables this deployment serves from, as
    /// `((kind, bucket), seconds)` rows sorted by kind then bucket —
    /// read from the same `Arc`'d tables the worker lanes execute
    /// against, so two deployments are behaviourally identical iff these
    /// rows are bit-identical.
    pub fn latency_table(&self) -> PallasResult<Vec<((String, usize), f64)>> {
        match self.coord.current_plan() {
            Some(plan) => {
                let mut rows = std::collections::BTreeMap::new();
                for a in plan.lane_assignments() {
                    for (key, lat) in self.factory.latency_table(Some(&a))? {
                        rows.entry(key).or_insert(lat);
                    }
                }
                Ok(rows.into_iter().collect())
            }
            None => self.factory.latency_table(None),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_catalog_covers_zoo() {
        let cat = model_catalog();
        assert_eq!(cat.len(), models::model_names().len());
        let wd = cat.iter().find(|m| m.name == "wide_deep").unwrap();
        assert!(wd.ops > 0 && wd.width.avg_width >= 3);
    }

    #[test]
    fn guideline_plan_matches_tuner_on_whole_machine() {
        // single-kind workload: the facade's guideline tier must agree
        // with calling the tuner directly
        let session = Session::on(CpuPlatform::large2());
        let w = Workload::single("wide_deep").unwrap();
        let plan = session.tune(&w).unwrap();
        assert_eq!(plan.tier, PlanTier::Guidelines);
        assert_eq!(plan.entries.len(), 1);
        let e = &plan.entries[0];
        assert_eq!((e.first_core, e.cores), (0, 48));
        let g = models::build("wide_deep", e.batch).unwrap();
        let direct = tuner::tune(&g, &CpuPlatform::large2()).config;
        assert_eq!(e.config.inter_op_pools, direct.inter_op_pools);
        assert_eq!(e.config.mkl_threads, direct.mkl_threads);
        assert!(e.predicted_latency_s > 0.0);
    }

    #[test]
    fn policy_pin_only_touches_dispatch_dimension() {
        let pinned = Session::builder()
            .platform(CpuPlatform::large2())
            .policy(SchedPolicy::CostlyFirst)
            .build();
        let free = Session::on(CpuPlatform::large2());
        let w = Workload::single("transformer").unwrap();
        let a = pinned.tune(&w).unwrap();
        let b = free.tune(&w).unwrap();
        assert_eq!(a.entries[0].config.sched_policy, SchedPolicy::CostlyFirst);
        assert_eq!(a.entries[0].config.inter_op_pools, b.entries[0].config.inter_op_pools);
        assert_eq!(a.entries[0].config.mkl_threads, b.entries[0].config.mkl_threads);
    }

    #[test]
    fn baseline_and_exhaustive_tiers_carry_provenance() {
        let session = Session::on(CpuPlatform::small());
        let w = Workload::single("wide_deep").unwrap();
        let base = session.tune_baseline(&w, Baseline::IntelRecommended).unwrap();
        assert_eq!(base.tier, PlanTier::Baseline(Baseline::IntelRecommended));
        let opt = session.tune_exhaustive(&w).unwrap();
        assert_eq!(opt.tier, PlanTier::Exhaustive);
        assert!(opt.evaluated > 10, "evaluated={}", opt.evaluated);
        // the optimum cannot lose to the baseline it subsumes
        assert!(opt.entries[0].predicted_latency_s <= base.entries[0].predicted_latency_s);
    }

    #[test]
    fn exhaustive_tier_honours_policy_pin_as_a_constraint() {
        // the pin restricts the sweep itself: the winner is a real
        // lattice point of the pinned sub-lattice, and the pinned sweep
        // evaluates strictly fewer points than the free one
        let w = Workload::single("inception_v2").unwrap();
        let free = Session::on(CpuPlatform::small()).tune_exhaustive(&w).unwrap();
        let pinned = Session::builder()
            .platform(CpuPlatform::small())
            .policy(SchedPolicy::Topo)
            .build()
            .tune_exhaustive(&w)
            .unwrap();
        assert!(pinned.evaluated < free.evaluated);
        let c = &pinned.entries[0].config;
        assert!(c.inter_op_pools == 1 || c.sched_policy == SchedPolicy::Topo);
        assert!(
            pinned.entries[0].predicted_latency_s >= free.entries[0].predicted_latency_s
        );
    }

    #[test]
    fn session_lattice_is_memoized_and_sweeps_share_one_pool() {
        let session = Session::on(CpuPlatform::small());
        // two calls return the same Vec allocation — no recomputation
        assert!(Arc::ptr_eq(&session.lattice(), &session.lattice()));
        let w = Workload::single("wide_deep").unwrap();
        let a = session.tune_exhaustive(&w).unwrap();
        let b = session.tune_exhaustive(&w).unwrap();
        assert_eq!(a.entries[0].config, b.entries[0].config);
        assert!(session.sweep_pool().spawn_count() <= 1, "a pool was spawned per sweep");
    }

    #[test]
    fn no_prune_session_matches_pruned() {
        let w = Workload::single("inception_v2").unwrap();
        let pruned = Session::on(CpuPlatform::small()).tune_exhaustive(&w).unwrap();
        let flat = Session::builder()
            .platform(CpuPlatform::small())
            .prune(false)
            .build()
            .tune_exhaustive(&w)
            .unwrap();
        assert_eq!(pruned.entries[0].config, flat.entries[0].config);
        assert_eq!(
            pruned.entries[0].predicted_latency_s.to_bits(),
            flat.entries[0].predicted_latency_s.to_bits()
        );
        assert_eq!(pruned.evaluated, flat.evaluated);
    }

    #[test]
    fn manual_config_defaults_mirror_simulate_cmd() {
        let session = Session::on(CpuPlatform::large());
        let cfg = session.manual_config(Some(2), None, None).unwrap();
        assert_eq!(cfg.mkl_threads, 12); // 24 physical / 2 pools
        assert_eq!(cfg.intra_op_threads, 12);
        assert!(session.manual_config(Some(0), None, None).is_err());
    }

    #[test]
    fn serve_rejects_mismatched_platform() {
        let tuned = Session::on(CpuPlatform::large2());
        let plan = tuned.tune(&Workload::single("wide_deep").unwrap()).unwrap();
        let other = Session::on(CpuPlatform::small());
        assert!(matches!(
            other.serve(&plan),
            Err(PallasError::PlanMismatch { .. })
        ));
    }
}
