//! Operators: the building blocks of the computational graph.
//!
//! Each operator carries a [`cost::OpCost`] descriptor — FLOPs, bytes moved,
//! and the framework-native data-preparation work that the paper's §5
//! identifies as the "programmability tax". The simulator consumes these
//! descriptors; it never executes real tensors (real numerics go through
//! [`crate::runtime`]).

pub mod cost;
pub mod kind;

pub use cost::OpCost;
pub use kind::OpKind;

/// FLOPs threshold above which an operator counts as *heavy* for the
/// paper's width analysis (§8: "a heavy operator is a compute-intensive or
/// embedding operator"). Embeddings are always heavy regardless of FLOPs
/// (they are bandwidth-bound, not FLOP-bound).
pub const HEAVY_FLOPS_THRESHOLD: f64 = 50.0e6;
