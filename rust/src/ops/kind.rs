//! Operator kinds.

/// The operator vocabulary of the model zoo.
///
/// Shapes use the paper's conventions: activations are
/// `[batch × features]`, convolutions are described by their im2col-GEMM
/// equivalent (the paper notes Caffe2/TF convert Conv to MatMul via
/// `im2col()`, §4.2).
#[derive(Debug, Clone, PartialEq)]
pub enum OpKind {
    /// Dense GEMM: `[m,k] @ [k,n]`.
    MatMul { m: usize, k: usize, n: usize },
    /// Convolution, described by its im2col GEMM: output pixels ×
    /// (kernel window) × output channels.
    Conv {
        batch: usize,
        out_h: usize,
        out_w: usize,
        in_c: usize,
        out_c: usize,
        k_h: usize,
        k_w: usize,
    },
    /// Embedding-table gather: `rows` lookups of `dim` floats from a table
    /// of `vocab` rows. Bandwidth-bound; always a heavy op for width
    /// analysis (paper §8).
    Embedding { vocab: usize, dim: usize, rows: usize },
    /// Elementwise math (ReLU, add, batchnorm apply, ...) over `elems`.
    Elementwise { elems: usize, name: &'static str },
    /// Tensor concat/reshape/transpose-class data movement.
    DataMovement { bytes: usize, name: &'static str },
    /// Pooling windows (cheap, bandwidth-ish).
    Pool { elems: usize },
    /// Softmax over `rows × cols`.
    Softmax { rows: usize, cols: usize },
    /// Backward gradient of a heavy op (training graphs, paper §4.1):
    /// roughly 2× the forward FLOPs.
    Gradient { fwd_flops: f64, fwd_bytes: f64 },
    /// Weight-sum / optimizer-apply over `params` parameters (training).
    WeightSum { params: usize },
}

impl OpKind {
    /// Short display name.
    pub fn name(&self) -> &'static str {
        match self {
            OpKind::MatMul { .. } => "MatMul",
            OpKind::Conv { .. } => "Conv",
            OpKind::Embedding { .. } => "Embedding",
            OpKind::Elementwise { name, .. } => name,
            OpKind::DataMovement { name, .. } => name,
            OpKind::Pool { .. } => "Pool",
            OpKind::Softmax { .. } => "Softmax",
            OpKind::Gradient { .. } => "Gradient",
            OpKind::WeightSum { .. } => "WeightSum",
        }
    }

    /// True for kinds the scheduler treats as library-kernel work
    /// (dispatched to MKL/MKL-DNN/Eigen); false for framework-native ops.
    pub fn uses_library_kernel(&self) -> bool {
        matches!(
            self,
            OpKind::MatMul { .. }
                | OpKind::Conv { .. }
                | OpKind::Gradient { .. }
                | OpKind::Embedding { .. }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names() {
        assert_eq!(OpKind::MatMul { m: 1, k: 1, n: 1 }.name(), "MatMul");
        assert_eq!(
            OpKind::Elementwise { elems: 10, name: "ReLU" }.name(),
            "ReLU"
        );
    }

    #[test]
    fn library_kernel_classification() {
        assert!(OpKind::MatMul { m: 8, k: 8, n: 8 }.uses_library_kernel());
        assert!(!OpKind::Pool { elems: 100 }.uses_library_kernel());
        assert!(!OpKind::DataMovement { bytes: 4, name: "Concat" }.uses_library_kernel());
    }
}
