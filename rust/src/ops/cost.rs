//! Operator cost descriptors.
//!
//! The key modelling decision (from the paper's §5.1): a GEMM of size
//! `n×n×n` does `O(n³)` FLOPs but its framework-native preparation work is
//! `O(n)`–`O(n²)` *bytes* — an Amdahl serial term that dominates once the
//! kernel is spread over 24 cores. `prep_bytes` carries that term; the
//! simulator turns it into serial (MatMul1) or intra-op-parallel (MatMul2)
//! time.

use super::kind::OpKind;
use super::HEAVY_FLOPS_THRESHOLD;

/// Cost descriptor attached to every graph node.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OpCost {
    /// Floating-point operations of the kernel body.
    pub flops: f64,
    /// Bytes read by the kernel (inputs + weights).
    pub input_bytes: f64,
    /// Bytes written (outputs).
    pub output_bytes: f64,
    /// Framework-native data-preparation bytes touched before the kernel
    /// runs (tensor validation, layout conversion, im2col staging, argument
    /// marshalling). The paper's "TF data preparation".
    pub prep_bytes: f64,
    /// Library-internal data-preparation bytes (packing/format conversion
    /// inside MKL — the serial term of Fig. 10's "MKL data prep").
    pub lib_prep_bytes: f64,
}

impl OpCost {
    /// Zero-cost descriptor (control-flow nodes).
    pub fn zero() -> Self {
        OpCost { flops: 0.0, input_bytes: 0.0, output_bytes: 0.0, prep_bytes: 0.0, lib_prep_bytes: 0.0 }
    }

    /// Derive the descriptor for an operator kind.
    pub fn of(kind: &OpKind) -> Self {
        const F: f64 = 4.0; // f32 bytes
        match *kind {
            OpKind::MatMul { m, k, n } => {
                let flops = 2.0 * m as f64 * k as f64 * n as f64;
                let in_b = F * (m as f64 * k as f64 + k as f64 * n as f64);
                let out_b = F * m as f64 * n as f64;
                OpCost {
                    flops,
                    input_bytes: in_b,
                    output_bytes: out_b,
                    // marshalling + validation touches the activation matrix
                    prep_bytes: F * m as f64 * k as f64,
                    // kernel packs both operands into its blocked format
                    lib_prep_bytes: 0.5 * (in_b + out_b),
                }
            }
            OpKind::Conv { batch, out_h, out_w, in_c, out_c, k_h, k_w } => {
                // im2col GEMM: [batch*oh*ow, ic*kh*kw] @ [ic*kh*kw, oc]
                let m = (batch * out_h * out_w) as f64;
                let k = (in_c * k_h * k_w) as f64;
                let n = out_c as f64;
                let flops = 2.0 * m * k * n;
                let in_b = F * (m * k + k * n);
                OpCost {
                    flops,
                    input_bytes: in_b,
                    output_bytes: F * m * n,
                    // im2col materialisation is the framework prep
                    prep_bytes: F * m * k,
                    lib_prep_bytes: 0.25 * in_b,
                }
            }
            OpKind::Embedding { dim, rows, .. } => {
                let bytes = F * (rows * dim) as f64;
                OpCost {
                    // a gather does no real FLOPs; count one op/element
                    flops: (rows * dim) as f64,
                    input_bytes: bytes,
                    output_bytes: bytes,
                    prep_bytes: F * rows as f64 * 8.0, // index marshalling
                    lib_prep_bytes: 0.0,
                }
            }
            OpKind::Elementwise { elems, .. } => OpCost {
                flops: elems as f64,
                input_bytes: F * elems as f64,
                output_bytes: F * elems as f64,
                prep_bytes: F * 16.0,
                lib_prep_bytes: 0.0,
            },
            OpKind::DataMovement { bytes, .. } => OpCost {
                flops: 0.0,
                input_bytes: bytes as f64,
                output_bytes: bytes as f64,
                prep_bytes: bytes as f64,
                lib_prep_bytes: 0.0,
            },
            OpKind::Pool { elems } => OpCost {
                flops: elems as f64,
                input_bytes: F * elems as f64,
                output_bytes: F * elems as f64 / 4.0,
                prep_bytes: F * 16.0,
                lib_prep_bytes: 0.0,
            },
            OpKind::Softmax { rows, cols } => {
                let e = (rows * cols) as f64;
                OpCost {
                    flops: 5.0 * e,
                    input_bytes: F * e,
                    output_bytes: F * e,
                    prep_bytes: F * 16.0,
                    lib_prep_bytes: 0.0,
                }
            }
            OpKind::Gradient { fwd_flops, fwd_bytes } => OpCost {
                flops: 2.0 * fwd_flops,
                input_bytes: 2.0 * fwd_bytes,
                output_bytes: fwd_bytes,
                prep_bytes: 0.5 * fwd_bytes,
                lib_prep_bytes: 0.5 * fwd_bytes,
            },
            OpKind::WeightSum { params } => OpCost {
                flops: 2.0 * params as f64,
                input_bytes: 2.0 * F * params as f64,
                output_bytes: F * params as f64,
                prep_bytes: F * 64.0,
                lib_prep_bytes: 0.0,
            },
        }
    }

    /// Heavy-operator classification for the width analysis (paper §8):
    /// compute-intensive (FLOPs over threshold) or an embedding.
    pub fn is_heavy(kind: &OpKind) -> bool {
        match kind {
            OpKind::Embedding { .. } => true,
            // optimizer-update ops sit on the training step's critical path
            // and are what the paper schedules in parallel with gradients
            OpKind::WeightSum { .. } => true,
            _ => Self::of(kind).flops >= HEAVY_FLOPS_THRESHOLD,
        }
    }

    /// Total bytes moved through memory by the kernel.
    pub fn total_bytes(&self) -> f64 {
        self.input_bytes + self.output_bytes
    }

    /// Arithmetic intensity (FLOPs per byte) — used by the roofline check.
    pub fn intensity(&self) -> f64 {
        if self.total_bytes() == 0.0 {
            0.0
        } else {
            self.flops / self.total_bytes()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_flops_cubic() {
        let c = OpCost::of(&OpKind::MatMul { m: 512, k: 512, n: 512 });
        assert_eq!(c.flops, 2.0 * 512f64.powi(3));
        // prep is O(n²) while flops are O(n³): the Amdahl term shrinks
        let c4k = OpCost::of(&OpKind::MatMul { m: 4096, k: 4096, n: 4096 });
        assert!(c4k.prep_bytes / c4k.flops < c.prep_bytes / c.flops);
    }

    #[test]
    fn conv_equals_im2col_gemm() {
        let conv = OpCost::of(&OpKind::Conv {
            batch: 16, out_h: 56, out_w: 56, in_c: 64, out_c: 64, k_h: 3, k_w: 3,
        });
        let gemm = OpCost::of(&OpKind::MatMul { m: 16 * 56 * 56, k: 64 * 9, n: 64 });
        assert_eq!(conv.flops, gemm.flops);
    }

    #[test]
    fn embedding_always_heavy() {
        let small_emb = OpKind::Embedding { vocab: 1000, dim: 16, rows: 4 };
        assert!(OpCost::is_heavy(&small_emb));
        assert!(OpCost::of(&small_emb).flops < HEAVY_FLOPS_THRESHOLD);
    }

    #[test]
    fn light_ops_not_heavy() {
        assert!(!OpCost::is_heavy(&OpKind::Elementwise { elems: 100, name: "ReLU" }));
        assert!(!OpCost::is_heavy(&OpKind::MatMul { m: 16, k: 256, n: 256 }));
    }

    #[test]
    fn big_matmul_heavy() {
        assert!(OpCost::is_heavy(&OpKind::MatMul { m: 512, k: 512, n: 512 }));
    }

    #[test]
    fn gradient_doubles_forward() {
        let g = OpCost::of(&OpKind::Gradient { fwd_flops: 1e9, fwd_bytes: 1e6 });
        assert_eq!(g.flops, 2e9);
    }

    #[test]
    fn intensity_positive_for_matmul() {
        let c = OpCost::of(&OpKind::MatMul { m: 128, k: 128, n: 128 });
        assert!(c.intensity() > 1.0);
    }
}
