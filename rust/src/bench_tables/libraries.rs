//! Figures 13–14 — library-choice experiments (paper §6).

use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::config::{CpuPlatform, MathLib, PoolLib};
use crate::libs::math::MathModel;
use crate::libs::threadpool::{make_pool, scatter_gather, Task, TaskPool, WaitGroup};
use crate::sim::constants::{pool_dispatch_overhead, pool_oversubscription_factor};

/// Fig. 13: single-thread GEMM top-down comparison of MKL / MKL-DNN /
/// Eigen — cycle breakdown + IPC, LLC MPKI, and memory-traffic split.
pub fn fig13_library_comparison() -> String {
    let p = CpuPlatform::small();
    let sizes = [256.0, 1024.0, 4096.0, 8192.0, 16384.0];
    let mut out = String::from("Fig 13 — GEMM library comparison (small, 1 thread)\n");
    let _ = writeln!(
        out,
        "{:<7} {:<8} {:>6} {:>6} {:>7} {:>7} {:>6} | {:>6} | {:>9} {:>9}",
        "size", "lib", "retire", "fe", "badspec", "backend", "ipc", "mpki", "prefetch", "demand"
    );
    for n in sizes {
        for lib in MathLib::ALL {
            let m = MathModel::new(lib);
            let td = m.topdown(n, &p);
            let mpki = m.llc_mpki(n, &p);
            let t = m.mem_traffic(n, &p);
            let _ = writeln!(
                out,
                "{:<7} {:<8} {:>5.0}% {:>5.0}% {:>6.0}% {:>6.0}% {:>6.2} | {:>6.2} | {:>8.2}GB {:>8.2}GB",
                n,
                lib.name(),
                td.retiring * 100.0,
                td.frontend * 100.0,
                td.bad_speculation * 100.0,
                (td.backend_core + td.backend_memory) * 100.0,
                td.ipc,
                mpki,
                t.prefetch_gb,
                t.demand_gb,
            );
        }
    }
    out
}

fn count_tasks(counter: &Arc<AtomicU64>, n: usize) -> Vec<Task> {
    (0..n)
        .map(|_| {
            let c = Arc::clone(counter);
            Box::new(move || {
                c.fetch_add(1, Ordering::Relaxed);
            }) as Task
        })
        .collect()
}

/// 10k micro-tasks submitted one `execute` at a time (the historical
/// Fig. 14 plane: per-task dispatch overhead, wrapper closure and all).
/// Returns seconds.
pub fn measure_pool_10k_on(pool: &dyn TaskPool) -> f64 {
    let counter = Arc::new(AtomicU64::new(0));
    let submit = |n: usize| {
        let wg = WaitGroup::new(n);
        for t in count_tasks(&counter, n) {
            let h = wg.handle();
            pool.execute(Box::new(move || {
                t();
                h.done();
            }));
        }
        wg.wait();
    };
    submit(100); // warm-up
    let t0 = Instant::now();
    submit(10_000);
    t0.elapsed().as_secs_f64()
}

/// 10k micro-tasks through [`scatter_gather`] — the batch-submission
/// plane (one injection, one wake decision, pool-counted completions).
/// Returns seconds.
pub fn measure_pool_batch_10k_on(pool: &dyn TaskPool) -> f64 {
    let counter = Arc::new(AtomicU64::new(0));
    scatter_gather(pool, count_tasks(&counter, 100)); // warm-up
    let t0 = Instant::now();
    scatter_gather(pool, count_tasks(&counter, 10_000));
    t0.elapsed().as_secs_f64()
}

/// Really run 10k micro-tasks through a pool (the paper's stress test:
/// minimal compute, maximal synchronisation), per-task submission.
/// Returns seconds.
pub fn measure_pool_10k(lib: PoolLib, threads: usize) -> f64 {
    let pool = make_pool(lib, threads);
    measure_pool_10k_on(pool.as_ref())
}

/// Modelled 10k-task latency on the paper's `small` platform (4 cores / 8
/// hyperthreads) — the Fig. 14 series the simulator uses.
pub fn model_pool_10k(lib: PoolLib, threads: usize, platform: &CpuPlatform) -> f64 {
    let hw = platform.logical_cores();
    let per_task = pool_dispatch_overhead(lib) * pool_oversubscription_factor(lib, threads, hw);
    // dispatch is serialised on the queue; execution overlaps
    10_000.0 * per_task
}

/// Fig. 14: thread-pool overhead — modelled for the paper's `small` box
/// and measured for real on this machine's pools.
pub fn fig14_threadpool_overhead() -> String {
    let p = CpuPlatform::small();
    let mut out = String::from("Fig 14 — 10k micro-tasks through each pool implementation\n");
    let _ = writeln!(out, "modelled on `small` (4C/8T):");
    let _ = writeln!(out, "{:<14} {:>12} {:>12}", "pool", "4 threads", "64 threads");
    for lib in PoolLib::ALL {
        let _ = writeln!(
            out,
            "{:<14} {:>10.2}ms {:>10.2}ms",
            lib.name(),
            model_pool_10k(lib, 4, &p) * 1e3,
            model_pool_10k(lib, 64, &p) * 1e3,
        );
    }
    let _ = writeln!(out, "measured on this machine (real pools, {} hw threads):",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1));
    let _ = writeln!(out, "{:<14} {:>12} {:>12}", "pool", "4 threads", "64 threads");
    for lib in PoolLib::ALL {
        let _ = writeln!(
            out,
            "{:<14} {:>10.2}ms {:>10.2}ms",
            lib.name(),
            measure_pool_10k(lib, 4) * 1e3,
            measure_pool_10k(lib, 64) * 1e3,
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig13_mkl_best_everywhere() {
        let s = fig13_library_comparison();
        assert!(s.contains("MKL") && s.contains("Eigen"));
    }

    #[test]
    fn fig14_model_ordering_folly_eigen_std() {
        let p = CpuPlatform::small();
        for threads in [4usize, 64] {
            let f = model_pool_10k(PoolLib::Folly, threads, &p);
            let e = model_pool_10k(PoolLib::Eigen, threads, &p);
            let s = model_pool_10k(PoolLib::StdThread, threads, &p);
            assert!(f < e && e < s, "threads={threads}: {f} {e} {s}");
        }
    }

    #[test]
    fn fig14_std_degrades_3x_at_64() {
        let p = CpuPlatform::small();
        let s4 = model_pool_10k(PoolLib::StdThread, 4, &p);
        let s64 = model_pool_10k(PoolLib::StdThread, 64, &p);
        assert!(s64 / s4 > 3.0, "ratio={}", s64 / s4);
        // Folly/Eigen stay roughly flat
        let f4 = model_pool_10k(PoolLib::Folly, 4, &p);
        let f64_ = model_pool_10k(PoolLib::Folly, 64, &p);
        assert!(f64_ / f4 < 1.5);
    }

    #[test]
    fn real_pools_complete_10k() {
        // correctness of the real path (timing asserted only loosely: the
        // CI box has 1 core, so only completion + sanity are stable)
        for lib in PoolLib::ALL {
            let secs = measure_pool_10k(lib, 4);
            assert!(secs > 0.0 && secs < 30.0, "{lib:?}: {secs}");
        }
    }

    #[test]
    fn batch_plane_completes_10k() {
        use crate::libs::threadpool::{EigenPool, ReferencePool};
        let secs = measure_pool_batch_10k_on(&EigenPool::new(4));
        assert!(secs > 0.0 && secs < 30.0, "eigen batch: {secs}");
        let secs = measure_pool_batch_10k_on(&ReferencePool::new(4));
        assert!(secs > 0.0 && secs < 30.0, "reference batch: {secs}");
    }
}
