//! Ablations: isolate each framework design feature's contribution on the
//! evaluation set (the "which knob bought what" analysis the paper's Fig. 1
//! staircase hints at, run across all models).
//!
//! Each ablation flips ONE feature of the guideline-tuned configuration and
//! reports the geomean slowdown — regenerate with `parframe ablations`.

use std::fmt::Write as _;

use crate::config::{CpuPlatform, FrameworkConfig, MathLib, OperatorImpl, ParallelismMode, PoolLib};
use crate::models;
use crate::tuner;
use crate::util::stats;

use super::evaluation::EVAL_MODELS;
use super::run;

/// One ablation: name + config mutation.
type Mutation = (&'static str, fn(&mut FrameworkConfig));

/// The ablation set: each entry degrades one design feature.
pub fn mutations() -> Vec<Mutation> {
    vec![
        ("sync scheduling (pools=1)", |c| {
            c.inter_op_pools = 1;
        }),
        ("serial operators (MatMul1)", |c| {
            c.operator_impl = OperatorImpl::Serial;
        }),
        ("Eigen GEMM kernels", |c| {
            c.math_lib = MathLib::Eigen;
        }),
        ("std::thread pool", |c| {
            c.pool_lib = PoolLib::StdThread;
        }),
        ("no model parallelism", |c| {
            c.parallelism = ParallelismMode::DataParallel;
        }),
        ("half the threads", |c| {
            c.mkl_threads = (c.mkl_threads / 2).max(1);
            c.intra_op_threads = (c.intra_op_threads / 2).max(1);
        }),
        ("2x the pools", |c| {
            c.inter_op_pools *= 2;
        }),
    ]
}

/// Geomean slowdown of one mutation across the evaluation set.
pub fn ablation_slowdown(mutate: fn(&mut FrameworkConfig), p: &CpuPlatform) -> f64 {
    let mut ratios = Vec::new();
    for name in EVAL_MODELS {
        let g = models::build(name, models::canonical_batch(name)).unwrap();
        let tuned = tuner::tune(&g, p).config;
        let mut ablated = tuned.clone();
        mutate(&mut ablated);
        if ablated.validate(p).is_err() {
            continue;
        }
        let base = run(&g, p, &tuned).latency_s;
        let abl = run(&g, p, &ablated).latency_s;
        ratios.push(abl / base);
    }
    stats::geomean(&ratios)
}

/// Render the ablation table.
pub fn ablation_table() -> String {
    let p = CpuPlatform::large2();
    let mut out = String::from(
        "Ablations — geomean slowdown from degrading one feature of the tuned\n\
         setting (large.2, evaluation set):\n",
    );
    let _ = writeln!(out, "{:<32} {:>10}", "ablation", "slowdown");
    let mut rows: Vec<(String, f64)> = mutations()
        .into_iter()
        .map(|(name, m)| (name.to_string(), ablation_slowdown(m, &p)))
        .collect();
    rows.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    for (name, s) in rows {
        let _ = writeln!(out, "{:<32} {:>9.2}x", name, s);
    }
    out.push_str("(1.00x = no effect; the guideline's pool/thread balance and the\n MatMul2 operator design carry most of the win)\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_ablation_helps() {
        // every mutation moves away from the tuned point; none may yield a
        // meaningful speedup (small slack for lattice coarseness)
        let p = CpuPlatform::large2();
        for (name, m) in mutations() {
            let s = ablation_slowdown(m, &p);
            assert!(s > 0.97, "{name}: {s}");
        }
    }

    #[test]
    fn serial_operators_hurt_most_of_all_single_knobs() {
        // the paper's §5 finding: operator design (intra-op prep
        // parallelism) is a first-order effect
        let p = CpuPlatform::large2();
        let serial = ablation_slowdown(|c| c.operator_impl = OperatorImpl::Serial, &p);
        assert!(serial > 1.1, "serial={serial}");
    }

    #[test]
    fn table_renders() {
        let t = ablation_table();
        assert!(t.contains("sync scheduling"));
        assert!(t.contains("Eigen"));
    }
}
