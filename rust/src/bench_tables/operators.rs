//! Figures 9–12 — operator-design experiments (paper §5).

use std::fmt::Write as _;

use crate::config::{CpuPlatform, OperatorImpl};
use crate::graph::{GraphBuilder, Graph};
use crate::models;
use crate::ops::OpKind;
use crate::sim::{self, Category, SimOptions};

use super::{breakdown_cols, breakdown_header, cfg, run};

/// A single-op MatMul graph (the §5 micro-workload).
pub fn matmul_graph(n: usize) -> Graph {
    let mut b = GraphBuilder::new(&format!("matmul_{n}"), n);
    b.add("matmul", OpKind::MatMul { m: n, k: n, n }, &[]);
    b.build()
}

/// A kernel-only MatMul graph: zero framework prep, modelling the bare
/// library call (Fig. 9's "MKL" series).
pub fn kernel_only_graph(n: usize) -> Graph {
    let mut b = GraphBuilder::new(&format!("mkl_{n}"), n);
    let id = b.add("matmul", OpKind::MatMul { m: n, k: n, n }, &[]);
    let _ = id;
    let mut g = b.build();
    g.nodes[0].cost.prep_bytes = 0.0; // strip the framework term
    g
}

/// 24-vs-1 MKL-thread speedup for a graph.
fn scaling(g: &Graph, p: &CpuPlatform, strip_fw_prep: bool) -> f64 {
    let _ = strip_fw_prep;
    let t1 = run(g, p, &cfg(1, 1, 1, OperatorImpl::Serial)).latency_s;
    let t24 = run(g, p, &cfg(1, 24, 1, OperatorImpl::Serial)).latency_s;
    t1 / t24
}

/// Fig. 9: speedup from 24 MKL threads, TF operator vs bare MKL kernel.
pub fn fig9_mkl_thread_scaling() -> String {
    let p = CpuPlatform::large();
    let sizes = [256usize, 512, 1024, 2048, 4096, 8192, 16384];
    let mut out = String::from("Fig 9 — speedup of 24 MKL threads over 1 (large)\n");
    let _ = writeln!(out, "{:<8} {:>10} {:>10}", "size", "TF op", "MKL kernel");
    for n in sizes {
        // TF series: framework prep included; MKL series: kernel+packing only
        let tf = scaling(&matmul_graph(n), &p, false);
        let mkl = scaling(&kernel_only_graph(n), &p, true);
        let _ = writeln!(out, "{:<8} {:>9.2}x {:>9.2}x", n, tf, mkl);
    }
    out
}

/// Fig. 10: run-time breakdown of MatMul-512 / MatMul-4k at 1 and 24 MKL
/// threads — data preparation is the scaling wall.
pub fn fig10_matmul_breakdown() -> String {
    let p = CpuPlatform::large();
    let mut out = String::from("Fig 10 — MatMul breakdowns (large), latency normalised to 1 thread\n");
    let _ = writeln!(out, "{:<22} rel.time {}", "case", breakdown_header());
    for n in [512usize, 4096] {
        let g = matmul_graph(n);
        let t1 = run(&g, &p, &cfg(1, 1, 1, OperatorImpl::Serial));
        for threads in [1usize, 24] {
            let r = run(&g, &p, &cfg(1, threads, 1, OperatorImpl::Serial));
            let _ = writeln!(
                out,
                "MatMul-{:<5} {:>2} thread{} {:>7.3} {}",
                n,
                threads,
                if threads == 1 { " " } else { "s" },
                r.latency_s / t1.latency_s,
                breakdown_cols(&r)
            );
        }
    }
    out
}

/// Fig. 11 rows: workload, 24-intra-thread speedup, programmability tax.
pub fn fig11_rows() -> Vec<(String, f64, f64)> {
    let p = CpuPlatform::large();
    let mut rows = Vec::new();
    let mut workloads: Vec<(String, Graph)> = vec![
        ("MatMul-512".into(), matmul_graph(512)),
        ("MatMul-4k".into(), matmul_graph(4096)),
    ];
    for name in ["squeezenet", "resnet50", "densenet121", "inception_v2"] {
        workloads.push((name.to_string(), models::build(name, 16).unwrap()));
    }
    for (name, g) in workloads {
        let serial = run(&g, &p, &cfg(1, 24, 1, OperatorImpl::Serial));
        let par = run(&g, &p, &cfg(1, 24, 24, OperatorImpl::IntraOpParallel));
        let speedup = serial.latency_s / par.latency_s;
        let tax = par.breakdown.programmability_tax();
        rows.push((name, speedup, tax));
    }
    rows
}

/// Fig. 11: intra-op-thread speedups + the programmability tax.
pub fn fig11_intra_op_threads() -> String {
    let mut out = String::from(
        "Fig 11 — 24 intra-op threads vs 1 (both 24 MKL threads, large)\n",
    );
    let _ = writeln!(out, "{:<14} {:>9} {:>18}", "workload", "speedup", "programmability tax");
    for (name, speedup, tax) in fig11_rows() {
        let _ = writeln!(out, "{:<14} {:>8.2}x {:>17.1}%", name, speedup, tax * 100.0);
    }
    out
}

/// Fig. 12: per-hyperthread activity for the MatMuls with 24 intra-op
/// threads — kernel threads on cores 0–23, intra threads on 24–47.
pub fn fig12_hyperthread_breakdown() -> String {
    let p = CpuPlatform::large();
    let mut out = String::from(
        "Fig 12 — hyperthread roles with 24 MKL + 24 intra-op threads (large)\n",
    );
    for n in [512usize, 4096] {
        let g = matmul_graph(n);
        let r = sim::simulate_opts(
            &g,
            &p,
            &cfg(1, 24, 24, OperatorImpl::IntraOpParallel),
            &SimOptions { record_timelines: true },
        )
        .expect("zoo graphs simulate");
        let busy = |core: usize, cat: Category| -> f64 {
            (r.timelines[core]
                .iter()
                .filter(|s| s.cat == cat)
                .map(|s| s.dur())
                .sum::<f64>()
                / r.latency_s)
                .max(0.0)
        };
        let _ = writeln!(
            out,
            "MatMul-{n}: core0 mkl={:.0}% prep={:.0}% | core24 (HT partner) prep={:.0}% mkl={:.0}%",
            busy(0, Category::MklCompute) * 100.0,
            busy(0, Category::FwPrep) * 100.0,
            busy(24, Category::FwPrep) * 100.0,
            busy(24, Category::MklCompute) * 100.0,
        );
    }
    out.push_str("(framework prep rides the idle hyperthread partners of the FMA-bound kernel threads)\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9_tf_below_mkl_and_below_cores() {
        let p = CpuPlatform::large();
        for n in [512usize, 4096] {
            let tf = scaling(&matmul_graph(n), &p, false);
            let mkl = scaling(&kernel_only_graph(n), &p, true);
            assert!(tf <= mkl + 1e-9, "n={n}: tf={tf} mkl={mkl}");
            assert!(mkl < 24.0, "n={n}: mkl={mkl}");
        }
    }

    #[test]
    fn fig9_small_matrices_scale_worst() {
        let p = CpuPlatform::large();
        let small = scaling(&matmul_graph(256), &p, false);
        let big = scaling(&matmul_graph(8192), &p, false);
        assert!(small < big, "small={small} big={big}");
        assert!(big > 8.0, "big={big}");
        assert!(big < 20.0, "big={big} (paper: ~16x max)");
    }

    #[test]
    fn fig10_prep_dominates_512_at_24_threads() {
        // wall-clock durations: the serial prep exceeds the (parallel)
        // kernel's duration at 24 threads (Fig. 10's scaling wall). The
        // breakdown stores core-seconds, so divide compute by its width.
        let p = CpuPlatform::large();
        let g = matmul_graph(512);
        let r = run(&g, &p, &cfg(1, 24, 1, OperatorImpl::Serial));
        let prep_wall = r.breakdown.get(Category::FwPrep); // serial: 1 core
        let compute_wall = r.breakdown.get(Category::MklCompute) / 24.0;
        assert!(
            prep_wall > compute_wall,
            "prep={prep_wall} compute={compute_wall}"
        );
    }

    #[test]
    fn fig11_speedup_band_matches_paper() {
        // paper: 1.05× (DenseNet) … 4.21× (SqueezeNet). We reproduce the
        // band and the prep-bound-vs-compute-bound contrast; the exact
        // DenseNet-vs-SqueezeNet ordering differs (our DenseNet models its
        // 3×3 convs via im2col where MKL-DNN used direct convolution) —
        // see EXPERIMENTS.md §Deviations.
        let rows = fig11_rows();
        let get = |n: &str| rows.iter().find(|r| r.0 == n).unwrap().1;
        assert!(get("MatMul-512") > 1.5, "mm512={}", get("MatMul-512"));
        assert!(get("MatMul-512") > get("MatMul-4k"), "512 should gain more");
        assert!(get("squeezenet") > 1.3, "squeeze={}", get("squeezenet"));
        for (name, s, _) in &rows {
            assert!(*s >= 0.95 && *s < 8.0, "{name}: {s}");
        }
    }

    #[test]
    fn fig11_tax_band_matches_paper() {
        // paper: tax ranges 1.3% … 63%, MatMul-512 highest, 4k small
        let rows = fig11_rows();
        let tax = |n: &str| rows.iter().find(|r| r.0 == n).unwrap().2;
        assert!(tax("MatMul-512") > 0.3, "512 tax={}", tax("MatMul-512"));
        assert!(tax("MatMul-4k") < tax("MatMul-512"));
        for (name, _, t) in &rows {
            assert!(*t > 0.005 && *t < 0.85, "{name}: tax={t}");
        }
    }

    #[test]
    fn fig12_intra_threads_on_hyperthread_partners() {
        let s = fig12_hyperthread_breakdown();
        assert!(s.contains("core24"));
    }
}
