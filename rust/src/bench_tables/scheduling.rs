//! Figures 1, 4, 6, 7, 8 — scheduling-mechanism experiments (paper §4) —
//! plus the SCHED-POL extension table comparing ready-op dispatch
//! policies at the guideline setting.

use std::fmt::Write as _;

use crate::config::{CpuPlatform, FrameworkConfig, OperatorImpl, SchedPolicy};
use crate::graph::analyze_width;
use crate::models;
use crate::sim::{self, SimOptions};
use crate::trace;
use crate::tuner;

use super::{breakdown_cols, breakdown_header, cfg, run};

/// Fig. 1: Inception v3 time breakdown as framework knobs are tuned step
/// by step (default → +inter-op → +intra-op → guideline vs TF-recommended).
pub fn fig1_inception_v3_breakdown() -> String {
    let p = CpuPlatform::large();
    let g = models::build("inception_v3", 16).unwrap();
    let steps = [
        ("default (sync, serial ops)", cfg(1, p.logical_cores(), 1, OperatorImpl::Serial)),
        ("+ inter-op pools", cfg(2, 24, 1, OperatorImpl::Serial)),
        ("+ intra-op threads", cfg(2, 24, 24, OperatorImpl::IntraOpParallel)),
        ("guideline (this work)", tuner::tune(&g, &p).config),
        ("TF-recommended", {
            let mut c = cfg(1, 24, 24, OperatorImpl::IntraOpParallel);
            c.mkl_threads = p.physical_cores();
            c.intra_op_threads = p.physical_cores();
            c
        }),
    ];
    let base = run(&g, &p, &steps[0].1).latency_s;
    let mut out = String::from("Fig 1 — Inception v3 (bs16, large): time breakdown per setting\n");
    let _ = writeln!(out, "{:<28} speedup {}", "setting", breakdown_header());
    for (name, c) in &steps {
        let r = run(&g, &p, c);
        let _ = writeln!(out, "{:<28} {:>6.2}x {}", name, base / r.latency_s, breakdown_cols(&r));
    }
    out
}

/// Speedup of asynchronous over synchronous scheduling for one model.
pub fn async_over_sync(name: &str, training: bool, p: &CpuPlatform) -> f64 {
    let batch = models::canonical_batch(name);
    let fwd = models::build(name, batch).unwrap();
    let g = if training { models::to_training_graph(&fwd) } else { fwd };
    let phys = p.physical_cores();
    let sync = run(&g, p, &cfg(1, phys, 1, OperatorImpl::Serial)).latency_s;
    // paper's Fig. 4 setup: inference 3 pools × 8, training 2 pools × 12
    let (pools, threads) = if training { (2, phys / 2) } else { (3, phys / 3) };
    let async_ = run(&g, p, &cfg(pools, threads, 1, OperatorImpl::Serial)).latency_s;
    sync / async_
}

/// Best pool count for a model by sweeping 1..=6 (used in Fig. 4's table).
pub fn best_pools(name: &str, training: bool, batch: usize, p: &CpuPlatform) -> usize {
    let fwd = models::build(name, batch).unwrap();
    let g = if training { models::to_training_graph(&fwd) } else { fwd };
    (1..=6)
        .min_by(|&a, &b| {
            let la = run(&g, p, &cfg(a, p.physical_cores() / a, 1, OperatorImpl::Serial)).latency_s;
            let lb = run(&g, p, &cfg(b, p.physical_cores() / b, 1, OperatorImpl::Serial)).latency_s;
            la.partial_cmp(&lb).unwrap()
        })
        .unwrap()
}

/// Fig. 4: async-over-sync speedups + max-width/best-pool table.
pub fn fig4_async_speedup() -> String {
    let p = CpuPlatform::large();
    let names = [
        "inception_v1",
        "inception_v2",
        "googlenet",
        "resnet50",
        "caffenet",
        "fc4k",
    ];
    let mut out = String::from("Fig 4 — async-over-sync speedup (large, bs canonical)\n");
    let _ = writeln!(out, "{:<14} {:>9} {:>9} | max-width  best-pools(inf)  best-pools(train)", "model", "inference", "training");
    for name in names {
        let inf = async_over_sync(name, false, &p);
        let tr = async_over_sync(name, true, &p);
        let batch = models::canonical_batch(name);
        let g = models::build(name, batch).unwrap();
        let w = analyze_width(&g);
        let bp_inf = best_pools(name, false, batch, &p);
        let bp_tr = best_pools(name, true, batch, &p);
        let _ = writeln!(
            out,
            "{:<14} {:>8.2}x {:>8.2}x | {:>9} {:>16} {:>18}",
            name, inf, tr, w.max_width, bp_inf, bp_tr
        );
    }
    out
}

/// Fig. 6: Inception v2 relative performance over (pools × threads).
pub fn fig6_pool_thread_sweep() -> String {
    let p = CpuPlatform::small();
    let g = models::build("inception_v2", 16).unwrap();
    let axis = [1usize, 2, 4, 8];
    // baseline: 1 pool × 1 thread
    let base = run(&g, &p, &cfg(1, 1, 1, OperatorImpl::Serial)).latency_s;
    let mut out = String::from(
        "Fig 6 — Inception v2 (bs16, small): relative performance, pools × MKL threads\n",
    );
    let _ = writeln!(
        out,
        "(4 physical cores / 8 hyperthreads; >8 total software threads = over-threading)"
    );
    let _ = writeln!(
        out,
        "pools\\threads {}",
        axis.iter().map(|t| format!("{t:>7}")).collect::<String>()
    );
    for pools in axis {
        let mut row = format!("{pools:>13} ");
        for threads in axis {
            let r = run(&g, &p, &cfg(pools, threads, 1, OperatorImpl::Serial));
            let _ = write!(row, "{:>7.2}", base / r.latency_s);
        }
        let _ = writeln!(out, "{row}");
    }
    out
}

/// The paper's four §4.2 cases on the `small` platform.
pub fn fig7_cases() -> Vec<(&'static str, usize, usize)> {
    // (label, pools, threads-per-pool)
    vec![
        ("1 thread", 1, 1),
        ("4 pools x 1 thread", 4, 1),
        ("1 pool x 4 threads", 1, 4),
        ("2 pools x 2 threads", 2, 2),
    ]
}

/// Fig. 7: execution-time breakdown of the four cases.
pub fn fig7_case_breakdowns() -> String {
    let p = CpuPlatform::small();
    let g = models::build("inception_v2", 16).unwrap();
    let mut out = String::from("Fig 7 — Inception v2 (bs16, small): breakdown of four cases\n");
    let _ = writeln!(out, "{:<22} latency  {}", "case", breakdown_header());
    for (label, pools, threads) in fig7_cases() {
        let r = run(&g, &p, &cfg(pools, threads, 1, OperatorImpl::Serial));
        let _ = writeln!(
            out,
            "{:<22} {:>6.1}ms {}",
            label,
            r.latency_s * 1e3,
            breakdown_cols(&r)
        );
    }
    out
}

/// SCHED-POL ("Table 3", an extension beyond the paper): each model's §8
/// guideline setting re-simulated under every dispatch policy, speedups
/// relative to topological order. Wide graphs are where the ready-op
/// priority lever (Liu et al., arXiv 1810.08955) pays off; chains are the
/// control group — dispatch order cannot matter there.
pub fn table3_policy_comparison() -> String {
    let p = CpuPlatform::large2();
    let names = ["resnet50", "inception_v1", "inception_v3", "wide_deep", "ncf", "transformer"];
    let mut out = String::from(
        "Table 3 — dispatch-policy speedup over topo at the guideline setting (large.2)\n",
    );
    let _ = writeln!(
        out,
        "{:<14} {:>6} {:>11} {:>15} {:>12}",
        "model", "pools", "topo", "critical-path", "costly"
    );
    for name in names {
        let g = models::build(name, models::canonical_batch(name)).unwrap();
        let mut c = tuner::tune(&g, &p).config;
        let lat = |c: &FrameworkConfig| run(&g, &p, c).latency_s;
        c.sched_policy = SchedPolicy::Topo;
        let topo = lat(&c);
        c.sched_policy = SchedPolicy::CriticalPathFirst;
        let cp = lat(&c);
        c.sched_policy = SchedPolicy::CostlyFirst;
        let costly = lat(&c);
        let _ = writeln!(
            out,
            "{:<14} {:>6} {:>9.3}ms {:>14.2}x {:>11.2}x",
            name,
            c.inter_op_pools,
            topo * 1e3,
            topo / cp,
            topo / costly
        );
    }
    out
}

/// Fig. 8: per-core execution traces of the multi-threaded cases.
pub fn fig8_traces() -> String {
    let p = CpuPlatform::small();
    let g = models::build("inception_v2", 16).unwrap();
    let mut out = String::from("Fig 8 — Inception v2 execution traces (small)\n");
    for (label, pools, threads) in fig7_cases().into_iter().skip(1) {
        let r = sim::simulate_opts(
            &g,
            &p,
            &cfg(pools, threads, 1, OperatorImpl::Serial),
            &SimOptions { record_timelines: true },
        )
        .expect("zoo graphs simulate");
        let _ = writeln!(out, "--- {label} (latency {:.1}ms)", r.latency_s * 1e3);
        out.push_str(&trace::ascii_trace(&r.timelines, r.latency_s, 72));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_guideline_speedup_band() {
        let s = fig1_inception_v3_breakdown();
        assert!(s.contains("guideline"));
        // parse the guideline speedup: should beat the default clearly
        let line = s.lines().find(|l| l.starts_with("guideline")).unwrap();
        let speedup: f64 = line.split_whitespace().nth(3).unwrap().trim_end_matches('x').parse().unwrap();
        assert!(speedup > 1.5, "guideline speedup {speedup}");
    }

    #[test]
    fn fig4_wide_models_speed_up_more() {
        let p = CpuPlatform::large();
        let wide = async_over_sync("inception_v1", false, &p);
        let chain = async_over_sync("caffenet", false, &p);
        assert!(wide > chain, "wide={wide} chain={chain}");
        assert!(wide > 1.05, "wide={wide}");
    }

    #[test]
    fn fig4_training_doubles_parallelism_for_chains() {
        let p = CpuPlatform::large();
        // chains gain async benefit only in training (grad ∥ wsum)
        let inf = async_over_sync("fc4k", false, &p);
        let tr = async_over_sync("fc4k", true, &p);
        assert!(tr > inf * 0.95, "inf={inf} train={tr}");
    }

    #[test]
    fn fig6_best_is_balanced_not_maximal() {
        // paper: [2 pools, 2 threads] is best on `small`; our model puts
        // 2×2 within a couple percent of 1×4 (critical-path effects) while
        // clearly beating the unbalanced and over-threaded corners.
        let p = CpuPlatform::small();
        let g = models::build("inception_v2", 16).unwrap();
        let t11 = run(&g, &p, &cfg(1, 1, 1, OperatorImpl::Serial)).latency_s;
        let t22 = run(&g, &p, &cfg(2, 2, 1, OperatorImpl::Serial)).latency_s;
        let t88 = run(&g, &p, &cfg(8, 8, 1, OperatorImpl::Serial)).latency_s;
        let t14 = run(&g, &p, &cfg(1, 4, 1, OperatorImpl::Serial)).latency_s;
        let t41 = run(&g, &p, &cfg(4, 1, 1, OperatorImpl::Serial)).latency_s;
        assert!(t22 < t88, "over-threading should lose: 2x2={t22} 8x8={t88}");
        assert!(t22 < t41, "2x2={t22} 4x1={t41}");
        assert!(t22 < t11, "2x2={t22} 1x1={t11}");
        assert!(t22 < t14 * 1.05, "2x2={t22} should be within 5% of 1x4={t14}");
    }

    #[test]
    fn fig8_contains_traces() {
        let s = fig8_traces();
        assert!(s.contains("2 pools x 2 threads"));
        assert!(s.contains("legend"));
    }

    #[test]
    fn table3_lists_models_and_policies() {
        let s = table3_policy_comparison();
        assert!(s.contains("Table 3"));
        assert!(s.contains("critical-path") && s.contains("costly"));
        for model in ["resnet50", "transformer", "inception_v3"] {
            assert!(s.contains(model), "missing {model}");
        }
    }
}
