//! Figures 15–17 — beyond-one-socket experiments (paper §7).

use std::fmt::Write as _;

use crate::config::{CpuPlatform, OperatorImpl};
use crate::models;

use super::{breakdown_cols, breakdown_header, cfg, run};
use super::operators::matmul_graph;

/// Data-parallel config: one pool spanning everything, all threads.
fn dp(p: &CpuPlatform) -> crate::config::FrameworkConfig {
    cfg(1, p.physical_cores(), p.physical_cores(), OperatorImpl::IntraOpParallel)
}

/// Fig. 15: ResNet-50 on one vs two sockets (data parallelism): the UPI
/// link keeps the second socket from doubling throughput.
pub fn fig15_resnet_two_socket() -> String {
    let one = CpuPlatform::large();
    let two = CpuPlatform::large2();
    let g = models::build("resnet50", 16).unwrap();
    let r1 = run(&g, &one, &dp(&one));
    let r2 = run(&g, &two, &dp(&two));
    let mut out = String::from("Fig 15 — ResNet-50 (bs16) data parallelism across sockets\n");
    let _ = writeln!(out, "{:<12} latency  speedup {}", "platform", breakdown_header());
    let _ = writeln!(out, "{:<12} {:>6.1}ms {:>7} {}", "large", r1.latency_s * 1e3, "1.00x", breakdown_cols(&r1));
    let _ = writeln!(
        out,
        "{:<12} {:>6.1}ms {:>6.2}x {}",
        "large.2",
        r2.latency_s * 1e3,
        r1.latency_s / r2.latency_s,
        breakdown_cols(&r2)
    );
    let _ = writeln!(out, "peak UPI demand: {:.1} GB/s", r2.upi_peak_bps / 1e9);
    out
}

/// Two-socket speedup + peak UPI consumption for a MatMul size.
pub fn two_socket_speedup(n: usize) -> (f64, f64) {
    let one = CpuPlatform::large();
    let two = CpuPlatform::large2();
    let g = matmul_graph(n);
    let r1 = run(&g, &one, &dp(&one));
    let r2 = run(&g, &two, &dp(&two));
    (r1.latency_s / r2.latency_s, r2.upi_peak_bps / 1e9)
}

/// Fig. 16: two-socket speedup and UPI bandwidth vs MatMul size (peaks at
/// 8k, declines at 16k as NUMA thrash sets in).
pub fn fig16_upi_bandwidth() -> String {
    let sizes = [512usize, 1024, 2048, 4096, 8192, 16384];
    let mut out = String::from("Fig 16 — two-socket (large.2) scaling of TF MatMul\n");
    let _ = writeln!(out, "{:<8} {:>9} {:>14}", "size", "speedup", "UPI GB/s");
    for n in sizes {
        let (s, bw) = two_socket_speedup(n);
        let _ = writeln!(out, "{:<8} {:>8.2}x {:>13.1}", n, s, bw);
    }
    out
}

/// Fig. 17: breakdowns of the MatMuls on one vs two sockets.
pub fn fig17_multisocket_breakdown() -> String {
    let one = CpuPlatform::large();
    let two = CpuPlatform::large2();
    let mut out = String::from("Fig 17 — MatMul breakdowns, one vs two sockets\n");
    let _ = writeln!(out, "{:<20} latency  {}", "case", breakdown_header());
    for n in [512usize, 4096, 8192] {
        let g = matmul_graph(n);
        for (pname, p) in [("large", &one), ("large.2", &two)] {
            let r = run(&g, p, &dp(p));
            let _ = writeln!(
                out,
                "MatMul-{:<5} {:<7} {:>6.1}ms {}",
                n,
                pname,
                r.latency_s * 1e3,
                breakdown_cols(&r)
            );
        }
    }
    out
}

/// Model parallelism for NCF (§7.2): one pool per socket over the four
/// embeddings vs single-socket execution.
pub fn ncf_model_parallel_speedup() -> f64 {
    let two = CpuPlatform::large2();
    let g = models::build("ncf", models::canonical_batch("ncf")).unwrap();
    let mut mp = cfg(4, 12, 12, OperatorImpl::IntraOpParallel);
    mp.parallelism = crate::config::ParallelismMode::ModelParallel;
    let sync = run(&g, &two, &cfg(1, 48, 48, OperatorImpl::IntraOpParallel));
    let par = run(&g, &two, &mp);
    sync.latency_s / par.latency_s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig15_speedup_below_2x() {
        let g = models::build("resnet50", 16).unwrap();
        let one = CpuPlatform::large();
        let two = CpuPlatform::large2();
        let s = run(&g, &one, &dp(&one)).latency_s / run(&g, &two, &dp(&two)).latency_s;
        // paper: 1.43×
        assert!(s > 1.1 && s < 1.9, "speedup={s}");
    }

    #[test]
    fn fig16_peak_at_8k_decline_at_16k() {
        let (s4k, _) = two_socket_speedup(4096);
        let (s8k, bw8k) = two_socket_speedup(8192);
        let (s16k, _) = two_socket_speedup(16384);
        assert!(s8k > s4k, "8k={s8k} 4k={s4k}");
        assert!(s16k < s8k, "16k={s16k} 8k={s8k}");
        // paper: ~1.8× at 8k; our saturating thread-scaling model yields a
        // more conservative ~1.4× with the same rise-then-fall shape
        assert!(s8k > 1.3 && s8k <= 2.0, "8k={s8k} (paper: ~1.8x)");
        assert!(bw8k > 50.0 && bw8k <= 110.0, "bw={bw8k} (paper: ~100 GB/s)");
    }

    #[test]
    fn fig16_small_matmul_barely_scales() {
        let (s512, _) = two_socket_speedup(512);
        assert!(s512 < 1.3, "512={s512}");
    }

    #[test]
    fn ncf_benefits_from_model_parallelism() {
        let s = ncf_model_parallel_speedup();
        assert!(s > 1.0, "ncf model-parallel speedup {s}");
    }
}
