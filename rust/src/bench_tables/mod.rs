//! Figure/table regeneration harness: one function per figure and table of
//! the paper's evaluation. Each returns the printable rows (and is smoke-
//! tested for the paper's qualitative relations in
//! `rust/tests/figures_smoke.rs`).
//!
//! CLI: `parframe figures --fig 9`, `parframe figures --table 2`,
//! `parframe figures --all`.

pub mod ablations;
pub mod evaluation;
pub mod libraries;
pub mod multisocket;
pub mod operators;
pub mod scheduling;

use crate::config::{CpuPlatform, FrameworkConfig, OperatorImpl};
use crate::graph::Graph;
use crate::sim::{self, Category, SimReport};

/// Render one figure by number.
pub fn figure(n: usize) -> Option<String> {
    Some(match n {
        1 => scheduling::fig1_inception_v3_breakdown(),
        4 => scheduling::fig4_async_speedup(),
        6 => scheduling::fig6_pool_thread_sweep(),
        7 => scheduling::fig7_case_breakdowns(),
        8 => scheduling::fig8_traces(),
        9 => operators::fig9_mkl_thread_scaling(),
        10 => operators::fig10_matmul_breakdown(),
        11 => operators::fig11_intra_op_threads(),
        12 => operators::fig12_hyperthread_breakdown(),
        13 => libraries::fig13_library_comparison(),
        14 => libraries::fig14_threadpool_overhead(),
        15 => multisocket::fig15_resnet_two_socket(),
        16 => multisocket::fig16_upi_bandwidth(),
        17 => multisocket::fig17_multisocket_breakdown(),
        18 => evaluation::fig18_guideline_evaluation(),
        _ => return None,
    })
}

/// Render one table by number (2 is the paper's; 3 is the SCHED-POL
/// dispatch-policy extension).
pub fn table(n: usize) -> Option<String> {
    match n {
        2 => Some(evaluation::table2_average_widths()),
        3 => Some(scheduling::table3_policy_comparison()),
        _ => None,
    }
}

/// All figure numbers with generators.
pub const FIGURES: [usize; 15] = [1, 4, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15, 16, 17, 18];

/// Shared helper: a framework config with explicit thread knobs.
pub(crate) fn cfg(pools: usize, mkl: usize, intra: usize, op: OperatorImpl) -> FrameworkConfig {
    FrameworkConfig {
        inter_op_pools: pools,
        mkl_threads: mkl,
        intra_op_threads: intra,
        operator_impl: op,
        ..FrameworkConfig::tuned_default()
    }
}

/// Shared helper: simulate and return the report (bench tables only run
/// zoo graphs, which are valid DAGs by construction).
pub(crate) fn run(g: &Graph, p: &CpuPlatform, c: &FrameworkConfig) -> SimReport {
    sim::simulate(g, p, c).expect("zoo graphs simulate")
}

/// Shared helper: format a breakdown as percentage columns.
pub(crate) fn breakdown_cols(r: &SimReport) -> String {
    let cats = [
        Category::MklCompute,
        Category::MklPrep,
        Category::FwPrep,
        Category::FwNative,
        Category::FwSched,
        Category::Barrier,
        Category::UpiTransfer,
        Category::Idle,
    ];
    cats.iter()
        .map(|c| format!("{:>5.1}%", r.breakdown.frac(*c) * 100.0))
        .collect::<Vec<_>>()
        .join(" ")
}

/// Header matching [`breakdown_cols`].
pub(crate) fn breakdown_header() -> &'static str {
    "  mkl   mklp  tfprep native sched  barr   upi   idle"
}
