//! Figure 18 + Table 2 — the headline evaluation (paper §8): the width
//! guideline vs the Intel/TensorFlow recommendations vs the exhaustive
//! global optimum, on the holdout workload set, on `large.2`.

use std::fmt::Write as _;

use crate::config::CpuPlatform;
use crate::graph::analyze_width;
use crate::models;
use crate::tuner::{baseline_config, exhaustive_search, tune, Baseline};

use super::run;

/// The §8 holdout workloads (vision + recommendation + translation).
pub const EVAL_MODELS: [&str; 7] = [
    "densenet121",
    "squeezenet",
    "resnet50",
    "inception_v3",
    "wide_deep",
    "ncf",
    "transformer",
];

/// One model's evaluation row: latencies under every setting.
#[derive(Debug, Clone)]
pub struct EvalRow {
    /// Model name.
    pub model: String,
    /// TF-performance-guide setting (the Fig. 18 baseline).
    pub tf_recommended: f64,
    /// Intel blog setting.
    pub intel: f64,
    /// Out-of-the-box TF default.
    pub tf_default: f64,
    /// This work (width guideline).
    pub ours: f64,
    /// Exhaustive-search optimum.
    pub global_opt: f64,
}

impl EvalRow {
    /// Speedup of `ours` over the TF-recommended baseline.
    pub fn speedup_vs_tf(&self) -> f64 {
        self.tf_recommended / self.ours
    }

    /// Speedup of `ours` over Intel's setting.
    pub fn speedup_vs_intel(&self) -> f64 {
        self.intel / self.ours
    }

    /// Fraction of globally-optimal performance we achieve.
    pub fn fraction_of_optimum(&self) -> f64 {
        self.global_opt / self.ours
    }
}

/// Evaluate one model on a platform.
pub fn eval_model(name: &str, p: &CpuPlatform) -> EvalRow {
    let g = models::build(name, models::canonical_batch(name)).unwrap();
    let lat = |cfg: &crate::config::FrameworkConfig| run(&g, p, cfg).latency_s;
    EvalRow {
        model: name.to_string(),
        tf_recommended: lat(&baseline_config(Baseline::TensorFlowRecommended, p)),
        intel: lat(&baseline_config(Baseline::IntelRecommended, p)),
        tf_default: lat(&baseline_config(Baseline::TensorFlowDefault, p)),
        ours: lat(&tune(&g, p).config),
        global_opt: exhaustive_search(&g, p).expect("zoo graphs simulate").best_latency_s,
    }
}

/// All Fig. 18 rows.
pub fn fig18_rows() -> Vec<EvalRow> {
    let p = CpuPlatform::large2();
    EVAL_MODELS.iter().map(|m| eval_model(m, &p)).collect()
}

/// Fig. 18: normalised performance per setting (baseline = TF-recommended).
pub fn fig18_guideline_evaluation() -> String {
    let rows = fig18_rows();
    let mut out =
        String::from("Fig 18 — performance vs recommended settings (large.2, higher is better)\n");
    let _ = writeln!(
        out,
        "{:<14} {:>8} {:>8} {:>8} {:>8} {:>8}",
        "model", "TF-rec", "Intel", "TF-dflt", "ours", "optimum"
    );
    for r in &rows {
        let norm = |lat: f64| r.tf_recommended / lat;
        let _ = writeln!(
            out,
            "{:<14} {:>8.2} {:>8.2} {:>8.2} {:>8.2} {:>8.2}",
            r.model,
            1.0,
            norm(r.intel),
            norm(r.tf_default),
            norm(r.ours),
            norm(r.global_opt),
        );
    }
    let gm = |f: &dyn Fn(&EvalRow) -> f64| {
        crate::util::stats::geomean(&rows.iter().map(|r| f(r)).collect::<Vec<_>>())
    };
    let _ = writeln!(
        out,
        "geomean: ours/TF-rec = {:.2}x, ours/Intel = {:.2}x, ours/optimum = {:.1}%",
        gm(&|r| r.speedup_vs_tf()),
        gm(&|r| r.speedup_vs_intel()),
        gm(&|r| r.fraction_of_optimum()) * 100.0
    );
    out
}

/// Table 2: average model width (= the pool count our guideline selects).
pub fn table2_average_widths() -> String {
    let mut out = String::from("Table 2 — average model width (pools selected by the guideline)\n");
    let mut names = String::new();
    let mut widths = String::new();
    for m in EVAL_MODELS {
        let g = models::build(m, models::canonical_batch(m)).unwrap();
        let w = analyze_width(&g);
        let _ = write!(names, "{:>13}", m);
        let _ = write!(widths, "{:>13}", w.avg_width);
    }
    let _ = writeln!(out, "{names}");
    let _ = writeln!(out, "{widths}");
    out.push_str("intra-op and MKL threads = physical cores / width\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::geomean;

    fn rows() -> Vec<EvalRow> {
        fig18_rows()
    }

    #[test]
    fn ours_beats_both_recommendations_on_average() {
        let rows = rows();
        let vs_tf = geomean(&rows.iter().map(EvalRow::speedup_vs_tf).collect::<Vec<_>>());
        let vs_intel = geomean(&rows.iter().map(EvalRow::speedup_vs_intel).collect::<Vec<_>>());
        // paper: 1.34× over TF-rec and 1.29× over Intel. Our simulator
        // reproduces the ordering with more conservative magnitudes
        // (~1.25× / ~1.06×) because our conv kernels saturate earlier,
        // which *helps* Intel's 24-thread setting — see EXPERIMENTS.md.
        assert!(vs_tf > 1.15, "vs TF-rec: {vs_tf}");
        assert!(vs_intel > 1.03, "vs Intel: {vs_intel}");
    }

    #[test]
    fn ours_within_5pct_of_optimum_everywhere() {
        for r in rows() {
            let frac = r.fraction_of_optimum();
            assert!(frac > 0.949, "{}: {:.3} of optimum", r.model, frac);
        }
    }

    #[test]
    fn tf_default_much_worse() {
        let rows = rows();
        let dflt = geomean(&rows.iter().map(|r| r.tf_recommended / r.tf_default).collect::<Vec<_>>());
        assert!(dflt < 0.9, "TF default should lag TF recommended: {dflt}");
    }

    #[test]
    fn intel_beats_tf_on_recsys_and_translation() {
        // paper: "Intel's settings perform better than TensorFlow's for
        // recommendation and translation models"
        for r in rows() {
            if ["ncf", "transformer", "wide_deep"].contains(&r.model.as_str()) {
                assert!(r.intel <= r.tf_recommended * 1.001, "{}: intel={} tf={}", r.model, r.intel, r.tf_recommended);
            }
        }
    }

    #[test]
    fn never_meaningfully_slower_than_recommended() {
        // the paper's robustness claim: worst case ≥95% of the optimum;
        // SqueezeNet is one of its two acknowledged sub-optimal cases (the
        // guideline picks avg-width 1 pools while the fire modules have
        // max width 2), so allow the same ≤5% slack vs the baselines
        for r in rows() {
            assert!(r.ours <= r.tf_recommended * 1.053, "{}", r.model);
            assert!(r.ours <= r.intel * 1.053, "{}", r.model);
        }
    }

    #[test]
    fn ours_strictly_wins_on_recsys_and_translation() {
        for r in rows() {
            if ["ncf", "wide_deep", "transformer"].contains(&r.model.as_str()) {
                assert!(r.ours < r.tf_recommended, "{}", r.model);
                assert!(r.ours <= r.intel * 1.001, "{}", r.model);
            }
        }
    }
}
