//! Splitting the machine into inter-op pools (paper Fig. 3c).
//!
//! Pools receive contiguous, equal ranges of physical cores. In
//! model-parallel mode pools are aligned to sockets where possible
//! (paper §7.2: "two inter-op pools, one per CPU socket").

use crate::config::{CpuPlatform, FrameworkConfig, ParallelismMode};

/// One pool's slice of the machine.
#[derive(Debug, Clone, PartialEq)]
pub struct PoolAssignment {
    /// First physical core.
    pub first_core: usize,
    /// Number of physical cores.
    pub cores: usize,
    /// Whether the range crosses a socket boundary.
    pub spans_sockets: bool,
    /// Number of sockets covered.
    pub sockets_used: usize,
}

/// Partition the platform for a framework setting. The pool count is
/// clamped to the physical core count (additional pools could never run
/// concurrently anyway; over-threading is penalised separately).
pub fn partition_pools(platform: &CpuPlatform, cfg: &FrameworkConfig) -> Vec<PoolAssignment> {
    let phys = platform.physical_cores();
    let pools = cfg.inter_op_pools.max(1).min(phys.max(1));
    let cpp = (phys / pools).max(1);
    (0..pools)
        .map(|p| {
            let first = match cfg.parallelism {
                // model-parallel: round-robin pools over sockets so pool i
                // lands on socket i % sockets when sizes allow
                ParallelismMode::ModelParallel if pools % platform.sockets == 0 => {
                    let per_socket = pools / platform.sockets;
                    let socket = p % platform.sockets;
                    let slot = p / platform.sockets;
                    socket * platform.cores_per_socket + slot * cpp.min(platform.cores_per_socket / per_socket.max(1))
                }
                _ => p * cpp,
            };
            let last = (first + cpp - 1).min(phys - 1);
            let spans = platform.sockets > 1 && platform.socket_of(first) != platform.socket_of(last);
            PoolAssignment {
                first_core: first,
                cores: cpp,
                spans_sockets: spans,
                sockets_used: if spans { 2 } else { 1 },
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FrameworkConfig;

    #[test]
    fn even_split_single_socket() {
        let p = CpuPlatform::large();
        let mut cfg = FrameworkConfig::tuned_default();
        cfg.inter_op_pools = 3;
        let pools = partition_pools(&p, &cfg);
        assert_eq!(pools.len(), 3);
        assert!(pools.iter().all(|a| a.cores == 8));
        assert_eq!(pools[1].first_core, 8);
        assert!(pools.iter().all(|a| !a.spans_sockets));
    }

    #[test]
    fn one_pool_spans_two_sockets() {
        let p = CpuPlatform::large2();
        let cfg = FrameworkConfig { inter_op_pools: 1, ..FrameworkConfig::tuned_default() };
        let pools = partition_pools(&p, &cfg);
        assert_eq!(pools.len(), 1);
        assert!(pools[0].spans_sockets);
        assert_eq!(pools[0].sockets_used, 2);
    }

    #[test]
    fn two_pools_align_to_sockets() {
        let p = CpuPlatform::large2();
        let cfg = FrameworkConfig { inter_op_pools: 2, ..FrameworkConfig::tuned_default() };
        let pools = partition_pools(&p, &cfg);
        assert_eq!(pools[0].first_core, 0);
        assert_eq!(pools[1].first_core, 24);
        assert!(pools.iter().all(|a| !a.spans_sockets));
    }

    #[test]
    fn pool_count_clamped_to_cores() {
        let p = CpuPlatform::small();
        let cfg = FrameworkConfig { inter_op_pools: 100, ..FrameworkConfig::tuned_default() };
        assert_eq!(partition_pools(&p, &cfg).len(), 4);
    }
}
