//! Splitting the machine into inter-op pools (paper Fig. 3c) and into
//! per-lane core slices for the serving coordinator.
//!
//! Pools receive contiguous, equal ranges of physical cores. In
//! model-parallel mode pools are aligned to sockets where possible
//! (paper §7.2: "two inter-op pools, one per CPU socket").
//! [`split_cores`] does the serving-side equivalent one level up:
//! dividing the machine between lane groups proportionally to traffic
//! weights, with no slice ever overlapping another.

use crate::config::{CpuPlatform, FrameworkConfig, ParallelismMode};
use crate::error::{PallasError, PallasResult};

/// A contiguous slice of physical cores granted to one worker lane (or
/// one lane group). Slices never overlap within a valid lane plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CoreAllocation {
    /// First physical core of the slice.
    pub first_core: usize,
    /// Number of physical cores in the slice.
    pub cores: usize,
}

impl CoreAllocation {
    /// Slice starting at `first_core` spanning `cores` cores.
    pub fn new(first_core: usize, cores: usize) -> Self {
        CoreAllocation { first_core, cores }
    }

    /// Last physical core of the slice (inclusive).
    pub fn last_core(&self) -> usize {
        self.first_core + self.cores.max(1) - 1
    }

    /// One past the last core (exclusive end).
    pub fn end(&self) -> usize {
        self.first_core + self.cores
    }

    /// True when the two slices share any physical core.
    pub fn overlaps(&self, other: &CoreAllocation) -> bool {
        self.first_core < other.end() && other.first_core < self.end()
    }

    /// True when `core` belongs to this slice.
    pub fn contains(&self, core: usize) -> bool {
        (self.first_core..self.end()).contains(&core)
    }
}

/// Split the machine's physical cores into contiguous, non-overlapping
/// slices proportional to `weights` (largest-remainder rounding, every
/// slice ≥ 1 core so a drained model keeps a lane alive). Deterministic:
/// remainder ties break to the lowest index. Errors when there are more
/// weights than physical cores, or no weights at all.
pub fn split_cores(platform: &CpuPlatform, weights: &[f64]) -> PallasResult<Vec<CoreAllocation>> {
    let n = weights.len();
    let phys = platform.physical_cores();
    if n == 0 {
        return Err(PallasError::InvalidPlan("split_cores: no weights".into()));
    }
    if n > phys {
        return Err(PallasError::InvalidPlan(format!(
            "split_cores: {n} groups need at least {n} cores, machine has {phys}"
        )));
    }
    let total: f64 = weights.iter().map(|w| w.max(0.0)).sum();
    let norm: Vec<f64> = if total > 0.0 {
        weights.iter().map(|w| w.max(0.0) / total).collect()
    } else {
        vec![1.0 / n as f64; n]
    };
    // every group starts at 1 core; the rest go out by largest remainder
    let spare = phys - n;
    let ideal: Vec<f64> = norm.iter().map(|f| f * spare as f64).collect();
    let mut counts: Vec<usize> = ideal.iter().map(|x| x.floor() as usize).collect();
    let mut used: usize = counts.iter().sum();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        let ra = ideal[a] - ideal[a].floor();
        let rb = ideal[b] - ideal[b].floor();
        rb.partial_cmp(&ra).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    let mut i = 0;
    while used < spare {
        counts[order[i % n]] += 1;
        used += 1;
        i += 1;
    }
    let mut out = Vec::with_capacity(n);
    let mut first = 0;
    for c in counts {
        let cores = c + 1;
        out.push(CoreAllocation { first_core: first, cores });
        first += cores;
    }
    Ok(out)
}

/// One pool's slice of the machine.
#[derive(Debug, Clone, PartialEq)]
pub struct PoolAssignment {
    /// First physical core.
    pub first_core: usize,
    /// Number of physical cores.
    pub cores: usize,
    /// Whether the range crosses a socket boundary.
    pub spans_sockets: bool,
    /// Number of sockets covered.
    pub sockets_used: usize,
}

/// Partition the platform for a framework setting. The pool count is
/// clamped to the physical core count (additional pools could never run
/// concurrently anyway; over-threading is penalised separately).
pub fn partition_pools(platform: &CpuPlatform, cfg: &FrameworkConfig) -> Vec<PoolAssignment> {
    let phys = platform.physical_cores();
    let pools = cfg.inter_op_pools.max(1).min(phys.max(1));
    let cpp = (phys / pools).max(1);
    (0..pools)
        .map(|p| {
            let first = match cfg.parallelism {
                // model-parallel: round-robin pools over sockets so pool i
                // lands on socket i % sockets when sizes allow
                ParallelismMode::ModelParallel if pools % platform.sockets == 0 => {
                    let per_socket = pools / platform.sockets;
                    let socket = p % platform.sockets;
                    let slot = p / platform.sockets;
                    socket * platform.cores_per_socket + slot * cpp.min(platform.cores_per_socket / per_socket.max(1))
                }
                _ => p * cpp,
            };
            let last = (first + cpp - 1).min(phys - 1);
            let spans = platform.sockets > 1 && platform.socket_of(first) != platform.socket_of(last);
            PoolAssignment {
                first_core: first,
                cores: cpp,
                spans_sockets: spans,
                sockets_used: if spans { 2 } else { 1 },
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FrameworkConfig;

    #[test]
    fn even_split_single_socket() {
        let p = CpuPlatform::large();
        let mut cfg = FrameworkConfig::tuned_default();
        cfg.inter_op_pools = 3;
        let pools = partition_pools(&p, &cfg);
        assert_eq!(pools.len(), 3);
        assert!(pools.iter().all(|a| a.cores == 8));
        assert_eq!(pools[1].first_core, 8);
        assert!(pools.iter().all(|a| !a.spans_sockets));
    }

    #[test]
    fn one_pool_spans_two_sockets() {
        let p = CpuPlatform::large2();
        let cfg = FrameworkConfig { inter_op_pools: 1, ..FrameworkConfig::tuned_default() };
        let pools = partition_pools(&p, &cfg);
        assert_eq!(pools.len(), 1);
        assert!(pools[0].spans_sockets);
        assert_eq!(pools[0].sockets_used, 2);
    }

    #[test]
    fn two_pools_align_to_sockets() {
        let p = CpuPlatform::large2();
        let cfg = FrameworkConfig { inter_op_pools: 2, ..FrameworkConfig::tuned_default() };
        let pools = partition_pools(&p, &cfg);
        assert_eq!(pools[0].first_core, 0);
        assert_eq!(pools[1].first_core, 24);
        assert!(pools.iter().all(|a| !a.spans_sockets));
    }

    #[test]
    fn pool_count_clamped_to_cores() {
        let p = CpuPlatform::small();
        let cfg = FrameworkConfig { inter_op_pools: 100, ..FrameworkConfig::tuned_default() };
        assert_eq!(partition_pools(&p, &cfg).len(), 4);
    }

    #[test]
    fn allocation_overlap_and_bounds() {
        let a = CoreAllocation::new(0, 8);
        let b = CoreAllocation::new(8, 4);
        let c = CoreAllocation::new(6, 4);
        assert!(!a.overlaps(&b));
        assert!(!b.overlaps(&a));
        assert!(a.overlaps(&c) && c.overlaps(&a) && b.overlaps(&c));
        assert_eq!(a.last_core(), 7);
        assert_eq!(a.end(), 8);
        assert!(a.contains(0) && a.contains(7) && !a.contains(8));
    }

    #[test]
    fn split_cores_proportional_and_exhaustive() {
        let p = CpuPlatform::large(); // 24 cores
        let allocs = split_cores(&p, &[3.0, 1.0]).unwrap();
        assert_eq!(allocs.len(), 2);
        let total: usize = allocs.iter().map(|a| a.cores).sum();
        assert_eq!(total, 24);
        assert_eq!(allocs[0].first_core, 0);
        assert_eq!(allocs[1].first_core, allocs[0].cores);
        assert!(allocs[0].cores > allocs[1].cores);
        assert!(!allocs[0].overlaps(&allocs[1]));
    }

    #[test]
    fn split_cores_zero_weight_keeps_a_core() {
        let p = CpuPlatform::large();
        let allocs = split_cores(&p, &[1.0, 0.0]).unwrap();
        assert_eq!(allocs[1].cores, 1, "drained group keeps one core");
        assert_eq!(allocs[0].cores, 23);
    }

    #[test]
    fn split_cores_all_zero_falls_back_to_equal() {
        let p = CpuPlatform::large();
        let allocs = split_cores(&p, &[0.0, 0.0, 0.0]).unwrap();
        assert_eq!(allocs.iter().map(|a| a.cores).sum::<usize>(), 24);
        assert!(allocs.iter().all(|a| a.cores == 8));
    }

    #[test]
    fn split_cores_rejects_impossible() {
        let p = CpuPlatform::small(); // 4 cores
        assert!(split_cores(&p, &[]).is_err());
        assert!(split_cores(&p, &[1.0; 5]).is_err());
        assert_eq!(split_cores(&p, &[1.0; 4]).unwrap().len(), 4);
    }
}
