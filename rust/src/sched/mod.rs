//! Operator-scheduling building blocks shared by the simulator's engine
//! and the serving coordinator: pool partitioning (how physical cores are
//! split into inter-op pools, paper Fig. 3c), core-aware lane planning
//! (how the machine is divided between serving lane groups, with §8
//! knobs per slice), and the policy-driven priority ready set that
//! implements asynchronous scheduling under a pluggable
//! [`crate::config::SchedPolicy`] (topological, critical-path-first, or
//! costliest-first dispatch).

pub mod lanes;
pub mod partition;
pub mod ready;

pub use lanes::{pick_lane, LaneAssignment, LaneGroup, LanePlan};
pub use partition::{partition_pools, split_cores, CoreAllocation, PoolAssignment};
pub use ready::{ConsumerCsr, ReadyQueue};
