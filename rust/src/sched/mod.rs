//! Operator-scheduling building blocks shared by the simulator's engine
//! and the serving coordinator: pool partitioning (how physical cores are
//! split into inter-op pools, paper Fig. 3c) and the topological ready
//! queue that implements asynchronous scheduling.

pub mod partition;
pub mod ready;

pub use partition::{partition_pools, PoolAssignment};
pub use ready::ReadyQueue;
