//! Policy-driven ready set: tracks dependency counts and yields runnable
//! operators in the order the configured [`SchedPolicy`] asks for —
//! topological id order (the classic behaviour), HEFT-style
//! critical-path-first, or largest-op-first.

use std::collections::BinaryHeap;
use std::sync::Arc;

use crate::config::SchedPolicy;
use crate::graph::{self, Graph};

/// One ready node with its dispatch priority. Max-heap order: highest
/// priority pops first; equal priorities tie-break to the **lowest node
/// id**, so pop order is fully deterministic for every policy.
#[derive(Debug, PartialEq)]
struct ReadyEntry {
    priority: f64,
    node: usize,
}

impl Eq for ReadyEntry {}

impl Ord for ReadyEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // priorities are asserted finite at construction, so partial_cmp
        // cannot actually fail; Equal keeps the order total regardless
        self.priority
            .partial_cmp(&other.priority)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| other.node.cmp(&self.node))
    }
}

impl PartialOrd for ReadyEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Flat CSR consumer adjacency (offsets + one index array). Built once
/// per graph and shared behind an `Arc` by every [`ReadyQueue`] derived
/// from the same [`crate::sim::PreparedGraph`], so repeated simulations
/// of one graph stop re-deriving the adjacency.
#[derive(Debug)]
pub struct ConsumerCsr {
    offsets: Vec<u32>,
    flat: Vec<u32>,
}

impl ConsumerCsr {
    /// Derive the consumer lists of `graph`: count, prefix-sum, fill.
    pub fn build(graph: &Graph) -> Self {
        let n = graph.len();
        let mut offsets = vec![0u32; n + 1];
        for node in &graph.nodes {
            for d in &node.deps {
                offsets[d.0 + 1] += 1;
            }
        }
        for i in 0..n {
            offsets[i + 1] += offsets[i];
        }
        let mut cursor = offsets.clone();
        let mut flat = vec![0u32; offsets[n] as usize];
        for node in &graph.nodes {
            for d in &node.deps {
                flat[cursor[d.0] as usize] = node.id.0 as u32;
                cursor[d.0] += 1;
            }
        }
        ConsumerCsr { offsets, flat }
    }
}

/// Dependency-tracking ready set over a graph.
///
/// The consumer adjacency is stored as a flat CSR layout (offsets + one
/// index array) rather than `Vec<Vec<_>>`: a `ReadyQueue` is built once
/// per simulated execution, and the exhaustive tuner runs hundreds of
/// simulations per graph, so the n-small-allocations pattern showed up in
/// the §Perf profile. The ready set itself is a binary heap — O(log n)
/// insert/pop instead of the old sorted-`Vec`'s O(n) insertion. The CSR
/// and the priority table sit behind `Arc`s so a prepared graph can hand
/// them out without recomputation.
pub struct ReadyQueue {
    remaining: Vec<usize>,
    cons: Arc<ConsumerCsr>,
    /// max-heap of ready nodes: highest priority first, ties to lowest id
    ready: BinaryHeap<ReadyEntry>,
    /// per-node dispatch priority; `None` ⇒ uniform, i.e. pure
    /// topological id order (saves the rank sweep on the hot Topo path)
    priority: Option<Arc<Vec<f64>>>,
    outstanding: usize,
}

impl ReadyQueue {
    /// Build from a graph with topological dispatch order; sources start
    /// ready.
    pub fn new(graph: &Graph) -> Self {
        Self::with_policy(graph, SchedPolicy::Topo)
    }

    /// Build from a graph with the given dispatch policy.
    pub fn with_policy(graph: &Graph, policy: SchedPolicy) -> Self {
        let priority = match policy {
            SchedPolicy::Topo => None,
            SchedPolicy::CriticalPathFirst => Some(Arc::new(graph::upward_ranks(graph))),
            SchedPolicy::CostlyFirst => Some(Arc::new(
                graph.nodes.iter().map(|nd| graph::dispatch_weight(&nd.cost)).collect(),
            )),
        };
        let remaining: Vec<usize> = graph.nodes.iter().map(|nd| nd.deps.len()).collect();
        Self::from_parts(remaining, Arc::new(ConsumerCsr::build(graph)), priority)
    }

    /// Assemble from precomputed parts (the `PreparedGraph` fast path).
    /// `remaining` carries each node's dependency count; `priority` must
    /// be the same table [`Self::with_policy`] would derive for the
    /// intended policy, so both constructors dispatch bit-identically.
    pub fn from_parts(
        remaining: Vec<usize>,
        cons: Arc<ConsumerCsr>,
        priority: Option<Arc<Vec<f64>>>,
    ) -> Self {
        if let Some(p) = &priority {
            debug_assert!(p.iter().all(|x| x.is_finite()), "non-finite dispatch priority");
        }
        let n = remaining.len();
        let mut q = ReadyQueue {
            remaining,
            cons,
            ready: BinaryHeap::with_capacity(16),
            priority,
            outstanding: n,
        };
        for i in 0..n {
            if q.remaining[i] == 0 {
                q.push_ready(i);
            }
        }
        q
    }

    fn push_ready(&mut self, node: usize) {
        let priority = self.priority.as_ref().map_or(0.0, |p| p[node]);
        self.ready.push(ReadyEntry { priority, node });
    }

    /// Next runnable node (highest dispatch priority), if any.
    pub fn pop(&mut self) -> Option<usize> {
        self.ready.pop().map(|e| e.node)
    }

    /// Mark a node complete, unlocking its consumers.
    pub fn complete(&mut self, node: usize) {
        self.outstanding -= 1;
        let lo = self.cons.offsets[node] as usize;
        let hi = self.cons.offsets[node + 1] as usize;
        for i in lo..hi {
            let c = self.cons.flat[i] as usize;
            self.remaining[c] -= 1;
            if self.remaining[c] == 0 {
                self.push_ready(c);
            }
        }
    }

    /// Count of nodes not yet completed.
    pub fn outstanding(&self) -> usize {
        self.outstanding
    }

    /// True when every node has completed.
    pub fn finished(&self) -> bool {
        self.outstanding == 0
    }

    /// Number of currently-ready nodes (instantaneous width).
    pub fn ready_count(&self) -> usize {
        self.ready.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::ops::OpKind;

    fn diamond() -> Graph {
        let k = OpKind::Pool { elems: 1 };
        let mut b = GraphBuilder::new("d", 1);
        let a = b.add("a", k.clone(), &[]);
        let l = b.add("l", k.clone(), &[a]);
        let r = b.add("r", k.clone(), &[a]);
        b.add("j", k, &[l, r]);
        b.build()
    }

    #[test]
    fn topological_release() {
        let g = diamond();
        let mut q = ReadyQueue::new(&g);
        assert_eq!(q.pop(), Some(0));
        assert_eq!(q.pop(), None); // l, r blocked
        q.complete(0);
        assert_eq!(q.ready_count(), 2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        q.complete(1);
        assert_eq!(q.pop(), None); // join still blocked on r
        q.complete(2);
        assert_eq!(q.pop(), Some(3));
        q.complete(3);
        assert!(q.finished());
    }

    #[test]
    fn outstanding_counts_down() {
        let g = diamond();
        let mut q = ReadyQueue::new(&g);
        assert_eq!(q.outstanding(), 4);
        let n = q.pop().unwrap();
        q.complete(n);
        assert_eq!(q.outstanding(), 3);
    }

    #[test]
    fn critical_path_prefers_longer_branch() {
        // a → {short (id 1), long chain (ids 2→3→4)}: topo pops 1 first,
        // critical-path pops the head of the long chain first
        let mm = OpKind::MatMul { m: 128, k: 128, n: 128 };
        let mut b = GraphBuilder::new("y", 1);
        let a = b.add("a", mm.clone(), &[]);
        b.add("short", mm.clone(), &[a]);
        let l1 = b.add("l1", mm.clone(), &[a]);
        let l2 = b.add("l2", mm.clone(), &[l1]);
        b.add("l3", mm, &[l2]);
        let g = b.build();

        let mut topo = ReadyQueue::new(&g);
        topo.complete(topo.pop().unwrap());
        assert_eq!(topo.pop(), Some(1));

        let mut cp = ReadyQueue::with_policy(&g, SchedPolicy::CriticalPathFirst);
        cp.complete(cp.pop().unwrap());
        assert_eq!(cp.pop(), Some(2), "critical-path must dispatch the chain head first");
    }

    #[test]
    fn costly_first_prefers_bigger_op() {
        let mut b = GraphBuilder::new("c", 1);
        let a = b.add("a", OpKind::Pool { elems: 1 }, &[]);
        b.add("small", OpKind::MatMul { m: 64, k: 64, n: 64 }, &[a]);
        b.add("big", OpKind::MatMul { m: 512, k: 512, n: 512 }, &[a]);
        let g = b.build();
        let mut q = ReadyQueue::with_policy(&g, SchedPolicy::CostlyFirst);
        q.complete(q.pop().unwrap());
        assert_eq!(q.pop(), Some(2), "costly-first must dispatch the big matmul first");
    }

    #[test]
    fn equal_priorities_tie_break_on_node_id() {
        // a star of identical children: every policy must pop them in
        // ascending id order (the determinism micro-assert of the heap
        // refactor — equal priorities cannot reorder)
        let k = OpKind::Pool { elems: 64 };
        let mut b = GraphBuilder::new("star", 1);
        let a = b.add("a", k.clone(), &[]);
        for i in 0..6 {
            b.add(&format!("c{i}"), k.clone(), &[a]);
        }
        let g = b.build();
        for policy in SchedPolicy::ALL {
            let mut q = ReadyQueue::with_policy(&g, policy);
            q.complete(q.pop().unwrap());
            let order: Vec<usize> = std::iter::from_fn(|| q.pop()).collect();
            assert_eq!(order, vec![1, 2, 3, 4, 5, 6], "{policy:?}");
        }
    }

    #[test]
    fn all_policies_drain_every_node() {
        let g = crate::models::build("inception_v2", 4).unwrap();
        for policy in SchedPolicy::ALL {
            let mut q = ReadyQueue::with_policy(&g, policy);
            let mut seen = 0usize;
            while let Some(n) = q.pop() {
                seen += 1;
                q.complete(n);
            }
            assert_eq!(seen, g.len(), "{policy:?}");
            assert!(q.finished(), "{policy:?}");
        }
    }
}
