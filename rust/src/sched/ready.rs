//! Topological ready queue: tracks dependency counts and yields runnable
//! operators in topological priority order (lowest node id first), which
//! keeps critical-path operators flowing ahead of stragglers.

use crate::graph::Graph;

/// Dependency-tracking ready queue over a graph.
///
/// The consumer adjacency is stored as a flat CSR layout (offsets + one
/// index array) rather than `Vec<Vec<_>>`: a `ReadyQueue` is built once
/// per simulated execution, and the exhaustive tuner runs hundreds of
/// simulations per graph, so the n-small-allocations pattern showed up in
/// the §Perf profile.
pub struct ReadyQueue {
    remaining: Vec<usize>,
    cons_offsets: Vec<u32>,
    cons_flat: Vec<u32>,
    /// ready node ids, kept sorted descending so `pop` takes the smallest
    ready: Vec<usize>,
    outstanding: usize,
}

impl ReadyQueue {
    /// Build from a graph; sources start ready.
    pub fn new(graph: &Graph) -> Self {
        let n = graph.len();
        let remaining: Vec<usize> = graph.nodes.iter().map(|nd| nd.deps.len()).collect();
        // CSR consumer lists: count, prefix-sum, fill
        let mut cons_offsets = vec![0u32; n + 1];
        for node in &graph.nodes {
            for d in &node.deps {
                cons_offsets[d.0 + 1] += 1;
            }
        }
        for i in 0..n {
            cons_offsets[i + 1] += cons_offsets[i];
        }
        let mut cursor = cons_offsets.clone();
        let mut cons_flat = vec![0u32; cons_offsets[n] as usize];
        for node in &graph.nodes {
            for d in &node.deps {
                cons_flat[cursor[d.0] as usize] = node.id.0 as u32;
                cursor[d.0] += 1;
            }
        }
        let mut ready: Vec<usize> = (0..n).filter(|&i| remaining[i] == 0).collect();
        ready.reverse();
        ReadyQueue { remaining, cons_offsets, cons_flat, ready, outstanding: n }
    }

    /// Next runnable node (topological order), if any.
    pub fn pop(&mut self) -> Option<usize> {
        self.ready.pop()
    }

    /// Mark a node complete, unlocking its consumers.
    pub fn complete(&mut self, node: usize) {
        self.outstanding -= 1;
        let lo = self.cons_offsets[node] as usize;
        let hi = self.cons_offsets[node + 1] as usize;
        for i in lo..hi {
            let c = self.cons_flat[i] as usize;
            self.remaining[c] -= 1;
            if self.remaining[c] == 0 {
                let pos = self.ready.partition_point(|&r| r > c);
                self.ready.insert(pos, c);
            }
        }
    }

    /// Count of nodes not yet completed.
    pub fn outstanding(&self) -> usize {
        self.outstanding
    }

    /// True when every node has completed.
    pub fn finished(&self) -> bool {
        self.outstanding == 0
    }

    /// Number of currently-ready nodes (instantaneous width).
    pub fn ready_count(&self) -> usize {
        self.ready.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::ops::OpKind;

    fn diamond() -> Graph {
        let k = OpKind::Pool { elems: 1 };
        let mut b = GraphBuilder::new("d", 1);
        let a = b.add("a", k.clone(), &[]);
        let l = b.add("l", k.clone(), &[a]);
        let r = b.add("r", k.clone(), &[a]);
        b.add("j", k, &[l, r]);
        b.build()
    }

    #[test]
    fn topological_release() {
        let g = diamond();
        let mut q = ReadyQueue::new(&g);
        assert_eq!(q.pop(), Some(0));
        assert_eq!(q.pop(), None); // l, r blocked
        q.complete(0);
        assert_eq!(q.ready_count(), 2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        q.complete(1);
        assert_eq!(q.pop(), None); // join still blocked on r
        q.complete(2);
        assert_eq!(q.pop(), Some(3));
        q.complete(3);
        assert!(q.finished());
    }

    #[test]
    fn outstanding_counts_down() {
        let g = diamond();
        let mut q = ReadyQueue::new(&g);
        assert_eq!(q.outstanding(), 4);
        let n = q.pop().unwrap();
        q.complete(n);
        assert_eq!(q.outstanding(), 3);
    }
}
