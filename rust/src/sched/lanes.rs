//! Core-aware lane planning for the serving coordinator.
//!
//! A [`LanePlan`] splits the machine's physical cores into non-overlapping
//! [`CoreAllocation`]s — one **lane group** per served model kind — and
//! gives every group a [`FrameworkConfig`] chosen by the paper's §8
//! guideline *on the group's own slice* (the prior the online re-tuner
//! starts from). Worker lanes within a group split the group's slice
//! further, so no two lanes ever share a physical core: co-located lanes
//! stop double-counting hardware, and "how fast is my model" becomes a
//! question about the lane's slice, not the whole box.
//!
//! [`pick_lane`] is the load-aware dispatch rule the coordinator's
//! batching loop uses in place of round-robin: least queued items among
//! the lanes hosting a batch's kind, ties to the lowest lane index.

use crate::config::{CpuPlatform, FrameworkConfig, SchedPolicy};
use crate::error::{PallasError, PallasResult};
use crate::models;
use crate::runtime::KindTable;
use crate::tuner::guidelines;

use super::partition::{split_cores, CoreAllocation};

/// Everything a worker lane needs to know about *where* it runs: its
/// physical-core slice, the model kinds it hosts, and the framework knobs
/// tuned for that slice. This is the core-allocation input of the
/// backend contract (`runtime::BackendFactory::create_on`).
#[derive(Debug, Clone, PartialEq)]
pub struct LaneAssignment {
    /// Lane index within the plan (names the worker thread).
    pub lane_id: usize,
    /// Physical cores this lane may use.
    pub allocation: CoreAllocation,
    /// Model kinds hosted (empty ⇒ every catalog kind).
    pub kinds: Vec<String>,
    /// Framework knobs for this lane; `None` lets the backend pick.
    pub framework: Option<FrameworkConfig>,
}

impl LaneAssignment {
    /// Dense hosted-kind mask over a [`KindTable`]: `mask[id] == true`
    /// iff this lane hosts the kind — dispatch tests membership by
    /// [`crate::runtime::KindId`] index instead of scanning a string
    /// list. `None` when the assignment hosts every kind (empty list);
    /// names outside the table are ignored (the plan may mention kinds
    /// the catalog doesn't serve).
    pub fn host_mask(&self, table: &KindTable) -> Option<Box<[bool]>> {
        if self.kinds.is_empty() {
            return None;
        }
        let mut mask = vec![false; table.len()].into_boxed_slice();
        for name in &self.kinds {
            if let Some(id) = table.resolve(name) {
                mask[id.index()] = true;
            }
        }
        Some(mask)
    }
}

/// One group of identical lanes serving one set of model kinds on a
/// dedicated core slice.
#[derive(Debug, Clone, PartialEq)]
pub struct LaneGroup {
    /// Model kinds this group hosts (usually one).
    pub kinds: Vec<String>,
    /// The group's slice of the machine.
    pub allocation: CoreAllocation,
    /// Worker lanes splitting the slice (≥ 1).
    pub lanes: usize,
    /// Framework knobs for every lane in the group.
    pub framework: FrameworkConfig,
}

/// A full serving plan: how the machine is divided between lane groups.
#[derive(Debug, Clone, PartialEq)]
pub struct LanePlan {
    /// The machine being divided.
    pub platform: CpuPlatform,
    /// The lane groups, in core order.
    pub groups: Vec<LaneGroup>,
}

impl LanePlan {
    /// The §8-prior plan: one group per kind, equal core shares, each
    /// group's knobs from the guideline on its own slice.
    pub fn guideline(platform: &CpuPlatform, kinds: &[&str]) -> PallasResult<Self> {
        let mix: Vec<(String, f64)> = kinds.iter().map(|k| (k.to_string(), 1.0)).collect();
        Self::for_mix(platform, &mix)
    }

    /// Plan for a traffic mix: core shares proportional to each kind's
    /// weight (zero-weight kinds keep one core so a drained model stays
    /// servable), framework knobs from the §8 guideline on each slice.
    pub fn for_mix(platform: &CpuPlatform, mix: &[(String, f64)]) -> PallasResult<Self> {
        if mix.is_empty() {
            return Err(PallasError::InvalidPlan("lane plan: no model kinds".into()));
        }
        let weights: Vec<f64> = mix.iter().map(|(_, w)| *w).collect();
        let allocs = split_cores(platform, &weights)?;
        let mut groups = Vec::with_capacity(mix.len());
        for ((kind, _), alloc) in mix.iter().zip(allocs) {
            let slice = platform.restrict(alloc.first_core, alloc.cores);
            let graph = models::build(kind, models::canonical_batch(kind))
                .ok_or_else(|| PallasError::UnknownModel(kind.clone()))?;
            let framework = guidelines::tune(&graph, &slice).config;
            groups.push(LaneGroup {
                kinds: vec![kind.clone()],
                allocation: alloc,
                lanes: 1,
                framework,
            });
        }
        let plan = LanePlan { platform: platform.clone(), groups };
        plan.validate()?;
        Ok(plan)
    }

    /// Same plan with every group's dispatch policy overridden (the CLI's
    /// `serve --policy` pin; the online re-tuner may still propose flips
    /// back on a later re-plan).
    pub fn with_policy(mut self, policy: SchedPolicy) -> Self {
        for g in &mut self.groups {
            g.framework.sched_policy = policy;
        }
        self
    }

    /// Per-lane assignments: each group's slice split contiguously among
    /// its lanes (never more lanes than cores).
    pub fn lane_assignments(&self) -> Vec<LaneAssignment> {
        let mut out = Vec::new();
        let mut lane_id = 0;
        for grp in &self.groups {
            let lanes = grp.lanes.clamp(1, grp.allocation.cores.max(1));
            let per = grp.allocation.cores / lanes;
            let extra = grp.allocation.cores % lanes;
            let mut first = grp.allocation.first_core;
            for l in 0..lanes {
                let cores = per + usize::from(l < extra);
                out.push(LaneAssignment {
                    lane_id,
                    allocation: CoreAllocation::new(first, cores),
                    kinds: grp.kinds.clone(),
                    framework: Some(grp.framework.clone()),
                });
                first += cores;
                lane_id += 1;
            }
        }
        out
    }

    /// All kinds the plan hosts, sorted and deduplicated.
    pub fn kinds(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self
            .groups
            .iter()
            .flat_map(|g| g.kinds.iter().map(String::as_str))
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// True when some group hosts `kind`.
    pub fn hosts(&self, kind: &str) -> bool {
        self.groups.iter().any(|g| g.kinds.iter().any(|k| k == kind))
    }

    /// The group hosting `kind`, if any.
    pub fn group_for(&self, kind: &str) -> Option<&LaneGroup> {
        self.groups.iter().find(|g| g.kinds.iter().any(|k| k == kind))
    }

    /// Check the invariants the coordinator relies on: at least one
    /// group, every group hosting ≥ 1 kind on ≥ 1 core, and lane
    /// allocations pairwise disjoint and inside the machine.
    pub fn validate(&self) -> PallasResult<()> {
        let invalid = |m: String| Err(PallasError::InvalidPlan(m));
        if self.groups.is_empty() {
            return invalid("lane plan: no groups".into());
        }
        let phys = self.platform.physical_cores();
        let lanes = self.lane_assignments();
        for a in &lanes {
            if a.allocation.cores == 0 {
                return invalid(format!("lane {}: empty core allocation", a.lane_id));
            }
            if a.allocation.end() > phys {
                return invalid(format!(
                    "lane {}: cores {}..={} exceed the machine's {} physical cores",
                    a.lane_id,
                    a.allocation.first_core,
                    a.allocation.last_core(),
                    phys
                ));
            }
            if a.kinds.is_empty() {
                return invalid(format!("lane {}: hosts no model kind", a.lane_id));
            }
        }
        for (i, a) in lanes.iter().enumerate() {
            for b in &lanes[i + 1..] {
                if a.allocation.overlaps(&b.allocation) {
                    return invalid(format!(
                        "lanes {} and {} overlap on physical cores",
                        a.lane_id, b.lane_id
                    ));
                }
            }
        }
        Ok(())
    }
}

/// Least-loaded dispatch: the index with the smallest load among lanes
/// for which `hosts` is true, ties to the lowest index (so dispatch is
/// deterministic). `None` when no lane hosts the kind.
pub fn pick_lane(loads: &[usize], hosts: impl Fn(usize) -> bool) -> Option<usize> {
    let mut best: Option<usize> = None;
    for (i, &load) in loads.iter().enumerate() {
        if !hosts(i) {
            continue;
        }
        best = match best {
            Some(b) if loads[b] <= load => Some(b),
            _ => Some(i),
        };
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn guideline_plan_covers_machine_without_overlap() {
        let p = CpuPlatform::large2();
        let plan = LanePlan::guideline(&p, &["wide_deep", "resnet50"]).unwrap();
        assert_eq!(plan.groups.len(), 2);
        plan.validate().unwrap();
        let total: usize = plan.groups.iter().map(|g| g.allocation.cores).sum();
        assert_eq!(total, 48);
        assert_eq!(plan.groups[0].allocation.cores, 24);
        assert!(plan.hosts("wide_deep") && plan.hosts("resnet50"));
        assert!(!plan.hosts("ncf"));
        assert_eq!(plan.kinds(), vec!["resnet50", "wide_deep"]);
    }

    #[test]
    fn group_framework_tuned_for_slice_not_machine() {
        // wide_deep on its 24-core half: §8 says 3 pools × 8 threads —
        // not the 16 threads the whole-machine guideline would give
        let p = CpuPlatform::large2();
        let plan = LanePlan::guideline(&p, &["wide_deep", "resnet50"]).unwrap();
        let wd = plan.group_for("wide_deep").unwrap();
        assert_eq!(wd.framework.inter_op_pools, 3);
        assert_eq!(wd.framework.mkl_threads, 8);
        // resnet50 (chain): one pool over its whole slice
        let rn = plan.group_for("resnet50").unwrap();
        assert_eq!(rn.framework.inter_op_pools, 1);
        assert_eq!(rn.framework.mkl_threads, 24);
    }

    #[test]
    fn for_mix_shifts_cores_to_the_hot_kind() {
        let p = CpuPlatform::large2();
        let mix = vec![("wide_deep".to_string(), 0.1), ("resnet50".to_string(), 0.9)];
        let plan = LanePlan::for_mix(&p, &mix).unwrap();
        let wd = plan.group_for("wide_deep").unwrap();
        let rn = plan.group_for("resnet50").unwrap();
        assert!(rn.allocation.cores > 3 * wd.allocation.cores);
        assert!(wd.allocation.cores >= 1);
    }

    #[test]
    fn multi_lane_group_splits_slice() {
        let p = CpuPlatform::large();
        let mut plan = LanePlan::guideline(&p, &["wide_deep"]).unwrap();
        plan.groups[0].lanes = 3;
        plan.validate().unwrap();
        let lanes = plan.lane_assignments();
        assert_eq!(lanes.len(), 3);
        assert_eq!(lanes.iter().map(|a| a.allocation.cores).sum::<usize>(), 24);
        assert_eq!(lanes[0].allocation.first_core, 0);
        assert_eq!(lanes[1].allocation.first_core, lanes[0].allocation.end());
        assert!(lanes.iter().all(|a| a.kinds == vec!["wide_deep".to_string()]));
        // distinct lane ids
        assert_eq!(lanes[0].lane_id, 0);
        assert_eq!(lanes[2].lane_id, 2);
    }

    #[test]
    fn group_policy_follows_slice_guideline_and_flows_to_lanes() {
        // transformer (wide) gets critical-path dispatch on its slice,
        // resnet50 (chain) keeps topo — and the knob reaches the lane
        // assignments the backend contract consumes
        let p = CpuPlatform::large2();
        let plan = LanePlan::guideline(&p, &["transformer", "resnet50"]).unwrap();
        let tr = plan.group_for("transformer").unwrap();
        let rn = plan.group_for("resnet50").unwrap();
        assert_eq!(tr.framework.sched_policy, SchedPolicy::CriticalPathFirst);
        assert_eq!(rn.framework.sched_policy, SchedPolicy::Topo);
        for a in plan.lane_assignments() {
            let want = if a.kinds == vec!["transformer".to_string()] {
                SchedPolicy::CriticalPathFirst
            } else {
                SchedPolicy::Topo
            };
            assert_eq!(a.framework.as_ref().unwrap().sched_policy, want);
        }
    }

    #[test]
    fn with_policy_overrides_every_group() {
        let p = CpuPlatform::large2();
        let plan = LanePlan::guideline(&p, &["transformer", "resnet50"])
            .unwrap()
            .with_policy(SchedPolicy::CostlyFirst);
        plan.validate().unwrap();
        assert!(plan
            .groups
            .iter()
            .all(|g| g.framework.sched_policy == SchedPolicy::CostlyFirst));
    }

    #[test]
    fn unknown_model_rejected() {
        let p = CpuPlatform::large();
        assert!(LanePlan::guideline(&p, &["bert"]).is_err());
        assert!(LanePlan::guideline(&p, &[]).is_err());
    }

    #[test]
    fn validate_catches_overlap_and_overflow() {
        let p = CpuPlatform::large();
        let mut plan = LanePlan::guideline(&p, &["wide_deep", "resnet50"]).unwrap();
        plan.groups[1].allocation = plan.groups[0].allocation;
        assert!(plan.validate().is_err());
        let mut plan = LanePlan::guideline(&p, &["wide_deep"]).unwrap();
        plan.groups[0].allocation = CoreAllocation::new(20, 10);
        assert!(plan.validate().is_err());
    }

    #[test]
    fn host_mask_is_dense_by_kind_id() {
        let table = KindTable::new(vec!["wide_deep".into(), "ncf".into(), "transformer".into()]);
        let a = LaneAssignment {
            lane_id: 0,
            allocation: CoreAllocation::new(0, 4),
            kinds: vec!["transformer".into(), "bert".into()],
            framework: None,
        };
        let mask = a.host_mask(&table).unwrap();
        // unknown names ("bert") are ignored; hosted kinds flip their slot
        assert_eq!(&mask[..], &[false, false, true]);
        let all = LaneAssignment { kinds: vec![], ..a };
        assert!(all.host_mask(&table).is_none());
    }

    #[test]
    fn pick_lane_least_loaded_deterministic() {
        assert_eq!(pick_lane(&[3, 1, 2], |_| true), Some(1));
        // ties break to the lowest index
        assert_eq!(pick_lane(&[2, 2, 2], |_| true), Some(0));
        // host restriction wins over load
        assert_eq!(pick_lane(&[5, 0, 0], |i| i == 0), Some(0));
        assert_eq!(pick_lane(&[1, 1], |_| false), None);
        assert_eq!(pick_lane(&[], |_| true), None);
    }
}
