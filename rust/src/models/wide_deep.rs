//! Google's Wide & Deep recommendation model (Cheng et al.): a wide
//! cross-feature branch and a deep branch over embedded categorical
//! features. Three parallel embedding-class heavy ops on one level ⇒
//! average width 3 (paper Table 2).

use crate::graph::{Graph, GraphBuilder};
use crate::ops::OpKind;

use super::fc;

/// Census-income-class dimensions (the published W&D benchmark).
const WIDE_VOCAB: usize = 1_000_000; // crossed-feature hash buckets
const CAT_VOCAB: usize = 100_000;
const EMB_DIM: usize = 64;
const DENSE_FEATURES: usize = 13;

/// Build Wide & Deep at the given batch size.
pub fn wide_deep(batch: usize) -> Graph {
    let mut b = GraphBuilder::new("wide_deep", batch);
    let ids = b.add(
        "input_ids",
        OpKind::DataMovement { bytes: 8 * batch * 32, name: "Feed" },
        &[],
    );
    // wide path: one big hashed cross-feature lookup
    let wide = b.add(
        "wide/cross_emb",
        OpKind::Embedding { vocab: WIDE_VOCAB, dim: 1, rows: batch * 16 },
        &[ids],
    );
    // deep path: two grouped categorical-embedding gathers
    let deep_a = b.add(
        "deep/emb_group_a",
        OpKind::Embedding { vocab: CAT_VOCAB, dim: EMB_DIM, rows: batch * 8 },
        &[ids],
    );
    let deep_b = b.add(
        "deep/emb_group_b",
        OpKind::Embedding { vocab: CAT_VOCAB, dim: EMB_DIM, rows: batch * 8 },
        &[ids],
    );
    let cat = b.add(
        "deep/concat",
        OpKind::DataMovement {
            bytes: 4 * batch * (16 * EMB_DIM + DENSE_FEATURES),
            name: "Concat",
        },
        &[deep_a, deep_b],
    );
    // deep tower: 1024→512→256, light at serving batch sizes
    let in_f = 16 * EMB_DIM + DENSE_FEATURES;
    let h1 = fc(&mut b, "deep/fc1", batch, in_f, 1024, &[cat]);
    let h2 = fc(&mut b, "deep/fc2", batch, 1024, 512, &[h1]);
    let h3 = fc(&mut b, "deep/fc3", batch, 512, 256, &[h2]);
    // head: wide logit + deep logit
    let head = b.add(
        "head/concat",
        OpKind::DataMovement { bytes: 4 * batch * (16 + 256), name: "Concat" },
        &[wide, h3],
    );
    fc(&mut b, "head/logit", batch, 16 + 256, 1, &[head]);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::analyze_width;

    #[test]
    fn avg_width_3() {
        // paper Table 2: W/D = 3
        let w = analyze_width(&wide_deep(16));
        assert_eq!(w.avg_width, 3, "{w:?}");
        assert_eq!(w.max_width, 3, "{w:?}");
    }

    #[test]
    fn deep_tower_light_at_serving_batch() {
        let g = wide_deep(16);
        for n in g.nodes.iter().filter(|n| n.name.starts_with("deep/fc")) {
            assert!(!n.is_heavy(), "{}", n.name);
        }
    }

    #[test]
    fn validates() {
        assert!(wide_deep(64).validate().is_ok());
    }
}
