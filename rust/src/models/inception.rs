//! Inception family: GoogLeNet (v1), Inception v2 (BN-Inception), and
//! Inception v3 — the paper's central inter-op-parallelism workloads
//! (§4.2's case study is Inception v2; Fig. 1 is Inception v3).
//!
//! Module shapes follow the published architectures; the paper's analysis
//! consumes branch structure (inter-op width) and conv sizes (intra-op
//! cost), both encoded here.

use crate::graph::{Graph, GraphBuilder, NodeId};

use super::{concat, conv, pool, relu};

/// One inception-module branch: a sequence of (out_c, kernel) convs.
struct Branch(Vec<(usize, usize)>);

/// Emit an inception module; returns the concat node.
fn module(
    b: &mut GraphBuilder,
    name: &str,
    batch: usize,
    hw: usize,
    in_c: usize,
    branches: &[Branch],
    input: NodeId,
) -> (NodeId, usize) {
    let mut outs: Vec<NodeId> = Vec::new();
    let mut out_c_total = 0;
    for (bi, Branch(convs)) in branches.iter().enumerate() {
        let mut prev = input;
        let mut prev_c = in_c;
        // pooling branch starts with a pool (kernel size 0 marks it)
        for (ci, &(out_c, k)) in convs.iter().enumerate() {
            if k == 0 {
                prev = pool(b, &format!("{name}/b{bi}/pool"), batch, hw, prev_c, &[prev]);
                continue;
            }
            prev = conv(
                b,
                &format!("{name}/b{bi}/conv{ci}_{k}x{k}"),
                batch,
                hw,
                prev_c,
                out_c,
                k,
                &[prev],
            );
            prev_c = out_c;
        }
        out_c_total += prev_c;
        outs.push(prev);
    }
    let cat = concat(b, &format!("{name}/concat"), 4 * batch * hw * hw * out_c_total, &outs);
    (cat, out_c_total)
}

/// GoogLeNet / Inception v1: stem + 9 four-branch modules + classifier.
/// Branches: 1×1 · 1×1→3×3 · 1×1→5×5 · pool→1×1 (max graph width 4).
pub fn googlenet(batch: usize) -> Graph {
    build_v1(batch, "googlenet")
}

/// Inception v1 under its paper alias (same network as GoogLeNet).
pub fn inception_v1(batch: usize) -> Graph {
    build_v1(batch, "inception_v1")
}

fn build_v1(batch: usize, name: &str) -> Graph {
    let mut b = GraphBuilder::new(name, batch);
    let input = b.add(
        "input",
        crate::ops::OpKind::DataMovement { bytes: 4 * batch * 224 * 224 * 3, name: "Feed" },
        &[],
    );
    // stem: 7x7/2, pool, 1x1, 3x3, pool
    let c1 = conv(&mut b, "conv1/7x7", batch, 112, 3, 64, 7, &[input]);
    let r1 = relu(&mut b, "relu1", batch, 112, 64, &[c1]);
    let p1 = pool(&mut b, "pool1", batch, 56, 64, &[r1]);
    let c2 = conv(&mut b, "conv2/1x1", batch, 56, 64, 64, 1, &[p1]);
    let c3 = conv(&mut b, "conv3/3x3", batch, 56, 64, 192, 3, &[c2]);
    let p2 = pool(&mut b, "pool2", batch, 28, 192, &[c3]);

    // (hw, in_c, [b0 1x1, b1 reduce, b1 3x3, b2 reduce, b2 5x5, b3 proj])
    let specs: [(usize, usize, [usize; 6]); 9] = [
        (28, 192, [64, 96, 128, 16, 32, 32]),
        (28, 256, [128, 128, 192, 32, 96, 64]),
        (14, 480, [192, 96, 208, 16, 48, 64]),
        (14, 512, [160, 112, 224, 24, 64, 64]),
        (14, 512, [128, 128, 256, 24, 64, 64]),
        (14, 512, [112, 144, 288, 32, 64, 64]),
        (14, 528, [256, 160, 320, 32, 128, 128]),
        (7, 832, [256, 160, 320, 32, 128, 128]),
        (7, 832, [384, 192, 384, 48, 128, 128]),
    ];
    let mut prev = p2;
    for (mi, (hw, in_c, s)) in specs.iter().enumerate() {
        let branches = [
            Branch(vec![(s[0], 1)]),
            Branch(vec![(s[1], 1), (s[2], 3)]),
            Branch(vec![(s[3], 1), (s[4], 5)]),
            Branch(vec![(0, 0), (s[5], 1)]),
        ];
        let (cat, _c) = module(&mut b, &format!("inc{}", mi + 3), batch, *hw, *in_c, &branches, prev);
        prev = cat;
    }
    let gp = pool(&mut b, "global_pool", batch, 1, 1024, &[prev]);
    super::fc(&mut b, "fc/logits", batch, 1024, 1000, &[gp]);
    b.build()
}

/// Inception v2 (BN-Inception), the §4.2 case-study network: modules with
/// four branches (1×1 · 1×1→3×3 · 1×1→3×3→3×3 · pool→1×1) and three-branch
/// reduction modules (Fig. 5).
pub fn inception_v2(batch: usize) -> Graph {
    let mut b = GraphBuilder::new("inception_v2", batch);
    let input = b.add(
        "input",
        crate::ops::OpKind::DataMovement { bytes: 4 * batch * 224 * 224 * 3, name: "Feed" },
        &[],
    );
    // area 2 (paper Fig. 5a): sequential stem — intra-op parallelism only
    let c1 = conv(&mut b, "conv1/7x7", batch, 112, 3, 64, 7, &[input]);
    let p1 = pool(&mut b, "pool1", batch, 56, 64, &[c1]);
    let c2a = conv(&mut b, "conv2/1x1", batch, 56, 64, 64, 1, &[p1]);
    let c2 = conv(&mut b, "conv2/3x3", batch, 56, 64, 192, 3, &[c2a]);
    let p2 = pool(&mut b, "pool2", batch, 28, 192, &[c2]);

    // area 1: inception modules (4-branch) + reductions (3-branch)
    // 4-branch spec: [1x1, r3, 3x3, r33, 3x3a(+3x3b), proj]
    let four = |b: &mut GraphBuilder, nm: &str, hw, in_c, s: [usize; 6], prev| {
        let branches = [
            Branch(vec![(s[0], 1)]),
            Branch(vec![(s[1], 1), (s[2], 3)]),
            Branch(vec![(s[3], 1), (s[4], 3), (s[4], 3)]),
            Branch(vec![(0, 0), (s[5], 1)]),
        ];
        module(b, nm, batch, hw, in_c, &branches, prev).0
    };
    // 3-branch reduction: [r3, 3x3/2, r33, 3x3a, 3x3b/2, pool]
    let three = |b: &mut GraphBuilder, nm: &str, hw, in_c, s: [usize; 4], prev| {
        let branches = [
            Branch(vec![(s[0], 1), (s[1], 3)]),
            Branch(vec![(s[2], 1), (s[3], 3), (s[3], 3)]),
            Branch(vec![(0, 0)]),
        ];
        module(b, nm, batch, hw, in_c, &branches, prev).0
    };

    let m = four(&mut b, "inc3a", 28, 192, [64, 64, 64, 64, 96, 32], p2);
    let m = four(&mut b, "inc3b", 28, 256, [64, 64, 96, 64, 96, 64], m);
    let m = three(&mut b, "inc3c", 14, 320, [128, 160, 64, 96], m);
    let m = four(&mut b, "inc4a", 14, 576, [224, 64, 96, 96, 128, 128], m);
    let m = four(&mut b, "inc4b", 14, 576, [192, 96, 128, 96, 128, 128], m);
    let m = four(&mut b, "inc4c", 14, 576, [160, 128, 160, 128, 160, 96], m);
    let m = four(&mut b, "inc4d", 14, 576, [96, 128, 192, 160, 192, 96], m);
    let m = three(&mut b, "inc4e", 7, 576, [128, 192, 192, 256], m);
    let m = four(&mut b, "inc5a", 7, 1024, [352, 192, 320, 160, 224, 128], m);
    let m = four(&mut b, "inc5b", 7, 1024, [352, 192, 320, 192, 224, 128], m);

    let gp = pool(&mut b, "global_pool", batch, 1, 1024, &[m]);
    super::fc(&mut b, "fc/logits", batch, 1024, 1000, &[gp]);
    b.build()
}

/// Inception v3 (the Fig. 1 workload): 299×299 input, factorised 7×1/1×7
/// modules; average graph width 2 (paper Table 2).
pub fn inception_v3(batch: usize) -> Graph {
    let mut b = GraphBuilder::new("inception_v3", batch);
    let input = b.add(
        "input",
        crate::ops::OpKind::DataMovement { bytes: 4 * batch * 299 * 299 * 3, name: "Feed" },
        &[],
    );
    // stem: five sequential convs (intra-op-only area)
    let c = conv(&mut b, "stem/conv1", batch, 149, 3, 32, 3, &[input]);
    let c = conv(&mut b, "stem/conv2", batch, 147, 32, 32, 3, &[c]);
    let c = conv(&mut b, "stem/conv3", batch, 147, 32, 64, 3, &[c]);
    let p = pool(&mut b, "stem/pool", batch, 73, 64, &[c]);
    let c = conv(&mut b, "stem/conv4", batch, 73, 64, 80, 1, &[p]);
    let c = conv(&mut b, "stem/conv5", batch, 71, 80, 192, 3, &[c]);
    let mut prev = pool(&mut b, "stem/pool2", batch, 35, 192, &[c]);

    // 3× mixed_5 (35×35): 1x1 · 1x1→5x5 · 1x1→3x3→3x3 · pool→1x1
    let mut in_c = 192;
    for (i, proj) in [32usize, 64, 64].iter().enumerate() {
        let branches = [
            Branch(vec![(64, 1)]),
            Branch(vec![(48, 1), (64, 5)]),
            Branch(vec![(64, 1), (96, 3), (96, 3)]),
            Branch(vec![(0, 0), (*proj, 1)]),
        ];
        let (cat, c) = module(&mut b, &format!("mixed5{}", i), batch, 35, in_c, &branches, prev);
        prev = cat;
        in_c = 64 + 64 + 96 + proj;
        debug_assert_eq!(in_c, c);
    }

    // reduction A (17×17): 3x3/2 · 1x1→3x3→3x3/2 · pool
    let branches = [
        Branch(vec![(384, 3)]),
        Branch(vec![(64, 1), (96, 3), (96, 3)]),
        Branch(vec![(0, 0)]),
    ];
    let (cat, _) = module(&mut b, "reductionA", batch, 17, in_c, &branches, prev);
    prev = cat;
    in_c = 384 + 96 + 288;

    // 4× mixed_6 (17×17): 1x1 · 1x1→1x7→7x1 · 1x1→7x1→1x7→7x1→1x7 · pool→1x1
    for (i, ch) in [128usize, 160, 160, 192].iter().enumerate() {
        let c7 = *ch;
        let branches = [
            Branch(vec![(192, 1)]),
            Branch(vec![(c7, 1), (c7, 7), (192, 7)]),
            Branch(vec![(c7, 1), (c7, 7), (c7, 7), (c7, 7), (192, 7)]),
            Branch(vec![(0, 0), (192, 1)]),
        ];
        let (cat, _) = module(&mut b, &format!("mixed6{}", i), batch, 17, in_c, &branches, prev);
        prev = cat;
        in_c = 192 * 4;
    }

    // auxiliary classifier head (part of the published v3 graph): runs in
    // parallel with the tail of the network
    let ap = pool(&mut b, "aux/pool", batch, 5, in_c, &[prev]);
    let ac1 = conv(&mut b, "aux/conv1x1", batch, 5, in_c, 128, 1, &[ap]);
    let ac2 = conv(&mut b, "aux/conv5x5", batch, 1, 128 * 25, 768, 1, &[ac1]);
    super::fc(&mut b, "aux/fc", batch, 768, 1000, &[ac2]);

    // reduction B (8×8)
    let branches = [
        Branch(vec![(192, 1), (320, 3)]),
        Branch(vec![(192, 1), (192, 7), (192, 7), (192, 3)]),
        Branch(vec![(0, 0)]),
    ];
    let (cat, _) = module(&mut b, "reductionB", batch, 8, in_c, &branches, prev);
    prev = cat;
    in_c = 320 + 192 + 768;

    // 2× mixed_7 (8×8): 1x1 · 1x1→(1x3∥3x1) · 1x1→3x3→(1x3∥3x1) · pool→1x1
    for i in 0..2 {
        let nm = format!("mixed7{}", i);
        let one = conv(&mut b, &format!("{nm}/b0/1x1"), batch, 8, in_c, 320, 1, &[prev]);
        let b1r = conv(&mut b, &format!("{nm}/b1/1x1"), batch, 8, in_c, 384, 1, &[prev]);
        let b1a = conv(&mut b, &format!("{nm}/b1/1x3"), batch, 8, 384, 384, 3, &[b1r]);
        let b1b = conv(&mut b, &format!("{nm}/b1/3x1"), batch, 8, 384, 384, 3, &[b1r]);
        let b2r = conv(&mut b, &format!("{nm}/b2/1x1"), batch, 8, in_c, 448, 1, &[prev]);
        let b2m = conv(&mut b, &format!("{nm}/b2/3x3"), batch, 8, 448, 384, 3, &[b2r]);
        let b2a = conv(&mut b, &format!("{nm}/b2/1x3"), batch, 8, 384, 384, 3, &[b2m]);
        let b2b = conv(&mut b, &format!("{nm}/b2/3x1"), batch, 8, 384, 384, 3, &[b2m]);
        let pp = pool(&mut b, &format!("{nm}/pool"), batch, 8, in_c, &[prev]);
        let proj = conv(&mut b, &format!("{nm}/b3/1x1"), batch, 8, in_c, 192, 1, &[pp]);
        in_c = 320 + 384 * 2 + 384 * 2 + 192;
        prev = concat(
            &mut b,
            &format!("{nm}/concat"),
            4 * batch * 8 * 8 * in_c,
            &[one, b1a, b1b, b2a, b2b, proj],
        );
    }

    let gp = pool(&mut b, "global_pool", batch, 1, in_c, &[prev]);
    super::fc(&mut b, "fc/logits", batch, in_c, 1000, &[gp]);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::analyze_width;

    #[test]
    fn googlenet_max_width_4() {
        let w = analyze_width(&googlenet(16));
        assert_eq!(w.max_width, 4, "{w:?}");
    }

    #[test]
    fn v2_has_four_branch_modules() {
        let w = analyze_width(&inception_v2(16));
        assert_eq!(w.max_width, 4, "{w:?}");
        assert!(w.avg_width >= 2, "{w:?}");
    }

    #[test]
    fn v3_avg_width_2() {
        // paper Table 2: IncepV3 = 2
        let w = analyze_width(&inception_v3(16));
        assert_eq!(w.avg_width, 2, "{w:?}");
    }

    #[test]
    fn graphs_validate() {
        for g in [googlenet(16), inception_v2(16), inception_v3(16)] {
            assert!(g.validate().is_ok());
            assert!(g.total_flops() > 1e9, "{}", g.name);
        }
    }

    #[test]
    fn batch_scales_flops_linearly() {
        let f1 = inception_v2(1).total_flops();
        let f16 = inception_v2(16).total_flops();
        assert!((f16 / f1 - 16.0).abs() < 0.01);
    }
}
