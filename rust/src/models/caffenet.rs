//! CaffeNet (AlexNet-class, Jia et al.): five convs + three giant FC
//! layers. A pure chain (width 1) whose FC6 (9216×4096) dominates — the
//! classic large-GEMM workload.

use crate::graph::{Graph, GraphBuilder};
use crate::ops::OpKind;

use super::{conv, fc, pool, relu};

/// Build CaffeNet at the given batch size.
pub fn caffenet(batch: usize) -> Graph {
    let mut b = GraphBuilder::new("caffenet", batch);
    let input = b.add(
        "input",
        OpKind::DataMovement { bytes: 4 * batch * 227 * 227 * 3, name: "Feed" },
        &[],
    );
    let c1 = conv(&mut b, "conv1/11x11", batch, 55, 3, 96, 11, &[input]);
    let r1 = relu(&mut b, "relu1", batch, 55, 96, &[c1]);
    let p1 = pool(&mut b, "pool1", batch, 27, 96, &[r1]);
    let c2 = conv(&mut b, "conv2/5x5", batch, 27, 96, 256, 5, &[p1]);
    let p2 = pool(&mut b, "pool2", batch, 13, 256, &[c2]);
    let c3 = conv(&mut b, "conv3/3x3", batch, 13, 256, 384, 3, &[p2]);
    let c4 = conv(&mut b, "conv4/3x3", batch, 13, 384, 384, 3, &[c3]);
    let c5 = conv(&mut b, "conv5/3x3", batch, 13, 384, 256, 3, &[c4]);
    let p5 = pool(&mut b, "pool5", batch, 6, 256, &[c5]);
    let f6 = fc(&mut b, "fc6", batch, 9216, 4096, &[p5]);
    let f7 = fc(&mut b, "fc7", batch, 4096, 4096, &[f6]);
    fc(&mut b, "fc8", batch, 4096, 1000, &[f7]);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::analyze_width;

    #[test]
    fn chain_width_1() {
        let w = analyze_width(&caffenet(16));
        assert_eq!((w.max_width, w.avg_width), (1, 1), "{w:?}");
    }

    #[test]
    fn fc6_dominates_params() {
        let g = caffenet(16);
        let fc6 = g.nodes.iter().find(|n| n.name == "fc6").unwrap();
        assert!(matches!(fc6.kind, OpKind::MatMul { k: 9216, n: 4096, .. }));
    }
}
