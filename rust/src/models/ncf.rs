//! Neural Collaborative Filtering (He et al., MLPerf): four parallel
//! embedding gathers (user/item × GMF/MLP paths) feeding a small MLP.
//! The embeddings are the heavy ops (bandwidth-bound) and sit on one level
//! ⇒ average width 4 (paper Table 2) — the workload where model parallelism
//! over two sockets pays off (§7.2).

use crate::graph::{Graph, GraphBuilder};
use crate::ops::OpKind;

use super::fc;

/// MovieLens-20M-class dimensions.
const N_USERS: usize = 138_000;
const N_ITEMS: usize = 27_000;
const GMF_DIM: usize = 64;
const MLP_DIM: usize = 128;

/// Build NCF (NeuMF variant) at the given batch size.
pub fn ncf(batch: usize) -> Graph {
    let mut b = GraphBuilder::new("ncf", batch);
    let ids = b.add(
        "input_ids",
        OpKind::DataMovement { bytes: 8 * batch * 2, name: "Feed" },
        &[],
    );
    // four parallel gathers — the inter-op parallelism
    let eu_g = b.add("emb/user_gmf", OpKind::Embedding { vocab: N_USERS, dim: GMF_DIM, rows: batch }, &[ids]);
    let ei_g = b.add("emb/item_gmf", OpKind::Embedding { vocab: N_ITEMS, dim: GMF_DIM, rows: batch }, &[ids]);
    let eu_m = b.add("emb/user_mlp", OpKind::Embedding { vocab: N_USERS, dim: MLP_DIM, rows: batch }, &[ids]);
    let ei_m = b.add("emb/item_mlp", OpKind::Embedding { vocab: N_ITEMS, dim: MLP_DIM, rows: batch }, &[ids]);

    // GMF path: elementwise product
    let gmf = b.add(
        "gmf/mul",
        OpKind::Elementwise { elems: batch * GMF_DIM, name: "Mul" },
        &[eu_g, ei_g],
    );
    // MLP path: concat + 3 FC layers (256→128→64), light at serving batch
    let cat = b.add(
        "mlp/concat",
        OpKind::DataMovement { bytes: 4 * batch * 2 * MLP_DIM, name: "Concat" },
        &[eu_m, ei_m],
    );
    let h1 = fc(&mut b, "mlp/fc1", batch, 2 * MLP_DIM, 256, &[cat]);
    let h2 = fc(&mut b, "mlp/fc2", batch, 256, 128, &[h1]);
    let h3 = fc(&mut b, "mlp/fc3", batch, 128, 64, &[h2]);

    // NeuMF head: concat GMF and MLP outputs, final FC to a score
    let head_cat = b.add(
        "neumf/concat",
        OpKind::DataMovement { bytes: 4 * batch * (GMF_DIM + 64), name: "Concat" },
        &[gmf, h3],
    );
    fc(&mut b, "neumf/fc", batch, GMF_DIM + 64, 1, &[head_cat]);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::analyze_width;

    #[test]
    fn avg_width_4() {
        // paper Table 2: NCF = 4
        let w = analyze_width(&ncf(256));
        assert_eq!(w.avg_width, 4, "{w:?}");
        assert_eq!(w.max_width, 4, "{w:?}");
        assert_eq!(w.levels, 1, "{w:?}");
    }

    #[test]
    fn mlp_fcs_are_light_at_serving_batch() {
        let g = ncf(256);
        for n in g.nodes.iter().filter(|n| n.name.starts_with("mlp/fc")) {
            assert!(!n.is_heavy(), "{} should be light", n.name);
        }
    }

    #[test]
    fn embeddings_heavy_at_any_batch() {
        let g = ncf(1);
        let heavy: Vec<_> = g.heavy_nodes().map(|n| n.name.clone()).collect();
        assert_eq!(heavy.len(), 4, "{heavy:?}");
        assert!(heavy.iter().all(|n| n.starts_with("emb/")));
    }
}
