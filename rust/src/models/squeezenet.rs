//! SqueezeNet (Iandola et al.): fire modules — a 1×1 squeeze conv feeding
//! parallel 1×1 and 3×3 expand convs. Many small kernels ⇒ framework-native
//! time dominates ⇒ the biggest intra-op-thread win in the paper's Fig. 11
//! (4.21×) and a high programmability tax (47%).

use crate::graph::{Graph, GraphBuilder, NodeId};
use crate::ops::OpKind;

use super::{concat, conv, pool};

/// One fire module; returns the concat of the expand branches.
fn fire(
    b: &mut GraphBuilder,
    name: &str,
    batch: usize,
    hw: usize,
    in_c: usize,
    squeeze: usize,
    expand: usize,
    input: NodeId,
) -> NodeId {
    let s = conv(b, &format!("{name}/squeeze1x1"), batch, hw, in_c, squeeze, 1, &[input]);
    let e1 = conv(b, &format!("{name}/expand1x1"), batch, hw, squeeze, expand, 1, &[s]);
    let e3 = conv(b, &format!("{name}/expand3x3"), batch, hw, squeeze, expand, 3, &[s]);
    concat(b, &format!("{name}/concat"), 4 * batch * hw * hw * 2 * expand, &[e1, e3])
}

/// Build SqueezeNet v1.1 at the given batch size.
pub fn squeezenet(batch: usize) -> Graph {
    let mut b = GraphBuilder::new("squeezenet", batch);
    let input = b.add(
        "input",
        OpKind::DataMovement { bytes: 4 * batch * 224 * 224 * 3, name: "Feed" },
        &[],
    );
    let c1 = conv(&mut b, "conv1/3x3", batch, 111, 3, 64, 3, &[input]);
    let mut prev = pool(&mut b, "pool1", batch, 55, 64, &[c1]);

    // (hw, in_c, squeeze, expand)
    let fires: [(usize, usize, usize, usize); 8] = [
        (55, 64, 16, 64),
        (55, 128, 16, 64),
        (27, 128, 32, 128),
        (27, 256, 32, 128),
        (13, 256, 48, 192),
        (13, 384, 48, 192),
        (13, 384, 64, 256),
        (13, 512, 64, 256),
    ];
    for (fi, (hw, in_c, s, e)) in fires.iter().enumerate() {
        if fi == 2 || fi == 4 {
            prev = pool(&mut b, &format!("pool{}", fi + 1), batch, *hw, *in_c, &[prev]);
        }
        prev = fire(&mut b, &format!("fire{}", fi + 2), batch, *hw, *in_c, *s, *e, prev);
    }
    let c_final = conv(&mut b, "conv10/1x1", batch, 13, 512, 1000, 1, &[prev]);
    pool(&mut b, "global_pool", batch, 1, 1000, &[c_final]);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::analyze_width;

    #[test]
    fn fire_modules_have_width_2() {
        let w = analyze_width(&squeezenet(16));
        assert_eq!(w.max_width, 2, "{w:?}");
    }

    #[test]
    fn avg_width_is_1() {
        // paper Table 2: Squeeze = 1 (⌊26 heavy / 18 levels⌋)
        let w = analyze_width(&squeezenet(16));
        assert_eq!(w.avg_width, 1, "{w:?}");
    }

    #[test]
    fn small_model_few_flops() {
        // SqueezeNet is ~0.7 GFLOPs/image — an order less than ResNet
        let s = squeezenet(1).total_flops();
        let r = super::super::resnet::resnet50(1).total_flops();
        assert!(s < r / 5.0, "squeeze={s:.2e} resnet={r:.2e}");
    }
}
