//! DenseNet-121 (Huang et al.): dense blocks of 1×1+3×3 conv pairs.
//! Every layer consumes the concat of all previous features, so the heavy
//! graph is a strict chain — average width 1 (paper Table 2) and the lowest
//! intra-op-thread benefit in Fig. 11 (many small convs).

use crate::graph::{Graph, GraphBuilder};
use crate::ops::OpKind;

use super::{concat, conv, fc, pool};

const GROWTH: usize = 32;

/// Build DenseNet-121 at the given batch size.
pub fn densenet121(batch: usize) -> Graph {
    let mut b = GraphBuilder::new("densenet121", batch);
    let input = b.add(
        "input",
        OpKind::DataMovement { bytes: 4 * batch * 224 * 224 * 3, name: "Feed" },
        &[],
    );
    let c1 = conv(&mut b, "conv1/7x7", batch, 112, 3, 64, 7, &[input]);
    let mut prev = pool(&mut b, "pool1", batch, 56, 64, &[c1]);
    let mut channels = 64usize;

    let blocks: [(usize, usize); 4] = [(6, 56), (12, 28), (24, 14), (16, 7)];
    for (bi, (layers, hw)) in blocks.iter().enumerate() {
        for li in 0..*layers {
            let nm = format!("dense{}/layer{}", bi + 1, li);
            // bottleneck 1x1 to 4*growth, then 3x3 to growth
            let c1x1 = conv(&mut b, &format!("{nm}/conv1x1"), batch, *hw, channels, 4 * GROWTH, 1, &[prev]);
            let c3x3 = conv(&mut b, &format!("{nm}/conv3x3"), batch, *hw, 4 * GROWTH, GROWTH, 3, &[c1x1]);
            channels += GROWTH;
            prev = concat(&mut b, &format!("{nm}/concat"), 4 * batch * hw * hw * channels, &[prev, c3x3]);
        }
        if bi < 3 {
            // transition: 1x1 halve channels + 2x2 pool
            channels /= 2;
            let t = conv(&mut b, &format!("trans{}/conv1x1", bi + 1), batch, *hw, channels * 2, channels, 1, &[prev]);
            prev = pool(&mut b, &format!("trans{}/pool", bi + 1), batch, hw / 2, channels, &[t]);
        }
    }
    let gp = pool(&mut b, "global_pool", batch, 1, channels, &[prev]);
    fc(&mut b, "fc/logits", batch, channels, 1000, &[gp]);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::analyze_width;

    #[test]
    fn width_is_chain() {
        let w = analyze_width(&densenet121(16));
        assert_eq!(w.avg_width, 1, "{w:?}");
        assert_eq!(w.max_width, 1, "{w:?}");
    }

    #[test]
    fn layer_count_is_121ish() {
        let g = densenet121(16);
        let convs = g.nodes.iter().filter(|n| n.kind.name() == "Conv").count();
        assert_eq!(convs, 1 + 2 * (6 + 12 + 24 + 16) + 3); // stem + pairs + transitions
    }

    #[test]
    fn validates() {
        assert!(densenet121(4).validate().is_ok());
    }
}
