//! Model zoo: the paper's workload set as computational graphs.
//!
//! Vision: Inception v1/v2/v3, GoogLeNet, ResNet-50, DenseNet-121,
//! SqueezeNet, CaffeNet (AlexNet-class), ResNeXt-50.
//! Recommendation/translation (the §8 holdout set): NCF, Wide&Deep,
//! Transformer. Micro: MatMul-N / FC-N (§5's MatMul-512 / MatMul-4k).
//!
//! Graphs encode *structure and cost*, not weights — real numerics for the
//! serving path come from the AOT artifacts in [`crate::runtime`].

pub mod caffenet;
pub mod densenet;
pub mod inception;
pub mod micro;
pub mod ncf;
pub mod resnet;
pub mod resnext;
pub mod squeezenet;
pub mod training;
pub mod transformer;
pub mod wide_deep;
pub mod zoo;

pub use training::to_training_graph;
pub use zoo::{build, canonical_batch, model_names};

use crate::graph::{GraphBuilder, NodeId};
use crate::ops::OpKind;

/// Shorthand: add a convolution described by its output geometry.
pub(crate) fn conv(
    b: &mut GraphBuilder,
    name: &str,
    batch: usize,
    hw: usize,
    in_c: usize,
    out_c: usize,
    k: usize,
    deps: &[NodeId],
) -> NodeId {
    b.add(
        name,
        OpKind::Conv { batch, out_h: hw, out_w: hw, in_c, out_c, k_h: k, k_w: k },
        deps,
    )
}

/// Shorthand: fully-connected layer `[batch, in] @ [in, out]`.
pub(crate) fn fc(
    b: &mut GraphBuilder,
    name: &str,
    batch: usize,
    in_f: usize,
    out_f: usize,
    deps: &[NodeId],
) -> NodeId {
    b.add(name, OpKind::MatMul { m: batch, k: in_f, n: out_f }, deps)
}

/// Shorthand: ReLU-class elementwise op sized to a conv output.
pub(crate) fn relu(
    b: &mut GraphBuilder,
    name: &str,
    batch: usize,
    hw: usize,
    c: usize,
    deps: &[NodeId],
) -> NodeId {
    b.add(name, OpKind::Elementwise { elems: batch * hw * hw * c, name: "ReLU" }, deps)
}

/// Shorthand: max/avg pool.
pub(crate) fn pool(
    b: &mut GraphBuilder,
    name: &str,
    batch: usize,
    hw: usize,
    c: usize,
    deps: &[NodeId],
) -> NodeId {
    b.add(name, OpKind::Pool { elems: batch * hw * hw * c }, deps)
}

/// Shorthand: concat along channels (framework-native data movement).
pub(crate) fn concat(
    b: &mut GraphBuilder,
    name: &str,
    bytes: usize,
    deps: &[NodeId],
) -> NodeId {
    b.add(name, OpKind::DataMovement { bytes, name: "Concat" }, deps)
}
