//! Forward-graph → training-graph transform (paper §4.1).
//!
//! "The computational graphs of training workloads contain gradient and sum
//! weight operators, which doubles the number of parallel operators."
//!
//! For each heavy forward op (in reverse topological order) we append:
//!
//! * a `Gradient` op — depends on the forward op and on the gradient of the
//!   *consumer* layer (backprop chain), costing ~2× the forward FLOPs;
//! * a `WeightSum` op — the weight-update for that layer, depending only on
//!   the layer's gradient, hence free to run *in parallel* with the next
//!   (earlier-layer) gradient. With large batches the gradient grows
//!   compute-intensive while the weight sum stays fixed-size — the imbalance
//!   the paper blames for training's best-pool count dropping at batch 128.

use std::collections::HashMap;

use crate::graph::{Graph, GraphBuilder, NodeId};
use crate::ops::OpKind;

/// Number of parameters (weights) a forward op trains, if any.
fn param_count(kind: &OpKind) -> Option<usize> {
    match *kind {
        OpKind::MatMul { k, n, .. } => Some(k * n),
        OpKind::Conv { in_c, out_c, k_h, k_w, .. } => Some(in_c * out_c * k_h * k_w),
        OpKind::Embedding { vocab, dim, .. } => Some(vocab * dim),
        _ => None,
    }
}

/// Build the training graph for a forward graph.
pub fn to_training_graph(fwd: &Graph) -> Graph {
    let mut b = GraphBuilder::new(&format!("{}_train", fwd.name), fwd.batch);

    // Re-insert the forward graph unchanged (ids are preserved because
    // insertion order is identical).
    let mut fwd_ids: Vec<NodeId> = Vec::with_capacity(fwd.len());
    for n in fwd.topo() {
        let deps: Vec<NodeId> = n.deps.iter().map(|d| fwd_ids[d.0]).collect();
        fwd_ids.push(b.add(&n.name, n.kind.clone(), &deps));
    }

    // Loss head: depends on the final node.
    let last = fwd_ids.last().copied();
    let loss = b.add(
        "loss",
        OpKind::Elementwise { elems: fwd.batch.max(1) * 64, name: "Loss" },
        last.map(|l| vec![l]).unwrap_or_default().as_slice(),
    );

    // Backward pass over heavy ops in reverse topo order. grad_of maps a
    // forward node to its gradient node; a heavy op's gradient depends on
    // the gradients of its heavy consumers (or the loss for outputs).
    let consumers = fwd.consumers();
    let mut grad_of: HashMap<usize, NodeId> = HashMap::new();
    for n in fwd.nodes.iter().rev() {
        if !n.is_heavy() {
            continue;
        }
        // nearest heavy consumers (transitively through light ops)
        let mut heavy_cons: Vec<NodeId> = Vec::new();
        let mut stack: Vec<NodeId> = consumers[n.id.0].clone();
        while let Some(c) = stack.pop() {
            if fwd.nodes[c.0].is_heavy() {
                if let Some(g) = grad_of.get(&c.0) {
                    heavy_cons.push(*g);
                }
            } else {
                stack.extend(consumers[c.0].iter().copied());
            }
        }
        let mut deps = vec![fwd_ids[n.id.0]];
        if heavy_cons.is_empty() {
            deps.push(loss);
        } else {
            heavy_cons.sort();
            heavy_cons.dedup();
            deps.extend(heavy_cons);
        }
        let g = b.add(
            &format!("grad/{}", n.name),
            OpKind::Gradient { fwd_flops: n.cost.flops, fwd_bytes: n.cost.total_bytes() },
            &deps,
        );
        grad_of.insert(n.id.0, g);
        if let Some(params) = param_count(&n.kind) {
            b.add(&format!("wsum/{}", n.name), OpKind::WeightSum { params }, &[g]);
        }
    }
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::analyze_width;
    use crate::models::micro;

    #[test]
    fn training_doubles_parallel_ops() {
        // A heavy chain has max width 1; its training graph runs each
        // layer's weight-sum in parallel with the previous layer's gradient.
        let fwd = micro::fc_stack(4096, 4, 512);
        let train = to_training_graph(&fwd);
        let wf = analyze_width(&fwd);
        let wt = analyze_width(&train);
        assert_eq!(wf.max_width, 1);
        assert_eq!(wt.max_width, 2, "grad ∥ wsum should double max width");
        assert_eq!(wt.heavy_ops, 3 * wf.heavy_ops, "grad + wsum per heavy op");
    }

    #[test]
    fn gradient_costs_double_forward() {
        let fwd = micro::matmul_n(1024);
        let train = to_training_graph(&fwd);
        let fwd_flops = fwd.total_flops();
        // total = fwd + grad(2×) + wsum(small)
        assert!(train.total_flops() > 2.9 * fwd_flops);
        assert!(train.total_flops() < 3.2 * fwd_flops);
    }

    #[test]
    fn training_graph_valid() {
        let fwd = micro::fc_stack(4096, 3, 256);
        assert!(to_training_graph(&fwd).validate().is_ok());
    }

    #[test]
    fn light_graph_gets_loss_only() {
        let fwd = micro::fc_stack(64, 2, 4); // nothing heavy
        let train = to_training_graph(&fwd);
        assert_eq!(train.len(), fwd.len() + 1); // + loss
    }
}
