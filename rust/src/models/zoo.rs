//! Model registry: name → graph builder, with the canonical batch sizes
//! used across the paper's experiments.

use crate::graph::Graph;

use super::{
    caffenet::caffenet,
    densenet::densenet121,
    inception::{googlenet, inception_v1, inception_v2, inception_v3},
    micro::{fc_stack, matmul_n},
    ncf::ncf,
    resnet::resnet50,
    resnext::resnext50,
    squeezenet::squeezenet,
    transformer::transformer,
    wide_deep::wide_deep,
};

/// All registry names (stable order, used by CLI listings).
pub fn model_names() -> Vec<&'static str> {
    vec![
        "inception_v1",
        "inception_v2",
        "inception_v3",
        "googlenet",
        "resnet50",
        "densenet121",
        "squeezenet",
        "caffenet",
        "resnext50",
        "transformer",
        "ncf",
        "wide_deep",
        "fc512",
        "fc4k",
        "matmul_512",
        "matmul_4k",
    ]
}

/// Canonical batch size per model (the sizes the paper evaluates at).
pub fn canonical_batch(name: &str) -> usize {
    match name {
        "ncf" => 256,
        "wide_deep" => 16,
        "transformer" => 16,
        "fc512" | "fc4k" => 512,
        _ => 16,
    }
}

/// Build a model graph by name; `None` for unknown names.
pub fn build(name: &str, batch: usize) -> Option<Graph> {
    let g = match name {
        "inception_v1" => inception_v1(batch),
        "inception_v2" => inception_v2(batch),
        "inception_v3" => inception_v3(batch),
        "googlenet" => googlenet(batch),
        "resnet50" => resnet50(batch),
        "densenet121" => densenet121(batch),
        "squeezenet" => squeezenet(batch),
        "caffenet" => caffenet(batch),
        "resnext50" => resnext50(batch),
        "transformer" => transformer(batch),
        "ncf" => ncf(batch),
        "wide_deep" => wide_deep(batch),
        "fc512" => fc_stack(512, 3, batch),
        "fc4k" => fc_stack(4096, 3, batch),
        "matmul_512" => matmul_n(512),
        "matmul_4k" => matmul_n(4096),
        _ => return None,
    };
    Some(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::analyze_width;

    #[test]
    fn all_names_build_and_validate() {
        for name in model_names() {
            let g = build(name, canonical_batch(name)).unwrap_or_else(|| panic!("{name}"));
            assert!(g.validate().is_ok(), "{name}");
            assert!(!g.is_empty(), "{name}");
        }
    }

    #[test]
    fn unknown_name_is_none() {
        assert!(build("bert", 1).is_none());
    }

    #[test]
    fn table2_average_widths() {
        // The paper's Table 2 (evaluation set, canonical batches).
        let expect = [
            ("densenet121", 1),
            ("squeezenet", 1),
            ("resnet50", 1),
            ("inception_v3", 2),
            ("wide_deep", 3),
            ("ncf", 4),
            ("transformer", 4),
        ];
        for (name, want) in expect {
            let g = build(name, canonical_batch(name)).unwrap();
            let w = analyze_width(&g);
            assert_eq!(w.avg_width, want, "{name}: {w:?}");
        }
    }
}
