//! Transformer (Vaswani et al.) for translation, as deployed for
//! CPU serving: encoder-decoder (6+6), d_model 768, 8 heads, d_ff 3072,
//! with **tensor-sharded projections** (the paper's §2.2.2 model
//! parallelism: "the same operator after splitting along the model size
//! dimension") — QKV/output/FFN/logits matmuls are column/row-sharded
//! 3-ways, Megatron-style, so every heavy level carries parallel operators.
//!
//! Inter-op structure: token+positional embeddings gather in parallel; the
//! decoder is gated on the encoder output (autoregressive translation);
//! all six decoder blocks' cross-attention K/V project from the encoder
//! output as soon as encoding finishes. Net: average graph width 4 (paper
//! Table 2) — the workload where Intel's 2-pool setting beats TensorFlow's
//! but both lose to width-based tuning (§8).

use crate::graph::{Graph, GraphBuilder, NodeId};
use crate::ops::OpKind;

/// Model width.
pub const D_MODEL: usize = 768;
/// Attention heads.
pub const N_HEADS: usize = 6;
/// Per-head dimension.
pub const D_HEAD: usize = D_MODEL / N_HEADS;
/// Feed-forward inner dimension.
pub const D_FF: usize = 3072;
/// Tensor-parallel shard count for the projection/FFN matmuls.
pub const SHARDS: usize = 4;
/// Sequence length per example.
pub const SEQ: usize = 256;
/// Vocabulary size (shared source/target BPE).
pub const VOCAB: usize = 32_000;
/// Encoder/decoder depth.
pub const LAYERS: usize = 6;

/// A dense projection `[tokens, in_f] @ [in_f, out_f]`, column-sharded
/// into `SHARDS` parallel matmuls plus a light concat.
fn sharded_proj(
    b: &mut GraphBuilder,
    name: &str,
    tokens: usize,
    in_f: usize,
    out_f: usize,
    deps: &[NodeId],
) -> NodeId {
    let per = out_f / SHARDS;
    let parts: Vec<NodeId> = (0..SHARDS)
        .map(|s| {
            b.add(
                &format!("{name}/shard{s}"),
                OpKind::MatMul { m: tokens, k: in_f, n: per },
                deps,
            )
        })
        .collect();
    b.add(
        &format!("{name}/concat"),
        OpKind::DataMovement { bytes: 4 * tokens * out_f, name: "Concat" },
        &parts,
    )
}

/// Per-head fused attention op: QKᵀ + softmax + AV over the whole batch.
fn head_attention(b: &mut GraphBuilder, name: &str, seqs: usize, deps: &[NodeId]) -> NodeId {
    let m = seqs * SEQ;
    b.add(name, OpKind::MatMul { m, k: SEQ, n: 2 * D_HEAD }, deps)
}

/// Multi-head attention with sharded projections; q from `q_src`, k/v from
/// `kv_src`.
fn attention(
    b: &mut GraphBuilder,
    name: &str,
    seqs: usize,
    q_src: NodeId,
    kv_src: NodeId,
) -> NodeId {
    let tokens = seqs * SEQ;
    // fused QKV projection (one sharded GEMM, standard practice); for
    // self-attention q_src == kv_src, so a single projection suffices
    let qkv = if q_src == kv_src {
        sharded_proj(b, &format!("{name}/qkv"), tokens, D_MODEL, 3 * D_MODEL, &[q_src])
    } else {
        sharded_proj(b, &format!("{name}/qkv"), tokens, D_MODEL, 3 * D_MODEL, &[q_src, kv_src])
    };
    let heads: Vec<NodeId> = (0..N_HEADS)
        .map(|h| head_attention(b, &format!("{name}/head{h}"), seqs, &[qkv]))
        .collect();
    let cat = b.add(
        &format!("{name}/headcat"),
        OpKind::DataMovement { bytes: 4 * tokens * D_MODEL, name: "Concat" },
        &heads,
    );
    sharded_proj(b, &format!("{name}/o"), tokens, D_MODEL, D_MODEL, &[cat])
}

/// Feed-forward block with sharded ff1/ff2 (+ light norm).
fn ffn(b: &mut GraphBuilder, name: &str, tokens: usize, input: NodeId) -> NodeId {
    let f1 = sharded_proj(b, &format!("{name}/ff1"), tokens, D_MODEL, D_FF, &[input]);
    let r = b.add(
        &format!("{name}/relu"),
        OpKind::Elementwise { elems: tokens * D_FF, name: "ReLU" },
        &[f1],
    );
    let f2 = sharded_proj(b, &format!("{name}/ff2"), tokens, D_FF, D_MODEL, &[r]);
    b.add(
        &format!("{name}/norm"),
        OpKind::Elementwise { elems: tokens * D_MODEL, name: "LayerNorm" },
        &[f2],
    )
}

/// Build the Transformer translation graph; `batch` = number of
/// 256-token sequences processed together.
pub fn transformer(batch: usize) -> Graph {
    let seqs = batch.max(1);
    let tokens = seqs * SEQ;
    let mut b = GraphBuilder::new("transformer", batch);
    let ids = b.add(
        "input_ids",
        OpKind::DataMovement { bytes: 8 * tokens * 2, name: "Feed" },
        &[],
    );
    // source-side parallel gathers: token + (learned) positional embeddings
    let src_tok = b.add("emb/src_tok", OpKind::Embedding { vocab: VOCAB, dim: D_MODEL, rows: tokens }, &[ids]);
    let src_pos = b.add("emb/src_pos", OpKind::Embedding { vocab: SEQ, dim: D_MODEL, rows: tokens }, &[ids]);
    let src = b.add(
        "emb/src_add",
        OpKind::Elementwise { elems: tokens * D_MODEL, name: "Add" },
        &[src_tok, src_pos],
    );

    // encoder stack
    let mut enc = src;
    for l in 0..LAYERS {
        let att = attention(&mut b, &format!("enc{l}/self"), seqs, enc, enc);
        enc = ffn(&mut b, &format!("enc{l}"), tokens, att);
    }

    // target-side gathers: in translation inference the decoder consumes
    // previously-generated tokens, so the target path is gated on the
    // encoder output (autoregressive decode).
    let tgt_tok = b.add("emb/tgt_tok", OpKind::Embedding { vocab: VOCAB, dim: D_MODEL, rows: tokens }, &[ids, enc]);
    let tgt_pos = b.add("emb/tgt_pos", OpKind::Embedding { vocab: SEQ, dim: D_MODEL, rows: tokens }, &[ids, enc]);
    let tgt = b.add(
        "emb/tgt_add",
        OpKind::Elementwise { elems: tokens * D_MODEL, name: "Add" },
        &[tgt_tok, tgt_pos],
    );

    // all decoder blocks' cross-attention K/V depend only on the encoder
    // output: schedule them as soon as encoding finishes (K/V cache fill)
    let cross_kv: Vec<NodeId> = (0..LAYERS)
        .map(|l| sharded_proj(&mut b, &format!("dec{l}/cross/kv"), tokens, D_MODEL, 2 * D_MODEL, &[enc]))
        .collect();

    // decoder stack: self-attention + cross-attention
    let mut dec = tgt;
    for l in 0..LAYERS {
        let self_out = attention(&mut b, &format!("dec{l}/self"), seqs, dec, dec);
        // cross-attention: q from the decoder, k/v from the cached fill
        let q = sharded_proj(&mut b, &format!("dec{l}/cross/q"), tokens, D_MODEL, D_MODEL, &[self_out]);
        let heads: Vec<NodeId> = (0..N_HEADS)
            .map(|h| head_attention(&mut b, &format!("dec{l}/cross/head{h}"), seqs, &[q, cross_kv[l]]))
            .collect();
        let cat = b.add(
            &format!("dec{l}/cross/headcat"),
            OpKind::DataMovement { bytes: 4 * tokens * D_MODEL, name: "Concat" },
            &heads,
        );
        let cross_out = sharded_proj(&mut b, &format!("dec{l}/cross/o"), tokens, D_MODEL, D_MODEL, &[cat]);
        dec = ffn(&mut b, &format!("dec{l}"), tokens, cross_out);
    }

    // vocabulary projection, column-sharded like the rest
    let per_shard = VOCAB / SHARDS + 1;
    let shards: Vec<NodeId> = (0..SHARDS)
        .map(|s| {
            b.add(
                &format!("logits/shard{s}"),
                OpKind::MatMul { m: tokens, k: D_MODEL, n: per_shard },
                &[dec],
            )
        })
        .collect();
    b.add(
        "logits/concat",
        OpKind::DataMovement { bytes: 4 * tokens * VOCAB, name: "Concat" },
        &shards,
    );
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::analyze_width;

    #[test]
    fn avg_width_4() {
        // paper Table 2: Trans = 4
        let w = analyze_width(&transformer(16));
        assert_eq!(w.avg_width, 4, "{w:?}");
    }

    #[test]
    fn cross_attention_kv_float_to_encoder_end() {
        // All decoder cross K/V fill right after the encoder: that level is
        // the widest in the graph.
        let w = analyze_width(&transformer(16));
        assert!(w.max_width >= LAYERS * SHARDS, "{w:?}");
    }

    #[test]
    fn heads_are_heavy_at_canonical_batch() {
        let g = transformer(16);
        let head = g.nodes.iter().find(|n| n.name == "enc0/self/head0").unwrap();
        assert!(head.is_heavy(), "flops={:.2e}", head.cost.flops);
    }

    #[test]
    fn shards_are_parallel_and_heavy() {
        let g = transformer(16);
        let s0 = g.nodes.iter().find(|n| n.name == "enc0/ff1/shard0").unwrap();
        let s1 = g.nodes.iter().find(|n| n.name == "enc0/ff1/shard1").unwrap();
        assert!(s0.is_heavy() && s1.is_heavy());
        assert_eq!(s0.deps, s1.deps); // same input ⇒ schedulable in parallel
    }

    #[test]
    fn validates_and_is_big() {
        let g = transformer(16);
        assert!(g.validate().is_ok());
        assert!(g.total_flops() > 5e11); // >0.5 TFLOP per batch
    }
}
