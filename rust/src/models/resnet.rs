//! ResNet-50 (He et al.): bottleneck residual blocks. Average width 1
//! (paper Table 2) — the residual adds are light, so the heavy-op graph is
//! almost a chain, with occasional 1×1 projection shortcuts (max width 2).

use crate::graph::{Graph, GraphBuilder, NodeId};
use crate::ops::OpKind;

use super::{conv, fc, pool, relu};

/// One bottleneck block: 1×1 reduce → 3×3 → 1×1 expand (+ projection
/// shortcut when the geometry changes).
#[allow(clippy::too_many_arguments)]
fn bottleneck(
    b: &mut GraphBuilder,
    name: &str,
    batch: usize,
    hw: usize,
    in_c: usize,
    mid_c: usize,
    out_c: usize,
    project: bool,
    input: NodeId,
) -> NodeId {
    let c1 = conv(b, &format!("{name}/conv1x1a"), batch, hw, in_c, mid_c, 1, &[input]);
    let c2 = conv(b, &format!("{name}/conv3x3"), batch, hw, mid_c, mid_c, 3, &[c1]);
    let c3 = conv(b, &format!("{name}/conv1x1b"), batch, hw, mid_c, out_c, 1, &[c2]);
    let shortcut = if project {
        conv(b, &format!("{name}/proj"), batch, hw, in_c, out_c, 1, &[input])
    } else {
        input
    };
    let add = b.add(
        &format!("{name}/add"),
        OpKind::Elementwise { elems: batch * hw * hw * out_c, name: "Add" },
        &[c3, shortcut],
    );
    relu(b, &format!("{name}/relu"), batch, hw, out_c, &[add])
}

/// Build ResNet-50 at the given batch size.
pub fn resnet50(batch: usize) -> Graph {
    let mut b = GraphBuilder::new("resnet50", batch);
    let input = b.add(
        "input",
        OpKind::DataMovement { bytes: 4 * batch * 224 * 224 * 3, name: "Feed" },
        &[],
    );
    let c1 = conv(&mut b, "conv1/7x7", batch, 112, 3, 64, 7, &[input]);
    let r1 = relu(&mut b, "relu1", batch, 112, 64, &[c1]);
    let mut prev = pool(&mut b, "pool1", batch, 56, 64, &[r1]);

    // (blocks, hw, mid_c, out_c)
    let stages: [(usize, usize, usize, usize); 4] =
        [(3, 56, 64, 256), (4, 28, 128, 512), (6, 14, 256, 1024), (3, 7, 512, 2048)];
    let mut in_c = 64;
    for (si, (blocks, hw, mid, out)) in stages.iter().enumerate() {
        for bi in 0..*blocks {
            let project = bi == 0;
            prev = bottleneck(
                &mut b,
                &format!("stage{}/block{}", si + 2, bi),
                batch,
                *hw,
                in_c,
                *mid,
                *out,
                project,
                prev,
            );
            in_c = *out;
        }
    }
    let gp = pool(&mut b, "global_pool", batch, 1, 2048, &[prev]);
    fc(&mut b, "fc/logits", batch, 2048, 1000, &[gp]);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::analyze_width;

    #[test]
    fn avg_width_is_1() {
        let w = analyze_width(&resnet50(16));
        assert_eq!(w.avg_width, 1, "{w:?}");
    }

    #[test]
    fn projection_shortcuts_give_max_width_2() {
        let w = analyze_width(&resnet50(16));
        assert_eq!(w.max_width, 2, "{w:?}");
    }

    #[test]
    fn has_53_convs_plus_fc() {
        let g = resnet50(16);
        let convs = g.nodes.iter().filter(|n| n.kind.name() == "Conv").count();
        assert_eq!(convs, 1 + 16 * 3 + 4); // stem + 48 block convs + 4 proj
    }

    #[test]
    fn flops_match_published_scale() {
        // ResNet-50 ≈ 4.1 GFLOPs/image (2× MACs); allow wide tolerance for
        // the simplified geometry.
        let g = resnet50(1);
        assert!(g.total_flops() > 5e9 && g.total_flops() < 13e9,
                "flops={:.2e}", g.total_flops());
    }
}
