//! ResNeXt-50 (32×4d, Xie et al.): ResNet-50's bottlenecks with grouped
//! 3×3 convs. The grouped conv is dispatched as a single library kernel, so
//! structurally this remains a chain (width 1) with a different
//! FLOPs/channel profile.

use crate::graph::{Graph, GraphBuilder, NodeId};
use crate::ops::OpKind;

use super::{conv, fc, pool, relu};

/// Grouped 3×3 conv modelled as its per-group GEMM sum: FLOPs divide by the
/// group count (32 groups, cardinality dimension).
fn grouped_conv3x3(
    b: &mut GraphBuilder,
    name: &str,
    batch: usize,
    hw: usize,
    channels: usize,
    groups: usize,
    dep: NodeId,
) -> NodeId {
    let per_group = channels / groups;
    // one kernel invocation: im2col GEMM with k reduced by the group factor
    b.add(
        name,
        OpKind::Conv {
            batch,
            out_h: hw,
            out_w: hw,
            in_c: per_group,
            out_c: channels,
            k_h: 3,
            k_w: 3,
        },
        &[dep],
    )
}

/// Build ResNeXt-50 (32×4d) at the given batch size.
pub fn resnext50(batch: usize) -> Graph {
    let mut b = GraphBuilder::new("resnext50", batch);
    let input = b.add(
        "input",
        OpKind::DataMovement { bytes: 4 * batch * 224 * 224 * 3, name: "Feed" },
        &[],
    );
    let c1 = conv(&mut b, "conv1/7x7", batch, 112, 3, 64, 7, &[input]);
    let r1 = relu(&mut b, "relu1", batch, 112, 64, &[c1]);
    let mut prev = pool(&mut b, "pool1", batch, 56, 64, &[r1]);

    let stages: [(usize, usize, usize, usize); 4] =
        [(3, 56, 128, 256), (4, 28, 256, 512), (6, 14, 512, 1024), (3, 7, 1024, 2048)];
    let mut in_c = 64;
    for (si, (blocks, hw, mid, out)) in stages.iter().enumerate() {
        for bi in 0..*blocks {
            let nm = format!("stage{}/block{}", si + 2, bi);
            let a = conv(&mut b, &format!("{nm}/conv1x1a"), batch, *hw, in_c, *mid, 1, &[prev]);
            let g = grouped_conv3x3(&mut b, &format!("{nm}/gconv3x3"), batch, *hw, *mid, 32, a);
            let c = conv(&mut b, &format!("{nm}/conv1x1b"), batch, *hw, *mid, *out, 1, &[g]);
            let shortcut = if bi == 0 {
                conv(&mut b, &format!("{nm}/proj"), batch, *hw, in_c, *out, 1, &[prev])
            } else {
                prev
            };
            let add = b.add(
                &format!("{nm}/add"),
                OpKind::Elementwise { elems: batch * hw * hw * out, name: "Add" },
                &[c, shortcut],
            );
            prev = relu(&mut b, &format!("{nm}/relu"), batch, *hw, *out, &[add]);
            in_c = *out;
        }
    }
    let gp = pool(&mut b, "global_pool", batch, 1, 2048, &[prev]);
    fc(&mut b, "fc/logits", batch, 2048, 1000, &[gp]);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::analyze_width;

    #[test]
    fn chain_like_resnet() {
        let w = analyze_width(&resnext50(16));
        assert_eq!(w.avg_width, 1, "{w:?}");
        assert_eq!(w.max_width, 2, "{w:?}");
    }

    #[test]
    fn grouped_conv_cheaper_than_dense() {
        // grouped 3×3 at same width costs 1/32 of the dense version
        let g = resnext50(1);
        let grouped = g.nodes.iter().find(|n| n.name.contains("gconv")).unwrap();
        if let OpKind::Conv { in_c, out_c, .. } = grouped.kind {
            // contraction dim is the per-group channel count: 1/32 of dense
            assert_eq!(in_c * 32, out_c);
        } else {
            panic!("not a conv");
        }
    }
}
