//! Micro-benchmarks: MatMul-N and FC stacks (the paper's §5 workloads).
//!
//! `MatMul-512` stands in for the FC layers of the YouTube/Facebook
//! recommendation models (sizes 64–1k); `MatMul-4k` for Transformer's FC
//! layers; `MatMul-8k`/`-16k` probe the UPI limit in §7.

use crate::graph::{Graph, GraphBuilder};
use crate::ops::OpKind;

/// A single square `n×n×n` MatMul operator (the paper's MatMul-N).
pub fn matmul_n(n: usize) -> Graph {
    let mut b = GraphBuilder::new(&format!("matmul_{n}"), n);
    let src = b.add("input", OpKind::DataMovement { bytes: 4 * n * n, name: "Feed" }, &[]);
    b.add("matmul", OpKind::MatMul { m: n, k: n, n }, &[src]);
    b.build()
}

/// A stack of `layers` FC layers of width `n` at `batch` (FC-512 etc.).
pub fn fc_stack(n: usize, layers: usize, batch: usize) -> Graph {
    let mut b = GraphBuilder::new(&format!("fc_{n}"), batch);
    let src = b.add("input", OpKind::DataMovement { bytes: 4 * batch * n, name: "Feed" }, &[]);
    let mut prev = src;
    for i in 0..layers {
        let mm = b.add(&format!("fc{i}"), OpKind::MatMul { m: batch, k: n, n }, &[prev]);
        prev = b.add(
            &format!("relu{i}"),
            OpKind::Elementwise { elems: batch * n, name: "ReLU" },
            &[mm],
        );
    }
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::analyze_width;

    #[test]
    fn matmul_flops() {
        let g = matmul_n(512);
        assert_eq!(g.total_flops(), 2.0 * 512f64.powi(3));
    }

    #[test]
    fn fc_stack_is_chain() {
        let g = fc_stack(4096, 3, 512);
        let w = analyze_width(&g);
        assert_eq!(w.max_width, 1);
        assert_eq!(w.levels, 3);
    }

    #[test]
    fn small_fc_stack_has_no_heavy_ops() {
        // FC-512 at batch 16: 2*16*512*512 = 8.4 MFLOPs < threshold
        let g = fc_stack(512, 3, 16);
        let w = analyze_width(&g);
        assert_eq!(w.heavy_ops, 0);
    }
}
