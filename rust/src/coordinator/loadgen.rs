//! Deterministic load generator for the serving path.
//!
//! Drives closed-loop (fixed concurrency, one request in flight per
//! worker) and open-loop (Poisson arrivals at an offered rate) request
//! streams against a running [`Coordinator`], seeded via
//! [`crate::util::prng::Prng`] so the request mix is reproducible, and
//! reports p50/p99 latency + throughput through the [`crate::metrics`]
//! histogram types. The serving bench and the `serve_workload` example
//! are thin wrappers over this module.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::metrics::LatencyHistogram;
use crate::runtime::gen_input;
use crate::util::prng::Prng;

use super::server::Coordinator;

/// Arrival process for generated requests.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Arrival {
    /// `concurrency` workers each keep exactly one request in flight.
    Closed {
        /// Number of closed-loop workers.
        concurrency: usize,
    },
    /// Poisson arrivals at `rate_rps` requests/second from one submitter.
    Open {
        /// Offered load in requests per second.
        rate_rps: f64,
    },
}

/// Workload description.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Model family to drive.
    pub kind: String,
    /// Total requests to issue.
    pub requests: usize,
    /// Arrival process.
    pub arrival: Arrival,
    /// PRNG seed for the request mix.
    pub seed: u64,
}

impl LoadgenConfig {
    /// Closed-loop workload with the default seed.
    pub fn closed(kind: &str, requests: usize, concurrency: usize) -> Self {
        LoadgenConfig {
            kind: kind.to_string(),
            requests,
            arrival: Arrival::Closed { concurrency: concurrency.max(1) },
            seed: 0x5EED,
        }
    }

    /// Open-loop workload with the default seed.
    pub fn open(kind: &str, requests: usize, rate_rps: f64) -> Self {
        LoadgenConfig {
            kind: kind.to_string(),
            requests,
            arrival: Arrival::Open { rate_rps },
            seed: 0x5EED,
        }
    }

    /// Override the request-mix seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

/// Aggregated result of one load-generation run.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// Requests that completed successfully.
    pub completed: usize,
    /// Requests that failed (submit rejection or execution error).
    pub errors: usize,
    /// Wall-clock duration of the run (seconds).
    pub elapsed_s: f64,
    /// Completed requests per wall-clock second.
    pub throughput_rps: f64,
    /// Wall-clock submit→response latency, p50 (ms).
    pub wall_p50_ms: f64,
    /// Wall-clock submit→response latency, p99 (ms).
    pub wall_p99_ms: f64,
    /// Model-view latency (queue + model time; simulated seconds on the
    /// sim backend), p50 (ms).
    pub model_p50_ms: f64,
    /// Model-view latency, p99 (ms).
    pub model_p99_ms: f64,
    /// Model-view latency, mean (ms).
    pub model_mean_ms: f64,
    /// Mean requests per dispatched batch over the coordinator lifetime.
    pub mean_batch: f64,
}

/// Run a workload against a coordinator and aggregate the results.
pub fn run(coord: &Coordinator, cfg: &LoadgenConfig) -> Result<LoadReport> {
    let shape = coord
        .router()
        .item_shape(&cfg.kind)
        .ok_or_else(|| anyhow!("kind '{}' not served", cfg.kind))?
        .clone();
    let dims = shape.dims();
    match cfg.arrival {
        Arrival::Closed { concurrency } => run_closed(coord, cfg, &dims, concurrency),
        Arrival::Open { rate_rps } => run_open(coord, cfg, &dims, rate_rps),
    }
}

fn run_closed(
    coord: &Coordinator,
    cfg: &LoadgenConfig,
    dims: &[usize],
    concurrency: usize,
) -> Result<LoadReport> {
    let remaining = AtomicUsize::new(cfg.requests);
    let t0 = Instant::now();
    let mut wall: Vec<f64> = Vec::with_capacity(cfg.requests);
    let mut model: Vec<f64> = Vec::with_capacity(cfg.requests);
    let mut errors = 0usize;
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..concurrency.max(1))
            .map(|w| {
                let submitter = coord.submitter();
                let kind = cfg.kind.clone();
                let seed = cfg.seed.wrapping_add((w as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
                let remaining = &remaining;
                s.spawn(move || {
                    let mut rng = Prng::new(seed);
                    let mut wall = Vec::new();
                    let mut model = Vec::new();
                    let mut errors = 0usize;
                    while remaining
                        .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| v.checked_sub(1))
                        .is_ok()
                    {
                        let input = gen_input(rng.below(9973) as u32, dims, 1.0);
                        let t = Instant::now();
                        match submitter.infer(&kind, input) {
                            Ok(resp) if resp.is_ok() => {
                                wall.push(t.elapsed().as_secs_f64());
                                model.push(resp.queue_s + resp.execute_s);
                            }
                            _ => errors += 1,
                        }
                    }
                    (wall, model, errors)
                })
            })
            .collect();
        for h in handles {
            let (w, m, e) = h.join().expect("loadgen worker panicked");
            wall.extend(w);
            model.extend(m);
            errors += e;
        }
    });
    Ok(build_report(coord, wall, model, errors, t0.elapsed().as_secs_f64()))
}

fn run_open(
    coord: &Coordinator,
    cfg: &LoadgenConfig,
    dims: &[usize],
    rate_rps: f64,
) -> Result<LoadReport> {
    let mut rng = Prng::new(cfg.seed);
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(cfg.requests);
    let mut errors = 0usize;
    let mut next_arrival = 0.0f64;
    for _ in 0..cfg.requests {
        if rate_rps > 0.0 {
            next_arrival += rng.exp(1.0 / rate_rps);
        }
        let now = t0.elapsed().as_secs_f64();
        if next_arrival > now {
            std::thread::sleep(Duration::from_secs_f64(next_arrival - now));
        }
        let input = gen_input(rng.below(9973) as u32, dims, 1.0);
        match coord.submit(&cfg.kind, input) {
            Ok(rx) => pending.push((rx, Instant::now())),
            Err(_) => errors += 1,
        }
    }
    let mut wall = Vec::with_capacity(pending.len());
    let mut model = Vec::with_capacity(pending.len());
    for (rx, t) in pending {
        match rx.recv() {
            Ok(resp) if resp.is_ok() => {
                wall.push(t.elapsed().as_secs_f64());
                model.push(resp.queue_s + resp.execute_s);
            }
            _ => errors += 1,
        }
    }
    Ok(build_report(coord, wall, model, errors, t0.elapsed().as_secs_f64()))
}

fn build_report(
    coord: &Coordinator,
    wall: Vec<f64>,
    model: Vec<f64>,
    errors: usize,
    elapsed_s: f64,
) -> LoadReport {
    let wall_h = LatencyHistogram::new();
    let model_h = LatencyHistogram::new();
    for &s in &wall {
        wall_h.record(s);
    }
    for &s in &model {
        model_h.record(s);
    }
    let completed = wall.len();
    LoadReport {
        completed,
        errors,
        elapsed_s,
        throughput_rps: if elapsed_s > 0.0 { completed as f64 / elapsed_s } else { 0.0 },
        wall_p50_ms: wall_h.percentile(50.0) * 1e3,
        wall_p99_ms: wall_h.percentile(99.0) * 1e3,
        model_p50_ms: model_h.percentile(50.0) * 1e3,
        model_p99_ms: model_h.percentile(99.0) * 1e3,
        model_mean_ms: model_h.mean() * 1e3,
        mean_batch: coord.metrics().mean_batch_size(),
    }
}

impl LoadReport {
    /// One-line summary for logs and CLI output.
    pub fn summary(&self) -> String {
        format!(
            "completed={} errors={} {:.1} req/s | wall p50={:.3}ms p99={:.3}ms | \
             model p50={:.3}ms p99={:.3}ms | mean_batch={:.2}",
            self.completed,
            self.errors,
            self.throughput_rps,
            self.wall_p50_ms,
            self.wall_p99_ms,
            self.model_p50_ms,
            self.model_p99_ms,
            self.mean_batch,
        )
    }
}
