//! Deterministic load generator for the serving path.
//!
//! Drives closed-loop (fixed concurrency, one request in flight per
//! worker) and open-loop (Poisson arrivals at an offered rate) request
//! streams against a running [`Coordinator`], seeded via
//! [`crate::util::prng::Prng`] so the request mix is reproducible, and
//! reports p50/p99 latency + throughput through the [`crate::metrics`]
//! histogram types. The serving bench and the `serve_workload` example
//! are thin wrappers over this module.
//!
//! Determinism is testable without a coordinator: [`open_plan`] is the
//! exact arrival schedule the open loop follows for a seed, and
//! [`closed_tags`] is the exact per-worker tag stream of the closed
//! loop. [`MixPhase`] describes shifting multi-model traffic (one model
//! ramps up while another drains) for the core-aware scheduler.
//!
//! Beyond synthetic streams, [`Scenario::Replay`] re-issues a *recorded*
//! trace's exact arrival process (inter-arrival offsets + kind sequence
//! from a [`crate::tracestore::ReplayPlan`]) — the paper-faithful way to
//! score a configuration against real traffic instead of a Poisson
//! approximation of it.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::metrics::{LatencyHistogram, WindowTracker};
use crate::runtime::{gen_input, KindId};
use crate::tracestore::ReplayPlan;
use crate::tuner::OnlineTuner;
use crate::util::prng::Prng;
use crate::util::stats;

use super::server::Coordinator;

/// Modulus for deterministic request tags (any large prime works; fixed
/// so schedules are stable across versions).
const TAG_MODULUS: usize = 9973;

/// Deterministic seed for closed-loop worker `w` of a run seeded `seed`.
pub fn worker_seed(seed: u64, worker: usize) -> u64 {
    seed.wrapping_add((worker as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// The first `n` input tags worker `w` draws in a closed-loop run — the
/// exact request order that worker submits for the same seed.
pub fn closed_tags(seed: u64, worker: usize, n: usize) -> Vec<u32> {
    let mut rng = Prng::new(worker_seed(seed, worker));
    (0..n).map(|_| rng.below(TAG_MODULUS) as u32).collect()
}

/// The open-loop plan for a seed: cumulative Poisson arrival offset
/// (seconds) plus input tag per request. [`run`]'s open loop follows
/// this exact schedule.
pub fn open_plan(seed: u64, rate_rps: f64, n: usize) -> Vec<(f64, u32)> {
    let mut rng = Prng::new(seed);
    let mut t = 0.0f64;
    (0..n)
        .map(|_| {
            if rate_rps > 0.0 {
                t += rng.exp(1.0 / rate_rps);
            }
            (t, rng.below(TAG_MODULUS) as u32)
        })
        .collect()
}

/// Arrival process for generated requests.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Arrival {
    /// `concurrency` workers each keep exactly one request in flight.
    Closed {
        /// Number of closed-loop workers.
        concurrency: usize,
    },
    /// Poisson arrivals at `rate_rps` requests/second from one submitter.
    Open {
        /// Offered load in requests per second.
        rate_rps: f64,
    },
}

/// Workload description.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Model family to drive.
    pub kind: String,
    /// Total requests to issue.
    pub requests: usize,
    /// Arrival process.
    pub arrival: Arrival,
    /// PRNG seed for the request mix.
    pub seed: u64,
}

impl LoadgenConfig {
    /// Closed-loop workload with the default seed.
    pub fn closed(kind: &str, requests: usize, concurrency: usize) -> Self {
        LoadgenConfig {
            kind: kind.to_string(),
            requests,
            arrival: Arrival::Closed { concurrency: concurrency.max(1) },
            seed: 0x5EED,
        }
    }

    /// Open-loop workload with the default seed.
    pub fn open(kind: &str, requests: usize, rate_rps: f64) -> Self {
        LoadgenConfig {
            kind: kind.to_string(),
            requests,
            arrival: Arrival::Open { rate_rps },
            seed: 0x5EED,
        }
    }

    /// Override the request-mix seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

/// Aggregated result of one load-generation run.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// Requests that completed successfully.
    pub completed: usize,
    /// Requests that failed (submit rejection or execution error).
    pub errors: usize,
    /// Wall-clock duration of the run (seconds).
    pub elapsed_s: f64,
    /// Completed requests per wall-clock second.
    pub throughput_rps: f64,
    /// Wall-clock submit→response latency, p50 (ms).
    pub wall_p50_ms: f64,
    /// Wall-clock submit→response latency, p99 (ms).
    pub wall_p99_ms: f64,
    /// Model-view latency (queue + model time; simulated seconds on the
    /// sim backend), p50 (ms).
    pub model_p50_ms: f64,
    /// Model-view latency, p99 (ms).
    pub model_p99_ms: f64,
    /// Model-view latency, mean (ms).
    pub model_mean_ms: f64,
    /// Mean requests per dispatched batch over the coordinator lifetime.
    pub mean_batch: f64,
}

/// A request stream to drive: a seeded synthetic workload, or the replay
/// of a recorded trace's exact arrival process.
#[derive(Debug, Clone)]
pub enum Scenario {
    /// Seeded closed-/open-loop stream ([`run`]).
    Synthetic(LoadgenConfig),
    /// Re-issue a recorded trace's arrivals ([`run_replay`]).
    Replay(ReplayPlan),
}

/// Run either scenario kind against a coordinator.
pub fn run_scenario(coord: &Coordinator, scenario: &Scenario) -> Result<LoadReport> {
    match scenario {
        Scenario::Synthetic(cfg) => run(coord, cfg),
        Scenario::Replay(plan) => run_replay(coord, plan),
    }
}

/// Re-issue a recorded arrival process: every request is submitted at
/// its recorded offset from the first arrival, with the recorded kind
/// sequence, and input tags from the plan's seeded PRNG — the generator
/// side is fully deterministic, so two replays of the same plan submit
/// an identical request stream.
pub fn run_replay(coord: &Coordinator, plan: &ReplayPlan) -> Result<LoadReport> {
    // resolve each referenced trace kind → (served id, dims) once
    let router = coord.router();
    let mut resolved: Vec<Option<(KindId, Vec<usize>)>> = vec![None; plan.kinds.len()];
    for &(_, k) in &plan.arrivals {
        let slot = resolved
            .get_mut(k as usize)
            .ok_or_else(|| anyhow!("replay: kind id {k} outside the trace kind table"))?;
        if slot.is_none() {
            let name = &plan.kinds[k as usize];
            let id = router
                .resolve(name)
                .ok_or_else(|| anyhow!("kind '{name}' not served"))?;
            *slot = Some((id, router.item_shape_id(id).dims()));
        }
    }
    let mut rng = Prng::new(plan.seed);
    let submitter = coord.submitter();
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(plan.arrivals.len());
    let mut errors = 0usize;
    for &(offset, k) in &plan.arrivals {
        let now = t0.elapsed().as_secs_f64();
        if offset > now {
            std::thread::sleep(Duration::from_secs_f64(offset - now));
        }
        let (id, dims) = resolved[k as usize].as_ref().expect("resolved above");
        let input = gen_input(rng.below(TAG_MODULUS) as u32, dims, 1.0);
        match submitter.submit_id(*id, input) {
            Ok(rx) => pending.push((rx, Instant::now())),
            Err(_) => errors += 1,
        }
    }
    let mut wall = Vec::with_capacity(pending.len());
    let mut model = Vec::with_capacity(pending.len());
    for (rx, t) in pending {
        match rx.recv() {
            Ok(resp) if resp.is_ok() => {
                wall.push(t.elapsed().as_secs_f64());
                model.push(resp.queue_s + resp.execute_s);
            }
            _ => errors += 1,
        }
    }
    Ok(build_report(coord, wall, model, errors, t0.elapsed().as_secs_f64()))
}

/// Run a workload against a coordinator and aggregate the results. The
/// kind is interned once here; every generated request submits by
/// [`crate::runtime::KindId`].
pub fn run(coord: &Coordinator, cfg: &LoadgenConfig) -> Result<LoadReport> {
    let id = coord
        .router()
        .resolve(&cfg.kind)
        .ok_or_else(|| anyhow!("kind '{}' not served", cfg.kind))?;
    let dims = coord.router().item_shape_id(id).dims();
    match cfg.arrival {
        Arrival::Closed { concurrency } => run_closed(coord, cfg, id, &dims, concurrency),
        Arrival::Open { rate_rps } => run_open(coord, cfg, id, &dims, rate_rps),
    }
}

fn run_closed(
    coord: &Coordinator,
    cfg: &LoadgenConfig,
    id: KindId,
    dims: &[usize],
    concurrency: usize,
) -> Result<LoadReport> {
    let remaining = AtomicUsize::new(cfg.requests);
    let t0 = Instant::now();
    let mut wall: Vec<f64> = Vec::with_capacity(cfg.requests);
    let mut model: Vec<f64> = Vec::with_capacity(cfg.requests);
    let mut errors = 0usize;
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..concurrency.max(1))
            .map(|w| {
                let submitter = coord.submitter();
                let seed = worker_seed(cfg.seed, w);
                let remaining = &remaining;
                s.spawn(move || {
                    let mut rng = Prng::new(seed);
                    let mut wall = Vec::new();
                    let mut model = Vec::new();
                    let mut errors = 0usize;
                    while remaining
                        .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| v.checked_sub(1))
                        .is_ok()
                    {
                        let input = gen_input(rng.below(TAG_MODULUS) as u32, dims, 1.0);
                        let t = Instant::now();
                        match submitter.infer_id(id, input) {
                            Ok(resp) if resp.is_ok() => {
                                wall.push(t.elapsed().as_secs_f64());
                                model.push(resp.queue_s + resp.execute_s);
                            }
                            _ => errors += 1,
                        }
                    }
                    (wall, model, errors)
                })
            })
            .collect();
        for h in handles {
            let (w, m, e) = h.join().expect("loadgen worker panicked");
            wall.extend(w);
            model.extend(m);
            errors += e;
        }
    });
    Ok(build_report(coord, wall, model, errors, t0.elapsed().as_secs_f64()))
}

fn run_open(
    coord: &Coordinator,
    cfg: &LoadgenConfig,
    id: KindId,
    dims: &[usize],
    rate_rps: f64,
) -> Result<LoadReport> {
    let plan = open_plan(cfg.seed, rate_rps, cfg.requests);
    let submitter = coord.submitter();
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(cfg.requests);
    let mut errors = 0usize;
    for (next_arrival, tag) in plan {
        let now = t0.elapsed().as_secs_f64();
        if next_arrival > now {
            std::thread::sleep(Duration::from_secs_f64(next_arrival - now));
        }
        let input = gen_input(tag, dims, 1.0);
        match submitter.submit_id(id, input) {
            Ok(rx) => pending.push((rx, Instant::now())),
            Err(_) => errors += 1,
        }
    }
    let mut wall = Vec::with_capacity(pending.len());
    let mut model = Vec::with_capacity(pending.len());
    for (rx, t) in pending {
        match rx.recv() {
            Ok(resp) if resp.is_ok() => {
                wall.push(t.elapsed().as_secs_f64());
                model.push(resp.queue_s + resp.execute_s);
            }
            _ => errors += 1,
        }
    }
    Ok(build_report(coord, wall, model, errors, t0.elapsed().as_secs_f64()))
}

fn build_report(
    coord: &Coordinator,
    wall: Vec<f64>,
    model: Vec<f64>,
    errors: usize,
    elapsed_s: f64,
) -> LoadReport {
    let wall_h = LatencyHistogram::new();
    let model_h = LatencyHistogram::new();
    for &s in &wall {
        wall_h.record(s);
    }
    for &s in &model {
        model_h.record(s);
    }
    let completed = wall.len();
    LoadReport {
        completed,
        errors,
        elapsed_s,
        throughput_rps: if elapsed_s > 0.0 { completed as f64 / elapsed_s } else { 0.0 },
        wall_p50_ms: wall_h.percentile(50.0) * 1e3,
        wall_p99_ms: wall_h.percentile(99.0) * 1e3,
        model_p50_ms: model_h.percentile(50.0) * 1e3,
        model_p99_ms: model_h.percentile(99.0) * 1e3,
        model_mean_ms: model_h.mean() * 1e3,
        mean_batch: coord.metrics().mean_batch_size(),
    }
}

impl LoadReport {
    /// One-line summary for logs and CLI output.
    pub fn summary(&self) -> String {
        format!(
            "completed={} errors={} {:.1} req/s | wall p50={:.3}ms p99={:.3}ms | \
             model p50={:.3}ms p99={:.3}ms | mean_batch={:.2}",
            self.completed,
            self.errors,
            self.throughput_rps,
            self.wall_p50_ms,
            self.wall_p99_ms,
            self.model_p50_ms,
            self.model_p99_ms,
            self.mean_batch,
        )
    }
}

// ---------------------------------------------------------------------------
// shifting multi-model mix (the core-aware scheduler's scenario)
// ---------------------------------------------------------------------------

/// One phase of a shifting multi-model mix: `requests` closed-loop
/// requests whose kinds are drawn (seeded) from `weights`.
#[derive(Debug, Clone)]
pub struct MixPhase {
    /// Per-kind traffic weights (need not sum to 1; zero allowed).
    pub weights: Vec<(String, f64)>,
    /// Requests issued in this phase.
    pub requests: usize,
}

impl MixPhase {
    /// Phase from borrowed kind names.
    pub fn new(weights: &[(&str, f64)], requests: usize) -> Self {
        MixPhase {
            weights: weights.iter().map(|(k, w)| (k.to_string(), *w)).collect(),
            requests,
        }
    }

    /// A ramp scenario: over `phases` (≥ 2) phases, traffic shifts
    /// linearly from all-`a` to all-`b` while volume stays constant —
    /// one model ramps up while the other drains.
    pub fn ramp(a: &str, b: &str, phases: usize, requests_per_phase: usize) -> Vec<MixPhase> {
        let n = phases.max(2);
        (0..n)
            .map(|i| {
                let f = i as f64 / (n - 1) as f64;
                MixPhase {
                    weights: vec![(a.to_string(), 1.0 - f), (b.to_string(), f)],
                    requests: requests_per_phase,
                }
            })
            .collect()
    }
}

/// Per-kind slice of a mix phase.
#[derive(Debug, Clone)]
pub struct KindReport {
    /// Model kind.
    pub kind: String,
    /// Requests of this kind that completed.
    pub completed: usize,
    /// Model-view latency (queue + model time), mean (ms).
    pub model_mean_ms: f64,
    /// Model-view latency, p99 (ms).
    pub model_p99_ms: f64,
}

/// Result of one mix phase: the aggregate plus per-kind latency.
#[derive(Debug, Clone)]
pub struct MixReport {
    /// Aggregate over the phase.
    pub overall: LoadReport,
    /// Per-kind breakdown, in the phase's weight order.
    pub per_kind: Vec<KindReport>,
}

impl MixReport {
    /// The slice for one kind, if it saw traffic.
    pub fn kind(&self, kind: &str) -> Option<&KindReport> {
        self.per_kind.iter().find(|k| k.kind == kind)
    }

    /// One-line summary for logs and CLI output.
    pub fn summary(&self) -> String {
        let mut s = self.overall.summary();
        for k in &self.per_kind {
            s.push_str(&format!(
                " | {}: n={} mean={:.3}ms p99={:.3}ms",
                k.kind, k.completed, k.model_mean_ms, k.model_p99_ms
            ));
        }
        s
    }
}

/// Run one phase of a shifting mix: `concurrency` closed-loop workers,
/// each request's kind drawn from the phase weights by the seeded PRNG
/// (same seed ⇒ same per-worker kind/tag stream).
pub fn run_mix_phase(
    coord: &Coordinator,
    phase: &MixPhase,
    concurrency: usize,
    seed: u64,
) -> Result<MixReport> {
    if phase.weights.is_empty() {
        bail!("mix phase: no kinds");
    }
    let total: f64 = phase.weights.iter().map(|(_, w)| w.max(0.0)).sum();
    if total <= 0.0 {
        bail!("mix phase: all weights zero");
    }
    // kind → (interned id, dims, cumulative weight), resolved once
    let mut cum = 0.0f64;
    let mut kinds: Vec<(String, KindId, Vec<usize>, f64)> = Vec::with_capacity(phase.weights.len());
    for (kind, w) in &phase.weights {
        let id = coord
            .router()
            .resolve(kind)
            .ok_or_else(|| anyhow!("kind '{kind}' not served"))?;
        cum += w.max(0.0) / total;
        kinds.push((kind.clone(), id, coord.router().item_shape_id(id).dims(), cum));
    }

    let remaining = AtomicUsize::new(phase.requests);
    let t0 = Instant::now();
    let mut samples: Vec<(usize, f64, f64)> = Vec::with_capacity(phase.requests);
    let mut errors = 0usize;
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..concurrency.max(1))
            .map(|w| {
                let submitter = coord.submitter();
                let kinds = &kinds;
                let remaining = &remaining;
                let seed = worker_seed(seed, w);
                s.spawn(move || {
                    let mut rng = Prng::new(seed);
                    let mut samples: Vec<(usize, f64, f64)> = Vec::new();
                    let mut errors = 0usize;
                    while remaining
                        .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| v.checked_sub(1))
                        .is_ok()
                    {
                        let u = rng.f64();
                        let ki = kinds
                            .iter()
                            .position(|(_, _, _, c)| u < *c)
                            .unwrap_or(kinds.len() - 1);
                        let tag = rng.below(TAG_MODULUS) as u32;
                        let input = gen_input(tag, &kinds[ki].2, 1.0);
                        let t = Instant::now();
                        match submitter.infer_id(kinds[ki].1, input) {
                            Ok(resp) if resp.is_ok() => samples.push((
                                ki,
                                t.elapsed().as_secs_f64(),
                                resp.queue_s + resp.execute_s,
                            )),
                            _ => errors += 1,
                        }
                    }
                    (samples, errors)
                })
            })
            .collect();
        for h in handles {
            let (sm, e) = h.join().expect("mix worker panicked");
            samples.extend(sm);
            errors += e;
        }
    });
    let elapsed_s = t0.elapsed().as_secs_f64();

    let wall: Vec<f64> = samples.iter().map(|&(_, w, _)| w).collect();
    let model: Vec<f64> = samples.iter().map(|&(_, _, m)| m).collect();
    let overall = build_report(coord, wall, model, errors, elapsed_s);
    let per_kind = kinds
        .iter()
        .enumerate()
        .map(|(i, (kind, _, _, _))| {
            let m: Vec<f64> =
                samples.iter().filter(|&&(ki, _, _)| ki == i).map(|&(_, _, m)| m).collect();
            KindReport {
                kind: kind.clone(),
                completed: m.len(),
                model_mean_ms: stats::mean(&m) * 1e3,
                model_p99_ms: stats::percentile(&m, 99.0) * 1e3,
            }
        })
        .collect();
    Ok(MixReport { overall, per_kind })
}

/// Drive a multi-phase shifting mix end-to-end: run each phase (seeded
/// `seed + i`), close a metrics window, and — when a re-tuner is given —
/// fold the window in and apply any proposed re-plan before the next
/// phase. Pass `tuner: None` for the startup-frozen baseline. The single
/// implementation of the observe → propose → apply loop used by the CLI,
/// the serving example and the adaptive integration test.
pub fn run_shift(
    coord: &Coordinator,
    phases: &[MixPhase],
    concurrency: usize,
    seed: u64,
    mut tuner: Option<&mut OnlineTuner>,
) -> Result<Vec<MixReport>> {
    let mut tracker = WindowTracker::new();
    let mut current = coord.current_plan();
    let mut reports = Vec::with_capacity(phases.len());
    for (i, phase) in phases.iter().enumerate() {
        let report = run_mix_phase(coord, phase, concurrency, seed.wrapping_add(i as u64))?;
        let window = tracker.snapshot(coord.metrics());
        if let Some(t) = tuner.as_deref_mut() {
            t.observe(&window);
            if let Some(cur) = current.as_ref() {
                if let Some(next) = t.propose(cur)? {
                    coord.apply_plan(next.clone())?;
                    current = Some(next);
                }
            }
        }
        reports.push(report);
    }
    Ok(reports)
}
