//! Request router: validates incoming requests against the backend's
//! serving catalog and interns their kind — the single point where a
//! request's `String` kind becomes a dense [`KindId`]. Everything
//! downstream (batchers, dispatch, lanes, backends) indexes by id.

use std::sync::Arc;

use crate::error::{PallasError, PallasResult};
use crate::runtime::{Catalog, KindId, KindTable};

pub use crate::runtime::ItemShape;

use super::request::Request;

/// Routes requests by model kind.
pub struct Router {
    table: Arc<KindTable>,
    /// Item-shape contracts, dense by [`KindId`].
    shapes: Vec<ItemShape>,
}

impl Router {
    /// Derive routing tables from a backend [`Catalog`]; every served
    /// family must expose at least one batch bucket.
    pub fn new(catalog: &Catalog) -> PallasResult<Self> {
        let mut shapes = Vec::with_capacity(catalog.models.len());
        for spec in &catalog.models {
            if spec.buckets.is_empty() {
                return Err(PallasError::InvalidConfig(format!(
                    "kind '{}': catalog exposes no batch buckets",
                    spec.kind
                )));
            }
            shapes.push(spec.item.clone());
        }
        Ok(Router { table: Arc::new(catalog.kind_table()), shapes })
    }

    /// The interned kind table (shared with the batching loop and lanes).
    pub fn table(&self) -> &Arc<KindTable> {
        &self.table
    }

    /// Interned id for a family name, if served.
    pub fn resolve(&self, kind: &str) -> Option<KindId> {
        self.table.resolve(kind)
    }

    /// Families this router serves, sorted (precomputed at construction
    /// — no per-call sort).
    pub fn kinds(&self) -> Vec<&str> {
        self.table.sorted_names()
    }

    /// The interned id→name table in dense [`KindId`] order — stable for
    /// the life of the coordinator. Trace files store this slice once in
    /// their footer so events carry only `u16` ids.
    pub fn id_names(&self) -> &[String] {
        self.table.names()
    }

    /// Shape contract for a family.
    pub fn item_shape(&self, kind: &str) -> Option<&ItemShape> {
        self.table.resolve(kind).map(|id| &self.shapes[id.index()])
    }

    /// Shape contract for an interned family.
    pub fn item_shape_id(&self, id: KindId) -> &ItemShape {
        &self.shapes[id.index()]
    }

    /// Validate an input for a named family; returns the interned kind
    /// (the admission step of [`super::Submitter::submit`]).
    pub fn route(&self, kind: &str, input: &crate::runtime::Tensor) -> PallasResult<KindId> {
        let Some(id) = self.table.resolve(kind) else {
            return Err(PallasError::UnknownModel(kind.to_string()));
        };
        self.validate_id(id, input)?;
        Ok(id)
    }

    /// Validate an input against an already-interned kind's contract.
    pub fn validate_id(&self, id: KindId, input: &crate::runtime::Tensor) -> PallasResult<()> {
        let Some(shape) = self.shapes.get(id.index()) else {
            return Err(PallasError::UnknownModel(format!("kind id {}", id.0)));
        };
        let want = shape.dims();
        if input.shape != want {
            return Err(PallasError::Backend(format!(
                "kind '{}': input shape {:?} != expected {:?}",
                self.table.name(id),
                input.shape,
                want
            )));
        }
        let n: usize = want.iter().product();
        if input.data.len() != n {
            return Err(PallasError::Backend(format!(
                "kind '{}': data length {} != {}",
                self.table.name(id),
                input.data.len(),
                n
            )));
        }
        Ok(())
    }

    /// Validate a fully-formed request (id + input already interned).
    pub fn validate(&self, req: &Request) -> PallasResult<()> {
        self.validate_id(req.kind, &req.input)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Manifest, Tensor};
    use std::path::Path;

    fn catalog() -> Catalog {
        Manifest::parse(
            Path::new("/tmp"),
            r#"{"version":1,"artifacts":[
              {"name":"mlp_b1","file":"f","kind":"mlp","batch":1,
               "inputs":[{"shape":[1,8],"tag":0,"scale":1.0}],"output_shape":[1,2],
               "expected":{"prefix":[],"sum":0,"abs_sum":0,"count":2}},
              {"name":"transformer_b2","file":"f","kind":"transformer","batch":2,
               "inputs":[{"shape":[64,16],"tag":0,"scale":1.0}],"output_shape":[64,16],
               "expected":{"prefix":[],"sum":0,"abs_sum":0,"count":1024}}
            ]}"#,
        )
        .unwrap()
        .catalog(&["mlp", "transformer"])
        .unwrap()
    }

    fn input(shape: Vec<usize>) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    #[test]
    fn derives_item_shapes() {
        let r = Router::new(&catalog()).unwrap();
        assert_eq!(r.item_shape("mlp").unwrap().rows_per_item, 1);
        // transformer bucket-2 artifact has 64 rows ⇒ 32 rows per sequence
        assert_eq!(r.item_shape("transformer").unwrap().rows_per_item, 32);
        assert_eq!(r.kinds(), vec!["mlp", "transformer"]);
        // dense id order (catalog interning order), for trace footers
        assert_eq!(r.id_names(), ["mlp", "transformer"]);
    }

    #[test]
    fn routes_valid_rejects_invalid() {
        let r = Router::new(&catalog()).unwrap();
        let id = r.route("mlp", &input(vec![1, 8])).unwrap();
        assert_eq!(Some(id), r.resolve("mlp"));
        assert_eq!(r.item_shape_id(id).rows_per_item, 1);
        assert!(r.route("mlp", &input(vec![2, 8])).is_err());
        assert!(matches!(
            r.route("bert", &input(vec![1, 8])),
            Err(PallasError::UnknownModel(_))
        ));
        // id-level validation matches the name-level one
        assert!(r.validate_id(id, &input(vec![1, 8])).is_ok());
        assert!(r.validate_id(id, &input(vec![64, 16])).is_err());
    }

    #[test]
    fn rejects_bucketless_catalog() {
        let c = Catalog {
            models: vec![crate::runtime::ModelSpec {
                kind: "mlp".into(),
                item: ItemShape { rows_per_item: 1, feature_dims: vec![8] },
                buckets: vec![],
            }],
        };
        assert!(Router::new(&c).is_err());
    }
}
