//! Request router: validates incoming requests against the artifact
//! manifest and routes them to the right per-model batching queue.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::runtime::Manifest;

use super::request::Request;

/// Per-item input shape for a model family (first dim = rows per item).
#[derive(Debug, Clone, PartialEq)]
pub struct ItemShape {
    /// Rows one item contributes to the batch dimension.
    pub rows_per_item: usize,
    /// Trailing feature dimensions.
    pub feature_dims: Vec<usize>,
}

/// Routes requests by model kind.
pub struct Router {
    shapes: HashMap<String, ItemShape>,
}

impl Router {
    /// Derive routing tables from the manifest: the bucket-1 artifact of
    /// each family defines the per-item shape.
    pub fn new(manifest: &Manifest, kinds: &[&str]) -> Result<Self> {
        let mut shapes = HashMap::new();
        for kind in kinds {
            let entry = manifest
                .artifact_for(kind, 1)
                .or_else(|| {
                    let b = manifest.buckets(kind).first().copied()?;
                    manifest.artifact_for(kind, b)
                })
                .ok_or_else(|| anyhow::anyhow!("no artifacts for kind '{kind}'"))?;
            let batch = entry.batch.max(1);
            let full = &entry.inputs[0].shape;
            if full.is_empty() || full[0] % batch != 0 {
                bail!("kind '{kind}': first dim {:?} not divisible by batch {batch}", full);
            }
            shapes.insert(
                kind.to_string(),
                ItemShape { rows_per_item: full[0] / batch, feature_dims: full[1..].to_vec() },
            );
        }
        Ok(Router { shapes })
    }

    /// Families this router serves.
    pub fn kinds(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.shapes.keys().map(String::as_str).collect();
        v.sort_unstable();
        v
    }

    /// Shape contract for a family.
    pub fn item_shape(&self, kind: &str) -> Option<&ItemShape> {
        self.shapes.get(kind)
    }

    /// Validate a request; returns the queue key (the kind) on success.
    pub fn route(&self, req: &Request) -> Result<String> {
        let Some(shape) = self.shapes.get(&req.kind) else {
            bail!("unknown model kind '{}'", req.kind);
        };
        let want: Vec<usize> =
            std::iter::once(shape.rows_per_item).chain(shape.feature_dims.iter().copied()).collect();
        if req.input.shape != want {
            bail!(
                "kind '{}': input shape {:?} != expected {:?}",
                req.kind,
                req.input.shape,
                want
            );
        }
        let n: usize = want.iter().product();
        if req.input.data.len() != n {
            bail!("kind '{}': data length {} != {}", req.kind, req.input.data.len(), n);
        }
        Ok(req.kind.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::RequestId;
    use crate::runtime::Tensor;
    use std::path::Path;
    use std::sync::mpsc::channel;
    use std::time::Instant;

    fn manifest() -> Manifest {
        Manifest::parse(
            Path::new("/tmp"),
            r#"{"version":1,"artifacts":[
              {"name":"mlp_b1","file":"f","kind":"mlp","batch":1,
               "inputs":[{"shape":[1,8],"tag":0,"scale":1.0}],"output_shape":[1,2],
               "expected":{"prefix":[],"sum":0,"abs_sum":0,"count":2}},
              {"name":"transformer_b2","file":"f","kind":"transformer","batch":2,
               "inputs":[{"shape":[64,16],"tag":0,"scale":1.0}],"output_shape":[64,16],
               "expected":{"prefix":[],"sum":0,"abs_sum":0,"count":1024}}
            ]}"#,
        )
        .unwrap()
    }

    fn req(kind: &str, shape: Vec<usize>) -> Request {
        let n: usize = shape.iter().product();
        let (tx, _rx) = channel();
        Request {
            id: RequestId(0),
            kind: kind.into(),
            input: Tensor { shape, data: vec![0.0; n] },
            enqueued: Instant::now(),
            reply: tx,
        }
    }

    #[test]
    fn derives_item_shapes() {
        let r = Router::new(&manifest(), &["mlp", "transformer"]).unwrap();
        assert_eq!(r.item_shape("mlp").unwrap().rows_per_item, 1);
        // transformer bucket-2 artifact has 64 rows ⇒ 32 rows per sequence
        assert_eq!(r.item_shape("transformer").unwrap().rows_per_item, 32);
    }

    #[test]
    fn routes_valid_rejects_invalid() {
        let r = Router::new(&manifest(), &["mlp"]).unwrap();
        assert_eq!(r.route(&req("mlp", vec![1, 8])).unwrap(), "mlp");
        assert!(r.route(&req("mlp", vec![2, 8])).is_err());
        assert!(r.route(&req("bert", vec![1, 8])).is_err());
    }

    #[test]
    fn unknown_kind_at_construction() {
        assert!(Router::new(&manifest(), &["resnet"]).is_err());
    }
}
