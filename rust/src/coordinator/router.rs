//! Request router: validates incoming requests against the backend's
//! serving catalog and routes them to the right per-model batching queue.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::runtime::Catalog;

pub use crate::runtime::ItemShape;

use super::request::Request;

/// Routes requests by model kind.
pub struct Router {
    shapes: HashMap<String, ItemShape>,
}

impl Router {
    /// Derive routing tables from a backend [`Catalog`]; every served
    /// family must expose at least one batch bucket.
    pub fn new(catalog: &Catalog) -> Result<Self> {
        let mut shapes = HashMap::new();
        for spec in &catalog.models {
            if spec.buckets.is_empty() {
                bail!("kind '{}': catalog exposes no batch buckets", spec.kind);
            }
            shapes.insert(spec.kind.clone(), spec.item.clone());
        }
        Ok(Router { shapes })
    }

    /// Families this router serves.
    pub fn kinds(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.shapes.keys().map(String::as_str).collect();
        v.sort_unstable();
        v
    }

    /// Shape contract for a family.
    pub fn item_shape(&self, kind: &str) -> Option<&ItemShape> {
        self.shapes.get(kind)
    }

    /// Validate a request; returns the queue key (the kind) on success.
    pub fn route(&self, req: &Request) -> Result<String> {
        let Some(shape) = self.shapes.get(&req.kind) else {
            bail!("unknown model kind '{}'", req.kind);
        };
        let want = shape.dims();
        if req.input.shape != want {
            bail!(
                "kind '{}': input shape {:?} != expected {:?}",
                req.kind,
                req.input.shape,
                want
            );
        }
        let n: usize = want.iter().product();
        if req.input.data.len() != n {
            bail!("kind '{}': data length {} != {}", req.kind, req.input.data.len(), n);
        }
        Ok(req.kind.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::RequestId;
    use crate::runtime::{Manifest, Tensor};
    use std::path::Path;
    use std::sync::mpsc::channel;
    use std::time::Instant;

    fn catalog() -> Catalog {
        Manifest::parse(
            Path::new("/tmp"),
            r#"{"version":1,"artifacts":[
              {"name":"mlp_b1","file":"f","kind":"mlp","batch":1,
               "inputs":[{"shape":[1,8],"tag":0,"scale":1.0}],"output_shape":[1,2],
               "expected":{"prefix":[],"sum":0,"abs_sum":0,"count":2}},
              {"name":"transformer_b2","file":"f","kind":"transformer","batch":2,
               "inputs":[{"shape":[64,16],"tag":0,"scale":1.0}],"output_shape":[64,16],
               "expected":{"prefix":[],"sum":0,"abs_sum":0,"count":1024}}
            ]}"#,
        )
        .unwrap()
        .catalog(&["mlp", "transformer"])
        .unwrap()
    }

    fn req(kind: &str, shape: Vec<usize>) -> Request {
        let n: usize = shape.iter().product();
        let (tx, _rx) = channel();
        Request {
            id: RequestId(0),
            kind: kind.into(),
            input: Tensor { shape, data: vec![0.0; n] },
            enqueued: Instant::now(),
            reply: tx,
        }
    }

    #[test]
    fn derives_item_shapes() {
        let r = Router::new(&catalog()).unwrap();
        assert_eq!(r.item_shape("mlp").unwrap().rows_per_item, 1);
        // transformer bucket-2 artifact has 64 rows ⇒ 32 rows per sequence
        assert_eq!(r.item_shape("transformer").unwrap().rows_per_item, 32);
        assert_eq!(r.kinds(), vec!["mlp", "transformer"]);
    }

    #[test]
    fn routes_valid_rejects_invalid() {
        let r = Router::new(&catalog()).unwrap();
        assert_eq!(r.route(&req("mlp", vec![1, 8])).unwrap(), "mlp");
        assert!(r.route(&req("mlp", vec![2, 8])).is_err());
        assert!(r.route(&req("bert", vec![1, 8])).is_err());
    }

    #[test]
    fn rejects_bucketless_catalog() {
        let c = Catalog {
            models: vec![crate::runtime::ModelSpec {
                kind: "mlp".into(),
                item: ItemShape { rows_per_item: 1, feature_dims: vec![8] },
                buckets: vec![],
            }],
        };
        assert!(Router::new(&c).is_err());
    }
}
