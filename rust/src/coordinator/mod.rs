//! Serving coordinator: the deployment layer that exploits the paper's
//! §2.2.3 *parallelism among requests* — independent inference requests are
//! batched onto the batch dimension and executed on AOT-compiled artifacts
//! via PJRT, with framework knobs chosen by the [`crate::tuner`].
//!
//! Dataflow:
//!
//! ```text
//! submit() ─▶ Router (validate, per-model queue)
//!                  └─▶ DynamicBatcher (bucketed batching, max-wait)
//!                           └─▶ Worker lanes (one ModelRuntime each; the
//!                               PJRT client is !Sync, so each lane owns
//!                               its runtime and drains a channel)
//! ```

pub mod batcher;
pub mod request;
pub mod router;
pub mod server;
pub mod worker;

pub use batcher::{BatchPolicy, DynamicBatcher, PendingBatch};
pub use request::{Request, RequestId, Response};
pub use router::Router;
pub use server::{Coordinator, CoordinatorConfig};
