//! Serving coordinator: the deployment layer that exploits the paper's
//! §2.2.3 *parallelism among requests* — independent inference requests are
//! batched onto the batch dimension and executed on a pluggable
//! [`crate::runtime::Backend`] (PJRT artifacts or the discrete-event
//! simulator), with framework knobs chosen by the [`crate::tuner`].
//!
//! Dataflow:
//!
//! ```text
//! submit() ─▶ Router (validate, per-model queue)
//!                  └─▶ DynamicBatcher (bucketed batching, max-wait)
//!                           └─▶ Worker lanes (one Backend instance each;
//!                               real PJRT clients are !Sync, so each lane
//!                               owns its backend and drains a channel)
//! ```
//!
//! [`loadgen`] drives deterministic closed-/open-loop request streams
//! through the full path and reports latency percentiles + throughput.

pub mod batcher;
pub mod loadgen;
pub mod request;
pub mod router;
pub mod server;
pub mod worker;

pub use batcher::{BatchPolicy, DynamicBatcher, PendingBatch};
pub use loadgen::{Arrival, LoadReport, LoadgenConfig};
pub use request::{Request, RequestId, Response};
pub use router::Router;
pub use server::{Coordinator, CoordinatorConfig, Submitter};
