//! Serving coordinator: the deployment layer that exploits the paper's
//! §2.2.3 *parallelism among requests* — independent inference requests are
//! batched onto the batch dimension and executed on a pluggable
//! [`crate::runtime::Backend`] (PJRT artifacts or the discrete-event
//! simulator), with framework knobs chosen by the [`crate::tuner`].
//!
//! Dataflow:
//!
//! ```text
//! submit() ─▶ Router (validate, per-model queue)
//!                  └─▶ DynamicBatcher (bucketed batching, max-wait)
//!                           └─▶ Worker lanes (least-loaded dispatch over
//!                               the lanes hosting the batch's kind; each
//!                               lane owns a Backend pinned to its
//!                               physical-core slice under a LanePlan)
//! ```
//!
//! Core-aware serving: a [`crate::sched::LanePlan`] gives every lane a
//! non-overlapping core slice with §8-guideline knobs for that slice;
//! [`Coordinator::apply_plan`] swaps the lane set live, which is what the
//! online re-tuner ([`crate::tuner::OnlineTuner`]) calls as traffic
//! shifts. [`loadgen`] drives deterministic closed-/open-loop and
//! shifting multi-model request streams through the full path and
//! reports latency percentiles + throughput.

pub mod batcher;
pub mod loadgen;
pub mod pool;
pub mod request;
pub mod router;
pub mod server;
pub mod worker;

pub use batcher::{BatchPolicy, DynamicBatcher, PendingBatch};
pub use loadgen::{Arrival, KindReport, LoadReport, LoadgenConfig, MixPhase, MixReport, Scenario};
pub use pool::{BatchBuf, BatchPool, PoolStats, BATCH_POOL_CAP};
pub use request::{Request, RequestId, Response};
pub use router::Router;
pub use server::{Coordinator, CoordinatorConfig, Submitter};
