//! Capacity-capped recycling pool for batch scratch buffers — the
//! serving-layer mirror of the engine's scratch arenas: steady-state
//! dispatch allocates nothing on the coordinator side.
//!
//! A [`BatchBuf`] carries the two growable allocations a
//! [`super::batcher::PendingBatch`] needs: the member-request `Vec` and
//! the gathered input scratch. The batching loop takes a buffer per cut,
//! the executing lane returns it after scatter, and the pool keeps at
//! most `cap` idle buffers (excess ones are dropped, so a burst can't
//! pin its high-water memory forever). Buffers move by value, which
//! makes a double-return unrepresentable; the counters make leaks
//! observable ([`PoolStats::outstanding`] must return to zero once all
//! lanes drain).

use std::sync::Mutex;

use crate::metrics::Counter;

use super::request::Request;

/// Idle buffers retained per coordinator (beyond this, returns drop).
pub const BATCH_POOL_CAP: usize = 64;

/// Recyclable scratch for one pending batch.
#[derive(Default)]
pub struct BatchBuf {
    /// Member-request storage (cleared between uses).
    pub requests: Vec<Request>,
    /// Gathered model-input scratch (cleared between uses).
    pub input: Vec<f32>,
}

impl BatchBuf {
    /// A fresh, empty buffer.
    pub fn new() -> Self {
        Self::default()
    }

    fn clear(&mut self) {
        self.requests.clear();
        self.input.clear();
    }
}

/// Point-in-time pool accounting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolStats {
    /// Buffers handed out ([`BatchPool::take`] calls).
    pub taken: u64,
    /// Takes served from an idle buffer instead of a fresh allocation.
    pub reused: u64,
    /// Buffers handed back ([`BatchPool::put`] calls).
    pub returned: u64,
    /// Returns dropped because the pool was at capacity.
    pub dropped: u64,
    /// Idle buffers currently pooled.
    pub pooled: usize,
}

impl PoolStats {
    /// Buffers taken but not yet returned (in-flight batches). Zero once
    /// the coordinator and its lanes have drained — anything else is a
    /// leak.
    pub fn outstanding(&self) -> i64 {
        self.taken as i64 - self.returned as i64
    }
}

/// Thread-safe buffer pool shared by the batching loop and every lane.
pub struct BatchPool {
    slots: Mutex<Vec<BatchBuf>>,
    cap: usize,
    taken: Counter,
    reused: Counter,
    returned: Counter,
    dropped: Counter,
}

impl BatchPool {
    /// Pool retaining at most `cap` idle buffers. `cap = 0` recycles
    /// nothing — every take allocates and every return drops, which is
    /// exactly the seed loop's allocation behaviour (the reference data
    /// plane runs on a zero-cap pool).
    pub fn new(cap: usize) -> Self {
        BatchPool {
            slots: Mutex::new(Vec::new()),
            cap,
            taken: Counter::new(),
            reused: Counter::new(),
            returned: Counter::new(),
            dropped: Counter::new(),
        }
    }

    /// Take a buffer: a pooled one when available, else freshly
    /// allocated (empty either way).
    pub fn take(&self) -> BatchBuf {
        self.taken.inc();
        if let Some(buf) = self.slots.lock().unwrap().pop() {
            self.reused.inc();
            return buf;
        }
        BatchBuf::new()
    }

    /// Return a buffer after scatter; it is cleared (requests dropped,
    /// capacity kept) and pooled, or dropped when the pool is full.
    pub fn put(&self, mut buf: BatchBuf) {
        buf.clear();
        self.returned.inc();
        let mut slots = self.slots.lock().unwrap();
        if slots.len() < self.cap {
            slots.push(buf);
        } else {
            self.dropped.inc();
        }
    }

    /// Current accounting snapshot.
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            taken: self.taken.get(),
            reused: self.reused.get(),
            returned: self.returned.get(),
            dropped: self.dropped.get(),
            pooled: self.slots.lock().unwrap().len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuses_returned_buffers() {
        let pool = BatchPool::new(4);
        let mut a = pool.take();
        a.input.resize(1024, 0.0);
        pool.put(a);
        let b = pool.take();
        // cleared but capacity retained: the steady-state no-alloc path
        assert!(b.input.is_empty() && b.requests.is_empty());
        assert!(b.input.capacity() >= 1024);
        let s = pool.stats();
        assert_eq!((s.taken, s.reused, s.returned, s.dropped), (2, 1, 1, 0));
        assert_eq!(s.outstanding(), 1);
        pool.put(b);
        assert_eq!(pool.stats().outstanding(), 0);
    }

    #[test]
    fn capacity_cap_drops_excess() {
        let pool = BatchPool::new(1);
        let (a, b) = (pool.take(), pool.take());
        pool.put(a);
        pool.put(b);
        let s = pool.stats();
        assert_eq!(s.pooled, 1);
        assert_eq!(s.dropped, 1);
        assert_eq!(s.outstanding(), 0);
    }

    #[test]
    fn zero_cap_pool_never_retains() {
        let pool = BatchPool::new(0);
        pool.put(pool.take());
        let s = pool.stats();
        assert_eq!(s.pooled, 0);
        assert_eq!(s.reused, 0);
        assert_eq!(s.dropped, 1);
    }
}
