//! The coordinator: ties router + batchers + worker lanes together behind
//! a submit/await API, generic over the execution backend. Lanes run any
//! [`BackendFactory`] product — the PJRT artifact runtime or the
//! simulation backend — so the full serving path works with zero external
//! artifacts.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::CpuPlatform;
use crate::metrics::ServingMetrics;
use crate::runtime::{
    BackendFactory, PjrtBackendFactory, SimBackendConfig, SimBackendFactory, Tensor,
};

use super::batcher::{BatchPolicy, DynamicBatcher};
use super::request::{Request, RequestId, Response};
use super::router::Router;
use super::worker::WorkerLane;

/// Coordinator construction options.
#[derive(Clone)]
pub struct CoordinatorConfig {
    /// Backend the worker lanes execute batches on.
    pub factory: Arc<dyn BackendFactory>,
    /// Worker lanes (each instantiates its own backend). Defaults to 1;
    /// the `serve` CLI sets it from the tuner's inter-op pool count.
    pub lanes: usize,
    /// Batching policy.
    pub policy: BatchPolicy,
}

impl CoordinatorConfig {
    /// Config over an explicit backend factory, with defaults.
    pub fn with_factory(factory: Arc<dyn BackendFactory>) -> Self {
        CoordinatorConfig { factory, lanes: 1, policy: BatchPolicy::default() }
    }

    /// Simulation-backed config: serve model-zoo `kinds` on `platform`
    /// with the default bucket ladder and tuner-chosen framework knobs.
    /// Needs no external artifacts — this is the tier-1 test path.
    pub fn sim(platform: CpuPlatform, kinds: &[&str]) -> Self {
        Self::sim_with(SimBackendConfig::new(platform, kinds))
    }

    /// Simulation-backed config with full control over the sim backend.
    pub fn sim_with(cfg: SimBackendConfig) -> Self {
        Self::with_factory(Arc::new(SimBackendFactory::new(cfg)))
    }

    /// PJRT-backed config serving artifact families from a directory.
    pub fn pjrt(artifacts_dir: impl Into<PathBuf>, kinds: &[&str]) -> Self {
        Self::with_factory(Arc::new(PjrtBackendFactory::new(artifacts_dir, kinds)))
    }

    /// Back-compat shorthand: PJRT config serving one artifact family.
    pub fn for_kind(artifacts_dir: impl Into<PathBuf>, kind: &str) -> Self {
        Self::pjrt(artifacts_dir, &[kind])
    }
}

/// Running serving system.
pub struct Coordinator {
    inbox: Sender<Request>,
    metrics: Arc<ServingMetrics>,
    router: Arc<Router>,
    next_id: Arc<AtomicU64>,
    shutdown: Arc<AtomicBool>,
    loop_handle: Option<JoinHandle<()>>,
}

/// Cloneable, `Send` submit handle. `Coordinator` holds an mpsc `Sender`
/// and is therefore `!Sync`; load-generator threads each take their own
/// `Submitter` instead of sharing a `&Coordinator`.
#[derive(Clone)]
pub struct Submitter {
    inbox: Sender<Request>,
    router: Arc<Router>,
    next_id: Arc<AtomicU64>,
}

impl Submitter {
    /// Submit one item; returns the receiver for its response.
    pub fn submit(&self, kind: &str, input: Tensor) -> Result<Receiver<Response>> {
        let (tx, rx) = channel();
        let req = Request {
            id: RequestId(self.next_id.fetch_add(1, Ordering::Relaxed)),
            kind: kind.to_string(),
            input,
            enqueued: Instant::now(),
            reply: tx,
        };
        self.router.route(&req)?;
        self.inbox
            .send(req)
            .map_err(|_| anyhow::anyhow!("coordinator stopped"))?;
        Ok(rx)
    }

    /// Submit and block for the response.
    pub fn infer(&self, kind: &str, input: Tensor) -> Result<Response> {
        let rx = self.submit(kind, input)?;
        Ok(rx.recv()?)
    }
}

impl Coordinator {
    /// Start lanes + the batching loop. Blocks until all lanes are ready
    /// (compiled for PJRT, pre-simulated for the sim backend).
    pub fn start(cfg: CoordinatorConfig) -> Result<Self> {
        let catalog = cfg.factory.catalog()?;
        let router = Arc::new(Router::new(&catalog)?);
        let metrics = Arc::new(ServingMetrics::new());

        let lanes: Vec<WorkerLane> = (0..cfg.lanes.max(1))
            .map(|i| WorkerLane::spawn(i, Arc::clone(&cfg.factory), Arc::clone(&metrics)))
            .collect::<Result<_>>()?;

        let mut batchers: HashMap<String, DynamicBatcher> = catalog
            .models
            .iter()
            .map(|m| {
                (
                    m.kind.clone(),
                    DynamicBatcher::new(&m.kind, m.buckets.clone(), cfg.policy.clone()),
                )
            })
            .collect();

        let (inbox, rx) = channel::<Request>();
        let shutdown = Arc::new(AtomicBool::new(false));
        let stop = Arc::clone(&shutdown);
        let loop_handle = std::thread::Builder::new()
            .name("coordinator-loop".into())
            .spawn(move || batching_loop(rx, &mut batchers, &lanes, &stop))?;

        Ok(Coordinator {
            inbox,
            metrics,
            router,
            next_id: Arc::new(AtomicU64::new(0)),
            shutdown,
            loop_handle: Some(loop_handle),
        })
    }

    /// A cloneable submit handle for cross-thread load generation.
    pub fn submitter(&self) -> Submitter {
        Submitter {
            inbox: self.inbox.clone(),
            router: Arc::clone(&self.router),
            next_id: Arc::clone(&self.next_id),
        }
    }

    /// Submit one item; returns the receiver for its response.
    pub fn submit(&self, kind: &str, input: Tensor) -> Result<Receiver<Response>> {
        self.submitter().submit(kind, input)
    }

    /// Submit and block for the response.
    pub fn infer(&self, kind: &str, input: Tensor) -> Result<Response> {
        let rx = self.submit(kind, input)?;
        Ok(rx.recv()?)
    }

    /// Serving metrics.
    pub fn metrics(&self) -> &ServingMetrics {
        &self.metrics
    }

    /// Router (shape contracts).
    pub fn router(&self) -> &Router {
        &self.router
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Release);
        if let Some(h) = self.loop_handle.take() {
            let _ = h.join();
        }
    }
}

/// The serving loop: drain the inbox into per-kind batchers, cut batches
/// when full or timed out, round-robin them over lanes.
fn batching_loop(
    rx: Receiver<Request>,
    batchers: &mut HashMap<String, DynamicBatcher>,
    lanes: &[WorkerLane],
    shutdown: &AtomicBool,
) {
    let mut next_lane = 0usize;
    loop {
        // sleep until the nearest deadline (or a short poll when idle)
        let now = Instant::now();
        let wait = batchers
            .values()
            .filter_map(|b| b.next_deadline(now))
            .min()
            .unwrap_or(Duration::from_millis(1));
        match rx.recv_timeout(wait) {
            Ok(req) => {
                if let Some(b) = batchers.get_mut(&req.kind) {
                    b.push(req);
                }
                // drain whatever else arrived
                while let Ok(req) = rx.try_recv() {
                    if let Some(b) = batchers.get_mut(&req.kind) {
                        b.push(req);
                    }
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                // flush remaining queues, then exit
                for b in batchers.values_mut() {
                    while !b.is_empty() {
                        lanes[next_lane % lanes.len()].submit(b.cut());
                        next_lane += 1;
                    }
                }
                return;
            }
        }
        let now = Instant::now();
        for b in batchers.values_mut() {
            while b.ready(now) {
                lanes[next_lane % lanes.len()].submit(b.cut());
                next_lane += 1;
            }
        }
        if shutdown.load(Ordering::Acquire) {
            for b in batchers.values_mut() {
                while !b.is_empty() {
                    lanes[next_lane % lanes.len()].submit(b.cut());
                    next_lane += 1;
                }
            }
            return;
        }
    }
}

/// A `Mutex`-free alias kept for API clarity in examples.
pub type SharedCoordinator = Arc<Mutex<Coordinator>>;
