//! The coordinator: ties router + batchers + worker lanes together behind
//! a submit/await API, with the lane count chosen by the paper's tuning
//! guideline (inter-op pools → independent execution lanes).

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::metrics::ServingMetrics;
use crate::runtime::{Manifest, Tensor};

use super::batcher::{BatchPolicy, DynamicBatcher};
use super::request::{Request, RequestId, Response};
use super::router::Router;
use super::worker::WorkerLane;

/// Coordinator construction options.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Where `manifest.json` + HLO artifacts live.
    pub artifacts_dir: PathBuf,
    /// Model families to serve.
    pub kinds: Vec<String>,
    /// Worker lanes (each compiles its own runtime). Defaults to 1; the
    /// `serve` CLI sets it from the tuner's inter-op pool count.
    pub lanes: usize,
    /// Batching policy.
    pub policy: BatchPolicy,
}

impl CoordinatorConfig {
    /// Config serving one family with defaults.
    pub fn for_kind(artifacts_dir: impl Into<PathBuf>, kind: &str) -> Self {
        CoordinatorConfig {
            artifacts_dir: artifacts_dir.into(),
            kinds: vec![kind.to_string()],
            lanes: 1,
            policy: BatchPolicy::default(),
        }
    }
}

/// Running serving system.
pub struct Coordinator {
    inbox: Sender<Request>,
    metrics: Arc<ServingMetrics>,
    router: Arc<Router>,
    next_id: AtomicU64,
    shutdown: Arc<AtomicBool>,
    loop_handle: Option<JoinHandle<()>>,
}

impl Coordinator {
    /// Start lanes + the batching loop. Blocks until all lanes compiled.
    pub fn start(cfg: CoordinatorConfig) -> Result<Self> {
        let manifest = Manifest::load(&cfg.artifacts_dir)?;
        let kinds: Vec<&str> = cfg.kinds.iter().map(String::as_str).collect();
        let router = Arc::new(Router::new(&manifest, &kinds)?);
        let metrics = Arc::new(ServingMetrics::new());

        let lanes: Vec<WorkerLane> = (0..cfg.lanes.max(1))
            .map(|i| {
                WorkerLane::spawn(
                    i,
                    cfg.artifacts_dir.clone(),
                    cfg.kinds.clone(),
                    Arc::clone(&metrics),
                )
            })
            .collect::<Result<_>>()?;

        let mut batchers: HashMap<String, DynamicBatcher> = cfg
            .kinds
            .iter()
            .map(|k| (k.clone(), DynamicBatcher::new(k, &manifest, cfg.policy.clone())))
            .collect();

        let (inbox, rx) = channel::<Request>();
        let shutdown = Arc::new(AtomicBool::new(false));
        let stop = Arc::clone(&shutdown);
        let loop_handle = std::thread::Builder::new()
            .name("coordinator-loop".into())
            .spawn(move || batching_loop(rx, &mut batchers, &lanes, &stop))?;

        Ok(Coordinator {
            inbox,
            metrics,
            router,
            next_id: AtomicU64::new(0),
            shutdown,
            loop_handle: Some(loop_handle),
        })
    }

    /// Submit one item; returns the receiver for its response.
    pub fn submit(&self, kind: &str, input: Tensor) -> Result<Receiver<Response>> {
        let (tx, rx) = channel();
        let req = Request {
            id: RequestId(self.next_id.fetch_add(1, Ordering::Relaxed)),
            kind: kind.to_string(),
            input,
            enqueued: Instant::now(),
            reply: tx,
        };
        self.router.route(&req)?;
        self.inbox
            .send(req)
            .map_err(|_| anyhow::anyhow!("coordinator stopped"))?;
        Ok(rx)
    }

    /// Submit and block for the response.
    pub fn infer(&self, kind: &str, input: Tensor) -> Result<Response> {
        let rx = self.submit(kind, input)?;
        Ok(rx.recv()?)
    }

    /// Serving metrics.
    pub fn metrics(&self) -> &ServingMetrics {
        &self.metrics
    }

    /// Router (shape contracts).
    pub fn router(&self) -> &Router {
        &self.router
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Release);
        if let Some(h) = self.loop_handle.take() {
            let _ = h.join();
        }
    }
}

/// The serving loop: drain the inbox into per-kind batchers, cut batches
/// when full or timed out, round-robin them over lanes.
fn batching_loop(
    rx: Receiver<Request>,
    batchers: &mut HashMap<String, DynamicBatcher>,
    lanes: &[WorkerLane],
    shutdown: &AtomicBool,
) {
    let mut next_lane = 0usize;
    loop {
        // sleep until the nearest deadline (or a short poll when idle)
        let now = Instant::now();
        let wait = batchers
            .values()
            .filter_map(|b| b.next_deadline(now))
            .min()
            .unwrap_or(Duration::from_millis(1));
        match rx.recv_timeout(wait) {
            Ok(req) => {
                if let Some(b) = batchers.get_mut(&req.kind) {
                    b.push(req);
                }
                // drain whatever else arrived
                while let Ok(req) = rx.try_recv() {
                    if let Some(b) = batchers.get_mut(&req.kind) {
                        b.push(req);
                    }
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                // flush remaining queues, then exit
                for b in batchers.values_mut() {
                    while !b.is_empty() {
                        lanes[next_lane % lanes.len()].submit(b.cut());
                        next_lane += 1;
                    }
                }
                return;
            }
        }
        let now = Instant::now();
        for b in batchers.values_mut() {
            while b.ready(now) {
                lanes[next_lane % lanes.len()].submit(b.cut());
                next_lane += 1;
            }
        }
        if shutdown.load(Ordering::Acquire) {
            for b in batchers.values_mut() {
                while !b.is_empty() {
                    lanes[next_lane % lanes.len()].submit(b.cut());
                    next_lane += 1;
                }
            }
            return;
        }
    }
}

/// A `Mutex`-free alias kept for API clarity in examples.
pub type SharedCoordinator = Arc<Mutex<Coordinator>>;
