//! The coordinator: ties router + batchers + worker lanes together behind
//! a submit/await API, generic over the execution backend. Lanes run any
//! [`BackendFactory`] product — the PJRT artifact runtime or the
//! simulation backend — so the full serving path works with zero external
//! artifacts.
//!
//! Two lane regimes:
//!
//! * **Unassigned** (`CoordinatorConfig::lanes`): N identical lanes over
//!   the whole machine, every lane hosting every kind.
//! * **Core-aware** (`CoordinatorConfig::plan`): one lane per
//!   [`LanePlan`] assignment, each pinned to a physical-core slice and a
//!   kind set with §8-guideline knobs for that slice. Batches go to the
//!   least-loaded lane hosting their kind, and [`Coordinator::apply_plan`]
//!   swaps the lane set live (for the online re-tuner) without dropping
//!   in-flight requests.
//!
//! Two data planes:
//!
//! * **Fast path** (default): kinds are interned to dense [`KindId`]s at
//!   admission, the batching loop indexes a `Vec` of batchers and drains
//!   the whole inbox backlog per wake-up, and batch buffers recycle
//!   through a capacity-capped [`BatchPool`] — steady state does no
//!   string hashing and no coordinator-side allocation.
//! * **Reference** (`CoordinatorConfig::reference_loop`): the seed data
//!   plane — string-keyed batcher map, one-message-at-a-time drain,
//!   allocating cuts, zero-cap pool. Kept for bit-identity pins and the
//!   `fastpath-vs-seed` bench ratio; batch-cut semantics (bucket ladder,
//!   max-wait bound, FIFO per kind) are identical by construction.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::CpuPlatform;
use crate::metrics::{KindCounters, ServingMetrics};
use crate::runtime::{
    BackendFactory, KindId, KindTable, PjrtBackendFactory, SimBackendConfig, SimBackendFactory,
    Tensor,
};
use crate::sched::{pick_lane, LanePlan};
use crate::tracestore::TraceRecorder;

use super::batcher::{BatchPolicy, DynamicBatcher};
use super::pool::{BatchPool, PoolStats, BATCH_POOL_CAP};
use super::request::{Request, RequestId, Response};
use super::router::Router;
use super::worker::{LaneEnv, WorkerLane};

/// Coordinator construction options.
#[derive(Clone)]
pub struct CoordinatorConfig {
    /// Backend the worker lanes execute batches on.
    pub factory: Arc<dyn BackendFactory>,
    /// Unassigned worker lanes (each instantiates its own backend over
    /// the whole machine). Ignored when `plan` is set. Defaults to 1.
    pub lanes: usize,
    /// Batching policy.
    pub policy: BatchPolicy,
    /// Core-aware lane plan: one lane per assignment, pinned to its core
    /// slice and kinds. `None` keeps the unassigned-lane behaviour.
    pub plan: Option<LanePlan>,
    /// Run the seed (reference) data plane: string-keyed batchers,
    /// one-at-a-time ingress, allocating cuts, no buffer recycling.
    /// Response semantics are identical to the fast path; only the
    /// constant factors differ. Defaults to false.
    pub reference_loop: bool,
    /// Trace recorder the lanes emit per-request events into
    /// ([`crate::tracestore`]). `None` (the default) disables capture at
    /// the cost of one branch per batch.
    pub recorder: Option<Arc<TraceRecorder>>,
}

impl CoordinatorConfig {
    /// Config over an explicit backend factory, with defaults.
    pub fn with_factory(factory: Arc<dyn BackendFactory>) -> Self {
        CoordinatorConfig {
            factory,
            lanes: 1,
            policy: BatchPolicy::default(),
            plan: None,
            reference_loop: false,
            recorder: None,
        }
    }

    /// Simulation-backed config: serve model-zoo `kinds` on `platform`
    /// with the default bucket ladder and tuner-chosen framework knobs.
    /// Needs no external artifacts — this is the tier-1 test path.
    pub fn sim(platform: CpuPlatform, kinds: &[&str]) -> Self {
        Self::sim_with(SimBackendConfig::new(platform, kinds))
    }

    /// Simulation-backed config with full control over the sim backend.
    pub fn sim_with(cfg: SimBackendConfig) -> Self {
        Self::with_factory(Arc::new(SimBackendFactory::new(cfg)))
    }

    /// PJRT-backed config serving artifact families from a directory.
    pub fn pjrt(artifacts_dir: impl Into<PathBuf>, kinds: &[&str]) -> Self {
        Self::with_factory(Arc::new(PjrtBackendFactory::new(artifacts_dir, kinds)))
    }

    /// Back-compat shorthand: PJRT config serving one artifact family.
    pub fn for_kind(artifacts_dir: impl Into<PathBuf>, kind: &str) -> Self {
        Self::pjrt(artifacts_dir, &[kind])
    }

    /// Attach a core-aware lane plan.
    pub fn with_plan(mut self, plan: LanePlan) -> Self {
        self.plan = Some(plan);
        self
    }

    /// Select the seed (reference) data plane.
    pub fn with_reference_loop(mut self, on: bool) -> Self {
        self.reference_loop = on;
        self
    }

    /// Attach a trace recorder; lanes will emit one [`TraceEvent`]
    /// per request at batch completion.
    ///
    /// [`TraceEvent`]: crate::tracestore::TraceEvent
    pub fn with_recorder(mut self, recorder: Arc<TraceRecorder>) -> Self {
        self.recorder = Some(recorder);
        self
    }
}

/// Messages into the batching loop: requests, plus an explicit shutdown
/// wake-up (the loop blocks on the inbox when idle, so shutdown must be
/// a message, not just a flag).
enum LoopMsg {
    Req(Request),
    Shutdown,
}

/// Running serving system.
pub struct Coordinator {
    inbox: Sender<LoopMsg>,
    metrics: Arc<ServingMetrics>,
    router: Arc<Router>,
    next_id: Arc<AtomicU64>,
    kind_counters: Arc<[Arc<KindCounters>]>,
    shutdown: Arc<AtomicBool>,
    lanes: Arc<RwLock<Vec<WorkerLane>>>,
    factory: Arc<dyn BackendFactory>,
    lane_env: LaneEnv,
    plan: Mutex<Option<LanePlan>>,
    loop_handle: Option<JoinHandle<()>>,
}

/// Cloneable, `Send` submit handle. `Coordinator` holds an mpsc `Sender`
/// and is therefore `!Sync`; load-generator threads each take their own
/// `Submitter` instead of sharing a `&Coordinator`.
#[derive(Clone)]
pub struct Submitter {
    inbox: Sender<LoopMsg>,
    router: Arc<Router>,
    next_id: Arc<AtomicU64>,
    /// Arrival counters dense by [`KindId`], interned at startup.
    kind_counters: Arc<[Arc<KindCounters>]>,
}

impl Submitter {
    /// Intern a kind name once; hot submit loops resolve up front and
    /// call [`Self::submit_id`] ever after.
    pub fn resolve(&self, kind: &str) -> Option<KindId> {
        self.router.resolve(kind)
    }

    /// Submit one item by name; returns the receiver for its response.
    /// This is the admission point where the kind string is interned —
    /// nothing downstream hashes or clones it.
    pub fn submit(&self, kind: &str, input: Tensor) -> Result<Receiver<Response>> {
        let id = self.router.route(kind, &input)?;
        self.submit_routed(id, input)
    }

    /// Submit one item by interned kind (the hot-loop entry point).
    pub fn submit_id(&self, id: KindId, input: Tensor) -> Result<Receiver<Response>> {
        self.router.validate_id(id, &input)?;
        self.submit_routed(id, input)
    }

    fn submit_routed(&self, id: KindId, input: Tensor) -> Result<Receiver<Response>> {
        let (tx, rx) = channel();
        let req = Request {
            id: RequestId(self.next_id.fetch_add(1, Ordering::Relaxed)),
            kind: id,
            input,
            enqueued: Instant::now(),
            reply: tx,
        };
        self.kind_counters[id.index()].arrivals.inc();
        self.inbox
            .send(LoopMsg::Req(req))
            .map_err(|_| anyhow::anyhow!("coordinator stopped"))?;
        Ok(rx)
    }

    /// Submit and block for the response.
    pub fn infer(&self, kind: &str, input: Tensor) -> Result<Response> {
        let rx = self.submit(kind, input)?;
        Ok(rx.recv()?)
    }

    /// Submit by interned kind and block for the response.
    pub fn infer_id(&self, id: KindId, input: Tensor) -> Result<Response> {
        let rx = self.submit_id(id, input)?;
        Ok(rx.recv()?)
    }
}

impl Coordinator {
    /// Start lanes + the batching loop. Blocks until all lanes are ready
    /// (compiled for PJRT, pre-simulated for the sim backend).
    pub fn start(cfg: CoordinatorConfig) -> Result<Self> {
        let catalog = cfg.factory.catalog()?;
        let router = Arc::new(Router::new(&catalog)?);
        let table = Arc::clone(router.table());
        let metrics = Arc::new(ServingMetrics::new());
        // dense per-kind counters, resolved once for every submitter
        let kind_counters: Arc<[Arc<KindCounters>]> =
            metrics.intern_kinds(table.names()).into();
        // the reference plane gets a zero-cap pool: every cut allocates
        // and every return drops, exactly the seed's behaviour
        let pool_cap = if cfg.reference_loop { 0 } else { BATCH_POOL_CAP };
        let lane_env = LaneEnv {
            metrics: Arc::clone(&metrics),
            table: Arc::clone(&table),
            pool: Arc::new(BatchPool::new(pool_cap)),
            recorder: cfg.recorder.clone(),
            reference: cfg.reference_loop,
        };

        let lanes: Vec<WorkerLane> = match &cfg.plan {
            Some(plan) => {
                plan.validate()?;
                for m in &catalog.models {
                    if !plan.hosts(&m.kind) {
                        bail!("lane plan hosts no lane for kind '{}'", m.kind);
                    }
                }
                plan.lane_assignments()
                    .into_iter()
                    .map(|a| {
                        WorkerLane::spawn_assigned(Arc::clone(&cfg.factory), a, lane_env.clone())
                    })
                    .collect::<Result<_>>()?
            }
            None => (0..cfg.lanes.max(1))
                .map(|i| WorkerLane::spawn(i, Arc::clone(&cfg.factory), lane_env.clone()))
                .collect::<Result<_>>()?,
        };
        let lanes = Arc::new(RwLock::new(lanes));

        let (inbox, rx) = channel::<LoopMsg>();
        let shutdown = Arc::new(AtomicBool::new(false));
        let stop = Arc::clone(&shutdown);
        let loop_lanes = Arc::clone(&lanes);
        let builder = std::thread::Builder::new().name("coordinator-loop".into());
        let loop_handle = if cfg.reference_loop {
            let mut batchers: HashMap<String, DynamicBatcher> = catalog
                .models
                .iter()
                .map(|m| {
                    let id = table.resolve(&m.kind).expect("catalog kind interned");
                    (m.kind.clone(), DynamicBatcher::new(id, m.buckets.clone(), cfg.policy.clone()))
                })
                .collect();
            let loop_table = Arc::clone(&table);
            builder.spawn(move || {
                batching_loop_reference(rx, &mut batchers, &loop_lanes, &loop_table, &stop)
            })?
        } else {
            // dense by KindId — the table interns catalog order, so slot
            // i serves KindId(i)
            let mut batchers: Vec<DynamicBatcher> = catalog
                .models
                .iter()
                .enumerate()
                .map(|(i, m)| {
                    debug_assert_eq!(table.resolve(&m.kind), Some(KindId(i as u16)));
                    DynamicBatcher::new(KindId(i as u16), m.buckets.clone(), cfg.policy.clone())
                })
                .collect();
            let loop_pool = Arc::clone(&lane_env.pool);
            builder
                .spawn(move || batching_loop(rx, &mut batchers, &loop_lanes, &loop_pool, &stop))?
        };

        Ok(Coordinator {
            inbox,
            metrics,
            router,
            next_id: Arc::new(AtomicU64::new(0)),
            kind_counters,
            shutdown,
            lanes,
            factory: cfg.factory,
            lane_env,
            plan: Mutex::new(cfg.plan),
            loop_handle: Some(loop_handle),
        })
    }

    /// Swap the lane set to a new core-aware plan without dropping
    /// in-flight requests: fresh lanes are spawned and readied first,
    /// then dispatch flips to them, then the old lanes drain the batches
    /// they already accepted and shut down.
    pub fn apply_plan(&self, plan: LanePlan) -> Result<()> {
        plan.validate()?;
        for kind in self.router.kinds() {
            if !plan.hosts(kind) {
                bail!("lane plan hosts no lane for kind '{kind}'");
            }
        }
        // serialise whole re-plans on the plan mutex so the stored plan
        // can never disagree with the live lane set under concurrent
        // apply_plan calls (the batching loop only takes the lanes read
        // lock, so this ordering cannot deadlock)
        let mut current = self.plan.lock().unwrap();
        let fresh: Vec<WorkerLane> = plan
            .lane_assignments()
            .into_iter()
            .map(|a| {
                WorkerLane::spawn_assigned(Arc::clone(&self.factory), a, self.lane_env.clone())
            })
            .collect::<Result<_>>()?;
        let old = {
            let mut guard = self.lanes.write().unwrap();
            std::mem::replace(&mut *guard, fresh)
        };
        // dropping the old lanes enqueues their shutdown *behind* any
        // batches they already accepted, so in-flight work completes
        // (and every pooled buffer returns) before the join
        drop(old);
        *current = Some(plan);
        Ok(())
    }

    /// The active lane plan, if core-aware serving is on.
    pub fn current_plan(&self) -> Option<LanePlan> {
        self.plan.lock().unwrap().clone()
    }

    /// Per-lane queue depth (items queued or executing), as
    /// `(lane_id, depth)` pairs in lane order.
    pub fn lane_depths(&self) -> Vec<(usize, usize)> {
        self.lanes
            .read()
            .unwrap()
            .iter()
            .map(|l| (l.lane_id(), l.queued_items()))
            .collect()
    }

    /// A cloneable submit handle for cross-thread load generation.
    pub fn submitter(&self) -> Submitter {
        Submitter {
            inbox: self.inbox.clone(),
            router: Arc::clone(&self.router),
            next_id: Arc::clone(&self.next_id),
            kind_counters: Arc::clone(&self.kind_counters),
        }
    }

    /// Submit one item; returns the receiver for its response.
    pub fn submit(&self, kind: &str, input: Tensor) -> Result<Receiver<Response>> {
        self.submitter().submit(kind, input)
    }

    /// Submit one item by interned kind.
    pub fn submit_id(&self, id: KindId, input: Tensor) -> Result<Receiver<Response>> {
        self.submitter().submit_id(id, input)
    }

    /// Submit and block for the response.
    pub fn infer(&self, kind: &str, input: Tensor) -> Result<Response> {
        let rx = self.submit(kind, input)?;
        Ok(rx.recv()?)
    }

    /// Submit by interned kind and block for the response.
    pub fn infer_id(&self, id: KindId, input: Tensor) -> Result<Response> {
        let rx = self.submit_id(id, input)?;
        Ok(rx.recv()?)
    }

    /// Serving metrics.
    pub fn metrics(&self) -> &ServingMetrics {
        &self.metrics
    }

    /// Router (shape contracts).
    pub fn router(&self) -> &Router {
        &self.router
    }

    /// The interned kind table.
    pub fn kind_table(&self) -> &Arc<KindTable> {
        self.router.table()
    }

    /// Batch-buffer pool accounting (leak diagnostics: `outstanding()`
    /// returns to zero once the coordinator drains).
    pub fn pool_stats(&self) -> PoolStats {
        self.lane_env.pool.stats()
    }

    /// The shared batch-buffer pool (handle survives the coordinator —
    /// tests use it to assert no buffer leaked across a full drain).
    pub fn batch_pool(&self) -> Arc<BatchPool> {
        Arc::clone(&self.lane_env.pool)
    }

    /// The attached trace recorder, if capture is on.
    pub fn recorder(&self) -> Option<Arc<TraceRecorder>> {
        self.lane_env.recorder.clone()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Release);
        // wake the loop even when it is blocked on an idle recv()
        let _ = self.inbox.send(LoopMsg::Shutdown);
        if let Some(h) = self.loop_handle.take() {
            let _ = h.join();
        }
        // join lane threads deterministically (flushed batches included)
        self.lanes.write().unwrap().clear();
    }
}

/// The fast serving loop: block once on the inbox (or until the nearest
/// batch deadline), drain the **whole backlog** into the dense per-kind
/// batchers, then cut and dispatch. Cuts fill recycled pool buffers and
/// go to the least-loaded lane hosting the kind. A
/// [`LoopMsg::Shutdown`] (or sender disconnect) flushes what remains and
/// exits — a shutdown seen mid-drain still flushes every request
/// received before it.
fn batching_loop(
    rx: Receiver<LoopMsg>,
    batchers: &mut [DynamicBatcher],
    lanes: &RwLock<Vec<WorkerLane>>,
    pool: &BatchPool,
    shutdown: &AtomicBool,
) {
    loop {
        let now = Instant::now();
        let wait = batchers.iter().filter_map(|b| b.next_deadline(now)).min();
        let msg = match wait {
            // nothing queued anywhere: block until work or shutdown
            None => match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => Some(LoopMsg::Shutdown),
            },
            // sleep exactly until the nearest batch deadline
            Some(d) => match rx.recv_timeout(d) {
                Ok(m) => Some(m),
                Err(RecvTimeoutError::Timeout) => None,
                Err(RecvTimeoutError::Disconnected) => Some(LoopMsg::Shutdown),
            },
        };
        let mut stop = shutdown.load(Ordering::Acquire);
        match msg {
            Some(LoopMsg::Req(req)) => {
                // router-validated: the id indexes the dense batcher slab
                batchers[req.kind.index()].push(req);
                for m in rx.try_iter() {
                    match m {
                        LoopMsg::Req(r) => batchers[r.kind.index()].push(r),
                        LoopMsg::Shutdown => {
                            stop = true;
                            break;
                        }
                    }
                }
            }
            Some(LoopMsg::Shutdown) => stop = true,
            None => {}
        }
        let now = Instant::now();
        let lanes = lanes.read().unwrap();
        for b in batchers.iter_mut() {
            while b.ready(now) {
                dispatch(&lanes, b.cut_into(pool.take()));
            }
        }
        if stop {
            for b in batchers.iter_mut() {
                while !b.is_empty() {
                    dispatch(&lanes, b.cut_into(pool.take()));
                }
            }
            return;
        }
    }
}

/// The seed serving loop, preserved as the reference data plane: same
/// recv / drain / cut schedule, but every batcher touch goes through an
/// owned `String` key (the seed's per-request clone + hash), ingress
/// drains one `try_recv` at a time, and cuts allocate fresh storage.
fn batching_loop_reference(
    rx: Receiver<LoopMsg>,
    batchers: &mut HashMap<String, DynamicBatcher>,
    lanes: &RwLock<Vec<WorkerLane>>,
    table: &KindTable,
    shutdown: &AtomicBool,
) {
    let enqueue = |batchers: &mut HashMap<String, DynamicBatcher>, req: Request| {
        // materialise the name, as the seed's Request.kind: String did
        let key = table.name(req.kind).to_string();
        if let Some(b) = batchers.get_mut(&key) {
            b.push(req);
        }
    };
    loop {
        let now = Instant::now();
        let wait = batchers.values().filter_map(|b| b.next_deadline(now)).min();
        let msg = match wait {
            None => match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => Some(LoopMsg::Shutdown),
            },
            Some(d) => match rx.recv_timeout(d) {
                Ok(m) => Some(m),
                Err(RecvTimeoutError::Timeout) => None,
                Err(RecvTimeoutError::Disconnected) => Some(LoopMsg::Shutdown),
            },
        };
        let mut stop = shutdown.load(Ordering::Acquire);
        match msg {
            Some(LoopMsg::Req(req)) => {
                enqueue(batchers, req);
                loop {
                    match rx.try_recv() {
                        Ok(LoopMsg::Req(r)) => enqueue(batchers, r),
                        Ok(LoopMsg::Shutdown) => {
                            stop = true;
                            break;
                        }
                        Err(_) => break,
                    }
                }
            }
            Some(LoopMsg::Shutdown) => stop = true,
            None => {}
        }
        let now = Instant::now();
        let lanes = lanes.read().unwrap();
        for b in batchers.values_mut() {
            while b.ready(now) {
                dispatch(&lanes, b.cut());
            }
        }
        if stop {
            for b in batchers.values_mut() {
                while !b.is_empty() {
                    dispatch(&lanes, b.cut());
                }
            }
            return;
        }
    }
}

/// Least-loaded dispatch over the lanes hosting the batch's kind
/// (deterministic: ties go to the lowest lane index).
fn dispatch(lanes: &[WorkerLane], batch: super::batcher::PendingBatch) {
    let loads: Vec<usize> = lanes.iter().map(WorkerLane::queued_items).collect();
    match pick_lane(&loads, |i| lanes[i].hosts(batch.kind)) {
        Some(i) => lanes[i].submit(batch),
        // start()/apply_plan() guarantee every catalog kind is hosted;
        // if a regression slips through, keep serving rather than drop
        None => {
            if let Some(l) = lanes.first() {
                l.submit(batch);
            }
        }
    }
}
