//! The coordinator: ties router + batchers + worker lanes together behind
//! a submit/await API, generic over the execution backend. Lanes run any
//! [`BackendFactory`] product — the PJRT artifact runtime or the
//! simulation backend — so the full serving path works with zero external
//! artifacts.
//!
//! Two lane regimes:
//!
//! * **Unassigned** (`CoordinatorConfig::lanes`): N identical lanes over
//!   the whole machine, every lane hosting every kind.
//! * **Core-aware** (`CoordinatorConfig::plan`): one lane per
//!   [`LanePlan`] assignment, each pinned to a physical-core slice and a
//!   kind set with §8-guideline knobs for that slice. Batches go to the
//!   least-loaded lane hosting their kind, and [`Coordinator::apply_plan`]
//!   swaps the lane set live (for the online re-tuner) without dropping
//!   in-flight requests.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::CpuPlatform;
use crate::metrics::ServingMetrics;
use crate::runtime::{
    BackendFactory, PjrtBackendFactory, SimBackendConfig, SimBackendFactory, Tensor,
};
use crate::sched::{pick_lane, LanePlan};

use super::batcher::{BatchPolicy, DynamicBatcher};
use super::request::{Request, RequestId, Response};
use super::router::Router;
use super::worker::WorkerLane;

/// Coordinator construction options.
#[derive(Clone)]
pub struct CoordinatorConfig {
    /// Backend the worker lanes execute batches on.
    pub factory: Arc<dyn BackendFactory>,
    /// Unassigned worker lanes (each instantiates its own backend over
    /// the whole machine). Ignored when `plan` is set. Defaults to 1.
    pub lanes: usize,
    /// Batching policy.
    pub policy: BatchPolicy,
    /// Core-aware lane plan: one lane per assignment, pinned to its core
    /// slice and kinds. `None` keeps the unassigned-lane behaviour.
    pub plan: Option<LanePlan>,
}

impl CoordinatorConfig {
    /// Config over an explicit backend factory, with defaults.
    pub fn with_factory(factory: Arc<dyn BackendFactory>) -> Self {
        CoordinatorConfig { factory, lanes: 1, policy: BatchPolicy::default(), plan: None }
    }

    /// Simulation-backed config: serve model-zoo `kinds` on `platform`
    /// with the default bucket ladder and tuner-chosen framework knobs.
    /// Needs no external artifacts — this is the tier-1 test path.
    pub fn sim(platform: CpuPlatform, kinds: &[&str]) -> Self {
        Self::sim_with(SimBackendConfig::new(platform, kinds))
    }

    /// Simulation-backed config with full control over the sim backend.
    pub fn sim_with(cfg: SimBackendConfig) -> Self {
        Self::with_factory(Arc::new(SimBackendFactory::new(cfg)))
    }

    /// PJRT-backed config serving artifact families from a directory.
    pub fn pjrt(artifacts_dir: impl Into<PathBuf>, kinds: &[&str]) -> Self {
        Self::with_factory(Arc::new(PjrtBackendFactory::new(artifacts_dir, kinds)))
    }

    /// Back-compat shorthand: PJRT config serving one artifact family.
    pub fn for_kind(artifacts_dir: impl Into<PathBuf>, kind: &str) -> Self {
        Self::pjrt(artifacts_dir, &[kind])
    }

    /// Attach a core-aware lane plan.
    pub fn with_plan(mut self, plan: LanePlan) -> Self {
        self.plan = Some(plan);
        self
    }
}

/// Messages into the batching loop: requests, plus an explicit shutdown
/// wake-up (the loop blocks on the inbox when idle, so shutdown must be
/// a message, not just a flag).
enum LoopMsg {
    Req(Request),
    Shutdown,
}

/// Running serving system.
pub struct Coordinator {
    inbox: Sender<LoopMsg>,
    metrics: Arc<ServingMetrics>,
    router: Arc<Router>,
    next_id: Arc<AtomicU64>,
    shutdown: Arc<AtomicBool>,
    lanes: Arc<RwLock<Vec<WorkerLane>>>,
    factory: Arc<dyn BackendFactory>,
    plan: Mutex<Option<LanePlan>>,
    loop_handle: Option<JoinHandle<()>>,
}

/// Cloneable, `Send` submit handle. `Coordinator` holds an mpsc `Sender`
/// and is therefore `!Sync`; load-generator threads each take their own
/// `Submitter` instead of sharing a `&Coordinator`.
#[derive(Clone)]
pub struct Submitter {
    inbox: Sender<LoopMsg>,
    router: Arc<Router>,
    next_id: Arc<AtomicU64>,
    metrics: Arc<ServingMetrics>,
}

impl Submitter {
    /// Submit one item; returns the receiver for its response.
    pub fn submit(&self, kind: &str, input: Tensor) -> Result<Receiver<Response>> {
        let (tx, rx) = channel();
        let req = Request {
            id: RequestId(self.next_id.fetch_add(1, Ordering::Relaxed)),
            kind: kind.to_string(),
            input,
            enqueued: Instant::now(),
            reply: tx,
        };
        self.router.route(&req)?;
        self.metrics.kind(kind).arrivals.inc();
        self.inbox
            .send(LoopMsg::Req(req))
            .map_err(|_| anyhow::anyhow!("coordinator stopped"))?;
        Ok(rx)
    }

    /// Submit and block for the response.
    pub fn infer(&self, kind: &str, input: Tensor) -> Result<Response> {
        let rx = self.submit(kind, input)?;
        Ok(rx.recv()?)
    }
}

impl Coordinator {
    /// Start lanes + the batching loop. Blocks until all lanes are ready
    /// (compiled for PJRT, pre-simulated for the sim backend).
    pub fn start(cfg: CoordinatorConfig) -> Result<Self> {
        let catalog = cfg.factory.catalog()?;
        let router = Arc::new(Router::new(&catalog)?);
        let metrics = Arc::new(ServingMetrics::new());

        let lanes: Vec<WorkerLane> = match &cfg.plan {
            Some(plan) => {
                plan.validate()?;
                for m in &catalog.models {
                    if !plan.hosts(&m.kind) {
                        bail!("lane plan hosts no lane for kind '{}'", m.kind);
                    }
                }
                plan.lane_assignments()
                    .into_iter()
                    .map(|a| {
                        WorkerLane::spawn_assigned(
                            Arc::clone(&cfg.factory),
                            a,
                            Arc::clone(&metrics),
                        )
                    })
                    .collect::<Result<_>>()?
            }
            None => (0..cfg.lanes.max(1))
                .map(|i| WorkerLane::spawn(i, Arc::clone(&cfg.factory), Arc::clone(&metrics)))
                .collect::<Result<_>>()?,
        };
        let lanes = Arc::new(RwLock::new(lanes));

        let mut batchers: HashMap<String, DynamicBatcher> = catalog
            .models
            .iter()
            .map(|m| {
                (
                    m.kind.clone(),
                    DynamicBatcher::new(&m.kind, m.buckets.clone(), cfg.policy.clone()),
                )
            })
            .collect();

        let (inbox, rx) = channel::<LoopMsg>();
        let shutdown = Arc::new(AtomicBool::new(false));
        let stop = Arc::clone(&shutdown);
        let loop_lanes = Arc::clone(&lanes);
        let loop_handle = std::thread::Builder::new()
            .name("coordinator-loop".into())
            .spawn(move || batching_loop(rx, &mut batchers, &loop_lanes, &stop))?;

        Ok(Coordinator {
            inbox,
            metrics,
            router,
            next_id: Arc::new(AtomicU64::new(0)),
            shutdown,
            lanes,
            factory: cfg.factory,
            plan: Mutex::new(cfg.plan),
            loop_handle: Some(loop_handle),
        })
    }

    /// Swap the lane set to a new core-aware plan without dropping
    /// in-flight requests: fresh lanes are spawned and readied first,
    /// then dispatch flips to them, then the old lanes drain the batches
    /// they already accepted and shut down.
    pub fn apply_plan(&self, plan: LanePlan) -> Result<()> {
        plan.validate()?;
        for kind in self.router.kinds() {
            if !plan.hosts(kind) {
                bail!("lane plan hosts no lane for kind '{kind}'");
            }
        }
        // serialise whole re-plans on the plan mutex so the stored plan
        // can never disagree with the live lane set under concurrent
        // apply_plan calls (the batching loop only takes the lanes read
        // lock, so this ordering cannot deadlock)
        let mut current = self.plan.lock().unwrap();
        let fresh: Vec<WorkerLane> = plan
            .lane_assignments()
            .into_iter()
            .map(|a| {
                WorkerLane::spawn_assigned(Arc::clone(&self.factory), a, Arc::clone(&self.metrics))
            })
            .collect::<Result<_>>()?;
        let old = {
            let mut guard = self.lanes.write().unwrap();
            std::mem::replace(&mut *guard, fresh)
        };
        // dropping the old lanes enqueues their shutdown *behind* any
        // batches they already accepted, so in-flight work completes
        // before the join
        drop(old);
        *current = Some(plan);
        Ok(())
    }

    /// The active lane plan, if core-aware serving is on.
    pub fn current_plan(&self) -> Option<LanePlan> {
        self.plan.lock().unwrap().clone()
    }

    /// Per-lane queue depth (items queued or executing), as
    /// `(lane_id, depth)` pairs in lane order.
    pub fn lane_depths(&self) -> Vec<(usize, usize)> {
        self.lanes
            .read()
            .unwrap()
            .iter()
            .map(|l| (l.lane_id(), l.queued_items()))
            .collect()
    }

    /// A cloneable submit handle for cross-thread load generation.
    pub fn submitter(&self) -> Submitter {
        Submitter {
            inbox: self.inbox.clone(),
            router: Arc::clone(&self.router),
            next_id: Arc::clone(&self.next_id),
            metrics: Arc::clone(&self.metrics),
        }
    }

    /// Submit one item; returns the receiver for its response.
    pub fn submit(&self, kind: &str, input: Tensor) -> Result<Receiver<Response>> {
        self.submitter().submit(kind, input)
    }

    /// Submit and block for the response.
    pub fn infer(&self, kind: &str, input: Tensor) -> Result<Response> {
        let rx = self.submit(kind, input)?;
        Ok(rx.recv()?)
    }

    /// Serving metrics.
    pub fn metrics(&self) -> &ServingMetrics {
        &self.metrics
    }

    /// Router (shape contracts).
    pub fn router(&self) -> &Router {
        &self.router
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Release);
        // wake the loop even when it is blocked on an idle recv()
        let _ = self.inbox.send(LoopMsg::Shutdown);
        if let Some(h) = self.loop_handle.take() {
            let _ = h.join();
        }
        // join lane threads deterministically (flushed batches included)
        self.lanes.write().unwrap().clear();
    }
}

/// The serving loop: drain the inbox into per-kind batchers, cut batches
/// when full or timed out, dispatch each to the least-loaded lane
/// hosting its kind. With nothing queued the loop **blocks** on the
/// inbox — no idle polling; a [`LoopMsg::Shutdown`] (or sender
/// disconnect) flushes what remains and exits.
fn batching_loop(
    rx: Receiver<LoopMsg>,
    batchers: &mut HashMap<String, DynamicBatcher>,
    lanes: &RwLock<Vec<WorkerLane>>,
    shutdown: &AtomicBool,
) {
    loop {
        let now = Instant::now();
        let wait = batchers.values().filter_map(|b| b.next_deadline(now)).min();
        let msg = match wait {
            // nothing queued anywhere: block until work or shutdown
            None => match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => Some(LoopMsg::Shutdown),
            },
            // sleep exactly until the nearest batch deadline
            Some(d) => match rx.recv_timeout(d) {
                Ok(m) => Some(m),
                Err(RecvTimeoutError::Timeout) => None,
                Err(RecvTimeoutError::Disconnected) => Some(LoopMsg::Shutdown),
            },
        };
        let mut stop = shutdown.load(Ordering::Acquire);
        match msg {
            Some(LoopMsg::Req(req)) => {
                enqueue(batchers, req);
                // drain whatever else arrived
                loop {
                    match rx.try_recv() {
                        Ok(LoopMsg::Req(r)) => enqueue(batchers, r),
                        Ok(LoopMsg::Shutdown) => {
                            stop = true;
                            break;
                        }
                        Err(_) => break,
                    }
                }
            }
            Some(LoopMsg::Shutdown) => stop = true,
            None => {}
        }
        let now = Instant::now();
        let lanes = lanes.read().unwrap();
        for b in batchers.values_mut() {
            while b.ready(now) {
                dispatch(&lanes, b.cut());
            }
        }
        if stop {
            for b in batchers.values_mut() {
                while !b.is_empty() {
                    dispatch(&lanes, b.cut());
                }
            }
            return;
        }
    }
}

fn enqueue(batchers: &mut HashMap<String, DynamicBatcher>, req: Request) {
    if let Some(b) = batchers.get_mut(&req.kind) {
        b.push(req);
    }
}

/// Least-loaded dispatch over the lanes hosting the batch's kind
/// (deterministic: ties go to the lowest lane index).
fn dispatch(lanes: &[WorkerLane], batch: super::batcher::PendingBatch) {
    let loads: Vec<usize> = lanes.iter().map(WorkerLane::queued_items).collect();
    match pick_lane(&loads, |i| lanes[i].hosts(&batch.kind)) {
        Some(i) => lanes[i].submit(batch),
        // start()/apply_plan() guarantee every catalog kind is hosted;
        // if a regression slips through, keep serving rather than drop
        None => {
            if let Some(l) = lanes.first() {
                l.submit(batch);
            }
        }
    }
}
