//! Dynamic batcher: maps request-level parallelism onto the batch
//! dimension (paper §2.2.3), bucketed to the AOT-compiled batch sizes.
//!
//! Policy: dispatch when the largest bucket fills, or when the oldest
//! queued request has waited `max_wait` (latency bound). The chosen bucket
//! is the smallest compiled batch ≥ the queue depth; short batches are
//! zero-padded (tracked in metrics as `padded`).
//!
//! Batchers are keyed by interned [`KindId`] — the batching loop indexes
//! a dense `Vec` of them, and [`DynamicBatcher::cut_into`] fills a
//! recycled [`BatchBuf`] so steady-state cuts allocate nothing.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::runtime::KindId;

use super::pool::BatchBuf;
use super::request::Request;

/// Batching policy knobs.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Max time the oldest request may wait before a partial batch ships.
    pub max_wait: Duration,
    /// Cap on requests per batch (defaults to the largest compiled bucket).
    pub max_batch: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_wait: Duration::from_millis(2), max_batch: usize::MAX }
    }
}

/// A batch ready for a worker lane.
pub struct PendingBatch {
    /// Model family (interned).
    pub kind: KindId,
    /// Compiled bucket (≥ requests.len()).
    pub bucket: usize,
    /// The member requests, in arrival order.
    pub requests: Vec<Request>,
    /// When the batcher cut this batch — the "cut" timestamp trace
    /// recording attributes to every member request.
    pub(crate) cut_at: Instant,
    /// Gather scratch carried from the pool; the executing lane fills it
    /// and returns it with the rest of the buffer after scatter.
    pub(crate) input: Vec<f32>,
}

impl PendingBatch {
    /// Reclaim the batch's storage as a cleared [`BatchBuf`] (drops the
    /// member requests). Lanes go through [`super::pool::BatchPool::put`]
    /// instead; this is for callers that recycle buffers by hand.
    pub fn recycle(mut self) -> BatchBuf {
        self.requests.clear();
        self.input.clear();
        BatchBuf { requests: self.requests, input: self.input }
    }
}

/// Per-model-family batching queue.
pub struct DynamicBatcher {
    kind: KindId,
    queue: VecDeque<Request>,
    policy: BatchPolicy,
    buckets: Vec<usize>,
}

impl DynamicBatcher {
    /// Create a batcher for one model family over its executable batch
    /// buckets (normalised to an ascending, deduplicated, non-zero list —
    /// the backend catalog supplies these).
    pub fn new(kind: KindId, mut buckets: Vec<usize>, policy: BatchPolicy) -> Self {
        buckets.retain(|&b| b > 0);
        buckets.sort_unstable();
        buckets.dedup();
        assert!(!buckets.is_empty(), "no batch buckets for kind {kind:?}");
        DynamicBatcher { kind, queue: VecDeque::new(), policy, buckets }
    }

    /// Largest compiled bucket.
    pub fn max_bucket(&self) -> usize {
        *self.buckets.last().unwrap()
    }

    /// Effective batch cap.
    fn cap(&self) -> usize {
        self.policy.max_batch.min(self.max_bucket())
    }

    /// Enqueue a request.
    pub fn push(&mut self, req: Request) {
        debug_assert_eq!(req.kind, self.kind);
        self.queue.push_back(req);
    }

    /// Queue depth.
    pub fn len(&self) -> usize {
        self.queue.len()
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Smallest compiled bucket that fits `n` items.
    pub fn bucket_for(&self, n: usize) -> usize {
        self.buckets
            .iter()
            .copied()
            .find(|&b| b >= n)
            .unwrap_or_else(|| self.max_bucket())
    }

    /// Should a batch be cut right now?
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.is_empty() {
            return false;
        }
        if self.queue.len() >= self.cap() {
            return true;
        }
        let oldest = self.queue.front().unwrap().enqueued;
        now.duration_since(oldest) >= self.policy.max_wait
    }

    /// Cut the next batch (assumes `ready()`); requests keep arrival order.
    /// Allocates fresh storage — the recycled path is [`Self::cut_into`].
    pub fn cut(&mut self) -> PendingBatch {
        self.cut_into(BatchBuf::new())
    }

    /// Cut the next batch into a pooled buffer: members drain into
    /// `buf.requests` and `buf.input` rides along as gather scratch.
    /// Bucket choice and membership are identical to [`Self::cut`].
    pub fn cut_into(&mut self, buf: BatchBuf) -> PendingBatch {
        let BatchBuf { mut requests, input } = buf;
        debug_assert!(requests.is_empty() && input.is_empty());
        let take = self.queue.len().min(self.cap());
        requests.extend(self.queue.drain(..take));
        let bucket = self.bucket_for(requests.len());
        PendingBatch { kind: self.kind, bucket, requests, cut_at: Instant::now(), input }
    }

    /// Time until the oldest request hits `max_wait` (None if empty) —
    /// lets the serving loop sleep precisely instead of spinning.
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.queue.front().map(|r| {
            let waited = now.duration_since(r.enqueued);
            self.policy.max_wait.saturating_sub(waited)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Tensor;
    use std::sync::mpsc::channel;

    fn buckets() -> Vec<usize> {
        vec![1, 2, 4]
    }

    fn req(id: u64) -> Request {
        let (tx, _rx) = channel();
        Request {
            id: super::super::request::RequestId(id),
            kind: KindId(0),
            input: Tensor { shape: vec![1, 4], data: vec![0.0; 4] },
            enqueued: Instant::now(),
            reply: tx,
        }
    }

    #[test]
    fn buckets_from_catalog() {
        let b = DynamicBatcher::new(KindId(0), buckets(), BatchPolicy::default());
        assert_eq!(b.max_bucket(), 4);
        assert_eq!(b.bucket_for(1), 1);
        assert_eq!(b.bucket_for(3), 4);
        assert_eq!(b.bucket_for(9), 4);
    }

    #[test]
    fn buckets_normalised() {
        // unsorted, duplicated, zero-containing input is cleaned up
        let b = DynamicBatcher::new(KindId(0), vec![4, 0, 1, 4, 2], BatchPolicy::default());
        assert_eq!(b.max_bucket(), 4);
        assert_eq!(b.bucket_for(2), 2);
    }

    #[test]
    fn full_bucket_is_ready_immediately() {
        let mut b = DynamicBatcher::new(KindId(0), buckets(), BatchPolicy::default());
        for i in 0..4 {
            b.push(req(i));
        }
        assert!(b.ready(Instant::now()));
        let batch = b.cut();
        assert_eq!(batch.requests.len(), 4);
        assert_eq!(batch.bucket, 4);
        assert!(b.is_empty());
    }

    #[test]
    fn partial_batch_waits_for_deadline() {
        let policy = BatchPolicy { max_wait: Duration::from_millis(50), max_batch: usize::MAX };
        let mut b = DynamicBatcher::new(KindId(0), buckets(), policy);
        b.push(req(0));
        let now = Instant::now();
        assert!(!b.ready(now));
        assert!(b.ready(now + Duration::from_millis(51)));
        let batch = b.cut();
        assert_eq!(batch.requests.len(), 1);
        assert_eq!(batch.bucket, 1);
    }

    #[test]
    fn arrival_order_preserved() {
        let mut b = DynamicBatcher::new(KindId(0), buckets(), BatchPolicy::default());
        for i in 0..3 {
            b.push(req(i));
        }
        b.push(req(3));
        let batch = b.cut();
        let ids: Vec<u64> = batch.requests.iter().map(|r| r.id.0).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn max_batch_caps_cut() {
        let policy = BatchPolicy { max_wait: Duration::ZERO, max_batch: 2 };
        let mut b = DynamicBatcher::new(KindId(0), buckets(), policy);
        for i in 0..5 {
            b.push(req(i));
        }
        let batch = b.cut();
        assert_eq!(batch.requests.len(), 2);
        assert_eq!(b.len(), 3);
    }

    #[test]
    fn cut_into_matches_cut_and_recycles() {
        let mut a = DynamicBatcher::new(KindId(0), buckets(), BatchPolicy::default());
        let mut b = DynamicBatcher::new(KindId(0), buckets(), BatchPolicy::default());
        for i in 0..3 {
            a.push(req(i));
            b.push(req(i));
        }
        let plain = a.cut();
        let mut buf = BatchBuf::new();
        buf.requests.reserve(8);
        let pooled = b.cut_into(buf);
        assert_eq!(pooled.bucket, plain.bucket);
        let ids = |p: &PendingBatch| p.requests.iter().map(|r| r.id.0).collect::<Vec<_>>();
        assert_eq!(ids(&pooled), ids(&plain));
        // the pooled cut reused the buffer's storage, not a fresh alloc
        assert!(pooled.requests.capacity() >= 8);
    }

    #[test]
    fn deadline_shrinks() {
        let policy = BatchPolicy { max_wait: Duration::from_millis(10), max_batch: usize::MAX };
        let mut b = DynamicBatcher::new(KindId(0), buckets(), policy);
        assert!(b.next_deadline(Instant::now()).is_none());
        b.push(req(0));
        let d = b.next_deadline(Instant::now()).unwrap();
        assert!(d <= Duration::from_millis(10));
    }
}
