//! Worker lane: one thread owning a lane-local [`Backend`] instance
//! (real PJRT clients are not `Sync`), draining batches from a channel,
//! executing, and scattering per-request responses.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use crate::metrics::ServingMetrics;
use crate::runtime::{Backend, BackendFactory, Tensor};

use super::batcher::PendingBatch;
use super::request::Response;

/// Handle to a running worker lane.
pub struct WorkerLane {
    tx: Sender<LaneMsg>,
    handle: Option<JoinHandle<()>>,
}

enum LaneMsg {
    Batch(PendingBatch),
    Shutdown,
}

impl WorkerLane {
    /// Spawn a lane that instantiates its own backend from `factory` on
    /// the lane thread. Returns once the backend is ready (so startup
    /// failures surface synchronously).
    pub fn spawn(
        lane_id: usize,
        factory: Arc<dyn BackendFactory>,
        metrics: Arc<ServingMetrics>,
    ) -> Result<Self> {
        let (tx, rx) = channel::<LaneMsg>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let handle = std::thread::Builder::new()
            .name(format!("worker-lane-{lane_id}"))
            .spawn(move || {
                let backend = match factory.create() {
                    Ok(b) => {
                        let _ = ready_tx.send(Ok(()));
                        b
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                lane_loop(&*backend, rx, &metrics);
            })?;
        ready_rx.recv()??;
        Ok(WorkerLane { tx, handle: Some(handle) })
    }

    /// Queue a batch for execution.
    pub fn submit(&self, batch: PendingBatch) {
        let _ = self.tx.send(LaneMsg::Batch(batch));
    }
}

impl Drop for WorkerLane {
    fn drop(&mut self) {
        let _ = self.tx.send(LaneMsg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn lane_loop(backend: &dyn Backend, rx: Receiver<LaneMsg>, metrics: &ServingMetrics) {
    while let Ok(msg) = rx.recv() {
        match msg {
            LaneMsg::Shutdown => return,
            LaneMsg::Batch(batch) => execute_batch(backend, batch, metrics),
        }
    }
}

/// Execute one batch: gather rows → run the bucketed backend → scatter.
pub fn execute_batch(backend: &dyn Backend, batch: PendingBatch, metrics: &ServingMetrics) {
    let dispatch_time = Instant::now();
    let n = batch.requests.len();

    // gather: rows of each item, zero-padding up to the bucket
    let rows_per_item = batch.requests[0].input.shape[0];
    let feat: usize = batch.requests[0].input.shape[1..].iter().product();
    let mut data = Vec::with_capacity(batch.bucket * rows_per_item * feat);
    for r in &batch.requests {
        data.extend_from_slice(&r.input.data);
    }
    data.resize(batch.bucket * rows_per_item * feat, 0.0);
    let mut shape = batch.requests[0].input.shape.clone();
    shape[0] = batch.bucket * rows_per_item;
    let x = Tensor { shape, data };

    let result = backend.execute(&batch.kind, batch.bucket, x);
    metrics.batches.inc();
    if batch.bucket > n {
        metrics.padded.add((batch.bucket - n) as u64);
    }

    // scatter: slice each item's rows back out
    match result {
        Ok(exec) => {
            // model time: wall-clock on real backends, simulated on sim
            let execute_s = exec.model_time_s;
            metrics.execute_latency.record(execute_s);
            let out = exec.output;
            let out_rows: usize = out.shape[0];
            let out_feat: usize = out.shape[1..].iter().product();
            let rows_per_out_item = out_rows / batch.bucket;
            for (i, req) in batch.requests.into_iter().enumerate() {
                let lo = i * rows_per_out_item * out_feat;
                let hi = lo + rows_per_out_item * out_feat;
                let mut item_shape = out.shape.clone();
                item_shape[0] = rows_per_out_item;
                let queue_s = dispatch_time.duration_since(req.enqueued).as_secs_f64();
                metrics.requests.inc();
                metrics.queue_latency.record(queue_s);
                metrics.request_latency.record(queue_s + execute_s);
                let _ = req.reply.send(Response {
                    id: req.id,
                    output: Ok(Tensor { shape: item_shape, data: out.data[lo..hi].to_vec() }),
                    queue_s,
                    execute_s,
                    bucket: batch.bucket,
                });
            }
        }
        Err(e) => {
            let execute_s = dispatch_time.elapsed().as_secs_f64();
            let msg = format!("{e:#}");
            for req in batch.requests {
                metrics.requests.inc();
                let _ = req.reply.send(Response {
                    id: req.id,
                    output: Err(msg.clone()),
                    queue_s: 0.0,
                    execute_s,
                    bucket: batch.bucket,
                });
            }
        }
    }
}
