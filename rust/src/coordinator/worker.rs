//! Worker lane: one thread owning a lane-local [`Backend`] instance
//! (real PJRT clients are not `Sync`), draining batches from a channel,
//! executing, and scattering per-request responses.
//!
//! Lanes are either *unassigned* (legacy: any kind, whole machine) or
//! *core-aware*: spawned from a [`LaneAssignment`] that pins the lane to
//! a physical-core slice, a kind set and framework knobs — the backend
//! is created through `BackendFactory::create_on` so simulated latencies
//! reflect the lane's slice, not the whole box. Every lane exports a
//! queue-depth gauge (items queued or executing) that the coordinator's
//! least-loaded dispatch reads.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use crate::error::PallasError;
use crate::metrics::{Gauge, ServingMetrics};
use crate::runtime::{Backend, BackendFactory, Tensor};
use crate::sched::LaneAssignment;

use super::batcher::PendingBatch;
use super::request::Response;

/// Handle to a running worker lane.
pub struct WorkerLane {
    tx: Sender<LaneMsg>,
    handle: Option<JoinHandle<()>>,
    lane_id: usize,
    kinds: Option<Vec<String>>,
    depth: Arc<Gauge>,
}

enum LaneMsg {
    Batch(PendingBatch),
    Shutdown,
}

impl WorkerLane {
    /// Spawn an unassigned lane: the backend runs on the whole machine
    /// and the lane accepts every catalog kind. Returns once the backend
    /// is ready (so startup failures surface synchronously).
    pub fn spawn(
        lane_id: usize,
        factory: Arc<dyn BackendFactory>,
        metrics: Arc<ServingMetrics>,
    ) -> Result<Self> {
        Self::spawn_inner(lane_id, factory, None, metrics)
    }

    /// Spawn a core-aware lane: the backend is created for the lane's
    /// physical-core allocation (`BackendFactory::create_on`) and the
    /// lane only accepts its assigned kinds.
    pub fn spawn_assigned(
        factory: Arc<dyn BackendFactory>,
        assignment: LaneAssignment,
        metrics: Arc<ServingMetrics>,
    ) -> Result<Self> {
        let lane_id = assignment.lane_id;
        Self::spawn_inner(lane_id, factory, Some(assignment), metrics)
    }

    fn spawn_inner(
        lane_id: usize,
        factory: Arc<dyn BackendFactory>,
        assignment: Option<LaneAssignment>,
        metrics: Arc<ServingMetrics>,
    ) -> Result<Self> {
        let kinds = assignment
            .as_ref()
            .and_then(|a| if a.kinds.is_empty() { None } else { Some(a.kinds.clone()) });
        let depth = Arc::new(Gauge::new());
        let lane_depth = Arc::clone(&depth);
        let (tx, rx) = channel::<LaneMsg>();
        let (ready_tx, ready_rx) = channel::<Result<(), PallasError>>();
        let handle = std::thread::Builder::new()
            .name(format!("worker-lane-{lane_id}"))
            .spawn(move || {
                let created = match &assignment {
                    Some(a) => factory.create_on(a),
                    None => factory.create(),
                };
                let backend = match created {
                    Ok(b) => {
                        let _ = ready_tx.send(Ok(()));
                        b
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                lane_loop(&*backend, rx, &metrics, &lane_depth);
            })?;
        ready_rx.recv()??;
        Ok(WorkerLane { tx, handle: Some(handle), lane_id, kinds, depth })
    }

    /// Queue a batch for execution.
    pub fn submit(&self, batch: PendingBatch) {
        self.depth.add(batch.requests.len() as u64);
        let _ = self.tx.send(LaneMsg::Batch(batch));
    }

    /// Items queued or executing on this lane — the load signal the
    /// coordinator's least-loaded dispatch reads.
    pub fn queued_items(&self) -> usize {
        self.depth.get()
    }

    /// True when this lane executes batches for `kind` (unassigned lanes
    /// host everything).
    pub fn hosts(&self, kind: &str) -> bool {
        match &self.kinds {
            None => true,
            Some(ks) => ks.iter().any(|k| k == kind),
        }
    }

    /// Lane index within its plan.
    pub fn lane_id(&self) -> usize {
        self.lane_id
    }
}

impl Drop for WorkerLane {
    fn drop(&mut self) {
        let _ = self.tx.send(LaneMsg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn lane_loop(
    backend: &dyn Backend,
    rx: Receiver<LaneMsg>,
    metrics: &ServingMetrics,
    depth: &Gauge,
) {
    while let Ok(msg) = rx.recv() {
        match msg {
            LaneMsg::Shutdown => return,
            LaneMsg::Batch(batch) => {
                let items = batch.requests.len() as u64;
                execute_batch(backend, batch, metrics);
                depth.sub(items);
            }
        }
    }
}

/// Execute one batch: gather rows → run the bucketed backend → scatter.
pub fn execute_batch(backend: &dyn Backend, batch: PendingBatch, metrics: &ServingMetrics) {
    let dispatch_time = Instant::now();
    let n = batch.requests.len();
    let kind_counters = metrics.kind(&batch.kind);

    // gather: rows of each item, zero-padding up to the bucket
    let rows_per_item = batch.requests[0].input.shape[0];
    let feat: usize = batch.requests[0].input.shape[1..].iter().product();
    let mut data = Vec::with_capacity(batch.bucket * rows_per_item * feat);
    for r in &batch.requests {
        data.extend_from_slice(&r.input.data);
    }
    data.resize(batch.bucket * rows_per_item * feat, 0.0);
    let mut shape = batch.requests[0].input.shape.clone();
    shape[0] = batch.bucket * rows_per_item;
    let x = Tensor { shape, data };

    let result = backend.execute(&batch.kind, batch.bucket, x);
    metrics.batches.inc();
    kind_counters.batches.inc();
    kind_counters.batch_items.add(n as u64);
    if batch.bucket > n {
        metrics.padded.add((batch.bucket - n) as u64);
    }

    // scatter: slice each item's rows back out
    match result {
        Ok(exec) => {
            // model time: wall-clock on real backends, simulated on sim
            let execute_s = exec.model_time_s;
            metrics.execute_latency.record(execute_s);
            let out = exec.output;
            let out_rows: usize = out.shape[0];
            let out_feat: usize = out.shape[1..].iter().product();
            let rows_per_out_item = out_rows / batch.bucket;
            for (i, req) in batch.requests.into_iter().enumerate() {
                let lo = i * rows_per_out_item * out_feat;
                let hi = lo + rows_per_out_item * out_feat;
                let mut item_shape = out.shape.clone();
                item_shape[0] = rows_per_out_item;
                let queue_s = dispatch_time.duration_since(req.enqueued).as_secs_f64();
                metrics.requests.inc();
                kind_counters.completed.inc();
                metrics.queue_latency.record(queue_s);
                metrics.request_latency.record(queue_s + execute_s);
                let _ = req.reply.send(Response {
                    id: req.id,
                    output: Ok(Tensor { shape: item_shape, data: out.data[lo..hi].to_vec() }),
                    queue_s,
                    execute_s,
                    bucket: batch.bucket,
                });
            }
        }
        Err(e) => {
            let execute_s = dispatch_time.elapsed().as_secs_f64();
            let msg = e.to_string();
            for req in batch.requests {
                metrics.requests.inc();
                kind_counters.completed.inc();
                let _ = req.reply.send(Response {
                    id: req.id,
                    output: Err(msg.clone()),
                    queue_s: 0.0,
                    execute_s,
                    bucket: batch.bucket,
                });
            }
        }
    }
}
