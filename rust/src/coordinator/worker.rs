//! Worker lane: one thread owning a lane-local [`Backend`] instance
//! (real PJRT clients are not `Sync`), draining batches from a channel,
//! executing, and scattering per-request responses.
//!
//! Lanes are either *unassigned* (legacy: any kind, whole machine) or
//! *core-aware*: spawned from a [`LaneAssignment`] that pins the lane to
//! a physical-core slice, a kind set and framework knobs — the backend
//! is created through `BackendFactory::create_on` so simulated latencies
//! reflect the lane's slice, not the whole box. Every lane exports a
//! queue-depth gauge (items queued or executing) that the coordinator's
//! least-loaded dispatch reads.
//!
//! Fast-path contract: batches carry interned [`KindId`]s and pooled
//! gather scratch. A lane gathers into the batch's recycled buffer, runs
//! the backend by id (`execute_id`), scatters, and returns the buffer to
//! the shared [`BatchPool`] — steady state allocates nothing on the
//! coordinator side. `LaneEnv::reference` flips the lane to the seed
//! data plane (string-keyed `execute`, no recycling) for bit-identity
//! pins and bench baselines.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use crate::error::PallasError;
use crate::metrics::{Gauge, KindCounters, ServingMetrics};
use crate::runtime::{Backend, BackendFactory, KindId, KindTable, Tensor};
use crate::sched::LaneAssignment;
use crate::tracestore::{TraceEvent, TraceRecorder};

use super::batcher::PendingBatch;
use super::pool::{BatchBuf, BatchPool};
use super::request::Response;

/// Everything a lane shares with the coordinator: metrics, the interned
/// kind table, the batch-buffer pool, and which data plane to run.
#[derive(Clone)]
pub struct LaneEnv {
    /// Coordinator-wide metrics bundle.
    pub metrics: Arc<ServingMetrics>,
    /// Interned kind table (dense `KindId` space).
    pub table: Arc<KindTable>,
    /// Shared recycling pool batches return their buffers to.
    pub pool: Arc<BatchPool>,
    /// Trace recorder lanes emit per-request [`TraceEvent`]s into at
    /// batch completion; `None` (the default) costs one branch per batch.
    pub recorder: Option<Arc<TraceRecorder>>,
    /// Run the seed (reference) data plane instead of the fast path.
    pub reference: bool,
}

/// Handle to a running worker lane.
pub struct WorkerLane {
    tx: Sender<LaneMsg>,
    handle: Option<JoinHandle<()>>,
    lane_id: usize,
    /// Dense hosted-kind mask (`None` ⇒ hosts every kind).
    hosts: Option<Box<[bool]>>,
    depth: Arc<Gauge>,
}

enum LaneMsg {
    Batch(PendingBatch),
    Shutdown,
}

impl WorkerLane {
    /// Spawn an unassigned lane: the backend runs on the whole machine
    /// and the lane accepts every catalog kind. Returns once the backend
    /// is ready (so startup failures surface synchronously).
    pub(crate) fn spawn(
        lane_id: usize,
        factory: Arc<dyn BackendFactory>,
        env: LaneEnv,
    ) -> Result<Self> {
        Self::spawn_inner(lane_id, factory, None, env)
    }

    /// Spawn a core-aware lane: the backend is created for the lane's
    /// physical-core allocation (`BackendFactory::create_on`) and the
    /// lane only accepts its assigned kinds.
    pub(crate) fn spawn_assigned(
        factory: Arc<dyn BackendFactory>,
        assignment: LaneAssignment,
        env: LaneEnv,
    ) -> Result<Self> {
        let lane_id = assignment.lane_id;
        Self::spawn_inner(lane_id, factory, Some(assignment), env)
    }

    fn spawn_inner(
        lane_id: usize,
        factory: Arc<dyn BackendFactory>,
        assignment: Option<LaneAssignment>,
        env: LaneEnv,
    ) -> Result<Self> {
        let hosts = assignment.as_ref().and_then(|a| a.host_mask(&env.table));
        let depth = Arc::new(Gauge::new());
        let lane_depth = Arc::clone(&depth);
        let (tx, rx) = channel::<LaneMsg>();
        let (ready_tx, ready_rx) = channel::<Result<(), PallasError>>();
        let handle = std::thread::Builder::new()
            .name(format!("worker-lane-{lane_id}"))
            .spawn(move || {
                let created = match &assignment {
                    Some(a) => factory.create_on(a),
                    None => factory.create(),
                };
                let backend = match created {
                    Ok(b) => {
                        let _ = ready_tx.send(Ok(()));
                        b
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                lane_loop(&*backend, lane_id, rx, &env, &lane_depth);
            })?;
        ready_rx.recv()??;
        Ok(WorkerLane { tx, handle: Some(handle), lane_id, hosts, depth })
    }

    /// Queue a batch for execution.
    pub fn submit(&self, batch: PendingBatch) {
        self.depth.add(batch.requests.len() as u64);
        let _ = self.tx.send(LaneMsg::Batch(batch));
    }

    /// Items queued or executing on this lane — the load signal the
    /// coordinator's least-loaded dispatch reads.
    pub fn queued_items(&self) -> usize {
        self.depth.get()
    }

    /// True when this lane executes batches for `kind` (unassigned lanes
    /// host everything). O(1): a dense mask indexed by [`KindId`].
    pub fn hosts(&self, kind: KindId) -> bool {
        match &self.hosts {
            None => true,
            Some(mask) => mask.get(kind.index()).copied().unwrap_or(false),
        }
    }

    /// Lane index within its plan.
    pub fn lane_id(&self) -> usize {
        self.lane_id
    }
}

impl Drop for WorkerLane {
    fn drop(&mut self) {
        // Shutdown queues *behind* any in-flight batches (FIFO channel),
        // so dropping a lane never strands a pooled buffer.
        let _ = self.tx.send(LaneMsg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn lane_loop(
    backend: &dyn Backend,
    lane_id: usize,
    rx: Receiver<LaneMsg>,
    env: &LaneEnv,
    depth: &Gauge,
) {
    // resolve per-kind counters once — no string hashing per batch
    let kind_counters = env.metrics.intern_kinds(env.table.names());
    while let Ok(msg) = rx.recv() {
        match msg {
            LaneMsg::Shutdown => return,
            LaneMsg::Batch(batch) => {
                let items = batch.requests.len() as u64;
                execute_batch(backend, lane_id, batch, env, &kind_counters);
                depth.sub(items);
            }
        }
    }
}

/// Execute one batch: gather rows into the pooled scratch → run the
/// bucketed backend → record trace events → scatter → return the buffer
/// to the pool.
fn execute_batch(
    backend: &dyn Backend,
    lane_id: usize,
    batch: PendingBatch,
    env: &LaneEnv,
    kind_counters: &[Arc<KindCounters>],
) {
    let dispatch_time = Instant::now();
    let PendingBatch { kind, bucket, mut requests, cut_at, input: mut data } = batch;
    let n = requests.len();
    let counters = &kind_counters[kind.index()];
    let name = env.table.name(kind);

    // gather: rows of each item into the recycled buffer, zero-padding
    // up to the bucket (capacity survives from previous batches)
    let rows_per_item = requests[0].input.shape[0];
    let feat: usize = requests[0].input.shape[1..].iter().product();
    data.clear();
    data.reserve(bucket * rows_per_item * feat);
    for r in &requests {
        data.extend_from_slice(&r.input.data);
    }
    data.resize(bucket * rows_per_item * feat, 0.0);
    let mut shape = requests[0].input.shape.clone();
    shape[0] = bucket * rows_per_item;
    let x = Tensor { shape, data };

    let result = if env.reference {
        // seed data plane: per-batch string-keyed table lookup
        backend.execute(name, bucket, &x)
    } else {
        backend.execute_id(kind, name, bucket, &x)
    };
    env.metrics.batches.inc();
    counters.batches.inc();
    counters.batch_items.add(n as u64);
    if bucket > n {
        env.metrics.padded.add((bucket - n) as u64);
    }

    // trace capture: one event per member request, one sharded-ring
    // write per batch, while `requests` is still populated. Disabled
    // recording costs exactly this branch.
    if let Some(rec) = &env.recorder {
        let complete_time = Instant::now();
        let batch_id = rec.next_batch_id();
        let cut_ns = rec.ns_since_epoch(cut_at);
        let dispatch_ns = rec.ns_since_epoch(dispatch_time);
        let complete_ns = rec.ns_since_epoch(complete_time);
        rec.record(
            lane_id,
            requests.iter().map(|r| TraceEvent {
                request_id: r.id.0,
                kind: kind.0,
                lane: lane_id as u16,
                batch_id,
                occupancy: n.min(u16::MAX as usize) as u16,
                bucket: bucket.min(u32::MAX as usize) as u32,
                arrival_ns: rec.ns_since_epoch(r.enqueued),
                cut_ns,
                dispatch_ns,
                complete_ns,
            }),
        );
    }

    // scatter: slice each item's rows back out
    match result {
        Ok(exec) => {
            // model time: wall-clock on real backends, simulated on sim
            let execute_s = exec.model_time_s;
            env.metrics.execute_latency.record(execute_s);
            let out = exec.output;
            let out_rows: usize = out.shape[0];
            let out_feat: usize = out.shape[1..].iter().product();
            let rows_per_out_item = out_rows / bucket;
            let mut item_shape = out.shape.clone();
            item_shape[0] = rows_per_out_item;
            for (i, req) in requests.drain(..).enumerate() {
                let lo = i * rows_per_out_item * out_feat;
                let hi = lo + rows_per_out_item * out_feat;
                let queue_s = dispatch_time.duration_since(req.enqueued).as_secs_f64();
                env.metrics.requests.inc();
                counters.completed.inc();
                env.metrics.queue_latency.record(queue_s);
                env.metrics.request_latency.record(queue_s + execute_s);
                let item = out.data[lo..hi].to_vec();
                let _ = req.reply.send(Response {
                    id: req.id,
                    output: Ok(Tensor { shape: item_shape.clone(), data: item }),
                    queue_s,
                    execute_s,
                    bucket,
                });
            }
        }
        Err(e) => {
            let execute_s = dispatch_time.elapsed().as_secs_f64();
            for req in requests.drain(..) {
                env.metrics.requests.inc();
                counters.completed.inc();
                let _ = req.reply.send(Response {
                    id: req.id,
                    output: Err(e.clone()),
                    queue_s: 0.0,
                    execute_s,
                    bucket,
                });
            }
        }
    }

    // hand the (drained) request Vec and gather scratch back to the pool
    env.pool.put(BatchBuf { requests, input: x.data });
}
