//! Request/response types for the serving path.

use std::sync::mpsc::Sender;
use std::time::Instant;

use crate::error::PallasError;
use crate::runtime::{KindId, Tensor};

/// Monotonically-assigned request identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RequestId(pub u64);

/// One inference request: a single item (one MLP feature row, one
/// transformer sequence) for a model family.
pub struct Request {
    /// Assigned id.
    pub id: RequestId,
    /// Interned model family (resolved once at admission — no string
    /// keys downstream of the router).
    pub kind: KindId,
    /// Input tensor for ONE item; first dimension is the per-item row
    /// count (1 for mlp, `seq` for transformer).
    pub input: Tensor,
    /// Submission time (for queue-latency accounting).
    pub enqueued: Instant,
    /// Where to deliver the response.
    pub reply: Sender<Response>,
}

/// Completed inference.
#[derive(Debug, Clone)]
pub struct Response {
    /// Request this answers.
    pub id: RequestId,
    /// Output rows for this item only (padding stripped), or the typed
    /// execution error.
    pub output: Result<Tensor, PallasError>,
    /// Seconds spent queued before dispatch.
    pub queue_s: f64,
    /// Seconds of model execution for the carrying batch.
    pub execute_s: f64,
    /// Batch bucket the request rode in.
    pub bucket: usize,
}

impl Response {
    /// True when inference succeeded.
    pub fn is_ok(&self) -> bool {
        self.output.is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_ok_flag() {
        let ok = Response {
            id: RequestId(1),
            output: Ok(Tensor { shape: vec![1], data: vec![0.0] }),
            queue_s: 0.0,
            execute_s: 0.0,
            bucket: 1,
        };
        assert!(ok.is_ok());
        let err = Response { output: Err(PallasError::Backend("boom".into())), ..ok };
        assert!(!err.is_ok());
        // the typed error survives the response intact (the PR 5 error
        // taxonomy, not a stringly round-trip)
        assert_eq!(err.output.err(), Some(PallasError::Backend("boom".into())));
    }
}
