//! `parframe` CLI — leader entrypoint.
//!
//! ```text
//! parframe models                          list the model zoo + widths
//! parframe tune --model ncf [--platform large.2]
//! parframe tune --model ncf --exhaustive --jobs 8   (parallel global-optimum sweep)
//! parframe simulate --model resnet50 --pools 2 --mkl 12 --intra 12
//! parframe figures --fig 18 | --table 2 | --all
//! parframe serve --kind wide_deep --requests 256      (sim backend)
//! parframe serve --kinds wide_deep,resnet50           (core-aware lane plan)
//! parframe serve --kinds wide_deep,resnet50 --adaptive (online re-tuning)
//! parframe serve --backend pjrt --artifacts artifacts --kind mlp
//! parframe check --artifacts artifacts     verify artifact digests via PJRT
//! ```

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use parframe::bench_tables;
use parframe::config::{CpuPlatform, OperatorImpl, RunConfig, SchedPolicy};
use parframe::coordinator::{
    loadgen, BatchPolicy, Coordinator, CoordinatorConfig, LoadgenConfig, MixPhase,
};
use parframe::graph::analyze_width;
use parframe::models;
use parframe::runtime::{ModelRuntime, SimBackendConfig, SimBackendFactory};
use parframe::sched::LanePlan;
use parframe::sim::{self, SimCache};
use parframe::tuner;
use parframe::tuner::{OnlineTuner, OnlineTunerConfig, SweepOptions};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Minimal flag parser: `--key value` pairs after the subcommand.
fn parse_flags(args: &[String]) -> Result<HashMap<String, String>> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            if key == "all" || key == "adaptive" || key == "exhaustive" {
                flags.insert(key.to_string(), "true".to_string());
                i += 1;
            } else {
                let v = args.get(i + 1).ok_or_else(|| anyhow!("missing value for --{key}"))?;
                flags.insert(key.to_string(), v.clone());
                i += 2;
            }
        } else {
            bail!("unexpected argument '{a}'");
        }
    }
    Ok(flags)
}

fn platform_from(flags: &HashMap<String, String>) -> Result<CpuPlatform> {
    let name = flags.get("platform").map(String::as_str).unwrap_or("large.2");
    CpuPlatform::by_name(name).ok_or_else(|| anyhow!("unknown platform '{name}'"))
}

/// Optional `--policy` flag.
fn policy_from(flags: &HashMap<String, String>) -> Result<Option<SchedPolicy>> {
    flags
        .get("policy")
        .map(|p| {
            SchedPolicy::parse(p)
                .ok_or_else(|| anyhow!("unknown policy '{p}' (topo | critical-path | costly)"))
        })
        .transpose()
}

/// `--jobs` flag: sweep worker threads for the tuner and the sim
/// backend's table pre-simulation (defaults to the host parallelism,
/// capped; results are bit-identical at any value).
fn jobs_from(flags: &HashMap<String, String>) -> Result<usize> {
    Ok(flags
        .get("jobs")
        .map(|j| j.parse::<usize>())
        .transpose()?
        .unwrap_or_else(tuner::default_jobs)
        .max(1))
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first().map(String::as_str) else {
        print_help();
        return Ok(());
    };
    let flags = parse_flags(&args[1..])?;

    match cmd {
        "models" => cmd_models(),
        "tune" => cmd_tune(&flags),
        "simulate" => cmd_simulate(&flags),
        "figures" => cmd_figures(&flags),
        "ablations" => {
            println!("{}", bench_tables::ablations::ablation_table());
            Ok(())
        }
        "serve" => cmd_serve(&flags),
        "check" => cmd_check(&flags),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try 'parframe help')"),
    }
}

fn print_help() {
    println!(
        "parframe — parallelism-aware DL framework runtime + auto-tuner\n\
         \n\
         commands:\n\
           models                         list the model zoo with width analysis\n\
           tune     --model M [--platform P] [--batch N] [--policy POL]\n\
                    [--exhaustive]         also run the global-optimum sweep\n\
                    [--jobs N]             sweep worker threads (default: host cores, ≤8)\n\
           simulate --model M [--pools/--mkl/--intra N] [--policy POL] [--platform P]\n\
           figures  --fig N | --table N | --all\n\
           ablations                      per-feature degradation table
           serve    [--backend sim|pjrt] [--kind wide_deep] [--requests N]\n\
                    [--lanes N] [--concurrency N] [--platform P]\n\
                    [--kinds A,B]          core-aware lane plan (sim only)\n\
                    [--adaptive]           online re-tuning over a load shift\n\
                    [--policy POL]         pin the dispatch policy (sim only)\n\
                    [--jobs N]             parallel latency-table pre-simulation\n\
                    [--artifacts DIR]      (pjrt backend only)\n\
           check    --artifacts DIR\n\
         platforms: small | large | large.2 (default large.2)\n\
         policies:  topo | critical-path | costly\n\
                    (tune/serve default: the tuner's width rule; simulate default: topo)\n\
         sweeps are deterministic: any --jobs value returns bit-identical results"
    );
}

fn cmd_models() -> Result<()> {
    println!("{:<14} {:>6} {:>7} {:>7} {:>9} {:>12}", "model", "batch", "ops", "heavy", "max-width", "avg-width");
    for name in models::model_names() {
        let batch = models::canonical_batch(name);
        let g = models::build(name, batch).unwrap();
        let w = analyze_width(&g);
        println!(
            "{:<14} {:>6} {:>7} {:>7} {:>9} {:>12}",
            name, batch, g.len(), w.heavy_ops, w.max_width, w.avg_width
        );
    }
    Ok(())
}

fn cmd_tune(flags: &HashMap<String, String>) -> Result<()> {
    let model = flags.get("model").context("--model required")?;
    let platform = platform_from(flags)?;
    let batch = flags
        .get("batch")
        .map(|b| b.parse::<usize>())
        .transpose()?
        .unwrap_or_else(|| models::canonical_batch(model));
    let g = models::build(model, batch).ok_or_else(|| anyhow!("unknown model '{model}'"))?;
    let mut t = tuner::tune(&g, &platform);
    if let Some(p) = policy_from(flags)? {
        t.config.sched_policy = p;
    }
    println!("model {model} (batch {batch}) on {}:", platform.name);
    println!(
        "  width: heavy_ops={} levels={} max={} avg={}",
        t.width.heavy_ops, t.width.levels, t.width.max_width, t.width.avg_width
    );
    println!(
        "  recommended: inter_op_pools={} mkl_threads={} intra_op_threads={} policy={}",
        t.config.inter_op_pools,
        t.config.mkl_threads,
        t.config.intra_op_threads,
        t.config.sched_policy.name()
    );
    let guided = sim::simulate(&g, &platform, &t.config);
    println!("  simulated latency: {:.3} ms ({:.0} GFLOP/s)", guided.latency_s * 1e3, guided.gflops);
    for b in tuner::Baseline::ALL {
        let cfg = tuner::baseline_config(b, &platform);
        let r = sim::simulate(&g, &platform, &cfg);
        println!(
            "  vs {:<24} {:.3} ms  (ours {:.2}x)",
            b.name(),
            r.latency_s * 1e3,
            r.latency_s / guided.latency_s
        );
    }
    if flags.contains_key("exhaustive") {
        let jobs = jobs_from(flags)?;
        let t0 = std::time::Instant::now();
        let opt = tuner::exhaustive_search_with(&g, &platform, &SweepOptions::with_jobs(jobs));
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "  global optimum (exhaustive, {} unique points, jobs={jobs}, {:.2}s, {:.0} points/s):",
            opt.evaluated,
            wall,
            opt.evaluated as f64 / wall.max(1e-9)
        );
        println!(
            "    pools={} mkl={} intra={} policy={} → {:.3} ms (guideline {:.3}x of optimum)",
            opt.best.inter_op_pools,
            opt.best.mkl_threads,
            opt.best.intra_op_threads,
            opt.best.sched_policy.name(),
            opt.best_latency_s * 1e3,
            guided.latency_s / opt.best_latency_s
        );
    }
    Ok(())
}

fn cmd_simulate(flags: &HashMap<String, String>) -> Result<()> {
    let model = flags.get("model").context("--model required")?;
    let platform = platform_from(flags)?;
    let batch = flags
        .get("batch")
        .map(|b| b.parse::<usize>())
        .transpose()?
        .unwrap_or_else(|| models::canonical_batch(model));
    let g = models::build(model, batch).ok_or_else(|| anyhow!("unknown model '{model}'"))?;
    let mut cfg = RunConfig { platform: platform.clone(), ..RunConfig::default() }.framework;
    cfg.operator_impl = OperatorImpl::IntraOpParallel;
    if let Some(p) = flags.get("pools") {
        cfg.inter_op_pools = p.parse()?;
    }
    if let Some(m) = flags.get("mkl") {
        cfg.mkl_threads = m.parse()?;
    } else {
        cfg.mkl_threads = (platform.physical_cores() / cfg.inter_op_pools.max(1)).max(1);
    }
    if let Some(i) = flags.get("intra") {
        cfg.intra_op_threads = i.parse()?;
    } else {
        cfg.intra_op_threads = cfg.mkl_threads;
    }
    if let Some(p) = policy_from(flags)? {
        cfg.sched_policy = p;
    }
    cfg.validate(&platform).map_err(|e| anyhow!(e))?;
    let r = sim::simulate(&g, &platform, &cfg);
    println!(
        "{model} (batch {batch}) on {} with pools={} mkl={} intra={} policy={}:",
        platform.name,
        cfg.inter_op_pools,
        cfg.mkl_threads,
        cfg.intra_op_threads,
        cfg.sched_policy.name()
    );
    println!(
        "  latency {:.3} ms | {:.0} GFLOP/s | throughput {:.1} items/s",
        r.latency_s * 1e3,
        r.gflops,
        r.throughput(batch)
    );
    for cat in sim::Category::ALL {
        println!("  {:<14} {:>6.1}%", cat.label(), r.breakdown.frac(cat) * 100.0);
    }
    Ok(())
}

fn cmd_figures(flags: &HashMap<String, String>) -> Result<()> {
    if flags.contains_key("all") {
        for n in bench_tables::FIGURES {
            println!("{}", bench_tables::figure(n).unwrap());
        }
        println!("{}", bench_tables::table(2).unwrap());
        println!("{}", bench_tables::table(3).unwrap());
        return Ok(());
    }
    if let Some(f) = flags.get("fig") {
        let n: usize = f.parse()?;
        let s = bench_tables::figure(n).ok_or_else(|| anyhow!("no generator for figure {n}"))?;
        println!("{s}");
        return Ok(());
    }
    if let Some(t) = flags.get("table") {
        let n: usize = t.parse()?;
        let s = bench_tables::table(n).ok_or_else(|| anyhow!("no generator for table {n}"))?;
        println!("{s}");
        return Ok(());
    }
    bail!("figures needs --fig N, --table N or --all")
}

fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    let backend = flags.get("backend").map(String::as_str).unwrap_or("sim");
    let n_requests: usize = flags.get("requests").map(|r| r.parse()).transpose()?.unwrap_or(256);
    let lanes: usize = flags.get("lanes").map(|l| l.parse()).transpose()?.unwrap_or(1);
    let concurrency: usize =
        flags.get("concurrency").map(|c| c.parse()).transpose()?.unwrap_or(4);

    // multi-kind core-aware serving (with optional online re-tuning)
    if flags.contains_key("kinds") || flags.contains_key("adaptive") {
        if backend != "sim" {
            bail!("--kinds/--adaptive need the sim backend");
        }
        return cmd_serve_planned(flags, n_requests, concurrency);
    }

    let policy = policy_from(flags)?;
    let (mut cfg, kind) = match backend {
        "sim" => {
            let platform = platform_from(flags)?;
            let kind = flags.get("kind").map(String::as_str).unwrap_or("wide_deep");
            println!(
                "starting coordinator: backend=sim kind={kind} lanes={lanes} platform={} policy={}",
                platform.name,
                policy.map(|p| p.name()).unwrap_or("tuner")
            );
            // pin only the policy dimension: buckets keep their per-batch
            // tuned thread knobs, so --policy A/Bs isolate dispatch order
            let mut sc = SimBackendConfig::new(platform, &[kind]);
            sc.policy = policy;
            sc.jobs = jobs_from(flags)?;
            (CoordinatorConfig::sim_with(sc), kind.to_string())
        }
        "pjrt" => {
            if policy.is_some() {
                bail!("--policy needs the sim backend (PJRT owns its own scheduling)");
            }
            let dir = flags.get("artifacts").map(String::as_str).unwrap_or("artifacts");
            let kind = flags.get("kind").map(String::as_str).unwrap_or("mlp");
            println!(
                "starting coordinator: backend=pjrt kind={kind} lanes={lanes} artifacts={dir}"
            );
            (CoordinatorConfig::pjrt(dir, &[kind]), kind.to_string())
        }
        other => bail!("unknown backend '{other}' (sim | pjrt)"),
    };
    cfg.lanes = lanes;
    cfg.policy = BatchPolicy::default();
    let coord = Coordinator::start(cfg)?;

    let report = loadgen::run(&coord, &LoadgenConfig::closed(&kind, n_requests, concurrency))?;
    println!("loadgen: {}", report.summary());
    println!("metrics: {}", coord.metrics().summary());
    Ok(())
}

/// Core-aware serving over ≥ 2 model kinds: a shifting-mix scenario
/// (kind A drains while kind B ramps) on a lane-planned coordinator.
/// With `--adaptive` the online re-tuner re-splits cores between phases;
/// without it the startup §8 plan stays frozen — run both to compare.
fn cmd_serve_planned(
    flags: &HashMap<String, String>,
    n_requests: usize,
    concurrency: usize,
) -> Result<()> {
    let platform = platform_from(flags)?;
    let adaptive = flags.contains_key("adaptive");
    let kinds_arg = flags
        .get("kinds")
        .cloned()
        .unwrap_or_else(|| "wide_deep,resnet50".to_string());
    let kinds: Vec<String> = kinds_arg
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if kinds.len() < 2 {
        bail!("core-aware serving needs ≥ 2 kinds, e.g. --kinds wide_deep,resnet50");
    }
    let kind_refs: Vec<&str> = kinds.iter().map(String::as_str).collect();

    let jobs = jobs_from(flags)?;
    let mut plan = LanePlan::guideline(&platform, &kind_refs)?;
    if let Some(pol) = policy_from(flags)? {
        plan = plan.with_policy(pol);
    }
    println!(
        "starting coordinator: backend=sim kinds={} platform={} adaptive={adaptive} jobs={jobs}",
        kinds.join(","),
        platform.name
    );
    print_plan(&plan);
    // one memo-cache shared by the backend's lane tables and the online
    // tuner's candidate scoring: a re-plan only simulates design points
    // neither tier has seen
    let cache = Arc::new(SimCache::new());
    let mut sc = SimBackendConfig::new(platform.clone(), &kind_refs);
    sc.jobs = jobs;
    let factory = SimBackendFactory::with_cache(sc, Arc::clone(&cache));
    let cfg = CoordinatorConfig::with_factory(Arc::new(factory)).with_plan(plan);
    let coord = Coordinator::start(cfg)?;

    let phases = MixPhase::ramp(&kinds[0], &kinds[1], 4, (n_requests / 4).max(8));
    let mut tuner = OnlineTuner::with_config(
        platform,
        &kind_refs,
        OnlineTunerConfig { jobs, ..OnlineTunerConfig::default() },
    )
    .with_cache(cache);
    let reports = loadgen::run_shift(
        &coord,
        &phases,
        concurrency,
        0x5EED,
        if adaptive { Some(&mut tuner) } else { None },
    )?;
    for (i, report) in reports.iter().enumerate() {
        println!("phase {i}: {}", report.summary());
    }
    if adaptive {
        println!("plan after online re-tuning:");
        print_plan(&coord.current_plan().expect("planned coordinator"));
    }
    println!("metrics: {}", coord.metrics().summary());
    Ok(())
}

fn print_plan(plan: &LanePlan) {
    for g in &plan.groups {
        println!(
            "  lane group {:?}: cores {}..={} ({}) pools={} mkl={} intra={} policy={}",
            g.kinds,
            g.allocation.first_core,
            g.allocation.last_core(),
            g.allocation.cores,
            g.framework.inter_op_pools,
            g.framework.mkl_threads,
            g.framework.intra_op_threads,
            g.framework.sched_policy.name()
        );
    }
}

fn cmd_check(flags: &HashMap<String, String>) -> Result<()> {
    let dir = flags.get("artifacts").map(String::as_str).unwrap_or("artifacts");
    let rt = ModelRuntime::load(std::path::Path::new(dir))?;
    println!("platform: {}", rt.platform());
    for name in rt.loaded().into_iter().map(str::to_string).collect::<Vec<_>>() {
        rt.self_check(&name)?;
        println!("  {name}: digest OK");
    }
    println!("all artifacts verified");
    Ok(())
}
