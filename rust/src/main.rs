//! `parframe` CLI — a thin declarative shell over [`parframe::api`].
//!
//! ```text
//! parframe models                          list the model zoo + widths
//! parframe tune --model ncf [--platform large.2] [--exhaustive] [--jobs 8]
//! parframe tune --model ncf --emit-plan plan.json   (serializable tuning plan)
//! parframe plan --show plan.json           inspect a plan artifact
//! parframe simulate --model resnet50 --pools 2 --mkl 12 --intra 12
//! parframe figures --fig 18 | --table 2 | --all
//! parframe serve --kind wide_deep --requests 256      (sim backend)
//! parframe serve --plan plan.json                     (deploy a tuned plan)
//! parframe serve --kinds wide_deep,resnet50           (core-aware lane plan)
//! parframe serve --kinds wide_deep,resnet50 --adaptive (online re-tuning)
//! parframe serve --backend pjrt --artifacts artifacts --kind mlp
//! parframe serve --kind wide_deep --record out.plt    (capture a serving trace)
//! parframe serve --plan plan.json --trace out.plt     (replay recorded arrivals)
//! parframe tune --trace out.plt             tune for a recorded traffic mix
//! parframe trace summary --file out.plt     p50/p99 queue/service breakdowns
//! parframe trace ab --file out.plt --plan a.json --plan b.json
//! parframe check --artifacts artifacts     verify artifact digests via PJRT
//! ```
//!
//! Every subcommand is a ~10-line adapter: parse flags against the
//! subcommand's declared spec (unknown flags error out listing what is
//! accepted), build a [`Session`]/[`Workload`]/[`Plan`], call the facade,
//! print.

use std::collections::HashMap;
use std::sync::Arc;

use parframe::api::{model_catalog, Plan, ServeHandle, Session, Workload};
use parframe::bench_tables;
use parframe::coordinator::loadgen;
use parframe::coordinator::{Coordinator, CoordinatorConfig, LoadgenConfig, MixPhase};
use parframe::runtime::ModelRuntime;
use parframe::tracestore::{TraceData, TraceRecorder};
use parframe::tuner::Baseline;
use parframe::{PallasError, PallasResult};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// One accepted flag of a subcommand: name (without `--`) and whether a
/// value follows it.
struct FlagSpec {
    name: &'static str,
    takes_value: bool,
}

const fn flag(name: &'static str) -> FlagSpec {
    FlagSpec { name, takes_value: true }
}

const fn switch(name: &'static str) -> FlagSpec {
    FlagSpec { name, takes_value: false }
}

const TUNE_FLAGS: &[FlagSpec] = &[
    flag("model"),
    flag("platform"),
    flag("batch"),
    flag("policy"),
    flag("jobs"),
    flag("emit-plan"),
    flag("trace"),
    switch("exhaustive"),
    switch("no-prune"),
];
const SIMULATE_FLAGS: &[FlagSpec] = &[
    flag("model"),
    flag("platform"),
    flag("batch"),
    flag("pools"),
    flag("mkl"),
    flag("intra"),
    flag("policy"),
];
const FIGURES_FLAGS: &[FlagSpec] = &[flag("fig"), flag("table"), switch("all")];
const SERVE_FLAGS: &[FlagSpec] = &[
    flag("backend"),
    flag("kind"),
    flag("kinds"),
    flag("plan"),
    flag("emit-plan"),
    flag("requests"),
    flag("lanes"),
    flag("concurrency"),
    flag("platform"),
    flag("policy"),
    flag("jobs"),
    flag("artifacts"),
    flag("record"),
    flag("trace"),
    switch("adaptive"),
];
const PLAN_FLAGS: &[FlagSpec] = &[flag("show")];
const TRACE_FILE_FLAGS: &[FlagSpec] = &[flag("file")];
const TRACE_SLOWEST_FLAGS: &[FlagSpec] = &[flag("file"), flag("top")];
const TRACE_SHOW_FLAGS: &[FlagSpec] = &[flag("file"), flag("width"), switch("chrome")];
const CHECK_FLAGS: &[FlagSpec] = &[flag("artifacts")];
const BENCH_CHECK_FLAGS: &[FlagSpec] = &[flag("file"), flag("suite")];
const NO_FLAGS: &[FlagSpec] = &[];

/// Parse `--key [value]` pairs against a subcommand's spec. Unknown or
/// misspelled flags are fatal and the error lists every accepted flag —
/// a dropped `--job 8` must never silently fall back to defaults.
fn parse_flags(
    cmd: &str,
    args: &[String],
    spec: &[FlagSpec],
) -> PallasResult<HashMap<String, String>> {
    let accepted = || -> String {
        if spec.is_empty() {
            return "none".into();
        }
        spec.iter()
            .map(|f| {
                if f.takes_value {
                    format!("--{} VALUE", f.name)
                } else {
                    format!("--{}", f.name)
                }
            })
            .collect::<Vec<_>>()
            .join(", ")
    };
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        let Some(key) = a.strip_prefix("--") else {
            return Err(PallasError::Cli(format!(
                "unexpected argument '{a}' for '{cmd}' (accepted flags: {})",
                accepted()
            )));
        };
        let Some(f) = spec.iter().find(|f| f.name == key) else {
            return Err(PallasError::Cli(format!(
                "unknown flag --{key} for '{cmd}' (accepted flags: {})",
                accepted()
            )));
        };
        if f.takes_value {
            let v = args.get(i + 1).ok_or_else(|| {
                PallasError::Cli(format!("missing value for --{key} (usage: --{key} VALUE)"))
            })?;
            flags.insert(key.to_string(), v.clone());
            i += 2;
        } else {
            flags.insert(key.to_string(), "true".to_string());
            i += 1;
        }
    }
    Ok(flags)
}

/// Build the session every subcommand shares from the common flags
/// (`--platform`, `--jobs`, `--policy`, and `tune`'s `--no-prune`).
fn session_from(flags: &HashMap<String, String>) -> PallasResult<Session> {
    let mut b = Session::builder();
    if let Some(p) = flags.get("platform") {
        b = b.platform_named(p)?;
    }
    if let Some(p) = flags.get("policy") {
        b = b.policy_named(p)?;
    }
    if let Some(j) = flags.get("jobs") {
        b = b.jobs(parse_num(j, "jobs")?);
    }
    if flags.contains_key("no-prune") {
        b = b.prune(false);
    }
    Ok(b.build())
}

fn parse_num(v: &str, what: &str) -> PallasResult<usize> {
    v.parse::<usize>()
        .map_err(|_| PallasError::Cli(format!("--{what} needs a number, got '{v}'")))
}

fn run() -> PallasResult<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first().map(String::as_str) else {
        print_help();
        return Ok(());
    };
    let rest = &args[1..];
    match cmd {
        "models" => {
            parse_flags(cmd, rest, NO_FLAGS)?;
            cmd_models()
        }
        "tune" => cmd_tune(&parse_flags(cmd, rest, TUNE_FLAGS)?),
        "simulate" => cmd_simulate(&parse_flags(cmd, rest, SIMULATE_FLAGS)?),
        "figures" => cmd_figures(&parse_flags(cmd, rest, FIGURES_FLAGS)?),
        "ablations" => {
            parse_flags(cmd, rest, NO_FLAGS)?;
            println!("{}", bench_tables::ablations::ablation_table());
            Ok(())
        }
        "serve" => cmd_serve(&parse_flags(cmd, rest, SERVE_FLAGS)?),
        "trace" => cmd_trace(rest),
        "plan" => cmd_plan(&parse_flags(cmd, rest, PLAN_FLAGS)?),
        "check" => cmd_check(&parse_flags(cmd, rest, CHECK_FLAGS)?),
        "bench-check" => cmd_bench_check(&parse_flags(cmd, rest, BENCH_CHECK_FLAGS)?),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => Err(PallasError::Cli(format!(
            "unknown command '{other}' (try 'parframe help')"
        ))),
    }
}

fn print_help() {
    println!(
        "parframe — parallelism-aware DL framework runtime + auto-tuner\n\
         \n\
         commands:\n\
           models                         list the model zoo with width analysis\n\
           tune     --model M [--platform P] [--batch N] [--policy POL]\n\
                    [--exhaustive]         also run the global-optimum search\n\
                    [--no-prune]           flat sweep instead of branch-and-bound\n\
                                           (bit-identical result; for measurement)\n\
                    [--jobs N]             sweep worker threads (default: host cores, ≤8,\n\
                                           or the PALLAS_JOBS env override)\n\
                    [--emit-plan FILE]     write the tuning decision as plan.json\n\
                    [--trace FILE.plt]     tune for a recorded traffic mix instead of\n\
                                           --model (kinds, weights and batch shapes\n\
                                           come from the trace; deterministic)\n\
           plan     --show FILE           inspect a plan artifact\n\
           simulate --model M [--pools/--mkl/--intra N] [--policy POL] [--platform P]\n\
           figures  --fig N | --table N | --all\n\
           ablations                      per-feature degradation table\n\
           serve    [--backend sim|pjrt] [--kind wide_deep] [--requests N]\n\
                    [--plan FILE]          deploy a tuned plan artifact (sim only)\n\
                    [--lanes N] [--concurrency N] [--platform P]\n\
                    [--kinds A,B]          core-aware lane plan (sim only)\n\
                    [--adaptive]           online re-tuning over a load shift\n\
                    [--emit-plan FILE]     snapshot the live plan after serving\n\
                    [--policy POL]         pin the dispatch policy (sim only)\n\
                    [--jobs N]             parallel latency-table pre-simulation\n\
                    [--artifacts DIR]      (pjrt backend only)\n\
                    [--record FILE.plt]    capture a serving trace (sim only)\n\
                    [--trace FILE.plt]     replay recorded arrivals (sim only)\n\
           trace    summary|kinds|batches|slowest|show --file FILE.plt\n\
                    slowest [--top N]      rank requests by end-to-end latency\n\
                    show [--width N] [--chrome]  render per-lane batch timelines\n\
                    ab --file FILE.plt --plan a.json --plan b.json\n\
                                           score plans against one recorded trace\n\
           check    --artifacts DIR\n\
           bench-check --file BENCH_sim.json --suite sim\n\
                    validate an emitted/committed benchmark JSON (schema + case keys)\n\
         platforms: small | large | large.2 (default large.2)\n\
         policies:  topo | critical-path | costly\n\
                    (tune/serve default: the tuner's width rule; simulate default: topo)\n\
         sweeps are deterministic: any --jobs value returns bit-identical results"
    );
}

fn cmd_models() -> PallasResult<()> {
    println!(
        "{:<14} {:>6} {:>7} {:>7} {:>9} {:>12}",
        "model", "batch", "ops", "heavy", "max-width", "avg-width"
    );
    for m in model_catalog() {
        println!(
            "{:<14} {:>6} {:>7} {:>7} {:>9} {:>12}",
            m.name, m.batch, m.ops, m.width.heavy_ops, m.width.max_width, m.width.avg_width
        );
    }
    Ok(())
}

fn workload_from(flags: &HashMap<String, String>) -> PallasResult<Workload> {
    let model = flags
        .get("model")
        .ok_or_else(|| PallasError::Cli("--model required".into()))?;
    let w = Workload::single(model)?;
    match flags.get("batch") {
        Some(b) => w.with_batch(parse_num(b, "batch")?),
        None => Ok(w),
    }
}

fn cmd_tune(flags: &HashMap<String, String>) -> PallasResult<()> {
    if flags.contains_key("trace") {
        return cmd_tune_trace(flags);
    }
    let session = session_from(flags)?;
    let w = workload_from(flags)?;
    let guided = session.tune(&w)?;
    let e = &guided.entries[0];
    println!("model {} (batch {}) on {}:", e.kind, e.batch, session.platform().name);
    println!(
        "  recommended: inter_op_pools={} mkl_threads={} intra_op_threads={} policy={}",
        e.config.inter_op_pools,
        e.config.mkl_threads,
        e.config.intra_op_threads,
        e.config.sched_policy.name()
    );
    println!("  simulated latency: {:.3} ms", e.predicted_latency_s * 1e3);
    for b in Baseline::ALL {
        let r = session.tune_baseline(&w, b)?;
        let lat = r.entries[0].predicted_latency_s;
        println!(
            "  vs {:<24} {:.3} ms  (ours {:.2}x)",
            b.name(),
            lat * 1e3,
            lat / e.predicted_latency_s
        );
    }
    let emitted = if flags.contains_key("exhaustive") {
        let opt = session.tune_exhaustive(&w)?;
        let oe = &opt.entries[0];
        println!(
            "  global optimum (exhaustive, {} unique points, jobs={}):",
            opt.evaluated,
            session.jobs()
        );
        println!(
            "    pools={} mkl={} intra={} policy={} → {:.3} ms (guideline {:.3}x of optimum)",
            oe.config.inter_op_pools,
            oe.config.mkl_threads,
            oe.config.intra_op_threads,
            oe.config.sched_policy.name(),
            oe.predicted_latency_s * 1e3,
            e.predicted_latency_s / oe.predicted_latency_s
        );
        opt
    } else {
        guided
    };
    if let Some(path) = flags.get("emit-plan") {
        emitted.save(path)?;
        println!("plan written to {path} (tier {})", emitted.tier.name());
    }
    Ok(())
}

/// `tune --trace out.plt`: tune for a *recorded* traffic mix. The trace
/// fixes the kinds, their traffic weights (request counts) and batch
/// shapes (mode compiled bucket), so `--model`/`--batch` are no-ops and
/// rejected. Scoring is simulator-backed, so the output is bit-identical
/// across runs and `--jobs` values.
fn cmd_tune_trace(flags: &HashMap<String, String>) -> PallasResult<()> {
    reject_flags(
        flags,
        &["model", "batch"],
        "tune --trace (the trace fixes the kinds and batch shapes)",
    )?;
    let path = flags.get("trace").expect("dispatched on --trace");
    let trace = TraceData::load(path)?;
    let session = session_from(flags)?;
    let w = Workload::from_trace(&trace)?;
    println!(
        "tuning from trace {path}: {} events, {} kinds on {}",
        trace.events.len(),
        w.entries.len(),
        session.platform().name
    );
    for e in &w.entries {
        println!("  {:<14} weight {:>6.0}  batch {}", e.kind, e.weight, e.batch);
    }
    let plan = if flags.contains_key("exhaustive") {
        let p = session.tune_exhaustive(&w)?;
        println!(
            "global optimum (exhaustive, {} unique points, jobs={}):",
            p.evaluated,
            session.jobs()
        );
        p
    } else {
        session.tune(&w)?
    };
    for line in plan.group_lines() {
        println!("{line}");
    }
    let score = session.score_plan_on_trace(&plan, &trace)?;
    println!(
        "trace-weighted simulated latency: {:.3} ms (tier {})",
        score * 1e3,
        plan.tier.name()
    );
    if let Some(out) = flags.get("emit-plan") {
        plan.save(out)?;
        println!("plan written to {out} (tier {})", plan.tier.name());
    }
    Ok(())
}

fn cmd_simulate(flags: &HashMap<String, String>) -> PallasResult<()> {
    let session = session_from(flags)?;
    let model = flags
        .get("model")
        .ok_or_else(|| PallasError::Cli("--model required".into()))?;
    let batch = match flags.get("batch") {
        Some(b) => parse_num(b, "batch")?,
        None => parframe::models::canonical_batch(model),
    };
    let num = |k: &str| flags.get(k).map(|v| parse_num(v, k)).transpose();
    let cfg = session.manual_config(num("pools")?, num("mkl")?, num("intra")?)?;
    let r = session.simulate(model, batch, &cfg)?;
    println!(
        "{model} (batch {batch}) on {} with pools={} mkl={} intra={} policy={}:",
        session.platform().name,
        cfg.inter_op_pools,
        cfg.mkl_threads,
        cfg.intra_op_threads,
        cfg.sched_policy.name()
    );
    println!(
        "  latency {:.3} ms | {:.0} GFLOP/s | throughput {:.1} items/s",
        r.latency_s * 1e3,
        r.gflops,
        r.throughput(batch)
    );
    for cat in parframe::sim::Category::ALL {
        println!("  {:<14} {:>6.1}%", cat.label(), r.breakdown.frac(cat) * 100.0);
    }
    Ok(())
}

fn cmd_figures(flags: &HashMap<String, String>) -> PallasResult<()> {
    if flags.contains_key("all") {
        for n in bench_tables::FIGURES {
            println!("{}", bench_tables::figure(n).unwrap());
        }
        println!("{}", bench_tables::table(2).unwrap());
        println!("{}", bench_tables::table(3).unwrap());
        return Ok(());
    }
    if let Some(f) = flags.get("fig") {
        let n = parse_num(f, "fig")?;
        let s = bench_tables::figure(n)
            .ok_or_else(|| PallasError::Cli(format!("no generator for figure {n}")))?;
        println!("{s}");
        return Ok(());
    }
    if let Some(t) = flags.get("table") {
        let n = parse_num(t, "table")?;
        let s = bench_tables::table(n)
            .ok_or_else(|| PallasError::Cli(format!("no generator for table {n}")))?;
        println!("{s}");
        return Ok(());
    }
    Err(PallasError::Cli("figures needs --fig N, --table N or --all".into()))
}

fn cmd_plan(flags: &HashMap<String, String>) -> PallasResult<()> {
    let path = flags
        .get("show")
        .ok_or_else(|| PallasError::Cli("plan needs --show FILE".into()))?;
    let plan = Plan::load(path)?;
    for line in plan.group_lines() {
        println!("{line}");
    }
    Ok(())
}

fn cmd_serve(flags: &HashMap<String, String>) -> PallasResult<()> {
    let backend = flags.get("backend").map(String::as_str).unwrap_or("sim");
    if backend == "pjrt" {
        return cmd_serve_pjrt(flags);
    }
    if backend != "sim" {
        return Err(PallasError::Cli(format!("unknown backend '{backend}' (sim | pjrt)")));
    }
    if flags.contains_key("plan") {
        cmd_serve_plan(flags)
    } else if flags.contains_key("kinds") || flags.contains_key("adaptive") {
        cmd_serve_planned(flags)
    } else {
        cmd_serve_single(flags)
    }
}

/// Reject flags that parse under `serve`'s spec but have no effect in
/// the dispatched serving mode — a no-op flag must fail, not silently
/// drop (same contract as unknown flags).
fn reject_flags(
    flags: &HashMap<String, String>,
    unusable: &[&str],
    mode: &str,
) -> PallasResult<()> {
    for f in unusable {
        if flags.contains_key(*f) {
            return Err(PallasError::Cli(format!("--{f} has no effect with {mode}")));
        }
    }
    Ok(())
}

fn requests_from(flags: &HashMap<String, String>) -> PallasResult<usize> {
    flags.get("requests").map(|r| parse_num(r, "requests")).transpose().map(|r| r.unwrap_or(256))
}

fn concurrency_from(flags: &HashMap<String, String>) -> PallasResult<usize> {
    flags
        .get("concurrency")
        .map(|c| parse_num(c, "concurrency"))
        .transpose()
        .map(|c| c.unwrap_or(4))
}

/// Deploy a `plan.json` artifact: the serving configuration is exactly
/// the plan's bits (group lines + latency table printed so CI can diff
/// them against `plan --show`).
fn cmd_serve_plan(flags: &HashMap<String, String>) -> PallasResult<()> {
    reject_flags(
        flags,
        &["adaptive", "policy", "lanes", "kind", "kinds", "emit-plan", "artifacts"],
        "serve --plan (the plan artifact fixes layout and knobs)",
    )?;
    let path = flags.get("plan").expect("dispatched on --plan");
    let plan = Plan::load(path)?;
    // the plan names its platform; an explicit --platform must match
    let mut session = Session::builder().platform_named(&plan.platform)?;
    if let Some(p) = flags.get("platform") {
        session = session.platform_named(p)?;
    }
    if let Some(j) = flags.get("jobs") {
        session = session.jobs(parse_num(j, "jobs")?);
    }
    let session = session.build();
    let recorder = flags.contains_key("record").then(|| Arc::new(TraceRecorder::new()));
    let handle = session.serve_with(&plan, recorder)?;
    println!(
        "serving plan {path}: tier={} evaluated={} platform={} fingerprint={:016x}",
        plan.tier.name(),
        plan.evaluated,
        plan.platform,
        plan.sim_fingerprint
    );
    // print the *live* lane set (not the artifact) so CI's diff against
    // `plan --show` proves serving deployed exactly the plan's bits
    let live = handle
        .coordinator()
        .current_plan()
        .ok_or_else(|| PallasError::InvalidPlan("plan deployment left no live plan".into()))?;
    for g in &live.groups {
        println!(
            "{}",
            parframe::api::group_line(
                &g.kinds[0],
                g.allocation.first_core,
                g.allocation.cores,
                g.lanes,
                &g.framework
            )
        );
    }
    println!("latency table (simulated seconds per batch):");
    for ((kind, bucket), lat) in handle.latency_table()? {
        println!("  {kind} b{bucket} {lat:e}");
    }
    if let Some(trace_path) = flags.get("trace") {
        // a replay re-issues the trace's arrival process verbatim, so
        // the synthetic-load knobs would be silent no-ops
        reject_flags(
            flags,
            &["requests", "concurrency"],
            "serve --trace (the trace fixes the arrival process)",
        )?;
        let trace = TraceData::load(trace_path)?;
        let replay = trace.replay_plan(0x5EED);
        println!("replaying {trace_path}: {} recorded arrivals", replay.arrivals.len());
        let r = handle.run_replay(&replay)?;
        println!("replay: {}", r.summary());
    } else {
        let n_requests = requests_from(flags)?;
        let concurrency = concurrency_from(flags)?;
        let per_kind = (n_requests / plan.entries.len()).max(1);
        for e in &plan.entries {
            let r = handle.run_closed(&e.kind, per_kind, concurrency)?;
            println!("loadgen {}: {}", e.kind, r.summary());
        }
    }
    save_recorded(&handle, flags)?;
    println!("metrics: {}", handle.coordinator().metrics().summary());
    Ok(())
}

/// After serving, drain an attached recorder to the `--record` path.
fn save_recorded(handle: &ServeHandle, flags: &HashMap<String, String>) -> PallasResult<()> {
    if let Some(path) = flags.get("record") {
        let data = handle.drain_trace()?;
        let stats = handle.recorder().expect("drain_trace found a recorder").stats();
        data.save(path)?;
        println!(
            "trace written to {path}: {} events, {} kinds ({} recorded, {} dropped)",
            data.events.len(),
            data.kinds.len(),
            stats.recorded,
            stats.dropped
        );
    }
    Ok(())
}

/// Single-kind serving on unassigned whole-machine lanes.
fn cmd_serve_single(flags: &HashMap<String, String>) -> PallasResult<()> {
    reject_flags(
        flags,
        &["emit-plan", "artifacts"],
        "the sim backend's single-kind serve (snapshots need --adaptive; artifacts need \
         --backend pjrt)",
    )?;
    let session = session_from(flags)?;
    let lanes = flags.get("lanes").map(|l| parse_num(l, "lanes")).transpose()?.unwrap_or(1);
    let recorder = flags.contains_key("record").then(|| Arc::new(TraceRecorder::new()));
    if let Some(trace_path) = flags.get("trace") {
        // replay mode: the trace names its kinds and fixes the arrival
        // process, so the synthetic-load knobs are silent no-ops
        reject_flags(
            flags,
            &["kind", "requests", "concurrency"],
            "serve --trace (the trace fixes the kinds and arrival process)",
        )?;
        let trace = TraceData::load(trace_path)?;
        if trace.kinds.is_empty() {
            return Err(PallasError::Cli(format!("{trace_path}: trace has an empty kind table")));
        }
        let kinds: Vec<&str> = trace.kinds.iter().map(String::as_str).collect();
        println!(
            "starting coordinator: backend=sim kinds={} lanes={lanes} platform={} (replay)",
            trace.kinds.join(","),
            session.platform().name
        );
        let handle = session.serve_unplanned_with(&kinds, lanes, recorder)?;
        let replay = trace.replay_plan(0x5EED);
        println!("replaying {trace_path}: {} recorded arrivals", replay.arrivals.len());
        let report = handle.run_replay(&replay)?;
        println!("replay: {}", report.summary());
        save_recorded(&handle, flags)?;
        println!("metrics: {}", handle.coordinator().metrics().summary());
        return Ok(());
    }
    let kind = flags.get("kind").map(String::as_str).unwrap_or("wide_deep");
    println!(
        "starting coordinator: backend=sim kind={kind} lanes={lanes} platform={} policy={}",
        session.platform().name,
        session.policy().map(|p| p.name()).unwrap_or("tuner")
    );
    let handle = session.serve_unplanned_with(&[kind], lanes, recorder)?;
    let report = handle.run_closed(kind, requests_from(flags)?, concurrency_from(flags)?)?;
    println!("loadgen: {}", report.summary());
    save_recorded(&handle, flags)?;
    println!("metrics: {}", handle.coordinator().metrics().summary());
    Ok(())
}

/// Core-aware serving over ≥ 2 kinds: a shifting-mix scenario on a
/// guideline lane plan, optionally re-tuned online between phases.
fn cmd_serve_planned(flags: &HashMap<String, String>) -> PallasResult<()> {
    reject_flags(
        flags,
        &["kind", "lanes", "artifacts", "record", "trace"],
        "core-aware serving (record/replay ride the --kind or --plan serving modes)",
    )?;
    let session = session_from(flags)?;
    let adaptive = flags.contains_key("adaptive");
    if !adaptive && flags.contains_key("emit-plan") {
        return Err(PallasError::Cli(
            "--emit-plan on serve snapshots the re-tuned plan; add --adaptive \
             (or emit from `tune`)"
                .into(),
        ));
    }
    let kinds_arg = flags
        .get("kinds")
        .cloned()
        .unwrap_or_else(|| "wide_deep,resnet50".to_string());
    let kinds: Vec<&str> =
        kinds_arg.split(',').map(str::trim).filter(|s| !s.is_empty()).collect();
    if kinds.len() < 2 {
        return Err(PallasError::Cli(
            "core-aware serving needs ≥ 2 kinds, e.g. --kinds wide_deep,resnet50".into(),
        ));
    }
    let workload = Workload::kinds(&kinds)?;
    let plan = session.tune(&workload)?;
    println!(
        "starting coordinator: backend=sim kinds={} platform={} adaptive={adaptive} jobs={}",
        kinds.join(","),
        session.platform().name,
        session.jobs()
    );
    for line in plan.group_lines() {
        println!("{line}");
    }
    let handle = session.serve(&plan)?;
    let n_requests = requests_from(flags)?;
    let phases = MixPhase::ramp(kinds[0], kinds[1], 4, (n_requests / 4).max(8));
    let reports = handle.run_shift(&phases, concurrency_from(flags)?, 0x5EED, adaptive)?;
    for (i, report) in reports.iter().enumerate() {
        println!("phase {i}: {}", report.summary());
    }
    if adaptive {
        let snap = session.snapshot(&handle)?;
        println!("plan after online re-tuning:");
        for line in snap.group_lines() {
            println!("{line}");
        }
        if let Some(path) = flags.get("emit-plan") {
            snap.save(path)?;
            println!("plan written to {path} (tier {})", snap.tier.name());
        }
    }
    println!("metrics: {}", handle.coordinator().metrics().summary());
    Ok(())
}

/// PJRT serving (artifact-gated; the facade's sim tiers don't apply).
fn cmd_serve_pjrt(flags: &HashMap<String, String>) -> PallasResult<()> {
    reject_flags(
        flags,
        &[
            "policy",
            "kinds",
            "adaptive",
            "plan",
            "jobs",
            "emit-plan",
            "platform",
            "record",
            "trace",
        ],
        "the pjrt backend (it owns scheduling and runs on the host machine)",
    )?;
    let dir = flags.get("artifacts").map(String::as_str).unwrap_or("artifacts");
    let kind = flags.get("kind").map(String::as_str).unwrap_or("mlp");
    let lanes = flags.get("lanes").map(|l| parse_num(l, "lanes")).transpose()?.unwrap_or(1);
    println!("starting coordinator: backend=pjrt kind={kind} lanes={lanes} artifacts={dir}");
    let mut cfg = CoordinatorConfig::pjrt(dir, &[kind]);
    cfg.lanes = lanes;
    let coord = Coordinator::start(cfg)?;
    let report = loadgen::run(
        &coord,
        &LoadgenConfig::closed(kind, requests_from(flags)?, concurrency_from(flags)?),
    )?;
    println!("loadgen: {}", report.summary());
    println!("metrics: {}", coord.metrics().summary());
    Ok(())
}

/// `parframe trace VERB --file out.plt`: offline queries over a recorded
/// `.plt` serving trace. The verb is positional (like a git subcommand)
/// so each verb can declare its own flag spec.
fn cmd_trace(rest: &[String]) -> PallasResult<()> {
    let Some(verb) = rest.first().map(String::as_str) else {
        return Err(PallasError::Cli(
            "trace needs a verb: summary | kinds | batches | slowest | show | ab \
             (e.g. parframe trace summary --file out.plt)"
                .into(),
        ));
    };
    let rest = &rest[1..];
    match verb {
        "summary" => cmd_trace_summary(&parse_flags("trace summary", rest, TRACE_FILE_FLAGS)?),
        "kinds" => cmd_trace_kinds(&parse_flags("trace kinds", rest, TRACE_FILE_FLAGS)?),
        "batches" => cmd_trace_batches(&parse_flags("trace batches", rest, TRACE_FILE_FLAGS)?),
        "slowest" => cmd_trace_slowest(&parse_flags("trace slowest", rest, TRACE_SLOWEST_FLAGS)?),
        "show" => cmd_trace_show(&parse_flags("trace show", rest, TRACE_SHOW_FLAGS)?),
        "ab" => cmd_trace_ab(rest),
        other => Err(PallasError::Cli(format!(
            "unknown trace verb '{other}' (summary | kinds | batches | slowest | show | ab)"
        ))),
    }
}

fn load_trace(flags: &HashMap<String, String>) -> PallasResult<TraceData> {
    let path = flags
        .get("file")
        .ok_or_else(|| PallasError::Cli("--file TRACE.plt required".into()))?;
    TraceData::load(path)
}

fn cmd_trace_summary(flags: &HashMap<String, String>) -> PallasResult<()> {
    let t = load_trace(flags)?;
    let s = t.summary();
    println!(
        "{} events over {:.3} s | {} batches (mean occupancy {:.2}) | {} lanes | {} kinds",
        s.events, s.duration_s, s.batches, s.mean_occupancy, s.lanes, s.kinds.len()
    );
    println!("per-kind latency breakdown (ms):");
    println!(
        "{:<14} {:>6} {:>7} {:>9} {:>9} {:>9} {:>9} {:>9}",
        "kind", "count", "bucket", "batch-p50", "wait-p50", "svc-p50", "total-p50", "total-p99"
    );
    for k in &s.kinds {
        println!(
            "{:<14} {:>6} {:>7} {:>9.3} {:>9.3} {:>9.3} {:>9.3} {:>9.3}",
            k.name,
            k.count,
            k.mode_bucket,
            k.p50_batching_ms,
            k.p50_lane_wait_ms,
            k.p50_service_ms,
            k.p50_total_ms,
            k.p99_total_ms
        );
    }
    Ok(())
}

fn cmd_trace_kinds(flags: &HashMap<String, String>) -> PallasResult<()> {
    let t = load_trace(flags)?;
    let counts = t.per_kind_counts();
    println!("{:<4} {:<14} {:>7}", "id", "kind", "events");
    // the footer's full interned table, including kinds with no traffic
    for (id, name) in t.kinds.iter().enumerate() {
        let n = counts
            .iter()
            .find(|&&(k, _)| k as usize == id)
            .map(|&(_, n)| n)
            .unwrap_or(0);
        println!("{id:<4} {name:<14} {n:>7}");
    }
    Ok(())
}

fn cmd_trace_batches(flags: &HashMap<String, String>) -> PallasResult<()> {
    let t = load_trace(flags)?;
    let rows = t.batch_rows();
    println!("{} batches over {} events", rows.len(), t.events.len());
    let hist = t.occupancy_histogram();
    let peak = hist.iter().map(|&(_, n)| n).max().unwrap_or(1);
    println!("occupancy histogram (requests per executed batch):");
    for &(occ, n) in &hist {
        let bar = "#".repeat((n * 40 / peak).max(1));
        println!("  {occ:>4} | {n:>6} {bar}");
    }
    Ok(())
}

fn cmd_trace_slowest(flags: &HashMap<String, String>) -> PallasResult<()> {
    let t = load_trace(flags)?;
    let top = flags.get("top").map(|v| parse_num(v, "top")).transpose()?.unwrap_or(10);
    println!("slowest {top} requests by end-to-end latency (ms):");
    println!(
        "{:<10} {:<14} {:>5} {:>8} {:>7} {:>9} {:>9} {:>9} {:>9}",
        "request", "kind", "lane", "batch", "bucket", "batching", "wait", "service", "total"
    );
    for e in t.slowest(top) {
        println!(
            "{:<10} {:<14} {:>5} {:>8} {:>7} {:>9.3} {:>9.3} {:>9.3} {:>9.3}",
            e.request_id,
            t.kind_name(e.kind),
            e.lane,
            e.batch_id,
            e.bucket,
            e.batching_ns() as f64 / 1e6,
            e.lane_wait_ns() as f64 / 1e6,
            e.service_ns() as f64 / 1e6,
            e.total_ns() as f64 / 1e6
        );
    }
    Ok(())
}

/// Render a trace through the existing simulator-trace emitters: one
/// compute burst per executed batch, one row per worker lane.
fn cmd_trace_show(flags: &HashMap<String, String>) -> PallasResult<()> {
    let t = load_trace(flags)?;
    let (timelines, span) = t.lane_timelines();
    if flags.contains_key("chrome") {
        println!("{}", parframe::trace::chrome_trace(&timelines));
        return Ok(());
    }
    let width = flags.get("width").map(|v| parse_num(v, "width")).transpose()?.unwrap_or(72);
    print!("{}", parframe::trace::ascii_trace(&timelines, span, width));
    println!("(rows are worker lanes; each # burst is one executed batch over {span:.3} s)");
    Ok(())
}

/// `trace ab` hand-parses its args: `--plan` legitimately repeats, which
/// the shared `parse_flags` map (last value wins) cannot express.
fn cmd_trace_ab(args: &[String]) -> PallasResult<()> {
    let mut file: Option<&str> = None;
    let mut plans: Vec<&str> = Vec::new();
    let mut i = 0;
    while i < args.len() {
        let value = args.get(i + 1).map(String::as_str);
        match args[i].as_str() {
            "--file" => {
                file = Some(value.ok_or_else(|| {
                    PallasError::Cli("missing value for --file (usage: --file TRACE.plt)".into())
                })?);
            }
            "--plan" => {
                plans.push(value.ok_or_else(|| {
                    PallasError::Cli("missing value for --plan (usage: --plan FILE)".into())
                })?);
            }
            other => {
                return Err(PallasError::Cli(format!(
                    "unexpected argument '{other}' for 'trace ab' (accepted flags: \
                     --file TRACE.plt, --plan FILE [repeatable])"
                )))
            }
        }
        i += 2;
    }
    let file = file.ok_or_else(|| PallasError::Cli("trace ab needs --file TRACE.plt".into()))?;
    if plans.len() < 2 {
        return Err(PallasError::Cli(
            "trace ab needs at least two --plan FILE flags to compare".into(),
        ));
    }
    let trace = TraceData::load(file)?;
    println!("scoring {} plans against {file} ({} events):", plans.len(), trace.events.len());
    let mut scored: Vec<(&str, f64)> = Vec::new();
    for &path in &plans {
        let plan = Plan::load(path)?;
        // the plan names its platform; score on that exact machine
        let session = Session::builder().platform_named(&plan.platform)?.build();
        let s = session.score_plan_on_trace(&plan, &trace)?;
        println!(
            "  {path}: {:.3} ms trace-weighted (tier {}, platform {})",
            s * 1e3,
            plan.tier.name(),
            plan.platform
        );
        scored.push((path, s));
    }
    let (best, best_s) = scored
        .iter()
        .copied()
        .min_by(|a, b| a.1.total_cmp(&b.1))
        .expect("at least two plans scored");
    println!("winner: {best} at {:.3} ms", best_s * 1e3);
    for &(path, s) in &scored {
        if path != best {
            println!("  beats {path} by {:.2}x", s / best_s);
        }
    }
    Ok(())
}

/// Every case name a suite's bench target is contractually required to
/// emit — `bench-check` fails if any is missing, so a bench refactor
/// that drops or renames a case (or a stale committed `BENCH_*.json`)
/// breaks CI instead of silently thinning the perf trajectory.
fn expected_bench_cases(suite: &str) -> Vec<String> {
    match suite {
        "sim" => [
            "simulate/seed-engine",
            "simulate/fast-engine",
            "simulate/prepared",
            "lattice-sweep/seed",
            "lattice-sweep/fastpath",
            "fastpath-vs-seed",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect(),
        "tuner" => {
            let mut v = Vec::new();
            for model in ["wide_deep", "inception_v3"] {
                for stage in
                    ["serial-cold", "parallel-cold", "pruned-cold", "warming", "warm-resweep"]
                {
                    v.push(format!("sweep/{model}/{stage}"));
                }
            }
            v.push("pruned-vs-flat".to_string());
            v.push("simulated-fraction".to_string());
            v.push("coldstart/3-kinds/serial".to_string());
            v.push("coldstart/3-kinds/parallel".to_string());
            v
        }
        "serving" => {
            let mut v = Vec::new();
            for regime in ["unassigned", "core-aware"] {
                for plane in ["seed", "fastpath"] {
                    v.push(format!("saturation/{regime}/{plane}"));
                }
                v.push(format!("fixed-load/{regime}/p50"));
                v.push(format!("fixed-load/{regime}/p99"));
            }
            v.push("fastpath-vs-seed".to_string());
            v
        }
        "trace" => [
            "saturation/record-off",
            "saturation/record-on",
            "record-overhead",
            "encode/events-per-sec",
            "decode/events-per-sec",
            "file/bytes-per-event",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect(),
        "threadpool" => {
            let mut v = Vec::new();
            // per-task submission plane: the three pool libraries plus
            // the preserved mutex reference plane, at 4 and 64 threads
            for threads in [4usize, 64] {
                for pool in ["std::thread", "Eigen", "Folly", "reference"] {
                    v.push(format!("{pool}/{threads}threads/10k-tasks"));
                }
            }
            for pool in ["std::thread", "Eigen", "Folly", "reference"] {
                v.push(format!("{pool}/single-task-roundtrip"));
            }
            // batch plane + the substrate-vs-reference headline ratios
            for threads in [4usize, 64] {
                v.push(format!("Eigen/{threads}threads/batch-submit"));
            }
            v.push("fastpath-vs-reference".to_string());
            v.push("fastpath-vs-reference/64threads".to_string());
            v
        }
        _ => Vec::new(),
    }
}

/// Validate a `BENCH_<suite>.json` emitted by `util::bench` (or the
/// committed copy at the repo root): it must parse, carry the current
/// schema version and the named suite, have well-typed fields, and
/// contain every expected case for suites with a declared case set.
fn cmd_bench_check(flags: &HashMap<String, String>) -> PallasResult<()> {
    use parframe::util::{bench::BENCH_SCHEMA_VERSION, json::Json};
    let path = flags.get("file").ok_or_else(|| PallasError::Cli("--file required".into()))?;
    let suite = flags.get("suite").ok_or_else(|| PallasError::Cli("--suite required".into()))?;
    let fail = |m: String| PallasError::Cli(format!("{path}: {m}"));
    let text = std::fs::read_to_string(path)
        .map_err(|e| PallasError::Cli(format!("cannot read {path}: {e}")))?;
    let doc = Json::parse(&text).map_err(|e| fail(format!("not valid JSON: {e}")))?;
    let version = doc
        .get("schema_version")
        .and_then(Json::as_f64)
        .ok_or_else(|| fail("missing numeric 'schema_version'".into()))?;
    if version != BENCH_SCHEMA_VERSION as f64 {
        return Err(fail(format!(
            "stale schema version {version} (current is {BENCH_SCHEMA_VERSION}; \
             re-run `cargo bench` and commit the refreshed file)"
        )));
    }
    let got_suite = doc
        .get("suite")
        .and_then(Json::as_str)
        .ok_or_else(|| fail("missing string 'suite'".into()))?;
    if got_suite != suite {
        return Err(fail(format!("suite is '{got_suite}', expected '{suite}'")));
    }
    let git_rev = doc
        .get("git_rev")
        .and_then(Json::as_str)
        .ok_or_else(|| fail("missing string 'git_rev'".into()))?;
    if git_rev == "unknown" {
        return Err(fail(
            "git_rev is 'unknown' — a committed BENCH_*.json must carry a real \
             revision (re-run the bench inside the checkout, or export GIT_REV)"
                .into(),
        ));
    }
    doc.get("timestamp")
        .and_then(Json::as_f64)
        .ok_or_else(|| fail("missing numeric 'timestamp'".into()))?;
    if !matches!(doc.get("fast"), Some(Json::Bool(_))) {
        return Err(fail("missing boolean 'fast'".into()));
    }
    let cases = doc
        .get("cases")
        .and_then(Json::as_arr)
        .ok_or_else(|| fail("missing array 'cases'".into()))?;
    if cases.is_empty() {
        return Err(fail("'cases' is empty".into()));
    }
    let mut names = Vec::with_capacity(cases.len());
    for (i, c) in cases.iter().enumerate() {
        let name = c
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| fail(format!("case {i}: missing string 'name'")))?;
        for field in ["iters", "mean_s", "p50_s", "p95_s", "sd_s"] {
            c.get(field)
                .and_then(Json::as_f64)
                .ok_or_else(|| fail(format!("case '{name}': missing numeric '{field}'")))?;
        }
        c.get("unit")
            .and_then(Json::as_str)
            .ok_or_else(|| fail(format!("case '{name}': missing string 'unit'")))?;
        names.push(name.to_string());
    }
    let expected = expected_bench_cases(suite);
    for want in &expected {
        if !names.iter().any(|n| n == want) {
            return Err(fail(format!(
                "missing expected case '{want}' (bench target and committed file out of sync?)"
            )));
        }
    }
    println!(
        "{path}: OK — suite '{suite}', schema v{BENCH_SCHEMA_VERSION}, {} cases ({} required)",
        names.len(),
        expected.len()
    );
    Ok(())
}

fn cmd_check(flags: &HashMap<String, String>) -> PallasResult<()> {
    let dir = flags.get("artifacts").map(String::as_str).unwrap_or("artifacts");
    let rt = ModelRuntime::load(std::path::Path::new(dir))?;
    println!("platform: {}", rt.platform());
    for name in rt.loaded().into_iter().map(str::to_string).collect::<Vec<_>>() {
        rt.self_check(&name)?;
        println!("  {name}: digest OK");
    }
    println!("all artifacts verified");
    Ok(())
}
