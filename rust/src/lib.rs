//! # parframe
//!
//! A parallelism-aware deep-learning framework runtime and auto-tuner — a
//! production-shaped reproduction of *"Exploiting Parallelism Opportunities
//! with Deep Learning Frameworks"* (Wang et al., 2019).
//!
//! The crate is organised in three layers (see `DESIGN.md`):
//!
//! * **Framework core** — [`graph`] (computational-graph IR + width
//!   analysis), [`ops`] (operator cost descriptors), [`models`] (the paper's
//!   model zoo), [`sched`] (sync/async operator scheduling over inter-op
//!   pools), [`libs`] (math-library models + three real thread pools).
//! * **Platform substrate** — [`sim`], a discrete-event simulator of the
//!   paper's Skylake testbeds (cores, SMT/FMA contention, LLC, memory and
//!   UPI bandwidth) that produces the same per-core time breakdowns the
//!   authors measured with `perf`.
//! * **Deployment** — [`runtime`] (pluggable execution backends behind the
//!   `Backend`/`BackendFactory` traits: the PJRT client running
//!   AOT-compiled JAX/Pallas artifacts, and `SimBackend`, which serves the
//!   model zoo through the simulator with zero external artifacts),
//!   [`coordinator`] (request router + dynamic batcher + load generator),
//!   and [`tuner`] (the paper's §8 guidelines + Intel/TensorFlow baselines +
//!   exhaustive search).
//!
//! [`bench_tables`] regenerates every figure and table of the paper's
//! evaluation.

pub mod bench_tables;
pub mod config;
pub mod coordinator;
pub mod graph;
pub mod libs;
pub mod metrics;
pub mod models;
pub mod ops;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod trace;
pub mod tuner;
pub mod util;
