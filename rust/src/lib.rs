//! # parframe
//!
//! A parallelism-aware deep-learning framework runtime and auto-tuner — a
//! production-shaped reproduction of *"Exploiting Parallelism Opportunities
//! with Deep Learning Frameworks"* (Wang et al., 2019).
//!
//! ## The supported surface: [`api`]
//!
//! Application code should go through the **[`api`] facade** — a
//! [`api::Session`] owning the shared platform/cache/sweep state, a
//! [`api::Workload`] describing what to tune, and a serializable
//! [`api::Plan`] carrying the tuning decision across processes
//! (`tune --emit-plan` → `serve --plan`). Every facade call returns the
//! typed [`PallasError`]. The CLI, the examples and the integration tests
//! are all thin shells over it; the blessed types are re-exported at the
//! crate root.
//!
//! ## Internals
//!
//! The remaining modules are the machinery the facade orchestrates
//! (public for benches, tests and power users; their APIs move more
//! freely than the facade's):
//!
//! * **Framework core** — [`graph`] (computational-graph IR + width
//!   analysis), [`ops`] (operator cost descriptors), [`models`] (the paper's
//!   model zoo), [`sched`] (sync/async operator scheduling over inter-op
//!   pools + core-aware lane planning), [`libs`] (math-library models +
//!   three real thread pools).
//! * **Platform substrate** — [`sim`], a discrete-event simulator of the
//!   paper's Skylake testbeds (cores, SMT/FMA contention, LLC, memory and
//!   UPI bandwidth) that produces the same per-core time breakdowns the
//!   authors measured with `perf`.
//! * **Deployment** — [`runtime`] (pluggable execution backends behind the
//!   `Backend`/`BackendFactory` traits: the PJRT client running
//!   AOT-compiled JAX/Pallas artifacts, and `SimBackend`, which serves the
//!   model zoo through the simulator with zero external artifacts),
//!   [`coordinator`] (request router + dynamic batcher + load generator),
//!   [`tracestore`] (serving trace capture, the columnar `.plt` store,
//!   and trace replay), and [`tuner`] (the paper's §8 guidelines +
//!   Intel/TensorFlow baselines + exhaustive search + the online
//!   re-tuner).
//!
//! [`bench_tables`] regenerates every figure and table of the paper's
//! evaluation.

pub mod api;
pub mod bench_tables;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod graph;
pub mod libs;
pub mod metrics;
pub mod models;
pub mod ops;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod trace;
pub mod tracestore;
pub mod tuner;
pub mod util;

pub use api::{
    model_catalog, ModelInfo, Plan, PlanEntry, PlanTier, ServeHandle, Session, SessionBuilder,
    Workload, WorkloadEntry,
};
pub use error::{PallasError, PallasResult};
