//! Engine fast-path data structures: a bucketed calendar queue for
//! completion events and a bitmask free-pool set.
//!
//! Both replace general-purpose collections (`BinaryHeap<Completion>`,
//! `Vec<usize>`) in the discrete-event loop while reproducing their
//! ordering semantics *bit-for-bit* — the property test in
//! `rust/tests/engine_fastpath.rs` holds the fast engine to the seed
//! path's exact reports, so these structures are not allowed to change
//! a single dispatch decision:
//!
//! * [`CalendarQueue`] pops the global minimum by `(time, node)` with
//!   `total_cmp` time ordering (NaN sorts after every finite time) —
//!   exactly the seed heap's `Completion` order;
//! * [`FreePools`] reproduces the seed `Vec` stack's LIFO pool pick:
//!   initial acquisitions come out `0, 1, 2, …`, and thereafter the
//!   most recently released pool is acquired first. Pool choice is
//!   observable (pool slices can differ in shape on odd splits), so
//!   this order is part of the engine's contract.

/// A pool finishing its current op at `time`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    /// Completion time (seconds). May be NaN if a cost model is poisoned;
    /// NaN events drain last instead of panicking the queue.
    pub time: f64,
    /// The pool that becomes free.
    pub pool: usize,
    /// The node that completed.
    pub node: usize,
}

/// Ascending event order: `(time, node)` with a total time order
/// (`total_cmp`), matching the seed `Completion` heap exactly.
fn event_cmp(a: &Event, b: &Event) -> std::cmp::Ordering {
    a.time.total_cmp(&b.time).then_with(|| a.node.cmp(&b.node))
}

/// Buckets per calendar "year". Power of two; the queue only ever holds
/// one in-flight op per pool (≤ logical cores), so buckets stay tiny.
const NBUCKETS: usize = 64;

/// Bucketed calendar queue over completion events.
///
/// Finite events inside the current year land in
/// `floor((t - year_start) / width)` buckets (unsorted — a bucket holds
/// a handful of events at most, so pop scans it for the min); events
/// beyond the year, and non-finite times, fall back to a sorted-insert
/// overflow list. When the in-year buckets drain, the year re-anchors
/// at the smallest overflow time and refills. The engine's pushes are
/// monotone (a completion is never scheduled before `now`), which keeps
/// the bucket cursor moving forward; a defensive cursor reset handles
/// any non-monotone push without losing ordering.
#[derive(Debug, Default)]
pub struct CalendarQueue {
    buckets: Vec<Vec<Event>>,
    /// Sorted *descending* by [`event_cmp`], so the minimum pops from
    /// the end. Holds beyond-year and non-finite events.
    overflow: Vec<Event>,
    /// Bucket time width (seconds); 0 until the first finite push seeds
    /// the year geometry.
    width: f64,
    year_start: f64,
    /// First bucket that can still hold the minimum.
    cur: usize,
    len: usize,
}

impl CalendarQueue {
    /// Empty queue (buckets allocate lazily on first use).
    pub fn new() -> Self {
        Self::default()
    }

    /// Remove all events but keep every allocation for reuse.
    pub fn clear(&mut self) {
        if self.buckets.len() != NBUCKETS {
            self.buckets = (0..NBUCKETS).map(|_| Vec::new()).collect();
        } else {
            for b in &mut self.buckets {
                b.clear();
            }
        }
        self.overflow.clear();
        self.width = 0.0;
        self.year_start = 0.0;
        self.cur = 0;
        self.len = 0;
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no events are pending.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Insert an event.
    pub fn push(&mut self, ev: Event) {
        if self.buckets.len() != NBUCKETS {
            self.clear();
        }
        self.len += 1;
        if !ev.time.is_finite() {
            self.sorted_insert(ev);
            return;
        }
        if self.width == 0.0 {
            // seed the year from the first finite completion: a quarter
            // of the year behind it, three quarters ahead — correctness
            // never depends on this choice, only bucket occupancy does
            self.width = (ev.time / (NBUCKETS as f64 / 4.0)).max(1e-12);
            self.year_start = 0.0;
            self.cur = 0;
        }
        let year_len = self.width * NBUCKETS as f64;
        if ev.time >= self.year_start + year_len {
            self.sorted_insert(ev);
            return;
        }
        // negative offsets saturate to bucket 0 on the float→usize cast
        let idx = (((ev.time - self.year_start) / self.width) as usize).min(NBUCKETS - 1);
        if idx < self.cur {
            self.cur = idx;
        }
        self.buckets[idx].push(ev);
    }

    /// Pop the minimum event by `(time, node)`; NaN-timed events last.
    pub fn pop(&mut self) -> Option<Event> {
        if self.len == 0 {
            return None;
        }
        loop {
            // the minimum lives in the first non-empty in-year bucket
            for i in self.cur..NBUCKETS {
                if self.buckets[i].is_empty() {
                    continue;
                }
                self.cur = i;
                let bucket = &mut self.buckets[i];
                let mut best = 0;
                for j in 1..bucket.len() {
                    if event_cmp(&bucket[j], &bucket[best]) == std::cmp::Ordering::Less {
                        best = j;
                    }
                }
                self.len -= 1;
                return Some(bucket.swap_remove(best));
            }
            // year exhausted: the minimum is the overflow tail
            let tail = *self.overflow.last().expect("len > 0 with empty buckets");
            if !tail.time.is_finite() {
                self.len -= 1;
                return self.overflow.pop();
            }
            // re-anchor the year at the smallest pending time and refill
            self.year_start = tail.time;
            self.cur = 0;
            let year_end = self.year_start + self.width * NBUCKETS as f64;
            while let Some(ev) = self.overflow.last().copied() {
                if !ev.time.is_finite() || ev.time >= year_end {
                    break;
                }
                self.overflow.pop();
                let idx =
                    (((ev.time - self.year_start) / self.width) as usize).min(NBUCKETS - 1);
                self.buckets[idx].push(ev);
            }
        }
    }

    /// Sorted-insert fallback: keep `overflow` descending so the
    /// minimum stays at the end.
    fn sorted_insert(&mut self, ev: Event) {
        let pos = self
            .overflow
            .partition_point(|e| event_cmp(e, &ev) == std::cmp::Ordering::Greater);
        self.overflow.insert(pos, ev);
    }
}

/// Free-pool set as a bitmask plus per-pool recency sequence numbers.
///
/// The bitmask answers "is any pool free" in O(words); the sequence
/// numbers reproduce the seed `Vec` stack's LIFO acquire order (pool
/// choice is observable whenever pool slices differ in shape, so the
/// order is part of the engine contract): the initial state hands out
/// pools in ascending index order, and afterwards the most recently
/// released pool wins.
#[derive(Debug, Default)]
pub struct FreePools {
    words: Vec<u64>,
    /// Recency stamp per pool; the free pool with the highest stamp is
    /// acquired next.
    seq: Vec<u64>,
    counter: u64,
    free: usize,
    pools: usize,
}

impl FreePools {
    /// All `pools` pools free, primed so the first `pools` acquisitions
    /// return `0, 1, …, pools - 1`.
    pub fn reset(&mut self, pools: usize) {
        self.pools = pools;
        let words = pools.div_ceil(64);
        self.words.clear();
        self.words.resize(words, 0);
        for p in 0..pools {
            self.words[p / 64] |= 1u64 << (p % 64);
        }
        self.seq.clear();
        self.seq.resize(pools, 0);
        for p in 0..pools {
            self.seq[p] = (pools - 1 - p) as u64;
        }
        self.counter = pools as u64;
        self.free = pools;
    }

    /// True when every pool is busy.
    pub fn is_empty(&self) -> bool {
        self.free == 0
    }

    /// Acquire the most recently released free pool (LIFO), or `None`.
    pub fn acquire(&mut self) -> Option<usize> {
        if self.free == 0 {
            return None;
        }
        let mut best = usize::MAX;
        for (w, &word) in self.words.iter().enumerate() {
            let mut bits = word;
            while bits != 0 {
                let p = w * 64 + bits.trailing_zeros() as usize;
                bits &= bits - 1;
                if best == usize::MAX || self.seq[p] > self.seq[best] {
                    best = p;
                }
            }
        }
        debug_assert!(best != usize::MAX, "free count > 0 with empty bitmask");
        let p = best;
        self.words[p / 64] &= !(1u64 << (p % 64));
        self.free -= 1;
        Some(p)
    }

    /// Release a pool back to the free set, stamping it most recent.
    pub fn release(&mut self, pool: usize) {
        debug_assert!(pool < self.pools);
        debug_assert!(self.words[pool / 64] & (1u64 << (pool % 64)) == 0, "double release");
        self.words[pool / 64] |= 1u64 << (pool % 64);
        self.seq[pool] = self.counter;
        self.counter += 1;
        self.free += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(time: f64, node: usize) -> Event {
        Event { time, pool: node, node }
    }

    #[test]
    fn calendar_pops_in_time_then_node_order() {
        let mut q = CalendarQueue::new();
        q.push(ev(3.0, 5));
        q.push(ev(1.0, 9));
        q.push(ev(1.0, 2));
        q.push(ev(2.0, 0));
        let order: Vec<usize> = std::iter::from_fn(|| q.pop()).map(|e| e.node).collect();
        assert_eq!(order, vec![2, 9, 0, 5]);
        assert!(q.is_empty());
    }

    #[test]
    fn calendar_matches_binary_heap_on_random_streams() {
        // mixed push/pop stream: the calendar queue must agree with a
        // reference sorted list at every step (times grow monotonically,
        // mirroring the engine's pushes, with large jumps to force year
        // re-anchoring and overflow inserts)
        let mut seed = 0x9E3779B97F4A7C15u64;
        let mut rand = move || {
            seed ^= seed << 13;
            seed ^= seed >> 7;
            seed ^= seed << 17;
            seed
        };
        let mut q = CalendarQueue::new();
        let mut reference: Vec<Event> = Vec::new();
        let mut now = 0.0f64;
        let mut node = 0usize;
        for step in 0..2000 {
            if rand() % 3 != 0 || reference.is_empty() {
                // occasionally jump far beyond the current year
                let jump = if rand() % 10 == 0 { 1000.0 } else { 1.0 };
                let dt = jump * (1.0 + (rand() % 100) as f64 / 10.0);
                let e = ev(now + dt, node);
                node += 1;
                q.push(e);
                reference.push(e);
            } else {
                reference.sort_by(|a, b| event_cmp(b, a));
                let want = reference.pop().unwrap();
                let got = q.pop().unwrap();
                assert_eq!(got, want, "step {step}");
                now = got.time;
            }
        }
        reference.sort_by(|a, b| event_cmp(b, a));
        while let Some(want) = reference.pop() {
            assert_eq!(q.pop().unwrap(), want);
        }
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn calendar_sorts_nan_after_finite() {
        let mut q = CalendarQueue::new();
        q.push(ev(f64::NAN, 0));
        q.push(ev(1.0, 1));
        q.push(ev(0.5, 2));
        assert_eq!(q.pop().unwrap().node, 2);
        assert_eq!(q.pop().unwrap().node, 1);
        assert!(q.pop().unwrap().time.is_nan());
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn calendar_clear_reuses_allocations() {
        let mut q = CalendarQueue::new();
        for i in 0..100 {
            q.push(ev(i as f64, i));
        }
        q.clear();
        assert!(q.is_empty());
        q.push(ev(7.0, 7));
        assert_eq!(q.pop().unwrap().node, 7);
    }

    #[test]
    fn free_pools_match_seed_stack_order() {
        // replay against the seed structure: Vec initialized
        // (0..pools).rev(), pop from the end, push on release
        let pools = 7;
        let mut fast = FreePools::default();
        fast.reset(pools);
        let mut seed: Vec<usize> = (0..pools).rev().collect();
        let mut seed_rng = 0xC0FFEEu64;
        let mut rand = move || {
            seed_rng ^= seed_rng << 13;
            seed_rng ^= seed_rng >> 7;
            seed_rng ^= seed_rng << 17;
            seed_rng
        };
        let mut held: Vec<usize> = Vec::new();
        for step in 0..500 {
            if rand() % 2 == 0 && !seed.is_empty() {
                let want = seed.pop();
                let got = fast.acquire();
                assert_eq!(got, want, "step {step}");
                held.push(got.unwrap());
            } else if !held.is_empty() {
                let p = held.swap_remove(rand() as usize % held.len());
                seed.push(p);
                fast.release(p);
            }
            assert_eq!(fast.is_empty(), seed.is_empty(), "step {step}");
        }
    }

    #[test]
    fn free_pools_initial_order_ascending() {
        let mut f = FreePools::default();
        f.reset(70); // spans two bitmask words
        let order: Vec<usize> = std::iter::from_fn(|| f.acquire()).collect();
        assert_eq!(order, (0..70).collect::<Vec<_>>());
        assert!(f.is_empty());
    }
}
