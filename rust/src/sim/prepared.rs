//! Prepared graphs + memoized simulation — the tuning-throughput layer.
//!
//! Every sweep in the stack (the exhaustive "global optimum" search of
//! Fig. 18, the §8-guideline robustness tests, the online re-tuner's
//! candidate scoring, and the sim backend's per-(kind, bucket) latency
//! tables) bottoms out in `sim::simulate`, and until this module each
//! call re-derived the same per-graph invariants and re-simulated
//! design points other tiers had already scored. Two pieces fix that:
//!
//! * [`PreparedGraph`] precomputes what every simulation of one graph
//!   shares — HEFT upward ranks, dispatch weights, the consumer CSR,
//!   per-node kernel-use flags — plus a structural fingerprint, so the
//!   engine's prepared entry point skips the per-call sweeps.
//! * [`SimCache`] memoizes whole [`SimReport`]s under a canonical
//!   fingerprint of (graph, platform, *effective* config).
//!   [`canonical_config`] maps can't-differ settings to one
//!   representative — any `sched_policy` collapses to `Topo` when only
//!   one pool exists (a single pool serialises every dispatch order),
//!   `parallelism` collapses on single-socket platforms (no socket to
//!   span), and `pin_threads` never reaches the cost model — so
//!   repeated `simulate` calls across tiers dedupe to a single run.
//! * **Delta-simulation**: a report miss whose *policy-erased* sibling
//!   was already simulated (the exhaustive lattice and online neighbor
//!   sets enumerate near-duplicate configs by construction) reuses the
//!   sibling family's [`PhaseTable`] — per-(pool shape, node) phase
//!   lists, which `sched_policy` provably never influences — and
//!   replays only the event loop. A sampled bit-identity guard
//!   revalidates the invariant on every reuse and rebuilds the table on
//!   any mismatch, so a cost-model change that breaks the invariant
//!   degrades to correct-but-slower instead of silently wrong.
//!
//! Determinism: the engine is a pure function of (graph, platform,
//! config), the cache always simulates the canonical representative,
//! and every entry is immutable once stored — so cached, uncached and
//! parallel sweeps return bit-identical reports (enforced by
//! `rust/tests/tuner_parallel.rs`).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::config::{CpuPlatform, FrameworkConfig, ParallelismMode, SchedPolicy};
use crate::error::PallasResult;
use crate::graph::{self, Graph};
use crate::models;
use crate::ops::{OpCost, OpKind};
use crate::sched::{partition_pools, ConsumerCsr, ReadyQueue};

use super::engine::{self, EngineScratch};
use super::opexec::{op_phases_into, Phase};
use super::{SimOptions, SimReport};

/// A graph with its per-simulation invariants precomputed: the tables
/// [`crate::sched::ReadyQueue::with_policy`] would otherwise re-derive on
/// every `simulate` call, shared behind `Arc`s instead.
#[derive(Debug)]
pub struct PreparedGraph {
    graph: Graph,
    /// Per-node dependency counts (the ready queue's initial state).
    remaining0: Vec<usize>,
    cons: Arc<ConsumerCsr>,
    /// HEFT upward ranks (critical-path-first dispatch priorities).
    ranks: Arc<Vec<f64>>,
    /// Per-op dispatch weights (costliest-first priorities).
    weights: Arc<Vec<f64>>,
    /// Per-node `OpKind::uses_library_kernel` flags.
    kernel_use: Vec<bool>,
    fingerprint: u64,
    /// Reusable engine buffers, checked out per simulation so sweep
    /// workers' steady-state loops are allocation-free.
    scratch: Mutex<Vec<EngineScratch>>,
}

/// Upper bound on pooled [`EngineScratch`] instances per graph — enough
/// for any sweep executor's worker count; beyond it, returned scratch is
/// simply dropped.
const SCRATCH_POOL_CAP: usize = 16;

impl PreparedGraph {
    /// Prepare a borrowed graph (clones it; use [`Self::from_owned`] when
    /// the caller can hand over ownership).
    pub fn new(graph: &Graph) -> Self {
        Self::from_owned(graph.clone())
    }

    /// Prepare an owned graph.
    pub fn from_owned(graph: Graph) -> Self {
        let ranks = Arc::new(graph::upward_ranks(&graph));
        let weights =
            Arc::new(graph.nodes.iter().map(|n| graph::dispatch_weight(&n.cost)).collect());
        let kernel_use = graph.nodes.iter().map(|n| n.kind.uses_library_kernel()).collect();
        let remaining0 = graph.nodes.iter().map(|n| n.deps.len()).collect();
        let cons = Arc::new(ConsumerCsr::build(&graph));
        let fingerprint = graph_fingerprint(&graph);
        PreparedGraph {
            graph,
            remaining0,
            cons,
            ranks,
            weights,
            kernel_use,
            fingerprint,
            scratch: Mutex::new(Vec::new()),
        }
    }

    /// Check an engine scratch out of the pool (fresh if empty).
    pub(crate) fn take_scratch(&self) -> EngineScratch {
        self.scratch.lock().unwrap().pop().unwrap_or_default()
    }

    /// Return an engine scratch to the pool for reuse.
    pub(crate) fn put_scratch(&self, s: EngineScratch) {
        let mut pool = self.scratch.lock().unwrap();
        if pool.len() < SCRATCH_POOL_CAP {
            pool.push(s);
        }
    }

    /// The underlying graph.
    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    /// Structural fingerprint (node kinds, costs and edges; names are
    /// ignored — they never reach the cost model).
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// Precomputed per-node library-kernel flags.
    pub fn kernel_use(&self) -> &[bool] {
        &self.kernel_use
    }

    /// A ready queue for one simulated execution under `policy`, built
    /// from the precomputed tables (bit-identical dispatch order to
    /// `ReadyQueue::with_policy` on the same graph).
    pub fn ready_queue(&self, policy: SchedPolicy) -> ReadyQueue {
        let priority = match policy {
            SchedPolicy::Topo => None,
            SchedPolicy::CriticalPathFirst => Some(Arc::clone(&self.ranks)),
            SchedPolicy::CostlyFirst => Some(Arc::clone(&self.weights)),
        };
        ReadyQueue::from_parts(self.remaining0.clone(), Arc::clone(&self.cons), priority)
    }
}

/// The canonical representative of a config's simulate-equivalence
/// class. Two configs mapping to the same canonical form produce the
/// same simulation outcome (for the 1-pool policy collapse: the same
/// multiset of serial op times, so equal up to floating-point
/// summation order — a ≤1-ulp effect; the other collapses are exactly
/// bit-identical), so the cache keys on it. Consequence: compare
/// cached scores with cached scores — mixing a cached score of a
/// *non-canonical* 1-pool config with a direct `simulate` of it may
/// differ in the last ulp. Every subsystem tier routes consistently
/// through the cache, and the exhaustive lattice and §8 guideline only
/// emit canonical configs, where hit, miss and direct simulation agree
/// bit-for-bit:
///
/// * one *effective* pool (`inter_op_pools == 1`, or a 1-core machine)
///   serialises all dispatch, so every `sched_policy` collapses to
///   `Topo` — the same pruning the exhaustive lattice applies;
/// * a single-socket platform has no socket boundary to span, so
///   `parallelism` collapses to `DataParallel`;
/// * `pin_threads` is config-file metadata the cost model never reads.
pub fn canonical_config(platform: &CpuPlatform, cfg: &FrameworkConfig) -> FrameworkConfig {
    let mut c = cfg.clone();
    if c.inter_op_pools == 1 || platform.physical_cores() == 1 {
        c.sched_policy = SchedPolicy::Topo;
    }
    if platform.sockets == 1 {
        c.parallelism = ParallelismMode::DataParallel;
    }
    c.pin_threads = true;
    c
}

/// Structural fingerprint of a platform: every field the simulator's
/// cost model reads, and *not* the display name — so two core slices
/// with the same shape (e.g. `large[0+8]` and `large[8+8]`) share cache
/// entries and serving lane tables.
pub fn platform_fingerprint(p: &CpuPlatform) -> u64 {
    let mut h = Fnv::new();
    h.u64(p.sockets as u64);
    h.u64(p.cores_per_socket as u64);
    h.u64(p.smt as u64);
    h.f64(p.freq_ghz);
    h.f64(p.peak_gflops_per_core);
    h.f64(p.llc_mib_per_socket);
    h.f64(p.mem_bw_gbps);
    h.f64(p.upi_gbps);
    h.finish()
}

/// Precomputed per-(pool shape, node) phase lists for one *config
/// family* — the set of configs differing only in `sched_policy`.
///
/// The delta-simulation invariant: `op_phases` reads every knob a pool's
/// execution depends on (pool count and shape, kernel/intra thread
/// counts, operator implementation, math/pool libraries, parallelism
/// mode) but **never** `sched_policy` — the policy only permutes
/// dispatch order. So all policy siblings of one config share phase
/// lists exactly, and only the first op whose phases change between two
/// lattice neighbors needs recomputing — for a policy step, that is no
/// op at all: the whole cost model is skipped and just the event loop
/// replays. Pool *shapes* are family-invariant too (`partition_pools`
/// never reads the policy), so the table is keyed by distinct pool
/// shape class rather than pool index.
///
/// Entries are produced by the same [`op_phases_into`] the engine would
/// call, so table-driven simulation is bit-identical to direct
/// simulation; [`Self::verify_sample`] re-checks that on every reuse.
#[derive(Debug)]
pub(crate) struct PhaseTable {
    /// Pool index → shape class index.
    classes: Vec<usize>,
    /// One representative pool context per shape class (guard rebuilds).
    class_ctxs: Vec<super::opexec::PoolCtx>,
    /// Flat phase arena; `spans[class * nodes + node]` addresses into it.
    arena: Vec<Phase>,
    /// Per-(class, node) `(start, len)` into `arena`.
    spans: Vec<(u32, u32)>,
    /// Per-(class, node) total duration (`opexec::total` of the list).
    totals: Vec<f64>,
    nodes: usize,
    /// Admissible analytic latency lower bound for every member of this
    /// config family (policy cannot change per-op durations, so one
    /// bound covers all siblings) — see [`Self::bound_s`].
    bound_s: f64,
}

impl PhaseTable {
    /// Build the family's phase table under any member config (phases
    /// are family-invariant, so the member choice cannot matter).
    pub(crate) fn build(
        prep: &PreparedGraph,
        platform: &CpuPlatform,
        cfg: &FrameworkConfig,
    ) -> PhaseTable {
        let assignments = partition_pools(platform, cfg);
        let ctxs = engine::pool_contexts(&assignments, cfg);
        // dedupe pools into shape classes (uneven splits give ≤2 shapes)
        let mut classes = Vec::with_capacity(ctxs.len());
        let mut class_keys: Vec<(usize, bool, usize)> = Vec::new();
        let mut class_ctxs = Vec::new();
        for ctx in &ctxs {
            let key = (ctx.phys_cores, ctx.spans_sockets, ctx.sockets_used);
            let class = match class_keys.iter().position(|k| *k == key) {
                Some(i) => i,
                None => {
                    class_keys.push(key);
                    class_ctxs.push(ctx.clone());
                    class_keys.len() - 1
                }
            };
            classes.push(class);
        }
        let nodes = prep.graph.len();
        let mut arena = Vec::with_capacity(class_ctxs.len() * nodes * 4);
        let mut spans = Vec::with_capacity(class_ctxs.len() * nodes);
        let mut totals = Vec::with_capacity(class_ctxs.len() * nodes);
        let mut buf: Vec<Phase> = Vec::new();
        for ctx in &class_ctxs {
            for node in &prep.graph.nodes {
                op_phases_into(node, cfg, platform, ctx, &mut buf);
                let start = arena.len() as u32;
                arena.extend_from_slice(&buf);
                spans.push((start, buf.len() as u32));
                totals.push(super::opexec::total(&buf));
            }
        }
        let bound_s = compute_bound(prep, &classes, &totals, nodes);
        PhaseTable { classes, class_ctxs, arena, spans, totals, nodes, bound_s }
    }

    /// Admissible analytic lower bound on the simulated latency of any
    /// config in this family: `max(critical-path time, total work /
    /// pool count)`, both built from per-node *minimum-over-classes*
    /// durations so no pool assignment the engine could pick beats it.
    ///
    /// Admissibility argument (`bound ≤ exact`, bit-level):
    ///
    /// * Critical path. The engine dispatches node `n` at
    ///   `start = now.max(pool_free_at)` with `now` at least the
    ///   completion time of every dependency (events pop in time
    ///   order), and completes it at the f64 sum `start + dur`. The
    ///   sweep here computes `cp[n] = max_dep cp + min_class dur` with
    ///   the *same* f64 addition; since `fl(a + b)` is monotone in both
    ///   arguments, `cp[n] ≤ completion[n]` inductively, so
    ///   `max cp ≤ latency` holds in the engine's own arithmetic.
    /// * Work / capacity. Every pool's busy time accumulates the same
    ///   per-node durations the totals arena holds, and the engine's
    ///   latency is at least the busiest pool's total, which is at
    ///   least (sum of all durations) / pools in exact arithmetic. The
    ///   f64 sum taken here may drift *above* the exact value by a few
    ///   ulps (summation order), so the quotient is deflated by 1e-9 —
    ///   about six orders of magnitude more than the worst-case
    ///   accumulated rounding at lattice-relevant graph sizes.
    ///
    /// `tuner::bound` asserts `bound ≤ exact` on every simulated point
    /// (the `bound_unsound` counter) so a cost-model change that breaks
    /// either argument is caught, not silently mis-pruned.
    pub(crate) fn bound_s(&self) -> f64 {
        self.bound_s
    }

    /// Shape class of a pool index.
    pub(crate) fn class_of(&self, pool: usize) -> usize {
        self.classes[pool]
    }

    /// The phase list for (shape class, node).
    pub(crate) fn phases(&self, class: usize, node: usize) -> &[Phase] {
        let (start, len) = self.spans[class * self.nodes + node];
        &self.arena[start as usize..(start + len) as usize]
    }

    /// Total duration for (shape class, node).
    pub(crate) fn total(&self, class: usize, node: usize) -> f64 {
        self.totals[class * self.nodes + node]
    }

    /// The bit-identity fallback guard: recompute a deterministic sample
    /// of nodes (≤ 8, spread across the graph) under `cfg` and compare
    /// against the stored lists bit-for-bit (category, span, and
    /// `dur.to_bits()`). A `false` means the policy-invariance
    /// assumption no longer holds for this family and the caller must
    /// rebuild instead of reusing.
    pub(crate) fn verify_sample(
        &self,
        prep: &PreparedGraph,
        platform: &CpuPlatform,
        cfg: &FrameworkConfig,
    ) -> bool {
        // the pool layout itself must be unchanged
        let assignments = partition_pools(platform, cfg);
        let ctxs = engine::pool_contexts(&assignments, cfg);
        if ctxs.len() != self.classes.len() {
            return false;
        }
        for (ctx, &class) in ctxs.iter().zip(&self.classes) {
            let want = &self.class_ctxs[class];
            if ctx.phys_cores != want.phys_cores
                || ctx.spans_sockets != want.spans_sockets
                || ctx.sockets_used != want.sockets_used
            {
                return false;
            }
        }
        let n = self.nodes;
        if n == 0 {
            return true;
        }
        let samples = n.min(8);
        let mut buf: Vec<Phase> = Vec::new();
        for s in 0..samples {
            let node = s * n / samples;
            for (class, ctx) in self.class_ctxs.iter().enumerate() {
                op_phases_into(&prep.graph.nodes[node], cfg, platform, ctx, &mut buf);
                let stored = self.phases(class, node);
                if buf.len() != stored.len() {
                    return false;
                }
                let same = buf.iter().zip(stored).all(|(a, b)| {
                    a.cat == b.cat && a.span == b.span && a.dur.to_bits() == b.dur.to_bits()
                });
                if !same {
                    return false;
                }
            }
        }
        true
    }
}

/// The `max(critical path, work / pools)` lower bound stored on every
/// [`PhaseTable`] — see [`PhaseTable::bound_s`] for the admissibility
/// argument. `classes` maps pool index → shape class, so its length is
/// the effective parallel capacity (pool count); `totals` is the
/// per-(class, node) duration arena.
fn compute_bound(prep: &PreparedGraph, classes: &[usize], totals: &[f64], nodes: usize) -> f64 {
    if nodes == 0 || classes.is_empty() {
        return 0.0;
    }
    let n_classes = totals.len() / nodes;
    // per-node duration no pool-shape assignment can beat
    let mut min_dur = totals[..nodes].to_vec();
    for class in 1..n_classes {
        for (node, slot) in min_dur.iter_mut().enumerate() {
            let d = totals[class * nodes + node];
            if d < *slot {
                *slot = d;
            }
        }
    }
    // forward critical-path sweep — node ids are topologically ordered
    // (every dependency has a smaller id), same invariant
    // `graph::upward_ranks` relies on in reverse
    let mut cp = vec![0.0f64; nodes];
    let mut cp_max = 0.0f64;
    let mut work = 0.0f64;
    for (node, g) in prep.graph.nodes.iter().enumerate() {
        let mut ready = 0.0f64;
        for d in &g.deps {
            ready = ready.max(cp[d.0]);
        }
        cp[node] = ready + min_dur[node];
        cp_max = cp_max.max(cp[node]);
        work += min_dur[node];
    }
    let pools = classes.len() as f64;
    cp_max.max(work / pools * (1.0 - 1e-9))
}

/// Memoized simulation reports + prepared zoo graphs, shared across
/// threads (a sweep executor's workers all consult one cache) and across
/// tiers (exhaustive search, guideline scoring, online re-tuning and
/// backend table construction dedupe against each other).
#[derive(Debug)]
pub struct SimCache {
    reports: Mutex<HashMap<(u64, u64, FrameworkConfig), Arc<SimReport>>>,
    prepared: Mutex<HashMap<(String, usize), Arc<PreparedGraph>>>,
    /// Policy-erased config family → shared phase table (delta-sim).
    families: Mutex<HashMap<(u64, u64, FrameworkConfig), Arc<PhaseTable>>>,
    hits: AtomicU64,
    misses: AtomicU64,
    delta_hits: AtomicU64,
    delta_fallbacks: AtomicU64,
    capacity: usize,
}

/// Default report capacity: a full `large.2` exhaustive lattice is
/// ~1.5k points, so this holds dozens of model sweeps before recycling.
const DEFAULT_CAPACITY: usize = 1 << 15;

impl Default for SimCache {
    fn default() -> Self {
        Self::with_capacity(DEFAULT_CAPACITY)
    }
}

impl SimCache {
    /// Cache with the default capacity.
    pub fn new() -> Self {
        Self::default()
    }

    /// Cache holding at most `capacity` reports; reaching the bound
    /// recycles the whole generation (simple, deterministic for any
    /// insertion order, and sweeps re-warm in one pass).
    pub fn with_capacity(capacity: usize) -> Self {
        SimCache {
            reports: Mutex::new(HashMap::new()),
            prepared: Mutex::new(HashMap::new()),
            families: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            delta_hits: AtomicU64::new(0),
            delta_fallbacks: AtomicU64::new(0),
            capacity: capacity.max(1),
        }
    }

    /// The simulation report for (graph, platform, config), memoized
    /// under the canonical fingerprint. On a miss the *canonical*
    /// representative is simulated via the prepared fast path, so hit
    /// and miss return bit-identical reports.
    ///
    /// Misses run through delta-simulation: the policy-erased family's
    /// [`PhaseTable`] is built on first contact and reused (after the
    /// sampled bit-identity guard) by every policy sibling, so only the
    /// event loop replays. Because full misses simulate through the
    /// very same table, hit / delta-hit / full-miss all return
    /// bit-identical reports regardless of arrival order or cache
    /// state.
    ///
    /// The lock is not held while simulating, so concurrent workers
    /// missing on the *same* key may each simulate it — a benign,
    /// jobs-bounded duplication (entries are immutable and identical;
    /// the last insert wins with the same bits) accepted over an
    /// in-flight-wait protocol.
    pub fn report(
        &self,
        prep: &PreparedGraph,
        platform: &CpuPlatform,
        cfg: &FrameworkConfig,
    ) -> PallasResult<Arc<SimReport>> {
        let canonical = canonical_config(platform, cfg);
        let key = (prep.fingerprint(), platform_fingerprint(platform), canonical);
        if let Some(r) = self.reports.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(r));
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let report = Arc::new(self.simulate_canonical(prep, platform, &key.2)?);
        let mut guard = self.reports.lock().unwrap();
        if guard.len() >= self.capacity {
            guard.clear();
        }
        guard.insert(key, Arc::clone(&report));
        Ok(report)
    }

    /// The policy-erased family's [`PhaseTable`] for a *canonical*
    /// config, built on first contact and revalidated by the sampled
    /// bit-identity guard on every reuse. Shared by the simulation path
    /// below and by `tuner::bound`, which reads the table's analytic
    /// lower bound without running the engine — so a pruned sweep's
    /// bound pass pre-warms exactly the tables its simulated survivors
    /// replay through.
    pub(crate) fn family_table(
        &self,
        prep: &PreparedGraph,
        platform: &CpuPlatform,
        canonical: &FrameworkConfig,
    ) -> Arc<PhaseTable> {
        let mut family = canonical.clone();
        family.sched_policy = SchedPolicy::Topo;
        let fkey = (prep.fingerprint(), platform_fingerprint(platform), family);
        let existing = self.families.lock().unwrap().get(&fkey).map(Arc::clone);
        match existing {
            Some(t) if t.verify_sample(prep, platform, canonical) => {
                self.delta_hits.fetch_add(1, Ordering::Relaxed);
                t
            }
            stale => {
                if stale.is_some() {
                    // guard tripped: the invariance assumption failed, so
                    // pay the full rebuild rather than reuse wrong phases
                    self.delta_fallbacks.fetch_add(1, Ordering::Relaxed);
                }
                let t = Arc::new(PhaseTable::build(prep, platform, canonical));
                let mut guard = self.families.lock().unwrap();
                if guard.len() >= self.capacity {
                    guard.clear();
                }
                guard.insert(fkey, Arc::clone(&t));
                t
            }
        }
    }

    /// Simulate a canonical config through its family's phase table
    /// (building or rebuilding the table as needed — see [`PhaseTable`]).
    fn simulate_canonical(
        &self,
        prep: &PreparedGraph,
        platform: &CpuPlatform,
        canonical: &FrameworkConfig,
    ) -> PallasResult<SimReport> {
        let table = self.family_table(prep, platform, canonical);
        engine::simulate_prepared_with_table(
            prep,
            platform,
            canonical,
            &SimOptions::default(),
            &table,
        )
    }

    /// Memoized batch latency (the quantity every sweep ranks on).
    pub fn latency(
        &self,
        prep: &PreparedGraph,
        platform: &CpuPlatform,
        cfg: &FrameworkConfig,
    ) -> PallasResult<f64> {
        Ok(self.report(prep, platform, cfg)?.latency_s)
    }

    /// The prepared graph for a model-zoo (kind, batch) pair, built once
    /// and shared (`None` for unknown models).
    pub fn prepared(&self, kind: &str, batch: usize) -> Option<Arc<PreparedGraph>> {
        let key = (kind.to_string(), batch);
        if let Some(p) = self.prepared.lock().unwrap().get(&key) {
            return Some(Arc::clone(p));
        }
        let prep = Arc::new(PreparedGraph::from_owned(models::build(kind, batch)?));
        let mut guard = self.prepared.lock().unwrap();
        if guard.len() >= self.capacity {
            guard.clear();
        }
        guard.insert(key, Arc::clone(&prep));
        Some(prep)
    }

    /// Cache hits so far (report lookups answered without simulating).
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Cache misses so far (simulations actually run).
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Misses that reused a policy-sibling's phase table (delta-sim) —
    /// the cost model was skipped and only the event loop replayed.
    pub fn delta_hits(&self) -> u64 {
        self.delta_hits.load(Ordering::Relaxed)
    }

    /// Times the bit-identity guard rejected a cached phase table and
    /// forced a full rebuild (0 unless the policy-invariance assumption
    /// is violated by a cost-model change).
    pub fn delta_fallbacks(&self) -> u64 {
        self.delta_fallbacks.load(Ordering::Relaxed)
    }

    /// Number of distinct reports currently held.
    pub fn entries(&self) -> usize {
        self.reports.lock().unwrap().len()
    }

    /// Drop every memoized report, phase table and prepared graph
    /// (stats are kept).
    pub fn clear(&self) {
        self.reports.lock().unwrap().clear();
        self.prepared.lock().unwrap().clear();
        self.families.lock().unwrap().clear();
    }
}

/// FNV-1a 64-bit — tiny, deterministic, dependency-free. Collisions are
/// astronomically unlikely across the handful of graphs/platforms one
/// process sweeps, and a collision only costs a wrong memo hit in a
/// simulation (never unsafety).
struct Fnv(u64);

impl Fnv {
    fn new() -> Self {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    fn byte(&mut self, b: u8) {
        self.0 ^= b as u64;
        self.0 = self.0.wrapping_mul(0x100_0000_01b3);
    }

    fn u64(&mut self, v: u64) {
        for b in v.to_le_bytes() {
            self.byte(b);
        }
    }

    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    fn finish(&self) -> u64 {
        self.0
    }
}

fn hash_kind(h: &mut Fnv, kind: &OpKind) {
    match *kind {
        OpKind::MatMul { m, k, n } => {
            h.byte(1);
            h.u64(m as u64);
            h.u64(k as u64);
            h.u64(n as u64);
        }
        OpKind::Conv { batch, out_h, out_w, in_c, out_c, k_h, k_w } => {
            h.byte(2);
            for v in [batch, out_h, out_w, in_c, out_c, k_h, k_w] {
                h.u64(v as u64);
            }
        }
        OpKind::Embedding { vocab, dim, rows } => {
            h.byte(3);
            h.u64(vocab as u64);
            h.u64(dim as u64);
            h.u64(rows as u64);
        }
        OpKind::Elementwise { elems, .. } => {
            h.byte(4);
            h.u64(elems as u64);
        }
        OpKind::DataMovement { bytes, .. } => {
            h.byte(5);
            h.u64(bytes as u64);
        }
        OpKind::Pool { elems } => {
            h.byte(6);
            h.u64(elems as u64);
        }
        OpKind::Softmax { rows, cols } => {
            h.byte(7);
            h.u64(rows as u64);
            h.u64(cols as u64);
        }
        OpKind::Gradient { fwd_flops, fwd_bytes } => {
            h.byte(8);
            h.f64(fwd_flops);
            h.f64(fwd_bytes);
        }
        OpKind::WeightSum { params } => {
            h.byte(9);
            h.u64(params as u64);
        }
    }
}

fn hash_cost(h: &mut Fnv, c: &OpCost) {
    h.f64(c.flops);
    h.f64(c.input_bytes);
    h.f64(c.output_bytes);
    h.f64(c.prep_bytes);
    h.f64(c.lib_prep_bytes);
}

/// Structural fingerprint of a graph without preparing it — what
/// [`PreparedGraph::fingerprint`] returns, minus the rank/CSR/weight
/// precomputation. Plan artifacts use this on their provenance path.
pub fn graph_structure_fingerprint(g: &Graph) -> u64 {
    graph_fingerprint(g)
}

/// Fold one `u64` into a running FNV-1a fingerprint. Shared with the
/// plan artifact's provenance hash so the hashing constants live in
/// exactly one place (drift would silently invalidate stored plans).
pub fn fingerprint_fold(h: u64, v: u64) -> u64 {
    let mut f = Fnv(h);
    f.u64(v);
    f.finish()
}

/// Hash everything about a graph the simulator can observe: node count,
/// per-node kind parameters, cost descriptors and dependency edges.
fn graph_fingerprint(g: &Graph) -> u64 {
    let mut h = Fnv::new();
    h.u64(g.batch as u64);
    h.u64(g.nodes.len() as u64);
    for node in &g.nodes {
        hash_kind(&mut h, &node.kind);
        hash_cost(&mut h, &node.cost);
        h.u64(node.deps.len() as u64);
        for d in &node.deps {
            h.u64(d.0 as u64);
        }
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim;

    #[test]
    fn canonical_collapses_policy_at_one_pool() {
        let p = CpuPlatform::large();
        let mut cfg = FrameworkConfig::tuned_default();
        cfg.sched_policy = SchedPolicy::CostlyFirst; // pools = 1
        assert_eq!(canonical_config(&p, &cfg).sched_policy, SchedPolicy::Topo);
        cfg.inter_op_pools = 2;
        assert_eq!(canonical_config(&p, &cfg).sched_policy, SchedPolicy::CostlyFirst);
    }

    #[test]
    fn canonical_collapses_parallelism_on_one_socket() {
        let mut cfg = FrameworkConfig::tuned_default();
        cfg.inter_op_pools = 4;
        cfg.parallelism = ParallelismMode::ModelParallel;
        let one = canonical_config(&CpuPlatform::large(), &cfg);
        assert_eq!(one.parallelism, ParallelismMode::DataParallel);
        let two = canonical_config(&CpuPlatform::large2(), &cfg);
        assert_eq!(two.parallelism, ParallelismMode::ModelParallel);
    }

    #[test]
    fn platform_fingerprint_ignores_name_only() {
        let l = CpuPlatform::large();
        let fp = platform_fingerprint;
        // same shape, different first core ⇒ same fingerprint
        assert_eq!(fp(&l.restrict(0, 8)), fp(&l.restrict(8, 8)));
        // different shape ⇒ different fingerprint
        assert_ne!(fp(&l.restrict(0, 8)), fp(&l.restrict(0, 12)));
        assert_ne!(fp(&l), fp(&CpuPlatform::large2()));
    }

    #[test]
    fn graph_fingerprints_distinguish_models() {
        let a = PreparedGraph::new(&models::build("wide_deep", 8).unwrap());
        let b = PreparedGraph::new(&models::build("wide_deep", 16).unwrap());
        let c = PreparedGraph::new(&models::build("ncf", 8).unwrap());
        assert_ne!(a.fingerprint(), b.fingerprint());
        assert_ne!(a.fingerprint(), c.fingerprint());
        let a2 = PreparedGraph::new(&models::build("wide_deep", 8).unwrap());
        assert_eq!(a.fingerprint(), a2.fingerprint());
    }

    #[test]
    fn cache_dedupes_equivalent_configs() {
        // two policies at one pool are the same design point: one miss,
        // then hits — and the same report bits either way
        let cache = SimCache::new();
        let prep = cache.prepared("wide_deep", 8).unwrap();
        let p = CpuPlatform::large();
        let mut cfg = FrameworkConfig::tuned_default();
        cfg.mkl_threads = 8;
        cfg.sched_policy = SchedPolicy::CostlyFirst;
        let a = cache.latency(&prep, &p, &cfg).unwrap();
        cfg.sched_policy = SchedPolicy::CriticalPathFirst;
        let b = cache.latency(&prep, &p, &cfg).unwrap();
        assert_eq!(a.to_bits(), b.to_bits());
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 1);
    }

    #[test]
    fn policy_siblings_share_phase_tables() {
        // three policies at >1 pool are three distinct design points but
        // one config family: one table build, then two delta hits — and
        // every sibling's report is bit-identical to direct simulation
        let cache = SimCache::new();
        let prep = cache.prepared("inception_v1", 16).unwrap();
        let p = CpuPlatform::large();
        let mut cfg = FrameworkConfig::tuned_default();
        cfg.inter_op_pools = 3;
        cfg.mkl_threads = 8;
        for policy in SchedPolicy::ALL {
            cfg.sched_policy = policy;
            let cached = cache.report(&prep, &p, &cfg).unwrap();
            let direct = sim::simulate(prep.graph(), &p, &cfg).unwrap();
            assert_eq!(cached.latency_s.to_bits(), direct.latency_s.to_bits(), "{policy:?}");
        }
        assert_eq!(cache.misses(), 3);
        assert_eq!(cache.delta_hits(), 2);
        assert_eq!(cache.delta_fallbacks(), 0);
    }

    #[test]
    fn phase_table_guard_accepts_family_members() {
        let cache = SimCache::new();
        let prep = cache.prepared("resnet50", 16).unwrap();
        let p = CpuPlatform::large2();
        let mut cfg = FrameworkConfig::tuned_default();
        cfg.inter_op_pools = 4;
        cfg.mkl_threads = 12;
        let table = PhaseTable::build(&prep, &p, &canonical_config(&p, &cfg));
        for policy in SchedPolicy::ALL {
            cfg.sched_policy = policy;
            assert!(table.verify_sample(&prep, &p, &canonical_config(&p, &cfg)), "{policy:?}");
        }
        // a knob that changes phases must be rejected (it is a different
        // family; the guard is the last line of defence if keying breaks)
        cfg.mkl_threads = 6;
        assert!(!table.verify_sample(&prep, &p, &canonical_config(&p, &cfg)));
    }

    #[test]
    fn phase_table_bound_is_admissible_and_positive() {
        let cache = SimCache::new();
        for p in [CpuPlatform::small(), CpuPlatform::large2()] {
            for kind in ["wide_deep", "inception_v1", "transformer"] {
                let prep = cache.prepared(kind, 16).unwrap();
                for pools in [1usize, 3] {
                    let mut cfg = FrameworkConfig::tuned_default();
                    cfg.inter_op_pools = pools;
                    cfg.mkl_threads = 4;
                    let canonical = canonical_config(&p, &cfg);
                    let table = PhaseTable::build(&prep, &p, &canonical);
                    let exact = cache.latency(&prep, &p, &cfg).unwrap();
                    assert!(table.bound_s() > 0.0, "{kind} pools={pools}");
                    assert!(
                        table.bound_s() <= exact,
                        "{kind} pools={pools}: bound {} > exact {}",
                        table.bound_s(),
                        exact
                    );
                }
            }
        }
    }

    #[test]
    fn cached_report_matches_direct_simulation() {
        let cache = SimCache::new();
        let prep = cache.prepared("ncf", 16).unwrap();
        let p = CpuPlatform::large2();
        let mut cfg = FrameworkConfig::tuned_default();
        cfg.inter_op_pools = 4;
        cfg.mkl_threads = 12;
        cfg.intra_op_threads = 12;
        cfg.sched_policy = SchedPolicy::CriticalPathFirst;
        let direct = sim::simulate(prep.graph(), &p, &cfg).unwrap();
        let cached = cache.report(&prep, &p, &cfg).unwrap();
        assert_eq!(direct.latency_s.to_bits(), cached.latency_s.to_bits());
        assert_eq!(direct.upi_bytes.to_bits(), cached.upi_bytes.to_bits());
        assert_eq!(direct.gflops.to_bits(), cached.gflops.to_bits());
    }

    #[test]
    fn capacity_bound_recycles() {
        let cache = SimCache::with_capacity(2);
        let prep = cache.prepared("wide_deep", 8).unwrap();
        let p = CpuPlatform::small();
        for pools in 1..=3usize {
            let mut cfg = FrameworkConfig::tuned_default();
            cfg.inter_op_pools = pools;
            cache.latency(&prep, &p, &cfg).unwrap();
        }
        assert!(cache.entries() <= 2, "entries={}", cache.entries());
        assert_eq!(cache.misses(), 3);
    }

    #[test]
    fn unknown_zoo_model_is_none() {
        assert!(SimCache::new().prepared("bert", 8).is_none());
    }
}
