//! Platform simulator — the substrate standing in for the paper's Skylake
//! testbeds (DESIGN.md §Substitutions).
//!
//! A discrete-event engine executes computational graphs over inter-op
//! pools of cores, modelling FMA sharing between hyperthreads, serial
//! framework/library prep terms, thread-pool dispatch overheads, DRAM
//! rooflines and the UPI link. It emits end-to-end latency plus the same
//! per-core breakdowns/traces the authors collected with `perf`.

pub mod breakdown;
pub mod constants;
pub mod engine;
pub mod memory;
pub mod opexec;

pub use breakdown::{Breakdown, Category, Segment};
pub use engine::{simulate, simulate_opts, SimOptions, SimReport};
