//! Platform simulator — the substrate standing in for the paper's Skylake
//! testbeds (DESIGN.md §Substitutions).
//!
//! A discrete-event engine executes computational graphs over inter-op
//! pools of cores, modelling FMA sharing between hyperthreads, serial
//! framework/library prep terms, thread-pool dispatch overheads, DRAM
//! rooflines and the UPI link. It emits end-to-end latency plus the same
//! per-core breakdowns/traces the authors collected with `perf`.
//!
//! [`prepared`] is the tuning-throughput layer on top: [`PreparedGraph`]
//! precomputes the per-node invariants every simulation re-derives
//! (upward ranks, dispatch weights, consumer CSR, kernel-use flags), and
//! [`SimCache`] memoizes whole reports under a canonical fingerprint of
//! (graph, platform, effective config) so repeated sweeps across the
//! exhaustive/guideline/online/backend tiers dedupe to a single run.
//!
//! The engine itself runs a fast path — bucketed calendar event queue
//! ([`events`]), free-pool bitmask, scratch-owned buffers, and
//! delta-simulation through cached per-family phase tables — held
//! bit-identical to the seed heap engine ([`engine::simulate_reference`])
//! by `rust/tests/engine_fastpath.rs` (DESIGN.md §Engine fast path).

pub mod breakdown;
pub mod constants;
pub mod engine;
pub mod events;
pub mod memory;
pub mod opexec;
pub mod prepared;

pub use breakdown::{Breakdown, Category, Segment};
pub use engine::{
    simulate, simulate_opts, simulate_prepared, simulate_reference, SimOptions, SimReport,
};
pub use prepared::{
    canonical_config, fingerprint_fold, graph_structure_fingerprint, platform_fingerprint,
    PreparedGraph, SimCache,
};
