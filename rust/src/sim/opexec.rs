//! Per-operator execution model: turns an op's cost descriptor plus the
//! framework/platform configuration into a sequence of timed phases.
//!
//! This encodes the paper's §5 findings:
//!
//! * framework data prep is an Amdahl serial term (O(n) for MatMul, the
//!   im2col fraction for Conv) unless `MatMul2`-style intra-op threads
//!   spread it (§5.2);
//! * library kernels have their own serial packing term (Fig. 10);
//! * kernel threads beyond the pool's physical cores add no FLOPs (the two
//!   hyperthreads share FMA units, §4.2);
//! * creating more software threads than hardware threads slows everything
//!   down (over-threading, Fig. 6).

use crate::config::{CpuPlatform, FrameworkConfig, OperatorImpl};
use crate::graph::Node;
use crate::libs::math::MathModel;
use crate::ops::OpKind;

use super::breakdown::Category;
use super::constants::*;
use super::memory;

/// Which logical cores of the pool a phase occupies.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Span {
    /// Pool main thread only (serial phases).
    Main,
    /// The kernel (MKL) threads: one per physical core, up to the count.
    Kernel(usize),
    /// The intra-op threads: hyperthread partners of the kernel threads.
    Intra(usize),
}

/// One timed phase of an operator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Phase {
    /// Accounting category.
    pub cat: Category,
    /// Duration in seconds.
    pub dur: f64,
    /// Cores occupied.
    pub span: Span,
}

/// Total duration of a phase list.
pub fn total(phases: &[Phase]) -> f64 {
    phases.iter().map(|p| p.dur).sum()
}

/// Framework-native prep bytes for a kernel op (the paper's O(n) rule for
/// MatMul; the im2col fraction for Conv).
fn fw_prep_bytes(node: &Node) -> f64 {
    // a zeroed descriptor means "bare library call" (Fig. 9's MKL series)
    if node.cost.prep_bytes == 0.0 {
        return 0.0;
    }
    match node.kind {
        OpKind::MatMul { m, .. } => FW_PREP_BYTES_PER_ROW * m as f64,
        // 1×1 convolutions need no im2col (a reshape suffices); larger
        // kernels stage half the im2col matrix in framework-native code —
        // this is why native time dominates the default Inception config
        // in the paper's Fig. 1/7 and why intra-op threads pay off
        OpKind::Conv { batch, out_h, out_w, k_h, k_w, .. } => {
            if k_h * k_w == 1 {
                FW_PREP_BYTES_PER_ROW * (batch * out_h * out_w) as f64 / 64.0
            } else {
                0.5 * node.cost.prep_bytes
            }
        }
        OpKind::Embedding { rows, .. } => 64.0 * rows as f64,
        OpKind::Gradient { fwd_bytes, .. } => 0.1 * fwd_bytes,
        _ => node.cost.prep_bytes,
    }
}

/// Context for executing ops on one inter-op pool.
#[derive(Debug, Clone)]
pub struct PoolCtx {
    /// Physical cores owned by this pool.
    pub phys_cores: usize,
    /// Pool spans both sockets (data-parallel beyond-one-socket mode).
    pub spans_sockets: bool,
    /// Number of sockets the pool's cores cover.
    pub sockets_used: usize,
}

/// Compute the phase list for `node` on a pool.
pub fn op_phases(
    node: &Node,
    cfg: &FrameworkConfig,
    platform: &CpuPlatform,
    pool: &PoolCtx,
) -> Vec<Phase> {
    let mut phases = Vec::with_capacity(4);
    op_phases_into(node, cfg, platform, pool, &mut phases);
    phases
}

/// Compute the phase list for `node` on a pool into a caller-owned
/// buffer (cleared first). The engine's steady-state loop reuses one
/// buffer per run, so dispatch allocates nothing.
///
/// NOTE(§Perf): a fixed-capacity inline list was tried here and measured
/// SLOWER than the Vec (the 200-byte by-value copies cost more than one
/// small allocation) — reverted in favour of buffer reuse; see
/// EXPERIMENTS.md §Perf.
pub fn op_phases_into(
    node: &Node,
    cfg: &FrameworkConfig,
    platform: &CpuPlatform,
    pool: &PoolCtx,
    phases: &mut Vec<Phase>,
) {
    phases.clear();
    let overthread = overthread_mult(cfg, platform);
    let peak_core = platform.peak_gflops_per_core * 1e9;
    let pool_threads = cfg.mkl_threads + cfg.intra_op_threads;

    // 1. scheduling: dispatch to the pool, wake workers
    let sched = sched_overhead(cfg.pool_lib, pool_threads)
        * pool_oversubscription_factor(
            cfg.pool_lib,
            cfg.inter_op_pools * pool_threads,
            platform.logical_cores(),
        );
    phases.push(Phase { cat: Category::FwSched, dur: sched * overthread, span: Span::Main });

    if !node.kind.uses_library_kernel() {
        // framework-native op: bandwidth + interpreted FLOPs; MatMul2-style
        // intra-op threads parallelise it (§5.2), otherwise single-threaded
        let serial = node.cost.total_bytes() / FW_NATIVE_RATE
            + node.cost.flops / (FW_NATIVE_FLOP_EFF * peak_core);
        let (dur, span) = match cfg.operator_impl {
            OperatorImpl::Serial => (serial, Span::Main),
            OperatorImpl::IntraOpParallel => {
                let t = adaptive_intra_threads(serial, cfg, pool);
                let scatter = t as f64 * pool_dispatch_overhead(cfg.pool_lib);
                (serial / t as f64 + scatter, Span::Intra(t))
            }
        };
        phases.push(Phase { cat: Category::FwNative, dur: dur * overthread, span });
        return;
    }

    // 2. framework data prep
    let prep_serial = fw_prep_bytes(node) / FW_PREP_RATE;
    match cfg.operator_impl {
        OperatorImpl::Serial => {
            phases.push(Phase { cat: Category::FwPrep, dur: prep_serial * overthread, span: Span::Main });
        }
        OperatorImpl::IntraOpParallel => {
            let t = adaptive_intra_threads(prep_serial, cfg, pool);
            let scatter = t as f64 * pool_dispatch_overhead(cfg.pool_lib);
            let dur = prep_serial / t as f64 + scatter;
            phases.push(Phase { cat: Category::FwPrep, dur: dur * overthread, span: Span::Intra(t) });
        }
    }

    // 3. library packing (serial inside the kernel)
    let lib = MathModel::new(cfg.math_lib);
    let lib_prep = node.cost.lib_prep_bytes / LIB_PACK_RATE;
    if lib_prep > 0.0 {
        phases.push(Phase { cat: Category::MklPrep, dur: lib_prep * overthread, span: Span::Main });
    }

    // 4. kernel compute. Threads saturate with kernel size: a 33 MFLOP GEMM
    // cannot feed 24 cores (per-thread slices drown in barrier cost), which
    // is why Fig. 9's speedups stay far below the core count for small
    // matrices.
    let t_cap = ((node.cost.flops / 1e6).sqrt().floor() as usize).max(1);
    let t_fma = cfg.mkl_threads.min(pool.phys_cores).min(t_cap).max(1);
    let par_eff = if matches!(node.kind, OpKind::Conv { .. }) {
        lib.parallel_efficiency_conv(t_fma)
    } else {
        lib.parallel_efficiency(t_fma)
    };
    let eff = kernel_efficiency(&lib, &node.kind) * par_eff;
    let mut compute = node.cost.flops / (peak_core * eff * t_fma as f64);
    // DRAM roofline (embeddings and huge layers are bandwidth-bound)
    let bw_floor = if matches!(node.kind, OpKind::Embedding { .. }) {
        node.cost.total_bytes() / (EMBEDDING_BW_FRAC * platform.mem_bw_gbps * 1e9)
    } else {
        memory::bandwidth_floor(&node.cost, platform, pool.sockets_used)
    };
    compute = compute.max(bw_floor);

    // cross-socket penalties for data-parallel kernels: remote-DRAM NUMA
    // throttling once the working set blows past the LLC neighbourhood,
    // plus the UPI transfer (which pipelines with compute — only the
    // excess beyond half the kernel time is exposed).
    let mut upi_exposed = 0.0;
    if pool.spans_sockets {
        let llc_bytes = platform.llc_mib_per_socket * 1024.0 * 1024.0;
        let pressure = node.cost.input_bytes / (16.0 * llc_bytes);
        compute *= 1.0 + 0.10 * (pressure - 1.0).max(0.0);
        let (upi, _) = memory::upi_transfer(&node.cost, platform);
        upi_exposed = (upi - 0.5 * compute).max(0.0);
    }
    phases.push(Phase {
        cat: Category::MklCompute,
        dur: compute * overthread,
        span: Span::Kernel(t_fma),
    });
    if upi_exposed > 0.0 {
        phases.push(Phase { cat: Category::UpiTransfer, dur: upi_exposed, span: Span::Main });
    }
}

/// Cost-aware intra-op fan-out (what Eigen's ParallelFor / TF's shard cost
/// model do): never split work finer than ~8 dispatch overheads per task,
/// so tiny ops stay serial instead of paying the scatter cost.
fn adaptive_intra_threads(serial: f64, cfg: &FrameworkConfig, pool: &PoolCtx) -> usize {
    let t_max = cfg.intra_op_threads.min(pool.phys_cores).max(1);
    let worth = (serial / (8.0 * pool_dispatch_overhead(cfg.pool_lib))).floor() as usize;
    worth.clamp(1, t_max)
}

/// Kernel efficiency for an op kind under a library model.
fn kernel_efficiency(lib: &MathModel, kind: &OpKind) -> f64 {
    match *kind {
        OpKind::MatMul { m, k, n } => lib.gemm_efficiency_mkn(m as f64, k as f64, n as f64),
        OpKind::Conv { batch, out_h, out_w, in_c, out_c, k_h, k_w } => {
            let m = (batch * out_h * out_w) as f64;
            let kk = (in_c * k_h * k_w) as f64;
            lib.gemm_efficiency_mkn(m, kk, out_c as f64)
        }
        OpKind::Gradient { fwd_flops, .. } => {
            // backward GEMMs have the same blocking behaviour
            lib.gemm_efficiency(fwd_flops.powf(1.0 / 3.0) / 2f64.powf(1.0 / 3.0))
        }
        _ => 0.5,
    }
}

/// Over-threading latency multiplier (Fig. 6's "over-threading" region).
pub fn overthread_mult(cfg: &FrameworkConfig, platform: &CpuPlatform) -> f64 {
    let sw = cfg.total_threads() as f64;
    let hw = platform.logical_cores() as f64;
    if sw <= hw {
        1.0
    } else {
        1.0 + OVERTHREAD_SLOPE * (sw / hw).log2()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{FrameworkConfig, MathLib, PoolLib};
    use crate::graph::GraphBuilder;

    fn large() -> CpuPlatform {
        CpuPlatform::large()
    }

    fn cfg(mkl: usize, intra: usize, op: OperatorImpl) -> FrameworkConfig {
        FrameworkConfig {
            inter_op_pools: 1,
            mkl_threads: mkl,
            intra_op_threads: intra,
            operator_impl: op,
            math_lib: MathLib::Mkl,
            pool_lib: PoolLib::Folly,
            ..FrameworkConfig::tuned_default()
        }
    }

    fn matmul_node(n: usize) -> Node {
        let mut b = GraphBuilder::new("t", 1);
        b.add("mm", OpKind::MatMul { m: n, k: n, n }, &[]);
        b.build().nodes.into_iter().next().unwrap()
    }

    fn pool24() -> PoolCtx {
        PoolCtx { phys_cores: 24, spans_sockets: false, sockets_used: 1 }
    }

    #[test]
    fn matmul512_prep_fraction_matches_paper() {
        // Fig. 10: ~10% prep at 1 MKL thread, >60% at 24 (serial prep)
        let n = matmul_node(512);
        let p1 = op_phases(&n, &cfg(1, 1, OperatorImpl::Serial), &large(), &pool24());
        let prep1: f64 = p1.iter().filter(|p| p.cat == Category::FwPrep).map(|p| p.dur).sum();
        let frac1 = prep1 / total(&p1);
        assert!(frac1 > 0.04 && frac1 < 0.2, "frac1={frac1}");

        let p24 = op_phases(&n, &cfg(24, 1, OperatorImpl::Serial), &large(), &pool24());
        let prep24: f64 = p24.iter().filter(|p| p.cat == Category::FwPrep).map(|p| p.dur).sum();
        let frac24 = prep24 / total(&p24);
        // the paper reports 72% (including barrier time on waiting cores);
        // on the main thread alone prep grows from ~10% to roughly half
        assert!(frac24 > 0.4, "frac24={frac24}");
    }

    #[test]
    fn matmul4k_prep_fraction_small() {
        // Fig. 10: < 3% in both configurations
        let n = matmul_node(4096);
        for threads in [1, 24] {
            let p = op_phases(&n, &cfg(threads, 1, OperatorImpl::Serial), &large(), &pool24());
            let prep: f64 = p.iter().filter(|p| p.cat == Category::FwPrep).map(|p| p.dur).sum();
            assert!(prep / total(&p) < 0.05, "threads={threads}");
        }
    }

    #[test]
    fn intra_op_threads_shrink_prep() {
        let n = matmul_node(512);
        let serial = op_phases(&n, &cfg(24, 1, OperatorImpl::Serial), &large(), &pool24());
        let par = op_phases(&n, &cfg(24, 24, OperatorImpl::IntraOpParallel), &large(), &pool24());
        assert!(total(&par) < 0.7 * total(&serial), "par={} serial={}", total(&par), total(&serial));
    }

    #[test]
    fn hyperthread_kernel_threads_add_nothing() {
        let n = matmul_node(2048);
        let t24 = total(&op_phases(&n, &cfg(24, 1, OperatorImpl::Serial), &large(), &pool24()));
        let t48 = total(&op_phases(&n, &cfg(48, 1, OperatorImpl::Serial), &large(), &pool24()));
        // 48 "MKL threads" on 24 cores: no extra FLOPs, at best equal
        assert!(t48 >= t24 * 0.99, "t48={t48} t24={t24}");
    }

    #[test]
    fn overthreading_penalises() {
        let p = CpuPlatform::small(); // 8 logical
        let mut c = cfg(4, 4, OperatorImpl::IntraOpParallel);
        c.inter_op_pools = 4; // 32 software threads on 8 logical cores
        assert!(overthread_mult(&c, &p) > 1.2);
        let ok = cfg(2, 2, OperatorImpl::IntraOpParallel);
        assert_eq!(overthread_mult(&ok, &p), 1.0);
    }

    #[test]
    fn light_op_single_threaded_when_serial() {
        let mut b = GraphBuilder::new("t", 1);
        b.add("cat", OpKind::DataMovement { bytes: 1 << 20, name: "Concat" }, &[]);
        let node = b.build().nodes.into_iter().next().unwrap();
        let p = op_phases(&node, &cfg(24, 24, OperatorImpl::Serial), &large(), &pool24());
        assert!(p.iter().all(|ph| matches!(ph.span, Span::Main)));
    }

    #[test]
    fn embedding_is_bandwidth_bound() {
        let mut b = GraphBuilder::new("t", 1);
        b.add(
            "emb",
            OpKind::Embedding { vocab: 1_000_000, dim: 256, rows: 100_000 },
            &[],
        );
        let node = b.build().nodes.into_iter().next().unwrap();
        let t1 = {
            let p = op_phases(&node, &cfg(1, 1, OperatorImpl::Serial), &large(), &pool24());
            p.iter().find(|p| p.cat == Category::MklCompute).unwrap().dur
        };
        let t24 = {
            let p = op_phases(&node, &cfg(24, 1, OperatorImpl::Serial), &large(), &pool24());
            p.iter().find(|p| p.cat == Category::MklCompute).unwrap().dur
        };
        // threads don't help a gather: time pinned by DRAM bandwidth
        assert!((t1 / t24) < 1.05, "t1={t1} t24={t24}");
    }

    #[test]
    fn data_parallel_numa_penalises_huge_kernels() {
        // spanning sockets slows a 16k GEMM (working set ≫ LLC): the
        // NUMA-thrash penalty behind Fig. 16's decline beyond 8k
        let n = matmul_node(16384);
        let spanning = PoolCtx { phys_cores: 48, spans_sockets: true, sockets_used: 2 };
        let local = PoolCtx { phys_cores: 48, spans_sockets: false, sockets_used: 2 };
        let p2 = CpuPlatform::large2();
        let c = cfg(48, 1, OperatorImpl::Serial);
        let t_span = op_phases(&n, &c, &p2, &spanning)
            .iter().find(|p| p.cat == Category::MklCompute).unwrap().dur;
        let t_local = op_phases(&n, &c, &p2, &local)
            .iter().find(|p| p.cat == Category::MklCompute).unwrap().dur;
        assert!(t_span > 1.15 * t_local, "span={t_span} local={t_local}");
    }

    #[test]
    fn data_parallel_exposes_upi_for_bandwidth_bound_ops() {
        // an embedding gather moves bytes without FLOPs to hide them
        // behind: the UPI phase becomes visible
        let mut b = GraphBuilder::new("t", 1);
        b.add(
            "emb",
            OpKind::Embedding { vocab: 10_000_000, dim: 512, rows: 8_000_000 },
            &[],
        );
        let node = b.build().nodes.into_iter().next().unwrap();
        let pool = PoolCtx { phys_cores: 48, spans_sockets: true, sockets_used: 2 };
        let p = op_phases(&node, &cfg(48, 1, OperatorImpl::Serial), &CpuPlatform::large2(), &pool);
        assert!(p.iter().any(|ph| ph.cat == Category::UpiTransfer), "{p:?}");
    }
}
