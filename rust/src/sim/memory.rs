//! Memory-system model: DRAM bandwidth shares and the inter-socket UPI
//! link (paper §7).
//!
//! The UPI model reproduces Fig. 16's empirical shape: measured throughput
//! approaches ~100 GB/s of the 120 GB/s peak, two-socket speedup peaks at
//! MatMul-8k (~1.8×) and *declines* at 16k when the per-socket panel
//! working set blows past the LLC and panels are re-streamed across the
//! link (NUMA thrash).

use crate::config::CpuPlatform;
use crate::ops::OpCost;

use super::constants::UPI_EFFECTIVE_FRAC;

/// Effective (achievable) UPI bandwidth in bytes/s.
pub fn upi_effective_bw(platform: &CpuPlatform) -> f64 {
    platform.upi_gbps * 1e9 * UPI_EFFECTIVE_FRAC
}

/// Cross-socket traffic for a data-parallel kernel execution.
///
/// Each socket computes half the output: half the activations plus the
/// gathered halves of the result cross the link; weight panels are
/// re-streamed when they no longer fit in the remote socket's LLC.
pub fn upi_traffic_bytes(cost: &OpCost, platform: &CpuPlatform) -> f64 {
    let base = 0.5 * (cost.input_bytes + cost.output_bytes);
    // NUMA-thrash multiplier: once the input working set exceeds ~16× the
    // socket LLC (a MatMul-8k on `large.2`), remote panels stop being
    // reused and are re-streamed — the Fig. 16 falloff beyond 8k.
    let llc_bytes = platform.llc_mib_per_socket * 1024.0 * 1024.0;
    let pressure = cost.input_bytes / (16.0 * llc_bytes);
    let thrash = 1.0 + 0.5 * (pressure - 1.0).max(0.0);
    base * thrash
}

/// Time for a data-parallel kernel's UPI phase, plus the achieved
/// throughput (bytes/s) for bandwidth accounting.
pub fn upi_transfer(cost: &OpCost, platform: &CpuPlatform) -> (f64, f64) {
    if platform.sockets < 2 {
        return (0.0, 0.0);
    }
    let bytes = upi_traffic_bytes(cost, platform);
    let bw = upi_effective_bw(platform);
    (bytes / bw, bw)
}

/// DRAM-bandwidth floor for a kernel: time below which the socket's memory
/// system cannot feed the cores.
pub fn bandwidth_floor(cost: &OpCost, platform: &CpuPlatform, sockets_used: usize) -> f64 {
    let bw = platform.mem_bw_gbps * 1e9 * sockets_used.max(1) as f64;
    cost.total_bytes() / bw
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::OpKind;

    fn l2() -> CpuPlatform {
        CpuPlatform::large2()
    }

    #[test]
    fn effective_bw_is_100_gbps() {
        assert!((upi_effective_bw(&l2()) - 100e9).abs() < 1e9);
    }

    #[test]
    fn single_socket_has_no_upi() {
        let c = OpCost::of(&OpKind::MatMul { m: 4096, k: 4096, n: 4096 });
        assert_eq!(upi_transfer(&c, &CpuPlatform::large()), (0.0, 0.0));
    }

    #[test]
    fn thrash_kicks_in_for_16k() {
        let c8 = OpCost::of(&OpKind::MatMul { m: 8192, k: 8192, n: 8192 });
        let c16 = OpCost::of(&OpKind::MatMul { m: 16384, k: 16384, n: 16384 });
        let r8 = upi_traffic_bytes(&c8, &l2()) / (0.5 * (c8.input_bytes + c8.output_bytes));
        let r16 = upi_traffic_bytes(&c16, &l2()) / (0.5 * (c16.input_bytes + c16.output_bytes));
        assert!(r8 < 1.5, "8k ratio {r8}");
        assert!(r16 > 2.0, "16k ratio {r16}");
    }

    #[test]
    fn bandwidth_floor_scales_with_sockets() {
        let c = OpCost::of(&OpKind::Embedding { vocab: 1_000_000, dim: 64, rows: 100_000 });
        let one = bandwidth_floor(&c, &l2(), 1);
        let two = bandwidth_floor(&c, &l2(), 2);
        assert!((one / two - 2.0).abs() < 1e-9);
    }
}
