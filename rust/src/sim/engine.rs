//! Discrete-event execution of a computational graph over inter-op pools.
//!
//! The scheduler model matches the paper's Fig. 3: the machine's physical
//! cores are split evenly into `inter_op_pools` pools; ready operators are
//! dispatched to free pools in the order the configured
//! [`crate::config::SchedPolicy`] dictates (topological, critical-path-
//! first, or costliest-first); a pool runs one operator at a time through
//! its phase list ([`super::opexec`]). One pool ⇒ synchronous scheduling;
//! N pools ⇒ asynchronous scheduling over N operators in flight.
//!
//! Two engines share the loop body:
//!
//! * the **fast path** ([`simulate`], [`simulate_opts`],
//!   [`simulate_prepared`]) runs a bucketed [`CalendarQueue`] +
//!   [`FreePools`] bitmask with every per-dispatch buffer reused from an
//!   [`EngineScratch`], so the steady-state loop allocates nothing; with
//!   a [`PhaseTable`] (delta-simulation through
//!   [`super::prepared::SimCache`]) it skips the cost model entirely;
//! * the **reference path** ([`simulate_reference`]) keeps the seed
//!   `BinaryHeap` + `Vec` free-pool structures. The property test
//!   `rust/tests/engine_fastpath.rs` holds the fast path to the
//!   reference's bit-identical reports.
//!
//! A graph whose dependencies can never all be satisfied (cycle,
//! unreachable dep) makes the engine stall; both paths return
//! [`PallasError::InvalidGraph`] instead of a silently partial report.
//!
//! Per-logical-core timelines are recorded so the harness can reproduce the
//! paper's `perf`-style stack bars and traces.

use std::collections::BinaryHeap;

use crate::config::{CpuPlatform, FrameworkConfig, ParallelismMode};
use crate::error::{PallasError, PallasResult};
use crate::graph::Graph;
use crate::sched::{partition_pools, ReadyQueue};

use super::breakdown::{Breakdown, Category, Segment};
use super::events::{CalendarQueue, Event, FreePools};
use super::opexec::{op_phases, op_phases_into, Phase, PoolCtx, Span};
use super::prepared::{PhaseTable, PreparedGraph};

/// Result of simulating one graph execution.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// End-to-end latency (seconds).
    pub latency_s: f64,
    /// Aggregate core-time per category.
    pub breakdown: Breakdown,
    /// Per-logical-core segments (kernel threads first, then their
    /// hyperthread partners), when `record_timelines` was set.
    pub timelines: Vec<Vec<Segment>>,
    /// Total bytes that crossed the UPI link.
    pub upi_bytes: f64,
    /// Peak UPI throughput observed (bytes/s).
    pub upi_peak_bps: f64,
    /// Achieved FLOP/s over the run.
    pub gflops: f64,
}

impl SimReport {
    /// Throughput in items/s given the graph's batch size.
    pub fn throughput(&self, batch: usize) -> f64 {
        batch as f64 / self.latency_s
    }
}

/// Simulation options.
#[derive(Debug, Clone)]
pub struct SimOptions {
    /// Record per-core segment timelines (needed for traces; costs memory).
    pub record_timelines: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { record_timelines: false }
    }
}

/// Reusable per-run engine buffers. [`PreparedGraph`] keeps a pool of
/// these so sweep workers check one out per simulation instead of
/// allocating event queues, pool vectors and phase buffers every call —
/// the steady-state dispatch loop is allocation-free.
#[derive(Debug, Default)]
pub struct EngineScratch {
    free: FreePools,
    events: CalendarQueue,
    pool_free_at: Vec<f64>,
    /// Per-pool accumulated op time (drives the Idle accounting).
    pool_busy: Vec<f64>,
    phases_buf: Vec<Phase>,
    /// Per-slice flag buffer for the timeline slow path.
    tl_scratch: Vec<bool>,
}

/// Simulate `graph` under `cfg` on `platform`.
pub fn simulate(
    graph: &Graph,
    platform: &CpuPlatform,
    cfg: &FrameworkConfig,
) -> PallasResult<SimReport> {
    simulate_opts(graph, platform, cfg, &SimOptions::default())
}

/// Simulate with options.
pub fn simulate_opts(
    graph: &Graph,
    platform: &CpuPlatform,
    cfg: &FrameworkConfig,
    opts: &SimOptions,
) -> PallasResult<SimReport> {
    let queue = ReadyQueue::with_policy(graph, cfg.sched_policy);
    let mut scratch = EngineScratch::default();
    run_engine_fast(graph, None, queue, platform, cfg, opts, None, &mut scratch)
}

/// Simulate using a [`PreparedGraph`] — same engine, but the upward
/// ranks, dispatch weights, consumer CSR and kernel-use flags come
/// precomputed, and the engine scratch is checked out of the prepared
/// graph's pool instead of allocated. Bit-identical to [`simulate_opts`]
/// on the same inputs (the prepared tables are built by the same
/// functions `ReadyQueue::with_policy` runs).
pub fn simulate_prepared(
    prep: &PreparedGraph,
    platform: &CpuPlatform,
    cfg: &FrameworkConfig,
    opts: &SimOptions,
) -> PallasResult<SimReport> {
    let queue = prep.ready_queue(cfg.sched_policy);
    let mut scratch = prep.take_scratch();
    let r = run_engine_fast(
        prep.graph(),
        Some(prep.kernel_use()),
        queue,
        platform,
        cfg,
        opts,
        None,
        &mut scratch,
    );
    prep.put_scratch(scratch);
    r
}

/// Delta-simulation entry point: phase lists come from a prebuilt
/// [`PhaseTable`] (policy-invariant per config family), so the cost
/// model is not consulted at all. Bit-identical to [`simulate_prepared`]
/// because the table holds exactly what `op_phases` returns for each
/// (pool shape, node) pair.
pub(crate) fn simulate_prepared_with_table(
    prep: &PreparedGraph,
    platform: &CpuPlatform,
    cfg: &FrameworkConfig,
    opts: &SimOptions,
    table: &PhaseTable,
) -> PallasResult<SimReport> {
    let queue = prep.ready_queue(cfg.sched_policy);
    let mut scratch = prep.take_scratch();
    let r = run_engine_fast(
        prep.graph(),
        Some(prep.kernel_use()),
        queue,
        platform,
        cfg,
        opts,
        Some(table),
        &mut scratch,
    );
    prep.put_scratch(scratch);
    r
}

/// The seed engine, kept as the correctness baseline: `BinaryHeap`
/// event queue, `Vec` free-pool stack, per-dispatch `op_phases`
/// allocation. The fast path must match its reports bit-for-bit
/// (`rust/tests/engine_fastpath.rs`); `benches/sim.rs` measures the
/// speedup against it. Not part of the public API surface.
#[doc(hidden)]
pub fn simulate_reference(
    graph: &Graph,
    platform: &CpuPlatform,
    cfg: &FrameworkConfig,
    opts: &SimOptions,
) -> PallasResult<SimReport> {
    let queue = ReadyQueue::with_policy(graph, cfg.sched_policy);
    run_engine_reference(graph, None, queue, platform, cfg, opts)
}

/// Pool contexts for the op-execution model; data-parallel spanning only
/// counts when the mode asks for it.
pub(crate) fn pool_contexts(
    assignments: &[crate::sched::PoolAssignment],
    cfg: &FrameworkConfig,
) -> Vec<PoolCtx> {
    assignments
        .iter()
        .map(|a| PoolCtx {
            phys_cores: a.cores,
            spans_sockets: a.spans_sockets && cfg.parallelism == ParallelismMode::DataParallel,
            sockets_used: a.sockets_used,
        })
        .collect()
}

/// Event-queue entry of the reference engine: a pool finishing its
/// current op.
#[derive(PartialEq)]
struct Completion {
    time: f64,
    pool: usize,
    node: usize,
}

impl Eq for Completion {}

impl Ord for Completion {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // min-heap on time (BinaryHeap is a max-heap); `total_cmp` keeps
        // the order total even if a cost model ever produces a NaN
        // latency, so a poisoned design point cannot panic the engine
        // mid-sweep (NaNs sort after every real completion time)
        other.time.total_cmp(&self.time).then_with(|| other.node.cmp(&self.node))
    }
}

impl PartialOrd for Completion {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// The fast discrete-event loop: calendar queue, free-pool bitmask,
/// scratch-owned buffers, optional [`PhaseTable`] phase source.
#[allow(clippy::too_many_arguments)]
fn run_engine_fast(
    graph: &Graph,
    kernel_use: Option<&[bool]>,
    mut queue: ReadyQueue,
    platform: &CpuPlatform,
    cfg: &FrameworkConfig,
    opts: &SimOptions,
    table: Option<&PhaseTable>,
    scratch: &mut EngineScratch,
) -> PallasResult<SimReport> {
    let assignments = partition_pools(platform, cfg);
    let pools = assignments.len();
    let pool_ctxs = pool_contexts(&assignments, cfg);

    let n = graph.len();
    let EngineScratch { free, events, pool_free_at, pool_busy, phases_buf, tl_scratch } = scratch;
    free.reset(pools);
    events.clear();
    pool_free_at.clear();
    pool_free_at.resize(pools, 0.0);
    pool_busy.clear();
    pool_busy.resize(pools, 0.0);
    let mut now = 0.0f64;
    let mut done = 0usize;

    let mut breakdown = Breakdown::new();
    let mut timelines: Vec<Vec<Segment>> =
        vec![Vec::new(); if opts.record_timelines { platform.logical_cores() } else { 0 }];
    let mut upi_bytes = 0.0f64;
    let mut upi_peak: f64 = 0.0;

    while done < n {
        // dispatch ready ops to free pools (policy-chosen priority)
        loop {
            if free.is_empty() {
                break;
            }
            let node = match queue.pop() {
                Some(nd) => nd,
                None => break,
            };
            let pool = free.acquire().expect("free set non-empty");
            let (phases, dur): (&[Phase], f64) = match table {
                Some(t) => {
                    let class = t.class_of(pool);
                    (t.phases(class, node), t.total(class, node))
                }
                None => {
                    op_phases_into(&graph.nodes[node], cfg, platform, &pool_ctxs[pool], phases_buf);
                    let d = super::opexec::total(phases_buf);
                    (&phases_buf[..], d)
                }
            };
            let start = now.max(pool_free_at[pool]);
            record(
                &mut breakdown,
                &mut timelines,
                tl_scratch,
                opts.record_timelines,
                platform,
                cfg,
                assignments[pool].first_core,
                assignments[pool].cores,
                start,
                phases,
                node,
            );
            // UPI accounting: every kernel on a socket-spanning pool moves
            // its cross-socket share over the link (pipelined with compute,
            // so the achieved rate is bytes over the op's whole duration,
            // capped at the link's effective ceiling — what the authors'
            // UPI counters reported)
            let node_uses_kernel = kernel_use
                .map(|k| k[node])
                .unwrap_or_else(|| graph.nodes[node].kind.uses_library_kernel());
            if pool_ctxs[pool].spans_sockets && node_uses_kernel {
                let cost = &graph.nodes[node].cost;
                upi_bytes += super::memory::upi_traffic_bytes(cost, platform);
                // peak sampled link rate: panel re-streaming keeps the link
                // busier the further the working set spills past the LLC
                // (Fig. 16b: consumption climbs towards ~100 GB/s with size)
                let llc = platform.llc_mib_per_socket * 1024.0 * 1024.0;
                let pressure = cost.input_bytes / (8.0 * llc);
                let rate = super::memory::upi_effective_bw(platform) * pressure / (1.0 + pressure);
                upi_peak = upi_peak.max(rate);
            }
            pool_busy[pool] += dur;
            pool_free_at[pool] = start + dur;
            events.push(Event { time: start + dur, pool, node });
        }

        // advance to the next completion
        let Some(Event { time, pool, node }) = events.pop() else {
            break; // stalled: reported as InvalidGraph below
        };
        now = time;
        free.release(pool);
        done += 1;
        queue.complete(node);
    }

    if done < n {
        return Err(PallasError::InvalidGraph(format!(
            "graph '{}' stalled after {done}/{n} ops (cyclic or unsatisfiable dependencies)",
            graph.name
        )));
    }

    let latency = now;
    finish_report(
        graph, platform, &assignments, pool_busy, latency, breakdown, timelines, upi_bytes,
        upi_peak,
    )
}

/// The seed discrete-event loop (`BinaryHeap` + `Vec` free pool), with
/// the same accounting fixes as the fast path so their reports stay
/// comparable bit-for-bit.
fn run_engine_reference(
    graph: &Graph,
    kernel_use: Option<&[bool]>,
    mut queue: ReadyQueue,
    platform: &CpuPlatform,
    cfg: &FrameworkConfig,
    opts: &SimOptions,
) -> PallasResult<SimReport> {
    let assignments = partition_pools(platform, cfg);
    let pools = assignments.len();
    let pool_ctxs = pool_contexts(&assignments, cfg);

    let n = graph.len();
    let mut free_pools: Vec<usize> = (0..pools).rev().collect();
    let mut heap: BinaryHeap<Completion> = BinaryHeap::new();
    let mut pool_free_at = vec![0.0f64; pools];
    let mut pool_busy = vec![0.0f64; pools];
    let mut now = 0.0f64;
    let mut done = 0usize;

    let mut breakdown = Breakdown::new();
    let mut timelines: Vec<Vec<Segment>> =
        vec![Vec::new(); if opts.record_timelines { platform.logical_cores() } else { 0 }];
    let mut upi_bytes = 0.0f64;
    let mut upi_peak: f64 = 0.0;
    let mut tl_scratch: Vec<bool> = Vec::new();

    while done < n {
        loop {
            if free_pools.is_empty() {
                break;
            }
            let node = match queue.pop() {
                Some(nd) => nd,
                None => break,
            };
            let pool = free_pools.pop().unwrap();
            let phases = op_phases(&graph.nodes[node], cfg, platform, &pool_ctxs[pool]);
            let start = now.max(pool_free_at[pool]);
            let dur = super::opexec::total(&phases);
            record(
                &mut breakdown,
                &mut timelines,
                &mut tl_scratch,
                opts.record_timelines,
                platform,
                cfg,
                assignments[pool].first_core,
                assignments[pool].cores,
                start,
                &phases,
                node,
            );
            let node_uses_kernel = kernel_use
                .map(|k| k[node])
                .unwrap_or_else(|| graph.nodes[node].kind.uses_library_kernel());
            if pool_ctxs[pool].spans_sockets && node_uses_kernel {
                let cost = &graph.nodes[node].cost;
                upi_bytes += super::memory::upi_traffic_bytes(cost, platform);
                let llc = platform.llc_mib_per_socket * 1024.0 * 1024.0;
                let pressure = cost.input_bytes / (8.0 * llc);
                let rate = super::memory::upi_effective_bw(platform) * pressure / (1.0 + pressure);
                upi_peak = upi_peak.max(rate);
            }
            pool_busy[pool] += dur;
            pool_free_at[pool] = start + dur;
            heap.push(Completion { time: start + dur, pool, node });
        }

        let Completion { time, pool, node } = match heap.pop() {
            Some(c) => c,
            None => break, // stalled: reported as InvalidGraph below
        };
        now = time;
        free_pools.push(pool);
        done += 1;
        queue.complete(node);
    }

    if done < n {
        return Err(PallasError::InvalidGraph(format!(
            "graph '{}' stalled after {done}/{n} ops (cyclic or unsatisfiable dependencies)",
            graph.name
        )));
    }

    let latency = now;
    finish_report(
        graph, platform, &assignments, &pool_busy, latency, breakdown, timelines, upi_bytes,
        upi_peak,
    )
}

/// Shared epilogue: idle accounting + report assembly.
///
/// A pool's idle time is the latency minus the op time it actually
/// accumulated (`pool_busy`, summed per dispatch) — *not* minus the time
/// it last freed up: a pool that stalls mid-stream waiting for
/// dependencies and then works again ends with a late `pool_free_at`
/// that would hide the stall entirely (the seed accounting treated
/// `[0, pool_free_at]` as fully busy).
#[allow(clippy::too_many_arguments)]
fn finish_report(
    graph: &Graph,
    platform: &CpuPlatform,
    assignments: &[crate::sched::PoolAssignment],
    pool_busy: &[f64],
    latency: f64,
    mut breakdown: Breakdown,
    timelines: Vec<Vec<Segment>>,
    upi_bytes: f64,
    upi_peak: f64,
) -> PallasResult<SimReport> {
    for (p, a) in assignments.iter().enumerate() {
        let idle = (latency - pool_busy[p]).max(0.0);
        // idle applies to all logical cores of the pool's own slice
        breakdown.add(Category::Idle, idle * (a.cores * platform.smt) as f64);
    }
    let gflops = graph.total_flops() / latency.max(1e-12) / 1e9;
    Ok(SimReport {
        latency_s: latency,
        breakdown,
        timelines,
        upi_bytes,
        upi_peak_bps: upi_peak,
        gflops,
    })
}

/// Record one op's phases into the breakdown (and timelines if requested).
/// `base`/`cpp` are the executing pool's *own* first physical core and
/// core count (pool slices need not be identical — Fig. 3c's even split
/// is just the common case). `scratch` is a reusable per-slice flag
/// buffer for the timeline slow path: marking the active kernel-thread
/// indices and scanning the flags is O(cores) per phase, where the old
/// `active.contains(&c)` scan was O(cores²).
#[allow(clippy::too_many_arguments)]
fn record(
    breakdown: &mut Breakdown,
    timelines: &mut [Vec<Segment>],
    scratch: &mut Vec<bool>,
    record_tl: bool,
    platform: &CpuPlatform,
    cfg: &FrameworkConfig,
    base: usize,
    cpp: usize,
    start: f64,
    phases: &[Phase],
    node: usize,
) {
    let phys = platform.physical_cores();
    if record_tl {
        scratch.clear();
        scratch.resize(cpp, false);
    }
    let mut t = start;
    for ph in phases {
        // how many logical cores this phase occupies (no allocation on the
        // accounting-only fast path — this runs once per phase per op and
        // dominates the engine profile under exhaustive search)
        let active_count = match ph.span {
            Span::Main => 1,
            Span::Kernel(k) | Span::Intra(k) => k.min(cpp),
        };
        breakdown.add(ph.cat, ph.dur * active_count as f64);
        // peers inside the pool wait at the barrier during serial phases
        let kernel_waiters = match ph.span {
            Span::Main => cpp.saturating_sub(1),
            Span::Kernel(k) => cpp.saturating_sub(k.min(cpp)),
            Span::Intra(_) => cpp, // kernel threads wait while prep runs
        };
        if cfg.mkl_threads > 1 {
            breakdown.add(Category::Barrier, ph.dur * kernel_waiters as f64);
        }
        if record_tl {
            // slow path: mark active slots in the scratch flags (indices
            // are kernel-thread offsets within the pool's slice) while
            // pushing the active logical-core segments
            for s in scratch.iter_mut() {
                *s = false;
            }
            let push = |timelines: &mut [Vec<Segment>], c: usize, cat: Category| {
                if c < timelines.len() {
                    timelines[c].push(Segment { t0: t, t1: t + ph.dur, cat, op: node });
                }
            };
            match ph.span {
                Span::Main => {
                    scratch[0] = true;
                    push(timelines, base, ph.cat);
                }
                Span::Kernel(k) => {
                    for i in 0..k.min(cpp) {
                        scratch[i] = true;
                        push(timelines, base + i, ph.cat);
                    }
                }
                // intra threads are SMT partners: logical id = phys + core
                // (no kernel-side slot is active — every kernel thread of
                // the slice waits at the barrier below)
                Span::Intra(k) => {
                    for i in 0..k.min(cpp) {
                        push(timelines, phys + base + i, ph.cat);
                    }
                }
            }
            if cfg.mkl_threads > 1 {
                for (i, &active) in scratch.iter().enumerate() {
                    if !active {
                        push(timelines, base + i, Category::Barrier);
                    }
                }
            }
        }
        t += ph.dur;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{FrameworkConfig, OperatorImpl};
    use crate::graph::{GraphBuilder, NodeId};
    use crate::models;
    use crate::ops::OpKind;

    fn cfg(pools: usize, mkl: usize, intra: usize) -> FrameworkConfig {
        FrameworkConfig {
            inter_op_pools: pools,
            mkl_threads: mkl,
            intra_op_threads: intra,
            operator_impl: OperatorImpl::Serial,
            ..FrameworkConfig::tuned_default()
        }
    }

    #[test]
    fn all_ops_complete() {
        let g = models::build("inception_v2", 16).unwrap();
        let r = simulate(&g, &CpuPlatform::large(), &cfg(1, 24, 1)).unwrap();
        assert!(r.latency_s > 0.0 && r.latency_s.is_finite());
    }

    #[test]
    fn more_kernel_threads_speed_up_wide_matmul() {
        let g = models::build("matmul_4k", 0).unwrap();
        let p = CpuPlatform::large();
        let t1 = simulate(&g, &p, &cfg(1, 1, 1)).unwrap().latency_s;
        let t24 = simulate(&g, &p, &cfg(1, 24, 1)).unwrap().latency_s;
        let speedup = t1 / t24;
        assert!(speedup > 8.0 && speedup < 24.0, "speedup={speedup}");
    }

    #[test]
    fn async_pools_help_wide_model() {
        let g = models::build("inception_v1", 16).unwrap();
        let p = CpuPlatform::large();
        let sync = simulate(&g, &p, &cfg(1, 24, 1)).unwrap().latency_s;
        let async3 = simulate(&g, &p, &cfg(3, 8, 1)).unwrap().latency_s;
        assert!(async3 < sync, "sync={sync} async={async3}");
    }

    #[test]
    fn async_pools_hurt_chain_model() {
        // a pure chain gets no inter-op parallelism; splitting cores into
        // pools only shrinks per-op thread counts
        let g = models::build("caffenet", 16).unwrap();
        let p = CpuPlatform::large();
        let sync = simulate(&g, &p, &cfg(1, 24, 1)).unwrap().latency_s;
        let async4 = simulate(&g, &p, &cfg(4, 6, 1)).unwrap().latency_s;
        assert!(async4 > sync, "sync={sync} async4={async4}");
    }

    #[test]
    fn all_policies_complete_deterministically() {
        let g = models::build("inception_v1", 16).unwrap();
        let p = CpuPlatform::large();
        for policy in crate::config::SchedPolicy::ALL {
            let mut c = cfg(3, 8, 1);
            c.sched_policy = policy;
            let a = simulate(&g, &p, &c).unwrap().latency_s;
            let b = simulate(&g, &p, &c).unwrap().latency_s;
            assert_eq!(a, b, "{policy:?}");
            assert!(a.is_finite() && a > 0.0, "{policy:?}");
        }
    }

    #[test]
    fn latency_deterministic() {
        let g = models::build("resnet50", 16).unwrap();
        let p = CpuPlatform::large();
        let a = simulate(&g, &p, &cfg(2, 12, 12)).unwrap().latency_s;
        let b = simulate(&g, &p, &cfg(2, 12, 12)).unwrap().latency_s;
        assert_eq!(a, b);
    }

    #[test]
    fn timelines_cover_latency() {
        let g = models::build("matmul_512", 0).unwrap();
        let p = CpuPlatform::large();
        let r = simulate_opts(&g, &p, &cfg(1, 24, 1), &SimOptions { record_timelines: true })
            .unwrap();
        assert_eq!(r.timelines.len(), p.logical_cores());
        let max_t1 = r
            .timelines
            .iter()
            .flat_map(|tl| tl.iter().map(|s| s.t1))
            .fold(0.0f64, f64::max);
        assert!((max_t1 - r.latency_s).abs() < 1e-9);
    }

    #[test]
    fn timeline_segments_ordered_nonoverlapping() {
        let g = models::build("inception_v2", 16).unwrap();
        let p = CpuPlatform::small();
        let r =
            simulate_opts(&g, &p, &cfg(2, 2, 2), &SimOptions { record_timelines: true }).unwrap();
        for tl in &r.timelines {
            for w in tl.windows(2) {
                assert!(w[1].t0 >= w[0].t1 - 1e-12);
            }
        }
    }

    #[test]
    fn completion_order_survives_nan_times() {
        // a NaN completion time must not panic the event heap
        // (`total_cmp` keeps the order total); NaNs sort after every
        // real time, so finite completions still drain first
        let mut heap = BinaryHeap::new();
        heap.push(Completion { time: f64::NAN, pool: 0, node: 0 });
        heap.push(Completion { time: 1.0, pool: 1, node: 1 });
        heap.push(Completion { time: 0.5, pool: 2, node: 2 });
        assert_eq!(heap.pop().unwrap().node, 2);
        assert_eq!(heap.pop().unwrap().node, 1);
        assert!(heap.pop().unwrap().time.is_nan());
    }

    #[test]
    fn barrier_timeline_marks_waiting_cores() {
        // mkl=2 of the pool's 4 cores: waiting kernel threads must show
        // Barrier segments (the scratch-flag slow path has to mirror the
        // active span exactly)
        let g = models::build("matmul_512", 0).unwrap();
        let p = CpuPlatform::small();
        let r =
            simulate_opts(&g, &p, &cfg(1, 2, 1), &SimOptions { record_timelines: true }).unwrap();
        let barriers = r
            .timelines
            .iter()
            .flatten()
            .filter(|s| s.cat == Category::Barrier)
            .count();
        assert!(barriers > 0, "no Barrier segments recorded");
        assert!(r.breakdown.get(Category::Barrier) > 0.0);
    }

    #[test]
    fn breakdown_has_kernel_time() {
        let g = models::build("resnet50", 16).unwrap();
        let r = simulate(&g, &CpuPlatform::large(), &cfg(1, 24, 1)).unwrap();
        assert!(r.breakdown.get(Category::MklCompute) > 0.0);
        assert!(r.breakdown.get(Category::FwPrep) > 0.0);
    }

    #[test]
    fn two_sockets_speed_up_resnet_partially() {
        // Fig. 15: 1.43× from the second socket, not 2× (UPI + serial
        // terms). §7.1 sets intra-op/MKL threads to all physical cores.
        let g = models::build("resnet50", 16).unwrap();
        let mut c1 = cfg(1, 24, 24);
        c1.operator_impl = OperatorImpl::IntraOpParallel;
        let mut c2 = cfg(1, 48, 48);
        c2.operator_impl = OperatorImpl::IntraOpParallel;
        let one = simulate(&g, &CpuPlatform::large(), &c1).unwrap().latency_s;
        let two = simulate(&g, &CpuPlatform::large2(), &c2).unwrap().latency_s;
        let speedup = one / two;
        assert!(speedup > 1.1 && speedup < 1.9, "speedup={speedup}");
    }

    #[test]
    fn cyclic_graph_returns_invalid_graph() {
        // a mutual dependency cycle can never dispatch: both engines must
        // return InvalidGraph instead of a silently partial report
        let mut b = GraphBuilder::new("cycle", 1);
        b.add("a", OpKind::MatMul { m: 64, k: 64, n: 64 }, &[]);
        b.add("b", OpKind::MatMul { m: 64, k: 64, n: 64 }, &[]);
        let mut g = b.build();
        g.nodes[0].deps = vec![NodeId(1)];
        g.nodes[1].deps = vec![NodeId(0)];
        let p = CpuPlatform::small();
        let c = cfg(2, 1, 1);
        for r in [
            simulate(&g, &p, &c),
            simulate_reference(&g, &p, &c, &SimOptions::default()),
        ] {
            match r {
                Err(PallasError::InvalidGraph(msg)) => {
                    assert!(msg.contains("0/2"), "{msg}");
                }
                other => panic!("expected InvalidGraph, got {other:?}"),
            }
        }
    }

    #[test]
    fn partially_stalled_graph_returns_invalid_graph() {
        // one runnable root, then a node whose dependency is itself —
        // the engine completes some work and must still refuse the report
        let mut b = GraphBuilder::new("stall", 1);
        b.add("root", OpKind::MatMul { m: 64, k: 64, n: 64 }, &[]);
        b.add("orphan", OpKind::MatMul { m: 64, k: 64, n: 64 }, &[]);
        let mut g = b.build();
        g.nodes[1].deps = vec![NodeId(1)]; // self-dependency: unsatisfiable
        let r = simulate(&g, &CpuPlatform::small(), &cfg(2, 1, 1));
        match r {
            Err(PallasError::InvalidGraph(msg)) => assert!(msg.contains("1/2"), "{msg}"),
            other => panic!("expected InvalidGraph, got {other:?}"),
        }
    }

    #[test]
    fn mid_stream_stall_counts_as_idle() {
        // two pools; pool 1 runs b, then c, stalls waiting for heavy a,
        // then runs e. Its pool_free_at ends at the latency, so the seed
        // accounting (busy = [0, pool_free_at]) saw zero idle for it; the
        // per-dispatch busy sum exposes the stall.
        let mm = |n: usize| OpKind::MatMul { m: n, k: n, n };
        let mut b = GraphBuilder::new("stall", 1);
        let a = b.add("a", mm(1024), &[]); // heavy: pins pool 0
        let bb = b.add("b", mm(128), &[]);
        let c = b.add("c", mm(128), &[bb]);
        b.add("d", mm(128), &[a]);
        b.add("e", mm(128), &[a, c]);
        let g = b.build();
        let p = CpuPlatform::small(); // 4 phys cores → 2 pools × 2 cores
        let c2 = cfg(2, 1, 1);
        let r = simulate_opts(&g, &p, &c2, &SimOptions { record_timelines: true }).unwrap();
        let latency = r.latency_s;
        // mkl=1 + Serial ⇒ every phase runs on the pool's base core, so
        // the base-core timeline is the pool's exact busy set
        let pool_cores = [0usize, 2];
        let units = (2 * p.smt) as f64; // cores-per-pool × smt
        let mut want_idle = 0.0;
        let mut old_idle = 0.0;
        for &base in &pool_cores {
            let busy: f64 = r.timelines[base].iter().map(|s| s.t1 - s.t0).sum();
            let free_at = r.timelines[base].iter().map(|s| s.t1).fold(0.0f64, f64::max);
            want_idle += (latency - busy).max(0.0) * units;
            old_idle += (latency - free_at.min(latency)).max(0.0) * units;
        }
        let got = r.breakdown.get(Category::Idle);
        assert!((got - want_idle).abs() <= 1e-9 * want_idle.max(1.0), "got={got} want={want_idle}");
        // the stalled pool finishes an op at the very end, so the seed
        // formula hides its whole mid-stream gap
        assert!(got > old_idle * 1.5 + 1e-12, "got={got} old={old_idle}");
    }

    #[test]
    fn fast_path_matches_reference_engine() {
        // cheap in-module smoke; the full zoo × platform × policy matrix
        // lives in rust/tests/engine_fastpath.rs
        let g = models::build("inception_v2", 16).unwrap();
        let p = CpuPlatform::large2();
        let mut c = cfg(3, 8, 8);
        c.operator_impl = OperatorImpl::IntraOpParallel;
        let opts = SimOptions { record_timelines: true };
        let fast = simulate_opts(&g, &p, &c, &opts).unwrap();
        let slow = simulate_reference(&g, &p, &c, &opts).unwrap();
        assert_eq!(fast.latency_s.to_bits(), slow.latency_s.to_bits());
        assert_eq!(fast.gflops.to_bits(), slow.gflops.to_bits());
        for cat in Category::ALL {
            assert_eq!(
                fast.breakdown.get(cat).to_bits(),
                slow.breakdown.get(cat).to_bits(),
                "{cat:?}"
            );
        }
        assert_eq!(fast.timelines, slow.timelines);
    }
}
