//! Time-accounting categories, per-core timelines and aggregate breakdowns
//! — the simulator's equivalent of the paper's `perf record` stack bars
//! (Figs. 1, 7, 10, 11, 12, 15, 17) and execution traces (Fig. 8).

/// What a logical core is doing during a segment.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Category {
    /// Library kernel floating-point work (the MKL/MKL-DNN/Eigen body).
    MklCompute,
    /// Library-internal data preparation (packing, layout).
    MklPrep,
    /// Framework-native data preparation for a kernel (the "TF data prep"
    /// the paper blames for poor scaling).
    FwPrep,
    /// Framework-native operator execution (control flow, reshape, concat).
    FwNative,
    /// Operator-scheduling overhead (thread-pool dispatch, wake-ups).
    FwSched,
    /// Waiting at an intra-op barrier for peers to finish.
    Barrier,
    /// Cross-socket UPI transfer time.
    UpiTransfer,
    /// Nothing scheduled on this core.
    Idle,
}

impl Category {
    /// All categories, in stack-bar display order.
    pub const ALL: [Category; 8] = [
        Category::MklCompute,
        Category::MklPrep,
        Category::FwPrep,
        Category::FwNative,
        Category::FwSched,
        Category::Barrier,
        Category::UpiTransfer,
        Category::Idle,
    ];

    /// Display label matching the paper's figures.
    pub fn label(&self) -> &'static str {
        match self {
            Category::MklCompute => "MKL compute",
            Category::MklPrep => "MKL data prep",
            Category::FwPrep => "TF data prep",
            Category::FwNative => "TF native ops",
            Category::FwSched => "scheduling",
            Category::Barrier => "barrier/sync",
            Category::UpiTransfer => "UPI transfer",
            Category::Idle => "idle",
        }
    }

    fn index(&self) -> usize {
        Category::ALL.iter().position(|c| c == self).unwrap()
    }
}

/// One contiguous activity on one logical core.
#[derive(Debug, Clone, PartialEq)]
pub struct Segment {
    /// Start time (seconds).
    pub t0: f64,
    /// End time (seconds).
    pub t1: f64,
    /// Activity class.
    pub cat: Category,
    /// Index of the graph node responsible (usize::MAX for idle).
    pub op: usize,
}

impl Segment {
    /// Segment duration.
    pub fn dur(&self) -> f64 {
        self.t1 - self.t0
    }
}

/// Aggregate seconds per category (summed over cores).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Breakdown {
    secs: [f64; 8],
}

impl Breakdown {
    /// Empty breakdown.
    pub fn new() -> Self {
        Self::default()
    }

    /// Accumulate `dur` seconds of `cat`.
    pub fn add(&mut self, cat: Category, dur: f64) {
        self.secs[cat.index()] += dur;
    }

    /// Seconds spent in a category.
    pub fn get(&self, cat: Category) -> f64 {
        self.secs[cat.index()]
    }

    /// Total accounted core-seconds.
    pub fn total(&self) -> f64 {
        self.secs.iter().sum()
    }

    /// Fraction of total core-time in a category (0 if empty).
    pub fn frac(&self, cat: Category) -> f64 {
        let t = self.total();
        if t == 0.0 {
            0.0
        } else {
            self.get(cat) / t
        }
    }

    /// Busy (non-idle, non-barrier) fraction.
    pub fn busy_frac(&self) -> f64 {
        1.0 - self.frac(Category::Idle) - self.frac(Category::Barrier)
    }

    /// Merge another breakdown into this one.
    pub fn merge(&mut self, other: &Breakdown) {
        for (a, b) in self.secs.iter_mut().zip(other.secs.iter()) {
            *a += b;
        }
    }

    /// The paper's "programmability tax": the non-MKL fraction of the
    /// occupied core-time (§5.2 estimates it from the non-MKL stack-bar
    /// fractions, which include the barrier time that serial framework
    /// phases impose on waiting kernel threads). Pool-idle time (cores a
    /// setting never uses) is excluded.
    pub fn programmability_tax(&self) -> f64 {
        let lib = self.get(Category::MklCompute) + self.get(Category::MklPrep);
        let occupied = self.total() - self.get(Category::Idle);
        if occupied <= 0.0 {
            0.0
        } else {
            (occupied - lib) / occupied
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_get_roundtrip() {
        let mut b = Breakdown::new();
        b.add(Category::MklCompute, 2.0);
        b.add(Category::FwPrep, 1.0);
        b.add(Category::FwPrep, 0.5);
        assert_eq!(b.get(Category::MklCompute), 2.0);
        assert_eq!(b.get(Category::FwPrep), 1.5);
        assert_eq!(b.total(), 3.5);
    }

    #[test]
    fn fractions() {
        let mut b = Breakdown::new();
        b.add(Category::MklCompute, 3.0);
        b.add(Category::Idle, 1.0);
        assert!((b.frac(Category::MklCompute) - 0.75).abs() < 1e-12);
        assert!((b.busy_frac() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn tax_excludes_library_time() {
        let mut b = Breakdown::new();
        b.add(Category::MklCompute, 6.0);
        b.add(Category::MklPrep, 1.0);
        b.add(Category::FwPrep, 2.0);
        b.add(Category::FwNative, 1.0);
        b.add(Category::Barrier, 5.0); // counted: stalls caused by fw phases
        b.add(Category::Idle, 5.0); // excluded: cores the setting never uses
        // occupied = 15, lib = 7 → tax = 8/15
        assert!((b.programmability_tax() - 8.0 / 15.0).abs() < 1e-12);
    }

    #[test]
    fn merge_sums() {
        let mut a = Breakdown::new();
        a.add(Category::FwSched, 1.0);
        let mut b = Breakdown::new();
        b.add(Category::FwSched, 2.0);
        a.merge(&b);
        assert_eq!(a.get(Category::FwSched), 3.0);
    }

    #[test]
    fn labels_unique() {
        let labels: std::collections::HashSet<_> =
            Category::ALL.iter().map(|c| c.label()).collect();
        assert_eq!(labels.len(), Category::ALL.len());
    }
}
