//! Simulator calibration constants.
//!
//! Anchored on the paper's measured ratios (not absolute values):
//!
//! * MatMul-512 spends ~10% of single-MKL-thread time in TF data prep and
//!   >72% with 24 threads (Fig. 10); MatMul-4k < 3% in both.
//! * Max TF-operator speedup at 24 threads ≈ 16× (Fig. 9).
//! * Thread-pool micro-task overheads: Folly < Eigen < std::thread, with
//!   std::thread degrading >3× at 16× oversubscription (Fig. 14).
//! * Effective UPI ceiling ≈ 100 GB/s of the 120 GB/s peak (Fig. 16).

use crate::config::PoolLib;

/// Framework-native data-prep processing rate per core (bytes/s). Tensor
/// validation + marshalling, not a raw memcpy.
pub const FW_PREP_RATE: f64 = 2.0e9;

/// Framework MatMul prep is O(n) in the paper (§5.1): bytes of prep work
/// per unit of the leading GEMM dimension.
pub const FW_PREP_BYTES_PER_ROW: f64 = 2048.0;

/// Library-internal packing rate (bytes/s), serial portion inside the
/// kernel (Fig. 10's "MKL data prep").
pub const LIB_PACK_RATE: f64 = 12.0e9;

/// Framework-native (non-kernel) op processing rate per core (bytes/s).
pub const FW_NATIVE_RATE: f64 = 4.0e9;

/// Native-op FLOPs run at this fraction of one core's peak (interpreted,
/// non-vectorised framework code).
pub const FW_NATIVE_FLOP_EFF: f64 = 0.08;

/// Fraction of DRAM bandwidth one embedding gather can stream.
pub const EMBEDDING_BW_FRAC: f64 = 0.6;

/// Over-threading penalty: latency multiplier grows with
/// `1 + OVERTHREAD_SLOPE * log2(software_threads / logical_cores)`.
pub const OVERTHREAD_SLOPE: f64 = 0.18;

/// Effective UPI ceiling as a fraction of the platform peak (the paper
/// measures ~100 of 120 GB/s).
pub const UPI_EFFECTIVE_FRAC: f64 = 100.0 / 120.0;

/// Beyond this working-set multiple of the socket LLC, cross-socket
/// traffic re-transfers panels (the 16k falloff in Fig. 16).
pub const UPI_THRASH_LLC_MULT: f64 = 220.0;

/// Per-task dispatch overhead (seconds) of each pool library at its sweet
/// spot (threads ≤ physical cores) — Fig. 14's left cluster.
pub fn pool_dispatch_overhead(lib: PoolLib) -> f64 {
    match lib {
        PoolLib::StdThread => 3.0e-6,
        PoolLib::Eigen => 1.6e-6,
        PoolLib::Folly => 0.9e-6,
    }
}

/// Growth of dispatch overhead when `threads` oversubscribe `cores`
/// hardware threads (Fig. 14's right cluster: std::thread degrades >3×,
/// Eigen/Folly stay roughly flat).
pub fn pool_oversubscription_factor(lib: PoolLib, threads: usize, hw_threads: usize) -> f64 {
    if threads <= hw_threads {
        return 1.0;
    }
    let ratio = threads as f64 / hw_threads as f64;
    match lib {
        // broadcast wake-ups: every task wakes all sleepers
        PoolLib::StdThread => 1.0 + 0.16 * (ratio - 1.0) * ratio.log2().max(1.0),
        PoolLib::Eigen => 1.0 + 0.04 * ratio.log2(),
        PoolLib::Folly => 1.0 + 0.02 * ratio.log2(),
    }
}

/// Per-operator scheduling cost on the pool's main thread: dispatch plus a
/// wake-up per worker notified.
pub fn sched_overhead(lib: PoolLib, pool_threads: usize) -> f64 {
    pool_dispatch_overhead(lib) * (1.0 + 0.25 * (pool_threads as f64).log2().max(0.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn folly_cheapest() {
        assert!(pool_dispatch_overhead(PoolLib::Folly) < pool_dispatch_overhead(PoolLib::Eigen));
        assert!(pool_dispatch_overhead(PoolLib::Eigen) < pool_dispatch_overhead(PoolLib::StdThread));
    }

    #[test]
    fn std_degrades_3x_at_16x_oversub() {
        // Fig. 14: 64 threads on a 4-core (8 HT) machine
        let f = pool_oversubscription_factor(PoolLib::StdThread, 64, 8);
        assert!(f > 3.0, "{f}");
        assert!(pool_oversubscription_factor(PoolLib::Folly, 64, 8) < 1.2);
        assert!(pool_oversubscription_factor(PoolLib::Eigen, 64, 8) < 1.3);
    }

    #[test]
    fn no_penalty_within_hw() {
        for lib in PoolLib::ALL {
            assert_eq!(pool_oversubscription_factor(lib, 8, 8), 1.0);
        }
    }

    #[test]
    fn sched_overhead_grows_with_pool_size() {
        let small = sched_overhead(PoolLib::Folly, 2);
        let big = sched_overhead(PoolLib::Folly, 48);
        assert!(big > small);
    }
}
