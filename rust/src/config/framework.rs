//! Framework configuration — the five design features of the paper's Fig. 2,
//! plus the dispatch-order axis they imply.
//!
//! * scheduling mechanism → [`FrameworkConfig::inter_op_pools`] (1 = fully
//!   synchronous, >1 = asynchronous over that many pools),
//! * scheduling policy → [`SchedPolicy`] (which ready operator a free pool
//!   picks up next — topological, critical-path-first, or costliest-first),
//! * operator design → [`OperatorImpl`] (`MatMul1` serial data-prep vs
//!   `MatMul2` intra-op-parallel data-prep),
//! * math library back end → [`MathLib`],
//! * thread-pool library → [`PoolLib`],
//! * beyond-one-socket mechanism → [`ParallelismMode`].

use crate::error::PallasError;

use super::platform::CpuPlatform;

/// How ready operators are prioritised for dispatch to free inter-op
/// pools. Runtime concurrency-control work (Liu et al., arXiv 1810.08955)
/// shows ready-op priority is itself a large performance lever on wide
/// graphs, so it is a first-class tunable dimension here.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SchedPolicy {
    /// Dispatch in topological id order (lowest node id first) — the
    /// insertion-order behaviour frameworks default to.
    Topo,
    /// HEFT-style upward-rank priority: the ready op with the costliest
    /// remaining downstream path dispatches first, keeping the critical
    /// path flowing while off-path ops fill scheduling bubbles.
    CriticalPathFirst,
    /// Largest-op-first: greedy by the op's own cost, ignoring graph
    /// structure (the classic LPT heuristic).
    CostlyFirst,
}

impl SchedPolicy {
    /// All supported policies.
    pub const ALL: [SchedPolicy; 3] =
        [SchedPolicy::Topo, SchedPolicy::CriticalPathFirst, SchedPolicy::CostlyFirst];

    /// Parse a CLI name.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "topo" | "topological" => Some(SchedPolicy::Topo),
            "critical-path" | "criticalpath" | "critical-path-first" | "cp" => {
                Some(SchedPolicy::CriticalPathFirst)
            }
            "costly" | "costly-first" | "costlyfirst" => Some(SchedPolicy::CostlyFirst),
            _ => None,
        }
    }

    /// Display name (also the canonical CLI spelling).
    pub fn name(&self) -> &'static str {
        match self {
            SchedPolicy::Topo => "topo",
            SchedPolicy::CriticalPathFirst => "critical-path",
            SchedPolicy::CostlyFirst => "costly",
        }
    }
}

/// Which math library provides the compute kernels (paper §6.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MathLib {
    /// Intel MKL: best GEMM, most effective software prefetching.
    Mkl,
    /// MKL-DNN (oneDNN): DL-specific kernels, slightly weaker GEMM.
    MklDnn,
    /// Eigen: portable C++ templates, least aggressive prefetching.
    Eigen,
}

impl MathLib {
    /// All supported libraries.
    pub const ALL: [MathLib; 3] = [MathLib::Mkl, MathLib::MklDnn, MathLib::Eigen];

    /// Parse a CLI name.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "mkl" => Some(MathLib::Mkl),
            "mkldnn" | "mkl-dnn" | "onednn" => Some(MathLib::MklDnn),
            "eigen" => Some(MathLib::Eigen),
            _ => None,
        }
    }

    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            MathLib::Mkl => "MKL",
            MathLib::MklDnn => "MKL-DNN",
            MathLib::Eigen => "Eigen",
        }
    }
}

/// Which thread-pool implementation dispatches tasks (paper §6.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PoolLib {
    /// Naive mutex + condvar pool over `std::thread`.
    StdThread,
    /// Eigen-style non-blocking pool with per-thread work-stealing deques.
    Eigen,
    /// Folly-style MPMC queue with LIFO wake-up semaphore.
    Folly,
}

impl PoolLib {
    /// All supported pool libraries.
    pub const ALL: [PoolLib; 3] = [PoolLib::StdThread, PoolLib::Eigen, PoolLib::Folly];

    /// Parse a CLI name.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "std" | "stdthread" | "std::thread" => Some(PoolLib::StdThread),
            "eigen" => Some(PoolLib::Eigen),
            "folly" => Some(PoolLib::Folly),
            _ => None,
        }
    }

    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            PoolLib::StdThread => "std::thread",
            PoolLib::Eigen => "Eigen",
            PoolLib::Folly => "Folly",
        }
    }
}

/// Operator implementation strategy (paper §5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OperatorImpl {
    /// `MatMul1`: framework-native data preparation runs serially on the
    /// pool's main thread before entering the library kernel.
    Serial,
    /// `MatMul2`: data preparation is split across an intra-op thread pool
    /// colocated with the kernel threads (hyperthread co-scheduling).
    IntraOpParallel,
}

/// How work is spread beyond one socket (paper §7).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ParallelismMode {
    /// Split the batch across sockets; weights are replicated, halves of
    /// the activations travel over UPI.
    DataParallel,
    /// Schedule different operators (inter-op pools) on different sockets.
    ModelParallel,
}

/// A complete framework parameter setting — one point in the design space
/// the paper sweeps (|settings| = logical_cores³ on `large.2`).
/// `Eq + Hash` so per-lane backend caches can key on the exact setting.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct FrameworkConfig {
    /// Number of independent asynchronous scheduling pools
    /// ("inter-op parallelism threads" in TensorFlow terms). 1 ⇒ fully
    /// synchronous scheduling.
    pub inter_op_pools: usize,
    /// Math-library (MKL) threads per pool — the intra-op kernel threads.
    pub mkl_threads: usize,
    /// Framework-level intra-op threads per pool (the `MatMul2` pool).
    pub intra_op_threads: usize,
    /// Operator implementation strategy.
    pub operator_impl: OperatorImpl,
    /// Math library back end.
    pub math_lib: MathLib,
    /// Thread-pool library.
    pub pool_lib: PoolLib,
    /// Beyond-one-socket mechanism.
    pub parallelism: ParallelismMode,
    /// Ready-operator dispatch policy for the inter-op scheduler.
    pub sched_policy: SchedPolicy,
    /// Bind one software thread per physical core first (Intel guidance).
    pub pin_threads: bool,
}

impl FrameworkConfig {
    /// The paper's tuned default: async pools with MatMul2 operators,
    /// MKL-DNN kernels and a Folly-class pool.
    pub fn tuned_default() -> Self {
        FrameworkConfig {
            inter_op_pools: 1,
            mkl_threads: 1,
            intra_op_threads: 1,
            operator_impl: OperatorImpl::IntraOpParallel,
            math_lib: MathLib::MklDnn,
            pool_lib: PoolLib::Folly,
            parallelism: ParallelismMode::DataParallel,
            sched_policy: SchedPolicy::Topo,
            pin_threads: true,
        }
    }

    /// TensorFlow performance-guide recommendation [14]: MKL/intra-op
    /// threads = physical cores, inter-op pools = sockets.
    pub fn tensorflow_recommended(p: &CpuPlatform) -> Self {
        FrameworkConfig {
            inter_op_pools: p.sockets,
            mkl_threads: p.physical_cores(),
            intra_op_threads: p.physical_cores(),
            ..Self::tuned_default()
        }
    }

    /// Intel blog recommendation [3]: MKL/intra-op threads = physical cores
    /// per socket, inter-op pools = sockets.
    pub fn intel_recommended(p: &CpuPlatform) -> Self {
        FrameworkConfig {
            inter_op_pools: p.sockets,
            mkl_threads: p.cores_per_socket,
            intra_op_threads: p.cores_per_socket,
            ..Self::tuned_default()
        }
    }

    /// TensorFlow's out-of-the-box default: every knob = logical cores.
    pub fn tensorflow_default(p: &CpuPlatform) -> Self {
        FrameworkConfig {
            inter_op_pools: p.logical_cores(),
            mkl_threads: p.logical_cores(),
            intra_op_threads: p.logical_cores(),
            ..Self::tuned_default()
        }
    }

    /// Total software threads this setting creates.
    pub fn total_threads(&self) -> usize {
        self.inter_op_pools * (self.mkl_threads + self.intra_op_threads)
    }

    /// True when more software threads than hardware threads exist
    /// ("over-threading" in the paper's Fig. 6).
    pub fn over_threaded(&self, p: &CpuPlatform) -> bool {
        self.total_threads() > p.logical_cores()
    }

    /// Sanity-check the setting against a platform.
    pub fn validate(&self, p: &CpuPlatform) -> Result<(), PallasError> {
        if self.inter_op_pools == 0 {
            return Err(PallasError::InvalidConfig("inter_op_pools must be >= 1".into()));
        }
        if self.mkl_threads == 0 {
            return Err(PallasError::InvalidConfig("mkl_threads must be >= 1".into()));
        }
        if self.intra_op_threads == 0 {
            return Err(PallasError::InvalidConfig("intra_op_threads must be >= 1".into()));
        }
        if self.inter_op_pools > p.logical_cores() {
            return Err(PallasError::InvalidConfig(format!(
                "inter_op_pools={} exceeds logical cores={}",
                self.inter_op_pools,
                p.logical_cores()
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recommended_settings_match_paper() {
        let l2 = CpuPlatform::large2();
        let tf = FrameworkConfig::tensorflow_recommended(&l2);
        assert_eq!((tf.inter_op_pools, tf.mkl_threads), (2, 48));
        let intel = FrameworkConfig::intel_recommended(&l2);
        assert_eq!((intel.inter_op_pools, intel.mkl_threads), (2, 24));
        let dflt = FrameworkConfig::tensorflow_default(&l2);
        assert_eq!((dflt.inter_op_pools, dflt.mkl_threads), (96, 96));
    }

    #[test]
    fn over_threading_detection() {
        let small = CpuPlatform::small();
        let mut c = FrameworkConfig::tuned_default();
        c.inter_op_pools = 4;
        c.mkl_threads = 4;
        c.intra_op_threads = 4;
        assert!(c.over_threaded(&small)); // 32 > 8
        c.inter_op_pools = 2;
        c.mkl_threads = 2;
        c.intra_op_threads = 2;
        assert!(!c.over_threaded(&small)); // 8 <= 8
    }

    #[test]
    fn validate_rejects_zeroes() {
        let p = CpuPlatform::small();
        let mut c = FrameworkConfig::tuned_default();
        c.inter_op_pools = 0;
        assert!(c.validate(&p).is_err());
        c = FrameworkConfig::tuned_default();
        c.mkl_threads = 0;
        assert!(c.validate(&p).is_err());
    }

    #[test]
    fn parse_enums() {
        assert_eq!(MathLib::parse("mkl-dnn"), Some(MathLib::MklDnn));
        assert_eq!(PoolLib::parse("folly"), Some(PoolLib::Folly));
        assert_eq!(MathLib::parse("cuda"), None);
        assert_eq!(SchedPolicy::parse("critical-path"), Some(SchedPolicy::CriticalPathFirst));
        assert_eq!(SchedPolicy::parse("costly"), Some(SchedPolicy::CostlyFirst));
        assert_eq!(SchedPolicy::parse("fifo"), None);
    }

    #[test]
    fn policy_names_roundtrip() {
        for p in SchedPolicy::ALL {
            assert_eq!(SchedPolicy::parse(p.name()), Some(p));
        }
    }

    #[test]
    fn default_policy_is_topo() {
        assert_eq!(FrameworkConfig::tuned_default().sched_policy, SchedPolicy::Topo);
    }
}
