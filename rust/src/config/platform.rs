//! Hardware platform descriptions (the paper's Table 1).
//!
//! These drive the discrete-event simulator in [`crate::sim`]: core counts,
//! SMT topology (two hyperthreads share one FMA unit), per-socket LLC and
//! memory bandwidth, and the inter-socket UPI link for `large.2`.

/// A CPU platform under study.
#[derive(Debug, Clone, PartialEq)]
pub struct CpuPlatform {
    /// Display name ("small", "large", "large.2").
    pub name: String,
    /// Number of CPU sockets.
    pub sockets: usize,
    /// Physical cores per socket.
    pub cores_per_socket: usize,
    /// Hyperthreads per physical core (2 on Skylake).
    pub smt: usize,
    /// Core frequency in GHz.
    pub freq_ghz: f64,
    /// Peak dense-FP32 GFLOP/s of ONE physical core (both hyperthreads
    /// share the FMA units, so SMT does not add peak FLOPs — paper §4.2).
    pub peak_gflops_per_core: f64,
    /// Last-level cache per socket, MiB.
    pub llc_mib_per_socket: f64,
    /// DRAM bandwidth per socket, GB/s.
    pub mem_bw_gbps: f64,
    /// Peak bidirectional UPI bandwidth between sockets, GB/s (0 when
    /// single-socket).
    pub upi_gbps: f64,
}

impl CpuPlatform {
    /// `small`: i7-6700K — 4 cores @ 4 GHz, 0.423 TFLOPS, 8 MiB LLC.
    pub fn small() -> Self {
        CpuPlatform {
            name: "small".into(),
            sockets: 1,
            cores_per_socket: 4,
            smt: 2,
            freq_ghz: 4.0,
            peak_gflops_per_core: 423.0 / 4.0,
            llc_mib_per_socket: 8.0,
            mem_bw_gbps: 34.0,
            upi_gbps: 0.0,
        }
    }

    /// `large`: Xeon Platinum 8175M — 24 cores @ 2.5 GHz, 1.64 TFLOPS,
    /// 33 MiB LLC.
    pub fn large() -> Self {
        CpuPlatform {
            name: "large".into(),
            sockets: 1,
            cores_per_socket: 24,
            smt: 2,
            freq_ghz: 2.5,
            peak_gflops_per_core: 1640.0 / 24.0,
            llc_mib_per_socket: 33.0,
            mem_bw_gbps: 100.0,
            upi_gbps: 0.0,
        }
    }

    /// `large.2`: two sockets of `large`, 120 GB/s peak bidirectional UPI.
    pub fn large2() -> Self {
        CpuPlatform {
            sockets: 2,
            name: "large.2".into(),
            upi_gbps: 120.0,
            ..Self::large()
        }
    }

    /// Look up a preset by name.
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "small" => Some(Self::small()),
            "large" => Some(Self::large()),
            "large.2" | "large2" => Some(Self::large2()),
            _ => None,
        }
    }

    /// Total physical cores across sockets.
    pub fn physical_cores(&self) -> usize {
        self.sockets * self.cores_per_socket
    }

    /// Total logical cores (hyperthreads).
    pub fn logical_cores(&self) -> usize {
        self.physical_cores() * self.smt
    }

    /// Peak GFLOP/s of the whole machine.
    pub fn peak_gflops(&self) -> f64 {
        self.peak_gflops_per_core * self.physical_cores() as f64
    }

    /// Socket that owns a given physical core index.
    pub fn socket_of(&self, phys_core: usize) -> usize {
        phys_core / self.cores_per_socket
    }

    /// A view of this platform restricted to a contiguous slice of
    /// physical cores (`first_core .. first_core + cores`). Per-socket
    /// shared resources — LLC capacity and DRAM bandwidth — are scaled by
    /// the fraction of each covered socket actually allocated, so lanes
    /// co-located on one box stop double-counting hardware: simulating a
    /// graph on the restricted view answers "how fast is this model on
    /// *my slice*", not "on the whole machine".
    pub fn restrict(&self, first_core: usize, cores: usize) -> CpuPlatform {
        let phys = self.physical_cores();
        let first = first_core.min(phys.saturating_sub(1));
        let cores = cores.clamp(1, phys - first);
        // per-socket share of the slice; the simulator models sockets
        // symmetrically, so a slice that only *dips* into a neighbouring
        // socket (minority share < ¼ of the majority) is modelled as its
        // majority socket alone — the stray cores bring NUMA traffic,
        // not symmetric capacity, and pretending 24+1 cores are 2×12
        // would mis-rank candidate plans
        let first_socket = self.socket_of(first);
        let last_socket = self.socket_of(first + cores - 1);
        let mut span = last_socket - first_socket + 1;
        let mut eff_cores = cores;
        if span > 1 {
            let shares: Vec<usize> = (first_socket..=last_socket)
                .map(|s| {
                    let lo = (s * self.cores_per_socket).max(first);
                    let hi = ((s + 1) * self.cores_per_socket).min(first + cores);
                    hi - lo
                })
                .collect();
            let max = *shares.iter().max().unwrap();
            let min = *shares.iter().min().unwrap();
            if min * 4 < max {
                span = 1;
                eff_cores = max;
            } else {
                // near-even straddle: symmetric split, floored
                eff_cores = (cores / span) * span;
            }
        }
        let cps = (eff_cores / span).max(1);
        let frac = (cps as f64 / self.cores_per_socket as f64).min(1.0);
        CpuPlatform {
            name: format!("{}[{first}+{cores}]", self.name),
            sockets: span,
            cores_per_socket: cps,
            llc_mib_per_socket: self.llc_mib_per_socket * frac,
            mem_bw_gbps: self.mem_bw_gbps * frac,
            upi_gbps: if span > 1 { self.upi_gbps } else { 0.0 },
            ..self.clone()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_table1() {
        let s = CpuPlatform::small();
        assert_eq!(s.physical_cores(), 4);
        assert_eq!(s.logical_cores(), 8);
        assert!((s.peak_gflops() - 423.0).abs() < 1e-9);

        let l = CpuPlatform::large();
        assert_eq!(l.physical_cores(), 24);
        assert_eq!(l.logical_cores(), 48);
        assert!((l.peak_gflops() - 1640.0).abs() < 1e-9);

        let l2 = CpuPlatform::large2();
        assert_eq!(l2.physical_cores(), 48);
        assert_eq!(l2.logical_cores(), 96);
        assert_eq!(l2.upi_gbps, 120.0);
    }

    #[test]
    fn by_name_roundtrip() {
        for n in ["small", "large", "large.2"] {
            assert_eq!(CpuPlatform::by_name(n).unwrap().name, n);
        }
        assert!(CpuPlatform::by_name("gpu").is_none());
    }

    #[test]
    fn restrict_single_socket_slice() {
        let l = CpuPlatform::large();
        let r = l.restrict(0, 8);
        assert_eq!(r.physical_cores(), 8);
        assert_eq!(r.sockets, 1);
        // a third of the socket's cores ⇒ a third of its LLC + bandwidth
        assert!((r.mem_bw_gbps - 100.0 / 3.0).abs() < 1e-9);
        assert!((r.llc_mib_per_socket - 11.0).abs() < 1e-9);
        assert_eq!(r.upi_gbps, 0.0);
        // per-core capability is untouched
        assert_eq!(r.freq_ghz, l.freq_ghz);
        assert_eq!(r.peak_gflops_per_core, l.peak_gflops_per_core);
    }

    #[test]
    fn restrict_spanning_sockets_keeps_upi() {
        let l2 = CpuPlatform::large2();
        let r = l2.restrict(12, 24); // cores 12..=35: 12 on each socket
        assert_eq!(r.sockets, 2);
        assert_eq!(r.physical_cores(), 24);
        assert_eq!(r.upi_gbps, 120.0);
        assert!((r.mem_bw_gbps - 50.0).abs() < 1e-9);
        // within one socket the UPI link disappears
        let one = l2.restrict(24, 24);
        assert_eq!(one.sockets, 1);
        assert_eq!(one.upi_gbps, 0.0);
        assert!((one.mem_bw_gbps - 100.0).abs() < 1e-9);
    }

    #[test]
    fn restrict_uneven_straddle_models_majority_socket() {
        // 25 cores = 24 on socket 0 + 1 on socket 1: NOT a symmetric
        // 2×12 machine — modelled as the majority socket alone
        let l2 = CpuPlatform::large2();
        let r = l2.restrict(0, 25);
        assert_eq!(r.sockets, 1);
        assert_eq!(r.physical_cores(), 24);
        assert_eq!(r.upi_gbps, 0.0);
        assert!((r.mem_bw_gbps - 100.0).abs() < 1e-9);
        // a 16+8 straddle is close enough to even to keep both sockets
        let s = l2.restrict(8, 24);
        assert_eq!(s.sockets, 2);
        assert_eq!(s.physical_cores(), 24);
        assert_eq!(s.upi_gbps, 120.0);
    }

    #[test]
    fn restrict_clamps_out_of_range() {
        let s = CpuPlatform::small();
        let r = s.restrict(2, 100);
        assert_eq!(r.physical_cores(), 2);
        let whole = s.restrict(0, 4);
        assert_eq!(whole.physical_cores(), 4);
        assert!((whole.mem_bw_gbps - s.mem_bw_gbps).abs() < 1e-9);
        let zero = s.restrict(0, 0);
        assert_eq!(zero.physical_cores(), 1);
    }

    #[test]
    fn socket_of_split() {
        let l2 = CpuPlatform::large2();
        assert_eq!(l2.socket_of(0), 0);
        assert_eq!(l2.socket_of(23), 0);
        assert_eq!(l2.socket_of(24), 1);
        assert_eq!(l2.socket_of(47), 1);
    }
}
