//! Hardware platform descriptions (the paper's Table 1).
//!
//! These drive the discrete-event simulator in [`crate::sim`]: core counts,
//! SMT topology (two hyperthreads share one FMA unit), per-socket LLC and
//! memory bandwidth, and the inter-socket UPI link for `large.2`.

/// A CPU platform under study.
#[derive(Debug, Clone, PartialEq)]
pub struct CpuPlatform {
    /// Display name ("small", "large", "large.2").
    pub name: String,
    /// Number of CPU sockets.
    pub sockets: usize,
    /// Physical cores per socket.
    pub cores_per_socket: usize,
    /// Hyperthreads per physical core (2 on Skylake).
    pub smt: usize,
    /// Core frequency in GHz.
    pub freq_ghz: f64,
    /// Peak dense-FP32 GFLOP/s of ONE physical core (both hyperthreads
    /// share the FMA units, so SMT does not add peak FLOPs — paper §4.2).
    pub peak_gflops_per_core: f64,
    /// Last-level cache per socket, MiB.
    pub llc_mib_per_socket: f64,
    /// DRAM bandwidth per socket, GB/s.
    pub mem_bw_gbps: f64,
    /// Peak bidirectional UPI bandwidth between sockets, GB/s (0 when
    /// single-socket).
    pub upi_gbps: f64,
}

impl CpuPlatform {
    /// `small`: i7-6700K — 4 cores @ 4 GHz, 0.423 TFLOPS, 8 MiB LLC.
    pub fn small() -> Self {
        CpuPlatform {
            name: "small".into(),
            sockets: 1,
            cores_per_socket: 4,
            smt: 2,
            freq_ghz: 4.0,
            peak_gflops_per_core: 423.0 / 4.0,
            llc_mib_per_socket: 8.0,
            mem_bw_gbps: 34.0,
            upi_gbps: 0.0,
        }
    }

    /// `large`: Xeon Platinum 8175M — 24 cores @ 2.5 GHz, 1.64 TFLOPS,
    /// 33 MiB LLC.
    pub fn large() -> Self {
        CpuPlatform {
            name: "large".into(),
            sockets: 1,
            cores_per_socket: 24,
            smt: 2,
            freq_ghz: 2.5,
            peak_gflops_per_core: 1640.0 / 24.0,
            llc_mib_per_socket: 33.0,
            mem_bw_gbps: 100.0,
            upi_gbps: 0.0,
        }
    }

    /// `large.2`: two sockets of `large`, 120 GB/s peak bidirectional UPI.
    pub fn large2() -> Self {
        CpuPlatform {
            sockets: 2,
            name: "large.2".into(),
            upi_gbps: 120.0,
            ..Self::large()
        }
    }

    /// Look up a preset by name.
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "small" => Some(Self::small()),
            "large" => Some(Self::large()),
            "large.2" | "large2" => Some(Self::large2()),
            _ => None,
        }
    }

    /// Total physical cores across sockets.
    pub fn physical_cores(&self) -> usize {
        self.sockets * self.cores_per_socket
    }

    /// Total logical cores (hyperthreads).
    pub fn logical_cores(&self) -> usize {
        self.physical_cores() * self.smt
    }

    /// Peak GFLOP/s of the whole machine.
    pub fn peak_gflops(&self) -> f64 {
        self.peak_gflops_per_core * self.physical_cores() as f64
    }

    /// Socket that owns a given physical core index.
    pub fn socket_of(&self, phys_core: usize) -> usize {
        phys_core / self.cores_per_socket
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_table1() {
        let s = CpuPlatform::small();
        assert_eq!(s.physical_cores(), 4);
        assert_eq!(s.logical_cores(), 8);
        assert!((s.peak_gflops() - 423.0).abs() < 1e-9);

        let l = CpuPlatform::large();
        assert_eq!(l.physical_cores(), 24);
        assert_eq!(l.logical_cores(), 48);
        assert!((l.peak_gflops() - 1640.0).abs() < 1e-9);

        let l2 = CpuPlatform::large2();
        assert_eq!(l2.physical_cores(), 48);
        assert_eq!(l2.logical_cores(), 96);
        assert_eq!(l2.upi_gbps, 120.0);
    }

    #[test]
    fn by_name_roundtrip() {
        for n in ["small", "large", "large.2"] {
            assert_eq!(CpuPlatform::by_name(n).unwrap().name, n);
        }
        assert!(CpuPlatform::by_name("gpu").is_none());
    }

    #[test]
    fn socket_of_split() {
        let l2 = CpuPlatform::large2();
        assert_eq!(l2.socket_of(0), 0);
        assert_eq!(l2.socket_of(23), 0);
        assert_eq!(l2.socket_of(24), 1);
        assert_eq!(l2.socket_of(47), 1);
    }
}
