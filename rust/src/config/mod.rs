//! Configuration: hardware platforms (paper Table 1), framework knobs
//! (paper Fig. 2), and the JSON config-file loader.

pub mod framework;
pub mod loader;
pub mod platform;

pub use framework::{FrameworkConfig, MathLib, OperatorImpl, ParallelismMode, PoolLib, SchedPolicy};
pub use loader::{apply_framework_keys, framework_from_json, framework_to_json, RunConfig};
pub use platform::CpuPlatform;
