//! Config-file loader.
//!
//! Deployments describe a run with a small JSON document (TOML is not
//! available offline; the schema is flat enough that JSON stays readable):
//!
//! ```json
//! {
//!   "platform": "large.2",
//!   "inter_op_pools": 3,
//!   "mkl_threads": 16,
//!   "intra_op_threads": 16,
//!   "operator_impl": "intra_op_parallel",
//!   "math_lib": "mkl-dnn",
//!   "pool_lib": "folly",
//!   "parallelism": "data",
//!   "sched_policy": "critical-path",
//!   "pin_threads": true
//! }
//! ```
//!
//! Every field is optional; omitted knobs keep their
//! [`FrameworkConfig::tuned_default`] value, and omitted `platform` means
//! `large`.

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

use super::framework::{
    FrameworkConfig, MathLib, OperatorImpl, ParallelismMode, PoolLib, SchedPolicy,
};
use super::platform::CpuPlatform;

/// A fully-resolved run configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct RunConfig {
    /// Hardware platform the simulator models.
    pub platform: CpuPlatform,
    /// Framework knob setting.
    pub framework: FrameworkConfig,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            platform: CpuPlatform::large(),
            framework: FrameworkConfig::tuned_default(),
        }
    }
}

impl RunConfig {
    /// Parse a JSON config document.
    pub fn from_json_str(text: &str) -> Result<Self> {
        let doc = Json::parse(text).map_err(|e| anyhow!("{e}"))?;
        let mut cfg = RunConfig::default();

        if let Some(p) = doc.get("platform") {
            let name = p.as_str().context("platform must be a string")?;
            cfg.platform = CpuPlatform::by_name(name)
                .ok_or_else(|| anyhow!("unknown platform '{name}'"))?;
        }
        let fw = &mut cfg.framework;
        if let Some(v) = doc.get("inter_op_pools") {
            fw.inter_op_pools = usize_field(v, "inter_op_pools")?;
        }
        if let Some(v) = doc.get("mkl_threads") {
            fw.mkl_threads = usize_field(v, "mkl_threads")?;
        }
        if let Some(v) = doc.get("intra_op_threads") {
            fw.intra_op_threads = usize_field(v, "intra_op_threads")?;
        }
        if let Some(v) = doc.get("operator_impl") {
            fw.operator_impl = match v.as_str() {
                Some("serial") | Some("matmul1") => OperatorImpl::Serial,
                Some("intra_op_parallel") | Some("matmul2") => OperatorImpl::IntraOpParallel,
                other => bail!("bad operator_impl: {other:?}"),
            };
        }
        if let Some(v) = doc.get("math_lib") {
            let s = v.as_str().context("math_lib must be a string")?;
            fw.math_lib = MathLib::parse(s).ok_or_else(|| anyhow!("bad math_lib '{s}'"))?;
        }
        if let Some(v) = doc.get("pool_lib") {
            let s = v.as_str().context("pool_lib must be a string")?;
            fw.pool_lib = PoolLib::parse(s).ok_or_else(|| anyhow!("bad pool_lib '{s}'"))?;
        }
        if let Some(v) = doc.get("parallelism") {
            fw.parallelism = match v.as_str() {
                Some("data") => ParallelismMode::DataParallel,
                Some("model") => ParallelismMode::ModelParallel,
                other => bail!("bad parallelism: {other:?}"),
            };
        }
        if let Some(v) = doc.get("sched_policy") {
            let s = v.as_str().context("sched_policy must be a string")?;
            fw.sched_policy =
                SchedPolicy::parse(s).ok_or_else(|| anyhow!("bad sched_policy '{s}'"))?;
        }
        if let Some(v) = doc.get("pin_threads") {
            fw.pin_threads = matches!(v, Json::Bool(true));
        }
        fw.validate(&cfg.platform).map_err(|e| anyhow!(e))?;
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        Self::from_json_str(&text)
    }

    /// Apply `key=value` CLI overrides on top of this config.
    pub fn apply_override(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "platform" => {
                self.platform = CpuPlatform::by_name(value)
                    .ok_or_else(|| anyhow!("unknown platform '{value}'"))?;
            }
            "inter_op_pools" => self.framework.inter_op_pools = value.parse()?,
            "mkl_threads" => self.framework.mkl_threads = value.parse()?,
            "intra_op_threads" => self.framework.intra_op_threads = value.parse()?,
            "math_lib" => {
                self.framework.math_lib =
                    MathLib::parse(value).ok_or_else(|| anyhow!("bad math_lib '{value}'"))?;
            }
            "pool_lib" => {
                self.framework.pool_lib =
                    PoolLib::parse(value).ok_or_else(|| anyhow!("bad pool_lib '{value}'"))?;
            }
            "operator_impl" => {
                self.framework.operator_impl = match value {
                    "serial" | "matmul1" => OperatorImpl::Serial,
                    "intra_op_parallel" | "matmul2" => OperatorImpl::IntraOpParallel,
                    _ => bail!("bad operator_impl '{value}'"),
                };
            }
            "sched_policy" => {
                self.framework.sched_policy = SchedPolicy::parse(value)
                    .ok_or_else(|| anyhow!("bad sched_policy '{value}'"))?;
            }
            _ => bail!("unknown config key '{key}'"),
        }
        Ok(())
    }
}

fn usize_field(v: &Json, name: &str) -> Result<usize> {
    v.as_usize().with_context(|| format!("{name} must be a number"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_document() {
        let cfg = RunConfig::from_json_str(
            r#"{"platform":"large.2","inter_op_pools":3,"mkl_threads":16,
                "intra_op_threads":16,"operator_impl":"matmul2",
                "math_lib":"mkl","pool_lib":"eigen","parallelism":"model",
                "pin_threads":true}"#,
        )
        .unwrap();
        assert_eq!(cfg.platform.name, "large.2");
        assert_eq!(cfg.framework.inter_op_pools, 3);
        assert_eq!(cfg.framework.mkl_threads, 16);
        assert_eq!(cfg.framework.math_lib, MathLib::Mkl);
        assert_eq!(cfg.framework.pool_lib, PoolLib::Eigen);
        assert_eq!(cfg.framework.parallelism, ParallelismMode::ModelParallel);
    }

    #[test]
    fn defaults_fill_in() {
        let cfg = RunConfig::from_json_str("{}").unwrap();
        assert_eq!(cfg.platform.name, "large");
        assert_eq!(cfg.framework, FrameworkConfig::tuned_default());
    }

    #[test]
    fn rejects_bad_values() {
        assert!(RunConfig::from_json_str(r#"{"platform":"tpu"}"#).is_err());
        assert!(RunConfig::from_json_str(r#"{"math_lib":"blas"}"#).is_err());
        assert!(RunConfig::from_json_str(r#"{"inter_op_pools":0}"#).is_err());
        assert!(RunConfig::from_json_str(r#"{"sched_policy":"fifo"}"#).is_err());
    }

    #[test]
    fn parses_sched_policy() {
        let cfg = RunConfig::from_json_str(r#"{"sched_policy":"critical-path"}"#).unwrap();
        assert_eq!(cfg.framework.sched_policy, SchedPolicy::CriticalPathFirst);
        let mut cfg = RunConfig::default();
        cfg.apply_override("sched_policy", "costly").unwrap();
        assert_eq!(cfg.framework.sched_policy, SchedPolicy::CostlyFirst);
    }

    #[test]
    fn cli_overrides() {
        let mut cfg = RunConfig::default();
        cfg.apply_override("platform", "small").unwrap();
        cfg.apply_override("mkl_threads", "4").unwrap();
        assert_eq!(cfg.platform.name, "small");
        assert_eq!(cfg.framework.mkl_threads, 4);
        assert!(cfg.apply_override("bogus", "1").is_err());
    }
}
