//! Config-file loader.
//!
//! Deployments describe a run with a small JSON document (TOML is not
//! available offline; the schema is flat enough that JSON stays readable):
//!
//! ```json
//! {
//!   "platform": "large.2",
//!   "inter_op_pools": 3,
//!   "mkl_threads": 16,
//!   "intra_op_threads": 16,
//!   "operator_impl": "intra_op_parallel",
//!   "math_lib": "mkl-dnn",
//!   "pool_lib": "folly",
//!   "parallelism": "data",
//!   "sched_policy": "critical-path",
//!   "pin_threads": true
//! }
//! ```
//!
//! Every field is optional; omitted knobs keep their
//! [`FrameworkConfig::tuned_default`] value, and omitted `platform` means
//! `large`. **Unknown keys are rejected** — a typo'd `sched_polcy` fails
//! loudly with [`PallasError::InvalidConfig`] instead of silently falling
//! back to defaults.
//!
//! The per-knob JSON mapping lives in [`apply_framework_keys`] /
//! [`framework_to_json`], shared with the serializable tuning-plan
//! artifact ([`crate::api::Plan`]) so the two documents can never drift.

use std::collections::BTreeMap;

use crate::error::{PallasError, PallasResult};
use crate::util::json::Json;

use super::framework::{
    FrameworkConfig, MathLib, OperatorImpl, ParallelismMode, PoolLib, SchedPolicy,
};
use super::platform::CpuPlatform;

/// The framework-knob keys [`apply_framework_keys`] understands, in
/// document order (also the accepted-key list quoted in errors).
pub const FRAMEWORK_KEYS: [&str; 9] = [
    "inter_op_pools",
    "mkl_threads",
    "intra_op_threads",
    "operator_impl",
    "math_lib",
    "pool_lib",
    "parallelism",
    "sched_policy",
    "pin_threads",
];

/// A fully-resolved run configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct RunConfig {
    /// Hardware platform the simulator models.
    pub platform: CpuPlatform,
    /// Framework knob setting.
    pub framework: FrameworkConfig,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            platform: CpuPlatform::large(),
            framework: FrameworkConfig::tuned_default(),
        }
    }
}

impl RunConfig {
    /// Parse a JSON config document. Unknown keys are rejected.
    pub fn from_json_str(text: &str) -> PallasResult<Self> {
        let doc = Json::parse(text)?;
        let obj = doc
            .as_obj()
            .ok_or_else(|| PallasError::InvalidConfig("config must be a JSON object".into()))?;
        let mut cfg = RunConfig::default();

        for key in obj.keys() {
            if key != "platform" && !FRAMEWORK_KEYS.contains(&key.as_str()) {
                return Err(unknown_key_error(key));
            }
        }
        if let Some(p) = obj.get("platform") {
            let name = p
                .as_str()
                .ok_or_else(|| PallasError::InvalidConfig("platform must be a string".into()))?;
            cfg.platform = CpuPlatform::by_name(name)
                .ok_or_else(|| PallasError::UnknownPlatform(name.to_string()))?;
        }
        apply_framework_keys(&mut cfg.framework, obj)?;
        cfg.framework.validate(&cfg.platform)?;
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn from_file(path: &str) -> PallasResult<Self> {
        let text = std::fs::read_to_string(path).map_err(|e| PallasError::io(path, e))?;
        Self::from_json_str(&text)
    }

    /// Apply `key=value` CLI overrides on top of this config.
    pub fn apply_override(&mut self, key: &str, value: &str) -> PallasResult<()> {
        match key {
            "platform" => {
                self.platform = CpuPlatform::by_name(value)
                    .ok_or_else(|| PallasError::UnknownPlatform(value.to_string()))?;
            }
            "inter_op_pools" => self.framework.inter_op_pools = parse_usize(key, value)?,
            "mkl_threads" => self.framework.mkl_threads = parse_usize(key, value)?,
            "intra_op_threads" => self.framework.intra_op_threads = parse_usize(key, value)?,
            "math_lib" => {
                self.framework.math_lib = MathLib::parse(value)
                    .ok_or_else(|| PallasError::InvalidConfig(format!("bad math_lib '{value}'")))?;
            }
            "pool_lib" => {
                self.framework.pool_lib = PoolLib::parse(value)
                    .ok_or_else(|| PallasError::InvalidConfig(format!("bad pool_lib '{value}'")))?;
            }
            "operator_impl" => {
                self.framework.operator_impl = parse_operator_impl(value)?;
            }
            "sched_policy" => {
                self.framework.sched_policy = SchedPolicy::parse(value)
                    .ok_or_else(|| PallasError::UnknownPolicy(value.to_string()))?;
            }
            _ => return Err(unknown_key_error(key)),
        }
        Ok(())
    }
}

fn unknown_key_error(key: &str) -> PallasError {
    PallasError::InvalidConfig(format!(
        "unknown config key '{key}' (accepted: platform, {})",
        FRAMEWORK_KEYS.join(", ")
    ))
}

fn parse_usize(name: &str, value: &str) -> PallasResult<usize> {
    value
        .parse::<usize>()
        .map_err(|_| PallasError::InvalidConfig(format!("{name} must be a number, got '{value}'")))
}

fn parse_operator_impl(value: &str) -> PallasResult<OperatorImpl> {
    match value {
        "serial" | "matmul1" => Ok(OperatorImpl::Serial),
        "intra_op_parallel" | "matmul2" => Ok(OperatorImpl::IntraOpParallel),
        _ => Err(PallasError::InvalidConfig(format!("bad operator_impl '{value}'"))),
    }
}

/// Canonical JSON spelling of each enum knob (the inverse of what
/// [`apply_framework_keys`] parses — round-trips exactly).
fn operator_impl_name(v: OperatorImpl) -> &'static str {
    match v {
        OperatorImpl::Serial => "serial",
        OperatorImpl::IntraOpParallel => "intra_op_parallel",
    }
}

fn math_lib_name(v: MathLib) -> &'static str {
    match v {
        MathLib::Mkl => "mkl",
        MathLib::MklDnn => "mkl-dnn",
        MathLib::Eigen => "eigen",
    }
}

fn pool_lib_name(v: PoolLib) -> &'static str {
    match v {
        PoolLib::StdThread => "std",
        PoolLib::Eigen => "eigen",
        PoolLib::Folly => "folly",
    }
}

fn parallelism_name(v: ParallelismMode) -> &'static str {
    match v {
        ParallelismMode::DataParallel => "data",
        ParallelismMode::ModelParallel => "model",
    }
}

/// Fold the framework-knob keys of a JSON object into `fw`. Keys outside
/// [`FRAMEWORK_KEYS`] are the **caller's** responsibility to reject (so
/// documents embedding a config object alongside other keys — like the
/// plan artifact — can reuse this); values of the wrong shape fail with
/// [`PallasError::InvalidConfig`].
pub fn apply_framework_keys(
    fw: &mut FrameworkConfig,
    obj: &BTreeMap<String, Json>,
) -> PallasResult<()> {
    let usize_field = |v: &Json, name: &str| -> PallasResult<usize> {
        v.as_usize()
            .ok_or_else(|| PallasError::InvalidConfig(format!("{name} must be a number")))
    };
    let str_field = |v: &Json, name: &str| -> PallasResult<String> {
        Ok(v.as_str()
            .ok_or_else(|| PallasError::InvalidConfig(format!("{name} must be a string")))?
            .to_string())
    };
    if let Some(v) = obj.get("inter_op_pools") {
        fw.inter_op_pools = usize_field(v, "inter_op_pools")?;
    }
    if let Some(v) = obj.get("mkl_threads") {
        fw.mkl_threads = usize_field(v, "mkl_threads")?;
    }
    if let Some(v) = obj.get("intra_op_threads") {
        fw.intra_op_threads = usize_field(v, "intra_op_threads")?;
    }
    if let Some(v) = obj.get("operator_impl") {
        fw.operator_impl = parse_operator_impl(&str_field(v, "operator_impl")?)?;
    }
    if let Some(v) = obj.get("math_lib") {
        let s = str_field(v, "math_lib")?;
        fw.math_lib = MathLib::parse(&s)
            .ok_or_else(|| PallasError::InvalidConfig(format!("bad math_lib '{s}'")))?;
    }
    if let Some(v) = obj.get("pool_lib") {
        let s = str_field(v, "pool_lib")?;
        fw.pool_lib = PoolLib::parse(&s)
            .ok_or_else(|| PallasError::InvalidConfig(format!("bad pool_lib '{s}'")))?;
    }
    if let Some(v) = obj.get("parallelism") {
        fw.parallelism = match str_field(v, "parallelism")?.as_str() {
            "data" => ParallelismMode::DataParallel,
            "model" => ParallelismMode::ModelParallel,
            other => {
                return Err(PallasError::InvalidConfig(format!("bad parallelism '{other}'")))
            }
        };
    }
    if let Some(v) = obj.get("sched_policy") {
        let s = str_field(v, "sched_policy")?;
        fw.sched_policy =
            SchedPolicy::parse(&s).ok_or_else(|| PallasError::UnknownPolicy(s.clone()))?;
    }
    if let Some(v) = obj.get("pin_threads") {
        fw.pin_threads = match v {
            Json::Bool(b) => *b,
            _ => {
                return Err(PallasError::InvalidConfig(
                    "pin_threads must be a boolean".into(),
                ))
            }
        };
    }
    Ok(())
}

/// Serialize a framework setting as the JSON object
/// [`apply_framework_keys`] parses back exactly (every knob explicit, so
/// a deserialized plan never depends on future default changes).
pub fn framework_to_json(fw: &FrameworkConfig) -> Json {
    let mut m = BTreeMap::new();
    m.insert("inter_op_pools".into(), Json::Num(fw.inter_op_pools as f64));
    m.insert("mkl_threads".into(), Json::Num(fw.mkl_threads as f64));
    m.insert("intra_op_threads".into(), Json::Num(fw.intra_op_threads as f64));
    m.insert("operator_impl".into(), Json::Str(operator_impl_name(fw.operator_impl).into()));
    m.insert("math_lib".into(), Json::Str(math_lib_name(fw.math_lib).into()));
    m.insert("pool_lib".into(), Json::Str(pool_lib_name(fw.pool_lib).into()));
    m.insert("parallelism".into(), Json::Str(parallelism_name(fw.parallelism).into()));
    m.insert("sched_policy".into(), Json::Str(fw.sched_policy.name().into()));
    m.insert("pin_threads".into(), Json::Bool(fw.pin_threads));
    Json::Obj(m)
}

/// Parse a framework setting from a full JSON object produced by
/// [`framework_to_json`], rejecting unknown keys.
pub fn framework_from_json(v: &Json) -> PallasResult<FrameworkConfig> {
    let obj = v
        .as_obj()
        .ok_or_else(|| PallasError::InvalidConfig("framework config must be an object".into()))?;
    for key in obj.keys() {
        if !FRAMEWORK_KEYS.contains(&key.as_str()) {
            return Err(unknown_key_error(key));
        }
    }
    let mut fw = FrameworkConfig::tuned_default();
    apply_framework_keys(&mut fw, obj)?;
    Ok(fw)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_document() {
        let cfg = RunConfig::from_json_str(
            r#"{"platform":"large.2","inter_op_pools":3,"mkl_threads":16,
                "intra_op_threads":16,"operator_impl":"matmul2",
                "math_lib":"mkl","pool_lib":"eigen","parallelism":"model",
                "pin_threads":true}"#,
        )
        .unwrap();
        assert_eq!(cfg.platform.name, "large.2");
        assert_eq!(cfg.framework.inter_op_pools, 3);
        assert_eq!(cfg.framework.mkl_threads, 16);
        assert_eq!(cfg.framework.math_lib, MathLib::Mkl);
        assert_eq!(cfg.framework.pool_lib, PoolLib::Eigen);
        assert_eq!(cfg.framework.parallelism, ParallelismMode::ModelParallel);
    }

    #[test]
    fn defaults_fill_in() {
        let cfg = RunConfig::from_json_str("{}").unwrap();
        assert_eq!(cfg.platform.name, "large");
        assert_eq!(cfg.framework, FrameworkConfig::tuned_default());
    }

    #[test]
    fn rejects_bad_values() {
        assert!(matches!(
            RunConfig::from_json_str(r#"{"platform":"tpu"}"#),
            Err(PallasError::UnknownPlatform(p)) if p == "tpu"
        ));
        assert!(matches!(
            RunConfig::from_json_str(r#"{"math_lib":"blas"}"#),
            Err(PallasError::InvalidConfig(_))
        ));
        assert!(matches!(
            RunConfig::from_json_str(r#"{"inter_op_pools":0}"#),
            Err(PallasError::InvalidConfig(_))
        ));
        assert!(matches!(
            RunConfig::from_json_str(r#"{"sched_policy":"fifo"}"#),
            Err(PallasError::UnknownPolicy(p)) if p == "fifo"
        ));
    }

    #[test]
    fn rejects_unknown_keys_naming_the_key() {
        // the silent-typo bug: 'sched_polcy' used to fall back to defaults
        let err = RunConfig::from_json_str(r#"{"sched_polcy":"critical-path"}"#).unwrap_err();
        match err {
            PallasError::InvalidConfig(m) => {
                assert!(m.contains("sched_polcy"), "{m}");
                assert!(m.contains("sched_policy"), "error should list accepted keys: {m}");
            }
            other => panic!("wrong variant: {other:?}"),
        }
        assert!(RunConfig::from_json_str(r#"{"platfrom":"large"}"#).is_err());
        // wrong-shape values are as fatal as wrong keys
        assert!(RunConfig::from_json_str(r#"{"pin_threads":"true"}"#).is_err());
        assert!(RunConfig::from_json_str(r#"{"pin_threads":false}"#).is_ok());
    }

    #[test]
    fn parses_sched_policy() {
        let cfg = RunConfig::from_json_str(r#"{"sched_policy":"critical-path"}"#).unwrap();
        assert_eq!(cfg.framework.sched_policy, SchedPolicy::CriticalPathFirst);
        let mut cfg = RunConfig::default();
        cfg.apply_override("sched_policy", "costly").unwrap();
        assert_eq!(cfg.framework.sched_policy, SchedPolicy::CostlyFirst);
    }

    #[test]
    fn cli_overrides() {
        let mut cfg = RunConfig::default();
        cfg.apply_override("platform", "small").unwrap();
        cfg.apply_override("mkl_threads", "4").unwrap();
        assert_eq!(cfg.platform.name, "small");
        assert_eq!(cfg.framework.mkl_threads, 4);
        assert!(cfg.apply_override("bogus", "1").is_err());
    }

    #[test]
    fn framework_json_roundtrip_every_knob() {
        // exercise non-default values on every enum dimension
        let mut fw = FrameworkConfig::tuned_default();
        fw.inter_op_pools = 3;
        fw.mkl_threads = 16;
        fw.intra_op_threads = 12;
        fw.operator_impl = OperatorImpl::Serial;
        fw.math_lib = MathLib::Eigen;
        fw.pool_lib = PoolLib::StdThread;
        fw.parallelism = ParallelismMode::ModelParallel;
        fw.sched_policy = SchedPolicy::CostlyFirst;
        fw.pin_threads = false;
        let v = framework_to_json(&fw);
        assert_eq!(framework_from_json(&v).unwrap(), fw);
        // and through a text round-trip
        let text = crate::util::json::to_string(&v);
        let fw2 = framework_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(fw2, fw);
    }

    #[test]
    fn framework_from_json_rejects_unknown_keys() {
        let mut v = framework_to_json(&FrameworkConfig::tuned_default());
        if let Json::Obj(m) = &mut v {
            m.insert("mkl_treads".into(), Json::Num(4.0));
        }
        assert!(framework_from_json(&v).is_err());
    }
}
