//! The paper's tuning guidelines (§8).
//!
//! > "The number of inter-op pools (p) is chosen to be the average model
//! > width. After p is chosen … the number of MKL threads and the number
//! > of intra-op threads for each thread pool should be equal to the total
//! > number of physical cores on the system divided by p."
//!
//! This collapses the 96³-point design space of `large.2` to a single
//! setting derived from graph structure — architecture-independent, since
//! it only reads the model's computational graph.
//!
//! The dispatch policy follows the same width rule: a wide graph
//! (average width ≥ 2) has real ordering freedom among ready operators,
//! so it gets critical-path-first dispatch; a chain graph has none, so
//! it keeps plain topological order.

use crate::config::{CpuPlatform, FrameworkConfig, OperatorImpl, ParallelismMode, SchedPolicy};
use crate::graph::{analyze_width, Graph, WidthAnalysis};

/// A tuned setting plus the analysis that produced it.
#[derive(Debug, Clone)]
pub struct Tuning {
    /// The recommended framework setting.
    pub config: FrameworkConfig,
    /// The width analysis it was derived from.
    pub width: WidthAnalysis,
}

/// Apply the guidelines to a model graph on a platform.
pub fn tune(graph: &Graph, platform: &CpuPlatform) -> Tuning {
    let width = analyze_width(graph);
    let phys = platform.physical_cores();
    // pools = average width, clamped to the machine
    let pools = width.avg_width.clamp(1, phys);
    let threads = (phys / pools).max(1);
    let config = FrameworkConfig {
        inter_op_pools: pools,
        mkl_threads: threads,
        intra_op_threads: threads,
        operator_impl: OperatorImpl::IntraOpParallel,
        // width ≥ 2 on a multi-socket box wants one pool per socket first
        // (model parallelism); width-1 models split the batch instead
        parallelism: if pools >= 2 && platform.sockets > 1 {
            ParallelismMode::ModelParallel
        } else {
            ParallelismMode::DataParallel
        },
        // wide graphs have ordering freedom worth exploiting; chains don't
        sched_policy: if width.avg_width >= 2 {
            SchedPolicy::CriticalPathFirst
        } else {
            SchedPolicy::Topo
        },
        ..FrameworkConfig::tuned_default()
    };
    Tuning { config, width }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    fn tune_named(name: &str, platform: &CpuPlatform) -> Tuning {
        let g = models::build(name, models::canonical_batch(name)).unwrap();
        tune(&g, platform)
    }

    #[test]
    fn wd_gets_3_pools_16_threads_on_large2() {
        // the paper's worked example: W/D → 3 pools, 16 MKL + 16 intra
        let t = tune_named("wide_deep", &CpuPlatform::large2());
        assert_eq!(t.config.inter_op_pools, 3);
        assert_eq!(t.config.mkl_threads, 16);
        assert_eq!(t.config.intra_op_threads, 16);
    }

    #[test]
    fn chain_models_get_one_pool_all_cores() {
        for name in ["resnet50", "densenet121", "squeezenet"] {
            let t = tune_named(name, &CpuPlatform::large2());
            assert_eq!(t.config.inter_op_pools, 1, "{name}");
            assert_eq!(t.config.mkl_threads, 48, "{name}");
        }
    }

    #[test]
    fn ncf_and_transformer_get_4_pools() {
        for name in ["ncf", "transformer"] {
            let t = tune_named(name, &CpuPlatform::large2());
            assert_eq!(t.config.inter_op_pools, 4, "{name}");
            assert_eq!(t.config.mkl_threads, 12, "{name}");
        }
    }

    #[test]
    fn never_overthreads() {
        for name in models::model_names() {
            for p in [CpuPlatform::small(), CpuPlatform::large(), CpuPlatform::large2()] {
                let t = tune_named(name, &p);
                assert!(
                    !t.config.over_threaded(&p),
                    "{name} on {}: {:?}",
                    p.name,
                    t.config
                );
                assert!(t.config.validate(&p).is_ok());
            }
        }
    }

    #[test]
    fn policy_follows_width_rule() {
        let p = CpuPlatform::large2();
        // wide graphs (avg width ≥ 2) get critical-path dispatch
        for name in ["inception_v3", "wide_deep", "ncf", "transformer"] {
            let t = tune_named(name, &p);
            assert_eq!(t.config.sched_policy, SchedPolicy::CriticalPathFirst, "{name}");
        }
        // chains have no ordering freedom — keep topological dispatch
        for name in ["resnet50", "caffenet", "squeezenet"] {
            let t = tune_named(name, &p);
            assert_eq!(t.config.sched_policy, SchedPolicy::Topo, "{name}");
        }
    }

    #[test]
    fn single_point_not_a_search() {
        // the guideline is closed-form: same graph → same setting
        let a = tune_named("inception_v3", &CpuPlatform::large2());
        let b = tune_named("inception_v3", &CpuPlatform::large2());
        assert_eq!(a.config, b.config);
        assert_eq!(a.config.inter_op_pools, 2); // Table 2: IncepV3 = 2
    }
}
