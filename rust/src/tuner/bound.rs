//! Admissible analytic latency lower bounds — the branch-and-bound
//! half of the exhaustive tier.
//!
//! A lattice point's bound is `max(critical-path time, total work /
//! pool count)` computed from the point's policy-erased family
//! [`PhaseTable`](crate::sim::SimCache) — per-op phase cost sums the
//! delta-simulation layer already materializes — without ever running
//! the event loop. The bound is *admissible* (`bound ≤ exact` in the
//! engine's own f64 arithmetic; the derivation lives on
//! `PhaseTable::bound_s`), which is what lets `exhaustive_search_with`
//! skip any point whose bound exceeds the incumbent's exact latency
//! while still returning the bit-identical flat-sweep optimum.
//!
//! Admissibility is not just argued, it is *watched*: every simulated
//! point in a pruned sweep calls `record_if_unsound`, which
//! increments the process-wide [`bound_unsound`] counter (and fires a
//! `debug_assert!`) whenever `exact < bound`. The counter is pinned to
//! zero by `rust/tests/tuner_prune.rs` and by `benches/tuner.rs`, which
//! CI runs — so a cost-model change that breaks the bound derivation
//! fails the build instead of silently returning a pruned-away optimum.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::config::{CpuPlatform, FrameworkConfig};
use crate::sim::{canonical_config, PreparedGraph, SimCache};

/// Process-wide count of admissibility violations (`exact < bound`)
/// observed on simulated points. Stays 0 unless the bound derivation
/// is broken by a cost-model or engine change.
static BOUND_UNSOUND: AtomicU64 = AtomicU64::new(0);

/// Admissibility violations observed so far (see module docs). Tests
/// and the tuner bench pin this at zero.
pub fn bound_unsound() -> u64 {
    BOUND_UNSOUND.load(Ordering::Relaxed)
}

/// Check one simulated point against its bound; an `exact < bound`
/// observation means the bound was inadmissible and pruning could have
/// discarded the optimum. Counts always; asserts in debug builds.
pub(crate) fn record_if_unsound(bound: f64, exact: f64) {
    if exact < bound {
        BOUND_UNSOUND.fetch_add(1, Ordering::Relaxed);
        debug_assert!(
            false,
            "inadmissible bound: exact {exact} < bound {bound} — pruning is unsound"
        );
    }
}

/// The admissible analytic latency lower bound for one design point,
/// computed without running the engine. Fetches (building on first
/// contact) the point's policy-erased family `PhaseTable` from
/// `cache`, so a sweep's bound pass costs one cost-model sweep per
/// config *family* — amortized across all policy siblings — and
/// pre-warms exactly the tables the surviving points replay through.
pub fn lower_bound(
    cache: &SimCache,
    prep: &PreparedGraph,
    platform: &CpuPlatform,
    cfg: &FrameworkConfig,
) -> f64 {
    let canonical = canonical_config(platform, cfg);
    cache.family_table(prep, platform, &canonical).bound_s()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SchedPolicy;

    #[test]
    fn bound_is_admissible_across_configs() {
        let cache = SimCache::new();
        let p = CpuPlatform::large2();
        let prep = cache.prepared("inception_v3", 16).unwrap();
        for pools in [1usize, 2, 4, 8] {
            for threads in [1usize, 4, 12] {
                let mut cfg = FrameworkConfig::tuned_default();
                cfg.inter_op_pools = pools;
                cfg.mkl_threads = threads;
                cfg.intra_op_threads = threads;
                let b = lower_bound(&cache, &prep, &p, &cfg);
                let exact = cache.latency(&prep, &p, &cfg).unwrap();
                assert!(b > 0.0, "pools={pools} threads={threads}");
                assert!(
                    b <= exact,
                    "pools={pools} threads={threads}: bound {b} > exact {exact}"
                );
            }
        }
        assert_eq!(bound_unsound(), 0);
    }

    #[test]
    fn bound_is_policy_invariant() {
        // the bound comes from the policy-erased family table, so all
        // policy siblings must report the exact same bits
        let cache = SimCache::new();
        let p = CpuPlatform::large();
        let prep = cache.prepared("transformer", 8).unwrap();
        let mut cfg = FrameworkConfig::tuned_default();
        cfg.inter_op_pools = 3;
        cfg.mkl_threads = 4;
        let mut bounds = Vec::new();
        for policy in SchedPolicy::ALL {
            cfg.sched_policy = policy;
            bounds.push(lower_bound(&cache, &prep, &p, &cfg).to_bits());
        }
        assert!(bounds.windows(2).all(|w| w[0] == w[1]), "{bounds:?}");
    }

    #[test]
    fn record_if_unsound_counts_only_violations() {
        let before = bound_unsound();
        record_if_unsound(1.0, 1.0); // bound == exact is sound
        record_if_unsound(0.5, 2.0); // bound < exact is sound
        assert_eq!(bound_unsound(), before);
    }
}
