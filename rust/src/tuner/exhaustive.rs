//! Exhaustive design-space search — the "global optimum" bar of Fig. 18.
//!
//! The raw space on `large.2` is `logical³ = 96³ = 884,736` points; like
//! the authors we sweep the feasible lattice (pool counts that divide the
//! machine sensibly, thread counts up to the logical core count) and
//! simulate each point. The dispatch-policy dimension
//! ([`crate::config::SchedPolicy`]) is swept alongside the thread lattice
//! wherever it can matter — with a single pool every policy yields the
//! same serial schedule, so only `Topo` is evaluated there. This is what
//! the guideline is supposed to match with *one* prediction.

use crate::config::{CpuPlatform, FrameworkConfig, OperatorImpl, SchedPolicy};
use crate::graph::Graph;
use crate::sim;

/// Search outcome.
#[derive(Debug, Clone)]
pub struct SearchResult {
    /// Best setting found.
    pub best: FrameworkConfig,
    /// Its simulated latency.
    pub best_latency_s: f64,
    /// Number of design points simulated.
    pub evaluated: usize,
}

/// Candidate pool counts for a platform.
fn pool_candidates(platform: &CpuPlatform) -> Vec<usize> {
    let phys = platform.physical_cores();
    let mut v: Vec<usize> = (1..=8).filter(|p| *p <= phys).collect();
    for extra in [12, 16, 24, phys] {
        if extra <= phys && !v.contains(&extra) {
            v.push(extra);
        }
    }
    v
}

/// Candidate per-pool thread counts.
fn thread_candidates(platform: &CpuPlatform, pools: usize) -> Vec<usize> {
    let phys = platform.physical_cores();
    let fair = (phys / pools).max(1);
    let mut v = vec![1, 2, 4, fair, 2 * fair, phys, platform.logical_cores()];
    v.sort_unstable();
    v.dedup();
    v.retain(|&t| t >= 1);
    v
}

/// Sweep the lattice and return the latency-optimal setting.
pub fn exhaustive_search(graph: &Graph, platform: &CpuPlatform) -> SearchResult {
    let mut best: Option<(FrameworkConfig, f64)> = None;
    let mut evaluated = 0usize;
    for pools in pool_candidates(platform) {
        // one pool serialises everything: dispatch order cannot change the
        // makespan, so sweeping policies there would just re-measure Topo
        let policies: &[SchedPolicy] =
            if pools == 1 { &[SchedPolicy::Topo] } else { &SchedPolicy::ALL };
        for mkl in thread_candidates(platform, pools) {
            for intra in thread_candidates(platform, pools) {
                for &policy in policies {
                    let cfg = FrameworkConfig {
                        inter_op_pools: pools,
                        mkl_threads: mkl,
                        intra_op_threads: intra,
                        operator_impl: OperatorImpl::IntraOpParallel,
                        sched_policy: policy,
                        ..FrameworkConfig::tuned_default()
                    };
                    if cfg.validate(platform).is_err() {
                        continue;
                    }
                    let lat = sim::simulate(graph, platform, &cfg).latency_s;
                    evaluated += 1;
                    if best.as_ref().map_or(true, |(_, b)| lat < *b) {
                        best = Some((cfg, lat));
                    }
                }
            }
        }
    }
    let (best, best_latency_s) = best.expect("non-empty lattice");
    SearchResult { best, best_latency_s, evaluated }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::tuner::guidelines::tune;

    #[test]
    fn sweeps_a_substantial_lattice() {
        let g = models::build("matmul_512", 0).unwrap();
        let r = exhaustive_search(&g, &CpuPlatform::small());
        assert!(r.evaluated > 50, "evaluated={}", r.evaluated);
        assert!(r.best_latency_s > 0.0);
    }

    #[test]
    fn policy_dimension_is_swept() {
        // multi-pool lattice points are evaluated once per policy: on
        // `small` the lattice is 4 pools × 4×4 threads, so the policy
        // sweep must push the count well past the 64 thread-only points
        let g = models::build("inception_v2", 16).unwrap();
        let r = exhaustive_search(&g, &CpuPlatform::small());
        assert!(r.evaluated > 100, "evaluated={}", r.evaluated);
        assert!(SchedPolicy::ALL.contains(&r.best.sched_policy));
    }

    #[test]
    fn optimum_at_least_as_good_as_guideline() {
        for name in ["squeezenet", "ncf", "wide_deep"] {
            let g = models::build(name, models::canonical_batch(name)).unwrap();
            let p = CpuPlatform::large2();
            let opt = exhaustive_search(&g, &p);
            let guided = tune(&g, &p);
            let guided_lat = crate::sim::simulate(&g, &p, &guided.config).latency_s;
            assert!(
                opt.best_latency_s <= guided_lat * 1.0001,
                "{name}: opt={} guided={guided_lat}",
                opt.best_latency_s
            );
        }
    }

    #[test]
    fn guideline_within_5_percent_of_optimum() {
        // the paper's headline robustness claim (§2.3): worst case ≥95%
        for name in ["resnet50", "inception_v3", "ncf", "wide_deep", "transformer"] {
            let g = models::build(name, models::canonical_batch(name)).unwrap();
            let p = CpuPlatform::large2();
            let opt = exhaustive_search(&g, &p);
            let guided = tune(&g, &p);
            let guided_lat = crate::sim::simulate(&g, &p, &guided.config).latency_s;
            let ratio = guided_lat / opt.best_latency_s;
            assert!(ratio <= 1.053, "{name}: guided/opt = {ratio:.3}");
        }
    }
}
