//! Exhaustive design-space search — the "global optimum" bar of Fig. 18.
//!
//! The raw space on `large.2` is `logical³ = 96³ = 884,736` points; like
//! the authors we sweep the feasible lattice (pool counts that divide the
//! machine sensibly, thread counts up to the logical core count) and
//! simulate each point. The dispatch-policy dimension
//! ([`crate::config::SchedPolicy`]) is swept alongside the thread lattice
//! wherever it can matter — with a single pool every policy yields the
//! same serial schedule, so only `Topo` is evaluated there. This is what
//! the guideline is supposed to match with *one* prediction.
//!
//! The sweep itself runs through the tuning-throughput subsystem:
//! [`lattice`] enumerates the deduplicated canonical design points,
//! [`exhaustive_search_with`] fans them over a
//! [`crate::tuner::parallel::par_map`] worker pool and scores each via
//! the shared [`crate::sim::SimCache`]. Reduction is index-ordered with
//! a strict `<`, so ties keep the lowest lattice point and the result is
//! bit-identical to the serial uncached loop at any `--jobs` value.

use std::collections::HashSet;
use std::sync::Arc;

use crate::config::{CpuPlatform, FrameworkConfig, OperatorImpl, SchedPolicy};
use crate::error::PallasResult;
use crate::graph::Graph;
use crate::sim::{self, PreparedGraph};

use super::parallel::{par_map, SweepOptions};

/// Search outcome.
#[derive(Debug, Clone)]
pub struct SearchResult {
    /// Best setting found.
    pub best: FrameworkConfig,
    /// Its simulated latency.
    pub best_latency_s: f64,
    /// Number of *unique* design points in the swept lattice (identical
    /// canonical configs are deduplicated before evaluation, so this
    /// counts distinct simulations regardless of caching or `--jobs`).
    pub evaluated: usize,
}

/// Candidate pool counts for a platform.
fn pool_candidates(platform: &CpuPlatform) -> Vec<usize> {
    let phys = platform.physical_cores();
    let mut v: Vec<usize> = (1..=8).filter(|p| *p <= phys).collect();
    for extra in [12, 16, 24, phys] {
        if extra <= phys && !v.contains(&extra) {
            v.push(extra);
        }
    }
    v
}

/// Candidate per-pool thread counts.
fn thread_candidates(platform: &CpuPlatform, pools: usize) -> Vec<usize> {
    let phys = platform.physical_cores();
    let fair = (phys / pools).max(1);
    let mut v = vec![1, 2, 4, fair, 2 * fair, phys, platform.logical_cores()];
    v.sort_unstable();
    v.dedup();
    v.retain(|&t| t >= 1);
    v
}

/// The feasible design lattice for a platform, in sweep order (pools,
/// then MKL threads, then intra-op threads, then policy), deduplicated:
/// every point is its own [`sim::canonical_config`] representative and
/// appears exactly once, so candidate collisions (e.g. `2*fair == phys`)
/// and can't-differ configs are never simulated twice.
pub fn lattice(platform: &CpuPlatform) -> Vec<FrameworkConfig> {
    let mut seen: HashSet<FrameworkConfig> = HashSet::new();
    let mut out = Vec::new();
    for pools in pool_candidates(platform) {
        // one pool serialises everything: dispatch order cannot change the
        // makespan, so sweeping policies there would just re-measure Topo
        let policies: &[SchedPolicy] =
            if pools == 1 { &[SchedPolicy::Topo] } else { &SchedPolicy::ALL };
        for mkl in thread_candidates(platform, pools) {
            for intra in thread_candidates(platform, pools) {
                for &policy in policies {
                    let cfg = FrameworkConfig {
                        inter_op_pools: pools,
                        mkl_threads: mkl,
                        intra_op_threads: intra,
                        operator_impl: OperatorImpl::IntraOpParallel,
                        sched_policy: policy,
                        ..FrameworkConfig::tuned_default()
                    };
                    if cfg.validate(platform).is_err() {
                        continue;
                    }
                    let canonical = sim::canonical_config(platform, &cfg);
                    if seen.insert(canonical.clone()) {
                        out.push(canonical);
                    }
                }
            }
        }
    }
    out
}

/// Sweep the lattice and return the latency-optimal setting, with the
/// default sweep options (parallel workers, fresh memo-cache). Errors
/// only if the graph itself cannot be simulated (e.g. a stalled DAG).
pub fn exhaustive_search(graph: &Graph, platform: &CpuPlatform) -> PallasResult<SearchResult> {
    exhaustive_search_with(graph, platform, &SweepOptions::default())
}

/// Sweep the lattice under explicit [`SweepOptions`]. Scoring fans out
/// over `opts.jobs` workers through `opts.cache`; the reduction is a
/// serial index-ordered scan with strict `<`, so the chosen point, its
/// latency bits and the unique-point count are identical to the serial
/// uncached sweep. With `opts.policy` set, only that policy's
/// sub-lattice is swept (1-pool points included — dispatch order cannot
/// matter there), so a policy pin constrains the search instead of
/// rewriting its result.
pub fn exhaustive_search_with(
    graph: &Graph,
    platform: &CpuPlatform,
    opts: &SweepOptions,
) -> PallasResult<SearchResult> {
    let mut points = lattice(platform);
    if let Some(pin) = opts.policy {
        points.retain(|c| c.inter_op_pools == 1 || c.sched_policy == pin);
    }
    let evaluated = points.len();
    let prep = Arc::new(PreparedGraph::new(graph));
    let plat = Arc::new(platform.clone());
    let cache = Arc::clone(&opts.cache);
    let scored: Vec<PallasResult<(FrameworkConfig, f64)>> =
        par_map(opts.jobs, points, move |_, cfg| {
            let lat = cache.latency(&prep, &plat, &cfg)?;
            Ok((cfg, lat))
        });
    let mut best: Option<(FrameworkConfig, f64)> = None;
    for scored_point in scored {
        let (cfg, lat) = scored_point?;
        if best.as_ref().map_or(true, |(_, b)| lat < *b) {
            best = Some((cfg, lat));
        }
    }
    let (best, best_latency_s) = best.expect("non-empty lattice");
    Ok(SearchResult { best, best_latency_s, evaluated })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::tuner::guidelines::tune;

    #[test]
    fn lattice_is_unique_and_canonical() {
        // the dedup satellite: no design point may appear twice, and
        // every point is its own canonical representative (pools == 1 ⇒
        // Topo only)
        for p in [CpuPlatform::small(), CpuPlatform::large(), CpuPlatform::large2()] {
            let points = lattice(&p);
            let set: std::collections::HashSet<_> = points.iter().cloned().collect();
            assert_eq!(set.len(), points.len(), "{}", p.name);
            for c in &points {
                assert_eq!(*c, crate::sim::canonical_config(&p, c), "{}", p.name);
                if c.inter_op_pools == 1 {
                    assert_eq!(c.sched_policy, SchedPolicy::Topo, "{}", p.name);
                }
            }
        }
    }

    #[test]
    fn sweeps_a_substantial_lattice() {
        let g = models::build("matmul_512", 0).unwrap();
        let r = exhaustive_search(&g, &CpuPlatform::small()).unwrap();
        assert!(r.evaluated > 50, "evaluated={}", r.evaluated);
        assert!(r.best_latency_s > 0.0);
    }

    #[test]
    fn policy_dimension_is_swept() {
        // multi-pool lattice points are evaluated once per policy: on
        // `small` the lattice is 4 pools × 4×4 threads, so the policy
        // sweep must push the count well past the 64 thread-only points
        let g = models::build("inception_v2", 16).unwrap();
        let r = exhaustive_search(&g, &CpuPlatform::small()).unwrap();
        assert!(r.evaluated > 100, "evaluated={}", r.evaluated);
        assert!(SchedPolicy::ALL.contains(&r.best.sched_policy));
    }

    #[test]
    fn policy_pin_constrains_the_sweep() {
        let g = models::build("inception_v2", 16).unwrap();
        let p = CpuPlatform::small();
        let free = exhaustive_search(&g, &p).unwrap();
        let pinned = exhaustive_search_with(
            &g,
            &p,
            &SweepOptions::default().pinned(Some(SchedPolicy::Topo)),
        )
        .unwrap();
        // the pinned sub-lattice is strictly smaller and every multi-pool
        // winner honours the pin; the pinned optimum can't beat the free one
        assert!(pinned.evaluated < free.evaluated);
        assert!(
            pinned.best.inter_op_pools == 1 || pinned.best.sched_policy == SchedPolicy::Topo
        );
        assert!(pinned.best_latency_s >= free.best_latency_s);
    }

    #[test]
    fn optimum_at_least_as_good_as_guideline() {
        for name in ["squeezenet", "ncf", "wide_deep"] {
            let g = models::build(name, models::canonical_batch(name)).unwrap();
            let p = CpuPlatform::large2();
            let opt = exhaustive_search(&g, &p).unwrap();
            let guided = tune(&g, &p);
            let guided_lat = crate::sim::simulate(&g, &p, &guided.config).unwrap().latency_s;
            assert!(
                opt.best_latency_s <= guided_lat * 1.0001,
                "{name}: opt={} guided={guided_lat}",
                opt.best_latency_s
            );
        }
    }

    #[test]
    fn guideline_within_5_percent_of_optimum() {
        // the paper's headline robustness claim (§2.3): worst case ≥95%
        for name in ["resnet50", "inception_v3", "ncf", "wide_deep", "transformer"] {
            let g = models::build(name, models::canonical_batch(name)).unwrap();
            let p = CpuPlatform::large2();
            let opt = exhaustive_search(&g, &p).unwrap();
            let guided = tune(&g, &p);
            let guided_lat = crate::sim::simulate(&g, &p, &guided.config).unwrap().latency_s;
            let ratio = guided_lat / opt.best_latency_s;
            assert!(ratio <= 1.053, "{name}: guided/opt = {ratio:.3}");
        }
    }
}
