//! Exhaustive design-space search — the "global optimum" bar of Fig. 18.
//!
//! The raw space on `large.2` is `logical³ = 96³ = 884,736` points; like
//! the authors we sweep the feasible lattice (pool counts that divide the
//! machine sensibly, thread counts up to the logical core count) and
//! score each point. The dispatch-policy dimension
//! ([`crate::config::SchedPolicy`]) is swept alongside the thread lattice
//! wherever it can matter — with a single pool every policy yields the
//! same serial schedule, so only `Topo` is evaluated there. This is what
//! the guideline is supposed to match with *one* prediction.
//!
//! The sweep is a **branch-and-bound search**, not a flat loop:
//! [`lattice`] enumerates the deduplicated canonical design points
//! (memoized per platform shape — rebuilding the Vec + dedup set per
//! search, including every online re-plan, was measurable), a bound
//! pass prices every point with the admissible analytic lower bound of
//! [`crate::tuner::bound`], and [`exhaustive_search_with`] then scores
//! points in **ascending-bound order** over the persistent
//! [`SweepPool`](crate::tuner::parallel::SweepPool) so the incumbent
//! tightens early. A point whose bound exceeds the incumbent's *exact*
//! latency is skipped without simulating; workers share the incumbent
//! through an atomic f64-bits cell, so pruning happens *during* the
//! parallel sweep. The final reduction re-sorts the simulated survivors
//! by original lattice index and scans with a strict `<` — and because
//! the bound is admissible, every latency-optimal point survives to
//! that scan, so the chosen config, its latency bits, and the
//! `evaluated` count are **bit-identical** to the flat sweep at any
//! `--jobs` value (enforced by `rust/tests/tuner_prune.rs`). Only
//! [`SearchResult::simulated`] tells the two apart.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::config::{CpuPlatform, FrameworkConfig, OperatorImpl, SchedPolicy};
use crate::error::PallasResult;
use crate::graph::Graph;
use crate::sim::{self, platform_fingerprint, PreparedGraph};

use super::bound;
use super::parallel::SweepOptions;

/// Search outcome.
#[derive(Debug, Clone)]
pub struct SearchResult {
    /// Best setting found.
    pub best: FrameworkConfig,
    /// Its simulated latency.
    pub best_latency_s: f64,
    /// Number of *unique* design points in the swept lattice (identical
    /// canonical configs are deduplicated before evaluation, so this
    /// counts distinct design points regardless of caching, pruning or
    /// `--jobs`).
    pub evaluated: usize,
    /// Points actually simulated: `evaluated` minus the points
    /// branch-and-bound discarded on their analytic lower bound alone.
    /// Equals `evaluated` when pruning is off.
    pub simulated: usize,
}

/// Candidate pool counts for a platform.
fn pool_candidates(platform: &CpuPlatform) -> Vec<usize> {
    let phys = platform.physical_cores();
    let mut v: Vec<usize> = (1..=8).filter(|p| *p <= phys).collect();
    for extra in [12, 16, 24, phys] {
        if extra <= phys && !v.contains(&extra) {
            v.push(extra);
        }
    }
    v
}

/// Candidate per-pool thread counts.
fn thread_candidates(platform: &CpuPlatform, pools: usize) -> Vec<usize> {
    let phys = platform.physical_cores();
    let fair = (phys / pools).max(1);
    let mut v = vec![1, 2, 4, fair, 2 * fair, phys, platform.logical_cores()];
    v.sort_unstable();
    v.dedup();
    v.retain(|&t| t >= 1);
    v
}

/// The feasible design lattice for a platform, in sweep order (pools,
/// then MKL threads, then intra-op threads, then policy), deduplicated:
/// every point is its own [`sim::canonical_config`] representative and
/// appears exactly once, so candidate collisions (e.g. `2*fair == phys`)
/// and can't-differ configs are never simulated twice.
///
/// Memoized per platform *shape* (the same shape-not-name fingerprint
/// the sim cache keys on) for the life of the process: every search —
/// and every online re-plan — shares one immutable `Arc`'d Vec instead
/// of re-running the enumeration + dedup. Two calls on same-shape
/// platforms return the identical allocation (`Arc::ptr_eq`).
pub fn lattice(platform: &CpuPlatform) -> Arc<Vec<FrameworkConfig>> {
    static MEMO: OnceLock<Mutex<HashMap<u64, Arc<Vec<FrameworkConfig>>>>> = OnceLock::new();
    let memo = MEMO.get_or_init(|| Mutex::new(HashMap::new()));
    let key = platform_fingerprint(platform);
    if let Some(l) = memo.lock().unwrap().get(&key) {
        return Arc::clone(l);
    }
    let built = Arc::new(build_lattice(platform));
    memo.lock().unwrap().entry(key).or_insert(built).clone()
}

fn build_lattice(platform: &CpuPlatform) -> Vec<FrameworkConfig> {
    let mut seen: HashSet<FrameworkConfig> = HashSet::new();
    let mut out = Vec::new();
    for pools in pool_candidates(platform) {
        // one pool serialises everything: dispatch order cannot change the
        // makespan, so sweeping policies there would just re-measure Topo
        let policies: &[SchedPolicy] =
            if pools == 1 { &[SchedPolicy::Topo] } else { &SchedPolicy::ALL };
        for mkl in thread_candidates(platform, pools) {
            for intra in thread_candidates(platform, pools) {
                for &policy in policies {
                    let cfg = FrameworkConfig {
                        inter_op_pools: pools,
                        mkl_threads: mkl,
                        intra_op_threads: intra,
                        operator_impl: OperatorImpl::IntraOpParallel,
                        sched_policy: policy,
                        ..FrameworkConfig::tuned_default()
                    };
                    if cfg.validate(platform).is_err() {
                        continue;
                    }
                    let canonical = sim::canonical_config(platform, &cfg);
                    if seen.insert(canonical.clone()) {
                        out.push(canonical);
                    }
                }
            }
        }
    }
    out
}

/// Sweep the lattice and return the latency-optimal setting, with the
/// default sweep options (parallel workers, fresh memo-cache, pruning
/// on). Errors only if the graph itself cannot be simulated (e.g. a
/// stalled DAG).
pub fn exhaustive_search(graph: &Graph, platform: &CpuPlatform) -> PallasResult<SearchResult> {
    exhaustive_search_with(graph, platform, &SweepOptions::default())
}

/// Lower the shared incumbent to `lat` if it improves it (CAS-min over
/// f64 bits — non-negative finite floats and `+inf` order identically
/// as sign-cleared `u64` bit patterns, so no float CAS is needed).
fn tighten_incumbent(cell: &AtomicU64, lat: f64) {
    let mut prev = cell.load(Ordering::Relaxed);
    while lat < f64::from_bits(prev) {
        match cell.compare_exchange_weak(prev, lat.to_bits(), Ordering::Relaxed, Ordering::Relaxed)
        {
            Ok(_) => break,
            Err(p) => prev = p,
        }
    }
}

/// Search the lattice under explicit [`SweepOptions`]. Scoring fans out
/// over the options' [`SweepPool`](crate::tuner::parallel::SweepPool)
/// through `opts.cache`; the reduction is a serial index-ordered scan
/// with strict `<`, so the chosen point, its latency bits and the
/// unique-point count are identical to the serial uncached flat sweep.
/// With `opts.policy` set, only that policy's sub-lattice is swept
/// (1-pool points included — dispatch order cannot matter there), so a
/// policy pin constrains the search instead of rewriting its result.
///
/// With `opts.prune` (the default) the sweep is best-first
/// branch-and-bound — see the module docs for why the optimum cannot be
/// pruned: a latency-optimal point's admissible bound never exceeds the
/// incumbent (which always holds an exact latency ≥ the optimum), and
/// the pruning test is strictly `bound > incumbent`, so every optimal
/// point reaches the index-ordered tie-break scan.
pub fn exhaustive_search_with(
    graph: &Graph,
    platform: &CpuPlatform,
    opts: &SweepOptions,
) -> PallasResult<SearchResult> {
    let all = lattice(platform);
    let points: Vec<(usize, FrameworkConfig)> = all
        .iter()
        .cloned()
        .enumerate()
        .filter(|(_, c)| {
            opts.policy.map_or(true, |pin| c.inter_op_pools == 1 || c.sched_policy == pin)
        })
        .collect();
    let evaluated = points.len();
    let prep = Arc::new(PreparedGraph::new(graph));
    let plat = Arc::new(platform.clone());
    let cache = Arc::clone(&opts.cache);

    if !opts.prune {
        let scored: Vec<PallasResult<(FrameworkConfig, f64)>> =
            opts.pool.par_map(points, move |_, (_, cfg)| {
                let lat = cache.latency(&prep, &plat, &cfg)?;
                Ok((cfg, lat))
            });
        let mut best: Option<(FrameworkConfig, f64)> = None;
        for scored_point in scored {
            let (cfg, lat) = scored_point?;
            if best.as_ref().map_or(true, |(_, b)| lat < *b) {
                best = Some((cfg, lat));
            }
        }
        let (best, best_latency_s) = best.expect("non-empty lattice");
        return Ok(SearchResult { best, best_latency_s, evaluated, simulated: evaluated });
    }

    // Bound pass: price every point analytically (no engine runs; one
    // family-table build amortized over all policy siblings, and the
    // tables pre-warm the delta-sim path the survivors replay through),
    // then order ascending so the strongest candidates simulate first
    // and the incumbent tightens as early as possible. Index breaks
    // bound ties, keeping the order deterministic.
    let mut order: Vec<(f64, usize, FrameworkConfig)> = points
        .into_iter()
        .map(|(idx, cfg)| {
            let b = bound::lower_bound(&cache, &prep, &plat, &cfg);
            (b, idx, cfg)
        })
        .collect();
    order.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));

    // Exact latency of the best point simulated so far, shared across
    // workers as f64 bits so pruning acts mid-sweep, not between chunks.
    let incumbent = Arc::new(AtomicU64::new(f64::INFINITY.to_bits()));
    let inc = Arc::clone(&incumbent);
    let scored: Vec<PallasResult<Option<(usize, FrameworkConfig, f64)>>> =
        opts.pool.par_map(order, move |_, (bnd, idx, cfg)| {
            // strict `>`: a bound *equal* to the incumbent could still be
            // an optimal point (bound == exact happens for serial
            // configs), and ties must reach the index-ordered scan
            if bnd > f64::from_bits(inc.load(Ordering::Relaxed)) {
                return Ok(None);
            }
            let lat = cache.latency(&prep, &plat, &cfg)?;
            bound::record_if_unsound(bnd, lat);
            tighten_incumbent(&inc, lat);
            Ok(Some((idx, cfg, lat)))
        });
    let mut survivors: Vec<(usize, FrameworkConfig, f64)> = Vec::with_capacity(evaluated);
    for s in scored {
        if let Some(t) = s? {
            survivors.push(t);
        }
    }
    let simulated = survivors.len();
    survivors.sort_by_key(|&(idx, _, _)| idx);
    let mut best: Option<(FrameworkConfig, f64)> = None;
    for (_, cfg, lat) in survivors {
        if best.as_ref().map_or(true, |(_, b)| lat < *b) {
            best = Some((cfg, lat));
        }
    }
    let (best, best_latency_s) = best.expect("non-empty lattice");
    Ok(SearchResult { best, best_latency_s, evaluated, simulated })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::tuner::guidelines::tune;

    #[test]
    fn lattice_is_unique_and_canonical() {
        // the dedup satellite: no design point may appear twice, and
        // every point is its own canonical representative (pools == 1 ⇒
        // Topo only)
        for p in [CpuPlatform::small(), CpuPlatform::large(), CpuPlatform::large2()] {
            let points = lattice(&p);
            let set: std::collections::HashSet<_> = points.iter().cloned().collect();
            assert_eq!(set.len(), points.len(), "{}", p.name);
            for c in points.iter() {
                assert_eq!(*c, crate::sim::canonical_config(&p, c), "{}", p.name);
                if c.inter_op_pools == 1 {
                    assert_eq!(c.sched_policy, SchedPolicy::Topo, "{}", p.name);
                }
            }
        }
    }

    #[test]
    fn lattice_is_memoized_per_shape() {
        // two calls share one allocation; a same-shape slice of a
        // different platform shares it too (shape-not-name keying), and
        // a different shape does not
        let p = CpuPlatform::large2();
        assert!(Arc::ptr_eq(&lattice(&p), &lattice(&p)));
        let l = CpuPlatform::large();
        assert!(Arc::ptr_eq(&lattice(&l.restrict(0, 8)), &lattice(&l.restrict(8, 8))));
        assert!(!Arc::ptr_eq(&lattice(&p), &lattice(&l)));
    }

    #[test]
    fn sweeps_a_substantial_lattice() {
        let g = models::build("matmul_512", 0).unwrap();
        let r = exhaustive_search(&g, &CpuPlatform::small()).unwrap();
        assert!(r.evaluated > 50, "evaluated={}", r.evaluated);
        assert!(r.simulated <= r.evaluated);
        assert!(r.best_latency_s > 0.0);
    }

    #[test]
    fn policy_dimension_is_swept() {
        // multi-pool lattice points are evaluated once per policy: on
        // `small` the lattice is 4 pools × 4×4 threads, so the policy
        // sweep must push the count well past the 64 thread-only points
        let g = models::build("inception_v2", 16).unwrap();
        let r = exhaustive_search(&g, &CpuPlatform::small()).unwrap();
        assert!(r.evaluated > 100, "evaluated={}", r.evaluated);
        assert!(SchedPolicy::ALL.contains(&r.best.sched_policy));
    }

    #[test]
    fn policy_pin_constrains_the_sweep() {
        let g = models::build("inception_v2", 16).unwrap();
        let p = CpuPlatform::small();
        let free = exhaustive_search(&g, &p).unwrap();
        let pinned = exhaustive_search_with(
            &g,
            &p,
            &SweepOptions::default().pinned(Some(SchedPolicy::Topo)),
        )
        .unwrap();
        // the pinned sub-lattice is strictly smaller and every multi-pool
        // winner honours the pin; the pinned optimum can't beat the free one
        assert!(pinned.evaluated < free.evaluated);
        assert!(
            pinned.best.inter_op_pools == 1 || pinned.best.sched_policy == SchedPolicy::Topo
        );
        assert!(pinned.best_latency_s >= free.best_latency_s);
    }

    #[test]
    fn pruned_matches_flat_and_stays_sound() {
        // the full zoo-wide property lives in rust/tests/tuner_prune.rs;
        // this is the unit-sized version of the tentpole claim
        let g = models::build("inception_v2", 16).unwrap();
        let p = CpuPlatform::small();
        let flat =
            exhaustive_search_with(&g, &p, &SweepOptions::with_jobs(1).prune(false)).unwrap();
        let pruned =
            exhaustive_search_with(&g, &p, &SweepOptions::with_jobs(1).prune(true)).unwrap();
        assert_eq!(flat.best, pruned.best);
        assert_eq!(flat.best_latency_s.to_bits(), pruned.best_latency_s.to_bits());
        assert_eq!(flat.evaluated, pruned.evaluated);
        assert_eq!(flat.simulated, flat.evaluated);
        assert!(pruned.simulated <= pruned.evaluated);
        assert_eq!(crate::tuner::bound::bound_unsound(), 0);
    }

    #[test]
    fn optimum_at_least_as_good_as_guideline() {
        for name in ["squeezenet", "ncf", "wide_deep"] {
            let g = models::build(name, models::canonical_batch(name)).unwrap();
            let p = CpuPlatform::large2();
            let opt = exhaustive_search(&g, &p).unwrap();
            let guided = tune(&g, &p);
            let guided_lat = crate::sim::simulate(&g, &p, &guided.config).unwrap().latency_s;
            assert!(
                opt.best_latency_s <= guided_lat * 1.0001,
                "{name}: opt={} guided={guided_lat}",
                opt.best_latency_s
            );
        }
    }

    #[test]
    fn guideline_within_5_percent_of_optimum() {
        // the paper's headline robustness claim (§2.3): worst case ≥95%
        for name in ["resnet50", "inception_v3", "ncf", "wide_deep", "transformer"] {
            let g = models::build(name, models::canonical_batch(name)).unwrap();
            let p = CpuPlatform::large2();
            let opt = exhaustive_search(&g, &p).unwrap();
            let guided = tune(&g, &p);
            let guided_lat = crate::sim::simulate(&g, &p, &guided.config).unwrap().latency_s;
            let ratio = guided_lat / opt.best_latency_s;
            assert!(ratio <= 1.053, "{name}: guided/opt = {ratio:.3}");
        }
    }
}
