//! Parallel sweep executor — design-space sweeps over the repo's own
//! thread pools.
//!
//! The paper's §6.2 pool designs (`libs::threadpool`) existed only as
//! benchmark subjects until this module; the tuner — the system's
//! hottest loop — now dogfoods the Eigen-style work-stealing pool to
//! fan simulation sweeps across cores. [`SweepPool`] is the executor:
//! a lazily-spawned *persistent* `EigenPool` (owned by `api::Session`
//! and by the online tuner across serving windows, so per-window
//! re-plans stop paying a pool spawn) whose [`SweepPool::par_map`]
//! submits work in index-contiguous chunks — one boxed closure and one
//! channel send per chunk instead of per item, the whole chunk set
//! injected through the pool's `execute_batch` so a sweep pays one
//! wake decision — and returns results in item order. Because reduction happens index-ordered on the caller's
//! thread (lowest-lattice-point tie-break preserved), a parallel sweep
//! is bit-identical to the serial loop it replaces at any `--jobs`
//! value.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use crate::config::SchedPolicy;
use crate::error::{PallasError, PallasResult};
use crate::libs::threadpool::{EigenPool, TaskPool};
use crate::sim::SimCache;

/// Strict parser for the `PALLAS_JOBS` override: `Ok(Some(n))` for a
/// positive integer, `Ok(None)` when unset/empty/unparsable (fall back
/// to the hardware default), `Err` for an explicit `0` — a request for
/// "no workers" is a config error, not a default.
///
/// Pure function of its input so tests never race on the process
/// environment (the `PARFRAME_BENCH_FAST` pattern).
pub fn parse_jobs(value: Option<&str>) -> PallasResult<Option<usize>> {
    let Some(raw) = value else { return Ok(None) };
    let raw = raw.trim();
    if raw.is_empty() {
        return Ok(None);
    }
    match raw.parse::<usize>() {
        Ok(0) => Err(PallasError::InvalidConfig(
            "PALLAS_JOBS=0: sweep worker count must be >= 1 (unset it for the default)".into(),
        )),
        Ok(n) => Ok(Some(n)),
        Err(_) => Ok(None),
    }
}

/// Default sweep worker count: the `PALLAS_JOBS` env override when set
/// to a positive integer (for CLI-less embedders; `0` panics with a
/// config error, anything unparsable falls through), else the host's
/// available parallelism capped at 8 (sweep items are coarse
/// simulations; beyond that the memo-cache lock and memory traffic eat
/// the gain).
pub fn default_jobs() -> usize {
    let env = std::env::var("PALLAS_JOBS").ok();
    match parse_jobs(env.as_deref()) {
        Ok(Some(n)) => n,
        Ok(None) => {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).clamp(1, 8)
        }
        Err(e) => panic!("{e}"),
    }
}

/// Chunks per worker in a [`SweepPool::par_map`] submission: enough
/// slack for work stealing to even out uneven item costs (lattice
/// points range from 1-pool serial sims to 8-pool wide ones), few
/// enough that per-chunk overhead stays negligible.
const OVERPARTITION: usize = 4;

/// A persistent sweep executor: one lazily-spawned [`EigenPool`]
/// reused across every sweep submitted to it. `api::Session` owns one
/// for the exhaustive/guideline tiers and hands it to serving;
/// `OnlineTuner` keeps one across windows — so steady-state re-plans
/// and re-sweeps pay zero thread spawns (observable via
/// [`Self::spawn_count`]).
#[derive(Debug)]
pub struct SweepPool {
    jobs: usize,
    /// The pool, spawned on first parallel submission. `Drop` of the
    /// owning `SweepPool` joins the workers (via `EigenPool`'s Drop).
    inner: Mutex<Option<Arc<EigenPool>>>,
    spawns: AtomicUsize,
}

impl SweepPool {
    /// An executor that will run up to `jobs` workers (1 = always
    /// inline; no thread is ever spawned).
    pub fn new(jobs: usize) -> Self {
        SweepPool { jobs: jobs.max(1), inner: Mutex::new(None), spawns: AtomicUsize::new(0) }
    }

    /// The configured worker count.
    pub fn jobs(&self) -> usize {
        self.jobs
    }

    /// How many times a pool has been spawned (0 or 1 for the life of
    /// this executor — the reuse tests pin it).
    pub fn spawn_count(&self) -> usize {
        self.spawns.load(Ordering::Relaxed)
    }

    fn pool(&self) -> Arc<EigenPool> {
        let mut guard = self.inner.lock().unwrap();
        match &*guard {
            Some(p) => Arc::clone(p),
            None => {
                let p = Arc::new(EigenPool::new(self.jobs));
                self.spawns.fetch_add(1, Ordering::Relaxed);
                *guard = Some(Arc::clone(&p));
                p
            }
        }
    }

    /// Map `f` over `items`, returning results in item order (`f` also
    /// receives the item index). With one job (or ≤ 1 item) this runs
    /// inline — no pool, no channel. Worker panics re-raise on the
    /// calling thread.
    ///
    /// Submission is chunked: index-contiguous chunks sized
    /// `ceil(items / (jobs * OVERPARTITION))`, one boxed closure + one
    /// channel send per *chunk* (not per item), each chunk's results
    /// written back into preallocated slots by chunk start index.
    pub fn par_map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(usize, T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let jobs = self.jobs.min(n.max(1));
        if jobs == 1 {
            return items.into_iter().enumerate().map(|(i, t)| f(i, t)).collect();
        }
        let pool = self.pool();
        let f = Arc::new(f);
        let chunk = n.div_ceil(jobs * OVERPARTITION).max(1);
        // each chunk reports (start index, caught results); panics
        // re-raise below after the channel drains
        let (tx, rx) = mpsc::channel::<(usize, std::thread::Result<Vec<R>>)>();
        let mut items = items.into_iter();
        let mut start = 0usize;
        let mut batch: Vec<crate::libs::threadpool::Task> =
            Vec::with_capacity(n.div_ceil(chunk));
        while start < n {
            let take: Vec<T> = items.by_ref().take(chunk).collect();
            let len = take.len();
            let f = Arc::clone(&f);
            let tx = tx.clone();
            batch.push(Box::new(move || {
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    take.into_iter()
                        .enumerate()
                        .map(|(off, t)| f(start + off, t))
                        .collect::<Vec<R>>()
                }));
                let _ = tx.send((start, r));
            }));
            start += len;
        }
        drop(tx);
        // one injection + one wake decision for the whole sweep, instead
        // of a submit (and, pre-substrate, a lock) per chunk
        pool.execute_batch(batch);
        let mut out: Vec<Option<R>> = std::iter::repeat_with(|| None).take(n).collect();
        for (start, r) in rx {
            match r {
                Ok(vs) => {
                    for (off, v) in vs.into_iter().enumerate() {
                        out[start + off] = Some(v);
                    }
                }
                Err(panic) => std::panic::resume_unwind(panic),
            }
        }
        out.into_iter().map(|o| o.expect("par_map worker dropped a result")).collect()
    }
}

/// One-shot convenience: map over a transient [`SweepPool`]. Callers
/// with a sweep loop (the session tiers, the online tuner) should hold
/// a `SweepPool` instead, so the workers persist across calls.
pub fn par_map<T, R, F>(jobs: usize, items: Vec<T>, f: F) -> Vec<R>
where
    T: Send + 'static,
    R: Send + 'static,
    F: Fn(usize, T) -> R + Send + Sync + 'static,
{
    SweepPool::new(jobs).par_map(items, f)
}

/// Knobs shared by every sweep entry point: the executor (worker count
/// + persistent pool), the simulation memo-cache the workers consult,
/// an optional pin on the dispatch-policy dimension, and the
/// branch-and-bound switch. Cloning shares the pool and the cache.
#[derive(Debug, Clone)]
pub struct SweepOptions {
    /// The sweep executor; share one across sweeps (a `Session` does)
    /// so repeated searches reuse the same worker threads.
    pub pool: Arc<SweepPool>,
    /// Memoized-simulation cache; share one across sweeps to dedupe
    /// design points between tuner tiers.
    pub cache: Arc<SimCache>,
    /// Restrict the swept lattice to this dispatch policy (1-pool points
    /// are kept — a single pool serialises every order, so they belong
    /// to every policy's sub-lattice). `None` sweeps all policies.
    pub policy: Option<SchedPolicy>,
    /// Branch-and-bound pruning (on by default; `tune --no-prune` and
    /// the flat-baseline bench cases turn it off). Pruned and flat
    /// sweeps return bit-identical results — the switch exists to
    /// measure that, not to choose an answer.
    pub prune: bool,
}

impl Default for SweepOptions {
    fn default() -> Self {
        Self::with_jobs(default_jobs())
    }
}

impl SweepOptions {
    /// Explicit worker count, fresh pool + fresh cache.
    pub fn with_jobs(jobs: usize) -> Self {
        SweepOptions {
            pool: Arc::new(SweepPool::new(jobs)),
            cache: Arc::new(SimCache::new()),
            policy: None,
            prune: true,
        }
    }

    /// Explicit worker count over a shared cache (fresh pool).
    pub fn shared(jobs: usize, cache: Arc<SimCache>) -> Self {
        SweepOptions { cache, ..Self::with_jobs(jobs) }
    }

    /// The executor's worker count.
    pub fn jobs(&self) -> usize {
        self.pool.jobs()
    }

    /// Pin (or unpin) the swept policy dimension.
    pub fn pinned(mut self, policy: Option<SchedPolicy>) -> Self {
        self.policy = policy;
        self
    }

    /// Run on a shared persistent executor instead of this option
    /// set's own pool.
    pub fn on_pool(mut self, pool: Arc<SweepPool>) -> Self {
        self.pool = pool;
        self
    }

    /// Enable/disable branch-and-bound pruning (the `--no-prune`
    /// escape hatch; results are bit-identical either way).
    pub fn prune(mut self, prune: bool) -> Self {
        self.prune = prune;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_item_order() {
        for jobs in [1, 2, 4, 16] {
            let items: Vec<usize> = (0..100).collect();
            let out = par_map(jobs, items, |i, x| {
                assert_eq!(i, x);
                x * 2
            });
            assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
        }
    }

    #[test]
    fn jobs_capped_by_items() {
        // 8 jobs over 2 items must not spawn an 8-thread pool that never
        // drains; just check completion + order
        let out = par_map(8, vec![10usize, 20], |_, x| x + 1);
        assert_eq!(out, vec![11, 21]);
    }

    #[test]
    fn empty_input_is_fine() {
        let out: Vec<usize> = par_map(4, Vec::<usize>::new(), |_, x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn worker_panic_propagates() {
        let caught = std::panic::catch_unwind(|| {
            par_map(4, (0..32).collect::<Vec<usize>>(), |_, x| {
                assert!(x != 13, "boom");
                x
            })
        });
        assert!(caught.is_err());
    }

    #[test]
    fn pool_is_reused_across_submissions() {
        let pool = SweepPool::new(4);
        assert_eq!(pool.spawn_count(), 0);
        let a = pool.par_map((0..64).collect::<Vec<usize>>(), |_, x| x * 3);
        let b = pool.par_map((0..64).collect::<Vec<usize>>(), |_, x| x * 3);
        assert_eq!(a, b);
        assert_eq!(a[63], 189);
        assert_eq!(pool.spawn_count(), 1, "second sweep must reuse the first pool");
    }

    #[test]
    fn serial_pool_never_spawns() {
        let pool = SweepPool::new(1);
        let out = pool.par_map((0..16).collect::<Vec<usize>>(), |i, x| i + x);
        assert_eq!(out[8], 16);
        assert_eq!(pool.spawn_count(), 0);
    }

    #[test]
    fn chunked_results_land_in_their_slots() {
        // more items than jobs * OVERPARTITION forces multi-item chunks;
        // identity-map must still come back in exact item order
        let pool = SweepPool::new(3);
        let items: Vec<usize> = (0..1000).collect();
        let out = pool.par_map(items, |i, x| {
            assert_eq!(i, x);
            x
        });
        assert_eq!(out, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn parse_jobs_is_strict() {
        assert_eq!(parse_jobs(None).unwrap(), None);
        assert_eq!(parse_jobs(Some("")).unwrap(), None);
        assert_eq!(parse_jobs(Some("  ")).unwrap(), None);
        assert_eq!(parse_jobs(Some("nope")).unwrap(), None);
        assert_eq!(parse_jobs(Some("-3")).unwrap(), None);
        assert_eq!(parse_jobs(Some("6")).unwrap(), Some(6));
        assert_eq!(parse_jobs(Some(" 2 ")).unwrap(), Some(2));
        assert!(parse_jobs(Some("0")).is_err());
    }

    #[test]
    fn default_jobs_sane() {
        // pure-parser tests above cover the env override race-free; here
        // just pin the hardware fallback range (the env var may be set
        // by an embedder's harness, so accept any positive count)
        let j = default_jobs();
        assert!(j >= 1);
    }
}
