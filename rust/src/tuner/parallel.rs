//! Parallel sweep executor — design-space sweeps over the repo's own
//! thread pools.
//!
//! The paper's §6.2 pool designs (`libs::threadpool`) existed only as
//! benchmark subjects until this module; the tuner — the system's
//! hottest loop — now dogfoods the Eigen-style work-stealing pool to
//! fan simulation sweeps across cores. [`par_map`] is the single
//! primitive: run a closure over every item, return results in item
//! order. Because reduction happens index-ordered on the caller's
//! thread (lowest-lattice-point tie-break preserved), a parallel sweep
//! is bit-identical to the serial loop it replaces at any `--jobs`
//! value.

use std::sync::mpsc;
use std::sync::Arc;

use crate::config::SchedPolicy;
use crate::libs::threadpool::{EigenPool, TaskPool};
use crate::sim::SimCache;

/// Default sweep worker count: the host's available parallelism, capped
/// at 8 (sweep items are coarse simulations; beyond that the memo-cache
/// lock and memory traffic eat the gain).
pub fn default_jobs() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).clamp(1, 8)
}

/// Knobs shared by every sweep entry point: worker count (`--jobs`), the
/// simulation memo-cache the workers consult, and an optional pin on the
/// dispatch-policy dimension. Cloning shares the cache.
#[derive(Debug, Clone)]
pub struct SweepOptions {
    /// Sweep worker threads (1 = serial, no pool spawned).
    pub jobs: usize,
    /// Memoized-simulation cache; share one across sweeps to dedupe
    /// design points between tuner tiers.
    pub cache: Arc<SimCache>,
    /// Restrict the swept lattice to this dispatch policy (1-pool points
    /// are kept — a single pool serialises every order, so they belong
    /// to every policy's sub-lattice). `None` sweeps all policies.
    pub policy: Option<SchedPolicy>,
}

impl Default for SweepOptions {
    fn default() -> Self {
        SweepOptions { jobs: default_jobs(), cache: Arc::new(SimCache::new()), policy: None }
    }
}

impl SweepOptions {
    /// Explicit worker count, fresh cache.
    pub fn with_jobs(jobs: usize) -> Self {
        SweepOptions { jobs, ..Self::default() }
    }

    /// Explicit worker count over a shared cache.
    pub fn shared(jobs: usize, cache: Arc<SimCache>) -> Self {
        SweepOptions { jobs, cache, policy: None }
    }

    /// Pin (or unpin) the swept policy dimension.
    pub fn pinned(mut self, policy: Option<SchedPolicy>) -> Self {
        self.policy = policy;
        self
    }
}

/// Map `f` over `items` on up to `jobs` Eigen-pool workers, returning
/// results in item order (`f` also receives the item index). With one
/// job (or ≤ 1 item) this runs inline — no pool, no channel. Worker
/// panics are re-raised on the calling thread.
///
/// The pool is spawned per call and joined on return: sweep items are
/// simulations (micro- to milliseconds each), so the one-off thread
/// spawn is noise next to the work it parallelises — and per-window
/// callers like the online tuner amortise it over a whole serving
/// window.
pub fn par_map<T, R, F>(jobs: usize, items: Vec<T>, f: F) -> Vec<R>
where
    T: Send + 'static,
    R: Send + 'static,
    F: Fn(usize, T) -> R + Send + Sync + 'static,
{
    let n = items.len();
    let jobs = jobs.clamp(1, n.max(1));
    if jobs == 1 {
        return items.into_iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let pool = EigenPool::new(jobs);
    let f = Arc::new(f);
    // each worker reports (index, caught result); panics re-raise below
    let (tx, rx) = mpsc::channel::<(usize, std::thread::Result<R>)>();
    for (i, item) in items.into_iter().enumerate() {
        let f = Arc::clone(&f);
        let tx = tx.clone();
        pool.execute(Box::new(move || {
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i, item)));
            let _ = tx.send((i, r));
        }));
    }
    drop(tx);
    let mut out: Vec<Option<R>> = std::iter::repeat_with(|| None).take(n).collect();
    for (i, r) in rx {
        match r {
            Ok(v) => out[i] = Some(v),
            Err(panic) => std::panic::resume_unwind(panic),
        }
    }
    out.into_iter()
        .map(|o| o.expect("par_map worker dropped a result"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_item_order() {
        for jobs in [1, 2, 4, 16] {
            let items: Vec<usize> = (0..100).collect();
            let out = par_map(jobs, items, |i, x| {
                assert_eq!(i, x);
                x * 2
            });
            assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
        }
    }

    #[test]
    fn jobs_capped_by_items() {
        // 8 jobs over 2 items must not spawn an 8-thread pool that never
        // drains; just check completion + order
        let out = par_map(8, vec![10usize, 20], |_, x| x + 1);
        assert_eq!(out, vec![11, 21]);
    }

    #[test]
    fn empty_input_is_fine() {
        let out: Vec<usize> = par_map(4, Vec::<usize>::new(), |_, x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn worker_panic_propagates() {
        let caught = std::panic::catch_unwind(|| {
            par_map(4, (0..32).collect::<Vec<usize>>(), |_, x| {
                assert!(x != 13, "boom");
                x
            })
        });
        assert!(caught.is_err());
    }

    #[test]
    fn default_jobs_sane() {
        let j = default_jobs();
        assert!((1..=8).contains(&j));
    }
}
