//! Framework-parameter tuning — the paper's §8 contribution, plus the
//! serving-time closed loop built on it.
//!
//! * [`guidelines`] — the width-based rule: `pools = average graph width`,
//!   `mkl_threads = intra_op_threads = physical_cores / pools`, and
//!   critical-path-first dispatch for wide graphs (avg width ≥ 2).
//! * [`baselines`] — the Intel blog, TensorFlow performance-guide and
//!   TensorFlow out-of-the-box settings the paper compares against.
//! * [`exhaustive`] — the global-optimum search over the design cube
//!   (96³ points on `large.2`; pruned to the feasible lattice, with the
//!   dispatch-policy dimension swept wherever > 1 pool makes it matter),
//!   run as branch-and-bound: ascending-bound order, shared incumbent,
//!   bit-identical optimum with far fewer simulations.
//! * [`bound`] — the admissible analytic latency lower bound the search
//!   prunes on (`max(critical path, work / pools)` from the family
//!   phase tables), plus the `bound_unsound` soundness counter.
//! * [`online`] — the windowed re-tuner: §8 as the prior, sim-scored
//!   candidate core splits and per-group policy flips, applied live by
//!   the coordinator.
//! * [`parallel`] — the sweep executor every tier above runs on: a
//!   persistent [`parallel::SweepPool`] over the repo's own Eigen-style
//!   thread pool (chunked submission, index-ordered results) plus the
//!   shared [`crate::sim::SimCache`] memo, with deterministic
//!   index-ordered reduction (results are bit-identical to the serial
//!   uncached path at any `--jobs` value).

pub mod baselines;
pub mod bound;
pub mod exhaustive;
pub mod guidelines;
pub mod online;
pub mod parallel;

pub use baselines::{baseline_config, Baseline};
pub use bound::{bound_unsound, lower_bound};
pub use exhaustive::{exhaustive_search, exhaustive_search_with, lattice, SearchResult};
pub use guidelines::tune;
pub use online::{OnlineTuner, OnlineTunerConfig};
pub use parallel::{default_jobs, par_map, parse_jobs, SweepOptions, SweepPool};
