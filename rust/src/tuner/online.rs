//! Online re-tuning: the closed-loop controller that adapts the serving
//! stack to shifting traffic — dynamic runtime concurrency control (Liu
//! et al., 2018) applied on top of the paper's §8 guideline.
//!
//! Each serving window the coordinator's metrics are folded in through
//! [`OnlineTuner::observe`] (EWMA-smoothed per-kind arrival rates);
//! [`OnlineTuner::propose`] then builds candidate [`LanePlan`]s — the
//! rate-proportional split with §8 knobs per slice as the prior, plus
//! neighbors that shift a few cores between the hottest and coldest
//! groups or flip one group's dispatch policy
//! ([`crate::config::SchedPolicy`]) — scores every candidate **under
//! each group's allocated cores** (in parallel, through a memoizing
//! [`crate::sim::SimCache`], so steady mixes and same-shape slices stop
//! re-simulating), and returns a new plan only when the predicted win
//! clears a hysteresis threshold (so the coordinator is not thrashed by
//! noise). The coordinator applies accepted plans with
//! `Coordinator::apply_plan`, which respawns lanes without dropping
//! in-flight requests.

use std::collections::HashMap;
use std::sync::Arc;

use crate::config::{CpuPlatform, SchedPolicy};
use crate::error::PallasResult;
use crate::metrics::WindowSnapshot;
use crate::sched::{LaneGroup, LanePlan};
use crate::sim::SimCache;

use super::parallel::{default_jobs, SweepPool};

/// Controller knobs.
#[derive(Debug, Clone)]
pub struct OnlineTunerConfig {
    /// EWMA weight on the newest window's arrival rate (1.0 = no memory).
    pub smoothing: f64,
    /// Ignore windows with fewer total arrivals than this (noise guard).
    pub min_window_arrivals: u64,
    /// Batch bucket candidate plans are scored at.
    pub score_bucket: usize,
    /// Predicted improvement required before a re-plan ships
    /// (0.05 ⇒ candidate must score ≥ 5% below the current plan).
    pub hysteresis: f64,
    /// Cores moved between groups when generating neighbor candidates.
    pub core_step: usize,
    /// Sweep workers for candidate scoring (`--jobs`): each re-plan
    /// scores its candidate plans in parallel, cutting the observe→apply
    /// latency of the control loop.
    pub jobs: usize,
}

impl Default for OnlineTunerConfig {
    fn default() -> Self {
        OnlineTunerConfig {
            smoothing: 0.5,
            min_window_arrivals: 8,
            score_bucket: 8,
            hysteresis: 0.05,
            core_step: 2,
            jobs: default_jobs(),
        }
    }
}

/// The closed-loop re-tuner: smoothed traffic state + candidate search.
/// Scoring goes through a private [`SimCache`], so re-plans under a
/// steady mix (and candidates sharing a slice shape) reuse earlier
/// simulations instead of re-running them each window.
#[derive(Debug)]
pub struct OnlineTuner {
    platform: CpuPlatform,
    kinds: Vec<String>,
    cfg: OnlineTunerConfig,
    rates: HashMap<String, f64>,
    cache: Arc<SimCache>,
    /// Persistent candidate-scoring executor: workers spawn on the
    /// first re-plan and are reused every window after, so the control
    /// loop stops paying a pool spawn per window.
    sweep: Arc<SweepPool>,
}

impl OnlineTuner {
    /// Controller for `kinds` on `platform` with default knobs.
    pub fn new(platform: CpuPlatform, kinds: &[&str]) -> Self {
        Self::with_config(platform, kinds, OnlineTunerConfig::default())
    }

    /// Controller with explicit knobs.
    pub fn with_config(platform: CpuPlatform, kinds: &[&str], cfg: OnlineTunerConfig) -> Self {
        let sweep = Arc::new(SweepPool::new(cfg.jobs));
        OnlineTuner {
            platform,
            kinds: kinds.iter().map(|s| s.to_string()).collect(),
            cfg,
            rates: HashMap::new(),
            cache: Arc::new(SimCache::new()),
            sweep,
        }
    }

    /// Score through a shared memo-cache instead of the private one —
    /// hand the serving backend's factory cache here and candidate
    /// scoring dedupes against the lane tables it already simulated
    /// (and vice versa after an accepted re-plan).
    pub fn with_cache(mut self, cache: Arc<SimCache>) -> Self {
        self.cache = cache;
        self
    }

    /// Score candidates on a shared persistent executor (e.g. the
    /// session's) instead of the tuner's own — lets an embedding tier
    /// pool worker threads across every sweep it runs.
    pub fn with_pool(mut self, pool: Arc<SweepPool>) -> Self {
        self.sweep = pool;
        self
    }

    /// The tuner's candidate-scoring executor (persists across
    /// windows; its `spawn_count` stays at ≤ 1 however many re-plans
    /// run).
    pub fn sweep_pool(&self) -> &Arc<SweepPool> {
        &self.sweep
    }

    /// Smoothed traffic share per kind (sums to 1; equal shares before
    /// any traffic is observed).
    pub fn mix(&self) -> Vec<(String, f64)> {
        let total: f64 =
            self.kinds.iter().map(|k| self.rates.get(k).copied().unwrap_or(0.0)).sum();
        self.kinds
            .iter()
            .map(|k| {
                let r = self.rates.get(k).copied().unwrap_or(0.0);
                let share = if total > 0.0 { r / total } else { 1.0 / self.kinds.len() as f64 };
                (k.clone(), share)
            })
            .collect()
    }

    /// Fold one serving window into the smoothed arrival rates. Windows
    /// below the noise guard (or with no elapsed time) are ignored.
    pub fn observe(&mut self, window: &WindowSnapshot) {
        if window.total_arrivals() < self.cfg.min_window_arrivals || window.elapsed_s <= 0.0 {
            return;
        }
        let a = self.cfg.smoothing.clamp(0.0, 1.0);
        for kind in &self.kinds {
            let rate = window.get(kind).map(|k| k.arrival_rate(window.elapsed_s)).unwrap_or(0.0);
            match self.rates.get_mut(kind) {
                Some(e) => *e = a * rate + (1.0 - a) * *e,
                None => {
                    self.rates.insert(kind.clone(), rate);
                }
            }
        }
    }

    /// Predicted per-item serving cost of a plan under the current mix:
    /// Σ_kind share × simulated batch latency on the *group's* core
    /// slice / bucket. Infinite when the plan fails to host a kind that
    /// has traffic. Memoized through the tuner's [`SimCache`].
    pub fn score(&self, plan: &LanePlan) -> f64 {
        score_plan(&self.cache, &self.mix(), self.cfg.score_bucket.max(1), plan)
    }

    /// Propose a better plan for the observed mix, or `None` when the
    /// current plan is within the hysteresis band of the best candidate.
    /// Candidates are scored in parallel (`cfg.jobs` workers); the
    /// reduction scans them in candidate order with a strict `<`, so the
    /// proposal is identical to the serial path at any worker count.
    pub fn propose(&self, current: &LanePlan) -> PallasResult<Option<LanePlan>> {
        let proportional = LanePlan::for_mix(&self.platform, &self.mix())?;
        let mut candidates = self.neighbors(&proportional);
        candidates.push(proportional);
        let current_score = self.score(current);
        let mix = Arc::new(self.mix());
        let bucket = self.cfg.score_bucket.max(1);
        let cache = Arc::clone(&self.cache);
        let scored: Vec<Option<(f64, LanePlan)>> =
            self.sweep.par_map(candidates, move |_, c| {
                if c.validate().is_err() {
                    return None;
                }
                let s = score_plan(&cache, &mix, bucket, &c);
                Some((s, c))
            });
        let mut best: Option<(f64, LanePlan)> = None;
        for (s, c) in scored.into_iter().flatten() {
            if best.as_ref().map_or(true, |(bs, _)| s < *bs) {
                best = Some((s, c));
            }
        }
        Ok(match best {
            Some((s, plan)) if s < current_score * (1.0 - self.cfg.hysteresis) => Some(plan),
            _ => None,
        })
    }

    /// Candidate plans one step away from `base`: every group's dispatch
    /// policy flipped to each alternative (same core split — lets a
    /// re-plan adopt e.g. critical-path dispatch when a wide model heats
    /// up), plus core shifts between the hottest and coldest groups (both
    /// directions) with every group's knobs re-derived from the §8
    /// guideline on its new slice.
    fn neighbors(&self, base: &LanePlan) -> Vec<LanePlan> {
        let mut out = Vec::new();
        for (i, g) in base.groups.iter().enumerate() {
            for pol in SchedPolicy::ALL {
                if pol == g.framework.sched_policy {
                    continue;
                }
                let mut p = base.clone();
                p.groups[i].framework.sched_policy = pol;
                out.push(p);
            }
        }
        if base.groups.len() < 2 {
            return out;
        }
        let mix = self.mix();
        let share = |g: &LaneGroup| -> f64 {
            g.kinds
                .iter()
                .map(|k| mix.iter().find(|(mk, _)| mk == k).map(|(_, s)| *s).unwrap_or(0.0))
                .sum()
        };
        let mut hot = 0usize;
        let mut cold = 0usize;
        for (i, g) in base.groups.iter().enumerate() {
            if share(g) > share(&base.groups[hot]) {
                hot = i;
            }
            if share(g) < share(&base.groups[cold]) {
                cold = i;
            }
        }
        if hot == cold {
            return out;
        }
        let step = self.cfg.core_step.max(1);
        for (from, to) in [(cold, hot), (hot, cold)] {
            if base.groups[from].allocation.cores <= step {
                continue;
            }
            let mut cores: Vec<f64> =
                base.groups.iter().map(|g| g.allocation.cores as f64).collect();
            cores[from] -= step as f64;
            cores[to] += step as f64;
            let mix: Vec<(String, f64)> = base
                .groups
                .iter()
                .zip(&cores)
                .map(|(g, c)| (g.kinds[0].clone(), *c))
                .collect();
            if let Ok(p) = LanePlan::for_mix(&self.platform, &mix) {
                out.push(p);
            }
        }
        out
    }
}

/// The scoring kernel shared by [`OnlineTuner::score`] and the parallel
/// candidate sweep: Σ share × memoized batch latency on the group's
/// slice / bucket. Slices with the same shape hit the same cache entry
/// ([`crate::sim::platform_fingerprint`] ignores core positions).
fn score_plan(cache: &SimCache, mix: &[(String, f64)], bucket: usize, plan: &LanePlan) -> f64 {
    let mut total = 0.0;
    for (kind, share) in mix {
        if *share <= 0.0 {
            continue;
        }
        let Some(group) = plan.group_for(kind) else {
            return f64::INFINITY;
        };
        let Some(prep) = cache.prepared(kind, bucket) else {
            return f64::INFINITY;
        };
        let slice = plan
            .platform
            .restrict(group.allocation.first_core, group.allocation.cores);
        // an unsimulatable graph scores like an unhosted kind: worst
        // possible, so re-planning never selects it
        let Ok(latency) = cache.latency(&prep, &slice, &group.framework) else {
            return f64::INFINITY;
        };
        total += share * latency / bucket as f64;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::KindWindow;

    const A: &str = "wide_deep";
    const B: &str = "resnet50";

    fn window(a_arrivals: u64, b_arrivals: u64) -> WindowSnapshot {
        WindowSnapshot {
            elapsed_s: 1.0,
            kinds: vec![
                KindWindow {
                    kind: A.into(),
                    arrivals: a_arrivals,
                    completed: a_arrivals,
                    batches: a_arrivals / 4,
                    batch_items: a_arrivals,
                },
                KindWindow {
                    kind: B.into(),
                    arrivals: b_arrivals,
                    completed: b_arrivals,
                    batches: b_arrivals / 4,
                    batch_items: b_arrivals,
                },
            ],
        }
    }

    #[test]
    fn mix_defaults_to_equal_then_follows_traffic() {
        let mut t = OnlineTuner::new(CpuPlatform::large2(), &[A, B]);
        let m0 = t.mix();
        assert!((m0[0].1 - 0.5).abs() < 1e-9);
        t.observe(&window(90, 10));
        let m1 = t.mix();
        assert!((m1[0].1 - 0.9).abs() < 1e-6, "share={}", m1[0].1);
        // EWMA pulls toward the new window, not all the way
        t.observe(&window(10, 90));
        let m2 = t.mix();
        assert!(m2[0].1 < 0.9 && m2[0].1 > 0.1, "share={}", m2[0].1);
    }

    #[test]
    fn noise_guard_ignores_tiny_windows() {
        let mut t = OnlineTuner::new(CpuPlatform::large2(), &[A, B]);
        t.observe(&window(3, 1)); // below min_window_arrivals = 8
        assert!((t.mix()[0].1 - 0.5).abs() < 1e-9, "tiny window must not move the mix");
    }

    #[test]
    fn propose_moves_cores_toward_hot_kind() {
        let platform = CpuPlatform::large2();
        let mut t = OnlineTuner::new(platform.clone(), &[A, B]);
        let initial = LanePlan::guideline(&platform, &[A, B]).unwrap();
        // heavy resnet50 traffic: the even split should lose to a
        // resnet-heavy split
        t.observe(&window(8, 72));
        t.observe(&window(8, 72));
        let next = t.propose(&initial).unwrap().expect("should re-plan under a strong shift");
        let rn = next.group_for(B).unwrap();
        let wd = next.group_for(A).unwrap();
        assert!(
            rn.allocation.cores > wd.allocation.cores,
            "hot kind got {} cores vs {}",
            rn.allocation.cores,
            wd.allocation.cores
        );
        next.validate().unwrap();
        // and the score agrees
        assert!(t.score(&next) < t.score(&initial));
    }

    #[test]
    fn proposals_converge_not_thrash() {
        // once a proposal is adopted, re-proposing under the same traffic
        // must be a no-op: the candidate set is a pure function of the
        // mix, so the adopted plan is already the best candidate and
        // cannot beat itself by the hysteresis margin
        let platform = CpuPlatform::large2();
        let mut t = OnlineTuner::new(platform.clone(), &[A, B]);
        let initial = LanePlan::guideline(&platform, &[A, B]).unwrap();
        t.observe(&window(8, 72));
        let adopted = t.propose(&initial).unwrap().expect("strong shift re-plans");
        assert!(t.propose(&adopted).unwrap().is_none(), "controller thrashed");
    }

    #[test]
    fn neighbors_include_policy_flips_for_every_group() {
        let platform = CpuPlatform::large2();
        let mut t = OnlineTuner::new(platform.clone(), &[A, B]);
        t.observe(&window(40, 40));
        let base = LanePlan::guideline(&platform, &[A, B]).unwrap();
        let n = t.neighbors(&base);
        for (i, g) in base.groups.iter().enumerate() {
            for pol in SchedPolicy::ALL {
                if pol == g.framework.sched_policy {
                    continue;
                }
                assert!(
                    n.iter().any(|p| {
                        p.groups[i].framework.sched_policy == pol
                            && p.groups[i].allocation == base.groups[i].allocation
                    }),
                    "missing flip of group {i} to {pol:?}"
                );
            }
        }
    }

    #[test]
    fn propose_identical_at_any_job_count() {
        // the deterministic-reduction contract: candidate scoring over 1
        // or 4 workers (and a warm vs cold cache) proposes the same plan
        let platform = CpuPlatform::large2();
        let initial = LanePlan::guideline(&platform, &[A, B]).unwrap();
        let mut plans = Vec::new();
        for jobs in [1usize, 4] {
            let cfg = OnlineTunerConfig { jobs, ..OnlineTunerConfig::default() };
            let mut t = OnlineTuner::with_config(platform.clone(), &[A, B], cfg);
            t.observe(&window(8, 72));
            let p = t.propose(&initial).unwrap().expect("strong shift re-plans");
            // a second propose on the same tuner re-scores through a warm
            // cache and must agree with itself
            assert_eq!(t.propose(&initial).unwrap().as_ref(), Some(&p));
            plans.push(p);
        }
        assert_eq!(plans[0], plans[1]);
    }

    #[test]
    fn replans_share_one_persistent_pool() {
        // the per-window pool-spawn fix: three proposes, at most one
        // worker-pool spawn for the life of the tuner
        let platform = CpuPlatform::large2();
        let cfg = OnlineTunerConfig { jobs: 4, ..OnlineTunerConfig::default() };
        let mut t = OnlineTuner::with_config(platform.clone(), &[A, B], cfg);
        let initial = LanePlan::guideline(&platform, &[A, B]).unwrap();
        t.observe(&window(8, 72));
        for _ in 0..3 {
            let _ = t.propose(&initial).unwrap();
        }
        assert!(t.sweep_pool().spawn_count() <= 1, "a pool was spawned per window");
    }

    #[test]
    fn unhosted_kind_scores_infinite() {
        let platform = CpuPlatform::large2();
        let mut t = OnlineTuner::new(platform.clone(), &[A, B]);
        t.observe(&window(40, 40));
        let only_a = LanePlan::guideline(&platform, &[A]).unwrap();
        assert!(t.score(&only_a).is_infinite());
    }
}
