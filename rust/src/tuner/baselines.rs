//! Baseline settings the paper evaluates against (Fig. 18).

use crate::config::{CpuPlatform, FrameworkConfig, OperatorImpl};

/// Which published recommendation to apply.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Baseline {
    /// TensorFlow performance guide [14]: MKL/intra-op threads = physical
    /// cores, inter-op pools = sockets.
    TensorFlowRecommended,
    /// Intel blog [3]: MKL/intra-op threads = physical cores per socket,
    /// inter-op pools = sockets.
    IntelRecommended,
    /// TensorFlow out-of-the-box: every knob = logical core count.
    TensorFlowDefault,
}

impl Baseline {
    /// All baselines in Fig. 18 order.
    pub const ALL: [Baseline; 3] = [
        Baseline::TensorFlowRecommended,
        Baseline::IntelRecommended,
        Baseline::TensorFlowDefault,
    ];

    /// Display name (also the canonical spelling in plan artifacts).
    pub fn name(&self) -> &'static str {
        match self {
            Baseline::TensorFlowRecommended => "TensorFlow-recommended",
            Baseline::IntelRecommended => "Intel-recommended",
            Baseline::TensorFlowDefault => "TensorFlow-default",
        }
    }

    /// Parse a baseline name (case-insensitive; accepts the canonical
    /// display spelling and short CLI aliases).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "tensorflow-recommended" | "tf-recommended" | "tf-rec" => {
                Some(Baseline::TensorFlowRecommended)
            }
            "intel-recommended" | "intel" => Some(Baseline::IntelRecommended),
            "tensorflow-default" | "tf-default" => Some(Baseline::TensorFlowDefault),
            _ => None,
        }
    }
}

/// Materialise a baseline on a platform. All baselines get the same
/// operator/library quality as the tuned setting — the comparison is about
/// threading knobs, not kernel quality.
pub fn baseline_config(b: Baseline, platform: &CpuPlatform) -> FrameworkConfig {
    let mut cfg = match b {
        Baseline::TensorFlowRecommended => FrameworkConfig::tensorflow_recommended(platform),
        Baseline::IntelRecommended => FrameworkConfig::intel_recommended(platform),
        Baseline::TensorFlowDefault => FrameworkConfig::tensorflow_default(platform),
    };
    cfg.operator_impl = OperatorImpl::IntraOpParallel;
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tf_recommended_overthreads_large2() {
        // 2 pools × (48+48) threads = 192 software threads on 96 logical —
        // the oversubscription the paper calls out
        let p = CpuPlatform::large2();
        let cfg = baseline_config(Baseline::TensorFlowRecommended, &p);
        assert!(cfg.over_threaded(&p));
    }

    #[test]
    fn intel_fits_hardware() {
        let p = CpuPlatform::large2();
        let cfg = baseline_config(Baseline::IntelRecommended, &p);
        assert!(!cfg.over_threaded(&p)); // 2 × (24+24) = 96 = logical cores
    }

    #[test]
    fn names_roundtrip_through_parse() {
        for b in Baseline::ALL {
            assert_eq!(Baseline::parse(b.name()), Some(b));
        }
        assert_eq!(Baseline::parse("intel"), Some(Baseline::IntelRecommended));
        assert_eq!(Baseline::parse("pytorch"), None);
    }

    #[test]
    fn tf_default_is_much_worse() {
        let p = CpuPlatform::large2();
        let cfg = baseline_config(Baseline::TensorFlowDefault, &p);
        assert_eq!(cfg.inter_op_pools, 96);
        assert_eq!(cfg.total_threads(), 96 * 192);
    }
}
