//! Serving metrics: latency histograms, throughput counters, queue-depth
//! gauges and per-kind windowed snapshots for the coordinator (and
//! anything else that wants cheap percentile tracking).
//!
//! Histograms are memory-bounded: past [`HISTOGRAM_RESERVOIR`] samples,
//! recording switches to reservoir sampling (algorithm R), so long soak
//! runs under the load generator hold a constant footprint while
//! percentiles stay representative of everything seen.
//! [`WindowTracker`] turns the cumulative per-kind counters into
//! per-window deltas — the signal the online re-tuner feeds on.

use std::collections::HashMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::prng::Prng;
use crate::util::stats;

/// Cap on samples a [`LatencyHistogram`] retains; recording beyond this
/// reservoir-samples uniformly over everything seen.
pub const HISTOGRAM_RESERVOIR: usize = 4096;

#[derive(Debug)]
struct Reservoir {
    samples: Vec<f64>,
    seen: u64,
    rng: Prng,
}

/// Thread-safe latency recorder with percentile queries and bounded
/// memory (uniform reservoir past [`HISTOGRAM_RESERVOIR`] samples).
#[derive(Debug)]
pub struct LatencyHistogram {
    inner: Mutex<Reservoir>,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// Empty histogram.
    pub fn new() -> Self {
        LatencyHistogram {
            inner: Mutex::new(Reservoir {
                samples: Vec::new(),
                seen: 0,
                rng: Prng::new(0x4857_6F67),
            }),
        }
    }

    /// Record one latency sample (seconds).
    pub fn record(&self, secs: f64) {
        let mut r = self.inner.lock().unwrap();
        r.seen += 1;
        if r.samples.len() < HISTOGRAM_RESERVOIR {
            r.samples.push(secs);
        } else {
            // algorithm R: keep each of the `seen` samples with equal
            // probability RESERVOIR/seen
            let seen = r.seen as usize;
            let j = r.rng.below(seen);
            if j < HISTOGRAM_RESERVOIR {
                r.samples[j] = secs;
            }
        }
    }

    /// Total samples recorded (not the retained subsample size).
    pub fn count(&self) -> usize {
        self.inner.lock().unwrap().seen as usize
    }

    /// Samples currently retained (≤ [`HISTOGRAM_RESERVOIR`]).
    pub fn retained(&self) -> usize {
        self.inner.lock().unwrap().samples.len()
    }

    /// Percentile (q in [0, 100]) over the retained subsample.
    pub fn percentile(&self, q: f64) -> f64 {
        stats::percentile(&self.inner.lock().unwrap().samples, q)
    }

    /// Mean latency over the retained subsample.
    pub fn mean(&self) -> f64 {
        stats::mean(&self.inner.lock().unwrap().samples)
    }

    /// Snapshot of the retained samples (for reports; a uniform
    /// subsample once more than [`HISTOGRAM_RESERVOIR`] were recorded).
    pub fn snapshot(&self) -> Vec<f64> {
        self.inner.lock().unwrap().samples.clone()
    }
}

/// Monotonic event counter.
#[derive(Debug, Default)]
pub struct Counter {
    n: AtomicU64,
}

impl Counter {
    /// Zeroed counter.
    pub fn new() -> Self {
        Self::default()
    }

    /// Increment by one; returns the new value.
    pub fn inc(&self) -> u64 {
        self.n.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Add `v`.
    pub fn add(&self, v: u64) {
        self.n.fetch_add(v, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.n.load(Ordering::Relaxed)
    }
}

/// Instantaneous level (queue depth, in-flight items): add/sub from any
/// thread, read anywhere. Reads clamp at zero.
#[derive(Debug, Default)]
pub struct Gauge {
    v: AtomicI64,
}

impl Gauge {
    /// Zeroed gauge.
    pub fn new() -> Self {
        Self::default()
    }

    /// Raise the level by `n`.
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n as i64, Ordering::Relaxed);
    }

    /// Lower the level by `n`.
    pub fn sub(&self, n: u64) {
        self.v.fetch_sub(n as i64, Ordering::Relaxed);
    }

    /// Current level (0 if transiently negative).
    pub fn get(&self) -> usize {
        self.v.load(Ordering::Relaxed).max(0) as usize
    }
}

/// Per-model-kind serving counters; arrivals vs completions per window
/// drive the online re-tuner.
#[derive(Debug, Default)]
pub struct KindCounters {
    /// Requests routed for this kind.
    pub arrivals: Counter,
    /// Requests answered (success or error) for this kind.
    pub completed: Counter,
    /// Batches dispatched for this kind.
    pub batches: Counter,
    /// Live (unpadded) items across those batches.
    pub batch_items: Counter,
}

/// Coordinator-wide metrics bundle.
#[derive(Debug, Default)]
pub struct ServingMetrics {
    /// End-to-end request latency: wall-clock queue time plus the
    /// carrying batch's model time (wall-clock on real backends,
    /// simulated seconds on the sim backend).
    pub request_latency: LatencyHistogram,
    /// Time spent waiting in the batching queue.
    pub queue_latency: LatencyHistogram,
    /// Model-execution time per dispatched batch.
    pub execute_latency: LatencyHistogram,
    /// Requests completed.
    pub requests: Counter,
    /// Batches dispatched.
    pub batches: Counter,
    /// Requests that had to be padded (batch bucket > actual).
    pub padded: Counter,
    per_kind: Mutex<HashMap<String, Arc<KindCounters>>>,
}

impl ServingMetrics {
    /// Fresh bundle.
    pub fn new() -> Self {
        Self::default()
    }

    /// Counters for one model kind (created on first touch). Steady
    /// state is a borrowed lookup — the `String` key is only allocated
    /// the first time a kind appears.
    pub fn kind(&self, kind: &str) -> Arc<KindCounters> {
        let mut g = self.per_kind.lock().unwrap();
        if let Some(c) = g.get(kind) {
            return Arc::clone(c);
        }
        Arc::clone(g.entry(kind.to_string()).or_default())
    }

    /// Pre-intern counters for a dense kind list: `out[i]` is the
    /// counter set for kind `names[i]` (the serving path resolves the
    /// whole [`crate::runtime::KindTable`] once at startup and indexes
    /// by `KindId` ever after — no string hashing per request).
    pub fn intern_kinds(&self, names: &[String]) -> Vec<Arc<KindCounters>> {
        names.iter().map(|n| self.kind(n)).collect()
    }

    /// Kinds that have recorded any activity, sorted.
    pub fn kinds_seen(&self) -> Vec<String> {
        let g = self.per_kind.lock().unwrap();
        let mut v: Vec<String> = g.keys().cloned().collect();
        v.sort_unstable();
        v
    }

    /// Mean requests per dispatched batch.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.get();
        if b == 0 {
            0.0
        } else {
            self.requests.get() as f64 / b as f64
        }
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "requests={} batches={} mean_batch={:.2} p50={:.3}ms p95={:.3}ms p99={:.3}ms",
            self.requests.get(),
            self.batches.get(),
            self.mean_batch_size(),
            self.request_latency.percentile(50.0) * 1e3,
            self.request_latency.percentile(95.0) * 1e3,
            self.request_latency.percentile(99.0) * 1e3,
        )
    }
}

/// One kind's activity over a closed window (counter deltas).
#[derive(Debug, Clone, PartialEq)]
pub struct KindWindow {
    /// Model kind.
    pub kind: String,
    /// Requests routed in the window.
    pub arrivals: u64,
    /// Requests answered in the window.
    pub completed: u64,
    /// Batches dispatched in the window.
    pub batches: u64,
    /// Live items across those batches.
    pub batch_items: u64,
}

impl KindWindow {
    /// Offered load over the window (requests/second).
    pub fn arrival_rate(&self, elapsed_s: f64) -> f64 {
        if elapsed_s > 0.0 {
            self.arrivals as f64 / elapsed_s
        } else {
            0.0
        }
    }

    /// Mean live items per dispatched batch in the window.
    pub fn batch_occupancy(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_items as f64 / self.batches as f64
        }
    }

    /// Arrivals not yet answered by window close (backlog growth).
    pub fn backlog(&self) -> i64 {
        self.arrivals as i64 - self.completed as i64
    }
}

/// One closed window of serving activity across all kinds.
#[derive(Debug, Clone)]
pub struct WindowSnapshot {
    /// Wall-clock length of the window (seconds).
    pub elapsed_s: f64,
    /// Per-kind deltas, sorted by kind.
    pub kinds: Vec<KindWindow>,
}

impl WindowSnapshot {
    /// The window for one kind, if it saw any activity ever.
    pub fn get(&self, kind: &str) -> Option<&KindWindow> {
        self.kinds.iter().find(|k| k.kind == kind)
    }

    /// Requests routed in the window, all kinds.
    pub fn total_arrivals(&self) -> u64 {
        self.kinds.iter().map(|k| k.arrivals).sum()
    }
}

/// Turns cumulative [`ServingMetrics`] counters into per-window deltas:
/// each [`WindowTracker::snapshot`] closes the window that began at the
/// previous call.
#[derive(Debug)]
pub struct WindowTracker {
    last: HashMap<String, [u64; 4]>,
    last_t: Instant,
}

impl Default for WindowTracker {
    fn default() -> Self {
        Self::new()
    }
}

impl WindowTracker {
    /// Open the first window now.
    pub fn new() -> Self {
        WindowTracker { last: HashMap::new(), last_t: Instant::now() }
    }

    /// Close the current window: per-kind deltas since the previous
    /// snapshot (or since construction).
    pub fn snapshot(&mut self, m: &ServingMetrics) -> WindowSnapshot {
        let now = Instant::now();
        let elapsed_s = now.duration_since(self.last_t).as_secs_f64();
        self.last_t = now;
        let mut kinds = Vec::new();
        for k in m.kinds_seen() {
            let c = m.kind(&k);
            let cur = [c.arrivals.get(), c.completed.get(), c.batches.get(), c.batch_items.get()];
            let prev = self.last.insert(k.clone(), cur).unwrap_or([0; 4]);
            kinds.push(KindWindow {
                kind: k,
                arrivals: cur[0].saturating_sub(prev[0]),
                completed: cur[1].saturating_sub(prev[1]),
                batches: cur[2].saturating_sub(prev[2]),
                batch_items: cur[3].saturating_sub(prev[3]),
            });
        }
        WindowSnapshot { elapsed_s, kinds }
    }
}

/// Simple stopwatch.
pub struct Timer(Instant);

impl Timer {
    /// Start timing.
    pub fn start() -> Self {
        Timer(Instant::now())
    }

    /// Elapsed seconds.
    pub fn elapsed_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles() {
        let h = LatencyHistogram::new();
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.count(), 100);
        assert!((h.percentile(50.0) - 50.5).abs() < 1.0);
        assert!(h.percentile(99.0) > 98.0);
        assert!((h.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn histogram_memory_bounded() {
        let h = LatencyHistogram::new();
        for i in 0..(HISTOGRAM_RESERVOIR * 4) {
            h.record(i as f64);
        }
        assert_eq!(h.count(), HISTOGRAM_RESERVOIR * 4);
        assert_eq!(h.retained(), HISTOGRAM_RESERVOIR);
        assert_eq!(h.snapshot().len(), HISTOGRAM_RESERVOIR);
        // the subsample still spans the distribution
        let p50 = h.percentile(50.0);
        let n = (HISTOGRAM_RESERVOIR * 4) as f64;
        assert!(p50 > n * 0.25 && p50 < n * 0.75, "p50={p50}");
    }

    #[test]
    fn counter_concurrent() {
        let c = std::sync::Arc::new(Counter::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let c = c.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 4000);
    }

    #[test]
    fn gauge_tracks_level() {
        let g = Gauge::new();
        g.add(5);
        g.sub(2);
        assert_eq!(g.get(), 3);
        g.sub(10);
        assert_eq!(g.get(), 0, "reads clamp at zero");
    }

    #[test]
    fn serving_summary_formats() {
        let m = ServingMetrics::new();
        m.requests.add(10);
        m.batches.add(4);
        m.request_latency.record(0.002);
        let s = m.summary();
        assert!(s.contains("requests=10"));
        assert!(s.contains("mean_batch=2.50"));
    }

    #[test]
    fn kind_counters_shared_and_listed() {
        let m = ServingMetrics::new();
        m.kind("wide_deep").arrivals.inc();
        m.kind("wide_deep").arrivals.inc();
        m.kind("resnet50").completed.inc();
        assert_eq!(m.kind("wide_deep").arrivals.get(), 2);
        assert_eq!(m.kinds_seen(), vec!["resnet50".to_string(), "wide_deep".to_string()]);
    }

    #[test]
    fn intern_kinds_shares_counters() {
        let m = ServingMetrics::new();
        let dense = m.intern_kinds(&["wide_deep".to_string(), "ncf".to_string()]);
        dense[1].arrivals.inc();
        // the dense slot and the string-keyed lookup are the same counters
        assert_eq!(m.kind("ncf").arrivals.get(), 1);
        assert_eq!(m.kind("wide_deep").arrivals.get(), 0);
    }

    #[test]
    fn window_tracker_deltas() {
        let m = ServingMetrics::new();
        let mut t = WindowTracker::new();
        m.kind("a").arrivals.add(10);
        m.kind("a").completed.add(8);
        m.kind("a").batches.add(4);
        m.kind("a").batch_items.add(8);
        let w1 = t.snapshot(&m);
        let a = w1.get("a").unwrap();
        assert_eq!(a.arrivals, 10);
        assert_eq!(a.backlog(), 2);
        assert_eq!(a.batch_occupancy(), 2.0);
        assert_eq!(w1.total_arrivals(), 10);

        // second window only sees the delta
        m.kind("a").arrivals.add(3);
        m.kind("b").arrivals.add(7);
        let w2 = t.snapshot(&m);
        assert_eq!(w2.get("a").unwrap().arrivals, 3);
        assert_eq!(w2.get("b").unwrap().arrivals, 7);
        assert_eq!(w2.get("a").unwrap().completed, 0);
        assert!(w2.elapsed_s >= 0.0);
    }

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(t.elapsed_s() >= 0.002);
    }
}
