//! Serving metrics: latency histograms and throughput counters for the
//! coordinator (and anything else that wants cheap percentile tracking).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats;

/// Thread-safe latency recorder with percentile queries.
#[derive(Debug, Default)]
pub struct LatencyHistogram {
    samples: Mutex<Vec<f64>>,
}

impl LatencyHistogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one latency sample (seconds).
    pub fn record(&self, secs: f64) {
        self.samples.lock().unwrap().push(secs);
    }

    /// Number of samples.
    pub fn count(&self) -> usize {
        self.samples.lock().unwrap().len()
    }

    /// Percentile (q in [0, 100]).
    pub fn percentile(&self, q: f64) -> f64 {
        stats::percentile(&self.samples.lock().unwrap(), q)
    }

    /// Mean latency.
    pub fn mean(&self) -> f64 {
        stats::mean(&self.samples.lock().unwrap())
    }

    /// Snapshot of all samples (for reports).
    pub fn snapshot(&self) -> Vec<f64> {
        self.samples.lock().unwrap().clone()
    }
}

/// Monotonic event counter.
#[derive(Debug, Default)]
pub struct Counter {
    n: AtomicU64,
}

impl Counter {
    /// Zeroed counter.
    pub fn new() -> Self {
        Self::default()
    }

    /// Increment by one; returns the new value.
    pub fn inc(&self) -> u64 {
        self.n.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Add `v`.
    pub fn add(&self, v: u64) {
        self.n.fetch_add(v, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.n.load(Ordering::Relaxed)
    }
}

/// Coordinator-wide metrics bundle.
#[derive(Debug, Default)]
pub struct ServingMetrics {
    /// End-to-end request latency: wall-clock queue time plus the
    /// carrying batch's model time (wall-clock on real backends,
    /// simulated seconds on the sim backend).
    pub request_latency: LatencyHistogram,
    /// Time spent waiting in the batching queue.
    pub queue_latency: LatencyHistogram,
    /// Model-execution time per dispatched batch.
    pub execute_latency: LatencyHistogram,
    /// Requests completed.
    pub requests: Counter,
    /// Batches dispatched.
    pub batches: Counter,
    /// Requests that had to be padded (batch bucket > actual).
    pub padded: Counter,
}

impl ServingMetrics {
    /// Fresh bundle.
    pub fn new() -> Self {
        Self::default()
    }

    /// Mean requests per dispatched batch.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.get();
        if b == 0 {
            0.0
        } else {
            self.requests.get() as f64 / b as f64
        }
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "requests={} batches={} mean_batch={:.2} p50={:.3}ms p95={:.3}ms p99={:.3}ms",
            self.requests.get(),
            self.batches.get(),
            self.mean_batch_size(),
            self.request_latency.percentile(50.0) * 1e3,
            self.request_latency.percentile(95.0) * 1e3,
            self.request_latency.percentile(99.0) * 1e3,
        )
    }
}

/// Simple stopwatch.
pub struct Timer(Instant);

impl Timer {
    /// Start timing.
    pub fn start() -> Self {
        Timer(Instant::now())
    }

    /// Elapsed seconds.
    pub fn elapsed_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles() {
        let h = LatencyHistogram::new();
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.count(), 100);
        assert!((h.percentile(50.0) - 50.5).abs() < 1.0);
        assert!(h.percentile(99.0) > 98.0);
        assert!((h.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn counter_concurrent() {
        let c = std::sync::Arc::new(Counter::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let c = c.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 4000);
    }

    #[test]
    fn serving_summary_formats() {
        let m = ServingMetrics::new();
        m.requests.add(10);
        m.batches.add(4);
        m.request_latency.record(0.002);
        let s = m.summary();
        assert!(s.contains("requests=10"));
        assert!(s.contains("mean_batch=2.50"));
    }

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(t.elapsed_s() >= 0.002);
    }
}
