//! Computational-graph IR.
//!
//! A model is a DAG of operator nodes (paper §2.2: "a node represents an
//! operator, and an edge indicates the dataflow dependencies"). The graph
//! exposes the two structural quantities the paper's analysis is built on:
//!
//! * **maximum width** — the largest number of heavy operators that can run
//!   simultaneously (Fig. 4's table),
//! * **average width** — `floor(heavy_ops / heavy_levels)`, the §8 quantity
//!   the tuner sets `inter_op_pools` to (Table 2),
//!
//! plus the **upward ranks** ([`rank`]) that drive critical-path-first
//! operator dispatch when the scheduling policy asks for it.

pub mod builder;
pub mod rank;
pub mod width;

pub use builder::GraphBuilder;
pub use rank::{dispatch_weight, upward_ranks};
pub use width::{WidthAnalysis, analyze_width};

use crate::error::PallasError;
use crate::ops::{OpCost, OpKind};

/// Node identifier (index into [`Graph::nodes`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub usize);

/// One operator in the graph.
#[derive(Debug, Clone)]
pub struct Node {
    /// Stable id == index in `Graph::nodes`.
    pub id: NodeId,
    /// Human-readable name ("conv2/3x3", "inception4a/b2/conv1x1", ...).
    pub name: String,
    /// Operator kind + shape.
    pub kind: OpKind,
    /// Derived cost descriptor.
    pub cost: OpCost,
    /// Dataflow dependencies (must finish before this node starts).
    pub deps: Vec<NodeId>,
}

impl Node {
    /// Heavy-operator classification (paper §8).
    pub fn is_heavy(&self) -> bool {
        OpCost::is_heavy(&self.kind)
    }
}

/// A computational graph for one model at one batch size.
#[derive(Debug, Clone)]
pub struct Graph {
    /// Model name ("inception_v2", ...).
    pub name: String,
    /// Batch size this instance was built for.
    pub batch: usize,
    /// Nodes in insertion order; edges point backwards (deps have smaller
    /// indices), so insertion order is already topological.
    pub nodes: Vec<Node>,
}

impl Graph {
    /// Number of operators.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// True when the graph has no operators.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Iterate nodes in topological (insertion) order.
    pub fn topo(&self) -> impl Iterator<Item = &Node> {
        self.nodes.iter()
    }

    /// Heavy operators only.
    pub fn heavy_nodes(&self) -> impl Iterator<Item = &Node> {
        self.nodes.iter().filter(|n| n.is_heavy())
    }

    /// Total FLOPs of one forward pass.
    pub fn total_flops(&self) -> f64 {
        self.nodes.iter().map(|n| n.cost.flops).sum()
    }

    /// Total bytes moved.
    pub fn total_bytes(&self) -> f64 {
        self.nodes.iter().map(|n| n.cost.total_bytes()).sum()
    }

    /// Consumers of each node (forward adjacency), built on demand.
    pub fn consumers(&self) -> Vec<Vec<NodeId>> {
        let mut out = vec![Vec::new(); self.nodes.len()];
        for n in &self.nodes {
            for d in &n.deps {
                out[d.0].push(n.id);
            }
        }
        out
    }

    /// Validate the DAG invariants (deps precede nodes, no dangling ids).
    pub fn validate(&self) -> Result<(), PallasError> {
        for (i, n) in self.nodes.iter().enumerate() {
            if n.id.0 != i {
                return Err(PallasError::InvalidGraph(format!("node {} id mismatch", i)));
            }
            for d in &n.deps {
                if d.0 >= i {
                    return Err(PallasError::InvalidGraph(format!(
                        "node '{}' depends on later/self node {}",
                        n.name, d.0
                    )));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::OpKind;

    fn diamond() -> Graph {
        let mut b = GraphBuilder::new("diamond", 1);
        let a = b.add("a", OpKind::MatMul { m: 512, k: 512, n: 512 }, &[]);
        let l = b.add("l", OpKind::MatMul { m: 512, k: 512, n: 512 }, &[a]);
        let r = b.add("r", OpKind::MatMul { m: 512, k: 512, n: 512 }, &[a]);
        b.add("join", OpKind::MatMul { m: 512, k: 512, n: 512 }, &[l, r]);
        b.build()
    }

    #[test]
    fn validates_topological_order() {
        assert!(diamond().validate().is_ok());
    }

    #[test]
    fn consumers_inverse_of_deps() {
        let g = diamond();
        let cons = g.consumers();
        assert_eq!(cons[0], vec![NodeId(1), NodeId(2)]);
        assert_eq!(cons[3], Vec::<NodeId>::new());
    }

    #[test]
    fn totals_accumulate() {
        let g = diamond();
        assert_eq!(g.total_flops(), 4.0 * 2.0 * 512f64.powi(3));
        assert!(g.total_bytes() > 0.0);
    }
}
