//! Graph-width analysis — the structural quantity behind the paper's
//! tuning guideline (§8).
//!
//! Heavy operators are layered by longest path *through heavy operators*:
//! `level(n) = 1 + max(level of heavy ancestors reachable through n's deps)`.
//! Light operators are transparent — they forward their heavy-ancestor level
//! without occupying a layer, mirroring how the paper counts only "heavy"
//! operators when measuring model width.
//!
//! * `max_width`  = max heavy ops on one level (Fig. 4's "maximum graph
//!   width": the most operators schedulable in parallel).
//! * `avg_width`  = `floor(total heavy ops / number of heavy levels)`,
//!   clamped to ≥ 1 (Table 2; e.g. Fig. 5b: `⌊7/3⌋ = 2`).

use super::Graph;

/// Result of the width analysis.
#[derive(Debug, Clone, PartialEq)]
pub struct WidthAnalysis {
    /// Heavy operators counted.
    pub heavy_ops: usize,
    /// Number of heavy levels (longest heavy chain length).
    pub levels: usize,
    /// Maximum number of heavy ops on one level.
    pub max_width: usize,
    /// `floor(heavy_ops / levels).max(1)` — the §8 average width.
    pub avg_width: usize,
    /// Heavy ops per level (index 0 = level 1).
    pub per_level: Vec<usize>,
}

/// Run the analysis on a graph.
pub fn analyze_width(g: &Graph) -> WidthAnalysis {
    // heavy_level[i]: level of node i if heavy; otherwise the max heavy
    // level among its ancestors (so light nodes are transparent).
    let mut carried = vec![0usize; g.len()];
    let mut per_level: Vec<usize> = Vec::new();
    let mut heavy_ops = 0usize;

    for n in g.topo() {
        let anc = n.deps.iter().map(|d| carried[d.0]).max().unwrap_or(0);
        if n.is_heavy() {
            let level = anc + 1;
            carried[n.id.0] = level;
            heavy_ops += 1;
            if per_level.len() < level {
                per_level.resize(level, 0);
            }
            per_level[level - 1] += 1;
        } else {
            carried[n.id.0] = anc;
        }
    }

    let levels = per_level.len();
    let max_width = per_level.iter().copied().max().unwrap_or(0);
    let avg_width = if levels == 0 { 1 } else { (heavy_ops / levels).max(1) };
    WidthAnalysis { heavy_ops, levels, max_width, avg_width, per_level }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::ops::OpKind;

    fn heavy() -> OpKind {
        OpKind::MatMul { m: 512, k: 512, n: 512 } // 268 MFLOPs > threshold
    }

    fn light() -> OpKind {
        OpKind::Elementwise { elems: 100, name: "ReLU" }
    }

    #[test]
    fn figure5b_example() {
        // The paper's worked example: 7 heavy convs over 3 layers → ⌊7/3⌋=2.
        // Four branches: [1], [1,1], [1,1,1], [1] laid out over 3 levels.
        let mut b = GraphBuilder::new("fig5b", 1);
        let src = b.add("in", light(), &[]);
        let b1 = b.add("b1", heavy(), &[src]);
        let b2a = b.add("b2a", heavy(), &[src]);
        let b2b = b.add("b2b", heavy(), &[b2a]);
        let b3a = b.add("b3a", heavy(), &[src]);
        let b3b = b.add("b3b", heavy(), &[b3a]);
        let b3c = b.add("b3c", heavy(), &[b3b]);
        let b4 = b.add("b4", heavy(), &[src]);
        b.add("concat", light(), &[b1, b2b, b3c, b4]);
        let w = analyze_width(&b.build());
        assert_eq!(w.heavy_ops, 7);
        assert_eq!(w.levels, 3);
        assert_eq!(w.max_width, 4);
        assert_eq!(w.avg_width, 2);
    }

    #[test]
    fn chain_has_width_one() {
        let mut b = GraphBuilder::new("chain", 1);
        let a = b.add("a", heavy(), &[]);
        let c = b.chain("c", heavy(), &[a], 5);
        b.add("out", light(), &[c]);
        let w = analyze_width(&b.build());
        assert_eq!((w.max_width, w.avg_width, w.levels), (1, 1, 6));
    }

    #[test]
    fn light_nodes_transparent() {
        // heavy -> light -> heavy still counts two levels
        let mut b = GraphBuilder::new("t", 1);
        let a = b.add("a", heavy(), &[]);
        let l = b.add("l", light(), &[a]);
        b.add("b", heavy(), &[l]);
        let w = analyze_width(&b.build());
        assert_eq!(w.levels, 2);
        assert_eq!(w.per_level, vec![1, 1]);
    }

    #[test]
    fn parallel_embeddings_ncf_shape() {
        // 4 embeddings + light MLP → avg width 4 (paper Table 2, NCF)
        let mut b = GraphBuilder::new("ncf-ish", 256);
        let ids = b.add("ids", light(), &[]);
        let embs: Vec<_> = (0..4)
            .map(|i| {
                b.add(
                    &format!("emb{i}"),
                    OpKind::Embedding { vocab: 100_000, dim: 64, rows: 256 },
                    &[ids],
                )
            })
            .collect();
        b.add("concat", light(), &embs);
        let w = analyze_width(&b.build());
        assert_eq!((w.levels, w.heavy_ops, w.avg_width, w.max_width), (1, 4, 4, 4));
    }

    #[test]
    fn empty_graph_defaults() {
        let b = GraphBuilder::new("empty", 1);
        let w = analyze_width(&b.build());
        assert_eq!((w.heavy_ops, w.levels, w.max_width, w.avg_width), (0, 0, 0, 1));
    }

    #[test]
    fn avg_width_floors() {
        // 3 heavy over 2 levels → floor(1.5) = 1
        let mut b = GraphBuilder::new("t", 1);
        let a = b.add("a", heavy(), &[]);
        let c = b.add("bb", heavy(), &[]);
        b.add("c", heavy(), &[a, c]);
        let w = analyze_width(&b.build());
        assert_eq!(w.avg_width, 1);
        assert_eq!(w.max_width, 2);
    }
}
