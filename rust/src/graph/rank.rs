//! Upward-rank priorities for critical-path-aware dispatch.
//!
//! HEFT's upward rank of a node is its own cost plus the costliest path
//! from it to a sink. Dispatching ready operators by descending rank keeps
//! the graph's critical path moving while off-path operators fill the
//! remaining pool slots — the ready-op priority lever of Liu et al.
//! (arXiv 1810.08955). Ranks are pure graph structure: they are computed
//! once per execution in a single reverse-topological sweep and consumed
//! by [`crate::sched::ReadyQueue`].

use crate::ops::OpCost;

use super::Graph;

/// Abstract dispatch cost of one operator: compute plus memory plus the
/// framework/library prep terms. Only the *relative ordering* matters for
/// scheduling priorities, so mixed units (FLOPs + bytes) are fine — both
/// translate to time within a small constant factor on the modelled
/// platforms.
pub fn dispatch_weight(cost: &OpCost) -> f64 {
    cost.flops + cost.total_bytes() + cost.prep_bytes + cost.lib_prep_bytes
}

/// Upward rank per node: `rank(n) = weight(n) + max over consumers c of
/// rank(c)` (0 for sinks). Nodes are stored in topological order (deps
/// have smaller ids), so one reverse sweep suffices.
pub fn upward_ranks(g: &Graph) -> Vec<f64> {
    let n = g.len();
    let mut rank = vec![0.0f64; n];
    // best[i] = max rank over i's consumers seen so far (consumers have
    // larger ids, so they are final by the time i is processed)
    let mut best = vec![0.0f64; n];
    for i in (0..n).rev() {
        let node = &g.nodes[i];
        let r = dispatch_weight(&node.cost) + best[i];
        rank[i] = r;
        for d in &node.deps {
            if r > best[d.0] {
                best[d.0] = r;
            }
        }
    }
    rank
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::ops::OpKind;

    fn mm(n: usize) -> OpKind {
        OpKind::MatMul { m: n, k: n, n }
    }

    #[test]
    fn chain_ranks_strictly_decrease() {
        let mut b = GraphBuilder::new("chain", 1);
        let a = b.add("a", mm(128), &[]);
        let c = b.chain("c", mm(128), &[a], 4);
        b.add("out", mm(128), &[c]);
        let g = b.build();
        let r = upward_ranks(&g);
        for w in r.windows(2) {
            assert!(w[0] > w[1], "{r:?}");
        }
    }

    #[test]
    fn longer_branch_outranks_shorter() {
        // a → {short: one op, long: three ops}; equal per-op cost
        let mut b = GraphBuilder::new("y", 1);
        let a = b.add("a", mm(128), &[]);
        let short = b.add("short", mm(128), &[a]);
        let l1 = b.add("l1", mm(128), &[a]);
        let l2 = b.add("l2", mm(128), &[l1]);
        let l3 = b.add("l3", mm(128), &[l2]);
        let g = b.build();
        let r = upward_ranks(&g);
        assert!(r[l1.0] > r[short.0], "{r:?}");
        assert!(r[a.0] > r[l1.0] && r[l1.0] > r[l2.0] && r[l2.0] > r[l3.0]);
        // sinks carry only their own weight
        assert_eq!(r[short.0], dispatch_weight(&g.nodes[short.0].cost));
    }

    #[test]
    fn ranks_finite_and_positive_on_zoo() {
        let g = crate::models::build("inception_v1", 16).unwrap();
        let r = upward_ranks(&g);
        assert_eq!(r.len(), g.len());
        assert!(r.iter().all(|x| x.is_finite() && *x >= 0.0));
    }
}
