//! Fluent graph construction used by the model zoo.

use crate::ops::{OpCost, OpKind};

use super::{Graph, Node, NodeId};

/// Builder that enforces topological insertion order.
pub struct GraphBuilder {
    name: String,
    batch: usize,
    nodes: Vec<Node>,
}

impl GraphBuilder {
    /// Start a graph for `name` at `batch`.
    pub fn new(name: &str, batch: usize) -> Self {
        GraphBuilder { name: name.to_string(), batch, nodes: Vec::new() }
    }

    /// Append an operator; `deps` must already exist.
    pub fn add(&mut self, name: &str, kind: OpKind, deps: &[NodeId]) -> NodeId {
        let id = NodeId(self.nodes.len());
        for d in deps {
            assert!(d.0 < id.0, "dep {} of '{}' not yet inserted", d.0, name);
        }
        let cost = OpCost::of(&kind);
        self.nodes.push(Node {
            id,
            name: name.to_string(),
            kind,
            cost,
            deps: deps.to_vec(),
        });
        id
    }

    /// Append a chain of `n` identical ops, each depending on the previous
    /// (first depends on `deps`). Returns the last id.
    pub fn chain(&mut self, base: &str, kind: OpKind, deps: &[NodeId], n: usize) -> NodeId {
        assert!(n > 0);
        let mut prev: Vec<NodeId> = deps.to_vec();
        let mut last = NodeId(0);
        for i in 0..n {
            last = self.add(&format!("{base}/{i}"), kind.clone(), &prev);
            prev = vec![last];
        }
        last
    }

    /// Number of nodes inserted so far.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// True if nothing inserted yet.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Finish; panics on invariant violations (programmer error in a model
    /// definition, not a runtime condition).
    pub fn build(self) -> Graph {
        let g = Graph { name: self.name, batch: self.batch, nodes: self.nodes };
        g.validate().expect("builder produced invalid graph");
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_links_sequentially() {
        let mut b = GraphBuilder::new("t", 1);
        let root = b.add("root", OpKind::Pool { elems: 10 }, &[]);
        let last = b.chain("c", OpKind::Pool { elems: 10 }, &[root], 3);
        let g = b.build();
        assert_eq!(g.len(), 4);
        assert_eq!(last, NodeId(3));
        assert_eq!(g.nodes[3].deps, vec![NodeId(2)]);
        assert_eq!(g.nodes[1].deps, vec![NodeId(0)]);
    }

    #[test]
    #[should_panic(expected = "not yet inserted")]
    fn rejects_forward_dep() {
        let mut b = GraphBuilder::new("t", 1);
        b.add("a", OpKind::Pool { elems: 1 }, &[NodeId(5)]);
    }
}
