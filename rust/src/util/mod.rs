//! Small in-tree utilities.
//!
//! The build environment is fully offline (only the `xla` crate's dependency
//! closure is vendored), so the pieces a crate would normally pull from
//! crates.io — a JSON codec, a seedable PRNG, descriptive statistics, a
//! micro-bench harness — live here instead.

pub mod bench;
pub mod json;
pub mod prng;
pub mod stats;
