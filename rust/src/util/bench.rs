//! Micro-bench harness (criterion is unavailable offline).
//!
//! Usage from a `harness = false` bench target:
//!
//! ```no_run
//! use parframe::util::bench::Bench;
//! let mut b = Bench::new("threadpool");
//! b.run("folly/10k-tasks", || { /* workload */ });
//! b.finish();
//! ```
//!
//! Each case is warmed up, then timed for a fixed wall-time budget; the
//! report prints mean / p50 / p95 / stddev per iteration, matching the
//! summary criterion would give.

use std::time::{Duration, Instant};

use super::stats;

/// One benchmark suite (a named group of cases).
pub struct Bench {
    name: String,
    /// (case name, per-iteration seconds)
    pub results: Vec<(String, Vec<f64>)>,
    /// Wall-clock budget per case.
    pub budget: Duration,
    /// Minimum measured iterations per case.
    pub min_iters: usize,
}

impl Bench {
    /// New suite with default budget (0.5 s per case, ≥10 iterations).
    pub fn new(name: &str) -> Self {
        // honor PARFRAME_BENCH_FAST=1 for CI smoke runs
        let fast = std::env::var("PARFRAME_BENCH_FAST").is_ok();
        Bench {
            name: name.to_string(),
            results: Vec::new(),
            budget: if fast { Duration::from_millis(50) } else { Duration::from_millis(500) },
            min_iters: if fast { 3 } else { 10 },
        }
    }

    /// Time one case; `f` is the workload for a single iteration.
    pub fn run<F: FnMut()>(&mut self, case: &str, mut f: F) {
        // warm-up
        f();
        f();
        let mut samples = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.budget || samples.len() < self.min_iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
            if samples.len() > 100_000 {
                break;
            }
        }
        self.report_case(case, &samples);
        self.results.push((case.to_string(), samples));
    }

    /// Time one case that returns a value (prevents dead-code elimination).
    pub fn run_with_output<T, F: FnMut() -> T>(&mut self, case: &str, mut f: F) {
        self.run(case, || {
            std::hint::black_box(f());
        });
    }

    fn report_case(&self, case: &str, samples: &[f64]) {
        println!(
            "{}/{:<40} iters={:<7} mean={} p50={} p95={} sd={}",
            self.name,
            case,
            samples.len(),
            fmt_t(stats::mean(samples)),
            fmt_t(stats::median(samples)),
            fmt_t(stats::percentile(samples, 95.0)),
            fmt_t(stats::stddev(samples)),
        );
    }

    /// Print the suite footer.
    pub fn finish(&self) {
        println!("bench suite '{}' done: {} cases", self.name, self.results.len());
    }
}

/// Human-format a duration in seconds.
pub fn fmt_t(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{:.3}s", secs)
    } else if secs >= 1e-3 {
        format!("{:.3}ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3}us", secs * 1e6)
    } else {
        format!("{:.1}ns", secs * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_records() {
        std::env::set_var("PARFRAME_BENCH_FAST", "1");
        let mut b = Bench::new("t");
        let mut counter = 0u64;
        b.run("noop", || {
            counter = counter.wrapping_add(1);
        });
        assert_eq!(b.results.len(), 1);
        assert!(b.results[0].1.len() >= 3);
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt_t(2.0), "2.000s");
        assert_eq!(fmt_t(2e-3), "2.000ms");
        assert_eq!(fmt_t(2e-6), "2.000us");
        assert_eq!(fmt_t(2e-9), "2.0ns");
    }
}
