//! Micro-bench harness (criterion is unavailable offline).
//!
//! Usage from a `harness = false` bench target:
//!
//! ```no_run
//! use parframe::util::bench::Bench;
//! let mut b = Bench::new("threadpool");
//! b.run("folly/10k-tasks", || { /* workload */ });
//! b.finish();
//! ```
//!
//! Each case is warmed up, then timed for a fixed wall-time budget; the
//! report prints mean / p50 / p95 / stddev per iteration, matching the
//! summary criterion would give. Besides the stdout rows, `finish`
//! emits a machine-readable `BENCH_<suite>.json` (see the README's
//! "Benchmark trajectory" section for the schema) so perf runs can be
//! committed and diffed across revisions.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use super::{json::Json, stats};

/// Version stamped into every emitted `BENCH_<suite>.json`; bump when
/// the shape of the document changes so stale committed files fail the
/// CI schema check instead of silently drifting.
pub const BENCH_SCHEMA_VERSION: u64 = 1;

/// Interpret the value of `PARFRAME_BENCH_FAST`.
///
/// Presence alone is NOT enough: `PARFRAME_BENCH_FAST=0` must run the
/// full budget (the seed checked `is_ok()`, so `=0` still enabled fast
/// mode). Empty, `0`, `false`, `no`, and `off` (any case) disable;
/// every other set value enables.
pub fn fast_flag(value: Option<&str>) -> bool {
    match value {
        None => false,
        Some(v) => {
            !matches!(v.trim().to_ascii_lowercase().as_str(), "" | "0" | "false" | "no" | "off")
        }
    }
}

/// One benchmark suite (a named group of cases).
pub struct Bench {
    name: String,
    /// (case name, per-iteration seconds)
    pub results: Vec<(String, Vec<f64>)>,
    /// (case name, value, unit) — custom metrics recorded with [`Bench::record`].
    pub records: Vec<(String, f64, String)>,
    /// (case name, samples, unit) — multi-sample metrics recorded with
    /// [`Bench::record_samples`]; get real `iters`/`p95`/`sd` columns.
    pub sampled: Vec<(String, Vec<f64>, String)>,
    /// Wall-clock budget per case.
    pub budget: Duration,
    /// Minimum measured iterations per case.
    pub min_iters: usize,
    fast: bool,
}

impl Bench {
    /// New suite with default budget (0.5 s per case, ≥10 iterations).
    /// `PARFRAME_BENCH_FAST=1` shrinks the budget for CI smoke runs.
    pub fn new(name: &str) -> Self {
        let fast = fast_flag(std::env::var("PARFRAME_BENCH_FAST").ok().as_deref());
        Bench {
            name: name.to_string(),
            results: Vec::new(),
            records: Vec::new(),
            sampled: Vec::new(),
            budget: if fast { Duration::from_millis(50) } else { Duration::from_millis(500) },
            min_iters: if fast { 3 } else { 10 },
            fast,
        }
    }

    /// Whether this suite is running under a truthy `PARFRAME_BENCH_FAST`.
    pub fn is_fast(&self) -> bool {
        self.fast
    }

    /// Time one case; `f` is the workload for a single iteration.
    pub fn run<F: FnMut()>(&mut self, case: &str, mut f: F) {
        // warm-up
        f();
        f();
        let mut samples = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.budget || samples.len() < self.min_iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
            if samples.len() > 100_000 {
                break;
            }
        }
        self.report_case(case, &samples);
        self.results.push((case.to_string(), samples));
    }

    /// Time one case that returns a value (prevents dead-code elimination).
    pub fn run_with_output<T, F: FnMut() -> T>(&mut self, case: &str, mut f: F) {
        self.run(case, || {
            std::hint::black_box(f());
        });
    }

    /// Record a custom single-shot metric (a whole-sweep wall time, a
    /// throughput in points/s, a speedup ratio, …) under `case`. It is
    /// printed alongside the timed rows and lands in the JSON with
    /// `iters = 1` and the given `unit`.
    pub fn record(&mut self, case: &str, value: f64, unit: &str) {
        println!("{}/{:<40} {value} {unit}", self.name, case);
        self.records.push((case.to_string(), value, unit.to_string()));
    }

    /// Record a custom metric measured more than once (e.g. a whole-sweep
    /// throughput re-timed over several full sweeps). Unlike [`Bench::record`]
    /// the JSON row carries `iters = samples.len()` and real `p95`/`sd`
    /// columns, so sweep-level cases are no longer single-shot statistics.
    pub fn record_samples(&mut self, case: &str, samples: Vec<f64>, unit: &str) {
        assert!(!samples.is_empty(), "record_samples needs at least one sample");
        println!(
            "{}/{:<40} iters={:<7} mean={:.6} p95={:.6} sd={:.6} {unit}",
            self.name,
            case,
            samples.len(),
            stats::mean(&samples),
            stats::percentile(&samples, 95.0),
            stats::stddev(&samples),
        );
        self.sampled.push((case.to_string(), samples, unit.to_string()));
    }

    fn report_case(&self, case: &str, samples: &[f64]) {
        println!(
            "{}/{:<40} iters={:<7} mean={} p50={} p95={} sd={}",
            self.name,
            case,
            samples.len(),
            fmt_t(stats::mean(samples)),
            fmt_t(stats::median(samples)),
            fmt_t(stats::percentile(samples, 95.0)),
            fmt_t(stats::stddev(samples)),
        );
    }

    /// Print the suite footer and emit `BENCH_<suite>.json` into the
    /// directory named by `PARFRAME_BENCH_OUT` (default: the current
    /// directory, i.e. the workspace root under `cargo bench`).
    pub fn finish(&self) {
        println!(
            "bench suite '{}' done: {} cases",
            self.name,
            self.results.len() + self.sampled.len() + self.records.len()
        );
        let dir = std::env::var("PARFRAME_BENCH_OUT").unwrap_or_else(|_| ".".to_string());
        match self.emit_to(Path::new(&dir)) {
            Ok(path) => println!("wrote {}", path.display()),
            Err(e) => eprintln!("bench: could not write BENCH_{}.json: {e}", self.name),
        }
    }

    /// Write the suite's JSON document into `dir`; returns the path.
    pub fn emit_to(&self, dir: &Path) -> std::io::Result<PathBuf> {
        let path = dir.join(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, super::json::to_string(&self.to_json()))?;
        Ok(path)
    }

    /// The suite as a [`Json`] document (schema v1).
    pub fn to_json(&self) -> Json {
        let case = |name: &str, iters: usize, samples: Option<&[f64]>, unit: &str| {
            let (mean, p50, p95, sd) = match samples {
                Some(s) => (
                    stats::mean(s),
                    stats::median(s),
                    stats::percentile(s, 95.0),
                    stats::stddev(s),
                ),
                None => (0.0, 0.0, 0.0, 0.0),
            };
            Json::Obj(
                [
                    ("name".to_string(), Json::Str(name.to_string())),
                    ("iters".to_string(), Json::Num(iters as f64)),
                    ("mean_s".to_string(), Json::Num(mean)),
                    ("p50_s".to_string(), Json::Num(p50)),
                    ("p95_s".to_string(), Json::Num(p95)),
                    ("sd_s".to_string(), Json::Num(sd)),
                    ("unit".to_string(), Json::Str(unit.to_string())),
                ]
                .into_iter()
                .collect(),
            )
        };
        let mut cases: Vec<Json> = self
            .results
            .iter()
            .map(|(name, samples)| case(name, samples.len(), Some(samples), "s"))
            .collect();
        for (name, samples, unit) in &self.sampled {
            cases.push(case(name, samples.len(), Some(samples), unit));
        }
        for (name, value, unit) in &self.records {
            let one = [*value];
            cases.push(case(name, 1, Some(&one), unit));
        }
        Json::Obj(
            [
                ("schema_version".to_string(), Json::Num(BENCH_SCHEMA_VERSION as f64)),
                ("suite".to_string(), Json::Str(self.name.clone())),
                ("git_rev".to_string(), Json::Str(git_rev())),
                ("timestamp".to_string(), Json::Num(unix_now())),
                ("fast".to_string(), Json::Bool(self.fast)),
                ("cases".to_string(), Json::Arr(cases)),
            ]
            .into_iter()
            .collect(),
        )
    }
}

/// The revision stamped into emitted documents: the `GIT_REV` env var
/// when set and non-empty (CI exports the build sha there — bench runs
/// in CI may execute outside the checkout, where `git` fails and the
/// seed emitted `"unknown"`), else `git rev-parse --short HEAD`, else
/// `"unknown"`.
fn git_rev() -> String {
    if let Ok(v) = std::env::var("GIT_REV") {
        let v = v.trim();
        if !v.is_empty() {
            return v.to_string();
        }
    }
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

fn unix_now() -> f64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs() as f64)
        .unwrap_or(0.0)
}

/// Human-format a duration in seconds.
pub fn fmt_t(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{:.3}s", secs)
    } else if secs >= 1e-3 {
        format!("{:.3}ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3}us", secs * 1e6)
    } else {
        format!("{:.1}ns", secs * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serializes the tests that mutate `PARFRAME_BENCH_FAST` — the
    /// test harness runs threads in one process sharing the env.
    static ENV_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn runs_and_records() {
        let _env = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        // fast mode on: presence with a truthy value
        std::env::set_var("PARFRAME_BENCH_FAST", "1");
        let mut b = Bench::new("t");
        assert!(b.is_fast(), "PARFRAME_BENCH_FAST=1 must enable fast mode");
        let mut counter = 0u64;
        b.run("noop", || {
            counter = counter.wrapping_add(1);
        });
        assert_eq!(b.results.len(), 1);
        assert!(b.results[0].1.len() >= 3);

        // the seed's `is_ok()` bug: `=0` still enabled fast mode. The
        // value must be parsed — "0" means a full run.
        std::env::set_var("PARFRAME_BENCH_FAST", "0");
        let full = Bench::new("t");
        assert!(!full.is_fast(), "PARFRAME_BENCH_FAST=0 must NOT enable fast mode");
        assert_eq!(full.budget, Duration::from_millis(500));
        assert_eq!(full.min_iters, 10);
        std::env::set_var("PARFRAME_BENCH_FAST", "1");
    }

    #[test]
    fn fast_flag_parses_values_not_presence() {
        assert!(!fast_flag(None));
        for off in ["", "0", "false", "FALSE", "no", "off", " 0 "] {
            assert!(!fast_flag(Some(off)), "{off:?} should disable fast mode");
        }
        for on in ["1", "true", "yes", "2", "fast"] {
            assert!(fast_flag(Some(on)), "{on:?} should enable fast mode");
        }
    }

    #[test]
    fn emits_schema_v1_json() {
        let _env = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        std::env::set_var("PARFRAME_BENCH_FAST", "1");
        let mut b = Bench::new("selftest");
        b.run("spin", || {
            std::hint::black_box(1 + 1);
        });
        b.record("ratio", 2.5, "x");
        let doc = Json::parse(&super::super::json::to_string(&b.to_json())).unwrap();
        assert_eq!(doc.get("schema_version").unwrap().as_f64(), Some(1.0));
        assert_eq!(doc.get("suite").unwrap().as_str(), Some("selftest"));
        assert!(doc.get("git_rev").unwrap().as_str().is_some());
        assert!(doc.get("timestamp").unwrap().as_f64().is_some());
        let cases = doc.get("cases").unwrap().as_arr().unwrap();
        assert_eq!(cases.len(), 2);
        let spin = &cases[0];
        assert_eq!(spin.get("name").unwrap().as_str(), Some("spin"));
        assert!(spin.get("iters").unwrap().as_usize().unwrap() >= 3);
        assert!(spin.get("mean_s").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(spin.get("unit").unwrap().as_str(), Some("s"));
        let ratio = &cases[1];
        assert_eq!(ratio.get("name").unwrap().as_str(), Some("ratio"));
        assert_eq!(ratio.get("iters").unwrap().as_usize(), Some(1));
        assert_eq!(ratio.get("mean_s").unwrap().as_f64(), Some(2.5));
        assert_eq!(ratio.get("sd_s").unwrap().as_f64(), Some(0.0));
        assert_eq!(ratio.get("unit").unwrap().as_str(), Some("x"));

        // emit_to writes a parseable file
        let dir = std::env::temp_dir().join(format!("parframe-bench-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = b.emit_to(&dir).unwrap();
        assert_eq!(path.file_name().unwrap(), "BENCH_selftest.json");
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(Json::parse(&text).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn record_samples_reports_real_iteration_stats() {
        let _env = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        std::env::set_var("PARFRAME_BENCH_FAST", "1");
        let mut b = Bench::new("samples");
        b.record_samples("sweep/x/serial-cold", vec![100.0, 110.0, 90.0], "points/s");
        let doc = Json::parse(&super::super::json::to_string(&b.to_json())).unwrap();
        let cases = doc.get("cases").unwrap().as_arr().unwrap();
        assert_eq!(cases.len(), 1);
        let row = &cases[0];
        assert_eq!(row.get("iters").unwrap().as_usize(), Some(3));
        assert_eq!(row.get("mean_s").unwrap().as_f64(), Some(100.0));
        assert_eq!(row.get("unit").unwrap().as_str(), Some("points/s"));
        // three distinct samples must surface as a nonzero spread
        assert!(row.get("sd_s").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn git_rev_env_override() {
        // GIT_REV (exported by CI) wins over shelling out to git, so
        // emitted documents carry a real revision even when the bench
        // runs outside a checkout
        let _env = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        std::env::set_var("GIT_REV", "cafef00d");
        let b = Bench::new("revtest");
        let doc = Json::parse(&super::super::json::to_string(&b.to_json())).unwrap();
        assert_eq!(doc.get("git_rev").unwrap().as_str(), Some("cafef00d"));
        // empty values fall through to the git / "unknown" chain
        std::env::set_var("GIT_REV", "  ");
        let doc = Json::parse(&super::super::json::to_string(&b.to_json())).unwrap();
        assert_ne!(doc.get("git_rev").unwrap().as_str(), Some("  "));
        std::env::remove_var("GIT_REV");
        let rev = git_rev();
        assert!(!rev.is_empty());
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt_t(2.0), "2.000s");
        assert_eq!(fmt_t(2e-3), "2.000ms");
        assert_eq!(fmt_t(2e-6), "2.000us");
        assert_eq!(fmt_t(2e-9), "2.0ns");
    }
}
