//! Descriptive statistics over f64 samples (latency distributions,
//! bench results, simulator outputs).

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated percentile (q in [0, 100]).
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (q / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

/// Median (p50).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Geometric mean; requires strictly-positive samples.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Min/max helpers that ignore NaN-free assumption violations gracefully.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

/// Maximum value (−∞ for empty input).
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn stddev_basic() {
        assert!((stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&v, 0.0), 10.0);
        assert_eq!(percentile(&v, 100.0), 40.0);
        assert_eq!(median(&v), 25.0);
    }

    #[test]
    fn geomean_of_ratios() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn min_max() {
        assert_eq!(min(&[3.0, 1.0, 2.0]), 1.0);
        assert_eq!(max(&[3.0, 1.0, 2.0]), 3.0);
    }
}
