//! Seedable PRNG (SplitMix64 + xoshiro256++) for workload generation and
//! the in-tree property-testing harness. Deterministic across platforms.

/// xoshiro256++ PRNG seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        };
        Prng { s: [next_sm(), next_sm(), next_sm(), next_sm()] }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform usize in `[0, n)`; `n` must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Exponentially-distributed value with the given mean (for Poisson
    /// request arrivals in the workload generator).
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = self.f64().max(1e-12);
        -mean * u.ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, v: &'a [T]) -> &'a T {
        &v[self.below(v.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Prng::new(1).next_u64(), Prng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut p = Prng::new(7);
        for _ in 0..10_000 {
            let x = p.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut p = Prng::new(9);
        for _ in 0..10_000 {
            assert!(p.below(13) < 13);
        }
    }

    #[test]
    fn range_inclusive() {
        let mut p = Prng::new(3);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let x = p.range(2, 5);
            assert!((2..=5).contains(&x));
            seen_lo |= x == 2;
            seen_hi |= x == 5;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn exp_mean_roughly_right() {
        let mut p = Prng::new(11);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| p.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut p = Prng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        p.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
