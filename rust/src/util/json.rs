//! Minimal JSON parser/writer.
//!
//! Covers the subset the artifact manifest and the config files need:
//! objects, arrays, strings (with `\uXXXX` escapes), numbers, booleans and
//! null. Not streaming, not zero-copy — the manifest is a few KiB.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Member lookup on an object; `None` for other variants.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric value truncated to usize.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// Borrowed string value.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    /// Borrowed array value.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v.as_slice()),
            _ => None,
        }
    }

    /// Borrowed object map (key → value), if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

/// Parse failure with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(c) => {
                    // copy a full UTF-8 sequence
                    let len = utf8_len(c);
                    let end = (self.i + len).min(self.b.len());
                    out.push_str(
                        std::str::from_utf8(&self.b[self.i..end])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                    self.i = end;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

/// Serialise a value to compact JSON text.
pub fn to_string(v: &Json) -> String {
    let mut s = String::new();
    write_value(v, &mut s);
    s
}

fn write_value(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{}", n));
            }
        }
        Json::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    '\r' => out.push_str("\\r"),
                    c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Json::Arr(a) => {
            out.push('[');
            for (i, e) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(e, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, e)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(&Json::Str(k.clone()), out);
                out.push(':');
                write_value(e, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null],"s":"hi\"there"}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&to_string(&v)).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn real_manifest_shape() {
        let doc = r#"{"version":1,"artifacts":[{"name":"mlp_b1","expected":{"sum":-3.25,"prefix":[0.1,-0.2]}}]}"#;
        let v = Json::parse(doc).unwrap();
        let a = &v.get("artifacts").unwrap().as_arr().unwrap()[0];
        assert_eq!(a.get("name").unwrap().as_str(), Some("mlp_b1"));
        assert_eq!(
            a.get("expected").unwrap().get("sum").unwrap().as_f64(),
            Some(-3.25)
        );
    }
}
