//! The crate's typed error — the single error type of the [`crate::api`]
//! facade.
//!
//! Before this module, failures crossed layer boundaries as bare
//! `String`s (`config::validate`) or opaque `anyhow` messages (loader,
//! tuner, runtime), so callers could neither match on what went wrong nor
//! trust the message shape. [`PallasError`] names every failure class the
//! public surface can produce; internal serving plumbing may still use
//! `anyhow` for thread-channel glue, and a `PallasError` flows into it
//! transparently (it implements [`std::error::Error`], which the vendored
//! `anyhow` shim blanket-converts).
//!
//! Taxonomy (documented in `DESIGN.md` §API layer):
//!
//! | variant          | meaning                                              |
//! |------------------|------------------------------------------------------|
//! | `InvalidConfig`  | framework knobs / config document rejected           |
//! | `UnknownModel`   | model name not in the zoo (or artifact set)          |
//! | `UnknownPlatform`| platform name not a Table-1 preset                   |
//! | `UnknownPolicy`  | dispatch-policy name not recognised                  |
//! | `InvalidGraph`   | computational-graph invariant violated               |
//! | `InvalidPlan`    | lane-plan/plan-artifact invariant violated           |
//! | `PlanMismatch`   | plan artifact targets a different platform           |
//! | `Parse`          | JSON / artifact-document parse failure               |
//! | `Io`             | file read/write failure (with the path)              |
//! | `Backend`        | execution-backend / serving-runtime failure          |
//! | `Cli`            | command-line usage error (unknown flag, bad value)   |

use std::fmt;

/// Every failure class the `parframe` public API can produce.
#[derive(Debug, Clone, PartialEq)]
pub enum PallasError {
    /// A framework setting or config document failed validation.
    InvalidConfig(String),
    /// A model name is not in the zoo (or served catalog).
    UnknownModel(String),
    /// A platform name is not one of the Table-1 presets.
    UnknownPlatform(String),
    /// A dispatch-policy name is not recognised.
    UnknownPolicy(String),
    /// A computational graph violated its DAG invariants.
    InvalidGraph(String),
    /// A lane plan or plan artifact violated its invariants.
    InvalidPlan(String),
    /// A serialized plan targets a different platform than the session.
    PlanMismatch {
        /// Platform the plan was tuned for.
        expected_platform: String,
        /// Platform it was applied to.
        got: String,
    },
    /// A document failed to parse (`what` names the document kind).
    Parse {
        /// Document kind ("json", "plan", "manifest", ...).
        what: String,
        /// Parser message.
        message: String,
    },
    /// A file operation failed.
    Io {
        /// Path involved.
        path: String,
        /// OS error message.
        message: String,
    },
    /// An execution backend or the serving runtime failed.
    Backend(String),
    /// Command-line usage error.
    Cli(String),
}

impl PallasError {
    /// Convenience constructor for file failures.
    pub fn io(path: impl fmt::Display, err: impl fmt::Display) -> Self {
        PallasError::Io { path: path.to_string(), message: err.to_string() }
    }

    /// Convenience constructor for parse failures.
    pub fn parse(what: impl Into<String>, err: impl fmt::Display) -> Self {
        PallasError::Parse { what: what.into(), message: err.to_string() }
    }
}

impl fmt::Display for PallasError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PallasError::InvalidConfig(m) => write!(f, "invalid config: {m}"),
            PallasError::UnknownModel(m) => write!(f, "unknown model '{m}'"),
            PallasError::UnknownPlatform(p) => {
                write!(f, "unknown platform '{p}' (small | large | large.2)")
            }
            PallasError::UnknownPolicy(p) => {
                write!(f, "unknown policy '{p}' (topo | critical-path | costly)")
            }
            PallasError::InvalidGraph(m) => write!(f, "invalid graph: {m}"),
            PallasError::InvalidPlan(m) => write!(f, "invalid plan: {m}"),
            PallasError::PlanMismatch { expected_platform, got } => write!(
                f,
                "plan mismatch: plan was tuned for platform '{expected_platform}', \
                 applied to '{got}'"
            ),
            PallasError::Parse { what, message } => write!(f, "{what} parse error: {message}"),
            PallasError::Io { path, message } => write!(f, "{path}: {message}"),
            PallasError::Backend(m) => write!(f, "backend error: {m}"),
            PallasError::Cli(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for PallasError {}

/// Result alias over [`PallasError`] — the facade's return type.
pub type PallasResult<T> = Result<T, PallasError>;

impl From<crate::util::json::JsonError> for PallasError {
    fn from(e: crate::util::json::JsonError) -> Self {
        PallasError::parse("json", e)
    }
}

// Internal serving plumbing (coordinator channels, loadgen) still speaks
// `anyhow`; the facade folds those failures into `Backend`. The reverse
// direction needs no impl: `PallasError: std::error::Error`, which the
// vendored shim's blanket `From` already converts.
impl From<anyhow::Error> for PallasError {
    fn from(e: anyhow::Error) -> Self {
        PallasError::Backend(format!("{e:#}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_failure_class() {
        assert_eq!(
            PallasError::UnknownModel("bert".into()).to_string(),
            "unknown model 'bert'"
        );
        assert!(PallasError::InvalidConfig("x".into()).to_string().contains("invalid config"));
        let pm = PallasError::PlanMismatch {
            expected_platform: "large.2".into(),
            got: "small".into(),
        };
        let s = pm.to_string();
        assert!(s.contains("large.2") && s.contains("small"), "{s}");
    }

    #[test]
    fn converts_into_anyhow_via_question_mark() {
        fn inner() -> PallasResult<()> {
            Err(PallasError::UnknownPlatform("tpu".into()))
        }
        fn outer() -> anyhow::Result<()> {
            inner()?;
            Ok(())
        }
        let e = outer().unwrap_err();
        assert!(e.to_string().contains("tpu"));
    }

    #[test]
    fn converts_from_anyhow() {
        let e: PallasError = anyhow::anyhow!("lane died").into();
        assert_eq!(e, PallasError::Backend("lane died".into()));
    }

    #[test]
    fn json_errors_become_parse() {
        let e: PallasError = crate::util::json::Json::parse("{").unwrap_err().into();
        assert!(matches!(e, PallasError::Parse { ref what, .. } if what == "json"));
    }
}
